"""L2 correctness: the split pipeline must equal the monolithic model.

The decisive invariant: running the five-part split contract (part1_fwd →
part2_fwd → part3_bwd → part2_bwd → part1_bwd) and applying SGD per part
must produce *exactly* the same loss and updated parameters as
`jax.value_and_grad` of the full model — i.e. split learning is a
re-factoring of backprop, not an approximation (the paper's premise that
the orchestration does not affect accuracy).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, model


def _batch(batch=4, seed=0):
    rng = np.random.default_rng(seed)
    x, y = data.make_batch(rng, batch)
    return jnp.asarray(x), jnp.asarray(y)


@pytest.mark.parametrize("arch", ["vgg_mini", "resnet_mini"])
def test_part_shapes_compose(arch):
    params = model.init_params(arch)
    p1, p2, p3 = model.split_params(arch, params)
    fns = model.make_part_fns(arch, use_pallas=False)
    x, y = _batch()
    a1 = fns["part1_fwd"](p1, x)
    a2 = fns["part2_fwd"](p2, a1)
    loss = fns["part3_loss"](p3, a2, y)
    assert a1.ndim == 4 and a2.ndim >= 2
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ["vgg_mini", "resnet_mini"])
def test_split_forward_equals_full_forward(arch):
    params = model.init_params(arch)
    p1, p2, p3 = model.split_params(arch, params)
    fns = model.make_part_fns(arch, use_pallas=False)
    x, _ = _batch()
    n = len(model.ARCHS[arch]["layers"])
    s2 = fns["cuts"][1]
    a2 = fns["part2_fwd"](p2, fns["part1_fwd"](p1, x))
    logits_split = model.forward_range(arch, p3, a2, s2, n, use_pallas=False)
    logits_full = model.full_forward(arch, params, x, use_pallas=False)
    np.testing.assert_allclose(logits_split, logits_full, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", ["vgg_mini", "resnet_mini"])
def test_split_gradients_equal_full_gradients(arch):
    """The split backprop chain == autodiff of the whole network."""
    params = model.init_params(arch)
    p1, p2, p3 = model.split_params(arch, params)
    fns = model.make_part_fns(arch, use_pallas=False)
    x, y = _batch()

    a1 = fns["part1_fwd"](p1, x)
    a2 = fns["part2_fwd"](p2, a1)
    loss_split, g3, g_a2 = fns["part3_bwd"](p3, a2, y)
    g2, g_a1 = fns["part2_bwd"](p2, a1, g_a2)
    g1 = fns["part1_bwd"](p1, x, g_a1)

    def full_loss(ps):
        return model.loss_fn(model.full_forward(arch, ps, x, use_pallas=False), y)

    loss_full, grads_full = jax.value_and_grad(full_loss)(params)
    s1, s2 = fns["cuts"]
    gf1, gf2, gf3 = grads_full[:s1], grads_full[s1:s2], grads_full[s2:]

    np.testing.assert_allclose(float(loss_split), float(loss_full), rtol=1e-6)
    for got_tree, want_tree, tag in [(g1, gf1, "p1"), (g2, gf2, "p2"), (g3, gf3, "p3")]:
        got = jax.tree_util.tree_leaves(got_tree)
        want = jax.tree_util.tree_leaves(want_tree)
        assert len(got) == len(want), tag
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-5, err_msg=tag)


def test_pallas_and_ref_paths_agree_through_part2():
    """part2_fwd with the Pallas kernel == part2_fwd with lax.conv."""
    arch = "vgg_mini"
    params = model.init_params(arch)
    _, p2, _ = model.split_params(arch, params)
    x, _ = _batch()
    p1, _, _ = model.split_params(arch, params)
    fns_pl = model.make_part_fns(arch, use_pallas=True)
    fns_ref = model.make_part_fns(arch, use_pallas=False)
    a1 = fns_ref["part1_fwd"](p1, x)
    out_pl = fns_pl["part2_fwd"](p2, a1)
    out_ref = fns_ref["part2_fwd"](p2, a1)
    np.testing.assert_allclose(out_pl, out_ref, rtol=1e-4, atol=1e-4)


def test_loss_decreases_over_steps():
    """A few SGD steps on the synthetic data must reduce the loss —
    the build-time smoke of the training contract (the full few-hundred-
    step run lives in examples/e2e_train.rs on the rust side)."""
    arch = "vgg_mini"
    params = model.init_params(arch)
    rng = np.random.default_rng(7)
    losses = []
    for step in range(8):
        x, y = data.make_batch(rng, 16)
        loss, params = model.reference_train_step(arch, params, jnp.asarray(x), jnp.asarray(y), lr=0.05)
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"loss did not fall: {losses}"


def test_loss_fn_matches_manual_cross_entropy():
    logits = jnp.asarray([[2.0, 0.0, -1.0], [0.5, 0.5, 0.5]])
    y = jnp.asarray([0, 2], jnp.int32)
    got = float(model.loss_fn(logits, y))
    p = jax.nn.softmax(logits)
    want = float(-(jnp.log(p[0, 0]) + jnp.log(p[1, 2])) / 2)
    assert abs(got - want) < 1e-6


@pytest.mark.parametrize("arch", ["vgg_mini", "resnet_mini"])
def test_default_cuts_valid(arch):
    n = len(model.ARCHS[arch]["layers"])
    s1, s2 = model.ARCHS[arch]["default_cuts"]
    assert 1 <= s1 < s2 < n
