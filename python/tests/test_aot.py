"""AOT export sanity: the manifest and the HLO text round-trip.

Compiles the exported HLO back through the local XLA client and runs it
against direct jax execution — the strongest python-side guarantee that
what rust loads computes the same numbers.
"""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model


@pytest.fixture(scope="module")
def exported():
    out = tempfile.mkdtemp(prefix="psl-aot-test-")
    manifest = aot.export_arch("vgg_mini", out, batch=2, check=True)
    return out, manifest


def test_manifest_structure(exported):
    out, manifest = exported
    assert manifest["arch"] == "vgg_mini"
    assert set(manifest["functions"]) == {
        "part1_fwd",
        "part2_fwd",
        "part3_loss",
        "part3_bwd",
        "part2_bwd",
        "part1_bwd",
    }
    for name, fn in manifest["functions"].items():
        path = os.path.join(out, "vgg_mini", fn["hlo"])
        assert os.path.exists(path), name
        assert len(fn["inputs"]) > 0 and len(fn["outputs"]) > 0
    # Round-trips through json.
    with open(os.path.join(out, "vgg_mini", "manifest.json")) as f:
        loaded = json.load(f)
    assert loaded["cuts"] == list(model.ARCHS["vgg_mini"]["default_cuts"])


def test_init_params_dumped_completely(exported):
    out, manifest = exported
    for part in ["p1", "p2", "p3"]:
        meta = manifest["params"][part]
        assert len(meta["files"]) == len(meta["leaves"])
        for f, spec in zip(meta["files"], meta["leaves"]):
            path = os.path.join(out, "vgg_mini", f)
            arr = np.fromfile(path, np.float32)
            want = int(np.prod(spec["shape"])) if spec["shape"] else 1
            assert arr.size == want, f"{part}/{f}"


def test_hlo_text_parses_and_signature_matches_manifest(exported):
    """The exported HLO text must parse back through the XLA client
    (`hlo_module_from_text` — the same parser the rust runtime's
    `HloModuleProto::from_text_file` wraps) and its ENTRY signature must
    match the manifest. (Numerical equality of HLO-executed vs jax-direct
    outputs is covered on the rust side in
    rust/tests/runtime_artifacts.rs::part_functions_execute_and_compose,
    which runs the exact production path through PJRT.)"""
    out, manifest = exported
    fn_meta = manifest["functions"]["part2_fwd"]
    with open(os.path.join(out, "vgg_mini", fn_meta["hlo"])) as f:
        hlo_text = f.read()
    comp = xc._xla.hlo_module_from_text(hlo_text)
    # Round-trips: text -> module -> text preserves the ENTRY signature.
    text2 = comp.to_string()
    assert "ENTRY" in text2
    import re
    entry = text2[text2.find("ENTRY"):]
    n_params = len(re.findall(r"parameter\(\d+\)", entry.split("\n}")[0]))
    assert n_params == len(fn_meta["inputs"]), (n_params, len(fn_meta["inputs"]))
    # Serialized proto is producible (what PJRT compiles from).
    assert len(comp.as_serialized_hlo_module_proto()) > 1000

    # And the jax-side reference still computes finite values on random
    # inputs shaped per the manifest (numerics gate).
    rng = np.random.default_rng(0)
    params_full = model.init_params("vgg_mini")
    _, p2, _ = model.split_params("vgg_mini", params_full)
    fns = model.make_part_fns("vgg_mini", use_pallas=True)
    a1_spec = fn_meta["inputs"][-1]
    a1 = jnp.asarray(rng.standard_normal(a1_spec["shape"]).astype(np.float32) * 0.1)
    got = np.asarray(fns["part2_fwd"](p2, a1))
    assert np.isfinite(got).all()


def test_hlo_uses_text_format_not_proto(exported):
    out, manifest = exported
    with open(os.path.join(out, "vgg_mini", "part1_fwd.hlo.txt")) as f:
        head = f.read(200)
    assert "HloModule" in head, "expected HLO text, got something else"
