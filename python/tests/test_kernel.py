"""L1 correctness: the Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes; fixed cases pin the shapes the exported
artifacts actually use. This is the CORE build-time correctness signal —
if these fail, `make artifacts` must not ship.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_block, ref


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# fused_matmul_bias_act
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("activation", ["relu", "none"])
@pytest.mark.parametrize(
    "m,k,n",
    [
        (8, 16, 8),
        (128, 144, 16),  # conv1 shape class (9*16 channels)
        (256, 288, 32),
        (64, 576, 64),  # conv 64->64 im2col
        (1, 9, 1),
        (33, 7, 5),  # deliberately tile-unfriendly
    ],
)
def test_matmul_matches_ref(m, k, n, activation):
    a, b = rand(1, (m, k)), rand(2, (k, n))
    bias = rand(3, (n,))
    got = fused_block.fused_matmul_bias_act(a, b, bias, activation=activation)
    want = ref.matmul_bias_act(a, b, bias, activation=activation)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 64),
    n=st.integers(1, 48),
    act=st.sampled_from(["relu", "none"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_sweep(m, k, n, act, seed):
    key = jax.random.PRNGKey(seed)
    ka, kb, kc = jax.random.split(key, 3)
    a = jax.random.normal(ka, (m, k), jnp.float32)
    b = jax.random.normal(kb, (k, n), jnp.float32)
    bias = jax.random.normal(kc, (n,), jnp.float32)
    got = fused_block.fused_matmul_bias_act(a, b, bias, activation=act)
    want = ref.matmul_bias_act(a, b, bias, activation=act)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_matmul_rejects_bad_shapes():
    with pytest.raises(AssertionError):
        fused_block.fused_matmul_bias_act(rand(1, (4, 5)), rand(2, (6, 3)), rand(3, (3,)))
    with pytest.raises(AssertionError):
        fused_block.fused_matmul_bias_act(rand(1, (4, 5)), rand(2, (5, 3)), rand(3, (4,)))


def test_relu_actually_clamps():
    a = -jnp.ones((4, 4), jnp.float32)
    b = jnp.eye(4, dtype=jnp.float32)
    bias = jnp.zeros((4,), jnp.float32)
    out = fused_block.fused_matmul_bias_act(a, b, bias, activation="relu")
    assert np.all(np.asarray(out) == 0.0)
    out2 = fused_block.fused_matmul_bias_act(a, b, bias, activation="none")
    assert np.all(np.asarray(out2) == -1.0)


# ---------------------------------------------------------------------------
# fused_conv3x3_relu
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,h,w,cin,cout",
    [
        (2, 8, 8, 3, 16),
        (1, 32, 32, 3, 16),  # part-1 entry shape
        (2, 16, 16, 16, 32),
        (1, 4, 4, 8, 8),
    ],
)
def test_conv_matches_lax(b, h, w, cin, cout):
    x = rand(4, (b, h, w, cin))
    wgt = rand(5, (3, 3, cin, cout)) * 0.2
    bias = rand(6, (cout,)) * 0.1
    got = fused_block.fused_conv3x3_relu(x, wgt, bias)
    want = ref.conv3x3_relu(x, wgt, bias)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 3),
    hw=st.sampled_from([4, 6, 8, 12]),
    cin=st.sampled_from([1, 3, 8, 16]),
    cout=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_hypothesis_sweep(b, hw, cin, cout, seed):
    key = jax.random.PRNGKey(seed)
    kx, kw, kb = jax.random.split(key, 3)
    x = jax.random.normal(kx, (b, hw, hw, cin), jnp.float32)
    wgt = jax.random.normal(kw, (3, 3, cin, cout), jnp.float32) * 0.2
    bias = jax.random.normal(kb, (cout,), jnp.float32) * 0.1
    got = fused_block.fused_conv3x3_relu(x, wgt, bias)
    want = ref.conv3x3_relu(x, wgt, bias)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_im2col_patch_order_matches_weight_reshape():
    # A 'delta' filter that picks patch position (dy, dx) must equal a
    # shifted image — proves the (dy, dx, c) ordering contract.
    x = rand(7, (1, 6, 6, 2))
    for dy in range(3):
        for dx in range(3):
            w = np.zeros((3, 3, 2, 2), np.float32)
            w[dy, dx, 0, 0] = 1.0
            w[dy, dx, 1, 1] = 1.0
            got = fused_block.fused_conv3x3_relu(x, jnp.asarray(w), jnp.zeros((2,), jnp.float32), activation="none")
            want = ref.conv3x3_relu(x, jnp.asarray(w), jnp.zeros((2,), jnp.float32), activation="none")
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_kernel_is_jittable_and_grads_flow():
    # The kernel must be differentiable (it sits inside part-2 bwd).
    x = rand(8, (2, 4, 4, 3))
    w = rand(9, (3, 3, 3, 4)) * 0.2
    b = jnp.zeros((4,), jnp.float32)

    def f(w):
        return fused_block.fused_conv3x3_relu(x, w, b).sum()

    g = jax.grad(f)(w)
    assert np.all(np.isfinite(np.asarray(g)))
    assert np.abs(np.asarray(g)).sum() > 0


# ---------------------------------------------------------------------------
# Roofline accounting helpers (DESIGN.md §Perf inputs)
# ---------------------------------------------------------------------------


def test_vmem_estimate_within_budget():
    # The largest exported matmul: conv 64->64 at batch 16 on 8x8 maps to
    # M=1024, K=576, N=64. One instance must fit a 16 MiB VMEM budget.
    bytes_ = fused_block.vmem_bytes_per_instance(1024, 576, 64)
    assert bytes_ < 16 * 1024 * 1024, f"VMEM estimate {bytes_}"


def test_mxu_estimate_monotone_in_tile_fill():
    low = fused_block.mxu_utilization_estimate(8, 9, 8)
    high = fused_block.mxu_utilization_estimate(1024, 576, 128)
    assert 0.0 < low < high <= 1.0
