"""HLO static analysis sanity (compile.aot_report)."""

import os

import pytest

from compile import aot_report

ARTIFACTS = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))


def test_analyze_hlo_counts_ops():
    hlo = """
HloModule test
ENTRY %main (p0: f32[4,8], p1: f32[8,16]) -> f32[4,16] {
  p0 = f32[4,8]{1,0} parameter(0)
  p1 = f32[8,16]{1,0} parameter(1)
  ROOT d = f32[4,16]{1,0} dot(p0, p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    r = aot_report.analyze_hlo(hlo)
    assert r["dots"] == 1
    assert r["ops"]["parameter"] == 2
    # 2*M*N*K = 2*4*16*8 = 1024 FLOPs.
    assert r["flops_est"] == 2 * 4 * 16 * 8
    assert r["param_bytes"] == 4 * (4 * 8 + 8 * 16)


@pytest.mark.skipif(not os.path.isdir(os.path.join(ARTIFACTS, "vgg_mini")), reason="run `make artifacts` first")
def test_exported_artifacts_contain_dot_work():
    rep = aot_report.report(ARTIFACTS)
    assert "vgg_mini" in rep
    fns = rep["vgg_mini"]
    # part2 fwd must carry the conv matmuls (the Pallas kernel's dots;
    # convs sharing a tile shape fold into shared loop bodies, so ≥4).
    assert fns["part2_fwd"]["dots"] >= 4, fns["part2_fwd"]["ops"]
    assert fns["part2_fwd"]["flops_est"] > 1e6
    # bwd carries ~2-3x the fwd dots (dA and dW per conv, custom VJP).
    assert fns["part2_bwd"]["dots"] >= 2 * fns["part2_fwd"]["dots"]
    # Every artifact parses and has instructions.
    for name, r in fns.items():
        assert r["n_instructions"] > 3, name
    # resnet_mini's part-2 uses lax convolutions instead of the kernel.
    assert rep["resnet_mini"]["part2_fwd"]["ops"].get("convolution", 0) >= 6
