"""Synthetic dataset sanity + cross-language contract with
rust/src/data/synth.rs (same template family; exact template parity is
asserted structurally — frequencies/phases are functions of (k, ch))."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data


def test_templates_deterministic_and_distinct():
    a = data.class_template(0)
    b = data.class_template(0)
    np.testing.assert_array_equal(a, b)
    for k in range(1, data.NUM_CLASSES):
        assert np.abs(data.class_template(k) - a).sum() > 10.0


def test_template_range_bounded():
    for k in range(data.NUM_CLASSES):
        t = data.class_template(k)
        assert np.all(np.abs(t) <= 0.5 + 1e-6)
        assert t.shape == data.SHAPE
        assert t.dtype == np.float32


@settings(max_examples=10, deadline=None)
@given(batch=st.integers(1, 64), seed=st.integers(0, 1000))
def test_batch_shapes_and_labels(batch, seed):
    rng = np.random.default_rng(seed)
    x, y = data.make_batch(rng, batch)
    assert x.shape == (batch, 32, 32, 3)
    assert y.shape == (batch,)
    assert y.dtype == np.int32
    assert np.all((0 <= y) & (y < data.NUM_CLASSES))
    assert np.isfinite(x).all()


def test_noise_scales():
    rng1 = np.random.default_rng(0)
    rng2 = np.random.default_rng(0)
    x_lo, y1 = data.make_batch(rng1, 16, noise=0.01)
    x_hi, y2 = data.make_batch(rng2, 16, noise=1.0)
    np.testing.assert_array_equal(y1, y2)
    # Residual energy after subtracting templates scales with noise.
    res = lambda x, y: np.mean([(x[b] - data.class_template(int(y[b]))) ** 2 for b in range(len(y))])
    assert res(x_hi, y2) > 50 * res(x_lo, y1)


def test_classes_linearly_separable_enough():
    """A trivial nearest-template classifier must beat chance by a lot —
    the property the e2e loss-curve relies on."""
    rng = np.random.default_rng(3)
    x, y = data.make_batch(rng, 200, noise=0.35)
    templates = np.stack([data.class_template(k) for k in range(10)])
    preds = []
    for b in range(len(y)):
        d = ((templates - x[b]) ** 2).sum(axis=(1, 2, 3))
        preds.append(int(d.argmin()))
    acc = float(np.mean(np.asarray(preds) == y))
    assert acc > 0.9, f"nearest-template accuracy {acc}"
