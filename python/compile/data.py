"""Synthetic CIFAR-10-like dataset (build-time / test-time).

CIFAR-10 itself is not downloadable in this image, and the paper's
orchestration layer is explicitly accuracy-oblivious (§III: "the resulting
model accuracy is not affected"), so the end-to-end training example only
needs a dataset on which the split pipeline demonstrably *learns*. We use
class-conditional signals: each class k has a deterministic low-frequency
template; samples are template + Gaussian noise. A linear-ish model
separates them, and the loss curve of the split pipeline must fall.

The rust runtime embeds the same generator (rust/src/data/synth.rs) so the
request path never touches python.
"""

import numpy as np

NUM_CLASSES = 10
SHAPE = (32, 32, 3)


def class_template(k: int) -> np.ndarray:
    """Deterministic template for class k: 2-D sinusoid mixtures whose
    frequencies/phases are simple functions of k (matches synth.rs)."""
    h, w, c = SHAPE
    yy, xx = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
    out = np.zeros(SHAPE, np.float32)
    for ch in range(c):
        fx = 1.0 + (k % 5)
        fy = 1.0 + ((k + ch) % 3)
        phase = 0.7 * k + 1.3 * ch
        out[:, :, ch] = np.sin(2 * np.pi * fx * xx / w + phase) * np.cos(
            2 * np.pi * fy * yy / h + 0.5 * phase
        )
    return 0.5 * out


def make_batch(rng: np.random.Generator, batch: int, noise: float = 0.35):
    """Returns (x float32 (B,32,32,3), y int32 (B,))."""
    y = rng.integers(0, NUM_CLASSES, size=batch).astype(np.int32)
    x = np.stack([class_template(int(k)) for k in y]).astype(np.float32)
    x += noise * rng.standard_normal(x.shape).astype(np.float32)
    return x, y
