"""L1 — Pallas kernels for the helper-side hot spot.

The helper executes part-2 of the split network: stacks of 3x3 conv +
bias + ReLU blocks. On TPU the profitable mapping is conv-as-im2col-matmul
feeding the MXU systolic array, with bias and ReLU fused in VMEM so the
activation tensor makes a single HBM round trip per block (see DESIGN.md
§Hardware-Adaptation). We express exactly that:

* ``fused_matmul_bias_act`` — tiled (M, K) x (K, N) matmul with fused bias
  add and optional ReLU. The grid tiles M and N; each program instance
  holds an (TM, K) A-slab and a (K, TN) B-slab in VMEM and writes one
  (TM, TN) output tile. K is the im2col contraction (9·C_in ≤ 1152 for our
  models) and fits VMEM comfortably; the accumulation happens in fp32 on
  the MXU via ``jnp.dot`` with ``preferred_element_type``.
* ``fused_conv3x3_relu`` — the conv block: XLA-level im2col patch
  extraction (a pure data-movement gather that XLA fuses with the
  surrounding HLO) followed by the Pallas matmul kernel.

Kernels are lowered with ``interpret=True``: this CPU image's PJRT cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO that
the rust runtime executes. Block-shape choices for a real TPU are recorded
in DESIGN.md (TM=128/TN=128 MXU tiles; VMEM budget per instance =
TM·K + K·TN + TM·TN floats).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped default tiles; shrunk automatically for small problems.
TILE_M = 128
TILE_N = 128


def _matmul_kernel(a_ref, b_ref, bias_ref, o_ref, *, activation: str):
    """One (TM, TN) output tile: o = act(a @ b + bias)."""
    acc = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    acc = acc + bias_ref[...][None, :]
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def _pick_tile(dim: int, tile: int) -> int:
    """Largest divisor of ``dim`` that is ≤ tile (prefer powers of two)."""
    t = min(tile, dim)
    while dim % t != 0:
        t -= 1
    return max(t, 1)


def _pallas_matmul(a, b, bias, activation: str):
    """Raw kernel invocation (no autodiff rules)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert bias.shape == (n,), f"bias shape {bias.shape} != ({n},)"
    tm = _pick_tile(m, TILE_M)
    tn = _pick_tile(n, TILE_N)
    grid = (m // tm, n // tn)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tn), lambda i, j: (0, j)),
            pl.BlockSpec((tn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a, b, bias)


# Pallas calls (interpret mode included) do not carry reverse-mode autodiff
# rules, but part-2's *backward* task must flow gradients through the
# kernel. We register the analytic VJP and express the two backward
# matmuls through the same Pallas kernel, so fwd AND bwd HLO both contain
# the tiled fused kernel (this is what the helper executes).
@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _fused(a, b, bias, activation):
    return _pallas_matmul(a, b, bias, activation)


def _fused_fwd(a, b, bias, activation):
    out = _pallas_matmul(a, b, bias, activation)
    return out, (a, b, out)


def _fused_bwd(activation, res, g):
    a, b, out = res
    if activation == "relu":
        g = g * (out > 0.0).astype(g.dtype)
    k = b.shape[0]
    n = b.shape[1]
    g_a = _pallas_matmul(g, b.T, jnp.zeros((k,), jnp.float32), "none")
    g_b = _pallas_matmul(a.T, g, jnp.zeros((n,), jnp.float32), "none")
    g_bias = g.sum(axis=0)
    return g_a, g_b, g_bias


_fused.defvjp(_fused_fwd, _fused_bwd)


@functools.partial(jax.jit, static_argnames=("activation",))
def fused_matmul_bias_act(a, b, bias, activation: str = "relu"):
    """act(a @ b + bias) as a tiled Pallas kernel (differentiable).

    a: (M, K) float32; b: (K, N) float32; bias: (N,) float32.
    Returns (M, N) float32.
    """
    # Shape checks happen eagerly (outside the traced call) for clear errors.
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert bias.shape == (n,), f"bias shape {bias.shape} != ({n},)"
    return _fused(a, b, bias, activation)


def im2col_3x3(x):
    """Extract 3x3 'SAME' patches: (B, H, W, C) → (B·H·W, 9·C).

    Pure data movement; XLA fuses the pad+gather into the surrounding HLO.
    Patch channel order: (dy, dx, c) row-major — the weight reshape in
    ``fused_conv3x3_relu`` matches it.
    """
    b, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cols = [xp[:, dy : dy + h, dx : dx + w, :] for dy in range(3) for dx in range(3)]
    patches = jnp.stack(cols, axis=3)  # (B, H, W, 9, C)
    return patches.reshape(b * h * w, 9 * c)


def fused_conv3x3_relu(x, w, bias, activation: str = "relu"):
    """3x3 SAME conv + bias + activation via im2col + the Pallas matmul.

    x: (B, H, W, Cin); w: (3, 3, Cin, Cout); bias: (Cout,).
    Returns (B, H, W, Cout).
    """
    b, h, wd, cin = x.shape
    assert w.shape[:3] == (3, 3, cin), f"weight shape {w.shape}"
    cout = w.shape[3]
    patches = im2col_3x3(x)  # (B·H·W, 9·Cin)
    wmat = w.reshape(9 * cin, cout)
    out = fused_matmul_bias_act(patches, wmat, bias, activation=activation)
    return out.reshape(b, h, wd, cout)


def vmem_bytes_per_instance(m: int, k: int, n: int, tile_m: int = TILE_M, tile_n: int = TILE_N) -> int:
    """Estimated VMEM footprint (bytes) of one kernel instance — used for
    the DESIGN.md §Perf roofline accounting (fp32)."""
    tm = _pick_tile(m, tile_m)
    tn = _pick_tile(n, tile_n)
    return 4 * (tm * k + k * tn + tm * tn + tn)


def mxu_utilization_estimate(m: int, k: int, n: int, tile_m: int = TILE_M, tile_n: int = TILE_N) -> float:
    """Fraction of 128x128 MXU lanes busy for the chosen tiles: how well
    the tile shape fills the systolic array (1.0 = perfectly aligned)."""
    tm = _pick_tile(m, tile_m)
    tn = _pick_tile(n, tile_n)
    fill = (min(tm, 128) / 128.0) * (min(tn, 128) / 128.0)
    # K dimension streams through the array; short K underfills the pipe.
    k_fill = min(k, 128) / 128.0
    return fill * (0.5 + 0.5 * k_fill)
