"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
reference. Every kernel in ``fused_block`` must match these to float32
tolerance under pytest/hypothesis sweeps (python/tests/test_kernel.py)."""

import jax.numpy as jnp


def matmul_bias_act(a, b, bias, activation: str = "relu"):
    """Reference for fused_matmul_bias_act."""
    out = a @ b + bias[None, :]
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    return out.astype(jnp.float32)


def conv3x3_relu(x, w, bias, activation: str = "relu"):
    """Reference 3x3 SAME conv + bias + activation via lax.conv."""
    import jax.lax as lax

    out = lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    out = out + bias[None, None, None, :]
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    return out.astype(jnp.float32)
