"""AOT compile path: lower the split-network part functions to HLO *text*
artifacts that the rust runtime loads via PJRT.

Interchange format is HLO text, NOT serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per architecture (default: vgg_mini and resnet_mini):

    artifacts/<arch>/<fn>.hlo.txt      six part functions (see model.py)
    artifacts/<arch>/manifest.json     flattened I/O signatures + cuts
    artifacts/<arch>/init/<part>_<k>.bin   initial params, raw f32 LE

Python runs ONCE at build time (`make artifacts`); the rust binary is
self-contained afterwards.

Usage: python -m compile.aot [--out-dir ../artifacts] [--archs vgg_mini,resnet_mini]
       [--batch 16] [--check]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

DEFAULT_BATCH = 16


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def leaf_specs(tree):
    """Flatten a pytree into [(path, shape, dtype)] in jax flatten order."""
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    specs = []
    for path, leaf in paths:
        name = "".join(str(p) for p in path)
        arr = np.asarray(leaf)
        specs.append({"path": name, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    return specs


def export_arch(arch: str, out_dir: str, batch: int, check: bool) -> dict:
    os.makedirs(os.path.join(out_dir, arch, "init"), exist_ok=True)
    spec = model.ARCHS[arch]
    cuts = spec["default_cuts"]
    fns = model.make_part_fns(arch, cuts, use_pallas=True)
    params = model.init_params(arch, seed=0)
    p1, p2, p3 = model.split_params(arch, params, cuts)

    # Example args (shapes fix the HLO signature).
    x = jnp.zeros((batch, *model.INPUT_SHAPE), jnp.float32)
    y = jnp.zeros((batch,), jnp.int32)
    a1 = fns["part1_fwd"](p1, x)
    a2 = fns["part2_fwd"](p2, a1)
    g_a2 = jnp.zeros_like(a2)
    g_a1 = jnp.zeros_like(a1)

    exports = {
        "part1_fwd": (fns["part1_fwd"], (p1, x)),
        "part2_fwd": (fns["part2_fwd"], (p2, a1)),
        "part3_loss": (fns["part3_loss"], (p3, a2, y)),
        "part3_bwd": (fns["part3_bwd"], (p3, a2, y)),
        "part2_bwd": (fns["part2_bwd"], (p2, a1, g_a2)),
        "part1_bwd": (fns["part1_bwd"], (p1, x, g_a1)),
    }

    manifest = {
        "arch": arch,
        "batch": batch,
        "cuts": list(cuts),
        "input_shape": list(model.INPUT_SHAPE),
        "num_classes": model.NUM_CLASSES,
        "functions": {},
        "params": {},
    }

    # Dump initial params per part (raw f32 little-endian in leaf order).
    for part_name, part in [("p1", p1), ("p2", p2), ("p3", p3)]:
        specs = leaf_specs(part)
        files = []
        for k, leaf in enumerate(jax.tree_util.tree_leaves(part)):
            fname = f"init/{part_name}_{k}.bin"
            np.asarray(leaf, np.float32).tofile(os.path.join(out_dir, arch, fname))
            files.append(fname)
        manifest["params"][part_name] = {
            "leaves": specs,
            "files": files,
            "n_elements": int(sum(int(np.prod(s["shape"])) if s["shape"] else 1 for s in specs)),
        }

    for name, (fn, args) in exports.items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, arch, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        in_specs = []
        for a in jax.tree_util.tree_leaves(args):
            arr = np.asarray(a)
            in_specs.append({"shape": list(arr.shape), "dtype": str(arr.dtype)})
        out_example = jax.eval_shape(fn, *args)
        out_specs = [
            {"shape": list(s.shape), "dtype": str(s.dtype)}
            for s in jax.tree_util.tree_leaves(out_example)
        ]
        manifest["functions"][name] = {
            "hlo": f"{name}.hlo.txt",
            "inputs": in_specs,
            "outputs": out_specs,
        }
        if check:
            _check_finite(fn, args, name)
        print(
            f"[aot] {arch}/{name}: {len(in_specs)} inputs, {len(out_specs)} outputs, "
            f"{len(text)//1024} KiB hlo"
        )

    mpath = os.path.join(out_dir, arch, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def _check_finite(fn, args, name: str):
    """Build-time numerics gate: reference outputs must be finite. (The
    full HLO-vs-jax cross-check runs on the rust side in cargo tests.)"""
    out = jax.tree_util.tree_leaves(fn(*args))
    assert all(np.all(np.isfinite(np.asarray(e))) for e in out), f"{name}: non-finite output"


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    )
    ap.add_argument("--archs", default="vgg_mini,resnet_mini")
    ap.add_argument("--batch", type=int, default=DEFAULT_BATCH)
    ap.add_argument("--check", action="store_true", help="verify reference outputs are finite")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    archs = [a.strip() for a in args.archs.split(",") if a.strip()]
    for arch in archs:
        export_arch(arch, out_dir, args.batch, args.check)
    # Top-level index for the rust artifact registry.
    with open(os.path.join(out_dir, "index.json"), "w") as f:
        json.dump({"archs": archs, "batch": args.batch}, f, indent=2, sort_keys=True)
    print(f"[aot] wrote artifacts to {out_dir}")


if __name__ == "__main__":
    main()
