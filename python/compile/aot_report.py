"""L2 perf surface: static cost analysis of the exported HLO artifacts.

Parses the HLO text (no execution) and reports, per artifact:
  * op histogram (dot/convolution/while/elementwise/...)
  * ENTRY parameter byte totals,
  * estimated FLOPs of the dot ops (out_numel x contracting dim, x2),
  * arithmetic intensity (FLOPs / param bytes) — the roofline x-axis,
plus the L1 kernel's VMEM/MXU tile estimates (fused_block helpers).

Used by the §Perf pass (EXPERIMENTS.md) to verify that the Pallas-
interpret matmuls survived lowering as real `dot` ops and that no
artifact recomputes what it should reuse.
Usage: python -m compile.aot_report [--artifacts ../artifacts]
"""

import argparse
import json
import os
import re

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?([\w.\-]+)\s*=\s*f32\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?[\w.\-]+\s*=\s*\S+\s+([\w\-]+)\(", re.M)
_DOT_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?[\w.\-]+\s*=\s*f32\[([\d,]*)\][^=]*\bdot\(([\w.\-]+),\s*([\w.\-]+)\),"
    r"\s*lhs_contracting_dims=\{([\d,]+)\}",
    re.M,
)


def _numel(dims: str) -> int:
    if not dims:
        return 1
    out = 1
    for d in dims.split(","):
        out *= int(d)
    return out


def analyze_hlo(text: str) -> dict:
    """Static analysis of one HLO module text."""
    ops = {}
    for m in _OP_RE.finditer(text):
        op = m.group(1)
        ops[op] = ops.get(op, 0) + 1

    # Symbol table: instruction name -> dims string.
    shapes = {}
    for line in text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            shapes[m.group(1)] = m.group(2)

    # FLOPs: per dot, 2 * numel(out) * K where K = product of the lhs
    # contracting dims. Dots inside while-loop bodies (the Pallas grid)
    # appear once; scale by the loop trip count is not recoverable
    # statically, so this is a per-iteration lower bound (noted in
    # EXPERIMENTS.md).
    flops = 0
    for m in _DOT_LINE_RE.finditer(text):
        out_dims, lhs_name, _rhs, contract = m.group(1), m.group(2), m.group(3), m.group(4)
        lhs_dims = shapes.get(lhs_name, "")
        if not lhs_dims:
            continue
        dims = [int(d) for d in lhs_dims.split(",") if d]
        k = 1
        for c in contract.split(","):
            ci = int(c)
            if ci < len(dims):
                k *= dims[ci]
        flops += 2 * _numel(out_dims) * k

    # ENTRY parameters only.
    param_bytes = 0
    entry = text[text.find("ENTRY"):] if "ENTRY" in text else text
    for line in entry.splitlines():
        if "parameter(" in line:
            m = _DEF_RE.match(line)
            if m:
                param_bytes += 4 * _numel(m.group(2))

    return {
        "ops": ops,
        "n_instructions": sum(ops.values()),
        "whiles": ops.get("while", 0),
        "dots": ops.get("dot", 0),
        "flops_est": flops,
        "param_bytes": param_bytes,
        "intensity": flops / max(1, param_bytes),
    }


def report(artifacts_dir: str) -> dict:
    out = {}
    for arch in sorted(os.listdir(artifacts_dir)):
        mpath = os.path.join(artifacts_dir, arch, "manifest.json")
        if not os.path.isfile(mpath):
            continue
        with open(mpath) as f:
            manifest = json.load(f)
        arch_report = {}
        for name, fn in manifest["functions"].items():
            with open(os.path.join(artifacts_dir, arch, fn["hlo"])) as f:
                arch_report[name] = analyze_hlo(f.read())
        out[arch] = arch_report
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifacts", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    args = ap.parse_args()
    rep = report(os.path.abspath(args.artifacts))
    for arch, fns in rep.items():
        print(f"\n== {arch} ==")
        print(f"{'fn':<12} {'instrs':>7} {'whiles':>7} {'dots':>5} {'MFLOP/it':>9} {'paramMB':>8} {'F/B':>6}")
        for name, r in sorted(fns.items()):
            print(
                f"{name:<12} {r['n_instructions']:>7} {r['whiles']:>7} {r['dots']:>5} "
                f"{r['flops_est']/1e6:>9.2f} {r['param_bytes']/1e6:>8.2f} {r['intensity']:>6.1f}"
            )
    # Kernel tile accounting (DESIGN.md §Perf inputs).
    from .kernels import fused_block

    print("\n== L1 kernel tile accounting (part-2 conv shapes, batch 16) ==")
    shapes = [
        ("conv 16→32 @16x16", 16 * 16 * 16, 9 * 16, 32),
        ("conv 32→32 @16x16", 16 * 16 * 16, 9 * 32, 32),
        ("conv 32→64 @8x8", 16 * 8 * 8, 9 * 32, 64),
        ("conv 64→64 @8x8", 16 * 8 * 8, 9 * 64, 64),
    ]
    print(f"{'shape':<20} {'M':>6} {'K':>5} {'N':>4} {'VMEM KiB':>9} {'MXU est':>8}")
    for label, m, k, n in shapes:
        vmem = fused_block.vmem_bytes_per_instance(m, k, n) / 1024
        mxu = fused_block.mxu_utilization_estimate(m, k, n)
        print(f"{label:<20} {m:>6} {k:>5} {n:>4} {vmem:>9.1f} {mxu:>8.2f}")


if __name__ == "__main__":
    main()
