"""L2 — the split neural network (SplitNet) in JAX.

The paper trains ResNet101 / VGG19 on CIFAR-10 split into three parts at
cut layers (σ1, σ2): part-1 and part-3 run on the client, part-2 on the
helper. The *optimization* layer only consumes profiled delays (embedded
in the rust profile bank); this module provides the *executable* model for
the end-to-end split-learning runtime: miniature VGG- and ResNet-style
families whose part-2 conv blocks run through the L1 Pallas kernel
(``kernels.fused_block``), so the kernel lowers into the exported HLO.

Everything here is build-time only. ``aot.py`` lowers the part functions
below to HLO text artifacts; the rust runtime executes them via PJRT.

Model structure: a list of layers, each a dict with a type tag; cutting at
(σ1, σ2) yields parts as index ranges (1-based cut semantics matching the
paper: part-1 = layers [1..σ1], part-2 = (σ1..σ2], part-3 = (σ2..L]).

Split-learning contract (one batch update, client j ↔ helper i):
    a1                    = part1_fwd(p1, x)
    a2                    = part2_fwd(p2, a1)
    loss, g3, g_a2        = part3_bwd(p3, a2, y)
    g2, g_a1              = part2_bwd(p2, a1, g_a2)
    g1                    = part1_bwd(p1, x, g_a1)
followed by SGD on (p1, p2, p3) — done natively in rust (elementwise).
"""

import jax
import jax.numpy as jnp

from .kernels import fused_block

# ---------------------------------------------------------------------------
# Layer zoo
# ---------------------------------------------------------------------------


def _conv_layer(cout):
    return {"kind": "conv", "cout": cout}


def _pool_layer():
    return {"kind": "pool"}


def _flatten_layer():
    return {"kind": "flatten"}


def _dense_layer(n, act="relu"):
    return {"kind": "dense", "n": n, "act": act}


def _res_layer(cout, stride=1):
    return {"kind": "res", "cout": cout, "stride": stride}


ARCHS = {
    # 11 layers; default cuts (2, 8): part-2 holds the conv bulk.
    "vgg_mini": {
        "layers": [
            _conv_layer(16),
            _conv_layer(16),
            _pool_layer(),
            _conv_layer(32),
            _conv_layer(32),
            _pool_layer(),
            _conv_layer(64),
            _conv_layer(64),
            _flatten_layer(),
            _dense_layer(128),
            _dense_layer(10, act="none"),
        ],
        "default_cuts": (2, 8),
    },
    # 9 layers; default cuts (1, 7).
    "resnet_mini": {
        "layers": [
            _conv_layer(16),
            _res_layer(16),
            _res_layer(32, stride=2),
            _res_layer(32),
            _res_layer(64, stride=2),
            _res_layer(64),
            _flatten_layer(),
            _dense_layer(64),
            _dense_layer(10, act="none"),
        ],
        "default_cuts": (1, 7),
    },
}

INPUT_SHAPE = (32, 32, 3)  # CIFAR-10-like
NUM_CLASSES = 10


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(arch: str, seed: int = 0):
    """He-init parameters for every layer; returns a list (one entry per
    layer, possibly an empty dict for parameterless layers)."""
    spec = ARCHS[arch]
    key = jax.random.PRNGKey(seed)
    params = []
    h, w, c = INPUT_SHAPE
    flat = None
    for layer in spec["layers"]:
        kind = layer["kind"]
        if kind == "conv":
            key, k1 = jax.random.split(key)
            cout = layer["cout"]
            std = (2.0 / (9 * c)) ** 0.5
            params.append({
                "w": jax.random.normal(k1, (3, 3, c, cout), jnp.float32) * std,
                "b": jnp.zeros((cout,), jnp.float32),
            })
            c = cout
        elif kind == "res":
            key, k1, k2, k3 = jax.random.split(key, 4)
            cout = layer["cout"]
            std1 = (2.0 / (9 * c)) ** 0.5
            std2 = (2.0 / (9 * cout)) ** 0.5
            p = {
                "w1": jax.random.normal(k1, (3, 3, c, cout), jnp.float32) * std1,
                "b1": jnp.zeros((cout,), jnp.float32),
                "w2": jax.random.normal(k2, (3, 3, cout, cout), jnp.float32) * std2,
                "b2": jnp.zeros((cout,), jnp.float32),
            }
            if layer["stride"] != 1 or cout != c:
                p["wskip"] = jax.random.normal(k3, (1, 1, c, cout), jnp.float32) * (2.0 / c) ** 0.5
            params.append(p)
            if layer["stride"] == 2:
                h, w = h // 2, w // 2
            c = cout
        elif kind == "pool":
            params.append({})
            h, w = h // 2, w // 2
        elif kind == "flatten":
            params.append({})
            flat = h * w * c
        elif kind == "dense":
            key, k1 = jax.random.split(key)
            n = layer["n"]
            fan_in = flat
            params.append({
                "w": jax.random.normal(k1, (fan_in, n), jnp.float32) * (2.0 / fan_in) ** 0.5,
                "b": jnp.zeros((n,), jnp.float32),
            })
            flat = n
        else:
            raise ValueError(kind)
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _apply_layer(layer, p, x, use_pallas: bool):
    kind = layer["kind"]
    if kind == "conv":
        if use_pallas:
            return fused_block.fused_conv3x3_relu(x, p["w"], p["b"])
        from .kernels import ref

        return ref.conv3x3_relu(x, p["w"], p["b"])
    if kind == "res":
        import jax.lax as lax

        def conv(v, w, b, stride):
            out = lax.conv_general_dilated(
                v, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
            )
            return out + b[None, None, None, :]

        stride = layer["stride"]
        h = jnp.maximum(conv(x, p["w1"], p["b1"], stride), 0.0)
        h = conv(h, p["w2"], p["b2"], 1)
        skip = x
        if "wskip" in p:
            skip = lax.conv_general_dilated(
                x, p["wskip"], (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
            )
        return jnp.maximum(h + skip, 0.0)
    if kind == "pool":
        b, h, w, c = x.shape
        return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))
    if kind == "flatten":
        return x.reshape(x.shape[0], -1)
    if kind == "dense":
        out = x @ p["w"] + p["b"][None, :]
        if layer["act"] == "relu":
            out = jnp.maximum(out, 0.0)
        return out
    raise ValueError(kind)


def forward_range(arch: str, params_slice, x, lo: int, hi: int, use_pallas: bool = True):
    """Apply layers lo..hi (0-based, hi exclusive) given that
    ``params_slice`` holds exactly those layers' params."""
    layers = ARCHS[arch]["layers"][lo:hi]
    assert len(layers) == len(params_slice)
    for layer, p in zip(layers, params_slice):
        x = _apply_layer(layer, p, x, use_pallas)
    return x


def full_forward(arch: str, params, x, use_pallas: bool = True):
    return forward_range(arch, params, x, 0, len(ARCHS[arch]["layers"]), use_pallas)


def split_params(arch: str, params, cuts=None):
    """Split a full param list at 1-based cut layers (σ1, σ2)."""
    s1, s2 = cuts or ARCHS[arch]["default_cuts"]
    return params[:s1], params[s1:s2], params[s2:]


def loss_fn(logits, y):
    """Mean softmax cross-entropy; y: int32 labels."""
    logz = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logz, y[:, None], axis=1).mean()


# ---------------------------------------------------------------------------
# Split-learning part functions (the AOT export surface)
# ---------------------------------------------------------------------------


def make_part_fns(arch: str, cuts=None, use_pallas: bool = True):
    """Build the six part functions for the SL batch-update contract.

    Each returned fn takes/returns *pytrees of arrays*; aot.py flattens
    them into the positional HLO signature recorded in the manifest.
    """
    spec = ARCHS[arch]
    n = len(spec["layers"])
    s1, s2 = cuts or spec["default_cuts"]
    assert 1 <= s1 < s2 < n, f"bad cuts ({s1},{s2}) for {arch}"

    def part1_fwd(p1, x):
        return forward_range(arch, p1, x, 0, s1, use_pallas)

    def part2_fwd(p2, a1):
        return forward_range(arch, p2, a1, s1, s2, use_pallas)

    def part3_loss(p3, a2, y):
        logits = forward_range(arch, p3, a2, s2, n, use_pallas)
        return loss_fn(logits, y)

    def part3_bwd(p3, a2, y):
        loss, (g3, g_a2) = jax.value_and_grad(part3_loss, argnums=(0, 1))(p3, a2, y)
        return loss, g3, g_a2

    def part2_bwd(p2, a1, g_a2):
        _, vjp = jax.vjp(lambda p, a: part2_fwd(p, a), p2, a1)
        g2, g_a1 = vjp(g_a2)
        return g2, g_a1

    def part1_bwd(p1, x, g_a1):
        _, vjp = jax.vjp(lambda p: part1_fwd(p, x), p1)
        (g1,) = vjp(g_a1)
        return g1

    return {
        "part1_fwd": part1_fwd,
        "part2_fwd": part2_fwd,
        "part3_loss": part3_loss,
        "part3_bwd": part3_bwd,
        "part2_bwd": part2_bwd,
        "part1_bwd": part1_bwd,
        "cuts": (s1, s2),
    }


def reference_train_step(arch: str, params, x, y, lr: float, use_pallas: bool = False):
    """Monolithic train step (loss + SGD) — the oracle the split pipeline
    must match exactly (python/tests/test_model.py)."""

    def full_loss(ps):
        return loss_fn(full_forward(arch, ps, x, use_pallas), y)

    loss, grads = jax.value_and_grad(full_loss)(params)
    new_params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)
    return loss, new_params
