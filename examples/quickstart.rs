//! Quickstart: generate a paper-style scenario, solve it with every
//! method, and compare makespans — the 60-second tour of the library.
//!
//! Run: `cargo run --release --example quickstart`

use psl::coordinator::{compare_methods, SolveRequest};
use psl::instance::profiles::Model;
use psl::instance::scenario::Scenario;

fn main() -> anyhow::Result<()> {
    // A medium, highly-heterogeneous system: 20 clients, 5 helpers,
    // ResNet101 profile (Scenario 2 of the paper's evaluation).
    let req = SolveRequest {
        scenario: Scenario::S2,
        model: Model::ResNet101,
        n_clients: 20,
        n_helpers: 5,
        seed: 42,
        slot_ms: None, // model default: 180 ms (§VII)
        switch_cost_ms: 0.0,
    };
    let inst = req.instance();
    println!(
        "instance {}: T = {} slots of {} ms (makespan lower bound {})",
        inst.label,
        inst.horizon(),
        inst.slot_ms,
        inst.makespan_lower_bound()
    );

    // Solve with the strategy (ADMM here: medium + heterogeneous),
    // balanced-greedy, and the random+FCFS baseline; replay each schedule
    // in continuous time.
    let rows = compare_methods(&req, /*include_exact=*/ false, /*replay=*/ true)?;
    println!("\n{:<10} {:>8} {:>12} {:>13} {:>10}", "method", "slots", "nominal[s]", "realized[s]", "solve");
    for r in &rows {
        println!(
            "{:<10} {:>8} {:>12.1} {:>13.1} {:>10}",
            r.method,
            r.makespan_slots,
            r.makespan_ms / 1000.0,
            r.realized_ms.unwrap() / 1000.0,
            psl::bench::fmt_s(r.solve_s)
        );
    }

    let strat = rows.iter().find(|r| r.method == "strategy").unwrap();
    let base = rows.iter().find(|r| r.method == "baseline").unwrap();
    let gain = (base.makespan_ms - strat.makespan_ms) / base.makespan_ms * 100.0;
    println!("\nworkflow optimization saves {gain:.1}% of the batch makespan vs the naive baseline");
    Ok(())
}
