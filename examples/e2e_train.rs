//! END-TO-END VALIDATION DRIVER: real parallel split learning through the
//! whole stack — L1 Pallas kernel → L2 JAX parts (AOT HLO artifacts) →
//! L3 rust coordinator executing optimized schedules over PJRT.
//!
//! What it does (recorded in EXPERIMENTS.md):
//!  1. builds a fleet of 6 clients / 2 helpers (vgg_mini artifacts),
//!  2. solves the workflow (paper's solution strategy) for the matching
//!     profiled instance,
//!  3. trains for a few hundred batch updates with FedAvg rounds, logging
//!     the loss curve — the proof that all layers compose,
//!  4. feeds the *measured* helper task times back into the optimizer and
//!     compares methods on the re-profiled instance (the paper's
//!     profiling loop).
//!
//! Requires artifacts: `make artifacts` first.
//! Run: `cargo run --release --example e2e_train [steps]`

use psl::coordinator::rounds::{fleet_instance, TrainRequest};
use psl::runtime::Engine;
use psl::slexec::{Driver, SplitModel, TrainCfg};
use psl::solver::{admm, baseline, greedy, strategy};
use psl::util::rng::Rng;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(240);
    let rounds = 8;
    let req = TrainRequest {
        arch: "vgg_mini".into(),
        artifacts_dir: psl::runtime::artifacts_dir(),
        n_clients: 6,
        n_helpers: 2,
        seed: 7,
        train: TrainCfg { batches_per_round: steps / rounds, rounds, lr: 0.05, seed: 7 },
    };

    // 1–2: instance + schedule.
    let inst = fleet_instance(&req);
    let (schedule, method) = strategy::solve(&inst, &admm::AdmmCfg::default()).unwrap();
    println!(
        "fleet J={} I={} | method {method:?} | makespan {} slots ({:.1}s nominal)",
        req.n_clients,
        req.n_helpers,
        schedule.makespan(&inst),
        schedule.makespan(&inst) as f64 * inst.slot_ms / 1000.0
    );

    // 3: real training.
    let engine = Arc::new(Engine::cpu()?);
    println!("PJRT platform: {}", engine.platform());
    let model = SplitModel::load(Arc::clone(&engine), &req.artifacts_dir, &req.arch)?;
    let mut driver = Driver::new(model, &inst, schedule, req.seed)?;
    let report = driver.train(&req.train)?;
    println!("\nloss curve ({} steps, {:.1}s wall):", report.steps, report.wall_s);
    let stride = (report.loss_curve.len() / 16).max(1);
    for (k, l) in report.loss_curve.iter().enumerate() {
        if k % stride == 0 || k + 1 == report.loss_curve.len() {
            println!("  step {:>4}: {:.4}", k + 1, l);
        }
    }
    let first = report.loss_curve.first().copied().unwrap_or(f64::NAN);
    let last = report.loss_curve.last().copied().unwrap_or(f64::NAN);
    println!("loss {first:.4} → {last:.4} ({})", if last < first { "LEARNING ✓" } else { "NOT LEARNING ✗" });
    anyhow::ensure!(last < first, "end-to-end training failed to reduce the loss");

    // 4: profiling loop — re-optimize with measured helper times.
    println!("\nmeasured helper task times (ms):");
    for (i, j, f, b) in &report.measured_ms {
        println!("  helper {i} / client {j}: fwd {f:>7.1}  bwd {b:>7.1}");
    }
    let mut reprofiled = inst.clone();
    // Scale measured wall-ms into the instance's slot units (the emulated
    // fleet is faster than the profiled testbed; preserve ratios).
    if !report.measured_ms.is_empty() {
        let mean_meas: f64 =
            report.measured_ms.iter().map(|(_, _, f, b)| f + b).sum::<f64>() / report.measured_ms.len() as f64;
        let mean_prof: f64 = (0..inst.n_clients)
            .map(|j| {
                let i = driver.schedule.assignment.helper_of[j];
                let e = inst.edge(i, j);
                (inst.p[e] + inst.pp[e]) as f64
            })
            .sum::<f64>()
            / inst.n_clients as f64;
        let scale = mean_prof / mean_meas;
        for (i, j, f, b) in &report.measured_ms {
            let e = inst.edge(*i, *j);
            reprofiled.p[e] = ((f * scale).round() as u32).max(1);
            reprofiled.pp[e] = ((b * scale).round() as u32).max(1);
        }
    }
    println!("\nre-optimizing on measured profile:");
    let a = admm::solve(&reprofiled, &admm::AdmmCfg::default()).unwrap().schedule.makespan(&reprofiled);
    let g = greedy::solve(&reprofiled).unwrap().makespan(&reprofiled);
    let b = baseline::solve_mean_makespan(&reprofiled, &mut Rng::seeded(3), 10);
    println!("  admm {a} | balanced-greedy {g} | baseline {b:.1} (slots)");

    println!("\nruntime artifact stats (calls / mean ms):");
    for (path, calls, mean_ms) in engine.stats() {
        let name = path.rsplit('/').next().unwrap_or(&path);
        println!("  {name:<22} {calls:>5}  {mean_ms:>8.2}");
    }
    Ok(())
}
