//! Heterogeneous-fleet study (the paper's Scenario 2 motivation): when
//! device speeds, link rates, memory and cut layers all vary, assignment
//! and scheduling decisions dominate the makespan. This example dissects
//! *why*: queuing delays, helper utilization, preemption counts, and the
//! §VI preemption-cost extension.
//!
//! Run: `cargo run --release --example heterogeneous_fleet`

use psl::instance::profiles::Model;
use psl::instance::scenario::{Scenario, ScenarioCfg};
use psl::sim;
use psl::solver::{admm, baseline, greedy, preemption};
use psl::util::rng::Rng;
use psl::util::stats::mean;

fn main() -> anyhow::Result<()> {
    let cfg = ScenarioCfg::new(Scenario::S2, Model::Vgg19, 30, 5, 7);
    let ms = cfg.generate();
    let inst = ms.quantize(550.0);
    println!("fleet: {} | T = {} slots", inst.label, inst.horizon());

    // --- solve three ways -------------------------------------------------
    let admm_res = admm::solve(&inst, &admm::AdmmCfg::default()).unwrap();
    let greedy_s = greedy::solve(&inst).unwrap();
    let mut rng = Rng::seeded(99);
    let base_s = baseline::solve(&inst, &mut rng).unwrap();

    for (name, s) in [("admm", &admm_res.schedule), ("greedy", &greedy_s), ("baseline", &base_s)] {
        let m = sim::summarize(&inst, s);
        let rep = sim::replay(&ms, s, None);
        println!(
            "\n[{name}] makespan {} slots ({:.1}s nominal, {:.1}s realized)",
            m.makespan_slots,
            m.makespan_ms / 1000.0,
            rep.makespan_ms / 1000.0
        );
        println!(
            "  queuing: mean {:.1} slots, max {} | preemptions {} | helper util% {:?}",
            m.mean_queuing_slots,
            m.max_queuing_slots,
            m.preemptions,
            m.helper_util.iter().map(|u| (u * 100.0).round() as i64).collect::<Vec<_>>()
        );
    }

    // --- robustness: jittered replays -------------------------------------
    println!("\nrobustness under 20% delay jitter (20 replays):");
    for (name, s) in [("admm", &admm_res.schedule), ("greedy", &greedy_s)] {
        let mut rng = Rng::seeded(5);
        let reps: Vec<f64> = (0..20)
            .map(|_| sim::replay(&ms, s, Some((&mut rng, 0.2))).makespan_ms / 1000.0)
            .collect();
        println!("  {name}: mean {:.1}s  max {:.1}s", mean(&reps), reps.iter().cloned().fold(0.0, f64::max));
    }

    // --- §VI extension: preemption costs ----------------------------------
    let costly = ScenarioCfg::new(Scenario::S2, Model::Vgg19, 30, 5, 7)
        .with_switch_cost(550.0)
        .generate()
        .quantize(550.0);
    let res2 = admm::solve(&costly, &admm::AdmmCfg::default()).unwrap();
    let raw = preemption::adjusted_makespan(&res2.schedule, &costly);
    let defrag = preemption::defragment(&res2.schedule, &costly);
    println!(
        "\npreemption cost μ = 1 slot: adjusted makespan {} → {} after defragmentation",
        raw,
        preemption::adjusted_makespan(&defrag, &costly)
    );
    Ok(())
}
