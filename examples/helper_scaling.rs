//! Helper-count sensitivity (Fig 8 of the paper): 100 clients in
//! Scenario 1, scaling the number of helpers from 1 to 14 with
//! balanced-greedy (the strategy's choice at this scale). The paper's
//! Observation 4: the second helper cuts the makespan by up to ~47.6%,
//! with sharply diminishing returns beyond ~10 helpers.
//!
//! Run: `cargo run --release --example helper_scaling`

use psl::instance::profiles::Model;
use psl::instance::scenario::{Scenario, ScenarioCfg};
use psl::solver::greedy;
use psl::util::stats::mean;

fn main() -> anyhow::Result<()> {
    let j = 100;
    let seeds: Vec<u64> = (0..5).collect();
    println!("J = {j} clients, Scenario 1, ResNet101, balanced-greedy (mean over {} seeds)", seeds.len());
    println!("{:>3} {:>14} {:>14} {:>10}", "I", "makespan[s]", "Δ vs I-1", "slots");
    let mut prev: Option<f64> = None;
    for i in 1..=14 {
        let ms: Vec<f64> = seeds
            .iter()
            .map(|&seed| {
                let inst = ScenarioCfg::new(Scenario::S1, Model::ResNet101, j, i, 100 + seed)
                    .generate()
                    .quantize(180.0);
                greedy::solve(&inst).expect("feasible").makespan(&inst) as f64 * inst.slot_ms / 1000.0
            })
            .collect();
        let m = mean(&ms);
        let delta = prev.map(|p| format!("{:+.1}%", (m - p) / p * 100.0)).unwrap_or_else(|| "-".into());
        println!("{i:>3} {m:>14.1} {delta:>14} {:>10.0}", m * 1000.0 / 180.0);
        prev = Some(m);
    }
    println!("\n(expect a large drop from I=1→2 and flat returns past ~10 — Observation 4)");
    Ok(())
}
