//! Minimal, dependency-free drop-in subset of the `anyhow` crate.
//!
//! This image's cargo registry ships no external crates, so the error
//! handling surface the psl crate actually uses is reimplemented here as a
//! path dependency (~150 lines): [`Error`], [`Result`], the [`Context`]
//! extension trait (for `Result` and `Option`), and the `anyhow!` /
//! `bail!` / `ensure!` macros.
//!
//! Semantics match upstream where psl relies on them:
//!
//! * `{e}` displays the outermost message; `{e:#}` displays the whole
//!   context chain joined with `": "` (what `psl`'s `main` prints).
//! * `?` converts any `std::error::Error + Send + Sync + 'static` into
//!   [`Error`], capturing its `source()` chain.
//! * `.context(..)` / `.with_context(..)` wrap errors (and `None`) with an
//!   outer message.
//!
//! Not implemented (unused by psl): downcasting, backtraces.

use std::fmt;

/// An error: an ordered chain of messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] as the
/// default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Debug shows the full chain so `.unwrap()` failures are actionable.
        f.write_str(&self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

// `Error` deliberately does not implement `std::error::Error`, so this does
// not overlap with the blanket impl above (the same structure upstream
// anyhow uses to stay coherent).
impl<T> Context<T> for std::result::Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*).into())
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: `", stringify!($cond), "`")).into());
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)+).into());
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e = Error::msg("root").context("middle").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: middle: root");
        assert_eq!(format!("{e:?}"), "outer: middle: root");
        assert_eq!(e.root_cause(), "root");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "missing file");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading config: missing file");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("no {}", "value")).unwrap_err();
        assert_eq!(format!("{e}"), "no value");
        assert_eq!(Some(3).context("present").unwrap(), 3);
    }

    #[test]
    fn context_on_anyhow_result_chains() {
        let r: Result<()> = Err(Error::msg("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 5);
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert!(format!("{}", f(5).unwrap_err()).contains("x != 5"));
        assert_eq!(format!("{}", f(3).unwrap_err()), "three is right out");
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
