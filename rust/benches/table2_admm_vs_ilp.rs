//! TABLE II reproduction: suboptimality and speedup of the ADMM-based
//! method compared to an exact solver, on the paper's grid:
//! {ResNet101, VGG19} × {Scenario 1, Scenario 2} × (J, I) ∈
//! {(10,2), (10,5), (15,5)}.
//!
//! The paper's exact reference is Gurobi on the time-indexed ILP; ours is
//! the specialized anytime branch-and-bound (solver::exact) with a
//! wall-clock budget (PSL_EXACT_BUDGET_S, default 20 s per cell). When
//! the budget expires the incumbent is used and the row is marked with
//! `*` (the paper's Gurobi also ran with gaps on bigger instances).
//!
//! Expected shape vs the paper: ADMM within ~0–15% of exact (they report
//! ≤10.2% typical, one 14.9% corner), with a large solve-time speedup.
//!
//! Run: cargo bench --bench table2_admm_vs_ilp

use psl::bench::{fmt_s, Report};
use psl::instance::profiles::Model;
use psl::instance::scenario::{Scenario, ScenarioCfg};
use psl::solver::{admm, exact};
use psl::util::json::Json;
use std::time::{Duration, Instant};

fn main() {
    let budget_s: u64 = std::env::var("PSL_EXACT_BUDGET_S").ok().and_then(|v| v.parse().ok()).unwrap_or(20);
    let seeds: Vec<u64> = vec![11, 12];
    let mut report = Report::new(
        "table2_admm_vs_ilp",
        &["scenario", "model", "J", "I", "T", "subopt%", "speedup", "exact", "admm", "proven"],
    );

    for scenario in [Scenario::S1, Scenario::S2] {
        for model in [Model::ResNet101, Model::Vgg19] {
            for &(j, i) in &[(10usize, 2usize), (10, 5), (15, 5)] {
                let slot = model.profile().default_slot_ms;
                let mut subopts = Vec::new();
                let mut speedups = Vec::new();
                let mut exact_times = Vec::new();
                let mut admm_times = Vec::new();
                let mut t_slots = 0;
                let mut proven_all = true;
                for &seed in &seeds {
                    let inst = ScenarioCfg::new(scenario, model, j, i, seed).generate().quantize(slot);
                    t_slots = inst.horizon();

                    let t0 = Instant::now();
                    let a = admm::solve(&inst, &admm::AdmmCfg::default()).expect("admm");
                    let admm_s = t0.elapsed().as_secs_f64();
                    let admm_make = a.schedule.makespan(&inst);

                    let ex = exact::solve(
                        &inst,
                        &exact::ExactCfg {
                            time_budget: Duration::from_secs(budget_s),
                            ..Default::default()
                        },
                    );
                    proven_all &= ex.proven_optimal;
                    let exact_s = ex.elapsed.as_secs_f64();
                    subopts.push((admm_make as f64 - ex.makespan as f64) / ex.makespan as f64 * 100.0);
                    speedups.push(exact_s / admm_s.max(1e-6));
                    exact_times.push(exact_s);
                    admm_times.push(admm_s);
                }
                let subopt = subopts.iter().sum::<f64>() / subopts.len() as f64;
                let speedup = speedups.iter().sum::<f64>() / speedups.len() as f64;
                let exact_mean = exact_times.iter().sum::<f64>() / exact_times.len() as f64;
                let admm_mean = admm_times.iter().sum::<f64>() / admm_times.len() as f64;
                report.row(
                    vec![
                        scenario.name().into(),
                        model.name().into(),
                        j.to_string(),
                        i.to_string(),
                        t_slots.to_string(),
                        format!("{subopt:.1}"),
                        format!("{speedup:.1}x"),
                        fmt_s(exact_mean),
                        fmt_s(admm_mean),
                        if proven_all { "yes".into() } else { "*gap".into() },
                    ],
                    Json::obj(vec![
                        ("scenario", Json::Str(scenario.name().into())),
                        ("model", Json::Str(model.name().into())),
                        ("j", Json::Num(j as f64)),
                        ("i", Json::Num(i as f64)),
                        ("t", Json::Num(t_slots as f64)),
                        ("subopt_pct", Json::Num(subopt)),
                        ("speedup", Json::Num(speedup)),
                        ("proven", Json::Bool(proven_all)),
                    ]),
                );
                eprintln!(
                    "[table2] {} {} J={j} I={i}: subopt {subopt:.1}% speedup {speedup:.1}x proven={proven_all}",
                    scenario.name(),
                    model.name()
                );
            }
        }
    }
    report.finish();
    println!(
        "\npaper reference: subopt 0–14.9% (typ. ≤10.2%), speedup 12.5–52x vs Gurobi;\n\
         our exact solver is specialized, so speedups are measured against it honestly."
    );
}
