//! Micro-benchmarks of the solver substrate (the §Perf iteration loop's
//! measurement surface): Baker block scheduler, FCFS, per-helper exact
//! search, y-subproblem B&B, end-to-end method solves, instance
//! generation and continuous replay.
//!
//! Run: cargo bench --bench solver_micro

use psl::bench::{fmt_s, time_fn, Report};
use psl::instance::profiles::Model;
use psl::instance::scenario::{Scenario, ScenarioCfg};
use psl::sim;
use psl::solver::schedule::{fcfs_schedule, Assignment};
use psl::solver::{admm, bwd, greedy};
use psl::util::json::Json;
use psl::util::rng::Rng;

fn main() {
    let mut report = Report::new("solver_micro", &["bench", "mean", "p90", "iters"]);
    let mut add = |name: &str, warmup: usize, iters: usize, f: &mut dyn FnMut()| {
        let s = time_fn(f, warmup, iters);
        report.row(
            vec![name.into(), fmt_s(s.mean), fmt_s(s.p90), s.n.to_string()],
            Json::obj(vec![
                ("bench", Json::Str(name.into())),
                ("mean_s", Json::Num(s.mean)),
                ("p90_s", Json::Num(s.p90)),
            ]),
        );
        eprintln!("[micro] {name}: {}", fmt_s(s.mean));
    };

    // Instance generation.
    add("gen_scenario2_j50_i10", 1, 10, &mut || {
        let _ = ScenarioCfg::new(Scenario::S2, Model::ResNet101, 50, 10, 1).generate();
    });

    // Baker block scheduler, 64 jobs.
    let mut rng = Rng::seeded(4);
    let jobs: Vec<bwd::Job> = (0..64)
        .map(|id| bwd::Job {
            id,
            release: rng.below(200) as u32,
            proc: rng.range_usize(1, 12) as u32,
            tail: rng.below(60) as u32,
        })
        .collect();
    let total: u32 = jobs.iter().map(|j| j.proc).sum();
    let free = psl::solver::schedule::SlotRuns::one(0, 400 + total);
    add("baker_block_64jobs", 3, 50, &mut || {
        let _ = bwd::preemptive_min_max_tail(&jobs, &free);
    });
    let mut scratch = bwd::CostScratch::default();
    add("ldt_cost_64jobs", 3, 200, &mut || {
        let _ = bwd::preemptive_cost_contiguous(&jobs, &mut scratch);
    });

    // FCFS scheduling at J=100.
    let inst100 = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 100, 10, 2).generate().quantize(180.0);
    let asg = Assignment::new((0..100).map(|j| j % 10).collect());
    add("fcfs_j100_i10", 2, 30, &mut || {
        let _ = fcfs_schedule(&inst100, asg.clone());
    });

    // balanced-greedy end-to-end at J=100 / J=1000.
    add("greedy_j100_i10", 2, 30, &mut || {
        let _ = greedy::solve(&inst100);
    });
    let inst1000 = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 1000, 20, 2).generate().quantize(180.0);
    add("greedy_j1000_i20", 1, 5, &mut || {
        let _ = greedy::solve(&inst1000);
    });

    // ADMM end-to-end at the paper's "14 minutes on Gurobi" size (70, 10).
    let inst70 = ScenarioCfg::new(Scenario::S2, Model::ResNet101, 70, 10, 3).generate().quantize(180.0);
    add("admm_j70_i10", 0, 3, &mut || {
        let _ = admm::solve(&inst70, &admm::AdmmCfg::default());
    });

    // ADMM at a medium size.
    let inst20 = ScenarioCfg::new(Scenario::S2, Model::Vgg19, 20, 5, 3).generate().quantize(550.0);
    add("admm_j20_i5", 1, 5, &mut || {
        let _ = admm::solve(&inst20, &admm::AdmmCfg::default());
    });

    // Continuous replay.
    let ms = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 100, 10, 2).generate();
    let sched = greedy::solve(&inst100).unwrap();
    add("replay_j100", 2, 30, &mut || {
        let _ = sim::replay(&ms, &sched, None);
    });

    report.finish();
    println!(
        "\nperf reference points: the paper reports 14 min for ADMM(+ILP subproblems) at (70,10);\n\
         our target (DESIGN.md §Perf) is ≥10x faster via the specialized subproblem solvers."
    );
}
