//! FIGURE 8 reproduction: batch makespan vs number of helpers for J = 100
//! clients in Scenario 1 with balanced-greedy (the strategy's pick at
//! this scale), reported as relative gains of adding each helper.
//!
//! Expected shape (Observation 4): adding the 2nd helper cuts the
//! makespan dramatically (paper: up to 47.6%); gains diminish past ~10.
//!
//! Run: cargo bench --bench fig8_helper_scaling

use psl::bench::Report;
use psl::instance::profiles::Model;
use psl::instance::scenario::{Scenario, ScenarioCfg};
use psl::solver::greedy;
use psl::util::json::Json;
use psl::util::stats::mean;

fn main() {
    let j = 100;
    let seeds: Vec<u64> = (0..5).collect();
    let mut report = Report::new("fig8_helper_scaling", &["model", "I", "makespan[s]", "gain vs I-1", "gain vs I=1"]);
    for model in [Model::ResNet101, Model::Vgg19] {
        let slot = model.profile().default_slot_ms;
        let mut prev: Option<f64> = None;
        let mut first: Option<f64> = None;
        for i in 1..=14usize {
            let makespans: Vec<f64> = seeds
                .iter()
                .map(|&seed| {
                    let inst = ScenarioCfg::new(Scenario::S1, model, j, i, 3_000 + seed).generate().quantize(slot);
                    greedy::solve(&inst).expect("feasible").makespan(&inst) as f64 * slot / 1000.0
                })
                .collect();
            let m = mean(&makespans);
            if first.is_none() {
                first = Some(m);
            }
            let d_prev = prev.map(|p| (p - m) / p * 100.0);
            let d_first = (first.unwrap() - m) / first.unwrap() * 100.0;
            report.row(
                vec![
                    model.name().into(),
                    i.to_string(),
                    format!("{m:.1}"),
                    d_prev.map(|d| format!("{d:.1}%")).unwrap_or_else(|| "-".into()),
                    format!("{d_first:.1}%"),
                ],
                Json::obj(vec![
                    ("model", Json::Str(model.name().into())),
                    ("i", Json::Num(i as f64)),
                    ("makespan_s", Json::Num(m)),
                    ("gain_vs_prev_pct", Json::Num(d_prev.unwrap_or(0.0))),
                ]),
            );
            prev = Some(m);
        }
        eprintln!("[fig8] {} done", model.name());
    }
    report.finish();
    println!("\nexpected shape (paper Fig 8 / Obs 4): ~47.6% drop from I=1→2, diminishing returns past ~10.");
}
