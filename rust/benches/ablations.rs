//! Ablations of the design choices DESIGN.md calls out:
//!
//!  A1  value of Algorithm 2 — optimal bwd schedule vs FCFS bwd, holding
//!      the assignment + fwd schedule fixed (Theorem 2's payoff);
//!  A2  value of preemption — ADMM (preemptive) vs its non-preemptive
//!      defragmented counterpart under the §VI switching-cost lens;
//!  A3  value of the w-subproblem local search — ADMM with 0 sweeps vs
//!      the default 3;
//!  A4  value of makespan-aware assignment — ADMM assignment + optimal
//!      schedules vs balanced assignment + optimal schedules.
//!
//! Run: cargo bench --bench ablations

use psl::bench::Report;
use psl::instance::profiles::Model;
use psl::instance::scenario::{Scenario, ScenarioCfg};
use psl::solver::schedule::fcfs_schedule;
use psl::solver::{admm, bwd, greedy};
use psl::util::json::Json;
use psl::util::stats::mean;

fn main() {
    let seeds: Vec<u64> = (0..5).collect();
    let mut report = Report::new("ablations", &["ablation", "scenario", "baseline[s]", "variant[s]", "gain%"]);
    let mut add_row = |name: &str, scen: &str, base: f64, var: f64, rec: Json| {
        let gain = (base - var) / base * 100.0;
        report.row(
            vec![name.into(), scen.into(), format!("{base:.1}"), format!("{var:.1}"), format!("{gain:.1}")],
            rec,
        );
        eprintln!("[ablation] {name}/{scen}: {base:.1}s -> {var:.1}s ({gain:.1}%)");
    };

    for scenario in [Scenario::S1, Scenario::S2] {
        let slot = 180.0;
        let insts: Vec<_> = seeds
            .iter()
            .map(|&s| ScenarioCfg::new(scenario, Model::ResNet101, 20, 4, 500 + s).generate().quantize(slot))
            .collect();

        // A1: FCFS bwd vs Algorithm 2 bwd on the greedy assignment.
        let fcfs_ms: Vec<f64> = insts
            .iter()
            .map(|inst| greedy::solve(inst).unwrap().makespan(inst) as f64 * slot / 1000.0)
            .collect();
        let alg2_ms: Vec<f64> = insts
            .iter()
            .map(|inst| {
                let g = greedy::solve(inst).unwrap();
                bwd::complete_with_optimal_bwd(inst, g.assignment.clone(), g.fwd.clone())
                    .makespan(inst) as f64
                    * slot
                    / 1000.0
            })
            .collect();
        add_row(
            "A1 optimal-bwd (Alg.2)",
            scenario.name(),
            mean(&fcfs_ms),
            mean(&alg2_ms),
            Json::obj(vec![
                ("ablation", Json::Str("A1".into())),
                ("scenario", Json::Str(scenario.name().into())),
                ("fcfs_s", Json::Num(mean(&fcfs_ms))),
                ("alg2_s", Json::Num(mean(&alg2_ms))),
            ]),
        );

        // A2: preemptive ADMM schedule vs non-preemptive FCFS on the same
        // (ADMM) assignment.
        let admm_scheds: Vec<_> = insts
            .iter()
            .map(|inst| admm::solve(inst, &admm::AdmmCfg::default()).unwrap().schedule)
            .collect();
        let preemptive: Vec<f64> = insts
            .iter()
            .zip(&admm_scheds)
            .map(|(inst, s)| s.makespan(inst) as f64 * slot / 1000.0)
            .collect();
        let nonpreemptive: Vec<f64> = insts
            .iter()
            .zip(&admm_scheds)
            .map(|(inst, s)| fcfs_schedule(inst, s.assignment.clone()).makespan(inst) as f64 * slot / 1000.0)
            .collect();
        add_row(
            "A2 preemption",
            scenario.name(),
            mean(&nonpreemptive),
            mean(&preemptive),
            Json::obj(vec![
                ("ablation", Json::Str("A2".into())),
                ("scenario", Json::Str(scenario.name().into())),
                ("nonpreemptive_s", Json::Num(mean(&nonpreemptive))),
                ("preemptive_s", Json::Num(mean(&preemptive))),
            ]),
        );

        // A3: local search off vs on.
        let no_ls: Vec<f64> = insts
            .iter()
            .map(|inst| {
                let cfg = admm::AdmmCfg { w_sweeps: 0, ..Default::default() };
                admm::solve(inst, &cfg).unwrap().schedule.makespan(inst) as f64 * slot / 1000.0
            })
            .collect();
        add_row(
            "A3 w-local-search",
            scenario.name(),
            mean(&no_ls),
            mean(&preemptive),
            Json::obj(vec![
                ("ablation", Json::Str("A3".into())),
                ("scenario", Json::Str(scenario.name().into())),
                ("no_ls_s", Json::Num(mean(&no_ls))),
                ("ls_s", Json::Num(mean(&preemptive))),
            ]),
        );

        // A4: balanced assignment + optimal schedules vs ADMM assignment +
        // optimal schedules (isolates the assignment decision).
        let balanced_opt: Vec<f64> = insts
            .iter()
            .map(|inst| {
                let a = greedy::balanced_assignment(inst).unwrap();
                let fwd = admm::schedule_fwd_given_assignment(inst, &a.helper_of);
                bwd::complete_with_optimal_bwd(inst, a, fwd).makespan(inst) as f64 * slot / 1000.0
            })
            .collect();
        add_row(
            "A4 makespan-aware assignment",
            scenario.name(),
            mean(&balanced_opt),
            mean(&preemptive),
            Json::obj(vec![
                ("ablation", Json::Str("A4".into())),
                ("scenario", Json::Str(scenario.name().into())),
                ("balanced_opt_s", Json::Num(mean(&balanced_opt))),
                ("admm_s", Json::Num(mean(&preemptive))),
            ]),
        );
    }
    report.finish();
    println!(
        "\nexpected: every ablation gain ≥ 0 on average, largest in Scenario 2 —\n\
         the paper's premise that scheduling AND assignment both matter (§I, §VII)."
    );
}
