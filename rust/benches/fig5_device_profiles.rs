//! TABLE I + FIGURE 5 reproduction: the testbed profile bank.
//!
//! Table I: whole-model batch-update times per device. Fig 5: profiled
//! part-1 compute times (fwd and bwd separately — the asymmetry that
//! motivates joint fwd/bwd optimization, §VII).
//!
//! Run: cargo bench --bench fig5_device_profiles

use psl::bench::Report;
use psl::instance::profiles::{Model, DEVICES};
use psl::util::json::Json;

fn main() {
    let mut t1 = Report::new("table1_device_batch_times", &["device", "resnet101[s]", "vgg19[s]", "ram[GB]", "helper?"]);
    for d in DEVICES {
        let r = d.device.batch_ms(Model::ResNet101) / 1000.0;
        let v = d.device.batch_ms(Model::Vgg19) / 1000.0;
        t1.row(
            vec![
                d.name.into(),
                format!("{r:.1}"),
                format!("{v:.1}"),
                format!("{:.0}", d.ram_gb),
                if d.helper_capable { "yes".into() } else { "no".into() },
            ],
            Json::obj(vec![
                ("device", Json::Str(d.name.into())),
                ("resnet_s", Json::Num(r)),
                ("vgg_s", Json::Num(v)),
                ("ram_gb", Json::Num(d.ram_gb)),
            ]),
        );
    }
    t1.finish();
    println!("paper Table I: RPi4 91.9/71.9s, Jetson(CPU) 143/396s, Jetson(GPU) 1.2/2.6s, VM 2/3.6s, M1 3.5/3.6s");

    let mut f5 = Report::new("fig5_part1_times", &["model", "device", "fwd[ms]", "bwd[ms]", "bwd/fwd"]);
    for model in [Model::ResNet101, Model::Vgg19] {
        let prof = model.profile();
        let (s1, _) = prof.default_cuts;
        for d in DEVICES {
            let (f, b) = d.device.range_fwd_bwd_ms(model, 1, s1);
            f5.row(
                vec![
                    prof.name.into(),
                    d.name.into(),
                    format!("{f:.0}"),
                    format!("{b:.0}"),
                    format!("{:.2}", b / f.max(1e-9)),
                ],
                Json::obj(vec![
                    ("model", Json::Str(prof.name.into())),
                    ("device", Json::Str(d.name.into())),
                    ("fwd_ms", Json::Num(f)),
                    ("bwd_ms", Json::Num(b)),
                ]),
            );
        }
    }
    f5.finish();
    println!(
        "\nexpected shape (Fig 5): bwd > fwd on every device; VGG19's bwd/fwd ratio larger than\n\
         ResNet101's (the paper's asymmetry argument for joint fwd+bwd optimization)."
    );
}
