//! FIGURE 7 reproduction: batch makespan of the ADMM-based method,
//! balanced-greedy and the random+FCFS baseline across both scenarios and
//! models, for (J, I) ∈ {(10,2), (30,5), (50,5), (70,10), (100,10)}.
//!
//! Expected shape (Observation 3 + discussion): ADMM wins medium sizes
//! (esp. Scenario 2, up to ~48% over balanced-greedy in the paper);
//! balanced-greedy takes over for large homogeneous instances; the
//! strategy (best of both) beats the baseline by up to ~52.3%, 23.4% on
//! average.
//!
//! Run: cargo bench --bench fig7_method_comparison
//! (PSL_FIG7_SEEDS to change averaging; default 3)

use psl::bench::Report;
use psl::instance::profiles::Model;
use psl::instance::scenario::{Scenario, ScenarioCfg};
use psl::solver::{admm, baseline, greedy};
use psl::util::json::Json;
use psl::util::rng::Rng;
use psl::util::stats::mean;

fn main() {
    let n_seeds: u64 = std::env::var("PSL_FIG7_SEEDS").ok().and_then(|v| v.parse().ok()).unwrap_or(3);
    let grid = [(10usize, 2usize), (30, 5), (50, 5), (70, 10), (100, 10)];
    let mut report = Report::new(
        "fig7_method_comparison",
        &["scenario", "model", "J", "I", "admm[s]", "greedy[s]", "baseline[s]", "strategyΔ%", "winner"],
    );
    let mut all_gains = Vec::new();
    for scenario in [Scenario::S1, Scenario::S2] {
        for model in [Model::ResNet101, Model::Vgg19] {
            let slot = model.profile().default_slot_ms;
            for &(j, i) in &grid {
                let mut admm_v = Vec::new();
                let mut greedy_v = Vec::new();
                let mut base_v = Vec::new();
                for seed in 0..n_seeds {
                    let inst = ScenarioCfg::new(scenario, model, j, i, 7_000 + seed).generate().quantize(slot);
                    let a = admm::solve(&inst, &admm::AdmmCfg::default()).expect("admm").schedule.makespan(&inst);
                    let g = greedy::solve(&inst).expect("greedy").makespan(&inst);
                    let b = baseline::solve_mean_makespan(&inst, &mut Rng::seeded(900 + seed), 5);
                    admm_v.push(a as f64 * slot / 1000.0);
                    greedy_v.push(g as f64 * slot / 1000.0);
                    base_v.push(b * slot / 1000.0);
                }
                let (a, g, b) = (mean(&admm_v), mean(&greedy_v), mean(&base_v));
                let strat = a.min(g); // the strategy keeps the better tool
                let gain = (b - strat) / b * 100.0;
                all_gains.push(gain);
                report.row(
                    vec![
                        scenario.name().into(),
                        model.name().into(),
                        j.to_string(),
                        i.to_string(),
                        format!("{a:.1}"),
                        format!("{g:.1}"),
                        format!("{b:.1}"),
                        format!("{gain:.1}"),
                        if a < g { "admm".into() } else { "greedy".into() },
                    ],
                    Json::obj(vec![
                        ("scenario", Json::Str(scenario.name().into())),
                        ("model", Json::Str(model.name().into())),
                        ("j", Json::Num(j as f64)),
                        ("i", Json::Num(i as f64)),
                        ("admm_s", Json::Num(a)),
                        ("greedy_s", Json::Num(g)),
                        ("baseline_s", Json::Num(b)),
                        ("strategy_gain_pct", Json::Num(gain)),
                    ]),
                );
                eprintln!(
                    "[fig7] {} {} (J={j},I={i}): admm {a:.1}s greedy {g:.1}s baseline {b:.1}s (gain {gain:.1}%)",
                    scenario.name(),
                    model.name()
                );
            }
        }
    }
    report.finish();
    let max_gain = all_gains.iter().cloned().fold(0.0, f64::max);
    println!(
        "\nstrategy vs baseline: mean gain {:.1}% | max gain {:.1}%\n\
         paper: up to 52.3%, average 23.4% — the *shape* to match: gains largest in\n\
         Scenario 2; ADMM preferred at medium sizes, balanced-greedy at J≳100 / homogeneous.",
        mean(&all_gains),
        max_gain
    );
}
