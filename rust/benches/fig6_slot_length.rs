//! FIGURE 6 reproduction: batch makespan obtained by the ADMM-based
//! method for time-slot lengths |S_t| ∈ {200, 150, 50} ms (Scenario 1),
//! with the solve-time speedup relative to the 50 ms case.
//!
//! Expected shape (Observation 2): makespan grows with |S_t| (coarser
//! preemption + ceil inflation), while solve time shrinks (smaller T).
//!
//! Run: cargo bench --bench fig6_slot_length

use psl::bench::Report;
use psl::instance::profiles::Model;
use psl::instance::scenario::{Scenario, ScenarioCfg};
use psl::sim::quantize::sweep_slot_lengths;
use psl::solver::admm::AdmmCfg;
use psl::util::json::Json;

fn main() {
    let slot_lengths = [200.0, 150.0, 50.0];
    let seeds: Vec<u64> = vec![21, 22, 23];
    let mut report = Report::new(
        "fig6_slot_length",
        &["model", "J", "I", "|S_t|[ms]", "T", "makespan[s]", "realized[s]", "solve-speedup", "preempt"],
    );
    for model in [Model::ResNet101, Model::Vgg19] {
        for &(j, i) in &[(10usize, 2usize), (15, 5)] {
            // Average rows across seeds.
            let mut acc: Vec<(f64, f64, f64, f64, f64)> = vec![(0.0, 0.0, 0.0, 0.0, 0.0); slot_lengths.len()];
            for &seed in &seeds {
                let ms = ScenarioCfg::new(Scenario::S1, model, j, i, seed).generate();
                let rows = sweep_slot_lengths(&ms, &slot_lengths, &AdmmCfg::default());
                for (k, r) in rows.iter().enumerate() {
                    acc[k].0 += r.horizon as f64;
                    acc[k].1 += r.nominal_ms;
                    acc[k].2 += r.realized_ms;
                    acc[k].3 += r.solve_s;
                    acc[k].4 += r.preemptions as f64;
                }
            }
            let n = seeds.len() as f64;
            let base_solve = acc[slot_lengths.len() - 1].3 / n; // |S_t| = 50 is last
            for (k, &slot) in slot_lengths.iter().enumerate() {
                let (t, nom, real, solve, pre) = acc[k];
                report.row(
                    vec![
                        model.name().into(),
                        j.to_string(),
                        i.to_string(),
                        format!("{slot:.0}"),
                        format!("{:.0}", t / n),
                        format!("{:.1}", nom / n / 1000.0),
                        format!("{:.1}", real / n / 1000.0),
                        format!("{:.1}%", (base_solve - solve / n) / base_solve * 100.0),
                        format!("{:.0}", pre / n),
                    ],
                    Json::obj(vec![
                        ("model", Json::Str(model.name().into())),
                        ("j", Json::Num(j as f64)),
                        ("slot_ms", Json::Num(slot)),
                        ("horizon", Json::Num(t / n)),
                        ("nominal_ms", Json::Num(nom / n)),
                        ("realized_ms", Json::Num(real / n)),
                        ("solve_s", Json::Num(solve / n)),
                    ]),
                );
            }
            eprintln!("[fig6] {} J={j} I={i} done", model.name());
        }
    }
    report.finish();
    println!(
        "\nexpected shape (paper Fig 6): makespan increases with |S_t|; solve time decreases\n\
         (they report up to 4.9% speedup at 200ms vs 50ms on their setup)."
    );
}
