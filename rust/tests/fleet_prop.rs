//! Property tests for the fleet-orchestration churn invariants:
//! deterministic event streams, stable client ids, arrivals drawn from
//! the scenario's device/link distributions, and memory-feasible repaired
//! assignments on every round.

use psl::fleet::events::{self, ChurnCfg};
use psl::fleet::orchestrator::{run_on_stream, FleetCfg, Policy};
use psl::fleet::{run, RoundEvents};
use psl::instance::profiles::{Device, Model};
use psl::instance::scenario::{Scenario, ScenarioCfg};
use psl::util::prop;

fn random_churn(rng: &mut psl::util::rng::Rng) -> ChurnCfg {
    ChurnCfg {
        rounds: rng.range_usize(2, 10),
        arrival_rate: rng.range_f64(0.0, 3.0),
        departure_prob: rng.range_f64(0.0, 0.5),
        max_clients: rng.range_usize(4, 24),
    }
}

#[test]
fn event_streams_deterministic_per_seed() {
    prop::check(40, |rng| {
        let base = rng.range_usize(1, 12);
        let churn = random_churn(rng);
        let seed = rng.next_u64();
        let a = events::generate(base, &churn, seed);
        let b = events::generate(base, &churn, seed);
        prop::assert_prop(a == b, "same (population, churn, seed) must replay identically");
    });
}

#[test]
fn client_ids_stable_across_rounds() {
    prop::check(40, |rng| {
        let base = rng.range_usize(1, 12);
        let churn = random_churn(rng);
        let stream = events::generate(base, &churn, rng.next_u64());
        let mut ever_seen: std::collections::BTreeSet<u64> = stream[0].roster.iter().copied().collect();
        for w in stream.windows(2) {
            let (prev, next) = (&w[0], &w[1]);
            for &id in &next.departures {
                prop::assert_prop(prev.roster.contains(&id), "departure of a present client");
            }
            for &id in &next.arrivals {
                prop::assert_prop(!ever_seen.contains(&id), "arrival ids are never reused");
                ever_seen.insert(id);
            }
            // Survivors keep their ids: every non-departed previous member
            // is still present under the same id.
            for &id in &prev.roster {
                prop::assert_prop(
                    next.roster.contains(&id) == !next.departures.contains(&id),
                    "survivor membership is exactly (previous minus departures)",
                );
            }
            prop::assert_prop(
                next.roster.len() <= churn.max_clients.max(base),
                "roster cap (raised to the base size if smaller) holds",
            );
        }
    });
}

#[test]
fn arrivals_draw_from_device_and_link_distributions() {
    // S1: client device mix is a uniform pool draw and links are clamped
    // lognormals — every minted client (base or arrival) must land inside
    // both supports.
    let pool: Vec<f64> = Device::client_pool().iter().map(|d| d.batch_ms(Model::ResNet101)).collect();
    prop::check(20, |rng| {
        let cfg = ScenarioCfg::new(Scenario::S1, Model::ResNet101, rng.range_usize(2, 8), rng.range_usize(1, 4), rng.next_u64());
        let world = cfg.fleet_world(24);
        for id in 0..24u64 {
            let c = world.mint_client(id);
            prop::assert_prop(
                pool.iter().any(|&p| (p - c.batch_ms).abs() < 1e-9),
                "minted batch time is a concrete pool member (S1 DeviceMix::Pool)",
            );
            for &r in &c.rates_mbps {
                prop::assert_prop((2.0..=60.0).contains(&r), "minted rate inside the Akamai-France clamp");
            }
            prop::assert_prop(c.d_gb <= world.d_cap + 1e-12, "admitted footprint respects the cap");
        }
    });
}

#[test]
fn repaired_assignments_always_satisfy_memory() {
    // The core safety property: whatever the churn history, every round's
    // schedule — repaired or fully re-solved — is feasible, including the
    // helper-memory constraint (5).
    prop::check(12, |rng| {
        let scen = Scenario::ALL[rng.below(Scenario::ALL.len())];
        let model = if rng.chance(0.5) { Model::ResNet101 } else { Model::Vgg19 };
        let j = rng.range_usize(2, 10);
        let i = rng.range_usize(1, 4);
        let cfg = ScenarioCfg::new(scen, model, j, i, rng.next_u64());
        let mut churn = random_churn(rng);
        churn.rounds = rng.range_usize(3, 6);
        churn.max_clients = churn.max_clients.max(j);
        let policy = [Policy::Incremental, Policy::RepairOnly][rng.below(2)];
        let fleet_cfg = FleetCfg::new(cfg, churn, policy);
        // run() debug-asserts per-round schedule feasibility (memory
        // included) before reporting; reaching the report is the property.
        let report = run(&fleet_cfg);
        for r in &report.rounds {
            prop::assert_prop(
                r.n_clients == 0 || r.makespan_slots >= r.lower_bound,
                "round makespan respects the fresh lower bound",
            );
            prop::assert_prop(
                r.n_clients > 0 || r.makespan_slots == 0,
                "empty rounds schedule nothing",
            );
        }
    });
}

#[test]
fn fleet_runs_deterministic_end_to_end() {
    let cfg = || {
        let scen = ScenarioCfg::new(Scenario::S4StragglerTail, Model::Vgg19, 8, 2, 31);
        let mut churn = ChurnCfg::stationary(8);
        churn.rounds = 6;
        FleetCfg::new(scen, churn, Policy::Incremental)
    };
    let a = run(&cfg()).to_json().pretty();
    let b = run(&cfg()).to_json().pretty();
    assert_eq!(a, b, "fleet report must replay byte-identically");
}

#[test]
fn injected_total_churn_recovers() {
    // Kill the whole fleet, then refill it purely with arrivals: every
    // arrival is minted from the scenario distributions and the
    // orchestrator reschedules from an empty warm state.
    let scen = ScenarioCfg::new(Scenario::S2, Model::ResNet101, 5, 2, 17);
    let world = scen.fleet_world(10);
    let stream = vec![
        RoundEvents::clients(0, vec![], vec![], vec![0, 1, 2, 3, 4]),
        RoundEvents::clients(1, vec![0, 1, 2, 3, 4], vec![], vec![]),
        RoundEvents::clients(2, vec![], vec![5, 6, 7], vec![5, 6, 7]),
        RoundEvents::clients(3, vec![5], vec![8], vec![6, 7, 8]),
    ];
    let churn = ChurnCfg { rounds: 4, arrival_rate: 0.0, departure_prob: 0.0, max_clients: 10 };
    let report = run_on_stream(&FleetCfg::new(scen, churn, Policy::Incremental), &world, &stream);
    assert_eq!(report.rounds[1].decision, "empty");
    assert!(report.rounds[2].makespan_slots > 0, "fresh arrivals get scheduled");
    assert!(report.rounds[3].makespan_slots > 0);
    assert_eq!(report.empty_rounds(), 1);
}
