//! Integration tests across the solver stack: every method on shared
//! scenario instances, cross-checked invariants (feasibility, ordering,
//! paper observations) — no artifacts required.

use psl::instance::profiles::Model;
use psl::instance::scenario::{Scenario, ScenarioCfg};
use psl::sim;
use psl::solver::{admm, baseline, bwd, exact, greedy, strategy};
use psl::util::rng::Rng;

fn inst(scen: Scenario, model: Model, j: usize, i: usize, seed: u64) -> psl::instance::Instance {
    let slot = model.profile().default_slot_ms;
    ScenarioCfg::new(scen, model, j, i, seed).generate().quantize(slot)
}

#[test]
fn all_methods_feasible_and_ordered_on_small_instance() {
    // exact ≤ admm, exact ≤ greedy, and the strategy ≤ baseline.
    let inst = inst(Scenario::S2, Model::Vgg19, 8, 2, 1);
    let ex = exact::solve(&inst, &exact::ExactCfg { time_budget: std::time::Duration::from_secs(20), ..Default::default() });
    let a = admm::solve(&inst, &admm::AdmmCfg::default()).unwrap().schedule;
    let g = greedy::solve(&inst).unwrap();
    let (s, _) = strategy::solve(&inst, &admm::AdmmCfg::default()).unwrap();
    let b = baseline::solve(&inst, &mut Rng::seeded(5)).unwrap();
    for (name, sched) in [("exact", &ex.schedule), ("admm", &a), ("greedy", &g), ("strategy", &s), ("baseline", &b)] {
        assert!(sched.is_feasible(&inst), "{name}: {:?}", sched.violations(&inst));
    }
    assert!(ex.makespan <= a.makespan(&inst), "exact must not lose to admm");
    assert!(ex.makespan <= g.makespan(&inst), "exact must not lose to greedy");
    assert!(s.makespan(&inst) <= g.makespan(&inst), "strategy keeps the better tool");
    assert!(ex.makespan as u32 >= inst.makespan_lower_bound());
}

#[test]
fn admm_beats_baseline_on_average_scenario2() {
    // Observation 3's direction: the optimizing methods beat random+FCFS
    // on average in the heterogeneous scenario.
    let mut admm_tot = 0.0;
    let mut base_tot = 0.0;
    let mut rng = Rng::seeded(77);
    for seed in 0..5 {
        let inst = inst(Scenario::S2, Model::ResNet101, 20, 5, 100 + seed);
        let a = admm::solve(&inst, &admm::AdmmCfg::default()).unwrap().schedule.makespan(&inst) as f64;
        admm_tot += a;
        base_tot += baseline::solve_mean_makespan(&inst, &mut rng, 5);
    }
    assert!(
        admm_tot < base_tot,
        "ADMM ({admm_tot}) should beat baseline ({base_tot}) on average in Scenario 2"
    );
}

#[test]
fn helper_scaling_monotone_in_expectation() {
    // Observation 4's direction: more helpers → shorter (or equal)
    // makespan on average; the 1→2 jump is the largest.
    let mean_at = |i: usize| -> f64 {
        (0..4)
            .map(|seed| {
                let inst = inst(Scenario::S1, Model::ResNet101, 60, i, 200 + seed);
                greedy::solve(&inst).unwrap().makespan(&inst) as f64
            })
            .sum::<f64>()
            / 4.0
    };
    let m1 = mean_at(1);
    let m2 = mean_at(2);
    let m8 = mean_at(8);
    assert!(m2 < m1, "second helper must help: {m1} -> {m2}");
    assert!(m8 < m2, "more helpers keep helping: {m2} -> {m8}");
    let first_gain = (m1 - m2) / m1;
    assert!(first_gain > 0.2, "1→2 helper gain should be large, got {:.1}%", first_gain * 100.0);
}

#[test]
fn optimal_bwd_improves_or_matches_fcfs_bwd() {
    // Theorem 2's value: swapping a FCFS bwd schedule for Algorithm 2
    // never hurts, keeping the same assignment and fwd schedule.
    for seed in 0..6 {
        let inst = inst(Scenario::S2, Model::Vgg19, 12, 3, 300 + seed);
        let fcfs = greedy::solve(&inst).unwrap();
        let improved = bwd::complete_with_optimal_bwd(&inst, fcfs.assignment.clone(), fcfs.fwd.clone());
        assert!(improved.is_feasible(&inst));
        assert!(improved.makespan(&inst) <= fcfs.makespan(&inst));
    }
}

#[test]
fn replay_consistent_across_methods() {
    let model = Model::ResNet101;
    let ms = ScenarioCfg::new(Scenario::S2, model, 15, 4, 9).generate();
    let slotted = ms.quantize(180.0);
    for (name, sched) in [
        ("admm", admm::solve(&slotted, &admm::AdmmCfg::default()).unwrap().schedule),
        ("greedy", greedy::solve(&slotted).unwrap()),
    ] {
        let rep = sim::replay(&ms, &sched, None);
        let nominal = sched.makespan(&slotted) as f64 * slotted.slot_ms;
        assert!(rep.makespan_ms <= nominal + 1e-6, "{name}: replay exceeds nominal");
        assert!(rep.makespan_ms > 0.0);
        assert_eq!(rep.completion_ms.len(), 15);
    }
}

#[test]
fn exact_is_anytime_and_never_worse_than_incumbents() {
    let inst = inst(Scenario::S2, Model::ResNet101, 14, 4, 4);
    let quick = exact::solve(
        &inst,
        &exact::ExactCfg { node_cap: 200, helper_node_cap: 2_000, time_budget: std::time::Duration::from_secs(3) },
    );
    let a = admm::solve(&inst, &admm::AdmmCfg::default()).unwrap().schedule.makespan(&inst);
    let g = greedy::solve(&inst).unwrap().makespan(&inst);
    assert!(quick.makespan <= a.min(g), "anytime exact seeds from the heuristics");
    assert!(quick.schedule.is_feasible(&inst));
}

#[test]
fn scenario_strategy_picks_match_paper_rules() {
    let huge = inst(Scenario::S1, Model::ResNet101, 120, 10, 1);
    assert_eq!(strategy::pick(&huge), strategy::Method::BalancedGreedy);
    let medium_het = inst(Scenario::S2, Model::Vgg19, 20, 5, 1);
    assert_eq!(strategy::pick(&medium_het), strategy::Method::Admm);
}

#[test]
fn switch_cost_extension_consistent() {
    let slot = 180.0;
    let inst = ScenarioCfg::new(Scenario::S2, Model::ResNet101, 10, 3, 6)
        .with_switch_cost(2.0 * slot)
        .generate()
        .quantize(slot);
    let res = admm::solve(&inst, &admm::AdmmCfg::default()).unwrap();
    let plain = res.schedule.makespan(&inst);
    let adjusted = res.schedule.makespan_with_switch_cost(&inst);
    assert!(adjusted >= plain);
    // With zero preemptions FCFS pays only the per-task start/stop edges.
    let g = greedy::solve(&inst).unwrap();
    assert_eq!(g.preemptions(), 0);
}
