//! Artifact-gated integration tests: the PJRT runtime and the SL
//! execution driver against the real AOT artifacts (`make artifacts`).
//! Each test skips (with a note) when artifacts/ is absent, so plain
//! `cargo test` stays green in a fresh checkout.

use psl::instance::profiles::Model;
use psl::instance::scenario::{Scenario, ScenarioCfg};
use psl::runtime::{Engine, Manifest, Tensor};
use psl::slexec::{Driver, SplitModel, TrainCfg};
use psl::solver::{admm, strategy};
use std::sync::Arc;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = psl::runtime::artifacts_dir();
    if dir.join("vgg_mini/manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("[skip] artifacts/ not built; run `make artifacts`");
        None
    }
}

#[test]
fn manifest_loads_and_params_match_shapes() {
    let Some(dir) = artifacts() else { return };
    for arch in ["vgg_mini", "resnet_mini"] {
        let m = Manifest::load(&dir, arch).unwrap();
        assert_eq!(m.arch, arch);
        assert_eq!(m.functions.len(), 6);
        for part in ["p1", "p2", "p3"] {
            let params = m.load_init_params(part).unwrap();
            let spec = &m.params[part];
            assert_eq!(params.len(), spec.leaves.len());
            for (t, leaf) in params.iter().zip(&spec.leaves) {
                assert_eq!(t.shape, leaf.shape, "{arch}/{part}");
            }
        }
    }
}

#[test]
fn part_functions_execute_and_compose() {
    let Some(dir) = artifacts() else { return };
    let engine = Arc::new(Engine::cpu().unwrap());
    let model = SplitModel::load(engine, &dir, "vgg_mini").unwrap();
    let batch = model.manifest.batch;
    let p1 = model.manifest.load_init_params("p1").unwrap();
    let p2 = model.manifest.load_init_params("p2").unwrap();
    let p3 = model.manifest.load_init_params("p3").unwrap();

    let mut ds = psl::data::SynthDataset::new(1, 0.35);
    let (x, y) = ds.batch(batch);
    let a1 = model.part1_fwd(&p1, &x).unwrap();
    assert_eq!(a1.shape[0], batch);
    let a2 = model.part2_fwd(&p2, &a1).unwrap();
    let loss = model.part3_loss(&p3, &a2, &y).unwrap();
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    // Untrained 10-class model: loss ≈ ln(10) ≈ 2.30.
    assert!((loss - 2.302).abs() < 0.8, "initial loss {loss} far from ln(10)");

    let (loss2, g3, g_a2) = model.part3_bwd(&p3, &a2, &y).unwrap();
    assert!((loss - loss2).abs() < 1e-5);
    assert_eq!(g3.len(), p3.len());
    assert_eq!(g_a2.shape, a2.shape);
    let (g2, g_a1) = model.part2_bwd(&p2, &a1, &g_a2).unwrap();
    assert_eq!(g2.len(), p2.len());
    assert_eq!(g_a1.shape, a1.shape);
    for (g, p) in g2.iter().zip(&p2) {
        assert_eq!(g.shape, p.shape);
    }
    let g1 = model.part1_bwd(&p1, &x, &g_a1).unwrap();
    assert_eq!(g1.len(), p1.len());
    // Gradients flow: at least one non-zero leaf everywhere.
    let nonzero = |ts: &[Tensor]| ts.iter().any(|t| t.as_f32().unwrap().iter().any(|v| v.abs() > 1e-12));
    assert!(nonzero(&g1) && nonzero(&g2) && nonzero(&g3), "dead gradients");
}

#[test]
fn sgd_on_parts_reduces_loss() {
    let Some(dir) = artifacts() else { return };
    let engine = Arc::new(Engine::cpu().unwrap());
    let model = SplitModel::load(engine, &dir, "vgg_mini").unwrap();
    let batch = model.manifest.batch;
    let mut p1 = model.manifest.load_init_params("p1").unwrap();
    let mut p2 = model.manifest.load_init_params("p2").unwrap();
    let mut p3 = model.manifest.load_init_params("p3").unwrap();
    let mut ds = psl::data::SynthDataset::new(3, 0.35);
    let (x, y) = ds.batch(batch);
    let mut losses = Vec::new();
    for _ in 0..6 {
        let a1 = model.part1_fwd(&p1, &x).unwrap();
        let a2 = model.part2_fwd(&p2, &a1).unwrap();
        let (loss, g3, g_a2) = model.part3_bwd(&p3, &a2, &y).unwrap();
        losses.push(loss);
        let (g2, g_a1) = model.part2_bwd(&p2, &a1, &g_a2).unwrap();
        let g1 = model.part1_bwd(&p1, &x, &g_a1).unwrap();
        let lr = 0.05;
        for (p, g) in p1.iter_mut().zip(&g1) {
            p.sgd_step(g, lr).unwrap();
        }
        for (p, g) in p2.iter_mut().zip(&g2) {
            p.sgd_step(g, lr).unwrap();
        }
        for (p, g) in p3.iter_mut().zip(&g3) {
            p.sgd_step(g, lr).unwrap();
        }
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "same-batch SGD must reduce loss: {losses:?}"
    );
}

#[test]
fn driver_trains_with_fedavg_and_schedule_order() {
    let Some(dir) = artifacts() else { return };
    let engine = Arc::new(Engine::cpu().unwrap());
    let model = SplitModel::load(engine, &dir, "vgg_mini").unwrap();
    let inst = ScenarioCfg::new(Scenario::S2, Model::Vgg19, 3, 2, 11).generate().quantize(550.0);
    let (schedule, _) = strategy::solve(&inst, &admm::AdmmCfg::default()).unwrap();
    let mut driver = Driver::new(model, &inst, schedule, 11).unwrap();
    let report = driver
        .train(&TrainCfg { batches_per_round: 3, rounds: 2, lr: 0.05, seed: 11 })
        .unwrap();
    assert_eq!(report.steps, 6);
    assert!(report.loss_curve.iter().all(|l| l.is_finite()));
    assert!(!report.measured_ms.is_empty(), "helper tasks must be measured");
    // The trend over the run should be downward.
    let first2 = (report.loss_curve[0] + report.loss_curve[1]) / 2.0;
    let last2 = (report.loss_curve[4] + report.loss_curve[5]) / 2.0;
    assert!(last2 < first2, "loss trend not downward: {:?}", report.loss_curve);
}

#[test]
fn resnet_mini_artifacts_also_execute() {
    let Some(dir) = artifacts() else { return };
    let engine = Arc::new(Engine::cpu().unwrap());
    let model = SplitModel::load(engine, &dir, "resnet_mini").unwrap();
    let p1 = model.manifest.load_init_params("p1").unwrap();
    let mut ds = psl::data::SynthDataset::new(5, 0.3);
    let (x, _) = ds.batch(model.manifest.batch);
    let a1 = model.part1_fwd(&p1, &x).unwrap();
    assert_eq!(a1.shape[0], model.manifest.batch);
}
