//! Integration tests for the multi-threaded sweep runner: deterministic
//! per-cell seeding (same grid + seed ⇒ byte-identical JSON regardless of
//! thread count) and canonical merge order.

use psl::bench::sweep::{cell_seed, cells, rows_to_json, run, SweepCfg};
use psl::instance::profiles::Model;
use psl::instance::scenario::Scenario;

fn grid_cfg(threads: usize) -> SweepCfg {
    SweepCfg {
        scenarios: vec![
            Scenario::S1,
            Scenario::S3Clustered,
            Scenario::S5MemoryStarved,
            Scenario::S6MegaHomogeneous,
        ],
        models: vec![Model::Vgg19],
        sizes: vec![(4, 2), (6, 2)],
        seeds: vec![7, 8],
        methods: vec!["greedy".to_string(), "baseline".to_string()],
        slot_ms: Some(550.0),
        transport: psl::transport::TransportCfg::dedicated(),
        threads,
    }
}

#[test]
fn json_byte_identical_across_thread_counts() {
    let one = rows_to_json(&run(&grid_cfg(1))).pretty();
    let four = rows_to_json(&run(&grid_cfg(4))).pretty();
    let eight = rows_to_json(&run(&grid_cfg(8))).pretty();
    assert_eq!(one, four, "1-thread and 4-thread sweeps must serialize identically");
    assert_eq!(one, eight, "1-thread and 8-thread sweeps must serialize identically");
}

#[test]
fn rows_merge_in_canonical_grid_order() {
    let cfg = grid_cfg(4);
    let grid = cells(&cfg);
    let rows = run(&cfg);
    assert_eq!(rows.len(), grid.len());
    assert_eq!(rows.len(), 4 * 1 * 2 * 2 * 2, "4 scenarios x 1 model x 2 sizes x 2 seeds x 2 methods");
    for (row, cell) in rows.iter().zip(&grid) {
        assert_eq!(row.scenario, cell.scenario.name());
        assert_eq!(row.model, cell.model.name());
        assert_eq!(row.n_clients, cell.n_clients);
        assert_eq!(row.n_helpers, cell.n_helpers);
        assert_eq!(row.seed, cell.seed);
        assert_eq!(row.method, cell.method);
    }
    // Canonical order: all of scenario1's cells precede s3-clustered's.
    let s1_last = rows.iter().rposition(|r| r.scenario == "scenario1").unwrap();
    let s3_first = rows.iter().position(|r| r.scenario == "s3-clustered").unwrap();
    assert!(s1_last < s3_first);
}

#[test]
fn per_cell_seeds_are_order_independent() {
    // The baseline's RNG stream is a function of the cell coordinates
    // only, so permuting the grid definition must not change any cell's
    // result row.
    let forward = run(&grid_cfg(2));
    let mut reversed_cfg = grid_cfg(2);
    reversed_cfg.scenarios.reverse();
    reversed_cfg.seeds.reverse();
    let reversed = run(&reversed_cfg);
    for row in &forward {
        let twin = reversed
            .iter()
            .find(|r| {
                r.scenario == row.scenario
                    && r.seed == row.seed
                    && r.n_clients == row.n_clients
                    && r.n_helpers == row.n_helpers
                    && r.method == row.method
            })
            .expect("every cell exists in the permuted sweep");
        assert_eq!(twin, row, "cell result depends on grid position");
    }
}

#[test]
fn changing_the_seed_changes_the_outcome_stream() {
    let mut a_cfg = grid_cfg(1);
    a_cfg.seeds = vec![7];
    let mut b_cfg = grid_cfg(1);
    b_cfg.seeds = vec![8];
    let a = rows_to_json(&run(&a_cfg)).pretty();
    let b = rows_to_json(&run(&b_cfg)).pretty();
    assert_ne!(a, b, "different base seeds must produce different sweeps");
    // And cell seeds differ per-coordinate.
    let ca = cells(&a_cfg);
    let cb = cells(&b_cfg);
    assert_ne!(cell_seed(&ca[0]), cell_seed(&cb[0]));
}

#[test]
fn full_family_strategy_sweep_is_deterministic() {
    // The acceptance-criteria shape: >= 4 families x >= 2 solvers across
    // multiple threads, with the strategy method recording its pick.
    let cfg = SweepCfg {
        scenarios: vec![
            Scenario::S1,
            Scenario::S2,
            Scenario::S4StragglerTail,
            Scenario::S6MegaHomogeneous,
        ],
        models: vec![Model::Vgg19],
        sizes: vec![(5, 2)],
        seeds: vec![21],
        methods: vec!["strategy".to_string(), "greedy".to_string()],
        slot_ms: Some(550.0),
        transport: psl::transport::TransportCfg::dedicated(),
        threads: 3,
    };
    let a = run(&cfg);
    let b = run(&cfg);
    assert_eq!(a, b);
    for r in a.iter().filter(|r| r.method == "strategy") {
        assert!(r.picked.is_some(), "{}: strategy row missing pick", r.scenario);
        assert!(r.makespan_slots.unwrap() >= r.lower_bound);
    }
}
