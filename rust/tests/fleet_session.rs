//! Long-horizon fleet-session guarantees: checkpoint/resume is
//! byte-identical, and session state stays O(max_clients) no matter how
//! many clients ever existed.

use psl::fleet::{ChurnCfg, FleetCfg, FleetCheckpoint, FleetSession, HelperChurnCfg, Policy};
use psl::instance::profiles::Model;
use psl::instance::scenario::{Scenario, ScenarioCfg};
use psl::util::json::Json;

fn golden_cfg() -> FleetCfg {
    let scen = ScenarioCfg::new(Scenario::S4StragglerTail, Model::Vgg19, 6, 2, 11);
    let mut churn = ChurnCfg::stationary(6);
    churn.rounds = 2000;
    let mut cfg = FleetCfg::new(scen, churn, Policy::Incremental);
    // One batch pair per round keeps the replay cost linear in rounds.
    cfg.epoch_batches = 2;
    cfg
}

/// The resume golden: a straight 2000-round run vs the same run
/// checkpointed — through the full JSON text round trip — and resumed
/// every 500 rounds. Final report and the round JSONL stream must match
/// byte for byte.
#[test]
fn checkpointed_run_matches_straight_run_over_2000_rounds() {
    let mut straight = FleetSession::new(golden_cfg());
    let stream = straight.event_stream();
    assert_eq!(stream.len(), 2000);
    for ev in &stream {
        straight.step(ev);
    }
    let straight_lines: Vec<String> = straight.completed().iter().map(|r| r.jsonl_line()).collect();
    let straight_report = straight.into_report().to_json().pretty();

    let mut session = FleetSession::new(golden_cfg());
    let mut resumes = 0;
    while session.next_round() < 2000 {
        session.step(&stream[session.next_round()]);
        let done = session.next_round();
        if done % 500 == 0 && done < 2000 {
            // Through the serialized text, exactly as the CLI would.
            let text = session.checkpoint().to_json().pretty();
            let ckpt = FleetCheckpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
            session = FleetSession::resume(ckpt).unwrap();
            assert_eq!(session.next_round(), done, "resume keeps the cursor");
            assert_eq!(session.event_stream(), stream, "config regenerates the identical stream");
            resumes += 1;
        }
    }
    assert_eq!(resumes, 3, "checkpointed at rounds 500, 1000, 1500");

    let lines: Vec<String> = session.completed().iter().map(|r| r.jsonl_line()).collect();
    assert_eq!(lines, straight_lines, "round JSONL stream is byte-identical");
    assert_eq!(session.into_report().to_json().pretty(), straight_report, "final report is byte-identical");
}

/// Heavy churn for 1500 rounds: hundreds of distinct client ids pass
/// through, but the session must only ever hold the live roster — the
/// minted cache and the checkpointed warm state are bounded by the
/// roster cap, not by the total ids seen.
#[test]
fn long_horizon_state_is_bounded_by_the_roster_cap() {
    let cap = 8;
    let scen = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 4, 2, 5);
    let churn = ChurnCfg { rounds: 1500, arrival_rate: 1.2, departure_prob: 0.3, max_clients: cap };
    let mut cfg = FleetCfg::new(scen, churn, Policy::RepairOnly);
    cfg.epoch_batches = 1;
    let mut session = FleetSession::new(cfg);
    let stream = session.event_stream();
    let total_arrivals: usize = stream.iter().map(|ev| ev.arrivals.len()).sum();
    assert!(
        total_arrivals > 20 * cap,
        "churn not heavy enough to expose a leak ({total_arrivals} arrivals)"
    );
    for ev in &stream {
        let round = session.step(ev);
        assert!(
            session.minted_len() <= cap,
            "round {}: minted cache grew to {} (> cap {cap})",
            ev.round,
            session.minted_len()
        );
        assert_eq!(session.minted_len(), round.n_clients, "cache tracks the live roster exactly");
    }
    let ckpt = session.checkpoint();
    assert!(ckpt.prev_assign.len() <= cap, "warm state bounded: {} assignments", ckpt.prev_assign.len());
    assert_eq!(ckpt.rounds.len(), 1500);
}

/// The same long-horizon guarantee with helper churn enabled: 1500
/// rounds of outages, returns, diurnal rate swings and permanent joins.
/// Every round must still step (the session debug-asserts schedule
/// feasibility on the surviving helper set before reporting), the live
/// pool never empties, warm state stays O(max_clients + max_helpers),
/// and an independent session over the same config replays the report
/// byte for byte.
#[test]
fn long_horizon_helper_churn_stays_feasible_and_bounded() {
    let cap = 8;
    let helper_cap = 6; // max(--max-helpers, base I=3)
    let cfg = || {
        let scen = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 4, 3, 5);
        let churn = ChurnCfg { rounds: 1500, arrival_rate: 1.2, departure_prob: 0.3, max_clients: cap };
        let mut cfg = FleetCfg::new(scen, churn, Policy::Incremental);
        cfg.epoch_batches = 1;
        cfg.helper_churn = HelperChurnCfg {
            down_rate: 0.12,
            outage_rounds: 2,
            join_rate: 0.05,
            max_helpers: helper_cap,
            diurnal_period: 50,
        };
        cfg
    };
    let mut session = FleetSession::new(cfg());
    let stream = session.event_stream();
    let outages: usize = stream.iter().map(|ev| ev.helper_down.len()).sum();
    let joins: usize = stream.iter().map(|ev| ev.helper_join.len()).sum();
    assert!(outages > 50, "helper churn not heavy enough to exercise degradation ({outages} outages)");
    assert!(joins > 0, "the join process never fired");
    let mut degraded = 0usize;
    for ev in &stream {
        let round = session.step(ev);
        assert!(round.helpers_live >= 1, "round {}: no live helper survived", ev.round);
        assert!(round.helpers_live <= helper_cap, "round {}: pool cap breached", ev.round);
        if round.degraded {
            degraded += 1;
        } else {
            assert_eq!(round.orphaned_clients, 0, "round {}: orphans without degradation", ev.round);
        }
        assert!(
            session.minted_len() <= cap,
            "round {}: minted cache grew to {} (> cap {cap})",
            ev.round,
            session.minted_len()
        );
    }
    assert!(degraded > 0, "outages never produced a degraded round");
    let ckpt = session.checkpoint();
    assert!(ckpt.prev_assign.len() <= cap, "warm state bounded: {} assignments", ckpt.prev_assign.len());
    assert!(
        ckpt.helpers_live.len() + ckpt.helpers_down.len() <= helper_cap,
        "helper roster bounded by the pool cap"
    );
    assert_eq!(ckpt.rounds.len(), 1500);

    let mut twin = FleetSession::new(cfg());
    for ev in &twin.event_stream() {
        twin.step(ev);
    }
    assert_eq!(
        twin.into_report().to_json().pretty(),
        session.into_report().to_json().pretty(),
        "helper-churn run must replay byte-identically"
    );
}
