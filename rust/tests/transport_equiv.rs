//! Transport-layer equivalence and invariant suite.
//!
//! The dedicated mode must be the identity: every solver decision byte-
//! identical to the pre-transport code path, across all scenario
//! families. The shared mode must produce checker-feasible (occupancy
//! sweep included), deterministic schedules whose effective makespans
//! respond monotonically to pool capacity, and a capacity covering the
//! whole roster must reproduce the dedicated instance exactly.

use psl::instance::profiles::Model;
use psl::instance::scenario::{Scenario, ScenarioCfg};
use psl::instance::Instance;
use psl::solver::schedule::{fcfs_schedule, Schedule};
use psl::solver::{admm, greedy, strategy};
use psl::transport::TransportCfg;

fn inst_for(scen: Scenario, j: usize, i: usize, seed: u64) -> Instance {
    ScenarioCfg::new(scen, Model::Vgg19, j, i, seed).generate().quantize(550.0)
}

fn sched_eq(a: &Schedule, b: &Schedule) -> bool {
    a.assignment == b.assignment && a.fwd == b.fwd && a.bwd == b.bwd
}

#[test]
fn dedicated_transport_is_the_identity_across_all_families() {
    let ded = TransportCfg::dedicated();
    for &scen in &Scenario::ALL {
        let inst = inst_for(scen, 8, 2, 11);
        // Signals: identical shape, zero contention.
        let sig = strategy::signals(&inst);
        let sig_t = strategy::signals_under(&inst, &ded);
        assert_eq!(sig_t.contention, 0.0, "{}", inst.label);
        assert_eq!(format!("{sig:?}"), format!("{sig_t:?}"), "{}", inst.label);
        // Strategy: same method, same schedule.
        let plain = strategy::solve(&inst, &admm::AdmmCfg::default());
        let under = strategy::solve_under(&inst, &ded, &admm::AdmmCfg::default());
        match (&plain, &under) {
            (None, None) => {}
            (Some((s1, m1)), Some((s2, m2))) => {
                assert_eq!(m1, m2, "{}", inst.label);
                assert!(sched_eq(s1, s2), "{}: schedule diverged under dedicated transport", inst.label);
            }
            _ => panic!("dedicated solve_under feasibility diverged on {}", inst.label),
        }
        // Greedy: byte-identical too.
        match (greedy::solve(&inst), greedy::solve_under(&inst, &ded)) {
            (None, None) => {}
            (Some(s1), Some(s2)) => {
                assert!(sched_eq(&s1, &s2), "{}: greedy diverged under dedicated transport", inst.label)
            }
            _ => panic!("dedicated greedy feasibility diverged on {}", inst.label),
        }
        // The instance projection itself is the identity.
        let loads = TransportCfg::loads_of(
            &plain.as_ref().map(|(s, _)| s.assignment.clone()).unwrap_or_else(|| {
                psl::solver::schedule::Assignment::new(vec![0; inst.n_clients])
            }),
            inst.n_helpers,
        );
        assert_eq!(format!("{:?}", ded.inflate(&inst, &loads)), format!("{inst:?}"), "{}", inst.label);
    }
}

#[test]
fn shared_transport_schedules_are_feasible_and_deterministic() {
    for &scen in &[Scenario::S1, Scenario::S4StragglerTail, Scenario::S8FlashCrowd] {
        let inst = inst_for(scen, 10, 2, 7);
        let t = TransportCfg::shared(2.0);
        let (a, ma) = strategy::solve_under(&inst, &t, &admm::AdmmCfg::default())
            .unwrap_or_else(|| panic!("{}: infeasible under shared uplink", inst.label));
        let (b, mb) = strategy::solve_under(&inst, &t, &admm::AdmmCfg::default()).unwrap();
        assert_eq!(ma, mb, "{}", inst.label);
        assert!(sched_eq(&a, &b), "{}: shared solve must be deterministic", inst.label);
        // Feasible under the occupancy-aware checker — and the dedicated
        // lower bound still holds (contention only inflates transfers).
        let v = a.violations_under(&inst, &t);
        assert!(v.is_empty(), "{}: {v:?}", inst.label);
        let eff = t.inflate_for_assignment(&inst, &a.assignment);
        assert!(a.makespan(&eff) >= inst.makespan_lower_bound(), "{}", inst.label);
        let g = greedy::solve_under(&inst, &t)
            .unwrap_or_else(|| panic!("{}: greedy infeasible under shared uplink", inst.label));
        let gv = g.violations_under(&inst, &t);
        assert!(gv.is_empty(), "{}: {gv:?}", inst.label);
    }
}

#[test]
fn effective_makespan_is_monotone_in_uplink_capacity() {
    // Fix the assignment (the paper's balanced placement) and watch the
    // transport projection alone: a bigger pool can never slow a helper
    // down, and FCFS on weakly shorter tasks can never finish later.
    let inst = inst_for(Scenario::S2, 12, 2, 3);
    let base = greedy::solve(&inst).expect("dedicated greedy feasible");
    let mut last: Option<u32> = None;
    for cap in [1.0, 2.0, 4.0, 1e9] {
        let t = TransportCfg::shared(cap);
        let eff = t.inflate_for_assignment(&inst, &base.assignment);
        let f = fcfs_schedule(&eff, base.assignment.clone());
        let m = f.makespan(&eff);
        if let Some(prev) = last {
            assert!(m <= prev, "capacity {cap}: makespan {m} worse than smaller pool's {prev}");
        }
        last = Some(m);
    }
    // A pool covering the whole roster reproduces the dedicated instance
    // byte for byte — shared converges to dedicated in the limit.
    let wide = TransportCfg::shared(1e9);
    assert_eq!(
        format!("{:?}", wide.inflate_for_assignment(&inst, &base.assignment)),
        format!("{inst:?}")
    );
}

#[test]
fn inflation_never_shrinks_a_delay_and_spares_processing() {
    let inst = inst_for(Scenario::S3Clustered, 9, 3, 21);
    let base = greedy::solve(&inst).expect("feasible");
    let t = TransportCfg::shared(1.0);
    let eff = t.inflate_for_assignment(&inst, &base.assignment);
    for e in 0..inst.n_clients * inst.n_helpers {
        assert!(eff.r[e] >= inst.r[e]);
        assert!(eff.l[e] >= inst.l[e]);
        assert!(eff.lp[e] >= inst.lp[e]);
        assert!(eff.rp[e] >= inst.rp[e]);
        // Contention is a link effect: compute stays untouched.
        assert_eq!(eff.p[e], inst.p[e]);
        assert_eq!(eff.pp[e], inst.pp[e]);
    }
}
