//! End-to-end CLI smoke tests: run the actual `psl` binary.

use std::process::Command;

fn psl(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_psl"))
        .args(args)
        .output()
        .expect("run psl binary");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.success(),
    )
}

/// Like [`psl`], but with stdin wired to a file (for `psl serve`).
fn psl_with_stdin(args: &[&str], stdin_path: &str) -> (String, String, bool) {
    let file = std::fs::File::open(stdin_path).expect("open stdin file");
    let out = Command::new(env!("CARGO_BIN_EXE_psl"))
        .args(args)
        .stdin(std::process::Stdio::from(file))
        .output()
        .expect("run psl binary");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.success(),
    )
}

#[test]
fn help_prints_usage() {
    let (stdout, _, ok) = psl(&["help"]);
    assert!(ok);
    assert!(stdout.contains("USAGE"));
    assert!(stdout.contains("sweep-slots"));
}

#[test]
fn unknown_command_fails() {
    let (_, stderr, ok) = psl(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown command"));
}

#[test]
fn profiles_prints_table1() {
    let (stdout, _, ok) = psl(&["profiles"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("RPi 4B"));
    assert!(stdout.contains("91.9"));
    assert!(stdout.contains("Fig 5"));
}

#[test]
fn gen_roundtrips_through_json() {
    let path = std::env::temp_dir().join(format!("psl-cli-gen-{}.json", std::process::id()));
    let (stdout, stderr, ok) = psl(&[
        "gen", "--scenario", "2", "--model", "vgg19", "-j", "6", "-i", "2", "--seed", "9", "--out",
        path.to_str().unwrap(),
    ]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    let text = std::fs::read_to_string(&path).unwrap();
    let inst = psl::instance::InstanceMs::from_json(&psl::util::json::Json::parse(&text).unwrap()).unwrap();
    assert_eq!(inst.n_clients, 6);
    assert_eq!(inst.n_helpers, 2);
    std::fs::remove_file(&path).ok();
}

#[test]
fn solve_reports_all_methods() {
    let (stdout, stderr, ok) = psl(&["solve", "--scenario", "2", "-j", "8", "-i", "2", "--seed", "3", "--replay"]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    for m in ["strategy", "admm", "greedy", "baseline"] {
        assert!(stdout.contains(m), "missing {m} in: {stdout}");
    }
    assert!(stdout.contains("T="));
}

#[test]
fn solve_single_method_and_gantt() {
    let path = std::env::temp_dir().join(format!("psl-cli-gantt-{}.json", std::process::id()));
    let (stdout, _, ok) = psl(&[
        "solve", "-j", "6", "-i", "2", "--method", "greedy", "--gantt", path.to_str().unwrap(),
    ]);
    assert!(ok, "{stdout}");
    let g = psl::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert!(g.get("segments").as_arr().unwrap().len() >= 12, "6 clients x 2 phases");
    std::fs::remove_file(&path).ok();
}

#[test]
fn sweep_grid_runs_and_saves_deterministic_json() {
    let args = |threads: &str, out: &str| {
        vec![
            "sweep", "--scenarios", "1,5,6", "--models", "vgg19", "--sizes", "4x2", "--seeds", "9",
            "--methods", "greedy,baseline", "--slot-ms", "550", "--threads", threads, "--out", out,
        ]
    };
    let (stdout, stderr, ok) = psl(&args("2", "cli-smoke-sweep-a"));
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("6 cells"), "3 scenarios x 2 methods: {stdout}");
    assert!(stdout.contains("s5-memory-starved"));
    let (stdout2, stderr2, ok2) = psl(&args("1", "cli-smoke-sweep-b"));
    assert!(ok2, "stdout={stdout2} stderr={stderr2}");
    let a = std::fs::read_to_string("target/psl-bench/cli-smoke-sweep-a.json").unwrap();
    let b = std::fs::read_to_string("target/psl-bench/cli-smoke-sweep-b.json").unwrap();
    assert_eq!(a, b, "sweep JSON must not depend on thread count");
    let doc = psl::util::json::Json::parse(&a).unwrap();
    assert_eq!(doc.get("rows").as_arr().unwrap().len(), 6);
    std::fs::remove_file("target/psl-bench/cli-smoke-sweep-a.json").ok();
    std::fs::remove_file("target/psl-bench/cli-smoke-sweep-b.json").ok();
}

#[test]
fn sweep_rejects_unknown_scenario() {
    let (_, stderr, ok) = psl(&["sweep", "--scenarios", "nope"]);
    assert!(!ok);
    assert!(stderr.contains("bad scenario"), "{stderr}");
}

#[test]
fn sweep_rejects_malformed_slot_ms_and_zero_sizes() {
    let (_, stderr, ok) = psl(&["sweep", "--scenarios", "1", "--slot-ms", "55O"]);
    assert!(!ok, "typo'd --slot-ms must not silently fall back to defaults");
    assert!(stderr.contains("bad --slot-ms"), "{stderr}");
    let (_, stderr2, ok2) = psl(&["sweep", "--scenarios", "1", "--sizes", "0x2"]);
    assert!(!ok2);
    assert!(stderr2.contains("J >= 1"), "{stderr2}");
}

#[test]
fn gen_accepts_new_families() {
    let (stdout, stderr, ok) = psl(&["gen", "--scenario", "s4-straggler-tail", "-j", "4", "-i", "2", "--seed", "2"]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("s4-straggler-tail"), "{stdout}");
}

#[test]
fn fleet_runs_and_is_byte_identical_across_runs() {
    let args = |out: &str| {
        vec![
            "fleet", "--scenario", "4", "--model", "vgg19", "-j", "6", "-i", "2", "--seed", "5",
            "--rounds", "6", "--out", out,
        ]
    };
    let (stdout, stderr, ok) = psl(&args("cli-smoke-fleet-a"));
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("full-initial"), "{stdout}");
    assert!(stdout.contains("summary:"), "{stdout}");
    let (stdout2, stderr2, ok2) = psl(&args("cli-smoke-fleet-b"));
    assert!(ok2, "stdout={stdout2} stderr={stderr2}");
    // Output paths embed the --out name; everything else must match.
    let strip = |s: &str| -> String {
        s.lines().filter(|l| !l.contains("-> target/psl-bench/")).collect::<Vec<_>>().join("\n")
    };
    assert_eq!(strip(&stdout), strip(&stdout2), "fleet stdout must be deterministic (no wall-clock)");
    let a = std::fs::read_to_string("target/psl-bench/cli-smoke-fleet-a.json").unwrap();
    let b = std::fs::read_to_string("target/psl-bench/cli-smoke-fleet-b.json").unwrap();
    assert_eq!(a, b, "fleet JSON must be byte-identical across runs");
    let doc = psl::util::json::Json::parse(&a).unwrap();
    assert_eq!(doc.get("kind").as_str(), Some("psl-fleet"));
    assert_eq!(doc.get("rounds_detail").as_arr().unwrap().len(), 6);
    // The default churn scenario exercises both paths of the tentpole:
    // at least one warm-start repair and at least one full re-solve.
    let decisions: Vec<String> = doc
        .get("rounds_detail")
        .as_arr()
        .unwrap()
        .iter()
        .map(|r| r.get("decision").as_str().unwrap().to_string())
        .collect();
    assert!(decisions.iter().any(|d| d == "repair"), "no repaired round in {decisions:?}");
    assert!(decisions.iter().any(|d| d.starts_with("full")), "no full round in {decisions:?}");
    // The JSONL stream sits next to the final JSON: one line per round,
    // each line equal to the corresponding rounds_detail entry.
    let jsonl = std::fs::read_to_string("target/psl-bench/cli-smoke-fleet-a.rounds.jsonl").unwrap();
    let lines: Vec<&str> = jsonl.lines().collect();
    assert_eq!(lines.len(), 6, "one JSONL line per round");
    for (line, detail) in lines.iter().zip(doc.get("rounds_detail").as_arr().unwrap()) {
        let parsed = psl::util::json::Json::parse(line).unwrap();
        assert_eq!(parsed.pretty(), detail.pretty(), "JSONL line == rounds_detail entry");
    }
    std::fs::remove_file("target/psl-bench/cli-smoke-fleet-a.json").ok();
    std::fs::remove_file("target/psl-bench/cli-smoke-fleet-b.json").ok();
    std::fs::remove_file("target/psl-bench/cli-smoke-fleet-a.rounds.jsonl").ok();
    std::fs::remove_file("target/psl-bench/cli-smoke-fleet-b.rounds.jsonl").ok();
}

#[test]
fn fleet_checkpoint_resume_is_byte_identical() {
    let scenario = |extra: &[&str], out: &str| {
        let mut v = vec![
            "fleet", "--scenario", "4", "--model", "vgg19", "-j", "6", "-i", "2", "--seed", "5",
        ];
        v.extend_from_slice(extra);
        v.extend_from_slice(&["--out", out]);
        v
    };
    // Straight 8-round run.
    let (stdout, stderr, ok) = psl(&scenario(&["--rounds", "8"], "cli-smoke-ckpt-straight"));
    assert!(ok, "stdout={stdout} stderr={stderr}");
    // Same run stopped at round 4 with a checkpoint.
    let (stdout, stderr, ok) =
        psl(&scenario(&["--rounds", "4", "--checkpoint-every", "4"], "cli-smoke-ckpt-part"));
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("checkpoint ->"), "{stdout}");
    let ckpt_path = "target/psl-bench/cli-smoke-ckpt-part.ckpt.json";
    let ckpt_text = std::fs::read_to_string(ckpt_path).expect("checkpoint written");
    assert!(ckpt_text.contains("\"kind\": \"psl-fleet-checkpoint\""), "schema-checked artifact");
    // Resume to the full horizon.
    let (stdout, stderr, ok) = psl(&[
        "fleet", "--resume", ckpt_path, "--rounds", "8", "--out", "cli-smoke-ckpt-resumed",
    ]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    // Final report and both sidecars must be byte-identical.
    for suffix in [".json", ".rounds.jsonl", ".events.jsonl"] {
        let a = std::fs::read_to_string(format!("target/psl-bench/cli-smoke-ckpt-straight{suffix}")).unwrap();
        let b = std::fs::read_to_string(format!("target/psl-bench/cli-smoke-ckpt-resumed{suffix}")).unwrap();
        assert_eq!(a, b, "resumed {suffix} differs from the straight run");
    }
    for name in ["cli-smoke-ckpt-straight", "cli-smoke-ckpt-part", "cli-smoke-ckpt-resumed"] {
        for suffix in [".json", ".rounds.jsonl", ".events.jsonl", ".ckpt.json"] {
            std::fs::remove_file(format!("target/psl-bench/{name}{suffix}")).ok();
        }
    }
}

#[test]
fn fleet_resume_rejects_recorded_flags() {
    let (stdout, stderr, ok) = psl(&[
        "fleet", "--scenario", "4", "-j", "4", "-i", "2", "--seed", "3", "--rounds", "2",
        "--checkpoint-every", "2", "--out", "cli-smoke-ckpt-conflict",
    ]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    let ckpt = "target/psl-bench/cli-smoke-ckpt-conflict.ckpt.json";
    let (_, stderr, ok) = psl(&["fleet", "--resume", ckpt, "--seed", "9"]);
    assert!(!ok, "overriding a recorded knob must fail");
    assert!(stderr.contains("recorded in the checkpoint"), "{stderr}");
    for suffix in [".json", ".rounds.jsonl", ".events.jsonl", ".ckpt.json"] {
        std::fs::remove_file(format!("target/psl-bench/cli-smoke-ckpt-conflict{suffix}")).ok();
    }
}

#[test]
fn serve_replays_a_recorded_event_log_byte_identically() {
    // A batch run records its event stream; piping that stream through
    // `psl serve` with the same scenario flags must reproduce the batch
    // run's round reports exactly on stdout.
    let (stdout, stderr, ok) = psl(&[
        "fleet", "--scenario", "4", "--model", "vgg19", "-j", "6", "-i", "2", "--seed", "5",
        "--rounds", "6", "--out", "cli-smoke-serve-src",
    ]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    let rounds = std::fs::read_to_string("target/psl-bench/cli-smoke-serve-src.rounds.jsonl").unwrap();
    let (stdout, stderr, ok) = psl_with_stdin(
        &["serve", "--scenario", "4", "--model", "vgg19", "-j", "6", "-i", "2", "--seed", "5"],
        "target/psl-bench/cli-smoke-serve-src.events.jsonl",
    );
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert_eq!(stdout, rounds, "serve stdout == the batch run's rounds sidecar");
    assert!(stderr.contains("6 rounds stepped"), "{stderr}");
    for suffix in [".json", ".rounds.jsonl", ".events.jsonl"] {
        std::fs::remove_file(format!("target/psl-bench/cli-smoke-serve-src{suffix}")).ok();
    }
}

#[test]
fn serve_strict_rejects_discontinuous_events() {
    let path = std::env::temp_dir().join(format!("psl-cli-serve-bad-{}.jsonl", std::process::id()));
    std::fs::write(&path, "{\"round\": 7, \"arrivals\": [], \"departures\": []}\n").unwrap();
    let (_, stderr, ok) =
        psl_with_stdin(&["serve", "-j", "4", "-i", "2", "--strict"], path.to_str().unwrap());
    assert!(!ok, "under --strict an out-of-order event must fail the serve loop");
    assert!(stderr.contains("does not continue the session"), "{stderr}");
    assert!(stderr.contains("event line 1"), "{stderr}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn serve_lenient_answers_bad_lines_and_keeps_serving() {
    // Without --strict the same bad line becomes a structured error
    // answer on stdout and the next (valid) round still steps.
    let path = std::env::temp_dir().join(format!("psl-cli-serve-lenient-{}.jsonl", std::process::id()));
    std::fs::write(
        &path,
        "{\"round\": 7, \"arrivals\": [], \"departures\": []}\n{\"arrivals\": [], \"departures\": []}\n",
    )
    .unwrap();
    let (stdout, stderr, ok) = psl_with_stdin(&["serve", "-j", "4", "-i", "2"], path.to_str().unwrap());
    assert!(ok, "lenient serve must survive a bad line: stderr={stderr}");
    let lines: Vec<&str> = stdout.lines().collect();
    assert_eq!(lines.len(), 2, "one error answer + one report: {stdout}");
    let err = psl::util::json::Json::parse(lines[0]).unwrap();
    assert!(err.get("error").as_str().unwrap().contains("does not continue the session"), "{stdout}");
    assert_eq!(err.get("line").as_f64(), Some(1.0));
    let report = psl::util::json::Json::parse(lines[1]).unwrap();
    assert_eq!(report.get("round").as_f64(), Some(0.0), "round 0 still stepped");
    assert!(stderr.contains("1 rounds stepped"), "{stderr}");
    assert!(stderr.contains("1 errored lines"), "{stderr}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn perf_smoke_writes_artifact() {
    let (stdout, stderr, ok) = psl(&["perf", "--smoke", "--out", "cli-smoke-perf"]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("check-dense"), "dense baseline rows present: {stdout}");
    assert!(stdout.contains("vs dense"), "speedup summary present: {stdout}");
    let text = std::fs::read_to_string("target/psl-bench/cli-smoke-perf.json").unwrap();
    let doc = psl::util::json::Json::parse(&text).unwrap();
    assert_eq!(doc.get("kind").as_str(), Some("psl-perf"));
    let rows = doc.get("rows").as_arr().unwrap();
    assert_eq!(rows.len(), 15, "3 scenarios x 1 size x 5 phases");
    for r in rows {
        let mean = r.get("mean_s").as_f64().unwrap();
        assert!(mean.is_finite() && mean >= 0.0, "finite timings in artifact");
    }
    std::fs::remove_file("target/psl-bench/cli-smoke-perf.json").ok();
}

#[test]
fn perf_rejects_bad_flags() {
    let (_, stderr, ok) = psl(&["perf", "--smoke", "--sizes", "0x2"]);
    assert!(!ok);
    assert!(stderr.contains("J >= 1"), "{stderr}");
    let (_, stderr2, ok2) = psl(&["perf", "--smoke", "--scenarios", "nope"]);
    assert!(!ok2);
    assert!(stderr2.contains("bad scenario"), "{stderr2}");
}

#[test]
fn fleet_grid_thread_count_invariant() {
    let args = |threads: &str, out: &str| {
        vec![
            "fleet", "--grid", "--scenarios", "1,4", "--model", "vgg19", "-j", "5", "-i", "2",
            "--churn-rates", "0.1,0.3", "--policies", "incremental,full", "--seeds", "3",
            "--rounds", "4", "--threads", threads, "--out", out,
        ]
    };
    let (stdout, stderr, ok) = psl(&args("2", "cli-smoke-fleet-grid-a"));
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("8 cells"), "2 scenarios x 2 churn x 2 policies: {stdout}");
    let (stdout2, stderr2, ok2) = psl(&args("1", "cli-smoke-fleet-grid-b"));
    assert!(ok2, "stdout={stdout2} stderr={stderr2}");
    let a = std::fs::read_to_string("target/psl-bench/cli-smoke-fleet-grid-a.json").unwrap();
    let b = std::fs::read_to_string("target/psl-bench/cli-smoke-fleet-grid-b.json").unwrap();
    assert_eq!(a, b, "fleet grid JSON must not depend on thread count");
    let doc = psl::util::json::Json::parse(&a).unwrap();
    assert_eq!(doc.get("rows").as_arr().unwrap().len(), 8);
    std::fs::remove_file("target/psl-bench/cli-smoke-fleet-grid-a.json").ok();
    std::fs::remove_file("target/psl-bench/cli-smoke-fleet-grid-b.json").ok();
}

#[test]
fn fleet_rejects_bad_policy_and_probability() {
    let (_, stderr, ok) = psl(&["fleet", "--policy", "yolo"]);
    assert!(!ok);
    assert!(stderr.contains("bad --policy"), "{stderr}");
    let (_, stderr2, ok2) = psl(&["fleet", "--depart-prob", "1.5"]);
    assert!(!ok2);
    assert!(stderr2.contains("depart-prob"), "{stderr2}");
}

#[test]
fn fleet_rejects_bad_helper_knobs() {
    let (_, stderr, ok) = psl(&["fleet", "--helper-down-rate", "1.5"]);
    assert!(!ok, "out-of-range outage probability must fail");
    assert!(stderr.contains("helper-down-rate"), "{stderr}");
    let (_, stderr, ok) = psl(&["fleet", "--helper-outage-rounds", "0"]);
    assert!(!ok);
    assert!(stderr.contains("helper-outage-rounds"), "{stderr}");
    // A join process needs headroom above the base pool.
    let (_, stderr, ok) = psl(&["fleet", "-i", "2", "--helper-join-rate", "0.5"]);
    assert!(!ok);
    assert!(stderr.contains("max-helpers"), "{stderr}");
    let (_, stderr, ok) = psl(&["fleet", "--capacity-threshold", "2.0"]);
    assert!(!ok);
    assert!(stderr.contains("capacity-threshold"), "{stderr}");
    // Serve validates the same knobs the same way.
    let (_, stderr, ok) = psl(&["serve", "--helper-down-rate", "nope"]);
    assert!(!ok);
    assert!(stderr.contains("helper-down-rate"), "{stderr}");
}

#[test]
fn fleet_grid_rejects_singular_helper_knobs_and_bad_axis_values() {
    // Singular helper knobs belong to single runs; the grid has its own
    // --helper-down-rates axis — exactly like the client-churn flags.
    let (_, stderr, ok) = psl(&["fleet", "--grid", "--helper-down-rate", "0.2"]);
    assert!(!ok);
    assert!(stderr.contains("single fleet runs"), "{stderr}");
    assert!(stderr.contains("helper-down-rates"), "hint names the axis: {stderr}");
    let (_, stderr, ok) = psl(&["fleet", "--grid", "--helper-down-rates", "0.1,1.5"]);
    assert!(!ok);
    assert!(stderr.contains("outside [0, 1]"), "{stderr}");
}

#[test]
fn fleet_s7_helper_bursts_degrades_and_stays_deterministic() {
    // The s7 family models bursty helper outages by default; crank the
    // rate so degradation is certain within the horizon, and check the
    // new per-round fields land in the sidecar.
    let args = |out: &str| {
        vec![
            "fleet", "--scenario", "7", "--model", "vgg19", "-j", "6", "-i", "3", "--seed", "5",
            "--rounds", "6", "--helper-down-rate", "0.9", "--helper-outage-rounds", "1",
            "--out", out,
        ]
    };
    let (stdout, stderr, ok) = psl(&args("cli-smoke-fleet-s7-a"));
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("s7-helper-bursts"), "{stdout}");
    assert!(stdout.contains("degraded"), "summary reports degradation: {stdout}");
    let (_, _, ok2) = psl(&args("cli-smoke-fleet-s7-b"));
    assert!(ok2);
    let a = std::fs::read_to_string("target/psl-bench/cli-smoke-fleet-s7-a.json").unwrap();
    let b = std::fs::read_to_string("target/psl-bench/cli-smoke-fleet-s7-b.json").unwrap();
    assert_eq!(a, b, "helper-churn fleet JSON must be byte-identical across runs");
    let jsonl = std::fs::read_to_string("target/psl-bench/cli-smoke-fleet-s7-a.rounds.jsonl").unwrap();
    assert!(jsonl.contains("\"helpers_live\""), "per-round helper fields in the sidecar");
    // At a 0.9 per-helper outage rate some round is degraded for any
    // seed that draws a single outage in 6 rounds x 3 helpers.
    assert!(jsonl.contains("\"degraded\": true"), "{jsonl}");
    for name in ["cli-smoke-fleet-s7-a", "cli-smoke-fleet-s7-b"] {
        for suffix in [".json", ".rounds.jsonl", ".events.jsonl"] {
            std::fs::remove_file(format!("target/psl-bench/{name}{suffix}")).ok();
        }
    }
}

#[test]
fn sweep_diff_self_passes_and_regression_fails() {
    // Build a tiny artifact, then diff it against itself (exit 0) and
    // against a doctored copy (non-zero exit, regression listed).
    let out = "cli-smoke-diff-base";
    let (stdout, stderr, ok) = psl(&[
        "sweep", "--scenarios", "1", "--models", "vgg19", "--sizes", "4x2", "--seeds", "9",
        "--methods", "greedy", "--slot-ms", "550", "--threads", "1", "--out", out,
    ]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    let base = format!("target/psl-bench/{out}.json");
    let (stdout, stderr, ok) = psl(&["sweep", "--diff", &base, &base]);
    assert!(ok, "self-diff must exit 0: stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("no regressions"), "{stdout}");

    // Doctor the artifact: inflate every makespan_ms 2x.
    let text = std::fs::read_to_string(&base).unwrap();
    let doc = psl::util::json::Json::parse(&text).unwrap();
    let old_ms = doc.get("rows").as_arr().unwrap()[0].get("makespan_ms").as_f64().unwrap();
    let doctored = text.replace(&format!("\"makespan_ms\": {old_ms}"), &format!("\"makespan_ms\": {}", old_ms * 2.0));
    assert_ne!(text, doctored, "doctoring must change the artifact");
    let worse = "target/psl-bench/cli-smoke-diff-worse.json";
    std::fs::write(worse, &doctored).unwrap();
    let (stdout, stderr, ok) = psl(&["sweep", "--diff", &base, worse]);
    assert!(!ok, "regression must exit non-zero: stdout={stdout}");
    assert!(stdout.contains("REGRESSION"), "{stdout}");
    assert!(stderr.contains("regressed"), "{stderr}");
    // The reverse direction (new is faster) passes.
    let (stdout, _, ok) = psl(&["sweep", "--diff", worse, &base]);
    assert!(ok, "{stdout}");
    std::fs::remove_file(&base).ok();
    std::fs::remove_file(worse).ok();
}

#[test]
fn fleet_s8_flash_crowd_runs_and_stays_deterministic() {
    let args = |out: &str| {
        vec![
            "fleet", "--scenario", "8", "--model", "vgg19", "-j", "6", "-i", "2", "--seed", "5",
            "--rounds", "10", "--out", out,
        ]
    };
    let (stdout, stderr, ok) = psl(&args("cli-smoke-fleet-s8-a"));
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("s8-flash-crowd"), "{stdout}");
    let (_, _, ok2) = psl(&args("cli-smoke-fleet-s8-b"));
    assert!(ok2);
    let a = std::fs::read_to_string("target/psl-bench/cli-smoke-fleet-s8-a.json").unwrap();
    let b = std::fs::read_to_string("target/psl-bench/cli-smoke-fleet-s8-b.json").unwrap();
    assert_eq!(a, b, "flash-crowd fleet JSON must be byte-identical across runs");
    for name in ["cli-smoke-fleet-s8-a", "cli-smoke-fleet-s8-b"] {
        for suffix in [".json", ".rounds.jsonl", ".events.jsonl"] {
            std::fs::remove_file(format!("target/psl-bench/{name}{suffix}")).ok();
        }
    }
}

#[test]
fn fleet_link_model_flags_gate_the_transport() {
    let base = |out: &str, extra: &[&str]| {
        let mut v = vec![
            "fleet", "--scenario", "4", "--model", "vgg19", "-j", "6", "-i", "2", "--seed", "5",
            "--rounds", "5", "--out", out,
        ];
        v.extend_from_slice(extra);
        v
    };
    // Explicit --link-model dedicated must not change a byte vs. no flag.
    let (_, _, ok) = psl(&base("cli-smoke-fleet-link-a", &[]));
    assert!(ok);
    let (_, _, ok) = psl(&base("cli-smoke-fleet-link-b", &["--link-model", "dedicated"]));
    assert!(ok);
    let a = std::fs::read_to_string("target/psl-bench/cli-smoke-fleet-link-a.json").unwrap();
    let b = std::fs::read_to_string("target/psl-bench/cli-smoke-fleet-link-b.json").unwrap();
    assert_eq!(a, b, "--link-model dedicated must be the identity");
    // Shared transport runs, tags the label, and changes the outcome.
    let (stdout, stderr, ok) =
        psl(&base("cli-smoke-fleet-link-c", &["--link-model", "shared", "--uplink-capacity", "2"]));
    assert!(ok, "stdout={stdout} stderr={stderr}");
    let c = std::fs::read_to_string("target/psl-bench/cli-smoke-fleet-link-c.json").unwrap();
    assert!(c.contains("link=shared cap=2"), "label tags the transport");
    assert_ne!(a, c, "a capacity-2 pool on 6 clients x 2 helpers must contend");
    // A capacity without the shared mode is a contradiction, not a no-op.
    let (_, stderr, ok) = psl(&base("cli-smoke-fleet-link-x", &["--uplink-capacity", "2"]));
    assert!(!ok);
    assert!(stderr.contains("--link-model shared"), "{stderr}");
    let (_, stderr, ok) = psl(&base("cli-smoke-fleet-link-x", &["--link-model", "mesh"]));
    assert!(!ok);
    assert!(stderr.contains("bad --link-model"), "{stderr}");
    for name in ["cli-smoke-fleet-link-a", "cli-smoke-fleet-link-b", "cli-smoke-fleet-link-c"] {
        for suffix in [".json", ".rounds.jsonl", ".events.jsonl"] {
            std::fs::remove_file(format!("target/psl-bench/{name}{suffix}")).ok();
        }
    }
}

#[test]
fn fleet_grid_uplink_axis_flows_into_the_policy_table() {
    let out = "cli-smoke-grid-uplink";
    let (stdout, stderr, ok) = psl(&[
        "fleet", "--grid", "--scenarios", "4", "--model", "vgg19", "-j", "5", "-i", "2",
        "--churn-rates", "0.1,0.3", "--uplink-capacities", "0,2", "--policies", "incremental,full",
        "--seeds", "7", "--rounds", "4", "--threads", "2", "--out", out,
    ]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("2 uplink capacities"), "{stdout}");
    let grid_path = format!("target/psl-bench/{out}.json");
    let text = std::fs::read_to_string(&grid_path).unwrap();
    assert!(text.contains("\"uplink_capacity\""), "grid rows record the transport axis");
    // analyze splits regimes by capacity and records the axis in the table.
    let (stdout, stderr, ok) = psl(&["analyze", &grid_path, "--out", "cli-smoke-uplink-table"]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("uplink-cap=2"), "regime/frontier lines name the shared regime: {stdout}");
    let table = std::fs::read_to_string("target/psl-bench/cli-smoke-uplink-table.json").unwrap();
    assert!(table.contains("\"uplink_capacity\""), "policy table carries the axis");
    std::fs::remove_file(&grid_path).ok();
    std::fs::remove_file("target/psl-bench/cli-smoke-uplink-table.json").ok();
}

#[test]
fn sweep_shared_transport_tags_rows_and_rejects_orphan_capacity() {
    let (stdout, stderr, ok) = psl(&[
        "sweep", "--scenarios", "1", "--models", "vgg19", "--sizes", "4x2", "--seeds", "9",
        "--methods", "greedy", "--slot-ms", "550", "--threads", "1", "--link-model", "shared",
        "--uplink-capacity", "2", "--out", "cli-smoke-sweep-shared",
    ]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("link=shared cap=2"), "{stdout}");
    let text = std::fs::read_to_string("target/psl-bench/cli-smoke-sweep-shared.json").unwrap();
    assert!(text.contains("\"uplink_capacity\""));
    let (_, stderr, ok) = psl(&["sweep", "--uplink-capacity", "2"]);
    assert!(!ok);
    assert!(stderr.contains("--link-model shared"), "{stderr}");
    std::fs::remove_file("target/psl-bench/cli-smoke-sweep-shared.json").ok();
}

#[test]
fn sweep_slots_runs() {
    let (stdout, stderr, ok) = psl(&[
        "sweep-slots", "-j", "6", "-i", "2", "--model", "vgg19", "--slots", "600,300",
    ]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("600"));
    assert!(stdout.contains("300"));
}
