//! End-to-end tests for the artifact analytics engine (`psl analyze`)
//! and the data-driven `auto` fleet policy: synthetic-grid frontier
//! determinism, the builtin `PolicyTable` golden snapshot, auto-policy
//! round decisions through the real orchestrator, and the `--perf-diff`
//! regression gate through the real binary.

use psl::bench::artifact::{self, ArtifactKind};
use psl::util::json::Json;
use std::process::Command;

fn psl_bin(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_psl"))
        .args(args)
        .output()
        .expect("run psl binary");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.success(),
    )
}

/// One synthetic fleet-grid row in the exact artifact shape
/// `bench::fleet::rows_to_json` writes.
fn grid_row(scenario: &str, churn: f64, policy: &str, seed: u64, makespan: f64, work: u64) -> Json {
    Json::obj(vec![
        ("scenario", Json::Str(scenario.to_string())),
        ("model", Json::Str("resnet101".to_string())),
        ("n_clients", Json::Num(10.0)),
        ("n_helpers", Json::Num(2.0)),
        ("churn_rate", Json::Num(churn)),
        ("helper_down_rate", Json::Num(0.0)),
        ("uplink_capacity", Json::Num(0.0)),
        ("policy", Json::Str(policy.to_string())),
        ("seed", Json::Str(seed.to_string())),
        ("rounds", Json::Num(8.0)),
        ("full_rounds", Json::Num(if policy == "full" { 8.0 } else { 1.0 })),
        ("repair_rounds", Json::Num(if policy == "full" { 0.0 } else { 7.0 })),
        ("empty_rounds", Json::Num(0.0)),
        ("mean_makespan_ms", Json::Num(makespan)),
        ("mean_period_ms", Json::Num(makespan * 0.8)),
        // Observed churn fraction: ≈ 2× the rate axis under the
        // stationary mapping, like the real grid runner records.
        ("mean_churn_frac", Json::Num(churn * 2.0)),
        ("total_work_units", Json::Str(work.to_string())),
    ])
}

/// A synthetic grid whose crossover is designed to land at churn 0.3:
/// incremental's work-discounted makespan degrades with churn while the
/// full arm stays flat.
fn synthetic_grid() -> Json {
    let mut rows = Vec::new();
    for seed in [1u64, 2] {
        for (churn, inc_makespan, inc_work) in [(0.05, 1000.0, 100), (0.15, 1100.0, 300), (0.3, 1400.0, 700)] {
            rows.push(grid_row("scenario1", churn, "incremental", seed, inc_makespan, inc_work));
            rows.push(grid_row("scenario1", churn, "full", seed, 950.0, 900));
        }
    }
    artifact::envelope(ArtifactKind::FleetGrid, vec![("rows", Json::Arr(rows))])
}

#[test]
fn synthetic_grid_frontier_is_deterministic_end_to_end() {
    let doc = synthetic_grid();
    let rows = psl::analyze::rows_from_doc(&doc).expect("synthetic grid parses");
    let table_of = || {
        psl::analyze::compute_policy_table(
            psl::analyze::frontiers(&psl::analyze::regime_tables(&rows)),
            "synthetic",
        )
    };
    let table_a = table_of();
    let table_b = table_of();
    assert_eq!(table_a, table_b);
    assert_eq!(table_a.to_json().pretty(), table_b.to_json().pretty());
    assert_eq!(table_a.entries.len(), 1);
    // Crossover at rate axis 0.3 → reported in observed units: 0.6.
    assert_eq!(table_a.entries[0].frontier_churn, Some(0.6), "designed crossover");
}

#[test]
fn builtin_policy_table_golden_snapshot() {
    // The exact bytes of the shipped default table: any change to the
    // builtin frontiers, the envelope, or the serialization shape must
    // show up here as a deliberate diff.
    let golden = r#"{
  "entries": [
    {
      "frontier_churn": 0.3,
      "n_clients": 10,
      "n_helpers": 2,
      "scenario": "s4-straggler-tail"
    },
    {
      "frontier_churn": 0.6,
      "n_clients": 10,
      "n_helpers": 2,
      "scenario": "scenario1"
    }
  ],
  "kind": "psl-policy-table",
  "schema_version": 7,
  "source": "builtin"
}"#;
    assert_eq!(psl::fleet::PolicyTable::builtin().to_json().pretty(), golden);
    // And it roundtrips through the registry loader.
    let parsed = psl::fleet::PolicyTable::from_json(&Json::parse(golden).unwrap()).unwrap();
    assert_eq!(parsed, psl::fleet::PolicyTable::builtin());
}

#[test]
fn analyze_cli_writes_policy_table_from_grid_artifact() {
    let grid_path = format!("target/psl-bench/analyze-test-grid-{}.json", std::process::id());
    std::fs::create_dir_all("target/psl-bench").unwrap();
    std::fs::write(&grid_path, synthetic_grid().pretty()).unwrap();
    let out_a = format!("analyze-test-table-a-{}", std::process::id());
    let (stdout, stderr, ok) = psl_bin(&["analyze", &grid_path, "--out", &out_a]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("policy frontier"), "{stdout}");
    assert!(stdout.contains("churn >= 0.60"), "designed crossover printed in observed units: {stdout}");
    // Deterministic: a second run produces byte-identical table output.
    let out_b = format!("analyze-test-table-b-{}", std::process::id());
    let (_, _, ok2) = psl_bin(&["analyze", &grid_path, "--out", &out_b]);
    assert!(ok2);
    let a = std::fs::read_to_string(format!("target/psl-bench/{out_a}.json")).unwrap();
    let b = std::fs::read_to_string(format!("target/psl-bench/{out_b}.json")).unwrap();
    assert_eq!(a, b, "analyze output must be byte-identical across runs");
    let table = psl::fleet::PolicyTable::from_json(&Json::parse(&a).unwrap()).unwrap();
    assert_eq!(table.entries[0].frontier_churn, Some(0.6));
    assert!(table.source.starts_with("analyze-test-grid-"), "provenance = artifact filename: {}", table.source);
    std::fs::remove_file(&grid_path).ok();
    std::fs::remove_file(format!("target/psl-bench/{out_a}.json")).ok();
    std::fs::remove_file(format!("target/psl-bench/{out_b}.json")).ok();
}

#[test]
fn analyze_cli_rejects_non_grid_artifacts() {
    let path = format!("target/psl-bench/analyze-test-notgrid-{}.json", std::process::id());
    std::fs::create_dir_all("target/psl-bench").unwrap();
    let sweep = artifact::envelope(ArtifactKind::Sweep, vec![("rows", Json::Arr(vec![]))]);
    std::fs::write(&path, sweep.pretty()).unwrap();
    let (_, stderr, ok) = psl_bin(&["analyze", &path]);
    assert!(!ok);
    assert!(stderr.contains("psl-fleet-grid"), "{stderr}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn fleet_auto_cli_consumes_a_policy_table_deterministically() {
    let pid = std::process::id();
    // A table whose scenario1 frontier is tiny: every churned round under
    // `auto` must re-solve fully (decision "full-auto").
    let table = psl::fleet::PolicyTable::new(
        "test".to_string(),
        vec![psl::fleet::PolicyEntry {
            scenario: "scenario1".to_string(),
            n_clients: 8,
            n_helpers: 2,
            frontier_churn: Some(0.0),
            helper_down_rate: 0.0,
            uplink_capacity: 0.0,
        }],
    );
    let table_name = format!("analyze-test-auto-table-{pid}");
    table.save(&table_name).unwrap();
    let table_path = format!("target/psl-bench/{table_name}.json");
    let run = |out: &str| {
        psl_bin(&[
            "fleet", "--scenario", "1", "--model", "vgg19", "-j", "8", "-i", "2", "--seed", "5",
            "--rounds", "6", "--policy", "auto", "--policy-table", &table_path, "--out", out,
        ])
    };
    let out_a = format!("analyze-test-auto-a-{pid}");
    let out_b = format!("analyze-test-auto-b-{pid}");
    let (stdout, stderr, ok) = run(&out_a);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    let (_, _, ok2) = run(&out_b);
    assert!(ok2);
    let a = std::fs::read_to_string(format!("target/psl-bench/{out_a}.json")).unwrap();
    let b = std::fs::read_to_string(format!("target/psl-bench/{out_b}.json")).unwrap();
    assert_eq!(a, b, "same seed + table -> byte-identical report");
    let doc = Json::parse(&a).unwrap();
    assert_eq!(doc.get("policy").as_str(), Some("auto"));
    let decisions: Vec<String> = doc
        .get("rounds_detail")
        .as_arr()
        .unwrap()
        .iter()
        .map(|r| r.get("decision").as_str().unwrap().to_string())
        .collect();
    assert_eq!(decisions[0], "full-initial");
    // frontier 0.0: every non-empty round past the first must go
    // full-auto (churn >= 0 always crosses it).
    for (k, d) in decisions.iter().enumerate().skip(1) {
        assert!(d == "full-auto" || d == "empty", "round {k}: {decisions:?}");
    }
    assert!(decisions.iter().any(|d| d == "full-auto"), "{decisions:?}");
    // The streamed sidecar summarizes per decision through the CLI.
    let jsonl = format!("target/psl-bench/{out_a}.rounds.jsonl");
    let (stdout, stderr, ok) = psl_bin(&["analyze", "--rounds", &jsonl]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("full-auto"), "{stdout}");
    assert!(stdout.contains("full-initial"), "{stdout}");
    for name in [&out_a, &out_b] {
        std::fs::remove_file(format!("target/psl-bench/{name}.json")).ok();
        std::fs::remove_file(format!("target/psl-bench/{name}.rounds.jsonl")).ok();
    }
    std::fs::remove_file(&table_path).ok();
}

#[test]
fn fleet_rejects_policy_table_without_auto() {
    let (_, stderr, ok) = psl_bin(&["fleet", "--policy", "incremental", "--policy-table", "nope.json"]);
    assert!(!ok);
    assert!(stderr.contains("--policy auto"), "{stderr}");
}

/// Multiply-and-offset a phase's `min_s` so it regresses regardless of
/// how small the measured timing was.
fn doctor_min_s(doc: &mut Json, phase: &str) {
    let Json::Obj(o) = doc else { panic!("artifact is an object") };
    let Some(Json::Arr(rows)) = o.get_mut("rows") else { panic!("rows[]") };
    let mut hit = false;
    for r in rows {
        let Json::Obj(ro) = r else { continue };
        if ro.get("phase").and_then(|p| p.as_str()) == Some(phase) {
            if let Some(Json::Num(v)) = ro.get_mut("min_s") {
                *v = *v * 10.0 + 10.0;
                hit = true;
            }
        }
    }
    assert!(hit, "no {phase} row to doctor");
}

#[test]
fn perf_diff_cli_regression_and_non_regression_pair() {
    let pid = std::process::id();
    let base_name = format!("analyze-test-perf-{pid}");
    let (stdout, stderr, ok) = psl_bin(&["perf", "--smoke", "--out", &base_name]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    let base = format!("target/psl-bench/{base_name}.json");

    // Non-regression: self-diff exits zero.
    let (stdout, stderr, ok) = psl_bin(&["analyze", "--perf-diff", &base, &base]);
    assert!(ok, "self-diff must exit 0: stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("no regressions"), "{stdout}");

    // Regression: a gated phase (solve) slowed -> non-zero exit.
    let mut doc = Json::parse(&std::fs::read_to_string(&base).unwrap()).unwrap();
    doctor_min_s(&mut doc, "solve");
    let worse = format!("target/psl-bench/analyze-test-perf-worse-{pid}.json");
    std::fs::write(&worse, doc.pretty()).unwrap();
    let (stdout, stderr, ok) = psl_bin(&["analyze", "--perf-diff", &base, &worse]);
    assert!(!ok, "slowdown must exit non-zero: stdout={stdout}");
    assert!(stdout.contains("REGRESSION"), "{stdout}");
    assert!(stderr.contains("regressed"), "{stderr}");
    // The reverse direction (new is faster) passes.
    let (stdout, _, ok) = psl_bin(&["analyze", "--perf-diff", &worse, &base]);
    assert!(ok, "{stdout}");

    // A dense-baseline slowdown is NOT gated: exit zero.
    let mut doc = Json::parse(&std::fs::read_to_string(&base).unwrap()).unwrap();
    doctor_min_s(&mut doc, "check-dense");
    let dense = format!("target/psl-bench/analyze-test-perf-dense-{pid}.json");
    std::fs::write(&dense, doc.pretty()).unwrap();
    let (stdout, stderr, ok) = psl_bin(&["analyze", "--perf-diff", &base, &dense]);
    assert!(ok, "dense baselines are reference-only: stdout={stdout} stderr={stderr}");

    // Disjoint grids (zero gated overlap) must fail, not pass green.
    let mut doc = Json::parse(&std::fs::read_to_string(&base).unwrap()).unwrap();
    let Json::Obj(o) = &mut doc else { panic!("artifact is an object") };
    o.insert("rows".to_string(), Json::Arr(vec![]));
    let empty = format!("target/psl-bench/analyze-test-perf-empty-{pid}.json");
    std::fs::write(&empty, doc.pretty()).unwrap();
    let (_, stderr, ok) = psl_bin(&["analyze", "--perf-diff", &base, &empty]);
    assert!(!ok, "a gate that compared nothing must not exit 0");
    assert!(stderr.contains("no gated perf cell"), "{stderr}");

    std::fs::remove_file(&base).ok();
    std::fs::remove_file(&worse).ok();
    std::fs::remove_file(&dense).ok();
    std::fs::remove_file(&empty).ok();
}
