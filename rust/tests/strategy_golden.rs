//! Golden snapshot of the §VII solution-strategy pick rule over a fixed
//! scenario × (J, I) grid. Every cell below sits well inside one side of
//! the rule's thresholds, so a change in the chosen `Method` means the
//! pick rule itself regressed (thresholds moved, a signal changed
//! definition, or a scenario family drifted) — not sampling noise.

use psl::instance::profiles::Model;
use psl::instance::scenario::{Scenario, ScenarioCfg};
use psl::solver::strategy::{self, Method};

/// (scenario, J, I, expected method) — the golden grid.
///
/// Rationale per cell:
/// * J ≤ 50 always routes to ADMM (size branch can't fire), independent
///   of heterogeneity or memory signals.
/// * J ≥ 100 with loose memory routes to balanced-greedy; S1 and
///   s6-mega-homogeneous keep full-RAM helpers, so flexibility is 1.0.
const GOLDEN: &[(Scenario, usize, usize, Method)] = &[
    (Scenario::S1, 10, 2, Method::Admm),
    (Scenario::S1, 20, 5, Method::Admm),
    (Scenario::S1, 120, 10, Method::BalancedGreedy),
    (Scenario::S2, 20, 5, Method::Admm),
    (Scenario::S2, 40, 8, Method::Admm),
    (Scenario::S3Clustered, 24, 6, Method::Admm),
    (Scenario::S4StragglerTail, 16, 4, Method::Admm),
    (Scenario::S5MemoryStarved, 12, 4, Method::Admm),
    (Scenario::S6MegaHomogeneous, 120, 8, Method::BalancedGreedy),
    (Scenario::S6MegaHomogeneous, 200, 10, Method::BalancedGreedy),
];

const GOLDEN_SEED: u64 = 7_042;
const GOLDEN_SLOT_MS: f64 = 180.0;

fn snapshot() -> String {
    GOLDEN
        .iter()
        .map(|&(scen, j, i, _)| {
            let inst = ScenarioCfg::new(scen, Model::ResNet101, j, i, GOLDEN_SEED)
                .generate()
                .quantize(GOLDEN_SLOT_MS);
            format!("{} J={j} I={i} -> {}", scen.name(), strategy::pick(&inst).name())
        })
        .collect::<Vec<_>>()
        .join("\n")
}

#[test]
fn pick_rule_matches_golden_grid() {
    let expected = GOLDEN
        .iter()
        .map(|&(scen, j, i, m)| format!("{} J={j} I={i} -> {}", scen.name(), m.name()))
        .collect::<Vec<_>>()
        .join("\n");
    assert_eq!(snapshot(), expected, "strategy pick rule diverged from the golden grid");
}

#[test]
fn golden_picks_stable_across_seeds() {
    // The margins are wide enough that the pick must not depend on the
    // instance seed.
    for seed in [1u64, 99, 12_345] {
        for &(scen, j, i, expected) in GOLDEN {
            let inst = ScenarioCfg::new(scen, Model::ResNet101, j, i, seed)
                .generate()
                .quantize(GOLDEN_SLOT_MS);
            assert_eq!(
                strategy::pick(&inst),
                expected,
                "{} J={j} I={i} seed={seed}",
                scen.name()
            );
        }
    }
}
