//! Shard-vs-monolithic property suite: the stitched output of the
//! sharded hierarchical solver (`psl::shard`) must be a *first-class*
//! schedule of the original instance — feasible under the interval-sweep
//! checker, bounded below by the monolithic lower bound — and must be
//! byte-identical regardless of worker-thread count or the order the
//! per-shard solutions arrive in.

use psl::instance::profiles::Model;
use psl::instance::scenario::{Scenario, ScenarioCfg};
use psl::shard::{self, ShardCfg};
use psl::solver::admm::AdmmCfg;

const SLOT_MS: f64 = 180.0;

/// (n_clients, n_helpers, shard_clients) per family. The memory-starved
/// family packs tightest against helper capacity, so its cells get more
/// helpers each (coarser split) to keep the global packing headroom.
fn family_shape(scen: Scenario) -> (usize, usize, usize) {
    match scen {
        Scenario::S5MemoryStarved => (120, 8, 60),
        _ => (120, 6, 30),
    }
}

fn shard_cfg(shard_clients: usize) -> ShardCfg {
    ShardCfg { shard_clients, ..ShardCfg::default() }
}

#[test]
fn stitched_schedule_is_feasible_on_every_scenario_family() {
    for &scen in Scenario::ALL.iter() {
        let (j, i, per_shard) = family_shape(scen);
        let ms = ScenarioCfg::new(scen, Model::ResNet101, j, i, 11).generate();
        let out = shard::solve_ms(&ms, SLOT_MS, &shard_cfg(per_shard), &AdmmCfg::default(), 3)
            .unwrap_or_else(|| panic!("{}: shard solve failed", scen.name()));
        assert!(out.shards.len() >= 2, "{}: expected a real multi-cell split", scen.name());
        // Feasibility is judged on the FULL instance through the same
        // interval-sweep checker every monolithic schedule passes.
        let inst = ms.quantize(SLOT_MS);
        let v = out.stitch.schedule.violations(&inst);
        assert!(v.is_empty(), "{}: stitched violations: {v:?}", scen.name());
        assert_eq!(
            out.stitch.makespan,
            out.stitch.schedule.makespan(&inst),
            "{}: reported stitched makespan must match the schedule's",
            scen.name()
        );
    }
}

#[test]
fn stitched_makespan_dominates_the_monolithic_lower_bound() {
    for &scen in Scenario::ALL.iter() {
        let (j, i, per_shard) = family_shape(scen);
        let ms = ScenarioCfg::new(scen, Model::ResNet101, j, i, 11).generate();
        let out = shard::solve_ms(&ms, SLOT_MS, &shard_cfg(per_shard), &AdmmCfg::default(), 3)
            .unwrap_or_else(|| panic!("{}: shard solve failed", scen.name()));
        let inst = ms.quantize(SLOT_MS);
        assert_eq!(
            out.monolithic_lb,
            inst.makespan_lower_bound(),
            "{}: edge-wise monolithic bound must equal the quantized instance's",
            scen.name()
        );
        assert!(
            out.stitch.makespan >= out.monolithic_lb,
            "{}: stitched {} beats the monolithic lower bound {}",
            scen.name(),
            out.stitch.makespan,
            out.monolithic_lb
        );
        // The stitch gap is reported against the max per-shard bound.
        assert!(out.stitch.stitch_gap >= 1.0, "{}: gap {}", scen.name(), out.stitch.stitch_gap);
    }
}

#[test]
fn outcome_is_identical_across_thread_counts() {
    let ms = ScenarioCfg::new(Scenario::S2, Model::ResNet101, 150, 5, 13).generate();
    let cfg = shard_cfg(30);
    let admm = AdmmCfg::default();
    let a = shard::solve_ms(&ms, SLOT_MS, &cfg, &admm, 1).unwrap();
    let b = shard::solve_ms(&ms, SLOT_MS, &cfg, &admm, 6).unwrap();
    assert_eq!(a.stitch.makespan, b.stitch.makespan);
    assert_eq!(a.stitch.migrations, b.stitch.migrations);
    assert_eq!(a.stitch.schedule.assignment, b.stitch.schedule.assignment);
    for j in 0..ms.n_clients {
        assert_eq!(a.stitch.schedule.fwd[j].runs(), b.stitch.schedule.fwd[j].runs(), "client {j} fwd");
        assert_eq!(a.stitch.schedule.bwd[j].runs(), b.stitch.schedule.bwd[j].runs(), "client {j} bwd");
    }
    assert_eq!(a.shards.len(), b.shards.len());
    for (sa, sb) in a.shards.iter().zip(&b.shards) {
        assert_eq!(sa.cell, sb.cell);
        assert_eq!(sa.makespan, sb.makespan);
        assert_eq!(sa.method, sb.method);
    }
}

#[test]
fn outcome_is_identical_across_shard_orderings() {
    // Same pipeline, but the per-shard solutions are handed to the
    // stitching pass in reversed order — every coordinator tie-break must
    // key on order-invariant identities (helper/client ids), so the
    // stitched output may not move.
    let ms = ScenarioCfg::new(Scenario::S3Clustered, Model::ResNet101, 160, 8, 5).generate();
    let cfg = shard_cfg(40);
    let admm = AdmmCfg::default();
    let plan = shard::partition_cells(&ms, &cfg);
    assert!(plan.n_cells() >= 3, "want a non-trivial permutation space");
    let shards = shard::solve_shards(&ms, SLOT_MS, &admm, &plan, 2).unwrap();
    let mut reversed = shards.clone();
    reversed.reverse();
    let (a, _) = shard::stitch_and_rebalance(&ms, SLOT_MS, &admm, &cfg, shards);
    let (b, _) = shard::stitch_and_rebalance(&ms, SLOT_MS, &admm, &cfg, reversed);
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.max_shard_lb, b.max_shard_lb);
    assert_eq!(a.migrations, b.migrations);
    assert_eq!(a.schedule.assignment, b.schedule.assignment);
    for j in 0..ms.n_clients {
        assert_eq!(a.schedule.fwd[j].runs(), b.schedule.fwd[j].runs(), "client {j} fwd");
        assert_eq!(a.schedule.bwd[j].runs(), b.schedule.bwd[j].runs(), "client {j} bwd");
    }
}

#[test]
fn quantized_entry_point_round_trips_through_the_original_instance() {
    // The Method::Sharded arm enters from an already-slotted Instance;
    // the lift back to milliseconds must be quantization-stable so the
    // stitched schedule lands in the original slot domain exactly.
    let ms = ScenarioCfg::new(Scenario::S4StragglerTail, Model::ResNet101, 140, 7, 23).generate();
    let inst = ms.quantize(SLOT_MS);
    let out = shard::solve_quantized(&inst, &shard_cfg(35), 2).unwrap();
    assert!(out.stitch.schedule.is_feasible(&inst));
    assert_eq!(out.stitch.makespan, out.stitch.schedule.makespan(&inst));
    assert!(out.stitch.makespan >= inst.makespan_lower_bound());
    assert_eq!(out.monolithic_lb, inst.makespan_lower_bound());
}
