//! Observability determinism contract (`crate::obs`), pinned end to end:
//!
//! * the deterministic counter map is **byte-identical across worker
//!   thread counts** (counters are commutative per-phase totals);
//! * an active recording **never changes a decision** — fleet round
//!   reports serialize to the same bytes with tracing on or off;
//! * the `psl-trace` artifact round-trips through the schema-checked
//!   registry loader and rejects documents from a newer schema;
//! * the exact solver actually journals its search (nodes, cutoffs,
//!   depth — the branch-and-bound statistics the perf gate diffs).

use psl::bench::artifact::{self, ArtifactKind, SCHEMA_VERSION};
use psl::instance::profiles::Model;
use psl::instance::scenario::{Scenario, ScenarioCfg};
use psl::obs::{trace_to_json, Recording};
use psl::shard::{solve_quantized, ShardCfg};
use psl::solver::exact::{self, ExactCfg};

#[test]
fn shard_counters_are_thread_count_invariant() {
    let inst = ScenarioCfg::new(Scenario::S6MegaHomogeneous, Model::ResNet101, 96, 4, 11)
        .generate()
        .quantize(200.0);
    let mut cfg = ShardCfg::default();
    cfg.shard_clients = 24;
    let capture = |threads: usize| {
        let rec = Recording::start();
        let outcome = solve_quantized(&inst, &cfg, threads).expect("shard solve");
        (rec.finish(), outcome.shards.len(), outcome.stitch.migrations)
    };
    let (seq, seq_shards, seq_migrations) = capture(1);
    let (par, par_shards, par_migrations) = capture(7);
    assert_eq!(seq.counters, par.counters, "counter map must not depend on thread count");
    assert_eq!((seq_shards, seq_migrations), (par_shards, par_migrations));
    assert!(seq.counter("shard.cells") >= 2, "96 clients / 24 per cell: {:?}", seq.counters);
    assert_eq!(seq.counter("shard.cells"), seq_shards as u64);
    // The parallel run went through the pool; the sequential one did not.
    assert_eq!(seq.counter("pool.invocations"), par.counter("pool.invocations"));
    assert!(par.spans.iter().any(|s| s.name == "shard/cell-solve"), "per-cell spans recorded");
}

#[test]
fn fleet_reports_are_byte_identical_with_and_without_recording() {
    use psl::fleet::{ChurnCfg, FleetCfg, FleetSession, Policy};
    let run = || {
        let scen = ScenarioCfg::new(Scenario::parse("4").unwrap(), Model::ResNet101, 8, 2, 7);
        let mut churn = ChurnCfg::stationary(8);
        churn.rounds = 5;
        let mut session = FleetSession::new(FleetCfg::new(scen, churn, Policy::parse("incremental").unwrap()));
        let stream = session.event_stream();
        stream.iter().map(|ev| session.step(ev).jsonl_line()).collect::<Vec<String>>()
    };
    let untraced = run();
    let rec = Recording::start();
    let traced = run();
    let data = rec.finish();
    assert_eq!(untraced, traced, "recording must not perturb any decision");
    assert_eq!(data.counter("fleet.rounds"), 5);
    assert!(data.spans.iter().any(|s| s.name == "fleet/decide"), "{:?}", data.spans.len());
}

#[test]
fn trace_artifact_roundtrips_and_rejects_newer_schema() {
    let rec = Recording::start();
    {
        let mut sp = psl::obs::span("test", "equiv/roundtrip");
        sp.arg("n", 1);
    }
    psl::obs::counter_add("equiv.count", 2);
    let data = rec.finish();
    let dir = std::env::temp_dir().join(format!("psl-obs-equiv-{}", std::process::id()));
    let path = dir.join("t.json");
    let written = psl::obs::write_trace(path.to_str().unwrap(), &data).unwrap();
    let doc = artifact::load_expecting(written.to_str().unwrap(), ArtifactKind::Trace).unwrap();
    assert_eq!(doc, trace_to_json(&data));
    assert_eq!(doc.get("counters").get("equiv.count").as_usize(), Some(2));
    // A trace is not a perf artifact.
    assert!(artifact::load_expecting(written.to_str().unwrap(), ArtifactKind::Perf).is_err());
    // Same document claiming a future schema must be refused.
    let future = doc
        .pretty()
        .replace(&format!("\"schema_version\": {SCHEMA_VERSION}"), "\"schema_version\": 999");
    let err = artifact::validate(&psl::util::json::Json::parse(&future).unwrap())
        .unwrap_err()
        .to_string();
    assert!(err.contains("newer"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn exact_solver_records_search_counters() {
    let inst = ScenarioCfg::new(Scenario::parse("2").unwrap(), Model::ResNet101, 6, 2, 42)
        .generate()
        .quantize(200.0);
    let rec = Recording::start();
    let result = exact::solve(&inst, &ExactCfg::default());
    let data = rec.finish();
    assert!(result.makespan >= result.lower_bound);
    assert!(data.counter("exact.nodes") > 0, "{:?}", data.counters);
    assert!(data.counter("exact.max_depth") >= 1, "{:?}", data.counters);
    // The journal mirrors the search the result reports: the outer span
    // carries the outer node count, and the counter total includes it.
    let outer = data
        .spans
        .iter()
        .find(|s| s.name == "exact/outer-dfs")
        .expect("outer search span");
    let outer_nodes = outer.args.iter().find(|(k, _)| *k == "nodes").map(|(_, v)| *v).unwrap();
    assert_eq!(outer_nodes, result.nodes as u64);
    assert!(data.counter("exact.nodes") >= outer_nodes);
}
