//! Representation-equivalence property tests for the run-length schedule
//! refactor: for random schedules across all six scenario families (and
//! deliberately corrupted variants), the [`SlotRuns`] representation must
//! reproduce the pre-refactor dense slot-list semantics exactly —
//! checker verdicts, fwd/bwd finishes and completions, segment streams,
//! and replay makespans. The dense reference implementations live only in
//! this file (and, as timed baselines, in `bench::perf`).

use psl::instance::profiles::Model;
use psl::instance::scenario::{Scenario, ScenarioCfg};
use psl::instance::{Instance, InstanceMs};
use psl::sim;
use psl::solver::schedule::{Schedule, SlotRuns};
use psl::solver::{admm, baseline, greedy};
use psl::util::prop;
use psl::util::rng::Rng;

// ---------------------------------------------------------------------------
// Dense reference encoder + pre-refactor semantics
// ---------------------------------------------------------------------------

/// Dense decode of a schedule (the pre-refactor representation).
fn to_dense(s: &Schedule) -> (Vec<Vec<u32>>, Vec<Vec<u32>>) {
    (
        s.fwd.iter().map(|r| r.to_slots()).collect(),
        s.bwd.iter().map(|r| r.to_slots()).collect(),
    )
}

/// Dense encode back into a schedule (exercises `from_slots`).
fn from_dense(helper_of: Vec<usize>, fwd: &[Vec<u32>], bwd: &[Vec<u32>]) -> Schedule {
    Schedule {
        assignment: psl::solver::schedule::Assignment::new(helper_of),
        fwd: fwd.iter().map(|s| SlotRuns::from_slots(s)).collect(),
        bwd: bwd.iter().map(|s| SlotRuns::from_slots(s)).collect(),
    }
}

/// The pre-refactor checker, verbatim semantics: per-slot loops plus the
/// per-(helper, slot) hash map for (3). Returns the violated-constraint
/// messages.
fn violations_dense(inst: &Instance, helper_of: &[usize], fwd: &[Vec<u32>], bwd: &[Vec<u32>]) -> Vec<String> {
    let mut errs = Vec::new();
    let jn = inst.n_clients;
    if helper_of.len() != jn || fwd.len() != jn || bwd.len() != jn {
        errs.push("shape mismatch".into());
        return errs;
    }
    {
        let mut used = vec![0.0f64; inst.n_helpers];
        for (j, &i) in helper_of.iter().enumerate() {
            used[i] += inst.d[j];
        }
        if !used.iter().zip(&inst.mem).all(|(u, m)| *u <= *m + 1e-9) {
            errs.push("(5) helper memory exceeded".into());
        }
    }
    for j in 0..jn {
        let i = helper_of[j];
        if i >= inst.n_helpers {
            errs.push(format!("client {j}: invalid helper {i}"));
            continue;
        }
        let e = inst.edge(i, j);
        for w in fwd[j].windows(2) {
            if w[1] <= w[0] {
                errs.push(format!("client {j}: fwd slots not strictly sorted"));
                break;
            }
        }
        for w in bwd[j].windows(2) {
            if w[1] <= w[0] {
                errs.push(format!("client {j}: bwd slots not strictly sorted"));
                break;
            }
        }
        if fwd[j].len() != inst.p[e] as usize {
            errs.push(format!("(6) client {j}"));
        }
        if bwd[j].len() != inst.pp[e] as usize {
            errs.push(format!("(7) client {j}"));
        }
        if let Some(&first) = fwd[j].first() {
            if first < inst.r[e] {
                errs.push(format!("(1) client {j}"));
            }
        }
        if let Some(&bfirst) = bwd[j].first() {
            let ready = fwd[j].last().map(|&t| t + 1).unwrap_or(0) + inst.l[e] + inst.lp[e];
            if bfirst < ready {
                errs.push(format!("(2) client {j}"));
            }
        }
    }
    let mut busy: std::collections::HashMap<(usize, u32), usize> = std::collections::HashMap::new();
    for j in 0..jn {
        let i = helper_of[j];
        for &t in fwd[j].iter().chain(bwd[j].iter()) {
            if let Some(other) = busy.insert((i, t), j) {
                if other != j || fwd[j].contains(&t) && bwd[j].contains(&t) {
                    errs.push(format!("(3) helper {i} slot {t}"));
                }
            }
        }
    }
    errs
}

/// Constraint tag of a violation message: the "(N)" prefix, or the first
/// word for untagged messages. Overlap *verdicts* must agree; the exact
/// per-slot message multiplicity may legally differ between the sweep
/// checker and the hash-map checker.
fn tags(errs: &[String]) -> std::collections::BTreeSet<String> {
    errs.iter()
        .map(|m| {
            if m.starts_with('(') {
                m[..3].to_string()
            } else if let Some(rest) = m.strip_prefix("client ") {
                // "client j: ..." well-formedness messages: keep the kind.
                let kind = if rest.contains("invalid helper") {
                    "invalid-helper"
                } else if rest.contains("fwd") {
                    "fwd-sorted"
                } else {
                    "bwd-sorted"
                };
                kind.to_string()
            } else {
                m.clone()
            }
        })
        .collect()
}

/// The pre-refactor segment derivation (slot-by-slot splitting), for
/// stream equivalence.
#[derive(Debug, PartialEq)]
struct DenseSeg {
    client: usize,
    is_bwd: bool,
    start: u32,
    len: u32,
    frac: f64,
}

fn dense_streams(n_helpers: usize, helper_of: &[usize], fwd: &[Vec<u32>], bwd: &[Vec<u32>]) -> Vec<Vec<DenseSeg>> {
    let mut out: Vec<Vec<DenseSeg>> = vec![Vec::new(); n_helpers];
    for j in 0..helper_of.len() {
        let i = helper_of[j];
        for (slots, is_bwd) in [(&fwd[j], false), (&bwd[j], true)] {
            if slots.is_empty() {
                continue;
            }
            let n = slots.len() as f64;
            let mut run = 0usize;
            for k in 1..=slots.len() {
                if k == slots.len() || slots[k] != slots[k - 1] + 1 {
                    out[i].push(DenseSeg {
                        client: j,
                        is_bwd,
                        start: slots[run],
                        len: (k - run) as u32,
                        frac: (k - run) as f64 / n,
                    });
                    run = k;
                }
            }
        }
    }
    for s in out.iter_mut() {
        s.sort_by_key(|seg| (seg.start, seg.client, seg.is_bwd));
    }
    out
}

/// The pre-refactor continuous replay (dense lists, per-helper execution),
/// returning the realized makespan.
fn replay_dense_makespan(ms: &InstanceMs, helper_of: &[usize], fwd: &[Vec<u32>], bwd: &[Vec<u32>]) -> f64 {
    let streams = dense_streams(ms.n_helpers, helper_of, fwd, bwd);
    let jn = ms.n_clients;
    let mut makespan = 0.0f64;
    for i in 0..ms.n_helpers {
        let clients: Vec<usize> = (0..jn).filter(|&j| helper_of[j] == i).collect();
        if clients.is_empty() {
            continue;
        }
        let idx_of = |j: usize| clients.iter().position(|&c| c == j).unwrap();
        let mut clock = 0.0f64;
        let mut fwd_done = vec![0.0f64; clients.len()];
        let mut fwd_rem: Vec<f64> = clients.iter().map(|&j| ms.p_ms[ms.edge(i, j)]).collect();
        let mut bwd_rem: Vec<f64> = clients.iter().map(|&j| ms.pp_ms[ms.edge(i, j)]).collect();
        for seg in &streams[i] {
            let k = idx_of(seg.client);
            let e = ms.edge(i, seg.client);
            let ready = if seg.is_bwd {
                fwd_done[k] + ms.l_ms[e] + ms.lp_ms[e]
            } else {
                ms.r_ms[e]
            };
            let start = clock.max(ready);
            let dur = if seg.is_bwd { ms.pp_ms[e] * seg.frac } else { ms.p_ms[e] * seg.frac };
            clock = start + dur;
            if seg.is_bwd {
                bwd_rem[k] -= dur;
                if bwd_rem[k] <= 1e-9 {
                    makespan = makespan.max(clock + ms.rp_ms[e]);
                }
            } else {
                fwd_rem[k] -= dur;
                if fwd_rem[k] <= 1e-9 {
                    fwd_done[k] = clock;
                }
            }
        }
    }
    makespan
}

// ---------------------------------------------------------------------------
// Schedule generators
// ---------------------------------------------------------------------------

fn any_scenario(rng: &mut Rng) -> Scenario {
    Scenario::ALL[rng.below(Scenario::ALL.len())]
}

fn random_case(rng: &mut Rng) -> (InstanceMs, Instance, Schedule) {
    let scen = any_scenario(rng);
    let model = if rng.chance(0.5) { Model::ResNet101 } else { Model::Vgg19 };
    let j = rng.range_usize(2, 14);
    let i = rng.range_usize(1, 4);
    let ms = ScenarioCfg::new(scen, model, j, i, rng.next_u64()).generate();
    let inst = ms.quantize(model.profile().default_slot_ms);
    let schedule = match rng.below(3) {
        0 => greedy::solve(&inst).expect("greedy"),
        1 => baseline::solve(&inst, rng).expect("baseline"),
        _ => admm::solve(&inst, &admm::AdmmCfg::default()).expect("admm").schedule,
    };
    (ms, inst, schedule)
}

/// Corrupt the dense lists in one of several constraint-violating ways.
fn corrupt(rng: &mut Rng, inst: &Instance, helper_of: &[usize], fwd: &mut [Vec<u32>], bwd: &mut [Vec<u32>]) {
    let j = rng.below(inst.n_clients);
    match rng.below(4) {
        0 => {
            // (1)/(3)-ish: shift the fwd task to start at slot 0.
            let e = inst.edge(helper_of[j], j);
            fwd[j] = (0..inst.p[e]).collect();
        }
        1 => {
            // (6): drop a slot.
            fwd[j].pop();
        }
        2 => {
            // (3): copy another client's slots.
            let other = rng.below(inst.n_clients);
            if other != j && helper_of[other] == helper_of[j] && !fwd[other].is_empty() {
                fwd[j] = fwd[other].clone();
            } else {
                bwd[j] = fwd[j].clone(); // same-client fwd/bwd collision
            }
        }
        _ => {
            // (2): pull the bwd task to right after the fwd finish.
            let fin = fwd[j].last().map(|&t| t + 1).unwrap_or(0);
            let n = bwd[j].len() as u32;
            bwd[j] = (fin..fin + n).collect();
        }
    }
}

// ---------------------------------------------------------------------------
// Properties
// ---------------------------------------------------------------------------

#[test]
fn dense_roundtrip_is_lossless() {
    prop::check(30, |rng| {
        let (_, _, s) = random_case(rng);
        let (df, db) = to_dense(&s);
        let back = from_dense(s.assignment.helper_of.clone(), &df, &db);
        prop::assert_prop(back.fwd == s.fwd && back.bwd == s.bwd, "to_slots/from_slots roundtrip");
    });
}

#[test]
fn checker_verdicts_match_dense_reference_on_solver_output() {
    prop::check(30, |rng| {
        let (_, inst, s) = random_case(rng);
        let (df, db) = to_dense(&s);
        let dense = violations_dense(&inst, &s.assignment.helper_of, &df, &db);
        let runs = s.violations(&inst);
        prop::assert_prop(
            dense.is_empty() == runs.is_empty(),
            &format!("feasibility verdict diverged: dense {dense:?} vs runs {runs:?}"),
        );
        prop::assert_prop(
            tags(&dense) == tags(&runs),
            &format!("constraint tags diverged: dense {:?} vs runs {:?}", tags(&dense), tags(&runs)),
        );
    });
}

#[test]
fn checker_verdicts_match_dense_reference_on_corrupted_schedules() {
    prop::check(60, |rng| {
        let (_, inst, s) = random_case(rng);
        let (mut df, mut db) = to_dense(&s);
        corrupt(rng, &inst, &s.assignment.helper_of, &mut df, &mut db);
        let bad = from_dense(s.assignment.helper_of.clone(), &df, &db);
        let dense = violations_dense(&inst, &s.assignment.helper_of, &df, &db);
        let runs = bad.violations(&inst);
        prop::assert_prop(
            dense.is_empty() == runs.is_empty(),
            &format!("feasibility verdict diverged after corruption: dense {dense:?} vs runs {runs:?}"),
        );
        prop::assert_prop(
            tags(&dense) == tags(&runs),
            &format!("tags diverged after corruption: dense {:?} vs runs {:?}", tags(&dense), tags(&runs)),
        );
    });
}

#[test]
fn finishes_completions_and_makespan_match_dense() {
    prop::check(40, |rng| {
        let (_, inst, s) = random_case(rng);
        let (df, db) = to_dense(&s);
        for j in 0..inst.n_clients {
            let fwd_fin = df[j].last().map(|&t| t + 1).unwrap_or(0);
            let bwd_fin = db[j].last().map(|&t| t + 1).unwrap_or(0);
            prop::assert_prop(s.fwd_finish(j) == fwd_fin, "fwd_finish");
            prop::assert_prop(s.bwd_finish(j) == bwd_fin, "bwd_finish");
            let e = inst.edge(s.assignment.helper_of[j], j);
            prop::assert_prop(s.fwd_completion(&inst, j) == fwd_fin + inst.l[e], "fwd completion");
            prop::assert_prop(s.completion(&inst, j) == bwd_fin + inst.rp[e], "completion");
            // Segment counts: run count == dense maximal-run count.
            let dense_segs = |slots: &[u32]| -> u32 {
                if slots.is_empty() {
                    0
                } else {
                    1 + slots.windows(2).filter(|w| w[1] != w[0] + 1).count() as u32
                }
            };
            prop::assert_prop(s.fwd[j].segments() == dense_segs(&df[j]), "fwd segments");
            prop::assert_prop(s.bwd[j].segments() == dense_segs(&db[j]), "bwd segments");
        }
        let dense_makespan = (0..inst.n_clients)
            .map(|j| db[j].last().map(|&t| t + 1).unwrap_or(0) + inst.rp[inst.edge(s.assignment.helper_of[j], j)])
            .max()
            .unwrap_or(0);
        prop::assert_prop(s.makespan(&inst) == dense_makespan, "makespan");
    });
}

#[test]
fn segment_streams_match_dense_derivation() {
    prop::check(40, |rng| {
        let (_, inst, s) = random_case(rng);
        let (df, db) = to_dense(&s);
        let dense = dense_streams(inst.n_helpers, &s.assignment.helper_of, &df, &db);
        let runs = sim::streams(inst.n_helpers, &s);
        prop::assert_prop(dense.len() == runs.len(), "stream count");
        for (d, r) in dense.iter().zip(&runs) {
            prop::assert_prop(d.len() == r.len(), "segments per helper");
            for (ds, rs) in d.iter().zip(r) {
                prop::assert_prop(
                    ds.client == rs.client
                        && ds.is_bwd == rs.is_bwd
                        && ds.start == rs.start
                        && ds.len == rs.len
                        && ds.frac == rs.frac,
                    &format!("segment diverged: dense {ds:?} vs runs {rs:?}"),
                );
            }
        }
    });
}

#[test]
fn replay_makespan_matches_dense_replay() {
    prop::check(40, |rng| {
        let (ms, _, s) = random_case(rng);
        let (df, db) = to_dense(&s);
        let dense = replay_dense_makespan(&ms, &s.assignment.helper_of, &df, &db);
        let runs = sim::replay(&ms, &s, None).makespan_ms;
        // Same segment streams + same arithmetic order → bitwise equal.
        prop::assert_prop(dense == runs, &format!("replay diverged: dense {dense} vs runs {runs}"));
    });
}

#[test]
fn epoch_replay_stays_consistent_with_single_batch() {
    // The pipelined engine consumes the same shared streams; its 1-batch
    // case must track the single-batch realized makespan.
    prop::check(15, |rng| {
        let (ms, _, s) = random_case(rng);
        let single = sim::replay(&ms, &s, None).makespan_ms;
        let epoch = psl::sim::epoch::replay_epoch(&ms, &s, 1);
        prop::assert_prop(
            (epoch.batch_ms - single).abs() <= 0.05 * single + 1e-9,
            &format!("epoch[1] {} vs single {}", epoch.batch_ms, single),
        );
    });
}

#[test]
fn schedule_memory_is_runs_not_slots() {
    // The acceptance claim made testable: on the mega-homogeneous family
    // (FCFS via strategy → zero preemptions) the stored representation is
    // exactly 2 runs per client while the slot count is orders larger.
    // Fine quantization: many slots per task, but still one run per task.
    let inst = ScenarioCfg::new(Scenario::S6MegaHomogeneous, Model::ResNet101, 64, 8, 7)
        .generate()
        .quantize(50.0);
    let s = greedy::solve(&inst).unwrap();
    assert_eq!(s.preemptions(), 0);
    assert_eq!(s.total_runs(), 2 * 64, "one run per task");
    assert!(
        s.total_slots() > 4 * s.total_runs() as u64,
        "slots {} should dwarf runs {}",
        s.total_slots(),
        s.total_runs()
    );
}
