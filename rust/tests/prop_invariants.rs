//! Cross-solver property invariants on randomized instances — the
//! heavyweight fuzz layer (scale cases with PSL_PROP_CASES).

use psl::instance::profiles::Model;
use psl::instance::scenario::{Scenario, ScenarioCfg};
use psl::instance::Instance;
use psl::solver::{admm, baseline, bwd, exact, greedy};
use psl::util::prop;
use psl::util::rng::Rng;

/// Uniform draw over every named scenario family — the fuzz layer must
/// exercise the grown families (clustered tiers, straggler tails, starved
/// memory, mega-homogeneous) exactly like the paper presets.
fn any_scenario(rng: &mut Rng) -> Scenario {
    Scenario::ALL[rng.below(Scenario::ALL.len())]
}

fn random_instance(rng: &mut Rng) -> Instance {
    let scen = any_scenario(rng);
    let model = if rng.chance(0.5) { Model::ResNet101 } else { Model::Vgg19 };
    let j = rng.range_usize(1, 18);
    let i = rng.range_usize(1, 5);
    let slot = rng.range_f64(100.0, 800.0);
    ScenarioCfg::new(scen, model, j, i, rng.next_u64()).generate().quantize(slot)
}

#[test]
fn every_solver_output_is_feasible() {
    prop::check(25, |rng| {
        let inst = random_instance(rng);
        let schedules = vec![
            ("greedy", greedy::solve(&inst).expect("greedy")),
            ("baseline", baseline::solve(&inst, rng).expect("baseline")),
            ("admm", admm::solve(&inst, &admm::AdmmCfg::default()).expect("admm").schedule),
        ];
        for (name, s) in schedules {
            let v = s.violations(&inst);
            prop::assert_prop(v.is_empty(), &format!("{name} on {}: {v:?}", inst.label));
            prop::assert_prop(
                s.makespan(&inst) >= inst.makespan_lower_bound(),
                &format!("{name}: makespan below lower bound"),
            );
        }
    });
}

#[test]
fn makespan_dominance_chain() {
    // exact ≤ decomposition(admm-assignment) and replaying Alg.2 on any
    // feasible fwd schedule cannot hurt.
    prop::check(10, |rng| {
        let scen = any_scenario(rng);
        let inst = ScenarioCfg::new(scen, Model::Vgg19, rng.range_usize(2, 8), 2, rng.next_u64())
            .generate()
            .quantize(550.0);
        let ex = exact::solve(
            &inst,
            &exact::ExactCfg {
                node_cap: 200_000,
                helper_node_cap: 100_000,
                time_budget: std::time::Duration::from_secs(10),
            },
        );
        let a = admm::solve(&inst, &admm::AdmmCfg::default()).unwrap().schedule;
        prop::assert_prop(ex.makespan <= a.makespan(&inst), "exact dominates admm");
        prop::assert_prop(ex.lower_bound <= ex.makespan, "bound sanity");

        let g = greedy::solve(&inst).unwrap();
        let improved = bwd::complete_with_optimal_bwd(&inst, g.assignment.clone(), g.fwd.clone());
        prop::assert_prop(improved.makespan(&inst) <= g.makespan(&inst), "Alg.2 never hurts");
    });
}

#[test]
fn admm_is_deterministic() {
    prop::check(8, |rng| {
        let inst = random_instance(rng);
        let a = admm::solve(&inst, &admm::AdmmCfg::default()).unwrap();
        let b = admm::solve(&inst, &admm::AdmmCfg::default()).unwrap();
        prop::assert_prop(
            a.schedule.makespan(&inst) == b.schedule.makespan(&inst),
            "same input, same makespan",
        );
        prop::assert_prop(
            a.schedule.assignment.helper_of == b.schedule.assignment.helper_of,
            "same input, same assignment",
        );
    });
}

#[test]
fn quantization_never_underestimates_work() {
    prop::check(20, |rng| {
        let scen = any_scenario(rng);
        let ms = ScenarioCfg::new(scen, Model::ResNet101, rng.range_usize(2, 12), rng.range_usize(1, 4), rng.next_u64())
            .generate();
        let fine = ms.quantize(50.0);
        let coarse = ms.quantize(400.0);
        for e in 0..fine.p.len() {
            prop::assert_prop(
                fine.p[e] as f64 * 50.0 + 50.0 > ms.p_ms[e],
                "fine quantization brackets true time",
            );
            prop::assert_prop(
                coarse.p[e] as f64 * 400.0 + 400.0 > ms.p_ms[e],
                "coarse quantization brackets true time",
            );
        }
        // Nominal coarse ≥ fine in ms terms per task (ceil property).
        for e in 0..fine.p.len() {
            prop::assert_prop(
                coarse.p[e] as f64 * 400.0 + 1e-9 >= fine.p[e] as f64 * 50.0 - 50.0,
                "coarse does not undercut fine by more than a slot",
            );
        }
    });
}

#[test]
fn gantt_json_roundtrips_for_all_methods() {
    prop::check(10, |rng| {
        let inst = random_instance(rng);
        let s = greedy::solve(&inst).unwrap();
        let doc = psl::sim::gantt_json(&inst, &s);
        let parsed = psl::util::json::Json::parse(&doc.pretty()).expect("valid json");
        prop::assert_prop(parsed.get("slot_ms").as_f64().is_some(), "slot_ms present");
    });
}

#[test]
fn replay_with_jitter_stays_feasible_in_expectation() {
    // Failure injection: heavy jitter must never crash the replay engine
    // or produce non-finite makespans.
    prop::check(15, |rng| {
        let scen = any_scenario(rng);
        let ms = ScenarioCfg::new(scen, Model::Vgg19, rng.range_usize(2, 10), rng.range_usize(1, 3), rng.next_u64())
            .generate();
        let inst = ms.quantize(550.0);
        let s = greedy::solve(&inst).unwrap();
        let rep = psl::sim::replay(&ms, &s, Some((rng, 0.6)));
        prop::assert_prop(rep.makespan_ms.is_finite() && rep.makespan_ms > 0.0, "finite makespan");
        prop::assert_prop(
            rep.completion_ms.iter().all(|c| c.is_finite() && *c > 0.0),
            "all clients complete under jitter",
        );
    });
}

#[test]
fn memory_pressure_respected_under_tight_capacity() {
    // Shrink helper memory towards the feasibility boundary; assignments
    // must stay memory-feasible for every solver that returns Some.
    prop::check(15, |rng| {
        let mut inst = random_instance(rng);
        let demand: f64 = inst.d.iter().sum();
        let cap: f64 = inst.mem.iter().sum();
        let scale = 1.05 * demand / cap;
        if scale < 1.0 {
            for m in inst.mem.iter_mut() {
                *m *= scale.max(0.2);
            }
        }
        let max_d = inst.d.iter().cloned().fold(0.0, f64::max);
        let max_m = inst.mem.iter().cloned().fold(0.0, f64::max);
        if max_m < max_d {
            return; // generator boundary case: not repairable here
        }
        if let Some(g) = greedy::solve(&inst) {
            prop::assert_prop(g.assignment.memory_ok(&inst), "greedy memory under pressure");
        }
        if let Some(b) = baseline::solve(&inst, rng) {
            prop::assert_prop(b.assignment.memory_ok(&inst), "baseline memory under pressure");
        }
    });
}

#[test]
fn every_named_family_is_solvable_end_to_end() {
    // Exhaustive (non-random) pass: every family × model must generate,
    // quantize, and yield a feasible greedy schedule above the lower bound.
    for scen in Scenario::ALL {
        for model in [Model::ResNet101, Model::Vgg19] {
            let slot = model.profile().default_slot_ms;
            let inst = ScenarioCfg::new(scen, model, 8, 3, 2026).generate().quantize(slot);
            let g = greedy::solve(&inst)
                .unwrap_or_else(|| panic!("{}/{}: greedy found no schedule", scen.name(), model.name()));
            assert!(g.is_feasible(&inst), "{}/{}: infeasible schedule", scen.name(), model.name());
            assert!(
                g.makespan(&inst) >= inst.makespan_lower_bound(),
                "{}/{}: makespan below lower bound",
                scen.name(),
                model.name()
            );
        }
    }
}
