//! PJRT runtime: the bridge between the rust coordinator and the AOT
//! artifacts produced by the python compile path.
//!
//! * [`tensor`] — host tensors + literal conversion + SGD/FedAvg math.
//! * [`artifact`] — manifest parsing / initial parameter loading.
//! * [`engine`] — compile-once execute-many PJRT wrapper.
//!
//! Python never runs here; after `make artifacts` the rust binary is
//! self-contained.

pub mod artifact;
pub mod engine;
pub mod tensor;

pub use artifact::{FunctionSpec, Manifest, ParamSpec, TensorSpec};
pub use engine::Engine;
pub use tensor::{DType, Tensor, TensorData};

/// Default artifacts directory (overridable via CLI / env PSL_ARTIFACTS).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("PSL_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
