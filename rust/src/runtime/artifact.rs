//! Artifact registry: parses the `manifest.json` emitted by
//! `python/compile/aot.py` and exposes typed descriptions of every
//! exported function and parameter bundle.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Shape + dtype of one tensor in an artifact signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(v: &Json) -> Result<TensorSpec> {
        let shape = v
            .get("shape")
            .as_arr()
            .context("spec.shape")?
            .iter()
            .map(|x| x.as_usize().context("shape dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { shape, dtype: v.get("dtype").as_str().unwrap_or("float32").to_string() })
    }

    pub fn n_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One exported function: HLO path + flattened I/O signature.
#[derive(Clone, Debug)]
pub struct FunctionSpec {
    pub name: String,
    pub hlo_path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One parameter bundle (p1/p2/p3): leaf specs + init files.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub leaves: Vec<TensorSpec>,
    pub files: Vec<PathBuf>,
}

/// Parsed manifest for one architecture.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub arch: String,
    pub batch: usize,
    pub cuts: (usize, usize),
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub functions: std::collections::BTreeMap<String, FunctionSpec>,
    pub params: std::collections::BTreeMap<String, ParamSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `artifacts/<arch>/manifest.json`.
    pub fn load(artifacts_dir: &Path, arch: &str) -> Result<Manifest> {
        let dir = artifacts_dir.join(arch);
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("read manifest for {arch} in {}", dir.display()))?;
        let v = Json::parse(&text).context("parse manifest.json")?;
        let mut functions = std::collections::BTreeMap::new();
        for (name, f) in v.get("functions").as_obj().context("functions")? {
            let inputs = f
                .get("inputs")
                .as_arr()
                .context("inputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = f
                .get("outputs")
                .as_arr()
                .context("outputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            functions.insert(
                name.clone(),
                FunctionSpec {
                    name: name.clone(),
                    hlo_path: dir.join(f.get("hlo").as_str().context("hlo path")?),
                    inputs,
                    outputs,
                },
            );
        }
        let mut params = std::collections::BTreeMap::new();
        for (name, p) in v.get("params").as_obj().context("params")? {
            let leaves = p
                .get("leaves")
                .as_arr()
                .context("leaves")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let files = p
                .get("files")
                .as_arr()
                .context("files")?
                .iter()
                .map(|x| Ok(dir.join(x.as_str().context("file")?)))
                .collect::<Result<Vec<_>>>()?;
            anyhow::ensure!(leaves.len() == files.len(), "params {name}: leaves/files mismatch");
            params.insert(name.clone(), ParamSpec { leaves, files });
        }
        let cuts_arr = v.get("cuts").as_arr().context("cuts")?;
        anyhow::ensure!(cuts_arr.len() == 2, "cuts must have 2 entries");
        Ok(Manifest {
            arch: v.get("arch").as_str().unwrap_or(arch).to_string(),
            batch: v.get("batch").as_usize().context("batch")?,
            cuts: (cuts_arr[0].as_usize().context("σ1")?, cuts_arr[1].as_usize().context("σ2")?),
            input_shape: v
                .get("input_shape")
                .as_arr()
                .context("input_shape")?
                .iter()
                .map(|x| x.as_usize().context("dim"))
                .collect::<Result<Vec<_>>>()?,
            num_classes: v.get("num_classes").as_usize().unwrap_or(10),
            functions,
            params,
            dir,
        })
    }

    pub fn function(&self, name: &str) -> Result<&FunctionSpec> {
        self.functions.get(name).with_context(|| format!("function {name} not in manifest"))
    }

    /// Load a part's initial parameters from the init dumps.
    pub fn load_init_params(&self, part: &str) -> Result<Vec<super::tensor::Tensor>> {
        let spec = self.params.get(part).with_context(|| format!("params {part} not in manifest"))?;
        spec.leaves
            .iter()
            .zip(&spec.files)
            .map(|(leaf, file)| super::tensor::Tensor::load_f32_raw(file, &leaf.shape))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Write a miniature synthetic manifest for parser tests (the real
    /// manifest round-trip is covered by the artifact-gated integration
    /// tests in rust/tests/).
    fn synthetic_manifest(dir: &Path) {
        std::fs::create_dir_all(dir.join("toy/init")).unwrap();
        let bytes: Vec<u8> = [1.0f32, 2.0, 3.0, 4.0].iter().flat_map(|x| x.to_le_bytes()).collect();
        std::fs::write(dir.join("toy/init/p1_0.bin"), &bytes).unwrap();
        let manifest = r#"{
            "arch": "toy", "batch": 2, "cuts": [1, 3],
            "input_shape": [4, 4, 1], "num_classes": 2,
            "functions": {
                "part1_fwd": {"hlo": "part1_fwd.hlo.txt",
                    "inputs": [{"shape": [2, 2], "dtype": "float32"}],
                    "outputs": [{"shape": [2, 2], "dtype": "float32"}]}
            },
            "params": {
                "p1": {"leaves": [{"path": "w", "shape": [2, 2], "dtype": "float32"}],
                        "files": ["init/p1_0.bin"], "n_elements": 4}
            }
        }"#;
        std::fs::write(dir.join("toy/manifest.json"), manifest).unwrap();
    }

    #[test]
    fn parses_synthetic_manifest() {
        let dir = std::env::temp_dir().join(format!("psl-manifest-{}", std::process::id()));
        synthetic_manifest(&dir);
        let m = Manifest::load(&dir, "toy").unwrap();
        assert_eq!(m.arch, "toy");
        assert_eq!(m.cuts, (1, 3));
        assert_eq!(m.batch, 2);
        let f = m.function("part1_fwd").unwrap();
        assert_eq!(f.inputs.len(), 1);
        assert_eq!(f.inputs[0].shape, vec![2, 2]);
        let p = m.load_init_params("p1").unwrap();
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        assert!(m.function("missing").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
