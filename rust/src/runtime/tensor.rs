//! Host-side tensors and conversion to/from PJRT literals.
//!
//! The SL runtime keeps all training state (parameters, activations,
//! gradients) as plain row-major host buffers; literals are created at the
//! PJRT boundary only. f32 (data) and i32 (labels) cover the exported
//! artifact signatures.

use anyhow::{bail, Context, Result};

/// Element type of a tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" | "f32" => Ok(DType::F32),
            "int32" | "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

/// A dense row-major host tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: TensorData::F32(vec![0.0; shape.iter().product()]) }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        anyhow::ensure!(data.len() == n, "shape {shape:?} wants {n} elements, got {}", data.len());
        Ok(Tensor { shape: shape.to_vec(), data: TensorData::F32(data) })
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        anyhow::ensure!(data.len() == n, "shape {shape:?} wants {n} elements, got {}", data.len());
        Ok(Tensor { shape: shape.to_vec(), data: TensorData::I32(data) })
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    /// SGD step: self -= lr * grad (both f32, same shape).
    pub fn sgd_step(&mut self, grad: &Tensor, lr: f32) -> Result<()> {
        anyhow::ensure!(self.shape == grad.shape, "sgd shape mismatch {:?} vs {:?}", self.shape, grad.shape);
        let g = grad.as_f32()?;
        for (p, gi) in self.as_f32_mut()?.iter_mut().zip(g) {
            *p -= lr * gi;
        }
        Ok(())
    }

    /// Weighted in-place accumulate: self += w * other (FedAvg building
    /// block).
    pub fn axpy(&mut self, w: f32, other: &Tensor) -> Result<()> {
        anyhow::ensure!(self.shape == other.shape, "axpy shape mismatch");
        let o = other.as_f32()?;
        for (a, b) in self.as_f32_mut()?.iter_mut().zip(o) {
            *a += w * b;
        }
        Ok(())
    }

    pub fn scale(&mut self, w: f32) -> Result<()> {
        for a in self.as_f32_mut()? {
            *a *= w;
        }
        Ok(())
    }

    /// Convert to a PJRT literal with the right shape (needs the `pjrt`
    /// feature — see runtime::engine).
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => xla::Literal::vec1(v.as_slice()),
            TensorData::I32(v) => xla::Literal::vec1(v.as_slice()),
        };
        // Scalars: vec1 of len 1 reshaped to rank-0.
        lit.reshape(&dims).context("literal reshape")
    }

    /// Read a literal back into a host tensor (needs the `pjrt` feature).
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape().context("literal shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Tensor::from_f32(&dims, lit.to_vec::<f32>()?),
            xla::ElementType::S32 => Tensor::from_i32(&dims, lit.to_vec::<i32>()?),
            other => bail!("unsupported literal type {other:?}"),
        }
    }

    /// Load raw little-endian f32 from a file (the aot.py init dumps).
    pub fn load_f32_raw(path: &std::path::Path, shape: &[usize]) -> Result<Tensor> {
        let bytes = std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
        anyhow::ensure!(bytes.len() % 4 == 0, "file not f32-aligned");
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Tensor::from_f32(shape, data)
    }

    /// Mean of the elements (for loss scalars / diagnostics).
    pub fn mean(&self) -> Result<f32> {
        let v = self.as_f32()?;
        anyhow::ensure!(!v.is_empty());
        Ok(v.iter().sum::<f32>() / v.len() as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_accessors() {
        let t = Tensor::from_f32(&[2, 3], vec![1.0; 6]).unwrap();
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), DType::F32);
        assert!(Tensor::from_f32(&[2, 3], vec![1.0; 5]).is_err());
    }

    #[test]
    fn sgd_and_axpy() {
        let mut p = Tensor::from_f32(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let g = Tensor::from_f32(&[3], vec![1.0, 1.0, 1.0]).unwrap();
        p.sgd_step(&g, 0.5).unwrap();
        assert_eq!(p.as_f32().unwrap(), &[0.5, 1.5, 2.5]);
        p.axpy(2.0, &g).unwrap();
        assert_eq!(p.as_f32().unwrap(), &[2.5, 3.5, 4.5]);
        let bad = Tensor::from_f32(&[2], vec![0.0; 2]).unwrap();
        assert!(p.sgd_step(&bad, 0.1).is_err());
    }

    #[test]
    fn raw_f32_roundtrip() {
        let dir = std::env::temp_dir().join("psl-tensor-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        let data: Vec<f32> = vec![0.25, -1.5, 3.0, 7.0];
        let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        let t = Tensor::load_f32_raw(&path, &[2, 2]).unwrap();
        assert_eq!(t.as_f32().unwrap(), data.as_slice());
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("float32").unwrap(), DType::F32);
        assert_eq!(DType::parse("int32").unwrap(), DType::I32);
        assert!(DType::parse("bfloat16").is_err());
    }
}
