//! PJRT execution engine: loads HLO-text artifacts, compiles them once on
//! the CPU PJRT client, and executes them from the L3 hot path.
//!
//! Adapted from /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`. One
//! compiled executable per artifact, cached for the process lifetime.
//!
//! The real implementation needs the `xla` PJRT bindings, which this
//! offline image cannot fetch, so it is gated behind the `pjrt` cargo
//! feature (see Cargo.toml). The default build ships a stub [`Engine`]
//! with the same API whose constructor returns a descriptive error; every
//! call site (slexec, `psl train`, artifact-gated tests) already handles
//! `Engine::cpu()` failing, so the rest of the crate is unaffected.

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use crate::runtime::tensor::Tensor;
    use anyhow::{Context, Result};
    use std::collections::HashMap;
    use std::path::Path;
    use std::sync::Mutex;

    /// A compiled artifact plus its call statistics.
    struct CachedExe {
        exe: xla::PjRtLoadedExecutable,
        calls: u64,
        total_ms: f64,
    }

    /// The engine. `Send`-able behind a Mutex: helper actor threads share one
    /// engine (PJRT CPU client is thread-safe; the cache map is what we lock).
    pub struct Engine {
        client: xla::PjRtClient,
        cache: Mutex<HashMap<String, CachedExe>>,
    }

    impl Engine {
        /// Create the CPU PJRT engine.
        pub fn cpu() -> Result<Engine> {
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            Ok(Engine { client, cache: Mutex::new(HashMap::new()) })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO text file (cached by path).
        pub fn load(&self, path: &Path) -> Result<()> {
            let key = path.display().to_string();
            {
                let cache = self.cache.lock().unwrap();
                if cache.contains_key(&key) {
                    return Ok(());
                }
            }
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).with_context(|| format!("compile {}", path.display()))?;
            self.cache
                .lock()
                .unwrap()
                .insert(key, CachedExe { exe, calls: 0, total_ms: 0.0 });
            Ok(())
        }

        /// Execute a loaded artifact on host tensors. The exported functions
        /// were lowered with `return_tuple=True`, so the single output literal
        /// is a tuple that we decompose into one tensor per output.
        pub fn execute(&self, path: &Path, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            self.load(path)?;
            let key = path.display().to_string();
            let literals: Vec<xla::Literal> = inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
            let start = std::time::Instant::now();
            // Execute without holding the cache lock beyond the map access:
            // PJRT executables are internally synchronized; we only guard the
            // HashMap itself, then update stats after.
            let result = {
                let cache = self.cache.lock().unwrap();
                let entry = cache.get(&key).expect("loaded above");
                entry.exe.execute::<xla::Literal>(&literals).context("pjrt execute")?
            };
            let out = result[0][0].to_literal_sync().context("fetch result")?;
            let tuple = out.to_tuple().context("decompose output tuple")?;
            let tensors = tuple.iter().map(Tensor::from_literal).collect::<Result<Vec<_>>>()?;
            let elapsed = start.elapsed().as_secs_f64() * 1e3;
            let mut cache = self.cache.lock().unwrap();
            if let Some(e) = cache.get_mut(&key) {
                e.calls += 1;
                e.total_ms += elapsed;
            }
            Ok(tensors)
        }

        /// (calls, mean ms) per loaded artifact — runtime profiling surface.
        pub fn stats(&self) -> Vec<(String, u64, f64)> {
            let cache = self.cache.lock().unwrap();
            let mut rows: Vec<(String, u64, f64)> = cache
                .iter()
                .map(|(k, e)| (k.clone(), e.calls, if e.calls > 0 { e.total_ms / e.calls as f64 } else { 0.0 }))
                .collect();
            rows.sort_by(|a, b| a.0.cmp(&b.0));
            rows
        }
    }

    #[cfg(test)]
    mod tests {
        // Engine tests require compiled artifacts; they live in
        // rust/tests/runtime_artifacts.rs and are gated on artifacts/ existing
        // (built by `make artifacts`). Here we only check construction.
        use super::*;

        #[test]
        fn cpu_engine_constructs() {
            let e = Engine::cpu().expect("PJRT CPU client");
            assert!(!e.platform().is_empty());
            assert!(e.stats().is_empty());
        }

        #[test]
        fn missing_artifact_errors() {
            let e = Engine::cpu().unwrap();
            let err = e.load(Path::new("/nonexistent/artifact.hlo.txt"));
            assert!(err.is_err());
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub_impl {
    use crate::runtime::tensor::Tensor;
    use anyhow::{bail, Result};
    use std::path::Path;

    const UNAVAILABLE: &str = "psl was built without the `pjrt` feature; the PJRT runtime is \
                               unavailable (rebuild with `--features pjrt` and the `xla` bindings \
                               to run real training)";

    /// API-compatible stand-in for the PJRT engine when the `pjrt` feature
    /// is off. Construction fails, so no caller can reach `execute`.
    pub struct Engine {
        _private: (),
    }

    impl Engine {
        pub fn cpu() -> Result<Engine> {
            bail!("{UNAVAILABLE}")
        }

        pub fn platform(&self) -> String {
            "pjrt-stub".to_string()
        }

        pub fn load(&self, _path: &Path) -> Result<()> {
            bail!("{UNAVAILABLE}")
        }

        pub fn execute(&self, _path: &Path, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            bail!("{UNAVAILABLE}")
        }

        pub fn stats(&self) -> Vec<(String, u64, f64)> {
            Vec::new()
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn stub_constructor_reports_missing_feature() {
            let err = Engine::cpu().err().expect("stub must not construct");
            assert!(format!("{err}").contains("pjrt"), "unhelpful error: {err}");
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::Engine;
#[cfg(not(feature = "pjrt"))]
pub use stub_impl::Engine;
