//! The multi-round fleet orchestrator: round loop + warm-started
//! incremental repair + full re-solve fallback policy.
//!
//! Each round the orchestrator materializes the roster's instance from
//! the [`FleetWorld`] client factory and produces a schedule one of two
//! ways:
//!
//! * **Full re-solve** — run [`strategy`] (the §VII signal-driven pick
//!   rule) from scratch. Always used for round 0, for the
//!   `full-every-round` policy, and as the *fallback* when a drift signal
//!   fires.
//! * **Incremental repair** — keep the previous round's [`Assignment`]:
//!   survivors stay on their helper, departures are evicted, arrivals are
//!   placed greedily (least-loaded memory-feasible helper), and only
//!   *overloaded* helpers are rebalanced by local moves. The repaired
//!   assignment is then FCFS-scheduled.
//!
//! Two drift signals can force the fallback under the `incremental`
//! policy: the round's **churn fraction** (membership delta over the
//! previous roster) and the repaired schedule's **makespan gap** against
//! the fresh instance lower bound, normalized by the gap the last full
//! solve achieved — absolute gaps are scenario-shaped (a straggler tail
//! inflates every round's gap), the *relative drift* is not. The
//! `repair-only` policy disables both (the no-fallback ablation arm in
//! the fleet grid), and the `auto` policy replaces the static churn
//! threshold with the **measured frontier** of a
//! [`PolicyTable`](super::policy::PolicyTable) — per scenario family and
//! fleet size — while keeping the gap safety net.
//!
//! Everything is deterministic in the scenario tuple + churn knobs: no
//! wall-clock enters any decision, and re-solve cost is reported as a
//! deterministic work proxy (candidate evaluations) instead of seconds.
//!
//! [`FleetWorld`]: crate::instance::scenario::FleetWorld

use super::events::{self, ChurnCfg, FlashCrowdCfg, HelperChurnCfg, RoundEvents};
use super::policy::PolicyTable;
use super::report::{FleetReport, RoundReport};
use super::session::FleetSession;
use crate::instance::scenario::{FleetWorld, ScenarioCfg};
use crate::instance::Instance;
use crate::solver::admm::AdmmCfg;
use crate::solver::schedule::Assignment;
use crate::solver::strategy;
use crate::util::rng::fnv64 as fnv;
use std::collections::BTreeMap;

/// Re-orchestration policy for non-initial rounds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Warm-started repair with drift-triggered full re-solve fallback.
    Incremental,
    /// Full re-solve every round (the cold-start reference arm).
    FullEveryRound,
    /// Repair always, never fall back (the no-fallback ablation arm).
    RepairOnly,
    /// Data-driven: consult a measured [`PolicyTable`] per round and go
    /// full when the observed churn crosses the family's frontier (the
    /// lower-bound-gap safety net stays active, as under `Incremental`).
    Auto,
}

impl Policy {
    pub const ALL: [Policy; 4] = [Policy::Incremental, Policy::FullEveryRound, Policy::RepairOnly, Policy::Auto];

    pub fn name(self) -> &'static str {
        match self {
            Policy::Incremental => "incremental",
            Policy::FullEveryRound => "full",
            Policy::RepairOnly => "repair-only",
            Policy::Auto => "auto",
        }
    }

    pub fn parse(s: &str) -> Option<Policy> {
        match s.to_ascii_lowercase().as_str() {
            "incremental" | "inc" => Some(Policy::Incremental),
            "full" | "full-every-round" => Some(Policy::FullEveryRound),
            "repair-only" | "repair" => Some(Policy::RepairOnly),
            "auto" => Some(Policy::Auto),
            _ => None,
        }
    }
}

/// A fully-specified fleet run.
#[derive(Clone, Debug)]
pub struct FleetCfg {
    /// Scenario tuple (spec, model, base J, I, seed).
    pub scenario: ScenarioCfg,
    /// None → the model's default |S_t|.
    pub slot_ms: Option<f64>,
    pub churn: ChurnCfg,
    pub policy: Policy,
    /// Membership-delta fraction above which `incremental` falls back to
    /// a full re-solve before repairing.
    pub churn_threshold: f64,
    /// Relative drift above which `incremental` discards the repair and
    /// re-solves: fall back when (repaired makespan / fresh lower bound)
    /// exceeds `gap_threshold` × the same ratio at the last full solve.
    pub gap_threshold: f64,
    /// Batches replayed per round for the epoch-pipelined period metric.
    pub epoch_batches: usize,
    /// Measured frontier table consulted by [`Policy::Auto`] (ignored by
    /// the other policies). `None` → [`PolicyTable::builtin`].
    pub policy_table: Option<PolicyTable>,
    /// Helper fault process. [`HelperChurnCfg::none`] (the default for
    /// every family except `s7-helper-bursts`) disables helper modeling
    /// entirely: the world, event stream, and artifacts stay
    /// byte-identical to builds that predate helper dynamics.
    pub helper_churn: HelperChurnCfg,
    /// Surviving-capacity fraction (live helper memory over live + down)
    /// below which a degraded round abandons repair and fully re-solves
    /// on the reduced helper set (`helper-resolve`).
    pub capacity_threshold: f64,
    /// Flash-crowd arrival spikes layered on the client event stream.
    /// [`FlashCrowdCfg::none`] (the default for every family except
    /// `s8-flash-crowd`) leaves the stream byte-identical to runs that
    /// predate flash crowds.
    pub flash: FlashCrowdCfg,
    /// Transport model for every transfer phase: solve, repair, replay,
    /// and checker all route through it. The dedicated default keeps
    /// each run byte-identical to builds that predate the transport
    /// layer; shared mode prices per-helper uplink contention into all
    /// of them ([`crate::transport`]).
    pub transport: crate::transport::TransportCfg,
}

impl FleetCfg {
    pub fn new(scenario: ScenarioCfg, churn: ChurnCfg, policy: Policy) -> FleetCfg {
        let helper_churn = if scenario.spec.name == "s7-helper-bursts" {
            HelperChurnCfg::bursts()
        } else {
            HelperChurnCfg::none()
        };
        let flash = if scenario.spec.name == "s8-flash-crowd" {
            FlashCrowdCfg::spikes()
        } else {
            FlashCrowdCfg::none()
        };
        FleetCfg {
            scenario,
            slot_ms: None,
            churn,
            policy,
            churn_threshold: 0.35,
            // Mild degradation is the price warm starts pay by design
            // (FCFS repair vs a preemptive full solve); the fallback is
            // for *severe* drift. The fleet grid quantifies the tradeoff.
            gap_threshold: 1.75,
            epoch_batches: 8,
            policy_table: None,
            helper_churn,
            capacity_threshold: 0.5,
            flash,
            transport: crate::transport::TransportCfg::dedicated(),
        }
    }

    pub fn slot_ms(&self) -> f64 {
        self.slot_ms.unwrap_or(self.scenario.model.profile().default_slot_ms)
    }

    /// Build the world this run orchestrates over, sized for `max_clients`
    /// admitted clients: the static world when helper dynamics are off
    /// (byte-identical to historical runs), the outage-proof dynamic
    /// world otherwise.
    pub fn build_world_sized(&self, max_clients: usize) -> FleetWorld {
        if self.helper_churn.is_none() {
            self.scenario.fleet_world(max_clients)
        } else {
            self.scenario.fleet_world_dynamic(max_clients)
        }
    }

    /// [`build_world_sized`](FleetCfg::build_world_sized) at the churn
    /// process's roster cap — how every batch entry point builds it.
    pub fn build_world(&self) -> FleetWorld {
        self.build_world_sized(self.churn.max_clients)
    }
}

/// How a round's schedule was obtained (recorded per round in the
/// report). The `Full*` variants carry the §VII method the strategy
/// routed to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Round 0 has no warm state.
    FullInitial,
    /// The `full-every-round` policy.
    FullPolicy,
    /// Churn fraction crossed `churn_threshold`.
    FullChurn,
    /// The `auto` policy's measured frontier fired for this round's
    /// observed churn (distinct from `FullChurn` so grid analyses can
    /// separate the static threshold from the data-driven one).
    FullAuto,
    /// Repaired makespan drifted past `gap_threshold` × the last full
    /// solve's lower-bound gap.
    FullGap,
    /// Repair could not place an arrival (defensively unreachable under
    /// the wedge-free world) — distinct from gap drift so decision
    /// analyses stay clean.
    FullInfeasible,
    /// Warm-started incremental repair was kept.
    Repair,
    /// A round at degraded helper capacity (outages live) kept the
    /// warm-started repair: orphaned clients migrated to surviving
    /// helpers, everyone else stayed put.
    HelperDegraded,
    /// A degraded round abandoned the warm state and fully re-solved on
    /// the reduced helper set — the surviving-capacity fraction fell
    /// below `capacity_threshold`, the repair drifted past the gap
    /// fallback, or migration could not place an orphan.
    HelperResolve,
    /// Empty roster: nothing to schedule.
    Empty,
}

impl Decision {
    pub const ALL: [Decision; 10] = [
        Decision::FullInitial,
        Decision::FullPolicy,
        Decision::FullChurn,
        Decision::FullAuto,
        Decision::FullGap,
        Decision::FullInfeasible,
        Decision::Repair,
        Decision::HelperDegraded,
        Decision::HelperResolve,
        Decision::Empty,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Decision::FullInitial => "full-initial",
            Decision::FullPolicy => "full-policy",
            Decision::FullChurn => "full-churn",
            Decision::FullAuto => "full-auto",
            Decision::FullGap => "full-gap",
            Decision::FullInfeasible => "full-infeasible",
            Decision::Repair => "repair",
            Decision::HelperDegraded => "helper-degraded",
            Decision::HelperResolve => "helper-resolve",
            Decision::Empty => "empty",
        }
    }

    /// Inverse of [`Decision::name`] — fleet checkpoints round-trip the
    /// recorded decision string through this.
    pub fn parse(s: &str) -> Option<Decision> {
        Decision::ALL.into_iter().find(|d| d.name() == s)
    }

    pub fn is_full(self) -> bool {
        matches!(
            self,
            Decision::FullInitial
                | Decision::FullPolicy
                | Decision::FullChurn
                | Decision::FullAuto
                | Decision::FullGap
                | Decision::FullInfeasible
                | Decision::HelperResolve
        )
    }
}

/// Outcome of the incremental repair pass. Candidate-evaluation counts
/// (the deterministic work proxy) accumulate into the caller's `work`
/// out-param.
pub(super) struct Repaired {
    pub(super) assignment: Assignment,
    pub(super) moves: usize,
    pub(super) placed: usize,
}

/// Warm-start repair: survivors keep their helper, arrivals are placed on
/// the least-loaded memory-feasible helper, then local moves drain only
/// overloaded helpers. `prev` maps stable client id → helper of the
/// previous round. Returns None only if an arrival fits no helper (cannot
/// happen under the world's wedge-free repair and roster cap, but the
/// caller falls back to a full solve defensively). A helper-less instance
/// is a construction error, not an infeasibility signal — rejected up
/// front in [`ScenarioCfg::fleet_world`], and asserted here so it can
/// never masquerade as a `full-infeasible` round (pre-fix, the `?` on the
/// empty rebalance argmax silently conflated the two).
pub(super) fn repair_assignment(
    inst: &Instance,
    roster_ids: &[u64],
    prev: &BTreeMap<u64, usize>,
    work: &mut u64,
) -> Option<Repaired> {
    repair_assignment_guided(inst, roster_ids, prev, work, false)
}

/// [`repair_assignment`] with an optional ADMM-style placement rule.
/// With `admm_y` false this is the historical FCFS warm start: arrivals
/// go to the helper with the smallest accumulated slot-load. With
/// `admm_y` true — the session sets it when the *last full solve* routed
/// to ADMM, reusing that solve's assignment-step objective as the warm
/// start — each arrival instead minimizes the helper's load *plus its
/// own marginal cost on that helper* (the per-edge `p + p'` term), the
/// same completion-cost argmin ADMM's y-update greedily descends.
/// Survivor pinning, rebalance moves, and the work proxy are identical
/// in both modes, so decision analyses compare like for like.
pub(super) fn repair_assignment_guided(
    inst: &Instance,
    roster_ids: &[u64],
    prev: &BTreeMap<u64, usize>,
    work: &mut u64,
    admm_y: bool,
) -> Option<Repaired> {
    let i_n = inst.n_helpers;
    assert!(i_n >= 1, "repair on a helper-less instance (fleet worlds require I >= 1)");
    let mut free = inst.mem.clone();
    let mut count = vec![0usize; i_n];
    let mut load = vec![0f64; i_n]; // estimated slot-load Σ (p + pp)
    let mut helper_of: Vec<Option<usize>> = vec![None; roster_ids.len()];
    for (j, id) in roster_ids.iter().enumerate() {
        if let Some(&i) = prev.get(id) {
            helper_of[j] = Some(i);
            free[i] -= inst.d[j];
            count[i] += 1;
            let e = inst.edge(i, j);
            load[i] += (inst.p[e] + inst.pp[e]) as f64;
        }
    }
    // Greedy placement of arrivals (id order == roster order).
    let mut placed = 0usize;
    for (j, slot) in helper_of.iter_mut().enumerate() {
        if slot.is_some() {
            continue;
        }
        *work += i_n as u64;
        let key = |i: usize| -> f64 {
            if admm_y {
                load[i] + (inst.p[inst.edge(i, j)] + inst.pp[inst.edge(i, j)]) as f64
            } else {
                load[i]
            }
        };
        let i = (0..i_n)
            .filter(|&i| free[i] >= inst.d[j])
            .min_by(|&a, &b| {
                key(a)
                    .partial_cmp(&key(b))
                    .unwrap()
                    .then(count[a].cmp(&count[b]))
                    .then(a.cmp(&b))
            })?;
        *slot = Some(i);
        free[i] -= inst.d[j];
        count[i] += 1;
        let e = inst.edge(i, j);
        load[i] += (inst.p[e] + inst.pp[e]) as f64;
        placed += 1;
    }
    let mut helper_of: Vec<usize> = helper_of.into_iter().map(|s| s.expect("all placed")).collect();

    // Rebalance only overloaded helpers: while the max estimated load
    // exceeds the mean by > 15%, move the best client off the argmax
    // helper if that strictly lowers the local max. Bounded by roster
    // size so repair stays O(J²·I) worst case and terminates.
    let mut moves = 0usize;
    while moves < roster_ids.len() {
        // Recompute each iteration: moves change per-edge weights, so
        // the total (and mean) drifts as clients relocate.
        let mean = load.iter().sum::<f64>() / i_n.max(1) as f64;
        let imax = (0..i_n)
            .max_by(|&a, &b| load[a].partial_cmp(&load[b]).unwrap().then(b.cmp(&a)))
            .expect("i_n >= 1 asserted above");
        if load[imax] <= 1.15 * mean + 1e-9 {
            break;
        }
        let mut best: Option<(f64, usize, usize)> = None; // (new local max, j, dst)
        for j in 0..roster_ids.len() {
            if helper_of[j] != imax {
                continue;
            }
            let w_src = (inst.p[inst.edge(imax, j)] + inst.pp[inst.edge(imax, j)]) as f64;
            for dst in 0..i_n {
                if dst == imax || free[dst] < inst.d[j] {
                    continue;
                }
                *work += 1;
                let w_dst = (inst.p[inst.edge(dst, j)] + inst.pp[inst.edge(dst, j)]) as f64;
                let after = (load[imax] - w_src).max(load[dst] + w_dst);
                if best.map_or(true, |(b, bj, bd)| (after, j, dst) < (b, bj, bd)) {
                    best = Some((after, j, dst));
                }
            }
        }
        match best {
            Some((after, j, dst)) if after + 1e-9 < load[imax] => {
                let w_src = (inst.p[inst.edge(imax, j)] + inst.pp[inst.edge(imax, j)]) as f64;
                let w_dst = (inst.p[inst.edge(dst, j)] + inst.pp[inst.edge(dst, j)]) as f64;
                helper_of[j] = dst;
                free[imax] += inst.d[j];
                free[dst] -= inst.d[j];
                load[imax] -= w_src;
                load[dst] += w_dst;
                moves += 1;
            }
            _ => break,
        }
    }
    Some(Repaired { assignment: Assignment::new(helper_of), moves, placed })
}

/// Deterministic work proxy for a full strategy solve: every method at
/// least scans all edges; ADMM additionally iterates up to `max_iters`
/// times over them.
pub(super) fn full_work(inst: &Instance, method: strategy::Method, admm: &AdmmCfg) -> u64 {
    let edges = (inst.n_clients * inst.n_helpers) as u64;
    match method {
        strategy::Method::Admm => edges * admm.max_iters as u64,
        strategy::Method::BalancedGreedy => edges,
        // Sharded solves scan every edge once to partition, then solve
        // cells whose edge sets partition the full edge set.
        strategy::Method::Sharded => edges * 2,
    }
}

/// Run the fleet: generate the event stream, loop rounds, repair or
/// re-solve, and collect the per-round report.
pub fn run(cfg: &FleetCfg) -> FleetReport {
    run_streaming(cfg, &mut |_| {})
}

/// [`run`] with a per-round sink: the callback receives each
/// [`RoundReport`] the moment its round finishes, *before* the next round
/// solves — long-horizon runs can stream a JSONL sidecar instead of
/// waiting for the final report.
pub fn run_streaming(cfg: &FleetCfg, sink: &mut dyn FnMut(&RoundReport)) -> FleetReport {
    let world = cfg.build_world();
    let stream = events::generate_fleet(
        world.base_clients(),
        &cfg.churn,
        &cfg.helper_churn,
        &cfg.flash,
        world.n_helpers(),
        cfg.scenario.seed ^ fnv(&cfg.scenario.spec.name),
    );
    run_on_stream_streaming(cfg, &world, &stream, sink)
}

/// [`run`] on a pre-generated event stream (tests inject hand-crafted
/// churn histories through this entry).
pub fn run_on_stream(cfg: &FleetCfg, world: &FleetWorld, stream: &[RoundEvents]) -> FleetReport {
    run_on_stream_streaming(cfg, world, stream, &mut |_| {})
}

/// [`run_on_stream`] with a per-round sink (see [`run_streaming`]).
///
/// This is now a thin driver over [`FleetSession`]: one `step` per event,
/// then [`FleetSession::into_report`]. Callers that need to pause,
/// checkpoint, or feed events interactively hold the session directly.
pub fn run_on_stream_streaming(
    cfg: &FleetCfg,
    world: &FleetWorld,
    stream: &[RoundEvents],
    sink: &mut dyn FnMut(&RoundReport),
) -> FleetReport {
    let mut session = FleetSession::with_world(cfg.clone(), world.clone());
    for ev in stream {
        let round = session.step(ev);
        sink(&round);
    }
    session.into_report()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::Scenario;

    fn cfg(policy: Policy) -> FleetCfg {
        let scen = ScenarioCfg::new(Scenario::S4StragglerTail, Model::Vgg19, 10, 3, 7);
        let mut churn = ChurnCfg::stationary(10);
        churn.rounds = 8;
        FleetCfg::new(scen, churn, policy)
    }

    #[test]
    fn deterministic_report() {
        let a = run(&cfg(Policy::Incremental));
        let b = run(&cfg(Policy::Incremental));
        assert_eq!(a.to_json().pretty(), b.to_json().pretty());
    }

    #[test]
    fn default_run_mixes_repair_and_full() {
        let r = run(&cfg(Policy::Incremental));
        assert_eq!(r.rounds.len(), 8);
        assert!(r.rounds.iter().any(|x| x.decision == "repair"), "no repaired round");
        assert!(r.rounds.iter().any(|x| x.decision.starts_with("full")), "no full round");
        assert_eq!(r.rounds[0].decision, "full-initial");
    }

    #[test]
    fn full_policy_always_full() {
        let r = run(&cfg(Policy::FullEveryRound));
        for x in &r.rounds {
            assert!(x.decision.starts_with("full") || x.decision == "empty", "{}", x.decision);
            assert!(x.n_clients == 0 || x.method.is_some(), "full rounds record the picked method");
        }
    }

    #[test]
    fn repair_only_never_falls_back() {
        let r = run(&cfg(Policy::RepairOnly));
        for x in r.rounds.iter().skip(1) {
            assert!(x.decision == "repair" || x.decision == "empty", "round {}: {}", x.round, x.decision);
        }
    }

    #[test]
    fn makespan_bounded_by_lower_bound() {
        let r = run(&cfg(Policy::Incremental));
        for x in &r.rounds {
            if x.n_clients > 0 {
                assert!(x.makespan_slots >= x.lower_bound, "round {}", x.round);
                assert!(x.period_ms > 0.0);
            }
        }
    }

    #[test]
    fn full_departure_round_is_empty_not_fatal() {
        let scen = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 4, 2, 3);
        let world = scen.fleet_world(8);
        let stream = vec![
            RoundEvents::clients(0, vec![], vec![], vec![0, 1, 2, 3]),
            RoundEvents::clients(1, vec![0, 1, 2, 3], vec![], vec![]),
            RoundEvents::clients(2, vec![], vec![4, 5], vec![4, 5]),
        ];
        let churn = ChurnCfg { rounds: 3, arrival_rate: 0.0, departure_prob: 0.0, max_clients: 8 };
        let r = run_on_stream(&FleetCfg::new(scen, churn, Policy::Incremental), &world, &stream);
        assert_eq!(r.rounds[1].decision, "empty");
        assert_eq!(r.rounds[1].makespan_slots, 0);
        // The fleet recovers: round 2 reschedules the fresh arrivals.
        assert!(r.rounds[2].makespan_slots > 0);
    }

    #[test]
    fn big_churn_spike_triggers_full_churn() {
        let scen = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 6, 2, 5);
        let world = scen.fleet_world(12);
        // Round 1 replaces most of the fleet → churn fraction 1.0 > 0.35.
        let stream = vec![
            RoundEvents::clients(0, vec![], vec![], vec![0, 1, 2, 3, 4, 5]),
            RoundEvents::clients(1, vec![0, 1, 2], vec![6, 7, 8], vec![3, 4, 5, 6, 7, 8]),
        ];
        let churn = ChurnCfg { rounds: 2, arrival_rate: 0.0, departure_prob: 0.0, max_clients: 12 };
        let r = run_on_stream(&FleetCfg::new(scen, churn, Policy::Incremental), &world, &stream);
        assert_eq!(r.rounds[1].decision, "full-churn");
    }

    #[test]
    fn streaming_sink_sees_every_round_in_order() {
        let mut streamed = Vec::new();
        let r = run_streaming(&cfg(Policy::Incremental), &mut |round| streamed.push(round.clone()));
        assert_eq!(streamed.len(), r.rounds.len());
        assert_eq!(streamed, r.rounds, "sink receives exactly the final report's rounds");
        // And the sink-less entry point produces the identical report.
        let plain = run(&cfg(Policy::Incremental));
        assert_eq!(plain.to_json().pretty(), r.to_json().pretty());
    }

    #[test]
    fn s8_flash_crowd_wires_spikes_and_other_families_do_not() {
        let s8 = ScenarioCfg::new(Scenario::S8FlashCrowd, Model::ResNet101, 8, 2, 5);
        let cfg8 = FleetCfg::new(s8, ChurnCfg::stationary(8), Policy::Incremental);
        assert!(!cfg8.flash.is_none(), "s8-flash-crowd defaults to arrival spikes");
        assert!(cfg8.helper_churn.is_none(), "s8 stresses arrivals, not helper faults");
        assert!(cfg8.transport.is_dedicated(), "transport stays opt-in");
        let s1 = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 8, 2, 5);
        let cfg1 = FleetCfg::new(s1, ChurnCfg::stationary(8), Policy::Incremental);
        assert!(cfg1.flash.is_none());
    }

    #[test]
    fn s8_flash_crowd_run_is_deterministic_and_surges() {
        let scen = ScenarioCfg::new(Scenario::S8FlashCrowd, Model::ResNet101, 8, 2, 11);
        let mut churn = ChurnCfg::stationary(8);
        churn.rounds = 12;
        let mk = || FleetCfg::new(scen.clone(), churn.clone(), Policy::Incremental);
        let a = run(&mk());
        let b = run(&mk());
        assert_eq!(a.to_json().pretty(), b.to_json().pretty());
        assert_eq!(a.rounds.len(), 12);
        // Spike rounds admit visibly more arrivals than the stationary
        // rate alone would: some round must beat the calm expectation.
        let max_arrivals = a.rounds.iter().map(|r| r.arrivals).max().unwrap();
        assert!(max_arrivals >= 2, "no arrival surge in a flash-crowd run (max {max_arrivals})");
        for r in &a.rounds {
            assert!(r.n_clients <= churn.max_clients, "round {} over the cap", r.round);
        }
    }

    #[test]
    fn admm_y_guided_repair_places_by_marginal_cost() {
        use crate::instance::Instance;
        // Three clients, two helpers. Clients 0 and 2 are pinned
        // survivors (loads 8 on helper 0, 10 on helper 1); client 1 is
        // the arrival, cheap on helper 1 (p+p' = 4) and expensive on
        // helper 0 (18). The FCFS rule sees only loads (8 < 10) and
        // seats it on helper 0, then needs a rebalance move to undo the
        // mistake; the ADMM-y rule prices the marginal edge
        // (8+18 = 26 vs 10+4 = 14) and seats it right immediately.
        let inst = Instance {
            n_clients: 3,
            n_helpers: 2,
            slot_ms: 100.0,
            r: vec![1; 6],
            l: vec![0; 6],
            lp: vec![0; 6],
            rp: vec![1; 6],
            //       (0,0)(0,1)(0,2)(1,0)(1,1)(1,2)
            p: vec![4, 9, 9, 9, 2, 5],
            pp: vec![4, 9, 9, 9, 2, 5],
            d: vec![1.0, 1.0, 1.0],
            mem: vec![10.0, 10.0],
            mu: vec![4, 4],
            label: "guided".into(),
        };
        let prev: BTreeMap<u64, usize> = [(0u64, 0usize), (2u64, 1usize)].into_iter().collect();
        let mut w = 0u64;
        let fcfs = repair_assignment_guided(&inst, &[0, 1, 2], &prev, &mut w, false).unwrap();
        let mut w2 = 0u64;
        let guided = repair_assignment_guided(&inst, &[0, 1, 2], &prev, &mut w2, true).unwrap();
        assert_eq!(guided.assignment.helper_of[1], 1, "guided placement prices the marginal edge");
        assert_eq!(guided.moves, 0, "no rebalance needed when the warm start prices edges");
        assert!(
            fcfs.moves > 0 || fcfs.assignment.helper_of[1] == 0,
            "FCFS either misplaces the arrival or pays a move to fix it"
        );
        // Survivors never move under either rule.
        for rep in [&fcfs, &guided] {
            assert_eq!(rep.assignment.helper_of[0], 0);
            assert_eq!(rep.assignment.helper_of[2], 1);
        }
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in Policy::ALL {
            assert_eq!(Policy::parse(p.name()), Some(p), "{}", p.name());
        }
        assert_eq!(Policy::parse("nope"), None);
    }

    #[test]
    fn decision_parse_roundtrip() {
        for d in Decision::ALL {
            assert_eq!(Decision::parse(d.name()), Some(d), "{}", d.name());
        }
        assert_eq!(Decision::parse("nope"), None);
    }

    #[test]
    #[should_panic(expected = "helper-less")]
    fn repair_rejects_helper_less_instance_instead_of_full_infeasible() {
        // Pre-fix, i_n == 0 fell out of the rebalance argmax `?` and was
        // reported as a full-infeasible round.
        let scen = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 2, 1, 3);
        let inst = {
            let mut ms = scen.generate();
            ms.n_helpers = 0;
            ms.mem_gb = vec![];
            ms.quantize(100.0)
        };
        let mut work = 0u64;
        let _ = repair_assignment(&inst, &[0, 1], &BTreeMap::new(), &mut work);
    }

    /// Hand-built three-round stream: heavy churn into round 1 (4/6 ≈
    /// 0.67), zero churn into round 2.
    fn auto_stream() -> Vec<RoundEvents> {
        vec![
            RoundEvents::clients(0, vec![], vec![], vec![0, 1, 2, 3, 4, 5]),
            RoundEvents::clients(1, vec![0, 1], vec![6, 7], vec![2, 3, 4, 5, 6, 7]),
            RoundEvents::clients(2, vec![], vec![], vec![2, 3, 4, 5, 6, 7]),
        ]
    }

    fn auto_cfg(scenario: Scenario, table: Option<crate::fleet::policy::PolicyTable>) -> FleetCfg {
        let scen = ScenarioCfg::new(scenario, Model::ResNet101, 6, 2, 5);
        let churn = ChurnCfg { rounds: 3, arrival_rate: 0.0, departure_prob: 0.0, max_clients: 12 };
        let mut cfg = FleetCfg::new(scen, churn, Policy::Auto);
        cfg.policy_table = table;
        // These tests pin the frontier consult; disarm the gap safety net
        // so FCFS-vs-full drift can't turn a repair round into full-gap.
        cfg.gap_threshold = f64::MAX;
        cfg
    }

    #[test]
    fn auto_policy_goes_full_past_the_table_frontier_and_repairs_below() {
        use crate::fleet::policy::{PolicyEntry, PolicyTable};
        let table = PolicyTable::new(
            "test".into(),
            vec![PolicyEntry {
                scenario: "scenario1".into(),
                n_clients: 6,
                n_helpers: 2,
                helper_down_rate: 0.0,
                uplink_capacity: 0.0,
                frontier_churn: Some(0.25),
            }],
        );
        let cfg = auto_cfg(Scenario::S1, Some(table));
        let world = cfg.scenario.fleet_world(12);
        let r = run_on_stream(&cfg, &world, &auto_stream());
        assert_eq!(r.rounds[0].decision, "full-initial");
        assert_eq!(r.rounds[1].decision, "full-auto", "churn 0.67 >= frontier 0.25");
        assert_eq!(r.rounds[2].decision, "repair", "churn 0 < frontier 0.25");
        assert_eq!(r.policy, "auto");
    }

    #[test]
    fn auto_policy_open_frontier_never_fulls_on_churn() {
        use crate::fleet::policy::{PolicyEntry, PolicyTable};
        // frontier None = incremental won at every measured rate.
        let table = PolicyTable::new(
            "test".into(),
            vec![PolicyEntry {
                scenario: "scenario1".into(),
                n_clients: 6,
                n_helpers: 2,
                helper_down_rate: 0.0,
                uplink_capacity: 0.0,
                frontier_churn: None,
            }],
        );
        let cfg = auto_cfg(Scenario::S1, Some(table));
        let world = cfg.scenario.fleet_world(12);
        let r = run_on_stream(&cfg, &world, &auto_stream());
        for x in r.rounds.iter().skip(1) {
            assert_eq!(x.decision, "repair", "round {}: {}", x.round, x.decision);
        }
    }

    #[test]
    fn auto_policy_unknown_family_falls_back_to_static_threshold_as_full_churn() {
        use crate::fleet::policy::{PolicyEntry, PolicyTable};
        // Table knows only scenario2 → scenario1 rounds fall back to the
        // static churn_threshold (0.35 < 0.67 → full), recorded as
        // full-churn (NOT full-auto: no measured frontier fired).
        let table = PolicyTable::new(
            "test".into(),
            vec![PolicyEntry {
                scenario: "scenario2".into(),
                n_clients: 6,
                n_helpers: 2,
                helper_down_rate: 0.0,
                uplink_capacity: 0.0,
                frontier_churn: Some(0.9),
            }],
        );
        let cfg = auto_cfg(Scenario::S1, Some(table));
        let world = cfg.scenario.fleet_world(12);
        let r = run_on_stream(&cfg, &world, &auto_stream());
        assert_eq!(r.rounds[1].decision, "full-churn");
        assert_eq!(r.rounds[2].decision, "repair");
    }

    #[test]
    fn auto_policy_defaults_to_builtin_table() {
        // s4-straggler-tail is in the builtin table with frontier 0.3
        // (observed-fraction units): the heavy-churn round goes full
        // without any table configured.
        let cfg = auto_cfg(Scenario::S4StragglerTail, None);
        let world = cfg.scenario.fleet_world(12);
        let r = run_on_stream(&cfg, &world, &auto_stream());
        assert_eq!(r.rounds[1].decision, "full-auto", "builtin frontier 0.3 < churn 0.67");
        assert_eq!(r.rounds[2].decision, "repair");
    }

    #[test]
    fn auto_runs_are_deterministic() {
        let mk = || {
            let scen = ScenarioCfg::new(Scenario::S4StragglerTail, Model::Vgg19, 10, 3, 7);
            let mut churn = ChurnCfg::stationary(10);
            churn.rounds = 8;
            FleetCfg::new(scen, churn, Policy::Auto)
        };
        let a = run(&mk());
        let b = run(&mk());
        assert_eq!(a.to_json().pretty(), b.to_json().pretty(), "same seed + table → byte-identical report");
    }
}
