//! Fleet-session checkpoints: the [`FleetSession`] warm state as a
//! schema-checked `psl-fleet-checkpoint` artifact.
//!
//! A checkpoint stores the full run config (enough to rebuild the
//! [`FleetWorld`] and regenerate the event stream, helper-churn knobs
//! included), the warm state the next round's decision depends on
//! (`prev_assign`, `prev_roster_len`, `last_full_gap`, the helper roster
//! — live ids, in-outage ids, and the never-reused id watermark — and the
//! round cursor), and the completed [`RoundReport`]s so a resumed run
//! replays its sidecar and finishes with the byte-identical final
//! report, including across a `helper_down`/`helper_up` boundary. Minted
//! clients and helpers are deliberately *not* stored — they are a pure
//! function of `(scenario tuple, id)` and re-mint on resume — so the
//! checkpoint stays O(max_clients + max_helpers + completed rounds).
//!
//! Only the named scenario families round-trip: a custom
//! [`ScenarioSpec`](crate::instance::scenario::ScenarioSpec) composition
//! cannot be reconstructed from its name alone, and loading such a
//! checkpoint fails with a clear error instead of silently re-deriving a
//! different world.
//!
//! [`FleetSession`]: super::session::FleetSession
//! [`FleetWorld`]: crate::instance::scenario::FleetWorld

use super::events::ChurnCfg;
use super::orchestrator::{FleetCfg, Policy};
use super::policy::PolicyTable;
use super::report::RoundReport;
use crate::bench::artifact::{self, ArtifactKind};
use crate::instance::profiles::Model;
use crate::instance::scenario::{Scenario, ScenarioCfg};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// A paused fleet session (see module docs).
#[derive(Clone, Debug)]
pub struct FleetCheckpoint {
    pub cfg: FleetCfg,
    /// Roster cap the world's memory repair was sized for (the session
    /// may have been built over a world wider than `cfg.churn.max_clients`).
    pub world_max_clients: usize,
    /// Round the next `step` must carry (`== rounds.len()`).
    pub next_round: usize,
    pub prev_roster_len: usize,
    /// Drift baseline (`f64::MAX` sentinel = no full solve yet).
    pub last_full_gap: f64,
    /// Previous round's kept assignment: stable client id → helper *id*
    /// (== position for base helpers, so static worlds are unchanged).
    pub prev_assign: BTreeMap<u64, usize>,
    /// Helper ids live when the snapshot landed (sorted).
    pub helpers_live: Vec<u64>,
    /// Helper ids in an outage when the snapshot landed (sorted). Their
    /// return rounds are *not* stored: the regenerated event stream (or
    /// the external serve feed) carries the `helper_up` events.
    pub helpers_down: Vec<u64>,
    /// Never-reused helper-id watermark (joins mint from here).
    pub helper_next_id: u64,
    /// §VII method the most recent full solve routed to (`None` before
    /// the first full round). The ADMM-y repair warm start keys off this,
    /// so it must survive a pause. Serialized only when `Some`, keeping
    /// pre-transport checkpoints byte-identical; absent reads back as
    /// `None` (lenient, unlike the v5 helper-dynamics hard gate).
    pub last_full_method: Option<&'static str>,
    /// Completed rounds, in order.
    pub rounds: Vec<RoundReport>,
}

/// Non-finite knobs (`--gap-threshold inf`, disarmed thresholds in
/// tests) have no JSON literal; `null` stands in and reads back as
/// `f64::INFINITY`. `f64::MAX` is finite and round-trips as a number.
fn finite_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

fn f64_or_inf(v: &Json, what: &str) -> Result<f64> {
    match v {
        Json::Null => Ok(f64::INFINITY),
        _ => v.as_f64().with_context(|| format!("checkpoint: bad {what}")),
    }
}

impl FleetCheckpoint {
    pub fn to_json(&self) -> Json {
        let scen = &self.cfg.scenario;
        let mut config_fields = vec![
            ("scenario", Json::Str(scen.spec.name.clone())),
            ("model", Json::Str(scen.model.name().to_string())),
            ("n_clients", Json::Num(scen.n_clients as f64)),
            ("n_helpers", Json::Num(scen.n_helpers as f64)),
            // String, not Num: u64 seeds can exceed 2^53.
            ("seed", Json::Str(scen.seed.to_string())),
            ("wire_factor", Json::Num(scen.wire_factor)),
            ("switch_cost_ms", Json::Num(scen.switch_cost_ms)),
            ("slot_ms", self.cfg.slot_ms.map(Json::Num).unwrap_or(Json::Null)),
            ("rounds", Json::Num(self.cfg.churn.rounds as f64)),
            ("arrival_rate", Json::Num(self.cfg.churn.arrival_rate)),
            ("departure_prob", Json::Num(self.cfg.churn.departure_prob)),
            ("max_clients", Json::Num(self.cfg.churn.max_clients as f64)),
            ("policy", Json::Str(self.cfg.policy.name().to_string())),
            ("churn_threshold", finite_or_null(self.cfg.churn_threshold)),
            ("gap_threshold", finite_or_null(self.cfg.gap_threshold)),
            ("epoch_batches", Json::Num(self.cfg.epoch_batches as f64)),
            ("helper_down_rate", Json::Num(self.cfg.helper_churn.down_rate)),
            ("helper_outage_rounds", Json::Num(self.cfg.helper_churn.outage_rounds as f64)),
            ("helper_join_rate", Json::Num(self.cfg.helper_churn.join_rate)),
            ("max_helpers", Json::Num(self.cfg.helper_churn.max_helpers as f64)),
            ("diurnal_period", Json::Num(self.cfg.helper_churn.diurnal_period as f64)),
            ("capacity_threshold", Json::Num(self.cfg.capacity_threshold)),
            (
                "policy_table",
                self.cfg.policy_table.as_ref().map(|t| t.to_json()).unwrap_or(Json::Null),
            ),
            ("world_max_clients", Json::Num(self.world_max_clients as f64)),
        ];
        // Transport config is emitted only when non-default so dedicated
        // checkpoints keep their historical bytes.
        if !self.cfg.transport.is_dedicated() {
            config_fields.push(("link_model", Json::Str(self.cfg.transport.mode.name().to_string())));
            config_fields.push(("uplink_capacity", Json::Num(self.cfg.transport.capacity)));
        }
        let config = Json::obj(config_fields);
        let mut state_fields = vec![
            ("next_round", Json::Num(self.next_round as f64)),
            ("prev_roster_len", Json::Num(self.prev_roster_len as f64)),
            ("last_full_gap", Json::Num(self.last_full_gap)),
            (
                "prev_assign",
                Json::Arr(
                    self.prev_assign
                        .iter()
                        .map(|(&id, &h)| Json::Arr(vec![Json::Num(id as f64), Json::Num(h as f64)]))
                        .collect(),
                ),
            ),
            (
                "helpers_live",
                Json::Arr(self.helpers_live.iter().map(|&h| Json::Num(h as f64)).collect()),
            ),
            (
                "helpers_down",
                Json::Arr(self.helpers_down.iter().map(|&h| Json::Num(h as f64)).collect()),
            ),
            ("helper_next_id", Json::Num(self.helper_next_id as f64)),
        ];
        if let Some(m) = self.last_full_method {
            state_fields.push(("last_full_method", Json::Str(m.to_string())));
        }
        let state = Json::obj(state_fields);
        artifact::envelope(ArtifactKind::FleetCheckpoint, vec![
            ("config", config),
            ("state", state),
            ("rounds", Json::Arr(self.rounds.iter().map(|r| r.to_json()).collect())),
        ])
    }

    pub fn from_json(doc: &Json) -> Result<FleetCheckpoint> {
        artifact::expect_kind(doc, ArtifactKind::FleetCheckpoint)?;
        let c = doc.get("config");
        c.as_obj().context("checkpoint: missing config object")?;
        let num = |v: &Json, what: &str| -> Result<f64> {
            v.as_f64().with_context(|| format!("checkpoint: bad {what}"))
        };
        let int = |v: &Json, what: &str| -> Result<usize> {
            v.as_usize().with_context(|| format!("checkpoint: bad {what}"))
        };
        // The helper-dynamics fields arrived with schema v5: a checkpoint
        // without them cannot restore the helper roster, so fail with the
        // registry's standard advice instead of inventing state.
        let required = |v: &Json, what: &str| -> Result<&Json> {
            match v {
                Json::Null => anyhow::bail!(
                    "checkpoint: no {what:?} — this artifact predates schema v{} helper \
                     dynamics; re-generate it with this build",
                    artifact::SCHEMA_VERSION
                ),
                v => Ok(v),
            }
        };
        let helper_ids = |v: &Json, what: &str| -> Result<Vec<u64>> {
            let arr = required(v, what)?
                .as_arr()
                .with_context(|| format!("checkpoint: bad {what}"))?;
            let mut out = Vec::with_capacity(arr.len());
            for x in arr {
                let f = num(x, what)?;
                anyhow::ensure!(
                    f >= 0.0 && f.fract() == 0.0,
                    "checkpoint: bad helper id {f} in {what}"
                );
                out.push(f as u64);
            }
            Ok(out)
        };
        let scenario_name = c.get("scenario").as_str().context("checkpoint: bad scenario")?;
        let scenario = Scenario::parse(scenario_name).with_context(|| {
            format!(
                "checkpoint scenario {scenario_name:?} is not a named family — \
                 custom ScenarioSpec compositions are not checkpointable"
            )
        })?;
        let model_name = c.get("model").as_str().context("checkpoint: bad model")?;
        let model = Model::parse(model_name).with_context(|| format!("checkpoint: unknown model {model_name:?}"))?;
        let seed_str = c.get("seed").as_str().context("checkpoint: bad seed")?;
        let seed: u64 = seed_str.parse().with_context(|| format!("checkpoint: bad seed {seed_str:?}"))?;
        let mut scen = ScenarioCfg::new(
            scenario,
            model,
            int(c.get("n_clients"), "n_clients")?,
            int(c.get("n_helpers"), "n_helpers")?,
            seed,
        );
        scen.wire_factor = num(c.get("wire_factor"), "wire_factor")?;
        scen.switch_cost_ms = num(c.get("switch_cost_ms"), "switch_cost_ms")?;
        let churn = ChurnCfg {
            rounds: int(c.get("rounds"), "rounds")?,
            arrival_rate: num(c.get("arrival_rate"), "arrival_rate")?,
            departure_prob: num(c.get("departure_prob"), "departure_prob")?,
            max_clients: int(c.get("max_clients"), "max_clients")?,
        };
        let policy_name = c.get("policy").as_str().context("checkpoint: bad policy")?;
        let policy =
            Policy::parse(policy_name).with_context(|| format!("checkpoint: unknown policy {policy_name:?}"))?;
        let mut cfg = FleetCfg::new(scen, churn, policy);
        cfg.slot_ms = match c.get("slot_ms") {
            Json::Null => None,
            v => Some(num(v, "slot_ms")?),
        };
        cfg.churn_threshold = f64_or_inf(c.get("churn_threshold"), "churn_threshold")?;
        cfg.gap_threshold = f64_or_inf(c.get("gap_threshold"), "gap_threshold")?;
        cfg.epoch_batches = int(c.get("epoch_batches"), "epoch_batches")?;
        cfg.policy_table = match c.get("policy_table") {
            Json::Null => None,
            v => Some(PolicyTable::from_json(v).context("checkpoint: bad policy_table")?),
        };
        cfg.helper_churn.down_rate =
            num(required(c.get("helper_down_rate"), "helper_down_rate")?, "helper_down_rate")?;
        cfg.helper_churn.outage_rounds = int(
            required(c.get("helper_outage_rounds"), "helper_outage_rounds")?,
            "helper_outage_rounds",
        )?;
        cfg.helper_churn.join_rate =
            num(required(c.get("helper_join_rate"), "helper_join_rate")?, "helper_join_rate")?;
        cfg.helper_churn.max_helpers =
            int(required(c.get("max_helpers"), "max_helpers")?, "max_helpers")?;
        cfg.helper_churn.diurnal_period =
            int(required(c.get("diurnal_period"), "diurnal_period")?, "diurnal_period")?;
        cfg.capacity_threshold = num(
            required(c.get("capacity_threshold"), "capacity_threshold")?,
            "capacity_threshold",
        )?;
        // Transport config is lenient (absent → dedicated): it is emitted
        // only when non-default, so pre-transport checkpoints stay
        // loadable.
        cfg.transport = match c.get("link_model") {
            Json::Null => crate::transport::TransportCfg::dedicated(),
            v => {
                let name = v.as_str().context("checkpoint: bad link_model")?;
                let mode = crate::transport::LinkMode::parse(name)
                    .with_context(|| format!("checkpoint: unknown link_model {name:?}"))?;
                match mode {
                    crate::transport::LinkMode::Dedicated => crate::transport::TransportCfg::dedicated(),
                    crate::transport::LinkMode::Shared => {
                        let cap = match c.get("uplink_capacity") {
                            Json::Null => crate::transport::DEFAULT_UPLINK_CAPACITY,
                            v => num(v, "uplink_capacity")?,
                        };
                        anyhow::ensure!(
                            cap.is_finite() && cap > 0.0,
                            "checkpoint: bad uplink_capacity {cap}"
                        );
                        crate::transport::TransportCfg::shared(cap)
                    }
                }
            }
        };
        let world_max_clients = int(c.get("world_max_clients"), "world_max_clients")?;

        let s = doc.get("state");
        s.as_obj().context("checkpoint: missing state object")?;
        let next_round = int(s.get("next_round"), "next_round")?;
        let prev_roster_len = int(s.get("prev_roster_len"), "prev_roster_len")?;
        let last_full_gap = num(s.get("last_full_gap"), "last_full_gap")?;
        let mut prev_assign = BTreeMap::new();
        for pair in s.get("prev_assign").as_arr().context("checkpoint: bad prev_assign")? {
            let pair = pair.as_arr().context("checkpoint: prev_assign entry is not a pair")?;
            anyhow::ensure!(pair.len() == 2, "checkpoint: prev_assign entry is not an [id, helper] pair");
            let id = num(&pair[0], "prev_assign id")?;
            anyhow::ensure!(id >= 0.0 && id.fract() == 0.0, "checkpoint: bad client id {id}");
            let helper = int(&pair[1], "prev_assign helper")?;
            anyhow::ensure!(
                prev_assign.insert(id as u64, helper).is_none(),
                "checkpoint: duplicate client id {id} in prev_assign"
            );
        }
        let helpers_live = helper_ids(s.get("helpers_live"), "helpers_live")?;
        let helpers_down = helper_ids(s.get("helpers_down"), "helpers_down")?;
        let next_id_f = num(required(s.get("helper_next_id"), "helper_next_id")?, "helper_next_id")?;
        anyhow::ensure!(
            next_id_f >= 0.0 && next_id_f.fract() == 0.0,
            "checkpoint: bad helper_next_id {next_id_f}"
        );
        let helper_next_id = next_id_f as u64;
        let last_full_method = match s.get("last_full_method") {
            Json::Null => None,
            v => {
                let name = v.as_str().context("checkpoint: bad last_full_method")?;
                Some(
                    crate::solver::strategy::Method::parse(name)
                        .with_context(|| format!("checkpoint: unknown last_full_method {name:?}"))?
                        .name(),
                )
            }
        };
        let rounds = doc
            .get("rounds")
            .as_arr()
            .context("checkpoint: missing rounds array")?
            .iter()
            .map(RoundReport::from_json)
            .collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(
            next_round == rounds.len(),
            "checkpoint cursor (round {next_round}) does not match its {} completed rounds",
            rounds.len()
        );
        anyhow::ensure!(
            prev_assign.len() == prev_roster_len,
            "checkpoint roster ({} assigned) does not match prev_roster_len {prev_roster_len}",
            prev_assign.len()
        );
        Ok(FleetCheckpoint {
            cfg,
            world_max_clients,
            next_round,
            prev_roster_len,
            last_full_gap,
            prev_assign,
            helpers_live,
            helpers_down,
            helper_next_id,
            last_full_method,
            rounds,
        })
    }

    /// Load from a file path (envelope-checked like every artifact).
    pub fn load(path: &str) -> Result<FleetCheckpoint> {
        let doc = artifact::load_expecting(path, ArtifactKind::FleetCheckpoint)?;
        FleetCheckpoint::from_json(&doc).with_context(|| format!("load {path}"))
    }

    /// Persist under `target/psl-bench/<name>.json`. Returns the path.
    pub fn save(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        artifact::save(name, &self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::session::FleetSession;
    use crate::instance::profiles::Model;

    fn session_cfg() -> FleetCfg {
        let scen = ScenarioCfg::new(Scenario::S4StragglerTail, Model::Vgg19, 6, 2, 11);
        let mut churn = ChurnCfg::stationary(6);
        churn.rounds = 6;
        FleetCfg::new(scen, churn, Policy::Incremental)
    }

    fn mid_run_checkpoint() -> FleetCheckpoint {
        let mut session = FleetSession::new(session_cfg());
        let stream = session.event_stream();
        for ev in &stream[..3] {
            session.step(ev);
        }
        session.checkpoint()
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let ckpt = mid_run_checkpoint();
        let doc = ckpt.to_json();
        assert_eq!(doc.get("kind").as_str(), Some("psl-fleet-checkpoint"));
        let text = doc.pretty();
        let back = FleetCheckpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().pretty(), text, "checkpoint JSON is a fixed point");
        assert_eq!(back.next_round, 3);
        assert_eq!(back.prev_assign, ckpt.prev_assign);
        assert_eq!(back.rounds, ckpt.rounds);
    }

    #[test]
    fn resume_after_roundtrip_matches_straight_run(){
        let straight = crate::fleet::orchestrator::run(&session_cfg());
        let text = mid_run_checkpoint().to_json().pretty();
        let ckpt = FleetCheckpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
        let mut session = FleetSession::resume(ckpt).unwrap();
        let stream = session.event_stream();
        for ev in &stream[session.next_round()..] {
            session.step(ev);
        }
        assert_eq!(session.into_report().to_json().pretty(), straight.to_json().pretty());
    }

    #[test]
    fn non_finite_thresholds_serialize_as_null() {
        let mut ckpt = mid_run_checkpoint();
        ckpt.cfg.gap_threshold = f64::INFINITY;
        let doc = ckpt.to_json();
        assert_eq!(doc.get("config").get("gap_threshold"), &Json::Null);
        let back = FleetCheckpoint::from_json(&doc).unwrap();
        assert!(back.cfg.gap_threshold.is_infinite());
        // f64::MAX (the untouched last_full_gap sentinel) stays a number.
        assert!(doc.get("state").get("last_full_gap").as_f64().is_some());
    }

    #[test]
    fn rejects_wrong_kind_and_inconsistent_state() {
        let fleet_doc = crate::fleet::orchestrator::run(&session_cfg()).to_json();
        let err = FleetCheckpoint::from_json(&fleet_doc).unwrap_err().to_string();
        assert!(err.contains("psl-fleet-checkpoint"), "{err}");

        let ckpt = mid_run_checkpoint();
        let mut doc = ckpt.to_json();
        if let Json::Obj(obj) = &mut doc {
            if let Some(Json::Obj(state)) = obj.get_mut("state") {
                state.insert("next_round".into(), Json::Num(99.0));
            }
        }
        let err = FleetCheckpoint::from_json(&doc).unwrap_err().to_string();
        assert!(err.contains("completed rounds"), "{err}");
    }

    #[test]
    fn custom_specs_are_not_checkpointable() {
        let mut ckpt = mid_run_checkpoint();
        ckpt.cfg.scenario.spec.name = "my-custom-mix".to_string();
        let err = FleetCheckpoint::from_json(&ckpt.to_json()).unwrap_err().to_string();
        assert!(err.contains("not checkpointable") || err.contains("my-custom-mix"), "{err}");
    }

    #[test]
    fn helper_state_roundtrips_exactly() {
        let mut ckpt = mid_run_checkpoint();
        assert_eq!(ckpt.helpers_live, vec![0, 1], "static worlds snapshot the base roster");
        assert_eq!(ckpt.helper_next_id, 2);
        // Forge a mid-outage snapshot of a dynamic world (3 helpers, one
        // dark, one joined) and check the state survives the JSON trip.
        ckpt.cfg.helper_churn.max_helpers = 6;
        ckpt.cfg.helper_churn.down_rate = 0.25;
        ckpt.helpers_live = vec![0, 2];
        ckpt.helpers_down = vec![1];
        ckpt.helper_next_id = 3;
        let text = ckpt.to_json().pretty();
        let back = FleetCheckpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.helpers_live, vec![0, 2]);
        assert_eq!(back.helpers_down, vec![1]);
        assert_eq!(back.helper_next_id, 3);
        assert_eq!(back.cfg.helper_churn.down_rate, 0.25);
        assert_eq!(back.to_json().pretty(), text, "helper state is a JSON fixed point");
    }

    #[test]
    fn pre_v5_checkpoints_are_rejected_with_advice() {
        let ckpt = mid_run_checkpoint();
        for (section, key) in [
            ("state", "helpers_live"),
            ("state", "helpers_down"),
            ("state", "helper_next_id"),
            ("config", "helper_down_rate"),
            ("config", "capacity_threshold"),
        ] {
            let mut doc = ckpt.to_json();
            if let Json::Obj(obj) = &mut doc {
                if let Some(Json::Obj(sec)) = obj.get_mut(section) {
                    sec.remove(key);
                }
            }
            let err = FleetCheckpoint::from_json(&doc).unwrap_err().to_string();
            assert!(err.contains("re-generate"), "{section}.{key}: {err}");
        }
    }

    #[test]
    fn transport_config_is_emitted_only_when_shared() {
        // Dedicated checkpoints keep the historical key set.
        let ded = mid_run_checkpoint();
        let text = ded.to_json().pretty();
        assert!(!text.contains("link_model"), "dedicated checkpoints omit transport keys");
        assert!(!text.contains("uplink_capacity"));
        let back = FleetCheckpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert!(back.cfg.transport.is_dedicated(), "absent link_model reads back as dedicated");
        // A shared-uplink checkpoint round-trips its pool capacity and is
        // a JSON fixed point.
        let mut cfg = session_cfg();
        cfg.transport = crate::transport::TransportCfg::shared(2.5);
        let mut session = FleetSession::new(cfg);
        let stream = session.event_stream();
        for ev in &stream[..3] {
            session.step(ev);
        }
        let doc = session.checkpoint().to_json();
        assert_eq!(doc.get("config").get("link_model").as_str(), Some("shared"));
        assert_eq!(doc.get("config").get("uplink_capacity").as_f64(), Some(2.5));
        let text = doc.pretty();
        let back = FleetCheckpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert!(!back.cfg.transport.is_dedicated());
        assert_eq!(back.cfg.transport.capacity, 2.5);
        assert_eq!(back.to_json().pretty(), text, "shared transport is a JSON fixed point");
    }

    #[test]
    fn last_full_method_rides_along_and_is_lenient() {
        let ckpt = mid_run_checkpoint();
        // This fleet ran a full solve by round 3, so the warm-start key
        // is populated and survives the JSON trip.
        let method = ckpt.last_full_method.expect("round 0 is always a full solve");
        let text = ckpt.to_json().pretty();
        let back = FleetCheckpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.last_full_method, Some(method));
        // Absent (pre-transport checkpoints) reads back as None...
        let mut doc = ckpt.to_json();
        if let Json::Obj(obj) = &mut doc {
            if let Some(Json::Obj(state)) = obj.get_mut("state") {
                state.remove("last_full_method");
            }
        }
        assert_eq!(FleetCheckpoint::from_json(&doc).unwrap().last_full_method, None);
        // ...but an unknown method name is rejected, not interned.
        if let Json::Obj(obj) = &mut doc {
            if let Some(Json::Obj(state)) = obj.get_mut("state") {
                state.insert("last_full_method".into(), Json::Str("oracle".into()));
            }
        }
        assert!(FleetCheckpoint::from_json(&doc).is_err());
    }

    #[test]
    fn policy_table_rides_along() {
        let mut cfg = session_cfg();
        cfg.policy = Policy::Auto;
        cfg.policy_table = Some(PolicyTable::builtin());
        let mut session = FleetSession::new(cfg);
        let stream = session.event_stream();
        for ev in &stream[..2] {
            session.step(ev);
        }
        let doc = session.checkpoint().to_json();
        let back = FleetCheckpoint::from_json(&doc).unwrap();
        assert_eq!(back.cfg.policy_table, Some(PolicyTable::builtin()));
    }
}
