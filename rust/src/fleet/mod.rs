//! Online fleet orchestration: multi-round churn simulation with
//! incremental warm-started re-solving.
//!
//! The paper optimizes a *single batch*'s makespan; §III notes training
//! repeats that workflow hundreds of times over a fleet whose membership
//! shifts. This subsystem closes the loop: a seeded, deterministic
//! multi-round run where clients arrive and depart between rounds
//! ([`events`]), the orchestrator re-solves each round *incrementally* —
//! warm-started repair of the previous round's assignment with a
//! drift-triggered full re-solve fallback ([`orchestrator`]) — and every
//! round's decision, cost proxy, makespan and epoch-pipelined period is
//! recorded in a deterministic JSON report ([`report`]).
//!
//! | Module | Role |
//! |---|---|
//! | [`events`] | seeded arrival/departure stream, stable client ids, roster cap |
//! | [`orchestrator`] | round loop, warm-start repair, churn/gap fallback policy |
//! | [`policy`] | measured churn-frontier [`PolicyTable`] behind the `auto` policy |
//! | [`report`] | per-round + summary JSON under `target/psl-bench/` |
//!
//! Clients are minted by the
//! [`FleetWorld`](crate::instance::scenario::FleetWorld) factory from the
//! scenario's `DeviceMix`/`LinkRegime`, so arrivals follow the same
//! distributions as the base population and every client reproduces from
//! `(scenario tuple, id)` alone. The `psl fleet` subcommand drives a
//! single run — streaming each finished round as a JSONL line next to the
//! final JSON via [`orchestrator::run_streaming`] — while
//! [`crate::bench::fleet`] fans a scenario × churn-rate × policy grid
//! across worker threads like `psl sweep`.

pub mod events;
pub mod orchestrator;
pub mod policy;
pub mod report;

pub use events::{ChurnCfg, RoundEvents};
pub use orchestrator::{run, run_streaming, Decision, FleetCfg, Policy};
pub use policy::{PolicyEntry, PolicyTable};
pub use report::{FleetReport, RoundReport};
