//! Online fleet orchestration: multi-round churn simulation with
//! incremental warm-started re-solving.
//!
//! The paper optimizes a *single batch*'s makespan; §III notes training
//! repeats that workflow hundreds of times over a fleet whose membership
//! shifts. This subsystem closes the loop: a seeded, deterministic
//! multi-round run where clients arrive and depart between rounds
//! ([`events`]), the orchestrator re-solves each round *incrementally* —
//! warm-started repair of the previous round's assignment with a
//! drift-triggered full re-solve fallback ([`orchestrator`]) — and every
//! round's decision, cost proxy, makespan and epoch-pipelined period is
//! recorded in a deterministic JSON report ([`report`]).
//!
//! | Module | Role |
//! |---|---|
//! | [`events`] | seeded arrival/departure stream, stable client ids, roster cap |
//! | [`session`] | the round loop as a stepwise, resumable [`FleetSession`] state machine |
//! | [`orchestrator`] | policy/repair decision logic + batch drivers over the session |
//! | [`checkpoint`] | session warm state as a `psl-fleet-checkpoint` artifact |
//! | [`serve`] | stdin/stdout JSONL decision service (`psl serve`) |
//! | [`policy`] | measured churn-frontier [`PolicyTable`] behind the `auto` policy |
//! | [`report`] | per-round + summary JSON under `target/psl-bench/` |
//!
//! Clients are minted by the
//! [`FleetWorld`](crate::instance::scenario::FleetWorld) factory from the
//! scenario's `DeviceMix`/`LinkRegime`, so arrivals follow the same
//! distributions as the base population and every client reproduces from
//! `(scenario tuple, id)` alone. The `psl fleet` subcommand drives a
//! [`FleetSession`] round by round — streaming each finished round as a
//! JSONL line next to the final JSON, snapshotting with
//! `--checkpoint-every` and continuing byte-identically with `--resume` —
//! `psl serve` ([`serve`]) feeds the same session from external event
//! lines, and [`crate::bench::fleet`] fans a scenario × churn-rate ×
//! policy grid across worker threads like `psl sweep`
//! (library callers can still use the one-shot
//! [`orchestrator::run`]/[`orchestrator::run_streaming`] drivers).

pub mod checkpoint;
pub mod events;
pub mod orchestrator;
pub mod policy;
pub mod report;
pub mod serve;
pub mod session;

pub use checkpoint::FleetCheckpoint;
pub use events::{ChurnCfg, HelperChurnCfg, HelperRoster, RoundEvents};
pub use orchestrator::{run, run_streaming, Decision, FleetCfg, Policy};
pub use policy::{PolicyEntry, PolicyTable};
pub use report::{FleetReport, RoundReport};
pub use serve::{serve, ServeOpts, ServeSummary};
pub use session::FleetSession;
