//! The data-driven `auto` re-solve policy: a [`PolicyTable`] maps
//! (scenario family, fleet size) to the **churn-rate frontier** where a
//! full re-solve overtakes incremental repair, and the orchestrator
//! consults it per round instead of a hard-coded churn threshold.
//!
//! Tables are *measured*, not designed: [`crate::analyze`] computes them
//! from a `psl fleet --grid` artifact by finding, per family × size, the
//! lowest grid churn rate at which the `full` arm's work-discounted
//! makespan beats the `incremental` arm's (the §VII strategy rule,
//! rebuilt empirically at the fleet layer). A [`builtin`](PolicyTable::builtin)
//! table derived from the default grid ships with the binary so
//! `psl fleet --policy auto` works out of the box; `--policy-table PATH`
//! swaps in a freshly measured one.
//!
//! Serialization uses the artifact registry
//! ([`crate::bench::artifact`], kind `psl-policy-table`), so the table
//! `psl analyze` writes is byte-stable and directly loadable here.

use crate::bench::artifact::{self, ArtifactKind};
use crate::util::json::Json;
use anyhow::{Context, Result};

/// One measured regime: for this scenario family at this fleet size,
/// full re-solving starts winning at `frontier_churn`.
#[derive(Clone, Debug, PartialEq)]
pub struct PolicyEntry {
    /// Scenario family name (`Scenario::name`, or a custom spec's name).
    pub scenario: String,
    /// Base fleet size of the measured grid cell.
    pub n_clients: usize,
    pub n_helpers: usize,
    /// *Observed* per-round churn fraction (membership delta over the
    /// previous roster — the orchestrator's `churn_frac` signal, ≈ 2×
    /// the grid's stationary rate axis) at/above which a full re-solve
    /// wins. `None` = incremental won at every measured churn rate
    /// (never trigger full from churn alone; the gap safety net still
    /// applies).
    pub frontier_churn: Option<f64>,
    /// Helper outage rate of the measured grid cell (the `psl fleet
    /// --grid --helper-down-rates` axis). 0.0 = a static helper pool —
    /// the pre-v5 measurement, serialized without the key so older
    /// tables load unchanged and new zero-rate tables stay byte-stable.
    pub helper_down_rate: f64,
    /// Shared-uplink pool capacity of the measured grid cell (the
    /// `psl fleet --grid --uplink-capacities` axis). 0.0 = the dedicated
    /// transport — the pre-v7 measurement, serialized without the key
    /// (same byte-stability rule as `helper_down_rate`).
    pub uplink_capacity: f64,
}

/// The serialized policy frontier consumed by `Policy::Auto`.
#[derive(Clone, Debug, PartialEq)]
pub struct PolicyTable {
    /// Provenance label — "builtin" or the grid artifact it was computed
    /// from. Informational only; never enters decisions.
    pub source: String,
    /// Sorted by (scenario, n_clients, n_helpers, helper_down_rate,
    /// uplink_capacity) for determinism.
    pub entries: Vec<PolicyEntry>,
}

impl PolicyTable {
    pub fn new(source: String, mut entries: Vec<PolicyEntry>) -> PolicyTable {
        entries.sort_by(|a, b| {
            (&a.scenario, a.n_clients, a.n_helpers)
                .cmp(&(&b.scenario, b.n_clients, b.n_helpers))
                .then(a.helper_down_rate.total_cmp(&b.helper_down_rate))
                .then(a.uplink_capacity.total_cmp(&b.uplink_capacity))
        });
        PolicyTable { source, entries }
    }

    /// The table shipped with the binary, covering the default
    /// `psl fleet --grid` axes (scenario1 / s4-straggler-tail at 10×2,
    /// churn rates 0.05 / 0.15 / 0.3 — observed per-round fractions ≈
    /// 0.1 / 0.3 / 0.6 under the stationary mapping).
    ///
    /// **These values are PROVISIONAL, not measured**: they encode the
    /// expected shape (the low-heterogeneity family's cheap full solves
    /// only pay off at heavy churn; the straggler-tail family's
    /// preemptive full solves win from moderate churn up) but were never
    /// produced by an actual grid run — replace them with the output of
    /// `psl analyze <fleet-grid.json>` on a real multi-seed grid and
    /// update the golden snapshot in `tests/analyze_policy.rs`
    /// (tracked in ROADMAP.md).
    pub fn builtin() -> PolicyTable {
        PolicyTable::new(
            "builtin".to_string(),
            vec![
                PolicyEntry {
                    scenario: "scenario1".to_string(),
                    n_clients: 10,
                    n_helpers: 2,
                    frontier_churn: Some(0.6),
                    helper_down_rate: 0.0,
                    uplink_capacity: 0.0,
                },
                PolicyEntry {
                    scenario: "s4-straggler-tail".to_string(),
                    n_clients: 10,
                    n_helpers: 2,
                    frontier_churn: Some(0.3),
                    helper_down_rate: 0.0,
                    uplink_capacity: 0.0,
                },
            ],
        )
    }

    /// The frontier governing a round: the entry of the same scenario
    /// family whose measured size is closest to the live fleet — client
    /// count first (the axis rosters actually move along), helper count
    /// as the secondary distance, final ties toward the smaller measured
    /// size. Returns `None` when the table has no entry for the family
    /// at all — the orchestrator then falls back to its static churn
    /// threshold (recorded as `full-churn`, not `full-auto`, so analyses
    /// can separate data-driven decisions from the fallback).
    pub fn lookup(&self, scenario: &str, n_clients: usize, n_helpers: usize) -> Option<&PolicyEntry> {
        self.lookup_at(scenario, n_clients, n_helpers, 0.0, 0.0)
    }

    /// [`lookup`](PolicyTable::lookup) with the helper-outage and
    /// uplink-capacity axes: among the family's entries, nearest client
    /// count wins first, then nearest helper count, then nearest measured
    /// `helper_down_rate`, then nearest measured `uplink_capacity`
    /// (0.0 = dedicated — so a dedicated-measured table still governs
    /// shared runs and vice versa), final ties toward the smaller
    /// measurement.
    pub fn lookup_at(
        &self,
        scenario: &str,
        n_clients: usize,
        n_helpers: usize,
        helper_down_rate: f64,
        uplink_capacity: f64,
    ) -> Option<&PolicyEntry> {
        self.entries
            .iter()
            .filter(|e| e.scenario == scenario)
            .min_by(|a, b| {
                let size = |e: &PolicyEntry| {
                    (e.n_clients.abs_diff(n_clients), e.n_helpers.abs_diff(n_helpers))
                };
                let rate_gap = |e: &PolicyEntry| (e.helper_down_rate - helper_down_rate).abs();
                let cap_gap = |e: &PolicyEntry| (e.uplink_capacity - uplink_capacity).abs();
                size(a)
                    .cmp(&size(b))
                    .then(rate_gap(a).total_cmp(&rate_gap(b)))
                    .then(cap_gap(a).total_cmp(&cap_gap(b)))
                    .then(a.n_clients.cmp(&b.n_clients))
                    .then(a.n_helpers.cmp(&b.n_helpers))
                    .then(a.helper_down_rate.total_cmp(&b.helper_down_rate))
                    .then(a.uplink_capacity.total_cmp(&b.uplink_capacity))
            })
    }

    // ---- serialization ---------------------------------------------------

    pub fn to_json(&self) -> Json {
        artifact::envelope(ArtifactKind::PolicyTable, vec![
            ("source", Json::Str(self.source.clone())),
            (
                "entries",
                Json::Arr(
                    self.entries
                        .iter()
                        .map(|e| {
                            let mut pairs = vec![
                                ("scenario", Json::Str(e.scenario.clone())),
                                ("n_clients", Json::Num(e.n_clients as f64)),
                                ("n_helpers", Json::Num(e.n_helpers as f64)),
                                (
                                    "frontier_churn",
                                    e.frontier_churn.map(Json::Num).unwrap_or(Json::Null),
                                ),
                            ];
                            // 0.0 = static pool: omitted, so tables with
                            // no helper axis keep their pre-v5 bytes.
                            if e.helper_down_rate > 0.0 {
                                pairs.push(("helper_down_rate", Json::Num(e.helper_down_rate)));
                            }
                            // 0.0 = dedicated transport: omitted, so
                            // tables with no uplink axis keep their
                            // pre-v7 bytes.
                            if e.uplink_capacity > 0.0 {
                                pairs.push(("uplink_capacity", Json::Num(e.uplink_capacity)));
                            }
                            Json::obj(pairs)
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(doc: &Json) -> Result<PolicyTable> {
        artifact::expect_kind(doc, ArtifactKind::PolicyTable)?;
        let source = doc.get("source").as_str().unwrap_or("unknown").to_string();
        let rows = doc.get("entries").as_arr().context("policy table missing entries[]")?;
        let mut entries = Vec::with_capacity(rows.len());
        for (k, e) in rows.iter().enumerate() {
            // A missing key reads as Null; only frontier_churn may be null.
            let frontier = match e.get("frontier_churn") {
                Json::Null => None,
                v => {
                    let f = v.as_f64().with_context(|| format!("entry {k}: bad frontier_churn {v}"))?;
                    anyhow::ensure!(
                        f.is_finite() && f >= 0.0,
                        "entry {k}: frontier_churn {f} must be finite and >= 0"
                    );
                    Some(f)
                }
            };
            // Absent in pre-v5 tables (and in zero-rate entries) = 0.0.
            let helper_down_rate = match e.get("helper_down_rate") {
                Json::Null => 0.0,
                v => {
                    let f = v
                        .as_f64()
                        .with_context(|| format!("entry {k}: bad helper_down_rate {v}"))?;
                    anyhow::ensure!(
                        f.is_finite() && (0.0..=1.0).contains(&f),
                        "entry {k}: helper_down_rate {f} must be a probability"
                    );
                    f
                }
            };
            // Absent in pre-v7 tables (and in dedicated entries) = 0.0.
            let uplink_capacity = match e.get("uplink_capacity") {
                Json::Null => 0.0,
                v => {
                    let f = v
                        .as_f64()
                        .with_context(|| format!("entry {k}: bad uplink_capacity {v}"))?;
                    anyhow::ensure!(
                        f.is_finite() && f >= 0.0,
                        "entry {k}: uplink_capacity {f} must be finite and >= 0"
                    );
                    f
                }
            };
            entries.push(PolicyEntry {
                scenario: e
                    .get("scenario")
                    .as_str()
                    .with_context(|| format!("entry {k}: missing/bad scenario"))?
                    .to_string(),
                n_clients: e.get("n_clients").as_usize().with_context(|| format!("entry {k}: missing/bad n_clients"))?,
                n_helpers: e.get("n_helpers").as_usize().with_context(|| format!("entry {k}: missing/bad n_helpers"))?,
                frontier_churn: frontier,
                helper_down_rate,
                uplink_capacity,
            });
        }
        Ok(PolicyTable::new(source, entries))
    }

    /// Load from a file through the registry ([`artifact::load_expecting`]).
    pub fn load(path: &str) -> Result<PolicyTable> {
        PolicyTable::from_json(&artifact::load_expecting(path, ArtifactKind::PolicyTable)?)
    }

    /// Persist under `target/psl-bench/<name>.json`. Returns the path.
    pub fn save(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        artifact::save(name, &self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(scenario: &str, n_clients: usize, frontier: Option<f64>) -> PolicyEntry {
        PolicyEntry {
            scenario: scenario.into(),
            n_clients,
            n_helpers: 2,
            frontier_churn: frontier,
            helper_down_rate: 0.0,
            uplink_capacity: 0.0,
        }
    }

    fn table() -> PolicyTable {
        PolicyTable::new(
            "test".to_string(),
            vec![
                entry("scenario1", 10, Some(0.3)),
                PolicyEntry { n_helpers: 4, ..entry("scenario1", 40, Some(0.2)) },
                entry("s5-memory-starved", 10, None),
            ],
        )
    }

    #[test]
    fn entries_sort_canonically() {
        let t = table();
        assert_eq!(t.entries[0].n_clients, 10);
        assert_eq!(t.entries[1].n_clients, 40);
        assert_eq!(t.entries[2].scenario, "s5-memory-starved");
    }

    #[test]
    fn lookup_picks_nearest_size_within_family() {
        let t = table();
        assert_eq!(t.lookup("scenario1", 12, 2).unwrap().n_clients, 10);
        assert_eq!(t.lookup("scenario1", 30, 4).unwrap().n_clients, 40);
        // Client counts equidistant (25 from both) → the run's helper
        // count breaks the tie toward the matching measurement.
        assert_eq!(t.lookup("scenario1", 25, 4).unwrap().n_clients, 40);
        assert_eq!(t.lookup("scenario1", 25, 2).unwrap().n_clients, 10);
        // Helper count also equidistant (3 from both) → smaller size.
        assert_eq!(t.lookup("scenario1", 25, 3).unwrap().n_clients, 10);
        assert!(t.lookup("scenario2", 10, 2).is_none());
    }

    #[test]
    fn lookup_exposes_open_frontiers_and_misses_distinctly() {
        let t = table();
        // Covered family with a measured frontier.
        assert_eq!(t.lookup("scenario1", 10, 2).unwrap().frontier_churn, Some(0.3));
        // Covered family where incremental won everywhere → Some(entry)
        // with an open (None) frontier — not the same as a table miss.
        assert_eq!(t.lookup("s5-memory-starved", 10, 2).unwrap().frontier_churn, None);
        // Unknown family → None (the orchestrator's static fallback).
        assert!(t.lookup("scenario2", 10, 2).is_none());
    }

    #[test]
    fn lookup_at_prefers_the_nearest_helper_outage_rate() {
        let t = PolicyTable::new(
            "test".to_string(),
            vec![
                entry("scenario1", 10, Some(0.3)),
                PolicyEntry { helper_down_rate: 0.12, ..entry("scenario1", 10, Some(0.15)) },
            ],
        );
        assert_eq!(t.lookup_at("scenario1", 10, 2, 0.0, 0.0).unwrap().frontier_churn, Some(0.3));
        assert_eq!(t.lookup_at("scenario1", 10, 2, 0.1, 0.0).unwrap().frontier_churn, Some(0.15));
        // lookup() is the zero-rate view of the same table.
        assert_eq!(t.lookup("scenario1", 10, 2).unwrap().frontier_churn, Some(0.3));
        // Size proximity still dominates the rate axis.
        let far = PolicyTable::new(
            "test".to_string(),
            vec![
                PolicyEntry { helper_down_rate: 0.12, ..entry("scenario1", 40, Some(0.15)) },
                entry("scenario1", 10, Some(0.3)),
            ],
        );
        assert_eq!(far.lookup_at("scenario1", 12, 2, 0.12, 0.0).unwrap().n_clients, 10);
    }

    #[test]
    fn lookup_at_prefers_the_nearest_uplink_capacity() {
        let t = PolicyTable::new(
            "test".to_string(),
            vec![
                entry("scenario1", 10, Some(0.3)),
                PolicyEntry { uplink_capacity: 2.0, ..entry("scenario1", 10, Some(0.1)) },
            ],
        );
        // A dedicated run (capacity axis 0.0) matches the dedicated
        // measurement; a shared run matches the nearest measured pool.
        assert_eq!(t.lookup_at("scenario1", 10, 2, 0.0, 0.0).unwrap().frontier_churn, Some(0.3));
        assert_eq!(t.lookup_at("scenario1", 10, 2, 0.0, 2.5).unwrap().frontier_churn, Some(0.1));
        // The helper-outage axis still dominates the capacity axis.
        let mixed = PolicyTable::new(
            "test".to_string(),
            vec![
                PolicyEntry { helper_down_rate: 0.12, uplink_capacity: 2.0, ..entry("scenario1", 10, Some(0.2)) },
                PolicyEntry { uplink_capacity: 4.0, ..entry("scenario1", 10, Some(0.1)) },
            ],
        );
        assert_eq!(mixed.lookup_at("scenario1", 10, 2, 0.12, 4.0).unwrap().frontier_churn, Some(0.2));
    }

    #[test]
    fn uplink_capacity_serializes_only_when_set() {
        let t = PolicyTable::new(
            "test".to_string(),
            vec![
                entry("scenario1", 10, Some(0.3)),
                PolicyEntry { uplink_capacity: 2.0, ..entry("scenario1", 10, Some(0.1)) },
            ],
        );
        let text = t.to_json().pretty();
        assert_eq!(text.matches("uplink_capacity").count(), 1, "{text}");
        let back = PolicyTable::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, t, "absent key reads back as 0.0");
        let bad = artifact::envelope(ArtifactKind::PolicyTable, vec![
            ("source", Json::Str("x".into())),
            (
                "entries",
                Json::Arr(vec![Json::obj(vec![
                    ("scenario", Json::Str("s".into())),
                    ("n_clients", Json::Num(4.0)),
                    ("n_helpers", Json::Num(2.0)),
                    ("frontier_churn", Json::Null),
                    ("uplink_capacity", Json::Num(-1.0)),
                ])]),
            ),
        ]);
        let err = PolicyTable::from_json(&bad).unwrap_err().to_string();
        assert!(err.contains("uplink_capacity"), "{err}");
    }

    #[test]
    fn helper_down_rate_serializes_only_when_set() {
        let t = PolicyTable::new(
            "test".to_string(),
            vec![
                entry("scenario1", 10, Some(0.3)),
                PolicyEntry { helper_down_rate: 0.12, ..entry("scenario1", 10, Some(0.15)) },
            ],
        );
        let text = t.to_json().pretty();
        assert_eq!(text.matches("helper_down_rate").count(), 1, "{text}");
        let back = PolicyTable::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, t, "absent key reads back as 0.0");
        let bad = artifact::envelope(ArtifactKind::PolicyTable, vec![
            ("source", Json::Str("x".into())),
            (
                "entries",
                Json::Arr(vec![Json::obj(vec![
                    ("scenario", Json::Str("s".into())),
                    ("n_clients", Json::Num(4.0)),
                    ("n_helpers", Json::Num(2.0)),
                    ("frontier_churn", Json::Null),
                    ("helper_down_rate", Json::Num(1.5)),
                ])]),
            ),
        ]);
        let err = PolicyTable::from_json(&bad).unwrap_err().to_string();
        assert!(err.contains("probability"), "{err}");
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let t = table();
        let doc = t.to_json();
        assert_eq!(doc.get("kind").as_str(), Some("psl-policy-table"));
        let back = PolicyTable::from_json(&Json::parse(&doc.pretty()).unwrap()).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.to_json().pretty(), doc.pretty(), "roundtrip is byte-stable");
    }

    #[test]
    fn from_json_rejects_malformed_tables() {
        assert!(PolicyTable::from_json(&Json::Num(1.0)).is_err());
        let wrong_kind = artifact::envelope(ArtifactKind::Sweep, vec![("entries", Json::Arr(vec![]))]);
        assert!(PolicyTable::from_json(&wrong_kind).is_err());
        let bad_entry = artifact::envelope(ArtifactKind::PolicyTable, vec![
            ("source", Json::Str("x".into())),
            ("entries", Json::Arr(vec![Json::obj(vec![("scenario", Json::Str("s".into()))])])),
        ]);
        let err = PolicyTable::from_json(&bad_entry).unwrap_err().to_string();
        assert!(err.contains("n_clients"), "{err}");
        let bad_frontier = artifact::envelope(ArtifactKind::PolicyTable, vec![
            ("source", Json::Str("x".into())),
            (
                "entries",
                Json::Arr(vec![Json::obj(vec![
                    ("scenario", Json::Str("s".into())),
                    ("n_clients", Json::Num(4.0)),
                    ("n_helpers", Json::Num(2.0)),
                    ("frontier_churn", Json::Str("lots".into())),
                ])]),
            ),
        ]);
        assert!(PolicyTable::from_json(&bad_frontier).is_err());
    }

    #[test]
    fn builtin_covers_default_grid_families() {
        let t = PolicyTable::builtin();
        assert_eq!(t.source, "builtin");
        assert!(t.lookup("scenario1", 10, 2).is_some());
        assert!(t.lookup("s4-straggler-tail", 10, 2).is_some());
    }
}
