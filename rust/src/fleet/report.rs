//! Per-round fleet reports: what the orchestrator decided, what it cost,
//! and what the fleet achieved — serialized to the same deterministic
//! JSON shape as the sweep artifacts (BTreeMap keys, no wall-clock, seeds
//! as strings), so `target/psl-bench/` fleet files diff cleanly across
//! machines and thread counts.

use crate::util::json::Json;

/// One orchestration round.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundReport {
    pub round: usize,
    pub n_clients: usize,
    pub arrivals: usize,
    pub departures: usize,
    /// "full-initial" | "full-policy" | "full-churn" | "full-auto" |
    /// "full-gap" | "full-infeasible" | "repair" | "helper-degraded" |
    /// "helper-resolve" | "empty" (see `orchestrator::Decision`).
    pub decision: &'static str,
    /// §VII method the strategy routed to on full rounds (None for
    /// repaired / empty rounds).
    pub method: Option<&'static str>,
    pub makespan_slots: u32,
    pub makespan_ms: f64,
    pub lower_bound: u32,
    /// Membership delta over the previous roster size.
    pub churn_frac: f64,
    /// Rebalance moves behind the *kept* repaired assignment (0 on full
    /// and empty rounds — a discarded repair's effort still counts in
    /// `work_units`).
    pub repair_moves: usize,
    /// Arrivals placed by the kept repair's greedy warm-start step (0 on
    /// full and empty rounds).
    pub placed_arrivals: usize,
    /// Deterministic re-solve cost proxy (candidate evaluations; full
    /// solves count edge scans × ADMM iteration cap).
    pub work_units: u64,
    /// Epoch-pipelined steady-state period (ms) via
    /// [`crate::sim::epoch::replay_epoch`].
    pub period_ms: f64,
    pub preemptions: u32,
    /// Instance-shape signal (§VII): CV of helper processing times.
    /// Recorded every round so analyze can fold signal trajectories into
    /// the policy frontier.
    pub heterogeneity: f64,
    /// Instance-shape signal: mean fraction of helpers whose memory can
    /// host each client.
    pub placement_flexibility: f64,
    /// Instance-shape signal: p95/median of per-client best-edge
    /// end-to-end times.
    pub tail_ratio: f64,
    /// Helpers live (not in an outage) when this round scheduled.
    pub helpers_live: usize,
    /// Roster clients whose previous-round helper was dark this round.
    pub orphaned_clients: usize,
    /// Orphans re-seated on surviving helpers by a *kept* repair (0 on
    /// full and empty rounds — a full re-solve reseats everyone).
    pub migrations: usize,
    /// At least one helper was in an outage when this round scheduled.
    pub degraded: bool,
    /// Excess transfer slowdown from shared-uplink contention
    /// ([`crate::solver::strategy::Signals::contention`]): 0.0 under the
    /// dedicated transport, `factor(ceil(J/I)) − 1` under a shared pool.
    /// Serialized only when positive so dedicated artifacts keep their
    /// historical bytes.
    pub contention: f64,
    /// `Some("admm-y")` when a *kept* repair placed its arrivals with the
    /// ADMM y-assignment warm start (the previous full solve routed to
    /// ADMM); `None` for FCFS-placed repairs and all non-repair rounds.
    /// Serialized only when `Some`.
    pub repair_source: Option<&'static str>,
}

impl RoundReport {
    /// The round's JSON object — one entry of `rounds_detail`, and one
    /// line of the streamed `<out>.rounds.jsonl` sidecar (same shape, so
    /// the JSONL concatenation is exactly the final report's detail
    /// array).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("round", Json::Num(self.round as f64)),
            ("n_clients", Json::Num(self.n_clients as f64)),
            ("arrivals", Json::Num(self.arrivals as f64)),
            ("departures", Json::Num(self.departures as f64)),
            ("decision", Json::Str(self.decision.to_string())),
            (
                "method",
                self.method.map(|m| Json::Str(m.to_string())).unwrap_or(Json::Null),
            ),
            ("makespan_slots", Json::Num(self.makespan_slots as f64)),
            ("makespan_ms", Json::Num(self.makespan_ms)),
            ("lower_bound", Json::Num(self.lower_bound as f64)),
            ("churn_frac", Json::Num(self.churn_frac)),
            ("repair_moves", Json::Num(self.repair_moves as f64)),
            ("placed_arrivals", Json::Num(self.placed_arrivals as f64)),
            ("work_units", Json::Str(self.work_units.to_string())),
            ("period_ms", Json::Num(self.period_ms)),
            ("preemptions", Json::Num(self.preemptions as f64)),
            ("heterogeneity", Json::Num(self.heterogeneity)),
            ("placement_flexibility", Json::Num(self.placement_flexibility)),
            ("tail_ratio", Json::Num(self.tail_ratio)),
            ("helpers_live", Json::Num(self.helpers_live as f64)),
            ("orphaned_clients", Json::Num(self.orphaned_clients as f64)),
            ("migrations", Json::Num(self.migrations as f64)),
            ("degraded", Json::Bool(self.degraded)),
        ];
        // Transport fields are emitted only when non-default so every
        // dedicated-mode artifact stays byte-identical to pre-transport
        // builds.
        if self.contention > 0.0 {
            fields.push(("contention", Json::Num(self.contention)));
        }
        if let Some(src) = self.repair_source {
            fields.push(("repair_source", Json::Str(src.to_string())));
        }
        Json::obj(fields)
    }

    /// Single-line JSON for round-by-round streaming (JSONL).
    pub fn jsonl_line(&self) -> String {
        self.to_json().dump()
    }

    /// Inverse of [`RoundReport::to_json`] — fleet checkpoints carry the
    /// completed rounds so a resumed run can replay its sidecar and
    /// finish with the byte-identical report. `decision`/`method` strings
    /// are interned back through the enum name tables (the struct fields
    /// are `&'static str`).
    pub fn from_json(doc: &Json) -> anyhow::Result<RoundReport> {
        use anyhow::Context;
        doc.as_obj().context("round report is not a JSON object")?;
        let num = |key: &str| -> anyhow::Result<f64> {
            doc.get(key).as_f64().with_context(|| format!("round report: bad {key:?}"))
        };
        let int = |key: &str| -> anyhow::Result<usize> {
            doc.get(key).as_usize().with_context(|| format!("round report: bad {key:?}"))
        };
        let decision_str = doc.get("decision").as_str().context("round report: bad \"decision\"")?;
        let decision = super::orchestrator::Decision::parse(decision_str)
            .with_context(|| format!("round report: unknown decision {decision_str:?}"))?
            .name();
        let method = match doc.get("method") {
            Json::Null => None,
            v => {
                let s = v.as_str().context("round report: bad \"method\"")?;
                Some(
                    crate::solver::strategy::Method::parse(s)
                        .with_context(|| format!("round report: unknown method {s:?}"))?
                        .name(),
                )
            }
        };
        // work_units is serialized as a string (u64 totals can exceed
        // 2^53); accept an integral number leniently for hand-written
        // lines.
        let work_units = match doc.get("work_units") {
            Json::Str(s) => s.parse::<u64>().with_context(|| format!("round report: bad work_units {s:?}"))?,
            v => {
                let f = v.as_f64().context("round report: bad \"work_units\"")?;
                anyhow::ensure!(f >= 0.0 && f.fract() == 0.0, "round report: bad work_units {f}");
                f as u64
            }
        };
        // The instance signals arrived with schema v4; a checkpoint
        // without them cannot replay byte-identically, so fail with the
        // registry's standard advice instead of inventing values.
        let signal = |key: &str| -> anyhow::Result<f64> {
            match doc.get(key) {
                Json::Null => anyhow::bail!(
                    "round report: no {key:?} — this artifact predates schema v4 signals; \
                     re-generate it with this build"
                ),
                v => v.as_f64().with_context(|| format!("round report: bad {key:?}")),
            }
        };
        // The helper-dynamics fields arrived with schema v5 — same rule.
        let helper_int = |key: &str| -> anyhow::Result<usize> {
            match doc.get(key) {
                Json::Null => anyhow::bail!(
                    "round report: no {key:?} — this artifact predates schema v{} helper \
                     dynamics; re-generate it with this build",
                    crate::bench::artifact::SCHEMA_VERSION
                ),
                v => v.as_usize().with_context(|| format!("round report: bad {key:?}")),
            }
        };
        let degraded = match doc.get("degraded") {
            Json::Null => anyhow::bail!(
                "round report: no \"degraded\" — this artifact predates schema v{} helper \
                 dynamics; re-generate it with this build",
                crate::bench::artifact::SCHEMA_VERSION
            ),
            Json::Bool(b) => *b,
            _ => anyhow::bail!("round report: bad \"degraded\""),
        };
        // Transport fields are lenient (absent → default): they are
        // emitted only when non-default, so every dedicated round omits
        // them by design.
        let contention = match doc.get("contention") {
            Json::Null => 0.0,
            v => {
                let c = v.as_f64().context("round report: bad \"contention\"")?;
                anyhow::ensure!(c.is_finite() && c >= 0.0, "round report: bad contention {c}");
                c
            }
        };
        let repair_source = match doc.get("repair_source") {
            Json::Null => None,
            v => match v.as_str().context("round report: bad \"repair_source\"")? {
                "admm-y" => Some("admm-y"),
                "fcfs" => Some("fcfs"),
                s => anyhow::bail!("round report: unknown repair_source {s:?}"),
            },
        };
        Ok(RoundReport {
            round: int("round")?,
            n_clients: int("n_clients")?,
            arrivals: int("arrivals")?,
            departures: int("departures")?,
            decision,
            method,
            makespan_slots: int("makespan_slots")? as u32,
            makespan_ms: num("makespan_ms")?,
            lower_bound: int("lower_bound")? as u32,
            churn_frac: num("churn_frac")?,
            repair_moves: int("repair_moves")?,
            placed_arrivals: int("placed_arrivals")?,
            work_units,
            period_ms: num("period_ms")?,
            preemptions: int("preemptions")? as u32,
            heterogeneity: signal("heterogeneity")?,
            placement_flexibility: signal("placement_flexibility")?,
            tail_ratio: signal("tail_ratio")?,
            helpers_live: helper_int("helpers_live")?,
            orphaned_clients: helper_int("orphaned_clients")?,
            migrations: helper_int("migrations")?,
            degraded,
            contention,
            repair_source,
        })
    }
}

/// A whole fleet run.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetReport {
    pub label: String,
    pub policy: String,
    pub slot_ms: f64,
    pub rounds: Vec<RoundReport>,
}

impl FleetReport {
    pub fn new(label: String, policy: String, slot_ms: f64, rounds: Vec<RoundReport>) -> FleetReport {
        FleetReport { label, policy, slot_ms, rounds }
    }

    // ---- summary accessors ----------------------------------------------

    /// Rounds that ran a full solve — the `full-*` tags plus
    /// `helper-resolve` (a full solve on the reduced helper set), so
    /// full + repair + empty still partitions every round.
    pub fn full_rounds(&self) -> usize {
        self.rounds
            .iter()
            .filter(|r| r.decision.starts_with("full") || r.decision == "helper-resolve")
            .count()
    }

    /// Rounds that kept a warm-started repair — `repair` plus
    /// `helper-degraded` (a kept repair that migrated orphans).
    pub fn repair_rounds(&self) -> usize {
        self.rounds
            .iter()
            .filter(|r| r.decision == "repair" || r.decision == "helper-degraded")
            .count()
    }

    pub fn empty_rounds(&self) -> usize {
        self.rounds.iter().filter(|r| r.decision == "empty").count()
    }

    /// Rounds scheduled with at least one helper in an outage.
    pub fn degraded_rounds(&self) -> usize {
        self.rounds.iter().filter(|r| r.degraded).count()
    }

    /// Total orphaned clients re-seated by kept repairs across the run.
    pub fn total_migrations(&self) -> usize {
        self.rounds.iter().map(|r| r.migrations).sum()
    }

    /// Mean makespan (ms) over non-empty rounds (0.0 if all empty).
    pub fn mean_makespan_ms(&self) -> f64 {
        let xs: Vec<f64> = self.rounds.iter().filter(|r| r.n_clients > 0).map(|r| r.makespan_ms).collect();
        if xs.is_empty() {
            0.0
        } else {
            xs.iter().sum::<f64>() / xs.len() as f64
        }
    }

    /// Mean epoch-pipelined period (ms) over non-empty rounds.
    pub fn mean_period_ms(&self) -> f64 {
        let xs: Vec<f64> = self.rounds.iter().filter(|r| r.n_clients > 0).map(|r| r.period_ms).collect();
        if xs.is_empty() {
            0.0
        } else {
            xs.iter().sum::<f64>() / xs.len() as f64
        }
    }

    /// Total deterministic solve-cost proxy across the run.
    pub fn total_work_units(&self) -> u64 {
        self.rounds.iter().map(|r| r.work_units).sum()
    }

    /// Mean *observed* membership-churn fraction over rounds after the
    /// first (round 0 has no previous roster to churn against). This is
    /// the unit the analyze frontier — and therefore the `auto` policy's
    /// per-round comparison — is measured in; note it is roughly twice
    /// the grid's stationary churn-rate axis (departures at rate r plus
    /// arrivals at r·J both count toward the membership delta).
    pub fn mean_churn_frac(&self) -> f64 {
        let xs: Vec<f64> = self.rounds.iter().filter(|r| r.round > 0).map(|r| r.churn_frac).collect();
        if xs.is_empty() {
            0.0
        } else {
            xs.iter().sum::<f64>() / xs.len() as f64
        }
    }

    pub fn to_json(&self) -> Json {
        crate::bench::artifact::envelope(crate::bench::artifact::ArtifactKind::Fleet, vec![
            ("label", Json::Str(self.label.clone())),
            ("policy", Json::Str(self.policy.clone())),
            ("slot_ms", Json::Num(self.slot_ms)),
            (
                "summary",
                Json::obj(vec![
                    ("rounds", Json::Num(self.rounds.len() as f64)),
                    ("full_rounds", Json::Num(self.full_rounds() as f64)),
                    ("repair_rounds", Json::Num(self.repair_rounds() as f64)),
                    ("empty_rounds", Json::Num(self.empty_rounds() as f64)),
                    ("degraded_rounds", Json::Num(self.degraded_rounds() as f64)),
                    ("migrations", Json::Num(self.total_migrations() as f64)),
                    ("mean_makespan_ms", Json::Num(self.mean_makespan_ms())),
                    ("mean_period_ms", Json::Num(self.mean_period_ms())),
                    // String, not Num: u64 work totals can exceed 2^53.
                    ("total_work_units", Json::Str(self.total_work_units().to_string())),
                ]),
            ),
            (
                "rounds_detail",
                Json::Arr(self.rounds.iter().map(|r| r.to_json()).collect()),
            ),
        ])
    }

    /// Persist under `target/psl-bench/<name>.json` (the sweep runner's
    /// location). Returns the path.
    pub fn save(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        crate::bench::save_artifact(name, &self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round(round: usize, decision: &'static str, makespan_ms: f64, work: u64) -> RoundReport {
        RoundReport {
            round,
            n_clients: if decision == "empty" { 0 } else { 4 },
            arrivals: 1,
            departures: 1,
            decision,
            method: if decision.starts_with("full") { Some("admm") } else { None },
            makespan_slots: (makespan_ms / 100.0) as u32,
            makespan_ms,
            lower_bound: 3,
            churn_frac: 0.25,
            repair_moves: 1,
            placed_arrivals: 1,
            work_units: work,
            period_ms: makespan_ms * 0.8,
            preemptions: 0,
            heterogeneity: 0.42,
            placement_flexibility: 0.9,
            tail_ratio: 1.5,
            helpers_live: 2,
            orphaned_clients: if decision == "helper-degraded" { 1 } else { 0 },
            migrations: if decision == "helper-degraded" { 1 } else { 0 },
            degraded: decision.starts_with("helper"),
            contention: 0.0,
            repair_source: None,
        }
    }

    fn report() -> FleetReport {
        FleetReport::new(
            "fleet:test".into(),
            "incremental".into(),
            100.0,
            vec![
                round(0, "full-initial", 1000.0, 500),
                round(1, "repair", 1100.0, 30),
                round(2, "empty", 0.0, 0),
                round(3, "full-gap", 900.0, 480),
                round(4, "helper-degraded", 1200.0, 40),
                round(5, "helper-resolve", 1000.0, 510),
            ],
        )
    }

    #[test]
    fn summary_counts() {
        let r = report();
        assert_eq!(r.full_rounds(), 3, "helper-resolve is a full solve");
        assert_eq!(r.repair_rounds(), 2, "helper-degraded is a kept repair");
        assert_eq!(r.empty_rounds(), 1);
        assert_eq!(
            r.full_rounds() + r.repair_rounds() + r.empty_rounds(),
            r.rounds.len(),
            "the three decision classes partition every round"
        );
        assert_eq!(r.degraded_rounds(), 2);
        assert_eq!(r.total_migrations(), 1);
        assert_eq!(r.total_work_units(), 1560);
        assert!((r.mean_makespan_ms() - 1040.0).abs() < 1e-9, "empty rounds excluded");
        assert!((r.mean_churn_frac() - 0.25).abs() < 1e-9, "round 0 excluded");
    }

    #[test]
    fn jsonl_lines_match_rounds_detail() {
        let r = report();
        let detail = r.to_json();
        let detail_rows = detail.get("rounds_detail").as_arr().unwrap();
        for (round, row) in r.rounds.iter().zip(detail_rows) {
            let line = round.jsonl_line();
            assert!(!line.contains('\n'), "JSONL lines are single-line");
            let parsed = Json::parse(&line).unwrap();
            assert_eq!(parsed.pretty(), row.pretty(), "JSONL line equals the detail entry");
        }
    }

    #[test]
    fn round_report_roundtrips_through_from_json() {
        for r in &report().rounds {
            let back = RoundReport::from_json(&Json::parse(&r.jsonl_line()).unwrap()).unwrap();
            assert_eq!(&back, r, "round {}", r.round);
        }
        // Unknown decision / method strings are rejected, not interned.
        let mut doc = report().rounds[0].to_json();
        if let Json::Obj(obj) = &mut doc {
            obj.insert("decision".into(), Json::Str("nope".into()));
        }
        assert!(RoundReport::from_json(&doc).is_err());
    }

    #[test]
    fn rounds_surface_instance_signals() {
        let doc = report().rounds[0].to_json();
        assert_eq!(doc.get("heterogeneity").as_f64(), Some(0.42));
        assert_eq!(doc.get("placement_flexibility").as_f64(), Some(0.9));
        assert_eq!(doc.get("tail_ratio").as_f64(), Some(1.5));
        // Pre-v4 rounds (no signals) must fail loudly: a resumed run
        // could not replay them byte-identically.
        let mut old = doc.clone();
        if let Json::Obj(obj) = &mut old {
            obj.remove("heterogeneity");
        }
        let err = RoundReport::from_json(&old).unwrap_err().to_string();
        assert!(err.contains("re-generate"), "{err}");
    }

    #[test]
    fn rounds_surface_helper_dynamics() {
        let doc = report().rounds[4].to_json();
        assert_eq!(doc.get("helpers_live").as_usize(), Some(2));
        assert_eq!(doc.get("orphaned_clients").as_usize(), Some(1));
        assert_eq!(doc.get("migrations").as_usize(), Some(1));
        assert_eq!(doc.get("degraded"), &Json::Bool(true));
        // Pre-v5 rounds (no helper fields) fail loudly, like pre-v4
        // signal-less rounds do.
        for key in ["helpers_live", "orphaned_clients", "migrations", "degraded"] {
            let mut old = doc.clone();
            if let Json::Obj(obj) = &mut old {
                obj.remove(key);
            }
            let err = RoundReport::from_json(&old).unwrap_err().to_string();
            assert!(err.contains("re-generate"), "{key}: {err}");
        }
    }

    #[test]
    fn transport_fields_are_emitted_only_when_non_default() {
        // A dedicated-mode round serializes without the transport keys —
        // the historical byte shape.
        let base = report().rounds[0].to_json();
        assert_eq!(base.get("contention"), &Json::Null);
        assert_eq!(base.get("repair_source"), &Json::Null);
        assert!(!base.dump().contains("contention"));
        assert!(!base.dump().contains("repair_source"));
        // Absent keys parse to the defaults (lenient, unlike the v4/v5
        // hard gates: pre-transport artifacts stay loadable).
        let back = RoundReport::from_json(&base).unwrap();
        assert_eq!(back.contention, 0.0);
        assert_eq!(back.repair_source, None);
        // Non-default values round-trip exactly.
        let mut shared = round(1, "repair", 1100.0, 30);
        shared.contention = 0.75;
        shared.repair_source = Some("admm-y");
        let doc = shared.to_json();
        assert_eq!(doc.get("contention").as_f64(), Some(0.75));
        assert_eq!(doc.get("repair_source").as_str(), Some("admm-y"));
        assert_eq!(RoundReport::from_json(&doc).unwrap(), shared);
        // Unknown sources are rejected, not interned.
        let mut bad = doc.clone();
        if let Json::Obj(obj) = &mut bad {
            obj.insert("repair_source".into(), Json::Str("oracle".into()));
        }
        assert!(RoundReport::from_json(&bad).is_err());
    }

    #[test]
    fn json_shape_and_determinism() {
        let r = report();
        let a = r.to_json().pretty();
        let b = r.to_json().pretty();
        assert_eq!(a, b);
        let doc = Json::parse(&a).unwrap();
        assert_eq!(doc.get("kind").as_str(), Some("psl-fleet"));
        assert_eq!(doc.get("rounds_detail").as_arr().unwrap().len(), 6);
        assert_eq!(doc.get("summary").get("repair_rounds").as_usize(), Some(2));
        assert_eq!(doc.get("summary").get("degraded_rounds").as_usize(), Some(2));
        assert_eq!(doc.get("summary").get("total_work_units").as_str(), Some("1560"));
    }
}
