//! The stepwise fleet session: the orchestrator's round loop promoted to
//! an explicit state machine.
//!
//! [`FleetSession`] owns everything `run_on_stream_streaming`'s loop used
//! to keep in locals — the minted-client cache, the previous round's
//! assignment, the last full solve's lower-bound gap, the round cursor —
//! and exposes one transition: [`step`](FleetSession::step) consumes a
//! [`RoundEvents`] and returns that round's [`RoundReport`]. Batch runs
//! (`psl fleet`), the fleet grid, and the stdin/stdout decision service
//! (`psl serve`) are all thin drivers over the same session, so every
//! entry point makes byte-identical decisions.
//!
//! Two invariants make long horizons and checkpointing work:
//!
//! * **Bounded state.** The `minted` cache holds exactly the live roster:
//!   departures are evicted in `step` (ids are never reused, so dropping
//!   them is safe) and `prev_assign` is rebuilt from the kept schedule
//!   each round. A 10⁵-round run holds O(`max_clients`) state, not
//!   O(total ids ever seen).
//! * **Small, sufficient warm state.** Minted clients are a pure function
//!   of `(scenario tuple, id)`, so a checkpoint
//!   ([`FleetSession::checkpoint`]) records only the config, the round
//!   cursor, `prev_assign` (ids → helpers), `last_full_gap`, and the
//!   completed rounds — [`FleetSession::resume`] re-mints the roster and
//!   continues byte-identically.

use super::checkpoint::FleetCheckpoint;
use super::events::{self, RoundEvents};
use super::orchestrator::{full_work, repair_assignment, Decision, FleetCfg, Policy};
use super::policy::PolicyTable;
use super::report::{FleetReport, RoundReport};
use crate::instance::scenario::{FleetClient, FleetWorld};
use crate::sim::epoch::replay_epoch;
use crate::solver::admm::AdmmCfg;
use crate::solver::greedy;
use crate::solver::schedule::{fcfs_schedule, Schedule};
use crate::solver::strategy;
use crate::util::rng::fnv64 as fnv;
use anyhow::Result;
use std::collections::BTreeMap;

/// A resumable multi-round orchestration session (see module docs).
pub struct FleetSession {
    cfg: FleetCfg,
    world: FleetWorld,
    admm_cfg: AdmmCfg,
    slot_ms: f64,
    /// Frontier table resolved once at construction: an explicit
    /// `cfg.policy_table` wins, else the builtin when the policy is
    /// `auto` (other policies never consult it).
    table: Option<PolicyTable>,
    /// Live minted clients — exactly the current roster.
    minted: BTreeMap<u64, FleetClient>,
    // ---- warm state (the checkpoint payload) ---------------------------
    /// Previous round's kept assignment: stable client id → helper.
    prev_assign: BTreeMap<u64, usize>,
    prev_roster_len: usize,
    /// Lower-bound gap of the last full solve — the drift baseline
    /// (`f64::MAX` until the first full solve).
    last_full_gap: f64,
    /// Round the next `step` must carry (`== completed.len()`).
    next_round: usize,
    completed: Vec<RoundReport>,
}

impl FleetSession {
    /// Fresh session; the world is derived from the config exactly as the
    /// batch entry points derive it.
    pub fn new(cfg: FleetCfg) -> FleetSession {
        let world = cfg.scenario.fleet_world(cfg.churn.max_clients);
        FleetSession::with_world(cfg, world)
    }

    /// Fresh session over an explicitly-built world (tests inject worlds
    /// sized independently of `cfg.churn.max_clients`).
    pub fn with_world(cfg: FleetCfg, world: FleetWorld) -> FleetSession {
        let table = match (&cfg.policy_table, cfg.policy) {
            (Some(t), _) => Some(t.clone()),
            (None, Policy::Auto) => Some(PolicyTable::builtin()),
            (None, _) => None,
        };
        let slot_ms = cfg.slot_ms();
        FleetSession {
            cfg,
            world,
            admm_cfg: AdmmCfg::default(),
            slot_ms,
            table,
            minted: BTreeMap::new(),
            prev_assign: BTreeMap::new(),
            prev_roster_len: 0,
            last_full_gap: f64::MAX,
            next_round: 0,
            completed: Vec::new(),
        }
    }

    /// Rebuild a session from a checkpoint. The world is re-derived from
    /// the stored config (clients re-mint from ids), the warm state is
    /// restored verbatim, and the next `step` continues exactly where the
    /// checkpointed run stopped.
    pub fn resume(ckpt: FleetCheckpoint) -> Result<FleetSession> {
        anyhow::ensure!(
            ckpt.next_round == ckpt.rounds.len(),
            "checkpoint cursor (round {}) does not match its {} completed rounds",
            ckpt.next_round,
            ckpt.rounds.len()
        );
        anyhow::ensure!(
            ckpt.prev_assign.len() == ckpt.prev_roster_len,
            "checkpoint roster ({} assigned) does not match prev_roster_len {}",
            ckpt.prev_assign.len(),
            ckpt.prev_roster_len
        );
        let world = ckpt.cfg.scenario.fleet_world(ckpt.world_max_clients);
        let n_helpers = world.n_helpers();
        for (&id, &h) in &ckpt.prev_assign {
            anyhow::ensure!(
                h < n_helpers,
                "checkpoint assigns client {id} to helper {h}, but the world has {n_helpers} helpers"
            );
        }
        let mut session = FleetSession::with_world(ckpt.cfg, world);
        session.minted =
            ckpt.prev_assign.keys().map(|&id| (id, session.world.mint_client(id))).collect();
        session.prev_assign = ckpt.prev_assign;
        session.prev_roster_len = ckpt.prev_roster_len;
        session.last_full_gap = ckpt.last_full_gap;
        session.next_round = ckpt.next_round;
        session.completed = ckpt.rounds;
        Ok(session)
    }

    /// Snapshot the warm state (plus the completed rounds, so a resumed
    /// run's final report and sidecars are self-contained).
    pub fn checkpoint(&self) -> FleetCheckpoint {
        FleetCheckpoint {
            cfg: self.cfg.clone(),
            world_max_clients: self.world.max_clients,
            next_round: self.next_round,
            prev_roster_len: self.prev_roster_len,
            last_full_gap: self.last_full_gap,
            prev_assign: self.prev_assign.clone(),
            rounds: self.completed.clone(),
        }
    }

    pub fn cfg(&self) -> &FleetCfg {
        &self.cfg
    }

    /// Round the next [`step`](FleetSession::step) must carry.
    pub fn next_round(&self) -> usize {
        self.next_round
    }

    /// Rounds already stepped (the resumed prefix included).
    pub fn completed(&self) -> &[RoundReport] {
        &self.completed
    }

    /// Live roster ids (sorted) — the membership the next event's
    /// departures are validated against.
    pub fn roster(&self) -> Vec<u64> {
        self.prev_assign.keys().copied().collect()
    }

    /// Round-0 membership (ids `0..base_clients`). The generated stream's
    /// first event lists the base population in `roster` without arrival
    /// events, so external round-0 lines are validated against this
    /// implicit previous roster rather than an empty one.
    pub fn base_roster(&self) -> Vec<u64> {
        (0..self.world.base_clients() as u64).collect()
    }

    /// Roster cap the world's wedge-free memory repair was sized for.
    pub fn max_clients(&self) -> usize {
        self.world.max_clients
    }

    /// Size of the minted-client cache (== live roster size; exposed for
    /// the long-horizon bounded-state tests).
    pub fn minted_len(&self) -> usize {
        self.minted.len()
    }

    /// The full event stream this session's config generates — the same
    /// stream the batch entry points replay. Because rounds are drawn
    /// sequentially from one seeded RNG, a stream generated for N rounds
    /// is a byte-identical prefix of the stream for M > N rounds, which
    /// is what makes `--resume` with a longer `--rounds` horizon sound.
    pub fn event_stream(&self) -> Vec<RoundEvents> {
        events::generate(
            self.world.base_clients(),
            &self.cfg.churn,
            self.cfg.scenario.seed ^ fnv(&self.cfg.scenario.spec.name),
        )
    }

    /// Extend (or confirm) the run horizon — used by `psl fleet --resume
    /// --rounds N`. Rejects horizons behind the cursor.
    pub fn extend_rounds(&mut self, rounds: usize) -> Result<()> {
        anyhow::ensure!(
            rounds >= self.next_round,
            "--rounds {rounds} is behind the checkpoint (already completed {} rounds)",
            self.next_round
        );
        self.cfg.churn.rounds = rounds;
        Ok(())
    }

    /// Advance one round: mint/evict clients to match the event's roster,
    /// decide repair vs full re-solve exactly as the orchestrator policy
    /// dictates, and record the round. Panics if the event does not carry
    /// the expected round number (external inputs are validated upstream
    /// by [`RoundEvents::from_json`]).
    pub fn step(&mut self, ev: &RoundEvents) -> RoundReport {
        assert_eq!(
            ev.round, self.next_round,
            "event round {} does not continue the session (expected {})",
            ev.round, self.next_round
        );
        // Evict departures before minting arrivals: ids are never reused,
        // so the cache tracks the live roster exactly and a long run
        // holds O(max_clients) state.
        for id in &ev.departures {
            self.minted.remove(id);
        }
        let world = &self.world;
        for &id in &ev.roster {
            self.minted.entry(id).or_insert_with(|| world.mint_client(id));
        }
        debug_assert_eq!(self.minted.len(), ev.roster.len(), "minted cache out of sync with roster");

        let cfg = &self.cfg;
        let admm_cfg = &self.admm_cfg;
        let slot_ms = self.slot_ms;
        let table = self.table.as_ref();
        let last_full_gap = self.last_full_gap;
        let roster: Vec<&FleetClient> = ev.roster.iter().map(|id| &self.minted[id]).collect();
        let ms = world.instance(&roster);
        let inst = ms.quantize(slot_ms);
        let churn_frac = ev.churn_fraction(self.prev_roster_len);
        let lb_raw = inst.makespan_lower_bound();
        let lb = lb_raw.max(1);
        // Instance-shape signals, computed once per round: full solves
        // consume them for the §VII pick and the round report surfaces
        // them for the analyze layer (ROADMAP item 5).
        let sig = strategy::signals(&inst);
        // The auto policy's per-round consult (None for other policies or
        // when nothing fires). A measured frontier firing is FullAuto; a
        // family the table does not cover falls back to the static churn
        // threshold and is recorded as FullChurn, so decision analyses
        // can separate data-driven re-solves from the fallback.
        let auto_full: Option<Decision> = if cfg.policy == Policy::Auto {
            table.and_then(|t| match t.lookup(&cfg.scenario.spec.name, roster.len(), inst.n_helpers) {
                Some(entry) => match entry.frontier_churn {
                    Some(frontier) if churn_frac >= frontier => Some(Decision::FullAuto),
                    _ => None,
                },
                None if churn_frac > cfg.churn_threshold => Some(Decision::FullChurn),
                None => None,
            })
        } else {
            None
        };
        let full_solve = |work_base: u64| -> ((Schedule, Option<strategy::Method>), u64) {
            // The wedge-free world guarantees a greedy assignment exists,
            // so a full solve can never come up empty.
            let (s, m) = strategy::solve_with_signals(&inst, admm_cfg, &sig)
                .or_else(|| greedy::solve(&inst).map(|s| (s, strategy::Method::BalancedGreedy)))
                .expect("wedge-free world must admit a greedy assignment");
            let w = work_base + full_work(&inst, m, admm_cfg);
            ((s, Some(m)), w)
        };

        let (decision, schedule, repair_moves, placed, work) = if roster.is_empty() {
            (Decision::Empty, None, 0, 0, 0u64)
        } else if ev.round == 0 || cfg.policy == Policy::FullEveryRound {
            let d = if ev.round == 0 { Decision::FullInitial } else { Decision::FullPolicy };
            let (s, w) = full_solve(0);
            (d, Some(s), 0, 0, w)
        } else if cfg.policy == Policy::Incremental && churn_frac > cfg.churn_threshold {
            let (s, w) = full_solve(0);
            (Decision::FullChurn, Some(s), 0, 0, w)
        } else if let Some(d) = auto_full {
            let (s, w) = full_solve(0);
            (d, Some(s), 0, 0, w)
        } else {
            let mut work = 0u64;
            match repair_assignment(&inst, &ev.roster, &self.prev_assign, &mut work) {
                Some(rep) => {
                    let s = fcfs_schedule(&inst, rep.assignment);
                    let gap = s.makespan(&inst) as f64 / lb as f64;
                    if matches!(cfg.policy, Policy::Incremental | Policy::Auto)
                        && gap > cfg.gap_threshold * last_full_gap
                    {
                        // The repair is discarded: report no repair stats
                        // for the kept schedule, but its effort still
                        // counts in the work proxy (it was spent).
                        let (s, w) = full_solve(work);
                        (Decision::FullGap, Some(s), 0, 0, w)
                    } else {
                        (Decision::Repair, Some((s, None)), rep.moves, rep.placed, work)
                    }
                }
                // Defensive: the wedge-free world makes this unreachable,
                // but an unplaceable arrival must trigger a full solve,
                // not a panic.
                None => {
                    let (s, w) = full_solve(work);
                    (Decision::FullInfeasible, Some(s), 0, 0, w)
                }
            }
        };
        if decision.is_full() {
            if let Some((s, _)) = &schedule {
                self.last_full_gap = s.makespan(&inst) as f64 / lb as f64;
            }
        }

        let (makespan_slots, preemptions, period_ms, method) = match &schedule {
            Some((s, m)) => {
                debug_assert!(s.is_feasible(&inst), "round {} schedule infeasible", ev.round);
                let e = replay_epoch(&ms, s, cfg.epoch_batches.max(1));
                (s.makespan(&inst), s.preemptions(), e.period_ms, m.map(|m| m.name()))
            }
            None => (0, 0, 0.0, None),
        };

        let round_report = RoundReport {
            round: ev.round,
            n_clients: roster.len(),
            arrivals: ev.arrivals.len(),
            departures: ev.departures.len(),
            decision: decision.name(),
            method,
            makespan_slots,
            makespan_ms: makespan_slots as f64 * slot_ms,
            lower_bound: lb_raw,
            churn_frac,
            repair_moves,
            placed_arrivals: placed,
            work_units: work,
            period_ms,
            preemptions,
            heterogeneity: sig.heterogeneity,
            placement_flexibility: sig.placement_flexibility,
            tail_ratio: sig.tail_ratio,
        };

        self.prev_assign = match &schedule {
            Some((s, _)) => roster.iter().zip(&s.assignment.helper_of).map(|(c, &i)| (c.id, i)).collect(),
            None => BTreeMap::new(),
        };
        self.prev_roster_len = roster.len();
        self.next_round += 1;
        self.completed.push(round_report.clone());
        round_report
    }

    /// Finish the session: the same [`FleetReport`] the batch entry
    /// points produce (resumed prefixes included).
    pub fn into_report(self) -> FleetReport {
        FleetReport::new(
            format!(
                "fleet:{}/{} J={} I={} seed={}",
                self.cfg.scenario.spec.name,
                self.cfg.scenario.model.name(),
                self.cfg.scenario.n_clients,
                self.cfg.scenario.n_helpers,
                self.cfg.scenario.seed
            ),
            self.cfg.policy.name().to_string(),
            self.slot_ms,
            self.completed,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::events::ChurnCfg;
    use crate::fleet::orchestrator::run;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};

    fn cfg(policy: Policy, rounds: usize) -> FleetCfg {
        let scen = ScenarioCfg::new(Scenario::S4StragglerTail, Model::Vgg19, 10, 3, 7);
        let mut churn = ChurnCfg::stationary(10);
        churn.rounds = rounds;
        FleetCfg::new(scen, churn, policy)
    }

    #[test]
    fn stepping_the_session_matches_the_batch_run() {
        for policy in [Policy::Incremental, Policy::Auto, Policy::FullEveryRound] {
            let batch = run(&cfg(policy, 8));
            let mut session = FleetSession::new(cfg(policy, 8));
            let stream = session.event_stream();
            for ev in &stream {
                session.step(ev);
            }
            let stepped = session.into_report();
            assert_eq!(
                stepped.to_json().pretty(),
                batch.to_json().pretty(),
                "policy {}",
                policy.name()
            );
        }
    }

    #[test]
    fn checkpoint_resume_continues_byte_identically() {
        let straight = run(&cfg(Policy::Incremental, 8));
        let mut first = FleetSession::new(cfg(Policy::Incremental, 8));
        let stream = first.event_stream();
        for ev in &stream[..4] {
            first.step(ev);
        }
        let ckpt = first.checkpoint();
        assert_eq!(ckpt.next_round, 4);
        let mut resumed = FleetSession::resume(ckpt).unwrap();
        assert_eq!(resumed.next_round(), 4);
        // The resumed session regenerates the same stream and continues.
        let stream2 = resumed.event_stream();
        assert_eq!(stream2, stream, "config regenerates the identical event stream");
        for ev in &stream2[4..] {
            resumed.step(ev);
        }
        assert_eq!(resumed.into_report().to_json().pretty(), straight.to_json().pretty());
    }

    #[test]
    fn departures_evict_minted_clients() {
        let scen = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 4, 2, 3);
        let world = scen.fleet_world(8);
        let stream = vec![
            RoundEvents { round: 0, departures: vec![], arrivals: vec![], roster: vec![0, 1, 2, 3] },
            RoundEvents { round: 1, departures: vec![0, 1, 2, 3], arrivals: vec![], roster: vec![] },
            RoundEvents { round: 2, departures: vec![], arrivals: vec![4, 5], roster: vec![4, 5] },
        ];
        let churn = ChurnCfg { rounds: 3, arrival_rate: 0.0, departure_prob: 0.0, max_clients: 8 };
        let mut session = FleetSession::with_world(FleetCfg::new(scen, churn, Policy::Incremental), world);
        session.step(&stream[0]);
        assert_eq!(session.minted_len(), 4);
        session.step(&stream[1]);
        assert_eq!(session.minted_len(), 0, "departed clients are evicted, not retained forever");
        session.step(&stream[2]);
        assert_eq!(session.minted_len(), 2);
        assert_eq!(session.roster(), vec![4, 5]);
    }

    #[test]
    fn extend_rounds_rejects_horizons_behind_the_cursor() {
        let mut session = FleetSession::new(cfg(Policy::Incremental, 4));
        let stream = session.event_stream();
        for ev in &stream {
            session.step(ev);
        }
        assert!(session.extend_rounds(2).is_err());
        session.extend_rounds(6).unwrap();
        assert_eq!(session.cfg().churn.rounds, 6);
    }

    #[test]
    #[should_panic(expected = "does not continue the session")]
    fn step_rejects_out_of_order_events() {
        let mut session = FleetSession::new(cfg(Policy::Incremental, 4));
        let stream = session.event_stream();
        session.step(&stream[1]);
    }
}
