//! The stepwise fleet session: the orchestrator's round loop promoted to
//! an explicit state machine.
//!
//! [`FleetSession`] owns everything `run_on_stream_streaming`'s loop used
//! to keep in locals — the minted-client cache, the previous round's
//! assignment, the last full solve's lower-bound gap, the round cursor —
//! and exposes one transition: [`step`](FleetSession::step) consumes a
//! [`RoundEvents`] and returns that round's [`RoundReport`]. Batch runs
//! (`psl fleet`), the fleet grid, and the stdin/stdout decision service
//! (`psl serve`) are all thin drivers over the same session, so every
//! entry point makes byte-identical decisions.
//!
//! Two invariants make long horizons and checkpointing work:
//!
//! * **Bounded state.** The `minted` cache holds exactly the live roster:
//!   departures are evicted in `step` (ids are never reused, so dropping
//!   them is safe) and `prev_assign` is rebuilt from the kept schedule
//!   each round. A 10⁵-round run holds O(`max_clients`) state, not
//!   O(total ids ever seen).
//! * **Small, sufficient warm state.** Minted clients (and helpers) are a
//!   pure function of `(scenario tuple, id)`, so a checkpoint
//!   ([`FleetSession::checkpoint`]) records only the config, the round
//!   cursor, `prev_assign` (client ids → helper ids), the helper roster
//!   (live / in-outage / id watermark), `last_full_gap`, and the
//!   completed rounds — [`FleetSession::resume`] re-mints the roster and
//!   continues byte-identically, including across a
//!   `helper_down`/`helper_up` outage boundary.

use super::checkpoint::FleetCheckpoint;
use super::events::{self, HelperRoster, RoundEvents};
use super::orchestrator::{full_work, repair_assignment_guided, Decision, FleetCfg, Policy};
use super::policy::PolicyTable;
use super::report::{FleetReport, RoundReport};
use crate::instance::scenario::{FleetClient, FleetHelper, FleetWorld};
use crate::sim::epoch::replay_epoch_under;
use crate::solver::admm::AdmmCfg;
use crate::solver::greedy;
use crate::solver::schedule::{fcfs_schedule, Schedule};
use crate::solver::strategy;
use crate::util::rng::fnv64 as fnv;
use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// A resumable multi-round orchestration session (see module docs).
pub struct FleetSession {
    cfg: FleetCfg,
    world: FleetWorld,
    admm_cfg: AdmmCfg,
    slot_ms: f64,
    /// Frontier table resolved once at construction: an explicit
    /// `cfg.policy_table` wins, else the builtin when the policy is
    /// `auto` (other policies never consult it).
    table: Option<PolicyTable>,
    /// Live minted clients — exactly the current roster.
    minted: BTreeMap<u64, FleetClient>,
    // ---- warm state (the checkpoint payload) ---------------------------
    /// Current helper roster (live / in-outage / id watermark). For
    /// worlds without helper dynamics this stays at
    /// [`HelperRoster::base`] forever, so it holds O(`max_helpers`)
    /// state alongside the O(`max_clients`) client cache.
    helpers: HelperRoster,
    /// Previous round's kept assignment: stable client id → *helper id*.
    /// Base helpers have `id == position`, so for static worlds this is
    /// byte-identical to the historical positional encoding.
    prev_assign: BTreeMap<u64, usize>,
    prev_roster_len: usize,
    /// Lower-bound gap of the last full solve — the drift baseline
    /// (`f64::MAX` until the first full solve).
    last_full_gap: f64,
    /// §VII method the last full solve routed to (`None` until one
    /// lands). When it was ADMM, repair rounds reuse its assignment-step
    /// objective to place arrivals (the `admm-y` warm start recorded in
    /// [`RoundReport::repair_source`]).
    last_full_method: Option<strategy::Method>,
    /// Round the next `step` must carry (`== completed.len()`).
    next_round: usize,
    completed: Vec<RoundReport>,
}

impl FleetSession {
    /// Fresh session; the world is derived from the config exactly as the
    /// batch entry points derive it.
    pub fn new(cfg: FleetCfg) -> FleetSession {
        let world = cfg.build_world();
        FleetSession::with_world(cfg, world)
    }

    /// Fresh session over an explicitly-built world (tests inject worlds
    /// sized independently of `cfg.churn.max_clients`).
    pub fn with_world(cfg: FleetCfg, world: FleetWorld) -> FleetSession {
        let table = match (&cfg.policy_table, cfg.policy) {
            (Some(t), _) => Some(t.clone()),
            (None, Policy::Auto) => Some(PolicyTable::builtin()),
            (None, _) => None,
        };
        let slot_ms = cfg.slot_ms();
        let helpers = HelperRoster::base(world.n_helpers());
        FleetSession {
            cfg,
            world,
            admm_cfg: AdmmCfg::default(),
            slot_ms,
            table,
            minted: BTreeMap::new(),
            helpers,
            prev_assign: BTreeMap::new(),
            prev_roster_len: 0,
            last_full_gap: f64::MAX,
            last_full_method: None,
            next_round: 0,
            completed: Vec::new(),
        }
    }

    /// Rebuild a session from a checkpoint. The world is re-derived from
    /// the stored config (clients re-mint from ids), the warm state is
    /// restored verbatim, and the next `step` continues exactly where the
    /// checkpointed run stopped.
    pub fn resume(ckpt: FleetCheckpoint) -> Result<FleetSession> {
        anyhow::ensure!(
            ckpt.next_round == ckpt.rounds.len(),
            "checkpoint cursor (round {}) does not match its {} completed rounds",
            ckpt.next_round,
            ckpt.rounds.len()
        );
        anyhow::ensure!(
            ckpt.prev_assign.len() == ckpt.prev_roster_len,
            "checkpoint roster ({} assigned) does not match prev_roster_len {}",
            ckpt.prev_assign.len(),
            ckpt.prev_roster_len
        );
        let world = ckpt.cfg.build_world_sized(ckpt.world_max_clients);
        let helpers = HelperRoster {
            live: ckpt.helpers_live.clone(),
            down: ckpt.helpers_down.clone(),
            next_id: ckpt.helper_next_id,
        };
        anyhow::ensure!(!helpers.live.is_empty(), "checkpoint helper roster has no live helper");
        anyhow::ensure!(
            helpers.live.windows(2).all(|w| w[0] < w[1])
                && helpers.down.windows(2).all(|w| w[0] < w[1]),
            "checkpoint helper roster ids must be strictly sorted"
        );
        anyhow::ensure!(
            helpers.live.iter().chain(&helpers.down).all(|&h| h < helpers.next_id),
            "checkpoint helper id exceeds the next-id watermark {}",
            helpers.next_id
        );
        anyhow::ensure!(
            helpers.down.iter().all(|h| helpers.live.binary_search(h).is_err()),
            "checkpoint helper roster lists an id as both live and down"
        );
        if !world.helper_modeled() {
            anyhow::ensure!(
                helpers.down.is_empty() && helpers.next_id == world.n_helpers() as u64,
                "checkpoint carries helper dynamics but its config models none"
            );
        }
        for (&id, &h) in &ckpt.prev_assign {
            anyhow::ensure!(
                helpers.live.binary_search(&(h as u64)).is_ok(),
                "checkpoint assigns client {id} to helper {h}, which is not live in the checkpoint roster"
            );
        }
        let mut session = FleetSession::with_world(ckpt.cfg, world);
        session.minted =
            ckpt.prev_assign.keys().map(|&id| (id, session.world.mint_client(id))).collect();
        session.helpers = helpers;
        session.prev_assign = ckpt.prev_assign;
        session.prev_roster_len = ckpt.prev_roster_len;
        session.last_full_gap = ckpt.last_full_gap;
        session.last_full_method = match ckpt.last_full_method {
            None => None,
            Some(name) => Some(strategy::Method::parse(name).with_context(|| {
                format!("checkpoint: unknown last_full_method {name:?}")
            })?),
        };
        session.next_round = ckpt.next_round;
        session.completed = ckpt.rounds;
        Ok(session)
    }

    /// Snapshot the warm state (plus the completed rounds, so a resumed
    /// run's final report and sidecars are self-contained).
    pub fn checkpoint(&self) -> FleetCheckpoint {
        FleetCheckpoint {
            cfg: self.cfg.clone(),
            world_max_clients: self.world.max_clients,
            next_round: self.next_round,
            prev_roster_len: self.prev_roster_len,
            last_full_gap: self.last_full_gap,
            last_full_method: self.last_full_method.map(|m| m.name()),
            prev_assign: self.prev_assign.clone(),
            helpers_live: self.helpers.live.clone(),
            helpers_down: self.helpers.down.clone(),
            helper_next_id: self.helpers.next_id,
            rounds: self.completed.clone(),
        }
    }

    pub fn cfg(&self) -> &FleetCfg {
        &self.cfg
    }

    /// Round the next [`step`](FleetSession::step) must carry.
    pub fn next_round(&self) -> usize {
        self.next_round
    }

    /// Rounds already stepped (the resumed prefix included).
    pub fn completed(&self) -> &[RoundReport] {
        &self.completed
    }

    /// Live roster ids (sorted) — the membership the next event's
    /// departures are validated against.
    pub fn roster(&self) -> Vec<u64> {
        self.prev_assign.keys().copied().collect()
    }

    /// Round-0 membership (ids `0..base_clients`). The generated stream's
    /// first event lists the base population in `roster` without arrival
    /// events, so external round-0 lines are validated against this
    /// implicit previous roster rather than an empty one.
    pub fn base_roster(&self) -> Vec<u64> {
        (0..self.world.base_clients() as u64).collect()
    }

    /// Roster cap the world's wedge-free memory repair was sized for.
    pub fn max_clients(&self) -> usize {
        self.world.max_clients
    }

    /// Current helper roster — external event lines (`psl serve`) are
    /// validated against this before they reach [`step`].
    pub fn helper_roster(&self) -> &HelperRoster {
        &self.helpers
    }

    /// Whether this session's world models helper dynamics (down/up/join
    /// events are only accepted when it does).
    pub fn helper_modeled(&self) -> bool {
        self.world.helper_modeled()
    }

    /// Size of the minted-client cache (== live roster size; exposed for
    /// the long-horizon bounded-state tests).
    pub fn minted_len(&self) -> usize {
        self.minted.len()
    }

    /// The full event stream this session's config generates — the same
    /// stream the batch entry points replay. Because rounds are drawn
    /// sequentially from one seeded RNG, a stream generated for N rounds
    /// is a byte-identical prefix of the stream for M > N rounds, which
    /// is what makes `--resume` with a longer `--rounds` horizon sound.
    pub fn event_stream(&self) -> Vec<RoundEvents> {
        events::generate_fleet(
            self.world.base_clients(),
            &self.cfg.churn,
            &self.cfg.helper_churn,
            &self.cfg.flash,
            self.world.n_helpers(),
            self.cfg.scenario.seed ^ fnv(&self.cfg.scenario.spec.name),
        )
    }

    /// Extend (or confirm) the run horizon — used by `psl fleet --resume
    /// --rounds N`. Rejects horizons behind the cursor.
    pub fn extend_rounds(&mut self, rounds: usize) -> Result<()> {
        anyhow::ensure!(
            rounds >= self.next_round,
            "--rounds {rounds} is behind the checkpoint (already completed {} rounds)",
            self.next_round
        );
        self.cfg.churn.rounds = rounds;
        Ok(())
    }

    /// Advance one round: mint/evict clients to match the event's roster,
    /// decide repair vs full re-solve exactly as the orchestrator policy
    /// dictates, and record the round. Panics if the event does not carry
    /// the expected round number (external inputs are validated upstream
    /// by [`RoundEvents::from_json`]).
    pub fn step(&mut self, ev: &RoundEvents) -> RoundReport {
        assert_eq!(
            ev.round, self.next_round,
            "event round {} does not continue the session (expected {})",
            ev.round, self.next_round
        );
        assert!(
            !ev.has_helper_events() || self.world.helper_modeled(),
            "round {} carries helper events but this session's world does not model helper \
             dynamics (external inputs are validated upstream by `psl serve`)",
            ev.round
        );
        {
            let _sp = crate::obs::span("fleet", "fleet/events-apply");
            // Helper events first: the roster they leave behind is the
            // helper set this round schedules on.
            self.helpers.apply(ev);
            // Evict departures before minting arrivals: ids are never
            // reused, so the cache tracks the live roster exactly and a
            // long run holds O(max_clients) state.
            for id in &ev.departures {
                self.minted.remove(id);
            }
            let world = &self.world;
            for &id in &ev.roster {
                self.minted.entry(id).or_insert_with(|| world.mint_client(id));
            }
        }
        let world = &self.world;
        debug_assert_eq!(self.minted.len(), ev.roster.len(), "minted cache out of sync with roster");

        let cfg = &self.cfg;
        let admm_cfg = &self.admm_cfg;
        let slot_ms = self.slot_ms;
        let table = self.table.as_ref();
        let last_full_gap = self.last_full_gap;
        let roster: Vec<&FleetClient> = ev.roster.iter().map(|id| &self.minted[id]).collect();
        let live_ids: Vec<u64> = self.helpers.live.clone();
        let (ms, inst) = {
            let _sp = crate::obs::span("fleet", "fleet/instance-build");
            let ms = if world.helper_modeled() {
                let live: Vec<FleetHelper> =
                    live_ids.iter().map(|&id| world.mint_helper(id)).collect();
                world.instance_on(&roster, &live)
            } else {
                world.instance(&roster)
            };
            let inst = ms.quantize(slot_ms);
            (ms, inst)
        };
        // Translate the warm state (client id → helper id) into positions
        // on this round's live helper list. Clients whose helper is in an
        // outage drop out — they are the orphans the repair re-places on
        // survivors. For static worlds ids == positions and nothing drops,
        // so this is byte-identical to the historical positional map.
        let helper_pos: BTreeMap<u64, usize> =
            live_ids.iter().enumerate().map(|(k, &h)| (h, k)).collect();
        let mut orphaned = 0usize;
        let mut prev_pos: BTreeMap<u64, usize> = BTreeMap::new();
        for &id in &ev.roster {
            if let Some(&h) = self.prev_assign.get(&id) {
                match helper_pos.get(&(h as u64)) {
                    Some(&k) => {
                        prev_pos.insert(id, k);
                    }
                    None => orphaned += 1,
                }
            }
        }
        // Degraded = at least one helper is dark this round. The capacity
        // fraction weighs surviving helper memory against the full pool
        // (live + in-outage); below `capacity_threshold` repair is not
        // attempted at all.
        let degraded = !self.helpers.down.is_empty();
        let capacity_fraction = if degraded {
            let live_mem: f64 = live_ids.iter().map(|&h| world.mint_helper(h).mem_gb).sum();
            let down_mem: f64 =
                self.helpers.down.iter().map(|&h| world.mint_helper(h).mem_gb).sum();
            live_mem / (live_mem + down_mem)
        } else {
            1.0
        };
        let churn_frac = ev.churn_fraction(self.prev_roster_len);
        let lb_raw = inst.makespan_lower_bound();
        let lb = lb_raw.max(1);
        // Instance-shape signals, computed once per round: full solves
        // consume them for the §VII pick and the round report surfaces
        // them for the analyze layer (ROADMAP item 5). Under the
        // dedicated transport default the contention signal is exactly
        // 0.0 and this is byte-identical to `strategy::signals`.
        let sig = strategy::signals_under(&inst, &cfg.transport);
        // Deterministic surcharge for pricing contention: every shared-
        // mode schedule (full or repaired) pays one inflation pass over
        // the edge set before FCFS can run. Zero under dedicated, so
        // historical work proxies are untouched.
        let transport_work: u64 = if cfg.transport.is_dedicated() {
            0
        } else {
            (inst.n_clients * inst.n_helpers) as u64
        };
        // Makespan of a schedule on the instance it was actually built
        // against: the contention-inflated projection in shared mode,
        // the raw instance under the dedicated default.
        let makespan_under = |s: &Schedule| -> u32 {
            if cfg.transport.is_dedicated() {
                s.makespan(&inst)
            } else {
                s.makespan(&cfg.transport.inflate_for_assignment(&inst, &s.assignment))
            }
        };
        // FCFS against the transport-effective instance for a repaired
        // assignment (identity in dedicated mode).
        let fcfs_under = |a: crate::solver::schedule::Assignment| -> Schedule {
            if cfg.transport.is_dedicated() {
                fcfs_schedule(&inst, a)
            } else {
                let eff = cfg.transport.inflate_for_assignment(&inst, &a);
                fcfs_schedule(&eff, a)
            }
        };
        // Whether repair rounds reuse the last full ADMM solve's
        // assignment objective for arrival placement (the `admm-y` warm
        // start); read *before* this round possibly replaces it.
        let admm_y = matches!(self.last_full_method, Some(strategy::Method::Admm));
        // The auto policy's per-round consult (None for other policies or
        // when nothing fires). A measured frontier firing is FullAuto; a
        // family the table does not cover falls back to the static churn
        // threshold and is recorded as FullChurn, so decision analyses
        // can separate data-driven re-solves from the fallback.
        let auto_full: Option<Decision> = if cfg.policy == Policy::Auto {
            table.and_then(|t| {
                match t.lookup_at(
                    &cfg.scenario.spec.name,
                    roster.len(),
                    inst.n_helpers,
                    cfg.helper_churn.down_rate,
                    // 0.0 is the dedicated-transport axis value, matching
                    // the grid's `--uplink-capacities 0` cell.
                    if cfg.transport.is_dedicated() { 0.0 } else { cfg.transport.capacity },
                ) {
                Some(entry) => match entry.frontier_churn {
                    Some(frontier) if churn_frac >= frontier => Some(Decision::FullAuto),
                    _ => None,
                },
                    None if churn_frac > cfg.churn_threshold => Some(Decision::FullChurn),
                    None => None,
                }
            })
        } else {
            None
        };
        let full_solve = |work_base: u64| -> ((Schedule, Option<strategy::Method>), u64) {
            // The wedge-free world guarantees a greedy assignment exists,
            // so a full solve can never come up empty. Shared mode
            // routes through the transport-aware solve path (shape the
            // assignment on the contention estimate, schedule on the
            // per-assignment effective rates); dedicated mode is the
            // historical byte-identical path.
            let solved = if cfg.transport.is_dedicated() {
                strategy::solve_with_signals(&inst, admm_cfg, &sig)
                    .or_else(|| greedy::solve(&inst).map(|s| (s, strategy::Method::BalancedGreedy)))
            } else {
                strategy::solve_under(&inst, &cfg.transport, admm_cfg).or_else(|| {
                    greedy::solve_under(&inst, &cfg.transport)
                        .map(|s| (s, strategy::Method::BalancedGreedy))
                })
            };
            let (s, m) = solved.expect("wedge-free world must admit a greedy assignment");
            let w = work_base + full_work(&inst, m, admm_cfg) + transport_work;
            ((s, Some(m)), w)
        };

        let decide_span = crate::obs::span("fleet", "fleet/decide");
        let (decision, schedule, repair_moves, placed, migrations, work) = if roster.is_empty() {
            (Decision::Empty, None, 0, 0, 0, 0u64)
        } else if ev.round == 0 || cfg.policy == Policy::FullEveryRound {
            let d = if ev.round == 0 { Decision::FullInitial } else { Decision::FullPolicy };
            let (s, w) = full_solve(0);
            (d, Some(s), 0, 0, 0, w)
        } else if cfg.policy == Policy::Incremental && churn_frac > cfg.churn_threshold {
            let (s, w) = full_solve(0);
            (Decision::FullChurn, Some(s), 0, 0, 0, w)
        } else if let Some(d) = auto_full {
            let (s, w) = full_solve(0);
            (d, Some(s), 0, 0, 0, w)
        } else if degraded && capacity_fraction < cfg.capacity_threshold {
            // Too much of the helper pool is dark: a repair onto the
            // survivors would concentrate load pathologically, so the
            // session abandons the warm state and fully re-solves on the
            // reduced helper set. This applies to every warm policy,
            // `repair-only` included (the documented feasibility
            // exception — a repair baseline that ignores capacity loss
            // would be measuring a different, broken system).
            let (s, w) = full_solve(0);
            (Decision::HelperResolve, Some(s), 0, 0, 0, w)
        } else {
            let mut work = 0u64;
            match repair_assignment_guided(&inst, &ev.roster, &prev_pos, &mut work, admm_y) {
                Some(rep) => {
                    let s = fcfs_under(rep.assignment);
                    work += transport_work;
                    let gap = makespan_under(&s) as f64 / lb as f64;
                    if matches!(cfg.policy, Policy::Incremental | Policy::Auto)
                        && gap > cfg.gap_threshold * last_full_gap
                    {
                        // The repair is discarded: report no repair stats
                        // for the kept schedule, but its effort still
                        // counts in the work proxy (it was spent). On a
                        // degraded round the fallback solves the reduced
                        // helper set, which gets its own tag.
                        let d = if degraded { Decision::HelperResolve } else { Decision::FullGap };
                        let (s, w) = full_solve(work);
                        (d, Some(s), 0, 0, 0, w)
                    } else if degraded {
                        // `rep.placed` counts every client the greedy
                        // placement seated: genuine arrivals plus the
                        // orphans migrated off down helpers.
                        (
                            Decision::HelperDegraded,
                            Some((s, None)),
                            rep.moves,
                            rep.placed - orphaned,
                            orphaned,
                            work,
                        )
                    } else {
                        (Decision::Repair, Some((s, None)), rep.moves, rep.placed, 0, work)
                    }
                }
                // Defensive: the wedge-free (and, under helper dynamics,
                // outage-proof) world makes this unreachable, but an
                // unplaceable client must trigger a full solve, not a
                // panic.
                None => {
                    let d =
                        if degraded { Decision::HelperResolve } else { Decision::FullInfeasible };
                    let (s, w) = full_solve(work);
                    (d, Some(s), 0, 0, 0, w)
                }
            }
        };
        drop(decide_span);
        // Orphans lose their in-flight forward/backward batch when their
        // helper drops: the retry is re-enqueued and charged to this
        // round's work proxy (one forward + one backward edge evaluation
        // per orphan), whichever path scheduled the round.
        let work = work + 2 * orphaned as u64;
        if decision.is_full() {
            if let Some((s, m)) = &schedule {
                self.last_full_gap = makespan_under(s) as f64 / lb as f64;
                if m.is_some() {
                    self.last_full_method = *m;
                }
            }
        }
        // The kept repair's warm-start source, for `analyze --rounds`
        // repair-source counts. `None` (the FCFS default and every
        // non-repair round) is not serialized, so dedicated runs that
        // never route to ADMM keep historical bytes.
        let repair_source: Option<&'static str> =
            match (decision, admm_y) {
                (Decision::Repair | Decision::HelperDegraded, true) => Some("admm-y"),
                _ => None,
            };

        let (makespan_slots, preemptions, period_ms, method) = match &schedule {
            Some((s, m)) => {
                debug_assert!(
                    s.violations_under(&inst, &cfg.transport).is_empty(),
                    "round {} schedule infeasible under the transport checker",
                    ev.round
                );
                let _sp = crate::obs::span("fleet", "fleet/replay-epoch");
                let e = replay_epoch_under(&ms, s, cfg.epoch_batches.max(1), &cfg.transport);
                (makespan_under(s), s.preemptions(), e.period_ms, m.map(|m| m.name()))
            }
            None => (0, 0, 0.0, None),
        };
        crate::obs::counter_add("fleet.rounds", 1);
        crate::obs::counter_add("fleet.repair_moves", repair_moves as u64);
        crate::obs::counter_add("fleet.migrations", migrations as u64);

        let round_report = RoundReport {
            round: ev.round,
            n_clients: roster.len(),
            arrivals: ev.arrivals.len(),
            departures: ev.departures.len(),
            decision: decision.name(),
            method,
            makespan_slots,
            makespan_ms: makespan_slots as f64 * slot_ms,
            lower_bound: lb_raw,
            churn_frac,
            repair_moves,
            placed_arrivals: placed,
            work_units: work,
            period_ms,
            preemptions,
            heterogeneity: sig.heterogeneity,
            placement_flexibility: sig.placement_flexibility,
            tail_ratio: sig.tail_ratio,
            contention: sig.contention,
            repair_source,
            helpers_live: live_ids.len(),
            orphaned_clients: orphaned,
            migrations,
            degraded,
        };

        // Rebuild the warm state in helper-*id* space: positions in this
        // round's schedule index the live helper list, not 0..I.
        self.prev_assign = match &schedule {
            Some((s, _)) => roster
                .iter()
                .zip(&s.assignment.helper_of)
                .map(|(c, &i)| (c.id, live_ids[i] as usize))
                .collect(),
            None => BTreeMap::new(),
        };
        self.prev_roster_len = roster.len();
        self.next_round += 1;
        self.completed.push(round_report.clone());
        round_report
    }

    /// Finish the session: the same [`FleetReport`] the batch entry
    /// points produce (resumed prefixes included).
    pub fn into_report(self) -> FleetReport {
        let mut label = format!(
            "fleet:{}/{} J={} I={} seed={}",
            self.cfg.scenario.spec.name,
            self.cfg.scenario.model.name(),
            self.cfg.scenario.n_clients,
            self.cfg.scenario.n_helpers,
            self.cfg.scenario.seed
        );
        // Shared-uplink runs tag the label; the dedicated default keeps
        // the historical label bytes.
        if !self.cfg.transport.is_dedicated() {
            label.push_str(&format!(" link=shared cap={}", self.cfg.transport.capacity));
        }
        FleetReport::new(label, self.cfg.policy.name().to_string(), self.slot_ms, self.completed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::events::ChurnCfg;
    use crate::fleet::orchestrator::run;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};

    fn cfg(policy: Policy, rounds: usize) -> FleetCfg {
        let scen = ScenarioCfg::new(Scenario::S4StragglerTail, Model::Vgg19, 10, 3, 7);
        let mut churn = ChurnCfg::stationary(10);
        churn.rounds = rounds;
        FleetCfg::new(scen, churn, policy)
    }

    #[test]
    fn stepping_the_session_matches_the_batch_run() {
        for policy in [Policy::Incremental, Policy::Auto, Policy::FullEveryRound] {
            let batch = run(&cfg(policy, 8));
            let mut session = FleetSession::new(cfg(policy, 8));
            let stream = session.event_stream();
            for ev in &stream {
                session.step(ev);
            }
            let stepped = session.into_report();
            assert_eq!(
                stepped.to_json().pretty(),
                batch.to_json().pretty(),
                "policy {}",
                policy.name()
            );
        }
    }

    #[test]
    fn checkpoint_resume_continues_byte_identically() {
        let straight = run(&cfg(Policy::Incremental, 8));
        let mut first = FleetSession::new(cfg(Policy::Incremental, 8));
        let stream = first.event_stream();
        for ev in &stream[..4] {
            first.step(ev);
        }
        let ckpt = first.checkpoint();
        assert_eq!(ckpt.next_round, 4);
        let mut resumed = FleetSession::resume(ckpt).unwrap();
        assert_eq!(resumed.next_round(), 4);
        // The resumed session regenerates the same stream and continues.
        let stream2 = resumed.event_stream();
        assert_eq!(stream2, stream, "config regenerates the identical event stream");
        for ev in &stream2[4..] {
            resumed.step(ev);
        }
        assert_eq!(resumed.into_report().to_json().pretty(), straight.to_json().pretty());
    }

    #[test]
    fn departures_evict_minted_clients() {
        let scen = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 4, 2, 3);
        let world = scen.fleet_world(8);
        let stream = vec![
            RoundEvents::clients(0, vec![], vec![], vec![0, 1, 2, 3]),
            RoundEvents::clients(1, vec![0, 1, 2, 3], vec![], vec![]),
            RoundEvents::clients(2, vec![], vec![4, 5], vec![4, 5]),
        ];
        let churn = ChurnCfg { rounds: 3, arrival_rate: 0.0, departure_prob: 0.0, max_clients: 8 };
        let mut session = FleetSession::with_world(FleetCfg::new(scen, churn, Policy::Incremental), world);
        session.step(&stream[0]);
        assert_eq!(session.minted_len(), 4);
        session.step(&stream[1]);
        assert_eq!(session.minted_len(), 0, "departed clients are evicted, not retained forever");
        session.step(&stream[2]);
        assert_eq!(session.minted_len(), 2);
        assert_eq!(session.roster(), vec![4, 5]);
    }

    #[test]
    fn extend_rounds_rejects_horizons_behind_the_cursor() {
        let mut session = FleetSession::new(cfg(Policy::Incremental, 4));
        let stream = session.event_stream();
        for ev in &stream {
            session.step(ev);
        }
        assert!(session.extend_rounds(2).is_err());
        session.extend_rounds(6).unwrap();
        assert_eq!(session.cfg().churn.rounds, 6);
    }

    #[test]
    fn repair_source_tracks_the_last_full_method() {
        let mut session = FleetSession::new(cfg(Policy::Incremental, 10));
        let stream = session.event_stream();
        let reports: Vec<_> = stream.iter().map(|ev| session.step(ev)).collect();
        for (k, r) in reports.iter().enumerate() {
            if r.decision == "repair" || r.decision == "helper-degraded" {
                // The warm-start source is admm-y exactly when the most
                // recent full solve routed to ADMM.
                let last_full = reports[..k].iter().rev().find_map(|p| p.method);
                let want = if last_full == Some("admm") { Some("admm-y") } else { None };
                assert_eq!(r.repair_source, want, "round {k}");
            } else {
                assert_eq!(r.repair_source, None, "round {k}: non-repair rounds have no source");
            }
        }
        // J = 10 routes full solves to ADMM (§VII), so this fleet must
        // actually exercise the admm-y warm start at least once.
        assert!(
            reports.iter().any(|r| r.repair_source == Some("admm-y")),
            "no admm-y repair in a fleet whose full solves route to ADMM"
        );
    }

    #[test]
    fn checkpoint_carries_last_full_method_across_resume() {
        let straight = run(&cfg(Policy::Incremental, 8));
        let mut first = FleetSession::new(cfg(Policy::Incremental, 8));
        let stream = first.event_stream();
        // Stop right after round 0: the resumed session's first repair
        // decision depends on last_full_method being restored.
        first.step(&stream[0]);
        let ckpt = first.checkpoint();
        assert_eq!(ckpt.last_full_method, Some("admm"), "round 0 routes to ADMM at J=10");
        let mut resumed = FleetSession::resume(ckpt).unwrap();
        for ev in &stream[1..] {
            resumed.step(ev);
        }
        assert_eq!(resumed.into_report().to_json().pretty(), straight.to_json().pretty());
    }

    #[test]
    fn shared_transport_session_is_deterministic_and_checker_feasible() {
        let mk = || {
            let mut c = cfg(Policy::Incremental, 8);
            c.transport = crate::transport::TransportCfg::shared(2.0);
            c
        };
        let a = run(&mk());
        let b = run(&mk());
        assert_eq!(a.to_json().pretty(), b.to_json().pretty(), "shared mode is deterministic");
        assert!(a.label.contains("link=shared cap=2"), "label records the link mode: {}", a.label);
        // Contention is recorded on loaded rounds (ceil(J/I) > capacity
        // for most of this fleet) and absent from the dedicated run.
        assert!(
            a.rounds.iter().any(|r| r.contention > 0.0),
            "no round recorded uplink contention at capacity 2"
        );
        let ded = run(&cfg(Policy::Incremental, 8));
        assert!(ded.rounds.iter().all(|r| r.contention == 0.0));
        for r in &a.rounds {
            if r.n_clients > 0 {
                assert!(r.makespan_slots >= r.lower_bound, "round {}", r.round);
            }
        }
    }

    #[test]
    #[should_panic(expected = "does not continue the session")]
    fn step_rejects_out_of_order_events() {
        let mut session = FleetSession::new(cfg(Policy::Incremental, 4));
        let stream = session.event_stream();
        session.step(&stream[1]);
    }

    // ---- helper dynamics ----------------------------------------------

    fn down(ev: RoundEvents, ids: Vec<u64>) -> RoundEvents {
        RoundEvents { helper_down: ids, ..ev }
    }

    fn up(ev: RoundEvents, ids: Vec<u64>) -> RoundEvents {
        RoundEvents { helper_up: ids, ..ev }
    }

    /// A 6-client, 3-helper config whose world models helper dynamics
    /// (via the `max_helpers` knob alone — no seeded faults, events are
    /// injected by hand) with the gap and capacity fallbacks disarmed,
    /// so decision assertions isolate the helper ladder.
    fn helper_cfg() -> FleetCfg {
        let scen = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 6, 3, 3);
        let churn = ChurnCfg { rounds: 4, arrival_rate: 0.0, departure_prob: 0.0, max_clients: 8 };
        let mut cfg = FleetCfg::new(scen, churn, Policy::Incremental);
        cfg.gap_threshold = f64::MAX;
        cfg.capacity_threshold = 0.0;
        cfg.helper_churn.max_helpers = 8;
        cfg
    }

    fn helper_session() -> FleetSession {
        FleetSession::new(helper_cfg())
    }

    #[test]
    fn helper_outage_degrades_and_recovers() {
        let roster: Vec<u64> = (0..6).collect();
        let mut s = helper_session();
        let r0 = s.step(&RoundEvents::clients(0, vec![], vec![], roster.clone()));
        assert_eq!(r0.helpers_live, 3);
        assert!(!r0.degraded);
        let r1 = s.step(&down(RoundEvents::clients(1, vec![], vec![], roster.clone()), vec![1]));
        assert_eq!(r1.decision, "helper-degraded", "an outage round keeps the repair");
        assert!(r1.degraded);
        assert_eq!(r1.helpers_live, 2);
        assert_eq!(
            r1.orphaned_clients, r1.migrations,
            "every orphan is migrated when the repair is kept"
        );
        assert_eq!(r1.placed_arrivals, 0, "migrations are not double-counted as arrivals");
        let r2 = s.step(&up(RoundEvents::clients(2, vec![], vec![], roster.clone()), vec![1]));
        assert_eq!(r2.helpers_live, 3);
        assert!(!r2.degraded, "after the outage ends the round is not degraded");
        assert_eq!(r2.decision, "repair", "recovered rounds carry the plain repair tag");
        assert_eq!(r2.orphaned_clients, 0);
    }

    #[test]
    fn capacity_collapse_forces_helper_resolve() {
        let roster: Vec<u64> = (0..6).collect();
        let mut cfg = helper_cfg();
        // Any capacity loss at all is below this threshold, so the first
        // outage round must abandon repair deterministically (the drawn
        // helper memories never enter the comparison).
        cfg.capacity_threshold = 1.0;
        let mut s = FleetSession::new(cfg);
        s.step(&RoundEvents::clients(0, vec![], vec![], roster.clone()));
        let r1 = s.step(&down(RoundEvents::clients(1, vec![], vec![], roster.clone()), vec![0, 2]));
        assert_eq!(r1.decision, "helper-resolve");
        assert!(r1.degraded);
        assert_eq!(r1.helpers_live, 1);
        assert_eq!(r1.migrations, 0, "a full re-solve reseats everyone; nothing counts as migration");
        assert!(r1.makespan_slots >= r1.lower_bound);
    }

    #[test]
    fn orphan_retry_work_is_charged() {
        let roster: Vec<u64> = (0..6).collect();
        let mut s = helper_session();
        s.step(&RoundEvents::clients(0, vec![], vec![], roster.clone()));
        // Down everything but helper 0: a makespan-minimizing round-0
        // solve spreads 6 clients over 3 helpers, so some client must
        // orphan here.
        let r1 = s.step(&down(RoundEvents::clients(1, vec![], vec![], roster.clone()), vec![1, 2]));
        assert_eq!(r1.decision, "helper-degraded");
        assert!(r1.orphaned_clients >= 1, "collapsing to one helper must orphan someone");
        // Work = per-orphan greedy placement (1 live helper each) + the
        // 2-unit forward/backward retry per orphan.
        assert!(
            r1.work_units >= 3 * r1.orphaned_clients as u64,
            "round 1 work {} does not cover {} orphans' placement + retry",
            r1.work_units,
            r1.orphaned_clients
        );
    }

    #[test]
    fn helper_join_expands_the_pool_without_degrading() {
        let roster: Vec<u64> = (0..6).collect();
        let mut s = helper_session();
        s.step(&RoundEvents::clients(0, vec![], vec![], roster.clone()));
        let ev = RoundEvents {
            helper_join: vec![3],
            ..RoundEvents::clients(1, vec![], vec![], roster.clone())
        };
        let r1 = s.step(&ev);
        assert_eq!(r1.helpers_live, 4);
        assert!(!r1.degraded, "a join is growth, not degradation");
        assert_eq!(r1.decision, "repair");
        assert_eq!(s.helper_roster().next_id, 4, "the id watermark advances past the join");
    }

    #[test]
    fn checkpoint_resume_crosses_an_outage_boundary() {
        let roster: Vec<u64> = (0..6).collect();
        let stream = vec![
            RoundEvents::clients(0, vec![], vec![], roster.clone()),
            down(RoundEvents::clients(1, vec![], vec![], roster.clone()), vec![1]),
            up(RoundEvents::clients(2, vec![], vec![], roster.clone()), vec![1]),
            RoundEvents::clients(3, vec![], vec![], roster.clone()),
        ];
        let mut straight = helper_session();
        for ev in &stream {
            straight.step(ev);
        }
        let want = straight.into_report();
        // Checkpoint mid-outage: helper 1 is down when the snapshot lands.
        let mut first = helper_session();
        first.step(&stream[0]);
        first.step(&stream[1]);
        let ckpt = first.checkpoint();
        assert_eq!(ckpt.helpers_down, vec![1]);
        let mut resumed = FleetSession::resume(ckpt).unwrap();
        assert_eq!(resumed.helper_roster().down, vec![1]);
        resumed.step(&stream[2]);
        resumed.step(&stream[3]);
        assert_eq!(
            resumed.into_report().to_json().pretty(),
            want.to_json().pretty(),
            "resume across a HelperDown/HelperUp boundary is byte-identical"
        );
    }

    #[test]
    fn resume_rejects_assignments_to_non_live_helpers() {
        let roster: Vec<u64> = (0..6).collect();
        let mut s = helper_session();
        s.step(&RoundEvents::clients(0, vec![], vec![], roster.clone()));
        let mut ckpt = s.checkpoint();
        // Forge a client pinned to a helper the forged roster marks down.
        ckpt.helpers_live = vec![0, 1];
        ckpt.helpers_down = vec![2];
        ckpt.prev_assign.insert(999, 2);
        ckpt.prev_roster_len += 1;
        let err = FleetSession::resume(ckpt).unwrap_err().to_string();
        assert!(err.contains("not live"), "{err}");
    }

    #[test]
    #[should_panic(expected = "does not model helper")]
    fn step_rejects_helper_events_on_a_static_world() {
        let mut session = FleetSession::new(cfg(Policy::Incremental, 4));
        let stream = session.event_stream();
        session.step(&down(stream[0].clone(), vec![0]));
    }
}
