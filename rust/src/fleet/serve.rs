//! The fleet decision service: a [`FleetSession`] driven over
//! stdin/stdout JSONL.
//!
//! Each input line is one [`RoundEvents`] object (the same line format
//! `psl fleet` records in its `<out>.events.jsonl` sidecar — `round` and
//! `roster` may be omitted and are derived from the session's cursor and
//! previous roster). For every event the session steps one round and
//! writes that round's [`RoundReport`] as a single JSONL line, flushed
//! immediately, so a driving process sees each decision before it must
//! produce the next event.
//!
//! A control line `{"checkpoint": "name"}` snapshots the session under
//! `target/psl-bench/<name>.json` instead of stepping a round; the
//! acknowledgement line `{"checkpointed": path, "round": N}` keeps the
//! stdout stream strictly line-per-input. Periodic `checkpoint_every`
//! snapshots acknowledge on stderr instead, so stdout stays exactly one
//! report line per event — diffable against a batch run's
//! `.rounds.jsonl`.
//!
//! **Fault tolerance.** By default a line that fails (unparseable JSON,
//! an event that does not continue the session, a roster beyond the
//! world cap, helper events against a world that does not model them)
//! emits a structured `{"error": ..., "line": N}` JSONL line and the
//! loop keeps serving subsequent lines — the session never steps a bad
//! round, so committed state stays valid. `ServeOpts::strict` restores
//! fail-fast: the first bad line aborts with a line-numbered error.

use super::events::RoundEvents;
use super::session::FleetSession;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::{BufRead, Write};

/// Serving knobs (all optional).
#[derive(Clone, Debug, Default)]
pub struct ServeOpts {
    /// Snapshot every N stepped rounds (None = only on demand).
    pub checkpoint_every: Option<usize>,
    /// Artifact name periodic snapshots are saved under.
    pub checkpoint_name: String,
    /// Fail fast on the first bad line instead of emitting a structured
    /// `{"error": ...}` line and continuing.
    pub strict: bool,
}

/// What a serve loop did (for the caller's closing diagnostics).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeSummary {
    pub rounds: usize,
    pub checkpoints: usize,
    /// Bad lines answered with `{"error": ...}` (always 0 under strict).
    pub errors: usize,
}

/// A successfully handled line's stdout payload.
enum LineOut {
    Report(String),
    Ack(String),
}

/// Drive `session` over `input` lines until EOF, writing one line per
/// input line to `out` (a round report, a checkpoint ack, or — lenient
/// mode — a structured error). A bad line never steps the session, so
/// committed rounds stay valid either way; under `ServeOpts::strict` it
/// aborts with a line-numbered error instead. I/O failures on the
/// streams themselves are always fatal.
pub fn serve<R: BufRead, W: Write>(
    session: &mut FleetSession,
    input: R,
    mut out: W,
    opts: &ServeOpts,
) -> Result<ServeSummary> {
    let mut summary = ServeSummary::default();
    for (k, line) in input.lines().enumerate() {
        let lineno = k + 1;
        let line = line.with_context(|| format!("read event line {lineno}"))?;
        let text = line.trim();
        if text.is_empty() {
            continue;
        }
        // Everything fallible about this line lands in one Result; the
        // match below decides structured-error-line vs strict abort.
        let outcome: Result<LineOut> = (|| {
            let doc = Json::parse(text)?;
            if let Some(name) = checkpoint_request(&doc) {
                let path = session
                    .checkpoint()
                    .save(name)
                    .with_context(|| format!("save checkpoint {name:?}"))?;
                let ack = Json::obj(vec![
                    ("checkpointed", Json::Str(path.display().to_string())),
                    ("round", Json::Num(session.next_round() as f64)),
                ]);
                summary.checkpoints += 1;
                return Ok(LineOut::Ack(ack.dump()));
            }
            // Round 0's implicit previous roster is the base population
            // (the generated stream states it in `roster` without
            // arrival events).
            let prev_roster =
                if session.next_round() == 0 { session.base_roster() } else { session.roster() };
            let ev = RoundEvents::from_json(
                &doc,
                session.next_round(),
                &prev_roster,
                session.helper_roster(),
            )?;
            anyhow::ensure!(
                ev.roster.len() <= session.max_clients(),
                "roster of {} exceeds the world's max-clients {} — restart serve with a \
                 larger --max-clients (the memory repair is sized at construction)",
                ev.roster.len(),
                session.max_clients()
            );
            anyhow::ensure!(
                !ev.has_helper_events() || session.helper_modeled(),
                "helper events need a world that models helper dynamics — restart serve \
                 with a helper knob (--max-helpers, --helper-down-rate, ...)"
            );
            // The serve-side latency measurement (ROADMAP: measured
            // per-event decision latency): wall-clock around the step,
            // logged at debug level and recorded on the round's trace
            // span. Diagnostics only — the report line is untouched.
            let t0 = std::time::Instant::now();
            let mut sp = crate::obs::span("serve", "serve/round");
            let report = session.step(&ev);
            let us = t0.elapsed().as_micros() as u64;
            sp.arg("round", report.round as u64);
            sp.arg("latency_us", us);
            drop(sp);
            crate::log_debug!("round {} stepped in {} us", report.round, us);
            summary.rounds += 1;
            Ok(LineOut::Report(report.jsonl_line()))
        })();
        match outcome {
            Ok(LineOut::Ack(ack)) => {
                writeln!(out, "{ack}").context("write checkpoint ack")?;
                out.flush().context("flush checkpoint ack")?;
            }
            Ok(LineOut::Report(line)) => {
                let round = session.next_round() - 1;
                writeln!(out, "{line}").with_context(|| format!("write round {round}"))?;
                out.flush().with_context(|| format!("flush round {round}"))?;
                if let Some(every) = opts.checkpoint_every {
                    if every >= 1 && session.next_round() % every == 0 {
                        let path = session
                            .checkpoint()
                            .save(&opts.checkpoint_name)
                            .with_context(|| format!("save periodic checkpoint after round {round}"))?;
                        eprintln!(
                            "serve: checkpoint -> {} (round {})",
                            path.display(),
                            session.next_round()
                        );
                        summary.checkpoints += 1;
                    }
                }
            }
            Err(e) => {
                if opts.strict {
                    return Err(e.context(format!("event line {lineno}")));
                }
                let err_line = Json::obj(vec![
                    ("error", Json::Str(format!("{e:#}"))),
                    ("line", Json::Num(lineno as f64)),
                ]);
                writeln!(out, "{}", err_line.dump()).context("write error line")?;
                out.flush().context("flush error line")?;
                summary.errors += 1;
            }
        }
    }
    Ok(summary)
}

/// A `{"checkpoint": "name"}` control line (no other event fields carry
/// that key).
fn checkpoint_request(doc: &Json) -> Option<&str> {
    doc.get("checkpoint").as_str()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::checkpoint::FleetCheckpoint;
    use crate::fleet::events::ChurnCfg;
    use crate::fleet::orchestrator::{run, FleetCfg, Policy};
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};

    fn cfg(rounds: usize) -> FleetCfg {
        let scen = ScenarioCfg::new(Scenario::S4StragglerTail, Model::Vgg19, 6, 2, 9);
        let mut churn = ChurnCfg::stationary(6);
        churn.rounds = rounds;
        FleetCfg::new(scen, churn, Policy::Incremental)
    }

    fn event_log(cfg: &FleetCfg) -> String {
        let session = FleetSession::new(cfg.clone());
        session.event_stream().iter().map(|ev| ev.jsonl_line() + "\n").collect()
    }

    #[test]
    fn serve_replays_the_batch_run_byte_identically() {
        let batch = run(&cfg(6));
        let input = event_log(&cfg(6));
        let mut out = Vec::new();
        let mut session = FleetSession::new(cfg(6));
        let summary = serve(&mut session, input.as_bytes(), &mut out, &ServeOpts::default()).unwrap();
        assert_eq!(summary, ServeSummary { rounds: 6, checkpoints: 0, errors: 0 });
        let expect: String = batch.rounds.iter().map(|r| r.jsonl_line() + "\n").collect();
        assert_eq!(String::from_utf8(out).unwrap(), expect, "stdout == the batch run's rounds_detail");
    }

    #[test]
    fn serve_accepts_minimal_event_lines() {
        // Lines carrying only arrivals/departures (no round, no roster)
        // — the schema a human or an external controller writes.
        let input = "\
{\"arrivals\": [], \"departures\": []}\n\
\n\
{\"departures\": [0, 3]}\n\
{\"arrivals\": [6]}\n";
        let mut out = Vec::new();
        let mut session = FleetSession::new(cfg(4));
        let summary = serve(&mut session, input.as_bytes(), &mut out, &ServeOpts::default()).unwrap();
        assert_eq!(summary.rounds, 3, "blank lines are skipped");
        assert_eq!(session.roster(), vec![1, 2, 4, 5, 6]);
    }

    fn strict() -> ServeOpts {
        ServeOpts { strict: true, ..ServeOpts::default() }
    }

    #[test]
    fn strict_mode_rejects_bad_events_with_line_numbers() {
        let mut session = FleetSession::new(cfg(4));
        let err = serve(&mut session, "not json\n".as_bytes(), &mut Vec::new(), &strict())
            .unwrap_err()
            .to_string();
        assert!(err.contains("line 1"), "{err}");

        let mut session = FleetSession::new(cfg(4));
        let input = "{\"arrivals\": []}\n{\"round\": 7}\n";
        let err = serve(&mut session, input.as_bytes(), &mut Vec::new(), &strict())
            .unwrap_err()
            .to_string();
        assert!(err.contains("line 2"), "{err}");
        assert_eq!(session.next_round(), 1, "committed rounds survive the abort");
    }

    #[test]
    fn strict_mode_rejects_rosters_beyond_the_world_cap() {
        let mut session = FleetSession::new(cfg(4));
        let cap = session.max_clients();
        let arrivals: Vec<String> = (6..2 + cap as u64).map(|id| id.to_string()).collect();
        let input = format!("{{\"arrivals\": [{}]}}\n", arrivals.join(", "));
        let err = serve(&mut session, input.as_bytes(), &mut Vec::new(), &strict())
            .unwrap_err()
            .to_string();
        assert!(err.contains("max-clients"), "{err}");
    }

    #[test]
    fn lenient_mode_answers_bad_lines_and_keeps_serving() {
        // Default (lenient) mode: line 1 is garbage, line 2 names the
        // wrong round, lines 3-4 are fine — the bad lines get structured
        // error answers and the good lines still step rounds.
        let input = "not json\n{\"round\": 7}\n{\"arrivals\": []}\n{\"departures\": [0]}\n";
        let mut out = Vec::new();
        let mut session = FleetSession::new(cfg(4));
        let summary = serve(&mut session, input.as_bytes(), &mut out, &ServeOpts::default()).unwrap();
        assert_eq!(summary, ServeSummary { rounds: 2, checkpoints: 0, errors: 2 });
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
        assert_eq!(lines.len(), 4, "one answer line per input line");
        assert!(lines[0].get("error").as_str().is_some());
        assert_eq!(lines[0].get("line").as_usize(), Some(1));
        assert!(lines[1].get("error").as_str().unwrap().contains("round 7"), "{}", text);
        assert_eq!(lines[1].get("line").as_usize(), Some(2));
        assert_eq!(lines[2].get("round").as_usize(), Some(0));
        assert_eq!(lines[3].get("round").as_usize(), Some(1));
        assert_eq!(session.next_round(), 2);
    }

    #[test]
    fn helper_events_are_rejected_on_a_static_world() {
        // cfg() is an S4 scenario: no helper churn is modeled, so a
        // helper event must be refused before it can reach step() —
        // leniently as an error line, strictly as an abort.
        let input = "{\"helper_down\": [0]}\n{\"arrivals\": []}\n";
        let mut out = Vec::new();
        let mut session = FleetSession::new(cfg(4));
        let summary = serve(&mut session, input.as_bytes(), &mut out, &ServeOpts::default()).unwrap();
        assert_eq!(summary, ServeSummary { rounds: 1, checkpoints: 0, errors: 1 });
        let text = String::from_utf8(out).unwrap();
        let first = Json::parse(text.lines().next().unwrap()).unwrap();
        assert!(first.get("error").as_str().unwrap().contains("--max-helpers"), "{text}");

        let mut session = FleetSession::new(cfg(4));
        let err = serve(&mut session, input.as_bytes(), &mut Vec::new(), &strict())
            .unwrap_err()
            .to_string();
        assert!(err.contains("line 1"), "{err}");
    }

    #[test]
    fn serve_replays_helper_outages_byte_identically() {
        // An s7-helper-bursts session's generated stream carries helper
        // events; feeding it back through serve must reproduce the batch
        // run's report lines exactly (closing the serve half of the
        // helper-dynamics loop).
        let scen = ScenarioCfg::new(Scenario::S7HelperBursts, Model::Vgg19, 6, 3, 9);
        let mut churn = ChurnCfg::stationary(6);
        churn.rounds = 20;
        let cfg = FleetCfg::new(scen, churn, Policy::Incremental);
        let input = event_log(&cfg);
        assert!(input.contains("helper_down"), "stream carries helper outages:\n{input}");
        let batch = run(&cfg);
        let mut out = Vec::new();
        let mut session = FleetSession::new(cfg.clone());
        let summary = serve(&mut session, input.as_bytes(), &mut out, &ServeOpts::default()).unwrap();
        assert_eq!(summary, ServeSummary { rounds: 20, checkpoints: 0, errors: 0 });
        let expect: String = batch.rounds.iter().map(|r| r.jsonl_line() + "\n").collect();
        assert_eq!(String::from_utf8(out).unwrap(), expect);
    }

    #[test]
    fn serve_replays_shared_transport_byte_identically() {
        // A shared-uplink session served over JSONL must reproduce the
        // batch run's report lines exactly — the transport model lives
        // in FleetCfg, so serve needs no knowledge of it beyond the
        // session it drives.
        let mut shared_cfg = cfg(6);
        shared_cfg.transport = crate::transport::TransportCfg::shared(2.0);
        let input = event_log(&shared_cfg);
        let batch = run(&shared_cfg);
        let mut out = Vec::new();
        let mut session = FleetSession::new(shared_cfg);
        let summary = serve(&mut session, input.as_bytes(), &mut out, &ServeOpts::default()).unwrap();
        assert_eq!(summary, ServeSummary { rounds: 6, checkpoints: 0, errors: 0 });
        let expect: String = batch.rounds.iter().map(|r| r.jsonl_line() + "\n").collect();
        assert_eq!(String::from_utf8(out).unwrap(), expect);
    }

    #[test]
    fn checkpoint_control_line_snapshots_and_acks() {
        let name = format!("serve-ckpt-test-{}", std::process::id());
        let input = format!(
            "{}\n{{\"checkpoint\": \"{name}\"}}\n",
            FleetSession::new(cfg(4)).event_stream()[0].jsonl_line()
        );
        let mut out = Vec::new();
        let mut session = FleetSession::new(cfg(4));
        let summary = serve(&mut session, input.as_bytes(), &mut out, &ServeOpts::default()).unwrap();
        assert_eq!(summary, ServeSummary { rounds: 1, checkpoints: 1, errors: 0 });
        let text = String::from_utf8(out).unwrap();
        let ack = Json::parse(text.lines().last().unwrap()).unwrap();
        let path = ack.get("checkpointed").as_str().unwrap().to_string();
        assert_eq!(ack.get("round").as_usize(), Some(1));
        let ckpt = FleetCheckpoint::load(&path).unwrap();
        assert_eq!(ckpt.next_round, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn periodic_checkpoints_keep_stdout_clean() {
        let name = format!("serve-ckpt-periodic-{}", std::process::id());
        let input = event_log(&cfg(5));
        let mut out = Vec::new();
        let mut session = FleetSession::new(cfg(5));
        let opts =
            ServeOpts { checkpoint_every: Some(2), checkpoint_name: name.clone(), strict: false };
        let summary = serve(&mut session, input.as_bytes(), &mut out, &opts).unwrap();
        assert_eq!(summary, ServeSummary { rounds: 5, checkpoints: 2, errors: 0 });
        let text = String::from_utf8(out).unwrap();
        assert_eq!(text.lines().count(), 5, "one report line per event, acks on stderr only");
        assert!(text.lines().all(|l| Json::parse(l).unwrap().get("round").as_usize().is_some()));
        let path = format!("target/psl-bench/{name}.json");
        let ckpt = FleetCheckpoint::load(&path).unwrap();
        assert_eq!(ckpt.next_round, 4, "last periodic snapshot is after round 4");
        std::fs::remove_file(&path).ok();
    }
}
