//! The fleet decision service: a [`FleetSession`] driven over
//! stdin/stdout JSONL.
//!
//! Each input line is one [`RoundEvents`] object (the same line format
//! `psl fleet` records in its `<out>.events.jsonl` sidecar — `round` and
//! `roster` may be omitted and are derived from the session's cursor and
//! previous roster). For every event the session steps one round and
//! writes that round's [`RoundReport`] as a single JSONL line, flushed
//! immediately, so a driving process sees each decision before it must
//! produce the next event.
//!
//! A control line `{"checkpoint": "name"}` snapshots the session under
//! `target/psl-bench/<name>.json` instead of stepping a round; the
//! acknowledgement line `{"checkpointed": path, "round": N}` keeps the
//! stdout stream strictly line-per-input. Periodic `checkpoint_every`
//! snapshots acknowledge on stderr instead, so stdout stays exactly one
//! report line per event — diffable against a batch run's
//! `.rounds.jsonl`.

use super::events::RoundEvents;
use super::session::FleetSession;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::{BufRead, Write};

/// Serving knobs (all optional).
#[derive(Clone, Debug, Default)]
pub struct ServeOpts {
    /// Snapshot every N stepped rounds (None = only on demand).
    pub checkpoint_every: Option<usize>,
    /// Artifact name periodic snapshots are saved under.
    pub checkpoint_name: String,
}

/// What a serve loop did (for the caller's closing diagnostics).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeSummary {
    pub rounds: usize,
    pub checkpoints: usize,
}

/// Drive `session` over `input` lines until EOF, writing one report line
/// per event to `out`. Any malformed or discontinuous event aborts with
/// a line-numbered error — the session's committed rounds stay valid (a
/// periodic checkpoint, if configured, allows resuming).
pub fn serve<R: BufRead, W: Write>(
    session: &mut FleetSession,
    input: R,
    mut out: W,
    opts: &ServeOpts,
) -> Result<ServeSummary> {
    let mut summary = ServeSummary::default();
    for (k, line) in input.lines().enumerate() {
        let lineno = k + 1;
        let line = line.with_context(|| format!("read event line {lineno}"))?;
        let text = line.trim();
        if text.is_empty() {
            continue;
        }
        let doc = Json::parse(text).with_context(|| format!("event line {lineno}"))?;
        if let Some(name) = checkpoint_request(&doc) {
            let path = session
                .checkpoint()
                .save(name)
                .with_context(|| format!("save checkpoint {name:?} (event line {lineno})"))?;
            let ack = Json::obj(vec![
                ("checkpointed", Json::Str(path.display().to_string())),
                ("round", Json::Num(session.next_round() as f64)),
            ]);
            writeln!(out, "{}", ack.dump()).context("write checkpoint ack")?;
            out.flush().context("flush checkpoint ack")?;
            summary.checkpoints += 1;
            continue;
        }
        // Round 0's implicit previous roster is the base population (the
        // generated stream states it in `roster` without arrival events).
        let prev_roster =
            if session.next_round() == 0 { session.base_roster() } else { session.roster() };
        let ev = RoundEvents::from_json(&doc, session.next_round(), &prev_roster)
            .with_context(|| format!("event line {lineno}"))?;
        anyhow::ensure!(
            ev.roster.len() <= session.max_clients(),
            "event line {lineno}: roster of {} exceeds the world's max-clients {} — \
             restart serve with a larger --max-clients (the memory repair is sized at construction)",
            ev.roster.len(),
            session.max_clients()
        );
        let report = session.step(&ev);
        writeln!(out, "{}", report.jsonl_line()).with_context(|| format!("write round {}", report.round))?;
        out.flush().with_context(|| format!("flush round {}", report.round))?;
        summary.rounds += 1;
        if let Some(every) = opts.checkpoint_every {
            if every >= 1 && session.next_round() % every == 0 {
                let path = session
                    .checkpoint()
                    .save(&opts.checkpoint_name)
                    .with_context(|| format!("save periodic checkpoint after round {}", report.round))?;
                eprintln!("serve: checkpoint -> {} (round {})", path.display(), session.next_round());
                summary.checkpoints += 1;
            }
        }
    }
    Ok(summary)
}

/// A `{"checkpoint": "name"}` control line (no other event fields carry
/// that key).
fn checkpoint_request(doc: &Json) -> Option<&str> {
    doc.get("checkpoint").as_str()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::checkpoint::FleetCheckpoint;
    use crate::fleet::events::ChurnCfg;
    use crate::fleet::orchestrator::{run, FleetCfg, Policy};
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};

    fn cfg(rounds: usize) -> FleetCfg {
        let scen = ScenarioCfg::new(Scenario::S4StragglerTail, Model::Vgg19, 6, 2, 9);
        let mut churn = ChurnCfg::stationary(6);
        churn.rounds = rounds;
        FleetCfg::new(scen, churn, Policy::Incremental)
    }

    fn event_log(cfg: &FleetCfg) -> String {
        let session = FleetSession::new(cfg.clone());
        session.event_stream().iter().map(|ev| ev.jsonl_line() + "\n").collect()
    }

    #[test]
    fn serve_replays_the_batch_run_byte_identically() {
        let batch = run(&cfg(6));
        let input = event_log(&cfg(6));
        let mut out = Vec::new();
        let mut session = FleetSession::new(cfg(6));
        let summary = serve(&mut session, input.as_bytes(), &mut out, &ServeOpts::default()).unwrap();
        assert_eq!(summary, ServeSummary { rounds: 6, checkpoints: 0 });
        let expect: String = batch.rounds.iter().map(|r| r.jsonl_line() + "\n").collect();
        assert_eq!(String::from_utf8(out).unwrap(), expect, "stdout == the batch run's rounds_detail");
    }

    #[test]
    fn serve_accepts_minimal_event_lines() {
        // Lines carrying only arrivals/departures (no round, no roster)
        // — the schema a human or an external controller writes.
        let input = "\
{\"arrivals\": [], \"departures\": []}\n\
\n\
{\"departures\": [0, 3]}\n\
{\"arrivals\": [6]}\n";
        let mut out = Vec::new();
        let mut session = FleetSession::new(cfg(4));
        let summary = serve(&mut session, input.as_bytes(), &mut out, &ServeOpts::default()).unwrap();
        assert_eq!(summary.rounds, 3, "blank lines are skipped");
        assert_eq!(session.roster(), vec![1, 2, 4, 5, 6]);
    }

    #[test]
    fn serve_rejects_bad_events_with_line_numbers() {
        let mut session = FleetSession::new(cfg(4));
        let err = serve(&mut session, "not json\n".as_bytes(), &mut Vec::new(), &ServeOpts::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("line 1"), "{err}");

        let mut session = FleetSession::new(cfg(4));
        let input = "{\"arrivals\": []}\n{\"round\": 7}\n";
        let err = serve(&mut session, input.as_bytes(), &mut Vec::new(), &ServeOpts::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("line 2"), "{err}");
        assert_eq!(session.next_round(), 1, "committed rounds survive the abort");
    }

    #[test]
    fn serve_rejects_rosters_beyond_the_world_cap() {
        let mut session = FleetSession::new(cfg(4));
        let cap = session.max_clients();
        let arrivals: Vec<String> = (6..2 + cap as u64).map(|id| id.to_string()).collect();
        let input = format!("{{\"arrivals\": [{}]}}\n", arrivals.join(", "));
        let err = serve(&mut session, input.as_bytes(), &mut Vec::new(), &ServeOpts::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("max-clients"), "{err}");
    }

    #[test]
    fn checkpoint_control_line_snapshots_and_acks() {
        let name = format!("serve-ckpt-test-{}", std::process::id());
        let input = format!(
            "{}\n{{\"checkpoint\": \"{name}\"}}\n",
            FleetSession::new(cfg(4)).event_stream()[0].jsonl_line()
        );
        let mut out = Vec::new();
        let mut session = FleetSession::new(cfg(4));
        let summary = serve(&mut session, input.as_bytes(), &mut out, &ServeOpts::default()).unwrap();
        assert_eq!(summary, ServeSummary { rounds: 1, checkpoints: 1 });
        let text = String::from_utf8(out).unwrap();
        let ack = Json::parse(text.lines().last().unwrap()).unwrap();
        let path = ack.get("checkpointed").as_str().unwrap().to_string();
        assert_eq!(ack.get("round").as_usize(), Some(1));
        let ckpt = FleetCheckpoint::load(&path).unwrap();
        assert_eq!(ckpt.next_round, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn periodic_checkpoints_keep_stdout_clean() {
        let name = format!("serve-ckpt-periodic-{}", std::process::id());
        let input = event_log(&cfg(5));
        let mut out = Vec::new();
        let mut session = FleetSession::new(cfg(5));
        let opts = ServeOpts { checkpoint_every: Some(2), checkpoint_name: name.clone() };
        let summary = serve(&mut session, input.as_bytes(), &mut out, &opts).unwrap();
        assert_eq!(summary, ServeSummary { rounds: 5, checkpoints: 2 });
        let text = String::from_utf8(out).unwrap();
        assert_eq!(text.lines().count(), 5, "one report line per event, acks on stderr only");
        assert!(text.lines().all(|l| Json::parse(l).unwrap().get("round").as_usize().is_some()));
        let path = format!("target/psl-bench/{name}.json");
        let ckpt = FleetCheckpoint::load(&path).unwrap();
        assert_eq!(ckpt.next_round, 4, "last periodic snapshot is after round 4");
        std::fs::remove_file(&path).ok();
    }
}
