//! Deterministic arrival/departure event streams for multi-round fleets.
//!
//! Round 0 is the base population (ids `0..J`). Between consecutive
//! rounds each present client departs with probability `departure_prob`
//! and `Poisson(arrival_rate)` new clients arrive (capped so the roster
//! never exceeds `max_clients`, the bound the [`FleetWorld`]'s memory
//! repair was sized for). Arrival ids continue the sequence and are never
//! reused, so a client's identity — and every draw behind it — is stable
//! across the whole run.
//!
//! The stream is a pure function of `(base population, churn knobs,
//! seed)`: replaying a fleet run with the same tuple reproduces the exact
//! same membership history, independent of thread count or wall clock.
//!
//! [`FleetWorld`]: crate::instance::scenario::FleetWorld

use crate::util::rng::{fnv64 as fnv, Rng};

/// Churn-process knobs for a fleet run.
#[derive(Clone, Debug)]
pub struct ChurnCfg {
    /// Number of training rounds to simulate (≥ 1).
    pub rounds: usize,
    /// Expected arrivals per round (Poisson rate).
    pub arrival_rate: f64,
    /// Per-client per-round departure probability.
    pub departure_prob: f64,
    /// Hard roster-size cap; arrivals beyond it are deferred (dropped
    /// from this round's admission, the rate keeps pressure up). A base
    /// population larger than the cap raises the effective cap to the
    /// base size — the initial fleet is never evicted to fit (the
    /// [`FleetWorld`] memory repair applies the same `max(base)` rule).
    ///
    /// [`FleetWorld`]: crate::instance::scenario::FleetWorld
    pub max_clients: usize,
}

impl ChurnCfg {
    /// Stationary default for a base population of `j`: departures at
    /// rate 0.12 balanced by 0.12·J expected arrivals, roster capped at
    /// 2·J.
    pub fn stationary(j: usize) -> ChurnCfg {
        ChurnCfg {
            rounds: 8,
            arrival_rate: 0.12 * j as f64,
            departure_prob: 0.12,
            max_clients: (2 * j).max(1),
        }
    }
}

/// Membership delta and resulting roster for one round.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RoundEvents {
    pub round: usize,
    /// Ids departing before this round (subset of the previous roster).
    pub departures: Vec<u64>,
    /// Ids arriving before this round (freshly minted, strictly above
    /// every id seen so far).
    pub arrivals: Vec<u64>,
    /// Membership for this round, sorted by id.
    pub roster: Vec<u64>,
}

impl RoundEvents {
    /// Fraction of the previous roster that changed (arrivals +
    /// departures over the previous size) — the orchestrator's churn
    /// drift signal.
    pub fn churn_fraction(&self, prev_roster_len: usize) -> f64 {
        (self.arrivals.len() + self.departures.len()) as f64 / prev_roster_len.max(1) as f64
    }
}

/// Knuth's Poisson sampler (λ small — per-round arrival rates).
fn poisson(rng: &mut Rng, lambda: f64) -> usize {
    if lambda <= 0.0 {
        return 0;
    }
    let l = (-lambda).exp();
    let mut k = 0usize;
    let mut p = 1.0;
    loop {
        p *= rng.f64();
        if p <= l || k >= 10_000 {
            return k;
        }
        k += 1;
    }
}

/// Generate the full event stream for a run. `base_clients` are ids
/// `0..J` present in round 0; `seed` should already mix the scenario
/// tuple (the orchestrator passes `cfg.seed ^ fnv(spec.name)`); the
/// stream label is mixed in here.
pub fn generate(base_clients: usize, churn: &ChurnCfg, seed: u64) -> Vec<RoundEvents> {
    assert!(churn.rounds >= 1, "a fleet run needs at least one round");
    let cap = churn.max_clients.max(base_clients);
    let mut rng = Rng::seeded(seed ^ fnv("fleet-events"));
    let mut roster: Vec<u64> = (0..base_clients as u64).collect();
    let mut next_id = base_clients as u64;
    let mut out = Vec::with_capacity(churn.rounds);
    out.push(RoundEvents { round: 0, departures: vec![], arrivals: vec![], roster: roster.clone() });
    for round in 1..churn.rounds {
        let mut departures = Vec::new();
        let mut stayed = Vec::with_capacity(roster.len());
        for &id in &roster {
            if rng.chance(churn.departure_prob) {
                departures.push(id);
            } else {
                stayed.push(id);
            }
        }
        let want = poisson(&mut rng, churn.arrival_rate);
        let admit = want.min(cap.saturating_sub(stayed.len()));
        let arrivals: Vec<u64> = (0..admit as u64).map(|k| next_id + k).collect();
        next_id += admit as u64;
        roster = stayed;
        roster.extend(&arrivals);
        roster.sort_unstable();
        out.push(RoundEvents { round, departures, arrivals, roster: roster.clone() });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn churn() -> ChurnCfg {
        ChurnCfg { rounds: 12, arrival_rate: 1.5, departure_prob: 0.2, max_clients: 20 }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(10, &churn(), 7);
        let b = generate(10, &churn(), 7);
        assert_eq!(a, b);
        let c = generate(10, &churn(), 8);
        assert_ne!(a, c, "different seeds must yield different streams");
    }

    #[test]
    fn round0_is_base_population() {
        let ev = generate(6, &churn(), 3);
        assert_eq!(ev[0].roster, vec![0, 1, 2, 3, 4, 5]);
        assert!(ev[0].arrivals.is_empty() && ev[0].departures.is_empty());
    }

    #[test]
    fn ids_never_reused_and_monotone() {
        let ev = generate(8, &churn(), 11);
        let mut seen: std::collections::BTreeSet<u64> = ev[0].roster.iter().copied().collect();
        for r in &ev[1..] {
            for &id in &r.arrivals {
                assert!(id >= seen.iter().max().map(|&m| m + 1).unwrap_or(0), "arrival id {id} not fresh");
                assert!(seen.insert(id), "id {id} reused");
            }
        }
    }

    #[test]
    fn roster_evolution_consistent() {
        let ev = generate(8, &churn(), 5);
        for w in ev.windows(2) {
            let (prev, next) = (&w[0], &w[1]);
            let mut expect: Vec<u64> = prev.roster.iter().copied().filter(|id| !next.departures.contains(id)).collect();
            expect.extend(&next.arrivals);
            expect.sort_unstable();
            assert_eq!(next.roster, expect, "round {}", next.round);
            assert!(next.departures.iter().all(|id| prev.roster.contains(id)));
        }
    }

    #[test]
    fn max_clients_respected() {
        let cfg = ChurnCfg { rounds: 30, arrival_rate: 5.0, departure_prob: 0.01, max_clients: 12 };
        for r in generate(10, &cfg, 4) {
            assert!(r.roster.len() <= 12, "round {} roster {}", r.round, r.roster.len());
        }
    }

    #[test]
    fn base_population_larger_than_cap_is_never_evicted() {
        // The cap governs admission, not eviction: a base fleet bigger
        // than max_clients stays whole, and no arrivals are admitted
        // until departures open headroom under the raised cap.
        let cfg = ChurnCfg { rounds: 5, arrival_rate: 3.0, departure_prob: 0.0, max_clients: 4 };
        let ev = generate(10, &cfg, 6);
        assert_eq!(ev[0].roster.len(), 10);
        for r in &ev {
            assert_eq!(r.roster.len(), 10, "effective cap = base size");
            assert!(r.arrivals.is_empty(), "no headroom below the raised cap");
        }
    }

    #[test]
    fn zero_churn_keeps_roster_static() {
        let cfg = ChurnCfg { rounds: 6, arrival_rate: 0.0, departure_prob: 0.0, max_clients: 10 };
        let ev = generate(5, &cfg, 9);
        for r in &ev {
            assert_eq!(r.roster, ev[0].roster);
            assert!(r.arrivals.is_empty() && r.departures.is_empty());
        }
    }

    #[test]
    fn full_departure_rounds_are_representable() {
        // With certain departure and no arrivals the roster empties and
        // stays empty — the stream itself never panics.
        let cfg = ChurnCfg { rounds: 4, arrival_rate: 0.0, departure_prob: 1.0, max_clients: 10 };
        let ev = generate(3, &cfg, 2);
        assert_eq!(ev[1].departures.len(), 3);
        assert!(ev[1].roster.is_empty());
        assert!(ev[3].roster.is_empty());
    }

    #[test]
    fn churn_fraction_counts_both_directions() {
        let r = RoundEvents { round: 1, departures: vec![0, 1], arrivals: vec![9], roster: vec![2, 9] };
        assert!((r.churn_fraction(3) - 1.0).abs() < 1e-12);
        assert!((r.churn_fraction(0) - 3.0).abs() < 1e-12, "empty previous roster guards the division");
    }

    #[test]
    fn poisson_mean_in_ballpark() {
        let mut rng = Rng::seeded(13);
        let n = 20_000;
        let total: usize = (0..n).map(|_| poisson(&mut rng, 2.5)).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - 2.5).abs() < 0.1, "poisson mean {mean}");
    }
}
