//! Deterministic arrival/departure event streams for multi-round fleets.
//!
//! Round 0 is the base population (ids `0..J`). Between consecutive
//! rounds each present client departs with probability `departure_prob`
//! and `Poisson(arrival_rate)` new clients arrive (capped so the roster
//! never exceeds `max_clients`, the bound the [`FleetWorld`]'s memory
//! repair was sized for). Arrival ids continue the sequence and are never
//! reused, so a client's identity — and every draw behind it — is stable
//! across the whole run.
//!
//! The stream is a pure function of `(base population, churn knobs,
//! seed)`: replaying a fleet run with the same tuple reproduces the exact
//! same membership history, independent of thread count or wall clock.
//!
//! [`FleetWorld`]: crate::instance::scenario::FleetWorld

use crate::util::json::Json;
use crate::util::rng::{fnv64 as fnv, Rng};
use anyhow::{Context, Result};

/// Churn-process knobs for a fleet run.
#[derive(Clone, Debug)]
pub struct ChurnCfg {
    /// Number of training rounds to simulate (≥ 1).
    pub rounds: usize,
    /// Expected arrivals per round (Poisson rate).
    pub arrival_rate: f64,
    /// Per-client per-round departure probability.
    pub departure_prob: f64,
    /// Hard roster-size cap; arrivals beyond it are deferred (dropped
    /// from this round's admission, the rate keeps pressure up). A base
    /// population larger than the cap raises the effective cap to the
    /// base size — the initial fleet is never evicted to fit (the
    /// [`FleetWorld`] memory repair applies the same `max(base)` rule).
    ///
    /// [`FleetWorld`]: crate::instance::scenario::FleetWorld
    pub max_clients: usize,
}

impl ChurnCfg {
    /// Stationary default for a base population of `j`: departures at
    /// rate 0.12 balanced by 0.12·J expected arrivals, roster capped at
    /// 2·J.
    pub fn stationary(j: usize) -> ChurnCfg {
        ChurnCfg {
            rounds: 8,
            arrival_rate: 0.12 * j as f64,
            departure_prob: 0.12,
            max_clients: (2 * j).max(1),
        }
    }
}

/// Membership delta and resulting roster for one round.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RoundEvents {
    pub round: usize,
    /// Ids departing before this round (subset of the previous roster).
    pub departures: Vec<u64>,
    /// Ids arriving before this round (freshly minted, strictly above
    /// every id seen so far).
    pub arrivals: Vec<u64>,
    /// Membership for this round, sorted by id.
    pub roster: Vec<u64>,
}

impl RoundEvents {
    /// Fraction of the previous roster that changed (arrivals +
    /// departures over the previous size) — the orchestrator's churn
    /// drift signal.
    pub fn churn_fraction(&self, prev_roster_len: usize) -> f64 {
        (self.arrivals.len() + self.departures.len()) as f64 / prev_roster_len.max(1) as f64
    }

    /// The event's JSON object — one line of the `<out>.events.jsonl`
    /// sidecar, and the line format `psl serve` consumes on stdin.
    pub fn to_json(&self) -> Json {
        let ids = |v: &[u64]| Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect());
        Json::obj(vec![
            ("round", Json::Num(self.round as f64)),
            ("arrivals", ids(&self.arrivals)),
            ("departures", ids(&self.departures)),
            ("roster", ids(&self.roster)),
        ])
    }

    /// Single-line JSON for event-log streaming (JSONL).
    pub fn jsonl_line(&self) -> String {
        self.to_json().dump()
    }

    /// Parse one event line against the session's expected position.
    /// `round` and `roster` are optional on the wire (a hand-written
    /// event only needs `arrivals`/`departures`); when present they must
    /// agree with `expect_round` and with the membership delta applied to
    /// `prev_roster` (which must be sorted — it is the previous event's
    /// `roster`).
    pub fn from_json(doc: &Json, expect_round: usize, prev_roster: &[u64]) -> Result<RoundEvents> {
        doc.as_obj().context("event is not a JSON object")?;
        let ids = |key: &str| -> Result<Vec<u64>> {
            let mut out = Vec::new();
            match doc.get(key) {
                Json::Null => {}
                v => {
                    for x in v.as_arr().with_context(|| format!("event {key:?} is not an array"))? {
                        let f = x.as_f64().with_context(|| format!("event {key:?} entry {x} is not a number"))?;
                        anyhow::ensure!(
                            f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64,
                            "event {key:?} entry {f} is not a client id"
                        );
                        out.push(f as u64);
                    }
                }
            }
            out.sort_unstable();
            anyhow::ensure!(out.windows(2).all(|w| w[0] != w[1]), "event {key:?} has duplicate ids");
            Ok(out)
        };
        let round = match doc.get("round") {
            Json::Null => expect_round,
            v => v.as_usize().with_context(|| format!("event round {v} is not an integer"))?,
        };
        anyhow::ensure!(
            round == expect_round,
            "event round {round} does not continue the session (expected round {expect_round})"
        );
        let departures = ids("departures")?;
        for id in &departures {
            anyhow::ensure!(
                prev_roster.binary_search(id).is_ok(),
                "departure id {id} is not in the previous roster"
            );
        }
        let arrivals = ids("arrivals")?;
        let mut roster: Vec<u64> =
            prev_roster.iter().copied().filter(|id| departures.binary_search(id).is_err()).collect();
        for id in &arrivals {
            anyhow::ensure!(
                roster.binary_search(id).is_err(),
                "arrival id {id} is already in the roster (ids are never reused)"
            );
            roster.push(*id);
        }
        roster.sort_unstable();
        if let Some(stated) = match doc.get("roster") {
            Json::Null => None,
            _ => Some(ids("roster")?),
        } {
            anyhow::ensure!(
                stated == roster,
                "event roster does not match previous roster - departures + arrivals"
            );
        }
        Ok(RoundEvents { round, departures, arrivals, roster })
    }
}

/// Poisson sampler. Knuth's multiplicative method below the split
/// threshold; above it, additivity of the Poisson distribution: a
/// Poisson(λ) draw is the sum of two independent Poisson(λ/2) draws, so
/// large rates recurse into small ones instead of evaluating
/// `(-λ).exp()`, which underflows to 0.0 near λ ≈ 745 and would spin the
/// multiplicative loop to its draw cap. The threshold is far above every
/// stationary per-round rate, so small-λ streams keep byte-identical
/// draw sequences.
fn poisson(rng: &mut Rng, lambda: f64) -> usize {
    if lambda <= 0.0 {
        return 0;
    }
    if lambda > 30.0 {
        let half = lambda / 2.0;
        return poisson(rng, half) + poisson(rng, half);
    }
    let l = (-lambda).exp();
    let mut k = 0usize;
    let mut p = 1.0;
    loop {
        p *= rng.f64();
        if p <= l || k >= 10_000 {
            return k;
        }
        k += 1;
    }
}

/// Generate the full event stream for a run. `base_clients` are ids
/// `0..J` present in round 0; `seed` should already mix the scenario
/// tuple (the orchestrator passes `cfg.seed ^ fnv(spec.name)`); the
/// stream label is mixed in here.
pub fn generate(base_clients: usize, churn: &ChurnCfg, seed: u64) -> Vec<RoundEvents> {
    assert!(churn.rounds >= 1, "a fleet run needs at least one round");
    let cap = churn.max_clients.max(base_clients);
    let mut rng = Rng::seeded(seed ^ fnv("fleet-events"));
    let mut roster: Vec<u64> = (0..base_clients as u64).collect();
    let mut next_id = base_clients as u64;
    let mut out = Vec::with_capacity(churn.rounds);
    out.push(RoundEvents { round: 0, departures: vec![], arrivals: vec![], roster: roster.clone() });
    for round in 1..churn.rounds {
        let mut departures = Vec::new();
        let mut stayed = Vec::with_capacity(roster.len());
        for &id in &roster {
            if rng.chance(churn.departure_prob) {
                departures.push(id);
            } else {
                stayed.push(id);
            }
        }
        let want = poisson(&mut rng, churn.arrival_rate);
        let admit = want.min(cap.saturating_sub(stayed.len()));
        let arrivals: Vec<u64> = (0..admit as u64).map(|k| next_id + k).collect();
        next_id += admit as u64;
        roster = stayed;
        roster.extend(&arrivals);
        roster.sort_unstable();
        out.push(RoundEvents { round, departures, arrivals, roster: roster.clone() });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn churn() -> ChurnCfg {
        ChurnCfg { rounds: 12, arrival_rate: 1.5, departure_prob: 0.2, max_clients: 20 }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(10, &churn(), 7);
        let b = generate(10, &churn(), 7);
        assert_eq!(a, b);
        let c = generate(10, &churn(), 8);
        assert_ne!(a, c, "different seeds must yield different streams");
    }

    #[test]
    fn round0_is_base_population() {
        let ev = generate(6, &churn(), 3);
        assert_eq!(ev[0].roster, vec![0, 1, 2, 3, 4, 5]);
        assert!(ev[0].arrivals.is_empty() && ev[0].departures.is_empty());
    }

    #[test]
    fn ids_never_reused_and_monotone() {
        let ev = generate(8, &churn(), 11);
        let mut seen: std::collections::BTreeSet<u64> = ev[0].roster.iter().copied().collect();
        for r in &ev[1..] {
            for &id in &r.arrivals {
                assert!(id >= seen.iter().max().map(|&m| m + 1).unwrap_or(0), "arrival id {id} not fresh");
                assert!(seen.insert(id), "id {id} reused");
            }
        }
    }

    #[test]
    fn roster_evolution_consistent() {
        let ev = generate(8, &churn(), 5);
        for w in ev.windows(2) {
            let (prev, next) = (&w[0], &w[1]);
            let mut expect: Vec<u64> = prev.roster.iter().copied().filter(|id| !next.departures.contains(id)).collect();
            expect.extend(&next.arrivals);
            expect.sort_unstable();
            assert_eq!(next.roster, expect, "round {}", next.round);
            assert!(next.departures.iter().all(|id| prev.roster.contains(id)));
        }
    }

    #[test]
    fn max_clients_respected() {
        let cfg = ChurnCfg { rounds: 30, arrival_rate: 5.0, departure_prob: 0.01, max_clients: 12 };
        for r in generate(10, &cfg, 4) {
            assert!(r.roster.len() <= 12, "round {} roster {}", r.round, r.roster.len());
        }
    }

    #[test]
    fn base_population_larger_than_cap_is_never_evicted() {
        // The cap governs admission, not eviction: a base fleet bigger
        // than max_clients stays whole, and no arrivals are admitted
        // until departures open headroom under the raised cap.
        let cfg = ChurnCfg { rounds: 5, arrival_rate: 3.0, departure_prob: 0.0, max_clients: 4 };
        let ev = generate(10, &cfg, 6);
        assert_eq!(ev[0].roster.len(), 10);
        for r in &ev {
            assert_eq!(r.roster.len(), 10, "effective cap = base size");
            assert!(r.arrivals.is_empty(), "no headroom below the raised cap");
        }
    }

    #[test]
    fn zero_churn_keeps_roster_static() {
        let cfg = ChurnCfg { rounds: 6, arrival_rate: 0.0, departure_prob: 0.0, max_clients: 10 };
        let ev = generate(5, &cfg, 9);
        for r in &ev {
            assert_eq!(r.roster, ev[0].roster);
            assert!(r.arrivals.is_empty() && r.departures.is_empty());
        }
    }

    #[test]
    fn full_departure_rounds_are_representable() {
        // With certain departure and no arrivals the roster empties and
        // stays empty — the stream itself never panics.
        let cfg = ChurnCfg { rounds: 4, arrival_rate: 0.0, departure_prob: 1.0, max_clients: 10 };
        let ev = generate(3, &cfg, 2);
        assert_eq!(ev[1].departures.len(), 3);
        assert!(ev[1].roster.is_empty());
        assert!(ev[3].roster.is_empty());
    }

    #[test]
    fn churn_fraction_counts_both_directions() {
        let r = RoundEvents { round: 1, departures: vec![0, 1], arrivals: vec![9], roster: vec![2, 9] };
        assert!((r.churn_fraction(3) - 1.0).abs() < 1e-12);
        assert!((r.churn_fraction(0) - 3.0).abs() < 1e-12, "empty previous roster guards the division");
    }

    #[test]
    fn poisson_mean_in_ballpark() {
        let mut rng = Rng::seeded(13);
        let n = 20_000;
        let total: usize = (0..n).map(|_| poisson(&mut rng, 2.5)).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - 2.5).abs() < 0.1, "poisson mean {mean}");
    }

    /// Verbatim copy of the pre-split Knuth loop: the small-λ path must
    /// consume the exact same uniform draws, so every existing stream and
    /// golden stays byte-identical.
    fn knuth_reference(rng: &mut Rng, lambda: f64) -> usize {
        if lambda <= 0.0 {
            return 0;
        }
        let l = (-lambda).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= rng.f64();
            if p <= l || k >= 10_000 {
                return k;
            }
            k += 1;
        }
    }

    #[test]
    fn small_lambda_path_is_byte_identical_to_knuth() {
        for seed in [1u64, 7, 42] {
            let mut a = Rng::seeded(seed);
            let mut b = Rng::seeded(seed);
            for lambda in [0.3, 1.5, 2.5, 12.0, 30.0] {
                for _ in 0..200 {
                    assert_eq!(poisson(&mut a, lambda), knuth_reference(&mut b, lambda), "lambda {lambda}");
                }
            }
        }
    }

    #[test]
    fn poisson_mean_in_ballpark_at_large_lambda() {
        // Pre-fix, exp(-1000) underflowed to 0.0 and every draw ran the
        // multiplicative loop to its 10 000 cap.
        let mut rng = Rng::seeded(17);
        let n = 400;
        let draws: Vec<usize> = (0..n).map(|_| poisson(&mut rng, 1000.0)).collect();
        let mean = draws.iter().sum::<usize>() as f64 / n as f64;
        // se = sqrt(1000/400) ≈ 1.6; ±15 is ~9σ — deterministic anyway.
        assert!((mean - 1000.0).abs() < 15.0, "poisson(1000) mean {mean}");
        assert!(draws.iter().all(|&k| k < 10_000), "no draw hits the degenerate cap");
    }

    #[test]
    fn event_json_roundtrips_through_from_json() {
        let ev = generate(10, &churn(), 7);
        for w in ev.windows(2) {
            let (prev, next) = (&w[0], &w[1]);
            let doc = Json::parse(&next.jsonl_line()).unwrap();
            let back = RoundEvents::from_json(&doc, next.round, &prev.roster).unwrap();
            assert_eq!(&back, next, "round {}", next.round);
        }
    }

    #[test]
    fn from_json_computes_roster_and_round_when_absent() {
        let doc = Json::obj(vec![
            ("arrivals", Json::Arr(vec![Json::Num(9.0)])),
            ("departures", Json::Arr(vec![Json::Num(1.0)])),
        ]);
        let ev = RoundEvents::from_json(&doc, 3, &[0, 1, 2]).unwrap();
        assert_eq!(ev.round, 3);
        assert_eq!(ev.roster, vec![0, 2, 9]);
    }

    #[test]
    fn from_json_rejects_inconsistent_events() {
        let prev = [0u64, 1, 2];
        // Wrong round.
        let doc = Json::obj(vec![("round", Json::Num(5.0))]);
        let err = RoundEvents::from_json(&doc, 3, &prev).unwrap_err().to_string();
        assert!(err.contains("expected round 3"), "{err}");
        // Departure of an id not present.
        let doc = Json::obj(vec![("departures", Json::Arr(vec![Json::Num(7.0)]))]);
        assert!(RoundEvents::from_json(&doc, 3, &prev).is_err());
        // Arrival reusing a live id.
        let doc = Json::obj(vec![("arrivals", Json::Arr(vec![Json::Num(1.0)]))]);
        assert!(RoundEvents::from_json(&doc, 3, &prev).is_err());
        // Stated roster that contradicts the delta.
        let doc = Json::obj(vec![
            ("departures", Json::Arr(vec![Json::Num(0.0)])),
            ("roster", Json::Arr(vec![Json::Num(0.0), Json::Num(1.0), Json::Num(2.0)])),
        ]);
        assert!(RoundEvents::from_json(&doc, 3, &prev).is_err());
        // Not an object at all.
        assert!(RoundEvents::from_json(&Json::Num(1.0), 0, &[]).is_err());
    }
}
