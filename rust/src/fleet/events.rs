//! Deterministic arrival/departure event streams for multi-round fleets.
//!
//! Round 0 is the base population (ids `0..J`). Between consecutive
//! rounds each present client departs with probability `departure_prob`
//! and `Poisson(arrival_rate)` new clients arrive (capped so the roster
//! never exceeds `max_clients`, the bound the [`FleetWorld`]'s memory
//! repair was sized for). Arrival ids continue the sequence and are never
//! reused, so a client's identity — and every draw behind it — is stable
//! across the whole run.
//!
//! Helpers have their own fault process layered on the same stream
//! (see [`HelperChurnCfg`]): a live helper goes down with probability
//! `down_rate` per round (`helper_down`), deterministically returns
//! exactly `outage_rounds` rounds later (`helper_up`), and fresh helpers
//! join permanently at Poisson rate `join_rate` (`helper_join`) under a
//! `max_helpers` pool cap. Helper ids, like client ids, are never
//! reused. Helper draws come from a separate RNG stream, so enabling
//! helper churn leaves the client membership history byte-identical.
//!
//! The stream is a pure function of `(base population, churn knobs,
//! seed)`: replaying a fleet run with the same tuple reproduces the exact
//! same membership history, independent of thread count or wall clock.
//!
//! [`FleetWorld`]: crate::instance::scenario::FleetWorld

use crate::util::json::Json;
use crate::util::rng::{fnv64 as fnv, Rng};
use anyhow::{Context, Result};

/// Churn-process knobs for a fleet run.
#[derive(Clone, Debug)]
pub struct ChurnCfg {
    /// Number of training rounds to simulate (≥ 1).
    pub rounds: usize,
    /// Expected arrivals per round (Poisson rate).
    pub arrival_rate: f64,
    /// Per-client per-round departure probability.
    pub departure_prob: f64,
    /// Hard roster-size cap; arrivals beyond it are deferred (dropped
    /// from this round's admission, the rate keeps pressure up). A base
    /// population larger than the cap raises the effective cap to the
    /// base size — the initial fleet is never evicted to fit (the
    /// [`FleetWorld`] memory repair applies the same `max(base)` rule).
    ///
    /// [`FleetWorld`]: crate::instance::scenario::FleetWorld
    pub max_clients: usize,
}

impl ChurnCfg {
    /// Stationary default for a base population of `j`: departures at
    /// rate 0.12 balanced by 0.12·J expected arrivals, roster capped at
    /// 2·J.
    pub fn stationary(j: usize) -> ChurnCfg {
        ChurnCfg {
            rounds: 8,
            arrival_rate: 0.12 * j as f64,
            departure_prob: 0.12,
            max_clients: (2 * j).max(1),
        }
    }
}

/// Helper fault-process knobs for a fleet run. All-zero rates (the
/// [`HelperChurnCfg::none`] default) disable helper modeling entirely:
/// the event stream, the world, and every artifact stay byte-identical
/// to a run built before helper dynamics existed.
#[derive(Clone, Debug)]
pub struct HelperChurnCfg {
    /// Per-helper per-round transient-outage probability.
    pub down_rate: f64,
    /// Outage length: a helper that goes down before round `r` comes
    /// back before round `r + outage_rounds` (clamped to ≥ 1). The
    /// return is deterministic — no draw is spent on it.
    pub outage_rounds: usize,
    /// Expected permanent helper arrivals per round (Poisson rate).
    pub join_rate: f64,
    /// Pool cap counting live *and* down helpers (outaged helpers come
    /// back, so they keep their slot). A base helper set larger than
    /// the cap raises the effective cap to the base size, mirroring
    /// [`ChurnCfg::max_clients`]. `0` means "base size".
    pub max_helpers: usize,
    /// Diurnal availability period in rounds (`0` disables). In the
    /// second half of each period ("night") the outage rate doubles
    /// (clamped to 1.0) and no helpers join.
    pub diurnal_period: usize,
}

impl HelperChurnCfg {
    /// Helper dynamics disabled: no draws, no events, no world changes.
    pub fn none() -> HelperChurnCfg {
        HelperChurnCfg {
            down_rate: 0.0,
            outage_rounds: 2,
            join_rate: 0.0,
            max_helpers: 0,
            diurnal_period: 0,
        }
    }

    /// True when helper dynamics are fully disabled. `max_helpers` and
    /// `diurnal_period` count as enabling knobs so a serve session can
    /// opt into helper modeling (accepting helper events on stdin)
    /// without any seeded faults of its own.
    pub fn is_none(&self) -> bool {
        self.down_rate == 0.0
            && self.join_rate == 0.0
            && self.max_helpers == 0
            && self.diurnal_period == 0
    }

    /// The `s7-helper-bursts` default: frequent short transient
    /// outages, no joins.
    pub fn bursts() -> HelperChurnCfg {
        HelperChurnCfg {
            down_rate: 0.12,
            outage_rounds: 2,
            join_rate: 0.0,
            max_helpers: 0,
            diurnal_period: 0,
        }
    }
}

/// Flash-crowd knobs: periodic burst spikes layered on the arrival
/// process. During a spike round the Poisson arrival rate is multiplied
/// by `multiplier`; departures and every other draw are untouched. The
/// [`FlashCrowdCfg::none`] default disables the process entirely —
/// the stream is byte-identical to one generated before flash crowds
/// existed. The `s8-flash-crowd` family turns this on by default
/// ([`FlashCrowdCfg::spikes`]).
#[derive(Clone, Debug)]
pub struct FlashCrowdCfg {
    /// Rounds between spike onsets (`0` disables the process).
    pub period: usize,
    /// Length of each spike in rounds (clamped to ≥ 1).
    pub spike_rounds: usize,
    /// Arrival-rate multiplier during a spike (≤ 1.0 disables).
    pub multiplier: f64,
}

impl FlashCrowdCfg {
    /// Flash crowds disabled: every round uses the base arrival rate.
    pub fn none() -> FlashCrowdCfg {
        FlashCrowdCfg { period: 0, spike_rounds: 1, multiplier: 1.0 }
    }

    /// True when the process is fully disabled.
    pub fn is_none(&self) -> bool {
        self.period == 0 || self.multiplier <= 1.0
    }

    /// The `s8-flash-crowd` default: every 4th round opens a 1-round
    /// spike at 4× the stationary arrival rate — enough pressure to hit
    /// the roster cap and exercise admission + repair under surge.
    pub fn spikes() -> FlashCrowdCfg {
        FlashCrowdCfg { period: 4, spike_rounds: 1, multiplier: 4.0 }
    }

    /// Arrival-rate multiplier for `round` (1.0 off-spike or disabled).
    pub fn multiplier_for(&self, round: usize) -> f64 {
        if self.is_none() {
            return 1.0;
        }
        if round % self.period < self.spike_rounds.max(1).min(self.period) {
            self.multiplier
        } else {
            1.0
        }
    }
}

/// Live/down partition of the helper pool, evolved by applying each
/// round's helper events in order. `live` and `down` are sorted and
/// disjoint; `next_id` is the first never-used helper id (join ids are
/// never reused, mirroring the client id space).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HelperRoster {
    /// Helpers currently serving, sorted by id.
    pub live: Vec<u64>,
    /// Helpers in a transient outage, sorted by id.
    pub down: Vec<u64>,
    /// First never-used helper id.
    pub next_id: u64,
}

impl HelperRoster {
    /// The round-0 roster: base helpers `0..I`, all live.
    pub fn base(n_helpers: usize) -> HelperRoster {
        assert!(n_helpers >= 1, "a fleet world needs at least one helper");
        HelperRoster {
            live: (0..n_helpers as u64).collect(),
            down: vec![],
            next_id: n_helpers as u64,
        }
    }

    /// Apply one round's helper events. Panics on an inconsistent event
    /// — callers feeding untrusted input must validate through
    /// [`RoundEvents::from_json`] first, which rejects every case these
    /// asserts would hit.
    pub fn apply(&mut self, ev: &RoundEvents) {
        for &id in &ev.helper_up {
            let k = self.down.binary_search(&id).expect("helper-up id must be in an outage");
            self.down.remove(k);
            let k = self.live.binary_search(&id).unwrap_err();
            self.live.insert(k, id);
        }
        for &id in &ev.helper_down {
            let k = self.live.binary_search(&id).expect("helper-down id must be live");
            self.live.remove(k);
            let k = self.down.binary_search(&id).unwrap_err();
            self.down.insert(k, id);
        }
        for &id in &ev.helper_join {
            assert!(id >= self.next_id, "helper ids are never reused");
            let k = self.live.binary_search(&id).unwrap_err();
            self.live.insert(k, id);
            self.next_id = id + 1;
        }
        assert!(!self.live.is_empty(), "helper events left no live helper");
    }
}

/// Membership delta and resulting roster for one round.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RoundEvents {
    pub round: usize,
    /// Ids departing before this round (subset of the previous roster).
    pub departures: Vec<u64>,
    /// Ids arriving before this round (freshly minted, strictly above
    /// every id seen so far).
    pub arrivals: Vec<u64>,
    /// Membership for this round, sorted by id.
    pub roster: Vec<u64>,
    /// Helpers entering a transient outage before this round (subset of
    /// the previously live helpers).
    pub helper_down: Vec<u64>,
    /// Helpers returning from an outage before this round (subset of
    /// the previously down helpers).
    pub helper_up: Vec<u64>,
    /// Fresh helpers joining permanently before this round (ids
    /// strictly above every helper id seen so far).
    pub helper_join: Vec<u64>,
}

impl RoundEvents {
    /// A client-only event — the constructor every helper-free call
    /// site and test literal uses; helper fields are empty.
    pub fn clients(
        round: usize,
        departures: Vec<u64>,
        arrivals: Vec<u64>,
        roster: Vec<u64>,
    ) -> RoundEvents {
        RoundEvents {
            round,
            departures,
            arrivals,
            roster,
            helper_down: vec![],
            helper_up: vec![],
            helper_join: vec![],
        }
    }

    /// Fraction of the previous roster that changed (arrivals +
    /// departures over the previous size) — the orchestrator's churn
    /// drift signal. Helper events are tracked separately (capacity
    /// fraction, not churn fraction).
    pub fn churn_fraction(&self, prev_roster_len: usize) -> f64 {
        (self.arrivals.len() + self.departures.len()) as f64 / prev_roster_len.max(1) as f64
    }

    /// True when this round carries any helper event.
    pub fn has_helper_events(&self) -> bool {
        !(self.helper_down.is_empty() && self.helper_up.is_empty() && self.helper_join.is_empty())
    }

    /// The event's JSON object — one line of the `<out>.events.jsonl`
    /// sidecar, and the line format `psl serve` consumes on stdin.
    /// Helper keys are emitted only when non-empty, so helper-free
    /// streams serialize byte-identically to builds that predate helper
    /// dynamics.
    pub fn to_json(&self) -> Json {
        let ids = |v: &[u64]| Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect());
        let mut fields = vec![
            ("round", Json::Num(self.round as f64)),
            ("arrivals", ids(&self.arrivals)),
            ("departures", ids(&self.departures)),
            ("roster", ids(&self.roster)),
        ];
        if !self.helper_down.is_empty() {
            fields.push(("helper_down", ids(&self.helper_down)));
        }
        if !self.helper_up.is_empty() {
            fields.push(("helper_up", ids(&self.helper_up)));
        }
        if !self.helper_join.is_empty() {
            fields.push(("helper_join", ids(&self.helper_join)));
        }
        Json::obj(fields)
    }

    /// Single-line JSON for event-log streaming (JSONL).
    pub fn jsonl_line(&self) -> String {
        self.to_json().dump()
    }

    /// Parse one event line against the session's expected position.
    /// `round` and `roster` are optional on the wire (a hand-written
    /// event only needs `arrivals`/`departures`); when present they must
    /// agree with `expect_round` and with the membership delta applied to
    /// `prev_roster` (which must be sorted — it is the previous event's
    /// `roster`). Helper events are validated against `prev_helpers`,
    /// the roster state after the previous round's events.
    pub fn from_json(
        doc: &Json,
        expect_round: usize,
        prev_roster: &[u64],
        prev_helpers: &HelperRoster,
    ) -> Result<RoundEvents> {
        doc.as_obj().context("event is not a JSON object")?;
        let ids = |key: &str| -> Result<Vec<u64>> {
            let mut out = Vec::new();
            match doc.get(key) {
                Json::Null => {}
                v => {
                    for x in v.as_arr().with_context(|| format!("event {key:?} is not an array"))? {
                        let f = x.as_f64().with_context(|| format!("event {key:?} entry {x} is not a number"))?;
                        anyhow::ensure!(
                            f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64,
                            "event {key:?} entry {f} is not an id"
                        );
                        out.push(f as u64);
                    }
                }
            }
            out.sort_unstable();
            anyhow::ensure!(out.windows(2).all(|w| w[0] != w[1]), "event {key:?} has duplicate ids");
            Ok(out)
        };
        let round = match doc.get("round") {
            Json::Null => expect_round,
            v => v.as_usize().with_context(|| format!("event round {v} is not an integer"))?,
        };
        anyhow::ensure!(
            round == expect_round,
            "event round {round} does not continue the session (expected round {expect_round})"
        );
        let departures = ids("departures")?;
        for id in &departures {
            anyhow::ensure!(
                prev_roster.binary_search(id).is_ok(),
                "departure id {id} is not in the previous roster"
            );
        }
        let arrivals = ids("arrivals")?;
        let mut roster: Vec<u64> =
            prev_roster.iter().copied().filter(|id| departures.binary_search(id).is_err()).collect();
        for id in &arrivals {
            anyhow::ensure!(
                departures.binary_search(id).is_err(),
                "arrival id {id} also departs in the same event (inconsistent roster)"
            );
            anyhow::ensure!(
                roster.binary_search(id).is_err(),
                "arrival id {id} is already in the roster (ids are never reused)"
            );
            roster.push(*id);
        }
        roster.sort_unstable();
        if let Some(stated) = match doc.get("roster") {
            Json::Null => None,
            _ => Some(ids("roster")?),
        } {
            anyhow::ensure!(
                stated == roster,
                "event roster does not match previous roster - departures + arrivals"
            );
        }
        let helper_down = ids("helper_down")?;
        let helper_up = ids("helper_up")?;
        let helper_join = ids("helper_join")?;
        for id in &helper_down {
            anyhow::ensure!(
                prev_helpers.live.binary_search(id).is_ok(),
                "helper-down id {id} is not a live helper"
            );
            anyhow::ensure!(
                helper_up.binary_search(id).is_err(),
                "helper id {id} cannot go down and come back in the same event"
            );
        }
        for id in &helper_up {
            anyhow::ensure!(
                prev_helpers.down.binary_search(id).is_ok(),
                "helper-up id {id} is not in an outage"
            );
        }
        for id in &helper_join {
            anyhow::ensure!(
                *id >= prev_helpers.next_id,
                "helper-join id {id} is not fresh (helper ids are never reused)"
            );
        }
        anyhow::ensure!(
            prev_helpers.live.len() + helper_up.len() + helper_join.len() > helper_down.len(),
            "helper events would leave no live helper"
        );
        Ok(RoundEvents { round, departures, arrivals, roster, helper_down, helper_up, helper_join })
    }
}

/// Poisson sampler. Knuth's multiplicative method below the split
/// threshold; above it, additivity of the Poisson distribution: a
/// Poisson(λ) draw is the sum of two independent Poisson(λ/2) draws, so
/// large rates recurse into small ones instead of evaluating
/// `(-λ).exp()`, which underflows to 0.0 near λ ≈ 745 and would spin the
/// multiplicative loop to its draw cap. The threshold is far above every
/// stationary per-round rate, so small-λ streams keep byte-identical
/// draw sequences.
fn poisson(rng: &mut Rng, lambda: f64) -> usize {
    if lambda <= 0.0 {
        return 0;
    }
    if lambda > 30.0 {
        let half = lambda / 2.0;
        return poisson(rng, half) + poisson(rng, half);
    }
    let l = (-lambda).exp();
    let mut k = 0usize;
    let mut p = 1.0;
    loop {
        p *= rng.f64();
        if p <= l || k >= 10_000 {
            return k;
        }
        k += 1;
    }
}

/// Generate the full event stream for a run. `base_clients` are ids
/// `0..J` present in round 0; `seed` should already mix the scenario
/// tuple (the orchestrator passes `cfg.seed ^ fnv(spec.name)`); the
/// stream label is mixed in here.
pub fn generate(base_clients: usize, churn: &ChurnCfg, seed: u64) -> Vec<RoundEvents> {
    generate_with_flash(base_clients, churn, &FlashCrowdCfg::none(), seed)
}

/// [`generate`] with flash-crowd arrival spikes: on spike rounds the
/// Poisson arrival rate is multiplied by
/// [`FlashCrowdCfg::multiplier_for`]; departures draw exactly as in
/// [`generate`]. With `flash.is_none()` the output is byte-identical to
/// [`generate`] — the spike multiplier only changes the λ handed to the
/// same sampler, never the draw structure.
pub fn generate_with_flash(
    base_clients: usize,
    churn: &ChurnCfg,
    flash: &FlashCrowdCfg,
    seed: u64,
) -> Vec<RoundEvents> {
    assert!(churn.rounds >= 1, "a fleet run needs at least one round");
    let cap = churn.max_clients.max(base_clients);
    let mut rng = Rng::seeded(seed ^ fnv("fleet-events"));
    let mut roster: Vec<u64> = (0..base_clients as u64).collect();
    let mut next_id = base_clients as u64;
    let mut out = Vec::with_capacity(churn.rounds);
    out.push(RoundEvents::clients(0, vec![], vec![], roster.clone()));
    for round in 1..churn.rounds {
        let mut departures = Vec::new();
        let mut stayed = Vec::with_capacity(roster.len());
        for &id in &roster {
            if rng.chance(churn.departure_prob) {
                departures.push(id);
            } else {
                stayed.push(id);
            }
        }
        let want = poisson(&mut rng, churn.arrival_rate * flash.multiplier_for(round));
        let admit = want.min(cap.saturating_sub(stayed.len()));
        let arrivals: Vec<u64> = (0..admit as u64).map(|k| next_id + k).collect();
        next_id += admit as u64;
        roster = stayed;
        roster.extend(&arrivals);
        roster.sort_unstable();
        out.push(RoundEvents::clients(round, departures, arrivals, roster.clone()));
    }
    out
}

/// [`generate`] plus the helper fault process. Client draws come from
/// the same stream as [`generate`] and helper draws from a separate one
/// (`seed ^ fnv("fleet-helper-events")`), so the client half of the
/// output is byte-identical with helper churn on or off; with
/// `helper.is_none()` the whole stream is byte-identical to
/// [`generate`].
///
/// Per round, in draw order: helpers whose outage ends this round come
/// back (deterministic, no draw), each previously-live helper draws one
/// outage chance (the draw is always consumed; a hit is suppressed if
/// it would leave no live helper), then `Poisson(join_rate)` fresh
/// helpers join under the pool cap. Each round's draws depend only on
/// the history, never the horizon, so a resumed or extended run
/// reproduces the same prefix.
pub fn generate_with_helpers(
    base_clients: usize,
    churn: &ChurnCfg,
    helper: &HelperChurnCfg,
    base_helpers: usize,
    seed: u64,
) -> Vec<RoundEvents> {
    generate_fleet(base_clients, churn, helper, &FlashCrowdCfg::none(), base_helpers, seed)
}

/// The full stream: flash-crowd client arrivals plus the helper fault
/// process. Each layer draws from its own RNG stream, so enabling
/// either leaves the other's half byte-identical; with both disabled
/// the output is byte-identical to [`generate`].
pub fn generate_fleet(
    base_clients: usize,
    churn: &ChurnCfg,
    helper: &HelperChurnCfg,
    flash: &FlashCrowdCfg,
    base_helpers: usize,
    seed: u64,
) -> Vec<RoundEvents> {
    let mut out = generate_with_flash(base_clients, churn, flash, seed);
    if helper.is_none() {
        return out;
    }
    let cap = helper.max_helpers.max(base_helpers);
    let mut rng = Rng::seeded(seed ^ fnv("fleet-helper-events"));
    let mut roster = HelperRoster::base(base_helpers);
    // (helper id, round it returns before) — outages in flight.
    let mut returns: Vec<(u64, usize)> = Vec::new();
    for round in 1..out.len() {
        let mut ups: Vec<u64> =
            returns.iter().filter(|&&(_, r)| r == round).map(|&(id, _)| id).collect();
        returns.retain(|&(_, r)| r != round);
        ups.sort_unstable();
        let (mut down_rate, mut join_rate) = (helper.down_rate, helper.join_rate);
        if helper.diurnal_period >= 2 {
            let phase = round % helper.diurnal_period;
            if 2 * phase >= helper.diurnal_period {
                down_rate = (down_rate * 2.0).min(1.0);
                join_rate = 0.0;
            }
        }
        let mut downs = Vec::new();
        for &id in &roster.live {
            // The chance draw is always consumed (left operand of &&),
            // so suppression near the last live helper never shifts
            // later draws. A returning helper is not live yet, so it
            // cannot fail again before serving one round.
            if rng.chance(down_rate) && roster.live.len() + ups.len() - downs.len() > 1 {
                downs.push(id);
                returns.push((id, round + helper.outage_rounds.max(1)));
            }
        }
        let want = poisson(&mut rng, join_rate);
        let total = roster.live.len() + roster.down.len();
        let admit = want.min(cap.saturating_sub(total));
        let joins: Vec<u64> = (0..admit as u64).map(|k| roster.next_id + k).collect();
        out[round].helper_down = downs;
        out[round].helper_up = ups;
        out[round].helper_join = joins;
        roster.apply(&out[round]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn churn() -> ChurnCfg {
        ChurnCfg { rounds: 12, arrival_rate: 1.5, departure_prob: 0.2, max_clients: 20 }
    }

    fn helper_churn() -> HelperChurnCfg {
        HelperChurnCfg {
            down_rate: 0.25,
            outage_rounds: 3,
            join_rate: 0.4,
            max_helpers: 6,
            diurnal_period: 0,
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(10, &churn(), 7);
        let b = generate(10, &churn(), 7);
        assert_eq!(a, b);
        let c = generate(10, &churn(), 8);
        assert_ne!(a, c, "different seeds must yield different streams");
    }

    #[test]
    fn round0_is_base_population() {
        let ev = generate(6, &churn(), 3);
        assert_eq!(ev[0].roster, vec![0, 1, 2, 3, 4, 5]);
        assert!(ev[0].arrivals.is_empty() && ev[0].departures.is_empty());
    }

    #[test]
    fn ids_never_reused_and_monotone() {
        let ev = generate(8, &churn(), 11);
        let mut seen: std::collections::BTreeSet<u64> = ev[0].roster.iter().copied().collect();
        for r in &ev[1..] {
            for &id in &r.arrivals {
                assert!(id >= seen.iter().max().map(|&m| m + 1).unwrap_or(0), "arrival id {id} not fresh");
                assert!(seen.insert(id), "id {id} reused");
            }
        }
    }

    #[test]
    fn roster_evolution_consistent() {
        let ev = generate(8, &churn(), 5);
        for w in ev.windows(2) {
            let (prev, next) = (&w[0], &w[1]);
            let mut expect: Vec<u64> = prev.roster.iter().copied().filter(|id| !next.departures.contains(id)).collect();
            expect.extend(&next.arrivals);
            expect.sort_unstable();
            assert_eq!(next.roster, expect, "round {}", next.round);
            assert!(next.departures.iter().all(|id| prev.roster.contains(id)));
        }
    }

    #[test]
    fn max_clients_respected() {
        let cfg = ChurnCfg { rounds: 30, arrival_rate: 5.0, departure_prob: 0.01, max_clients: 12 };
        for r in generate(10, &cfg, 4) {
            assert!(r.roster.len() <= 12, "round {} roster {}", r.round, r.roster.len());
        }
    }

    #[test]
    fn base_population_larger_than_cap_is_never_evicted() {
        // The cap governs admission, not eviction: a base fleet bigger
        // than max_clients stays whole, and no arrivals are admitted
        // until departures open headroom under the raised cap.
        let cfg = ChurnCfg { rounds: 5, arrival_rate: 3.0, departure_prob: 0.0, max_clients: 4 };
        let ev = generate(10, &cfg, 6);
        assert_eq!(ev[0].roster.len(), 10);
        for r in &ev {
            assert_eq!(r.roster.len(), 10, "effective cap = base size");
            assert!(r.arrivals.is_empty(), "no headroom below the raised cap");
        }
    }

    #[test]
    fn zero_churn_keeps_roster_static() {
        let cfg = ChurnCfg { rounds: 6, arrival_rate: 0.0, departure_prob: 0.0, max_clients: 10 };
        let ev = generate(5, &cfg, 9);
        for r in &ev {
            assert_eq!(r.roster, ev[0].roster);
            assert!(r.arrivals.is_empty() && r.departures.is_empty());
        }
    }

    #[test]
    fn full_departure_rounds_are_representable() {
        // With certain departure and no arrivals the roster empties and
        // stays empty — the stream itself never panics.
        let cfg = ChurnCfg { rounds: 4, arrival_rate: 0.0, departure_prob: 1.0, max_clients: 10 };
        let ev = generate(3, &cfg, 2);
        assert_eq!(ev[1].departures.len(), 3);
        assert!(ev[1].roster.is_empty());
        assert!(ev[3].roster.is_empty());
    }

    #[test]
    fn churn_fraction_counts_both_directions() {
        let r = RoundEvents::clients(1, vec![0, 1], vec![9], vec![2, 9]);
        assert!((r.churn_fraction(3) - 1.0).abs() < 1e-12);
        assert!((r.churn_fraction(0) - 3.0).abs() < 1e-12, "empty previous roster guards the division");
    }

    #[test]
    fn poisson_mean_in_ballpark() {
        let mut rng = Rng::seeded(13);
        let n = 20_000;
        let total: usize = (0..n).map(|_| poisson(&mut rng, 2.5)).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - 2.5).abs() < 0.1, "poisson mean {mean}");
    }

    /// Verbatim copy of the pre-split Knuth loop: the small-λ path must
    /// consume the exact same uniform draws, so every existing stream and
    /// golden stays byte-identical.
    fn knuth_reference(rng: &mut Rng, lambda: f64) -> usize {
        if lambda <= 0.0 {
            return 0;
        }
        let l = (-lambda).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= rng.f64();
            if p <= l || k >= 10_000 {
                return k;
            }
            k += 1;
        }
    }

    #[test]
    fn small_lambda_path_is_byte_identical_to_knuth() {
        for seed in [1u64, 7, 42] {
            let mut a = Rng::seeded(seed);
            let mut b = Rng::seeded(seed);
            for lambda in [0.3, 1.5, 2.5, 12.0, 30.0] {
                for _ in 0..200 {
                    assert_eq!(poisson(&mut a, lambda), knuth_reference(&mut b, lambda), "lambda {lambda}");
                }
            }
        }
    }

    #[test]
    fn poisson_mean_in_ballpark_at_large_lambda() {
        // Pre-fix, exp(-1000) underflowed to 0.0 and every draw ran the
        // multiplicative loop to its 10 000 cap.
        let mut rng = Rng::seeded(17);
        let n = 400;
        let draws: Vec<usize> = (0..n).map(|_| poisson(&mut rng, 1000.0)).collect();
        let mean = draws.iter().sum::<usize>() as f64 / n as f64;
        // se = sqrt(1000/400) ≈ 1.6; ±15 is ~9σ — deterministic anyway.
        assert!((mean - 1000.0).abs() < 15.0, "poisson(1000) mean {mean}");
        assert!(draws.iter().all(|&k| k < 10_000), "no draw hits the degenerate cap");
    }

    #[test]
    fn event_json_roundtrips_through_from_json() {
        let ev = generate(10, &churn(), 7);
        let helpers = HelperRoster::base(2);
        for w in ev.windows(2) {
            let (prev, next) = (&w[0], &w[1]);
            let doc = Json::parse(&next.jsonl_line()).unwrap();
            let back = RoundEvents::from_json(&doc, next.round, &prev.roster, &helpers).unwrap();
            assert_eq!(&back, next, "round {}", next.round);
        }
    }

    #[test]
    fn from_json_computes_roster_and_round_when_absent() {
        let doc = Json::obj(vec![
            ("arrivals", Json::Arr(vec![Json::Num(9.0)])),
            ("departures", Json::Arr(vec![Json::Num(1.0)])),
        ]);
        let ev = RoundEvents::from_json(&doc, 3, &[0, 1, 2], &HelperRoster::base(2)).unwrap();
        assert_eq!(ev.round, 3);
        assert_eq!(ev.roster, vec![0, 2, 9]);
    }

    #[test]
    fn from_json_rejects_inconsistent_events() {
        let prev = [0u64, 1, 2];
        let helpers = HelperRoster::base(2);
        // Wrong round.
        let doc = Json::obj(vec![("round", Json::Num(5.0))]);
        let err = RoundEvents::from_json(&doc, 3, &prev, &helpers).unwrap_err().to_string();
        assert!(err.contains("expected round 3"), "{err}");
        // Departure of an id not present.
        let doc = Json::obj(vec![("departures", Json::Arr(vec![Json::Num(7.0)]))]);
        assert!(RoundEvents::from_json(&doc, 3, &prev, &helpers).is_err());
        // Arrival reusing a live id.
        let doc = Json::obj(vec![("arrivals", Json::Arr(vec![Json::Num(1.0)]))]);
        assert!(RoundEvents::from_json(&doc, 3, &prev, &helpers).is_err());
        // Stated roster that contradicts the delta.
        let doc = Json::obj(vec![
            ("departures", Json::Arr(vec![Json::Num(0.0)])),
            ("roster", Json::Arr(vec![Json::Num(0.0), Json::Num(1.0), Json::Num(2.0)])),
        ]);
        assert!(RoundEvents::from_json(&doc, 3, &prev, &helpers).is_err());
        // Not an object at all.
        assert!(RoundEvents::from_json(&Json::Num(1.0), 0, &[], &helpers).is_err());
    }

    #[test]
    fn from_json_rejects_arrival_that_also_departs() {
        // Regression: an id in both lists used to slip through because
        // arrivals were only checked against the already-filtered
        // roster — the "arrival" of a simultaneous departer rebuilt the
        // roster it claimed to leave.
        let doc = Json::obj(vec![
            ("departures", Json::Arr(vec![Json::Num(1.0)])),
            ("arrivals", Json::Arr(vec![Json::Num(1.0)])),
        ]);
        let err = RoundEvents::from_json(&doc, 3, &[0, 1, 2], &HelperRoster::base(2))
            .unwrap_err()
            .to_string();
        assert!(err.contains("arrival id 1 also departs in the same event"), "{err}");
    }

    #[test]
    fn disabled_flash_crowd_is_byte_identical_to_generate() {
        let a = generate_with_flash(10, &churn(), &FlashCrowdCfg::none(), 7);
        let b = generate(10, &churn(), 7);
        assert_eq!(a, b);
        // multiplier ≤ 1.0 also counts as disabled.
        let c = generate_with_flash(
            10,
            &churn(),
            &FlashCrowdCfg { period: 4, spike_rounds: 1, multiplier: 1.0 },
            7,
        );
        assert_eq!(b, c);
    }

    #[test]
    fn flash_crowd_spikes_inflate_spike_round_arrivals() {
        // Deterministic per seed, and across many seeds the spike rounds
        // must admit clearly more arrivals than the off-spike rounds.
        let cfg = ChurnCfg { rounds: 16, arrival_rate: 1.0, departure_prob: 0.3, max_clients: 200 };
        let flash = FlashCrowdCfg { period: 4, spike_rounds: 1, multiplier: 6.0 };
        let a = generate_with_flash(12, &cfg, &flash, 7);
        assert_eq!(a, generate_with_flash(12, &cfg, &flash, 7));
        let (mut spike, mut calm, mut spike_n, mut calm_n) = (0usize, 0usize, 0usize, 0usize);
        for seed in 0..30u64 {
            for r in &generate_with_flash(12, &cfg, &flash, seed)[1..] {
                if flash.multiplier_for(r.round) > 1.0 {
                    spike += r.arrivals.len();
                    spike_n += 1;
                } else {
                    calm += r.arrivals.len();
                    calm_n += 1;
                }
            }
        }
        let (spike_mean, calm_mean) = (spike as f64 / spike_n as f64, calm as f64 / calm_n as f64);
        assert!(
            spike_mean > 3.0 * calm_mean,
            "spike mean {spike_mean} vs calm mean {calm_mean}"
        );
    }

    #[test]
    fn flash_crowd_respects_roster_cap() {
        let cfg = ChurnCfg { rounds: 20, arrival_rate: 2.0, departure_prob: 0.05, max_clients: 15 };
        let flash = FlashCrowdCfg::spikes();
        for r in generate_with_flash(10, &cfg, &flash, 3) {
            assert!(r.roster.len() <= 15, "round {} roster {}", r.round, r.roster.len());
        }
    }

    #[test]
    fn multiplier_for_windows() {
        let f = FlashCrowdCfg { period: 5, spike_rounds: 2, multiplier: 3.0 };
        for round in 0..20 {
            let want = if round % 5 < 2 { 3.0 } else { 1.0 };
            assert_eq!(f.multiplier_for(round), want, "round {round}");
        }
        // spike_rounds ≥ period degenerates to every round spiking.
        let g = FlashCrowdCfg { period: 3, spike_rounds: 9, multiplier: 2.0 };
        assert!((0..9).all(|r| g.multiplier_for(r) == 2.0));
        assert_eq!(FlashCrowdCfg::none().multiplier_for(4), 1.0);
    }

    #[test]
    fn generate_fleet_layers_compose_independently() {
        // Flash spikes draw from the client stream, faults from the
        // helper stream: turning flash on must leave helper events
        // byte-identical, and turning helpers on must leave the flashed
        // client half byte-identical.
        let flash = FlashCrowdCfg::spikes();
        let full = generate_fleet(10, &churn(), &helper_churn(), &flash, 3, 7);
        let flash_only = generate_with_flash(10, &churn(), &flash, 7);
        let helpers_only = generate_with_helpers(10, &churn(), &helper_churn(), 3, 7);
        for ((f, c), h) in full.iter().zip(&flash_only).zip(&helpers_only) {
            assert_eq!(f.arrivals, c.arrivals);
            assert_eq!(f.departures, c.departures);
            assert_eq!(f.roster, c.roster);
            assert_eq!(f.helper_down, h.helper_down);
            assert_eq!(f.helper_up, h.helper_up);
            assert_eq!(f.helper_join, h.helper_join);
        }
    }

    #[test]
    fn helper_stream_deterministic_and_client_draws_untouched() {
        let a = generate_with_helpers(10, &churn(), &helper_churn(), 3, 7);
        let b = generate_with_helpers(10, &churn(), &helper_churn(), 3, 7);
        assert_eq!(a, b);
        assert!(a.iter().any(|r| r.has_helper_events()), "fault process must fire at these rates");
        // The client half is byte-identical to the helper-free stream.
        let plain = generate(10, &churn(), 7);
        for (h, p) in a.iter().zip(&plain) {
            assert_eq!(h.round, p.round);
            assert_eq!(h.departures, p.departures);
            assert_eq!(h.arrivals, p.arrivals);
            assert_eq!(h.roster, p.roster);
        }
    }

    #[test]
    fn disabled_helper_churn_is_byte_identical_to_generate() {
        let a = generate_with_helpers(10, &churn(), &HelperChurnCfg::none(), 3, 7);
        let b = generate(10, &churn(), 7);
        assert_eq!(a, b);
        for r in &a {
            assert!(!r.jsonl_line().contains("helper"), "no helper keys on the wire");
        }
    }

    #[test]
    fn downs_return_exactly_outage_rounds_later() {
        let cfg = ChurnCfg { rounds: 40, arrival_rate: 0.5, departure_prob: 0.1, max_clients: 16 };
        let hc = helper_churn(); // outage_rounds: 3
        let ev = generate_with_helpers(8, &cfg, &hc, 4, 21);
        for (r, round) in ev.iter().enumerate() {
            for &id in &round.helper_down {
                let back = r + hc.outage_rounds;
                if back < ev.len() {
                    assert!(
                        ev[back].helper_up.binary_search(&id).is_ok(),
                        "helper {id} down at round {r} must return at round {back}"
                    );
                    for mid in ev[r + 1..back].iter() {
                        assert!(!mid.helper_up.contains(&id) && !mid.helper_down.contains(&id));
                    }
                }
            }
        }
    }

    #[test]
    fn last_live_helper_never_goes_down() {
        let cfg = ChurnCfg { rounds: 20, arrival_rate: 0.5, departure_prob: 0.1, max_clients: 16 };
        let hc = HelperChurnCfg {
            down_rate: 1.0,
            outage_rounds: 5,
            join_rate: 0.0,
            max_helpers: 0,
            diurnal_period: 0,
        };
        let ev = generate_with_helpers(8, &cfg, &hc, 3, 9);
        let mut roster = HelperRoster::base(3);
        for r in &ev[1..] {
            roster.apply(r); // panics if any event empties the live set
            assert!(!roster.live.is_empty(), "round {}", r.round);
        }
    }

    #[test]
    fn join_ids_monotone_and_pool_cap_respected() {
        let cfg = ChurnCfg { rounds: 30, arrival_rate: 0.5, departure_prob: 0.1, max_clients: 16 };
        let hc = HelperChurnCfg {
            down_rate: 0.3,
            outage_rounds: 4,
            join_rate: 2.0,
            max_helpers: 7,
            diurnal_period: 0,
        };
        let ev = generate_with_helpers(8, &cfg, &hc, 3, 13);
        let mut roster = HelperRoster::base(3);
        let mut last_join = 2u64;
        let mut joined = false;
        for r in &ev[1..] {
            for &id in &r.helper_join {
                assert!(id > last_join, "join id {id} not fresh");
                last_join = id;
                joined = true;
            }
            roster.apply(r);
            assert!(
                roster.live.len() + roster.down.len() <= 7,
                "round {}: pool {} + {} exceeds cap",
                r.round,
                roster.live.len(),
                roster.down.len()
            );
        }
        assert!(joined, "join process must fire at rate 2.0 over 30 rounds");
        assert_eq!(roster.live.len() + roster.down.len(), 7, "pool fills to the cap at this rate");
    }

    #[test]
    fn diurnal_nights_suppress_joins() {
        let cfg = ChurnCfg { rounds: 24, arrival_rate: 0.5, departure_prob: 0.1, max_clients: 16 };
        let hc = HelperChurnCfg {
            down_rate: 0.2,
            outage_rounds: 2,
            join_rate: 3.0,
            max_helpers: 40,
            diurnal_period: 6,
        };
        let ev = generate_with_helpers(8, &cfg, &hc, 3, 31);
        let mut day_joins = 0usize;
        for r in &ev[1..] {
            if 2 * (r.round % 6) >= 6 {
                assert!(r.helper_join.is_empty(), "night round {} admitted joins", r.round);
            } else {
                day_joins += r.helper_join.len();
            }
        }
        assert!(day_joins > 0, "day rounds must admit joins at rate 3.0");
    }

    #[test]
    fn helper_events_roundtrip_through_from_json() {
        let ev = generate_with_helpers(10, &churn(), &helper_churn(), 3, 7);
        let mut helpers = HelperRoster::base(3);
        for w in ev.windows(2) {
            let (prev, next) = (&w[0], &w[1]);
            let doc = Json::parse(&next.jsonl_line()).unwrap();
            let back = RoundEvents::from_json(&doc, next.round, &prev.roster, &helpers).unwrap();
            assert_eq!(&back, next, "round {}", next.round);
            helpers.apply(next);
        }
    }

    #[test]
    fn from_json_rejects_inconsistent_helper_events() {
        let prev = [0u64, 1, 2];
        let mut helpers = HelperRoster::base(3); // live 0,1,2 — next_id 3
        helpers.apply(&RoundEvents {
            helper_down: vec![2],
            ..RoundEvents::clients(0, vec![], vec![], vec![])
        }); // live 0,1 — down 2
        let one = |key: &str, id: f64| Json::obj(vec![(key, Json::Arr(vec![Json::Num(id)]))]);
        // Down of a helper that is not live.
        let err = RoundEvents::from_json(&one("helper_down", 2.0), 3, &prev, &helpers)
            .unwrap_err()
            .to_string();
        assert!(err.contains("helper-down id 2 is not a live helper"), "{err}");
        // Up of a helper that is not in an outage.
        let err = RoundEvents::from_json(&one("helper_up", 1.0), 3, &prev, &helpers)
            .unwrap_err()
            .to_string();
        assert!(err.contains("helper-up id 1 is not in an outage"), "{err}");
        // Down and up of the same helper in one event.
        let doc = Json::obj(vec![
            ("helper_down", Json::Arr(vec![Json::Num(1.0)])),
            ("helper_up", Json::Arr(vec![Json::Num(1.0)])),
        ]);
        let err = RoundEvents::from_json(&doc, 3, &prev, &helpers).unwrap_err().to_string();
        assert!(err.contains("cannot go down and come back"), "{err}");
        // Join reusing a helper id.
        let err = RoundEvents::from_json(&one("helper_join", 2.0), 3, &prev, &helpers)
            .unwrap_err()
            .to_string();
        assert!(err.contains("helper-join id 2 is not fresh"), "{err}");
        // Downing every live helper.
        let doc = Json::obj(vec![(
            "helper_down",
            Json::Arr(vec![Json::Num(0.0), Json::Num(1.0)]),
        )]);
        let err = RoundEvents::from_json(&doc, 3, &prev, &helpers).unwrap_err().to_string();
        assert!(err.contains("would leave no live helper"), "{err}");
        // ... unless an up or join keeps the set non-empty.
        let doc = Json::obj(vec![
            ("helper_down", Json::Arr(vec![Json::Num(0.0), Json::Num(1.0)])),
            ("helper_up", Json::Arr(vec![Json::Num(2.0)])),
        ]);
        assert!(RoundEvents::from_json(&doc, 3, &prev, &helpers).is_ok());
    }
}
