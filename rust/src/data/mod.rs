//! Datasets for the executable split-learning runtime.

pub mod synth;

pub use synth::SynthDataset;
