//! Synthetic CIFAR-10-like dataset — the rust twin of
//! `python/compile/data.py` (CIFAR-10 is not downloadable in this image;
//! the paper's orchestration layer is accuracy-oblivious, §III).
//!
//! Class k has a deterministic low-frequency sinusoid template; samples
//! are template + Gaussian noise. The split pipeline must drive the
//! cross-entropy loss down on this data (examples/e2e_train.rs).

use crate::runtime::tensor::Tensor;
use crate::util::rng::Rng;

pub const NUM_CLASSES: usize = 10;
pub const HEIGHT: usize = 32;
pub const WIDTH: usize = 32;
pub const CHANNELS: usize = 3;

/// Deterministic class template, shape (H, W, C) row-major — matches
/// `data.class_template` in python.
pub fn class_template(k: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; HEIGHT * WIDTH * CHANNELS];
    for y in 0..HEIGHT {
        for x in 0..WIDTH {
            for ch in 0..CHANNELS {
                let fx = 1.0 + (k % 5) as f32;
                let fy = 1.0 + ((k + ch) % 3) as f32;
                let phase = 0.7 * k as f32 + 1.3 * ch as f32;
                let v = (2.0 * std::f32::consts::PI * fx * x as f32 / WIDTH as f32 + phase).sin()
                    * (2.0 * std::f32::consts::PI * fy * y as f32 / HEIGHT as f32 + 0.5 * phase).cos();
                out[(y * WIDTH + x) * CHANNELS + ch] = 0.5 * v;
            }
        }
    }
    out
}

/// A data source bound to one client (its local dataset shard).
pub struct SynthDataset {
    rng: Rng,
    noise: f32,
    templates: Vec<Vec<f32>>,
}

impl SynthDataset {
    pub fn new(seed: u64, noise: f32) -> SynthDataset {
        SynthDataset {
            rng: Rng::seeded(seed),
            noise,
            templates: (0..NUM_CLASSES).map(class_template).collect(),
        }
    }

    /// Draw a batch: (x: (B,32,32,3) f32, y: (B,) i32).
    pub fn batch(&mut self, batch: usize) -> (Tensor, Tensor) {
        let img = HEIGHT * WIDTH * CHANNELS;
        let mut x = vec![0.0f32; batch * img];
        let mut y = vec![0i32; batch];
        for b in 0..batch {
            let k = self.rng.below(NUM_CLASSES);
            y[b] = k as i32;
            let t = &self.templates[k];
            for (dst, &src) in x[b * img..(b + 1) * img].iter_mut().zip(t.iter()) {
                *dst = src + self.noise * self.rng.gauss() as f32;
            }
        }
        (
            Tensor::from_f32(&[batch, HEIGHT, WIDTH, CHANNELS], x).unwrap(),
            Tensor::from_i32(&[batch], y).unwrap(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes() {
        let mut ds = SynthDataset::new(1, 0.3);
        let (x, y) = ds.batch(8);
        assert_eq!(x.shape, vec![8, 32, 32, 3]);
        assert_eq!(y.shape, vec![8]);
    }

    #[test]
    fn deterministic_per_seed() {
        let (x1, y1) = SynthDataset::new(7, 0.3).batch(4);
        let (x2, y2) = SynthDataset::new(7, 0.3).batch(4);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        let (x3, _) = SynthDataset::new(8, 0.3).batch(4);
        assert_ne!(x1, x3);
    }

    #[test]
    fn templates_distinct_between_classes() {
        let a = class_template(0);
        let b = class_template(1);
        let diff: f32 = a.iter().zip(&b).map(|(p, q)| (p - q).abs()).sum();
        assert!(diff > 10.0, "templates too similar: {diff}");
    }

    #[test]
    fn labels_cover_classes() {
        let mut ds = SynthDataset::new(3, 0.1);
        let (_, y) = ds.batch(400);
        let labels: std::collections::HashSet<i32> = match y.data {
            crate::runtime::tensor::TensorData::I32(v) => v.into_iter().collect(),
            _ => panic!(),
        };
        assert_eq!(labels.len(), NUM_CLASSES);
    }
}
