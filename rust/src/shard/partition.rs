//! Deterministic partitioning of an [`InstanceMs`] into **helper cells**.
//!
//! A cell is a (helpers, clients) pair; the union of cells covers every
//! helper and every client exactly once, so solving each cell
//! independently and merging the per-cell schedules yields a complete
//! (and, because helper sets are disjoint, capacity-feasible) global
//! schedule — the decomposition MP-SL exploits with its multihop helper
//! chains.
//!
//! Cells form by **affinity**, not arbitrarily:
//!
//! 1. Helpers sort by mean part-2 forward processing time (the device-tier
//!    axis) and split into contiguous balanced groups — similar-tier
//!    helpers land in the same cell.
//! 2. Clients sort by their best-edge client-side round trip
//!    `min_i (r + l + l' + r')` (the link-regime axis) and split into
//!    contiguous balanced slices, pairing the best-connected clients with
//!    the fastest helper tier.
//! 3. Two deterministic fix-up passes repair memory: every client must
//!    fit some helper in its cell (hard, always reparable because the
//!    globally largest helper lives in some cell), and cells whose
//!    aggregate footprint exceeds aggregate capacity shed their largest
//!    clients to the slackest fitting cell (best-effort).
//!
//! Everything is a pure function of the instance and the
//! [`ShardCfg`] — no RNG — so a partition is reproducible from the
//! instance bytes alone.

use crate::instance::InstanceMs;

/// Shard-layer knobs: cell sizing and the stitching rebalance bounds.
#[derive(Clone, Debug)]
pub struct ShardCfg {
    /// Target clients per cell; the cell count is
    /// `ceil(J / shard_clients)` clamped to `[1, I]`.
    pub shard_clients: usize,
    /// Stitch-gap threshold (stitched makespan / max per-shard lower
    /// bound) above which the coordinator attempts boundary-client
    /// migrations.
    pub rebalance_gap: f64,
    /// Maximum migrations the coordinator commits per stitch.
    pub max_migrations: usize,
}

impl Default for ShardCfg {
    fn default() -> Self {
        ShardCfg { shard_clients: 1024, rebalance_gap: 1.25, max_migrations: 4 }
    }
}

/// One helper cell: original helper and client indices, both sorted
/// ascending (the canonical form every consumer relies on).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardCell {
    pub helpers: Vec<usize>,
    pub clients: Vec<usize>,
}

impl ShardCell {
    /// Smallest original helper id — the order-invariant identity used
    /// for tie-breaking across cells (cell *positions* depend on
    /// enumeration order; helper ids do not).
    pub fn min_helper(&self) -> usize {
        self.helpers.first().copied().unwrap_or(usize::MAX)
    }
}

/// A complete partition: every helper in exactly one cell, every client
/// in exactly one cell.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    pub cells: Vec<ShardCell>,
}

impl ShardPlan {
    pub fn n_cells(&self) -> usize {
        self.cells.len()
    }
}

/// Carve the cell's sub-instance out of the full instance: client
/// columns first, then helper rows.
pub fn sub_instance(ms: &InstanceMs, cell: &ShardCell) -> InstanceMs {
    ms.restrict_clients(&cell.clients).restrict_helpers(&cell.helpers)
}

/// Build the partition. See the module docs for the three passes.
pub fn partition(ms: &InstanceMs, cfg: &ShardCfg) -> ShardPlan {
    let j_n = ms.n_clients;
    let i_n = ms.n_helpers;
    let target = cfg.shard_clients.max(1);
    let k = if j_n == 0 { 1 } else { ((j_n + target - 1) / target).max(1).min(i_n.max(1)) };
    if k <= 1 || i_n < 2 {
        return ShardPlan {
            cells: vec![ShardCell { helpers: (0..i_n).collect(), clients: (0..j_n).collect() }],
        };
    }

    // Pass 1: helpers by device tier (mean p), contiguous balanced groups.
    let mut helper_order: Vec<usize> = (0..i_n).collect();
    let helper_key: Vec<f64> = (0..i_n)
        .map(|i| {
            let row = &ms.p_ms[i * j_n..(i + 1) * j_n];
            if j_n == 0 { 0.0 } else { row.iter().sum::<f64>() / j_n as f64 }
        })
        .collect();
    helper_order.sort_by(|&a, &b| {
        helper_key[a].partial_cmp(&helper_key[b]).unwrap().then(a.cmp(&b))
    });

    // Pass 2: clients by best-edge link round trip, contiguous balanced
    // slices aligned with the helper tiers.
    let mut client_order: Vec<usize> = (0..j_n).collect();
    let client_key: Vec<f64> = (0..j_n)
        .map(|j| {
            (0..i_n)
                .map(|i| {
                    let e = i * j_n + j;
                    ms.r_ms[e] + ms.l_ms[e] + ms.lp_ms[e] + ms.rp_ms[e]
                })
                .fold(f64::MAX, f64::min)
        })
        .collect();
    client_order.sort_by(|&a, &b| {
        client_key[a].partial_cmp(&client_key[b]).unwrap().then(a.cmp(&b))
    });

    let slice = |order: &[usize], t: usize| -> Vec<usize> {
        let n = order.len();
        let base = n / k;
        let rem = n % k;
        let start = t * base + t.min(rem);
        let len = base + usize::from(t < rem);
        order[start..start + len].to_vec()
    };
    let mut cells: Vec<ShardCell> = (0..k)
        .map(|t| ShardCell { helpers: slice(&helper_order, t), clients: slice(&client_order, t) })
        .collect();

    // Pass 3a: hard memory fix-up — every client must fit some helper in
    // its cell. The cell holding the globally largest helper always fits,
    // so this never fails.
    let cell_max_mem = |cell: &ShardCell| -> f64 {
        cell.helpers.iter().map(|&i| ms.mem_gb[i]).fold(0.0, f64::max)
    };
    for t in 0..k {
        let misfits: Vec<usize> = {
            let max_mem = cell_max_mem(&cells[t]);
            cells[t].clients.iter().copied().filter(|&j| ms.d_gb[j] > max_mem).collect()
        };
        for j in misfits {
            let dest = (0..k)
                .find(|&u| u != t && ms.d_gb[j] <= cell_max_mem(&cells[u]))
                .expect("validated instance: some cell holds a helper that fits every client");
            cells[t].clients.retain(|&x| x != j);
            cells[dest].clients.push(j);
        }
    }

    // Pass 3b: best-effort capacity fix-up — shed the largest clients of
    // aggregate-overloaded cells to the slackest cell that fits them.
    let sum_d = |cell: &ShardCell| -> f64 { cell.clients.iter().map(|&j| ms.d_gb[j]).sum() };
    let sum_mem = |cell: &ShardCell| -> f64 { cell.helpers.iter().map(|&i| ms.mem_gb[i]).sum() };
    let mut moves_left = j_n;
    for t in 0..k {
        while sum_d(&cells[t]) > sum_mem(&cells[t]) && moves_left > 0 {
            let donor = cells[t]
                .clients
                .iter()
                .copied()
                .max_by(|&a, &b| {
                    ms.d_gb[a].partial_cmp(&ms.d_gb[b]).unwrap().then(b.cmp(&a))
                });
            let Some(j) = donor else { break };
            let dest = (0..k)
                .filter(|&u| u != t && ms.d_gb[j] <= cell_max_mem(&cells[u]))
                .max_by(|&a, &b| {
                    let sa = sum_mem(&cells[a]) - sum_d(&cells[a]);
                    let sb = sum_mem(&cells[b]) - sum_d(&cells[b]);
                    sa.partial_cmp(&sb).unwrap().then(b.cmp(&a))
                });
            let Some(u) = dest else { break };
            if sum_mem(&cells[u]) - sum_d(&cells[u]) < ms.d_gb[j] {
                break; // nowhere has real slack; leave it to the solver
            }
            cells[t].clients.retain(|&x| x != j);
            cells[u].clients.push(j);
            moves_left -= 1;
        }
    }

    for cell in &mut cells {
        cell.helpers.sort_unstable();
        cell.clients.sort_unstable();
    }
    ShardPlan { cells }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};

    fn gen(scenario: Scenario, j: usize, i: usize, seed: u64) -> InstanceMs {
        ScenarioCfg::new(scenario, Model::ResNet101, j, i, seed).generate()
    }

    fn assert_is_partition(ms: &InstanceMs, plan: &ShardPlan) {
        let mut helpers: Vec<usize> = plan.cells.iter().flat_map(|c| c.helpers.clone()).collect();
        let mut clients: Vec<usize> = plan.cells.iter().flat_map(|c| c.clients.clone()).collect();
        helpers.sort_unstable();
        clients.sort_unstable();
        assert_eq!(helpers, (0..ms.n_helpers).collect::<Vec<_>>());
        assert_eq!(clients, (0..ms.n_clients).collect::<Vec<_>>());
    }

    #[test]
    fn small_instances_stay_monolithic() {
        let ms = gen(Scenario::S1, 40, 4, 1);
        let plan = partition(&ms, &ShardCfg::default());
        assert_eq!(plan.n_cells(), 1);
        assert_is_partition(&ms, &plan);
    }

    #[test]
    fn cell_count_and_balance() {
        let ms = gen(Scenario::S6MegaHomogeneous, 300, 6, 2);
        let cfg = ShardCfg { shard_clients: 100, ..ShardCfg::default() };
        let plan = partition(&ms, &cfg);
        assert_eq!(plan.n_cells(), 3);
        assert_is_partition(&ms, &plan);
        for cell in &plan.cells {
            assert_eq!(cell.helpers.len(), 2);
            // Balanced up to the memory fix-up passes.
            assert!(cell.clients.len() >= 90 && cell.clients.len() <= 110, "{}", cell.clients.len());
        }
    }

    #[test]
    fn partition_is_deterministic() {
        let ms = gen(Scenario::S2, 200, 5, 9);
        let cfg = ShardCfg { shard_clients: 50, ..ShardCfg::default() };
        assert_eq!(partition(&ms, &cfg), partition(&ms, &cfg));
    }

    #[test]
    fn every_client_fits_some_helper_in_its_cell() {
        // S5 is the memory-starved family — the hard fix-up must hold there.
        let ms = gen(Scenario::S5MemoryStarved, 240, 8, 3);
        let cfg = ShardCfg { shard_clients: 60, ..ShardCfg::default() };
        let plan = partition(&ms, &cfg);
        assert_is_partition(&ms, &plan);
        for cell in &plan.cells {
            let max_mem = cell.helpers.iter().map(|&i| ms.mem_gb[i]).fold(0.0, f64::max);
            for &j in &cell.clients {
                assert!(ms.d_gb[j] <= max_mem, "client {j} does not fit its cell");
            }
            // And sub-instance construction must therefore not panic.
            let sub = sub_instance(&ms, cell);
            assert_eq!(sub.n_clients, cell.clients.len());
            assert_eq!(sub.n_helpers, cell.helpers.len());
        }
    }

    #[test]
    fn helper_tiers_are_contiguous_in_capability() {
        let ms = gen(Scenario::S2, 200, 6, 4);
        let cfg = ShardCfg { shard_clients: 50, ..ShardCfg::default() };
        let plan = partition(&ms, &cfg);
        // Mean-p ranges of distinct cells must not interleave: sort cells
        // by their mean helper key and check ranges are ordered.
        let key = |i: usize| -> f64 {
            let row = &ms.p_ms[i * ms.n_clients..(i + 1) * ms.n_clients];
            row.iter().sum::<f64>() / ms.n_clients as f64
        };
        let mut ranges: Vec<(f64, f64)> = plan
            .cells
            .iter()
            .map(|c| {
                let ks: Vec<f64> = c.helpers.iter().map(|&i| key(i)).collect();
                (ks.iter().cloned().fold(f64::MAX, f64::min), ks.iter().cloned().fold(f64::MIN, f64::max))
            })
            .collect();
        ranges.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for w in ranges.windows(2) {
            assert!(w[0].1 <= w[1].0 + 1e-9, "tier ranges interleave: {:?}", ranges);
        }
    }
}
