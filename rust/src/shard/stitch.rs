//! The coordinator's stitching pass: merge per-shard schedules into one
//! global [`Schedule`], measure the stitching gap, and rebalance.
//!
//! **Merging is exact, not heuristic**: cells own disjoint helper sets,
//! so re-indexing each shard's local helpers/clients back to original
//! ids and keeping every slot origin at 0 yields a global schedule in
//! which constraint (3) — one task per helper per slot — holds slot for
//! slot because it held inside each cell. The stitched makespan is
//! therefore simply the max over shard makespans, and what sharding
//! *costs* is visible in the **stitch gap**: stitched makespan divided
//! by the max per-shard lower bound. A gap of 1 means the dominant shard
//! is already at its own bound; a large gap means one cell is overloaded
//! relative to its helpers — exactly the case the bounded rebalancing
//! pass attacks by migrating the worst shard's boundary client (its
//! makespan-defining one) to the least-loaded cell that can host it,
//! re-solving only the two touched cells, and keeping the move only if
//! the global makespan strictly improves.
//!
//! Every choice in the pass tie-breaks on order-invariant keys (shard
//! identity = smallest original helper id, client identity = original
//! client id), so a permuted `Vec<ShardSolved>` stitches to byte-
//! identical output — pinned by the shard property suite.

use crate::instance::InstanceMs;
use crate::solver::admm::AdmmCfg;
use crate::solver::schedule::{Assignment, Schedule, SlotRuns};

use super::partition::ShardCfg;
use super::solve::{solve_one, ShardSolved};

/// Outcome of the stitching pass.
#[derive(Clone, Debug)]
pub struct StitchReport {
    /// The merged global schedule, in original instance indexing.
    pub schedule: Schedule,
    /// Global makespan = max over shard makespans, slots.
    pub makespan: u32,
    /// Max per-shard trivial lower bound, slots.
    pub max_shard_lb: u32,
    /// `makespan / max(max_shard_lb, 1)` — the cost of solving shards
    /// independently instead of monolithically.
    pub stitch_gap: f64,
    /// Boundary-client migrations the rebalancing pass committed.
    pub migrations: usize,
}

/// Merge per-shard schedules into one schedule over the full instance.
/// Panics (debug) if the shards do not partition the client set.
pub fn merge(n_clients: usize, shards: &[ShardSolved]) -> Schedule {
    let mut helper_of = vec![usize::MAX; n_clients];
    let mut fwd = vec![SlotRuns::new(); n_clients];
    let mut bwd = vec![SlotRuns::new(); n_clients];
    for sh in shards {
        for (jj, &j) in sh.cell.clients.iter().enumerate() {
            debug_assert_eq!(helper_of[j], usize::MAX, "client {j} in two shards");
            helper_of[j] = sh.cell.helpers[sh.schedule.assignment.helper_of[jj]];
            fwd[j] = sh.schedule.fwd[jj].clone();
            bwd[j] = sh.schedule.bwd[jj].clone();
        }
    }
    debug_assert!(helper_of.iter().all(|&i| i != usize::MAX), "unassigned client after merge");
    Schedule { assignment: Assignment::new(helper_of), fwd, bwd }
}

fn global_makespan(shards: &[ShardSolved]) -> u32 {
    shards.iter().map(|s| s.makespan).max().unwrap_or(0)
}

fn gap_of(makespan: u32, max_lb: u32) -> f64 {
    makespan as f64 / max_lb.max(1) as f64
}

/// Order-invariant "worst shard" choice: highest makespan, ties to the
/// smallest original helper id.
fn worst_shard(shards: &[ShardSolved]) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (k, sh) in shards.iter().enumerate() {
        if sh.cell.clients.is_empty() {
            continue;
        }
        best = match best {
            None => Some(k),
            Some(b) => {
                let (bm, bh) = (shards[b].makespan, shards[b].cell.min_helper());
                if (sh.makespan, bh) > (bm, sh.cell.min_helper()) {
                    // sh.makespan > bm, or equal makespan with smaller id.
                    Some(k)
                } else {
                    Some(b)
                }
            }
        };
    }
    best
}

/// Stitch `shards` and run the bounded rebalancing pass. Returns the
/// report plus the (possibly re-solved) shards so callers can surface
/// final per-shard metrics.
pub fn stitch_and_rebalance(
    ms: &InstanceMs,
    slot_ms: f64,
    admm_cfg: &AdmmCfg,
    cfg: &ShardCfg,
    mut shards: Vec<ShardSolved>,
) -> (StitchReport, Vec<ShardSolved>) {
    let mut migrations = 0usize;
    while migrations < cfg.max_migrations && shards.len() >= 2 {
        let makespan = global_makespan(&shards);
        let max_lb = shards.iter().map(|s| s.lower_bound).max().unwrap_or(0);
        if gap_of(makespan, max_lb) <= cfg.rebalance_gap {
            break;
        }
        let Some(w) = worst_shard(&shards) else { break };
        if shards[w].makespan < makespan {
            break; // worst client-bearing shard is not the bottleneck
        }
        // Boundary client: the makespan-defining one, ties to the
        // smallest original client id.
        let Some(jj) = (0..shards[w].completions.len()).max_by_key(|&jj| {
            (shards[w].completions[jj], usize::MAX - shards[w].cell.clients[jj])
        }) else {
            break;
        };
        let j = shards[w].cell.clients[jj];
        // Receiver: the least-loaded other shard whose largest helper can
        // host the client; ties to the smallest helper id.
        let mut recv: Option<usize> = None;
        for (k, sh) in shards.iter().enumerate() {
            if k == w {
                continue;
            }
            let fits = sh.cell.helpers.iter().any(|&i| ms.mem_gb[i] >= ms.d_gb[j]);
            if !fits {
                continue;
            }
            recv = match recv {
                None => Some(k),
                Some(b) => {
                    let (bm, bh) = (shards[b].makespan, shards[b].cell.min_helper());
                    if (sh.makespan, sh.cell.min_helper()) < (bm, bh) {
                        Some(k)
                    } else {
                        Some(b)
                    }
                }
            };
        }
        let Some(r) = recv else { break };

        let mut donor_cell = shards[w].cell.clone();
        donor_cell.clients.retain(|&x| x != j);
        let mut recv_cell = shards[r].cell.clone();
        let pos = recv_cell.clients.partition_point(|&x| x < j);
        recv_cell.clients.insert(pos, j);

        let resolved = solve_one(ms, slot_ms, admm_cfg, donor_cell)
            .zip(solve_one(ms, slot_ms, admm_cfg, recv_cell));
        let Some((new_donor, new_recv)) = resolved else { break };
        let candidate = shards
            .iter()
            .enumerate()
            .map(|(k, sh)| {
                if k == w {
                    new_donor.makespan
                } else if k == r {
                    new_recv.makespan
                } else {
                    sh.makespan
                }
            })
            .max()
            .unwrap_or(0);
        if candidate >= makespan {
            break; // migration does not strictly help; stop rebalancing
        }
        shards[w] = new_donor;
        shards[r] = new_recv;
        migrations += 1;
    }

    let makespan = global_makespan(&shards);
    let max_shard_lb = shards.iter().map(|s| s.lower_bound).max().unwrap_or(0);
    let report = StitchReport {
        schedule: merge(ms.n_clients, &shards),
        makespan,
        max_shard_lb,
        stitch_gap: gap_of(makespan, max_shard_lb),
        migrations,
    };
    (report, shards)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};
    use crate::shard::partition::partition;
    use crate::shard::solve::solve_shards;

    fn solved(j: usize, i: usize, per_shard: usize, seed: u64) -> (InstanceMs, Vec<ShardSolved>) {
        let ms = ScenarioCfg::new(Scenario::S2, Model::ResNet101, j, i, seed).generate();
        let cfg = ShardCfg { shard_clients: per_shard, ..ShardCfg::default() };
        let plan = partition(&ms, &cfg);
        let shards = solve_shards(&ms, 180.0, &AdmmCfg::default(), &plan, 2).unwrap();
        (ms, shards)
    }

    #[test]
    fn merged_schedule_is_feasible_on_the_full_instance() {
        let (ms, shards) = solved(120, 4, 30, 7);
        let inst = ms.quantize(180.0);
        let sched = merge(inst.n_clients, &shards);
        let v = sched.violations(&inst);
        assert!(v.is_empty(), "stitched violations: {v:?}");
        assert_eq!(
            sched.makespan(&inst),
            shards.iter().map(|s| s.makespan).max().unwrap(),
            "global makespan must equal the max shard makespan"
        );
    }

    #[test]
    fn stitch_is_shard_order_invariant() {
        let (ms, shards) = solved(150, 5, 30, 13);
        let cfg = ShardCfg { shard_clients: 30, ..ShardCfg::default() };
        let admm = AdmmCfg::default();
        let (a, _) = stitch_and_rebalance(&ms, 180.0, &admm, &cfg, shards.clone());
        let mut rev = shards;
        rev.reverse();
        let (b, _) = stitch_and_rebalance(&ms, 180.0, &admm, &cfg, rev);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.schedule.assignment, b.schedule.assignment);
        for j in 0..ms.n_clients {
            assert_eq!(a.schedule.fwd[j].runs(), b.schedule.fwd[j].runs());
            assert_eq!(a.schedule.bwd[j].runs(), b.schedule.bwd[j].runs());
        }
    }

    #[test]
    fn rebalance_never_worsens_and_respects_the_bound() {
        let (ms, shards) = solved(200, 5, 40, 3);
        let before = shards.iter().map(|s| s.makespan).max().unwrap();
        // Force rebalancing on: any gap over 1.0 triggers it.
        let cfg = ShardCfg { shard_clients: 40, rebalance_gap: 1.0, max_migrations: 3 };
        let (rep, after) = stitch_and_rebalance(&ms, 180.0, &AdmmCfg::default(), &cfg, shards);
        assert!(rep.makespan <= before, "rebalancing worsened the makespan");
        assert!(rep.migrations <= 3);
        let inst = ms.quantize(180.0);
        assert!(rep.schedule.is_feasible(&inst), "post-rebalance stitched schedule infeasible");
        // Shards returned are the ones the report was computed from.
        assert_eq!(rep.makespan, after.iter().map(|s| s.makespan).max().unwrap());
    }
}
