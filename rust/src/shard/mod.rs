//! Sharded hierarchical solving for mega-scale instances — the repo's
//! first above-the-solver hierarchy.
//!
//! The paper reaches near-optimality by decomposing ℙ per helper
//! (Theorem 2); this layer applies the same idea one level up, where
//! the monolithic solvers stop being affordable: partition the instance
//! into **helper cells** by link-regime/device-tier affinity
//! ([`partition`]), solve every cell concurrently with the flat §VII
//! strategy ([`solve`]), and stitch the per-cell schedules into one
//! global schedule with a bounded cross-cell rebalancing pass
//! ([`stitch`]). MP-SL's multihop helper chains (PAPERS.md,
//! arxiv 2402.00208) are exactly such cells with internal structure.
//!
//! | Module | Role |
//! |---|---|
//! | [`partition`] | deterministic helper cells, memory fix-up, [`ShardCfg`] |
//! | [`solve`] | concurrent per-shard solves over [`crate::exec::pool`] |
//! | [`stitch`] | merge → stitch gap → bounded boundary-client migration |
//! | [`grid`] | `psl shard` grid runner + `psl-shard` artifact rows |
//!
//! Entry points: [`solve_ms`] from the continuous domain, and
//! [`solve_quantized`] from an already-slotted [`Instance`] (what
//! [`Method::Sharded`](crate::solver::strategy::Method) routes through —
//! the instance is lifted with the quantization-stable
//! [`Instance::to_ms`] so every cell re-quantizes to exactly the
//! original slot counts). Results are thread-count and shard-order
//! invariant; the worker count only changes wall-clock time.

pub mod grid;
pub mod partition;
pub mod solve;
pub mod stitch;

pub use grid::{ShardGridCfg, ShardRow};
pub use partition::{partition as partition_cells, sub_instance, ShardCell, ShardCfg, ShardPlan};
pub use solve::{solve_shards, ShardSolved};
pub use stitch::{merge, stitch_and_rebalance, StitchReport};

use crate::instance::{Instance, InstanceMs};
use crate::solver::admm::AdmmCfg;

/// Everything one sharded solve produces: the final per-shard solutions
/// (post-rebalance), the stitch report (with the merged global
/// schedule), and the monolithic lower bound for context.
#[derive(Clone, Debug)]
pub struct ShardOutcome {
    pub shards: Vec<ShardSolved>,
    pub stitch: StitchReport,
    /// Trivial lower bound of the *unsharded* instance, slots — the
    /// floor a perfect monolithic solve could not beat. `stitch.makespan
    /// / monolithic_lb` bounds what sharding can have cost.
    pub monolithic_lb: u32,
}

/// Monolithic trivial lower bound computed edge-wise from the ms-level
/// instance (same quantization as [`InstanceMs::quantize`]) without
/// materializing the full slotted instance — at mega scale that
/// materialization is the dominant allocation.
fn monolithic_lb_ms(ms: &InstanceMs, slot_ms: f64) -> u32 {
    let q = |v: f64| (v / slot_ms).ceil() as u32;
    let q1 = |v: f64| q(v).max(1);
    let mut lb = 0u32;
    for j in 0..ms.n_clients {
        let mut best = u32::MAX;
        for i in 0..ms.n_helpers {
            let e = ms.edge(i, j);
            best = best.min(
                q(ms.r_ms[e])
                    + q1(ms.p_ms[e])
                    + q(ms.l_ms[e])
                    + q(ms.lp_ms[e])
                    + q1(ms.pp_ms[e])
                    + q(ms.rp_ms[e]),
            );
        }
        lb = lb.max(best);
    }
    if ms.n_clients == 0 {
        0
    } else {
        lb
    }
}

/// Full pipeline from the continuous domain: partition → concurrent
/// per-shard solves (`threads` pool workers) → stitch + rebalance.
/// Returns `None` if some cell is unsolvable (memory-wedged beyond the
/// partitioner's best-effort repair).
pub fn solve_ms(
    ms: &InstanceMs,
    slot_ms: f64,
    cfg: &ShardCfg,
    admm_cfg: &AdmmCfg,
    threads: usize,
) -> Option<ShardOutcome> {
    let plan = {
        let _sp = crate::obs::span("shard", "shard/partition");
        partition::partition(ms, cfg)
    };
    crate::obs::counter_add("shard.cells", plan.cells.len() as u64);
    let shards = {
        let mut sp = crate::obs::span("shard", "shard/solve-cells");
        sp.arg("cells", plan.cells.len() as u64);
        solve::solve_shards(ms, slot_ms, admm_cfg, &plan, threads)?
    };
    let (stitch, shards) = {
        let _sp = crate::obs::span("shard", "shard/stitch");
        stitch::stitch_and_rebalance(ms, slot_ms, admm_cfg, cfg, shards)
    };
    crate::obs::counter_add("shard.migrations", stitch.migrations as u64);
    Some(ShardOutcome { shards, stitch, monolithic_lb: monolithic_lb_ms(ms, slot_ms) })
}

/// [`solve_ms`] from an already-quantized instance — the
/// [`Method::Sharded`](crate::solver::strategy::Method) path. The lift
/// through [`Instance::to_ms`] is quantization-stable, so the stitched
/// schedule's slot counts match `inst` exactly and the returned
/// schedule drops into any consumer of the original instance.
pub fn solve_quantized(inst: &Instance, cfg: &ShardCfg, threads: usize) -> Option<ShardOutcome> {
    solve_quantized_with(inst, cfg, &AdmmCfg::default(), threads)
}

/// [`solve_quantized`] with an explicit ADMM config.
pub fn solve_quantized_with(
    inst: &Instance,
    cfg: &ShardCfg,
    admm_cfg: &AdmmCfg,
    threads: usize,
) -> Option<ShardOutcome> {
    let ms = inst.to_ms();
    let mut out = solve_ms(&ms, inst.slot_ms, cfg, admm_cfg, threads)?;
    // The edge-wise bound on the lifted instance equals the original's by
    // quantization stability; use the original's directly for clarity.
    out.monolithic_lb = inst.makespan_lower_bound();
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};

    #[test]
    fn outcome_is_feasible_and_bounded_below() {
        let ms = ScenarioCfg::new(Scenario::S3Clustered, Model::ResNet101, 180, 6, 21).generate();
        let cfg = ShardCfg { shard_clients: 45, ..ShardCfg::default() };
        let out = solve_ms(&ms, 180.0, &cfg, &AdmmCfg::default(), 3).unwrap();
        let inst = ms.quantize(180.0);
        assert!(out.stitch.schedule.is_feasible(&inst));
        assert_eq!(out.stitch.makespan, out.stitch.schedule.makespan(&inst));
        assert!(out.stitch.makespan >= out.monolithic_lb);
        assert_eq!(out.monolithic_lb, inst.makespan_lower_bound());
    }

    #[test]
    fn quantized_entry_matches_ms_entry() {
        let ms = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 120, 4, 8).generate();
        let inst = ms.quantize(180.0);
        let cfg = ShardCfg { shard_clients: 30, ..ShardCfg::default() };
        let out = solve_quantized(&inst, &cfg, 2).unwrap();
        // The stitched schedule must be feasible against the *original*
        // quantized instance — the whole point of the stable lift.
        assert!(out.stitch.schedule.is_feasible(&inst));
        assert!(out.stitch.makespan >= inst.makespan_lower_bound());
    }

    #[test]
    fn strategy_sharded_arm_returns_full_indexing() {
        // Through solver::strategy with a forced-small frontier we cannot
        // go (the const is fixed); call the arm directly instead.
        let ms = ScenarioCfg::new(Scenario::S6MegaHomogeneous, Model::ResNet101, 96, 4, 2).generate();
        let inst = ms.quantize(180.0);
        let out = solve_quantized(&inst, &ShardCfg { shard_clients: 24, ..ShardCfg::default() }, 2).unwrap();
        assert_eq!(out.stitch.schedule.assignment.helper_of.len(), inst.n_clients);
        assert_eq!(out.stitch.schedule.fwd.len(), inst.n_clients);
        assert!(out.stitch.schedule.assignment.helper_of.iter().all(|&i| i < inst.n_helpers));
    }
}
