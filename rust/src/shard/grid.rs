//! The `psl shard` grid runner: scenario × size cells solved through the
//! full shard pipeline (partition → concurrent per-shard solves →
//! stitch + rebalance), emitted as the `psl-shard` artifact.
//!
//! Like `psl sweep`, output is **thread-count invariant**: per-cell
//! seeds are a pure function of the cell coordinates, cells run
//! sequentially (the parallelism lives inside each cell's shard solves),
//! and the artifact records no worker counts — the same grid config
//! always produces the same bytes.

use crate::bench::artifact::{self, ArtifactKind};
use crate::instance::profiles::Model;
use crate::instance::scenario::{Scenario, ScenarioCfg};
use crate::solver::admm::AdmmCfg;
use crate::solver::strategy::Method;
use crate::util::json::Json;
use crate::util::rng::fnv64;

use super::partition::ShardCfg;
use super::{solve_ms, ShardOutcome};

/// Grid configuration for `psl shard`.
#[derive(Clone, Debug)]
pub struct ShardGridCfg {
    pub scenarios: Vec<Scenario>,
    pub model: Model,
    /// (n_clients, n_helpers) cells.
    pub sizes: Vec<(usize, usize)>,
    pub seed: u64,
    /// Slot length; `None` = the model profile's default.
    pub slot_ms: Option<f64>,
    pub shard: ShardCfg,
    pub threads: usize,
}

/// One shard of one grid cell, as reported in the artifact.
#[derive(Clone, Debug)]
pub struct ShardRowShard {
    pub shard: usize,
    pub n_clients: usize,
    pub n_helpers: usize,
    /// Smallest original helper id — the shard's order-invariant identity.
    pub min_helper: usize,
    pub method: Method,
    pub makespan_slots: u32,
    pub lower_bound_slots: u32,
}

/// One grid cell: the partition's shape, per-shard metrics, and the
/// stitched result.
#[derive(Clone, Debug)]
pub struct ShardRow {
    pub scenario: Scenario,
    pub model: Model,
    pub n_clients: usize,
    pub n_helpers: usize,
    pub seed: u64,
    pub slot_ms: f64,
    pub n_shards: usize,
    pub migrations: usize,
    pub shards: Vec<ShardRowShard>,
    pub stitched_makespan_slots: u32,
    pub stitched_makespan_ms: f64,
    pub max_shard_lb_slots: u32,
    /// stitched makespan / max per-shard lower bound.
    pub stitch_gap: f64,
    /// The monolithic instance's trivial lower bound — what a perfect
    /// unsharded solve could not beat.
    pub monolithic_lb_slots: u32,
}

/// Per-cell seed: a pure function of the grid seed and the cell
/// coordinates, so adding/removing/reordering cells never changes any
/// other cell's instance (same discipline as `psl sweep`).
pub fn cell_seed(seed: u64, scenario: Scenario, model: Model, j: usize, i: usize) -> u64 {
    seed ^ fnv64(scenario.name())
        ^ fnv64(model.name()).rotate_left(13)
        ^ (j as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15).rotate_left(29)
        ^ (i as u64).wrapping_mul(0xc2b2_ae3d_27d4_eb4f).rotate_left(43)
}

fn row_of(cfg: &ShardGridCfg, scenario: Scenario, j: usize, i: usize) -> anyhow::Result<ShardRow> {
    let seed = cell_seed(cfg.seed, scenario, cfg.model, j, i);
    let ms = ScenarioCfg::new(scenario, cfg.model, j, i, seed).generate();
    let slot_ms = cfg.slot_ms.unwrap_or(cfg.model.profile().default_slot_ms);
    let out: ShardOutcome =
        solve_ms(&ms, slot_ms, &cfg.shard, &AdmmCfg::default(), cfg.threads).ok_or_else(|| {
            anyhow::anyhow!("{} {j}x{i}: shard solve failed (memory-wedged cell)", scenario.name())
        })?;
    let shards = out
        .shards
        .iter()
        .enumerate()
        .map(|(k, sh)| ShardRowShard {
            shard: k,
            n_clients: sh.cell.clients.len(),
            n_helpers: sh.cell.helpers.len(),
            min_helper: sh.cell.min_helper(),
            method: sh.method,
            makespan_slots: sh.makespan,
            lower_bound_slots: sh.lower_bound,
        })
        .collect();
    Ok(ShardRow {
        scenario,
        model: cfg.model,
        n_clients: j,
        n_helpers: i,
        seed,
        slot_ms,
        n_shards: out.shards.len(),
        migrations: out.stitch.migrations,
        shards,
        stitched_makespan_slots: out.stitch.makespan,
        stitched_makespan_ms: out.stitch.makespan as f64 * slot_ms,
        max_shard_lb_slots: out.stitch.max_shard_lb,
        stitch_gap: out.stitch.stitch_gap,
        monolithic_lb_slots: out.monolithic_lb,
    })
}

/// Run the grid. Cells run sequentially in canonical (scenario, size)
/// order; the shard-level parallelism inside each cell uses
/// `cfg.threads` workers.
pub fn run(cfg: &ShardGridCfg) -> anyhow::Result<Vec<ShardRow>> {
    let mut rows = Vec::new();
    for &scenario in &cfg.scenarios {
        for &(j, i) in &cfg.sizes {
            rows.push(row_of(cfg, scenario, j, i)?);
        }
    }
    Ok(rows)
}

/// Serialize rows as the `psl-shard` artifact document.
pub fn rows_to_json(rows: &[ShardRow]) -> Json {
    let arr = rows
        .iter()
        .map(|r| {
            let shards = r
                .shards
                .iter()
                .map(|s| {
                    Json::obj(vec![
                        ("shard", Json::Num(s.shard as f64)),
                        ("n_clients", Json::Num(s.n_clients as f64)),
                        ("n_helpers", Json::Num(s.n_helpers as f64)),
                        ("min_helper", Json::Num(s.min_helper as f64)),
                        ("method", Json::Str(s.method.name().to_string())),
                        ("makespan_slots", Json::Num(s.makespan_slots as f64)),
                        ("lower_bound_slots", Json::Num(s.lower_bound_slots as f64)),
                    ])
                })
                .collect();
            Json::obj(vec![
                ("scenario", Json::Str(r.scenario.name().to_string())),
                ("model", Json::Str(r.model.name().to_string())),
                ("n_clients", Json::Num(r.n_clients as f64)),
                ("n_helpers", Json::Num(r.n_helpers as f64)),
                ("seed", Json::Str(r.seed.to_string())),
                ("slot_ms", Json::Num(r.slot_ms)),
                ("n_shards", Json::Num(r.n_shards as f64)),
                ("migrations", Json::Num(r.migrations as f64)),
                ("shards", Json::Arr(shards)),
                ("stitched_makespan_slots", Json::Num(r.stitched_makespan_slots as f64)),
                ("stitched_makespan_ms", Json::Num(r.stitched_makespan_ms)),
                ("max_shard_lb_slots", Json::Num(r.max_shard_lb_slots as f64)),
                ("stitch_gap", Json::Num(r.stitch_gap)),
                ("monolithic_lb_slots", Json::Num(r.monolithic_lb_slots as f64)),
            ])
        })
        .collect();
    artifact::envelope(ArtifactKind::Shard, vec![("rows", Json::Arr(arr))])
}

/// Persist under `target/psl-bench/<name>.json`.
pub fn save(name: &str, rows: &[ShardRow]) -> std::io::Result<std::path::PathBuf> {
    artifact::save(name, &rows_to_json(rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(threads: usize) -> ShardGridCfg {
        ShardGridCfg {
            scenarios: vec![Scenario::S6MegaHomogeneous],
            model: Model::ResNet101,
            sizes: vec![(96, 4)],
            seed: 42,
            slot_ms: None,
            shard: ShardCfg { shard_clients: 24, ..ShardCfg::default() },
            threads,
        }
    }

    #[test]
    fn grid_rows_carry_per_shard_and_stitched_metrics() {
        let rows = run(&small_cfg(2)).unwrap();
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        assert_eq!(r.n_shards, 4);
        assert_eq!(r.shards.len(), 4);
        assert_eq!(
            r.stitched_makespan_slots,
            r.shards.iter().map(|s| s.makespan_slots).max().unwrap()
        );
        assert!(r.stitch_gap >= 1.0);
        assert!(r.stitched_makespan_slots >= r.monolithic_lb_slots);
        assert!(r.stitched_makespan_ms > 0.0);
    }

    #[test]
    fn grid_bytes_are_thread_count_invariant() {
        let a = rows_to_json(&run(&small_cfg(1)).unwrap()).pretty();
        let b = rows_to_json(&run(&small_cfg(8)).unwrap()).pretty();
        assert_eq!(a, b);
    }

    #[test]
    fn artifact_has_shard_kind_and_validates() {
        let doc = rows_to_json(&run(&small_cfg(2)).unwrap());
        assert_eq!(artifact::validate(&doc).unwrap(), ArtifactKind::Shard);
        assert_eq!(doc.get("kind").as_str(), Some("psl-shard"));
    }

    #[test]
    fn cell_seed_depends_on_every_coordinate() {
        let base = cell_seed(1, Scenario::S1, Model::ResNet101, 32, 4);
        assert_ne!(base, cell_seed(2, Scenario::S1, Model::ResNet101, 32, 4));
        assert_ne!(base, cell_seed(1, Scenario::S2, Model::ResNet101, 32, 4));
        assert_ne!(base, cell_seed(1, Scenario::S1, Model::Vgg19, 32, 4));
        assert_ne!(base, cell_seed(1, Scenario::S1, Model::ResNet101, 64, 4));
        assert_ne!(base, cell_seed(1, Scenario::S1, Model::ResNet101, 32, 8));
    }
}
