//! Concurrent per-shard solves over [`exec::pool::run_parallel`].
//!
//! Each cell becomes an independent sub-instance (client columns ×
//! helper rows of the parent), quantized at the parent's slot length and
//! solved with the flat §VII strategy rule — each shard picks its own
//! method from its own [`Signals`](crate::solver::strategy::Signals), so
//! a heterogeneous cell can run ADMM while its mega-homogeneous sibling
//! runs balanced-greedy.
//!
//! Results are **thread-count invariant** the same way `psl sweep` is:
//! jobs are pure functions of `(instance, cell, slot length, solver
//! config)` and [`run_parallel`](crate::exec::pool::run_parallel)
//! returns them in job order, so the worker count only changes
//! wall-clock time, never bytes. Nested parallelism (a solver using the
//! pool inside a shard job) is collapsed to sequential by the pool's
//! oversubscription guard.

use crate::exec::pool;
use crate::instance::InstanceMs;
use crate::solver::admm::AdmmCfg;
use crate::solver::schedule::{Assignment, Schedule};
use crate::solver::strategy::{self, Method};

use super::partition::{sub_instance, ShardCell, ShardPlan};

/// One solved shard: the cell, its schedule in **local** (cell-relative)
/// indexing, and the metrics the stitcher and the psl-shard artifact
/// need.
#[derive(Clone, Debug)]
pub struct ShardSolved {
    pub cell: ShardCell,
    /// Local indexing: client `jj` is original `cell.clients[jj]`,
    /// helper `ii` is original `cell.helpers[ii]`.
    pub schedule: Schedule,
    pub method: Method,
    /// Shard makespan in slots (slot origin 0, like every shard's).
    pub makespan: u32,
    /// Shard-local trivial lower bound, slots.
    pub lower_bound: u32,
    /// Per-client completions, local order — the stitcher's boundary-
    /// client selection reads these without re-materializing the
    /// sub-instance.
    pub completions: Vec<u32>,
}

/// Solve one cell. Pure; safe to call from any thread.
pub fn solve_one(
    ms: &InstanceMs,
    slot_ms: f64,
    admm_cfg: &AdmmCfg,
    cell: ShardCell,
) -> Option<ShardSolved> {
    let sub_ms = sub_instance(ms, &cell);
    solve_prepared(&sub_ms, slot_ms, admm_cfg, cell)
}

fn solve_prepared(
    sub_ms: &InstanceMs,
    slot_ms: f64,
    admm_cfg: &AdmmCfg,
    cell: ShardCell,
) -> Option<ShardSolved> {
    if cell.clients.is_empty() {
        return Some(ShardSolved {
            cell,
            schedule: Schedule { assignment: Assignment::new(vec![]), fwd: vec![], bwd: vec![] },
            method: Method::BalancedGreedy,
            makespan: 0,
            lower_bound: 0,
            completions: vec![],
        });
    }
    let mut sp = crate::obs::span("shard", "shard/cell-solve");
    sp.arg("clients", cell.clients.len() as u64);
    let sub = sub_ms.quantize(slot_ms);
    let s = strategy::signals(&sub);
    // One hierarchy level only: a cell that is still above the shard
    // frontier (a degenerate partition can produce one) solves flat
    // instead of recursing into another shard layer.
    let (schedule, method) = match strategy::pick_from_signals(&s) {
        Method::Sharded => strategy::solve_flat(&sub, admm_cfg, &s)?,
        _ => strategy::solve_with_signals(&sub, admm_cfg, &s)?,
    };
    let makespan = schedule.makespan(&sub);
    let lower_bound = sub.makespan_lower_bound();
    let completions = (0..sub.n_clients).map(|jj| schedule.completion(&sub, jj)).collect();
    Some(ShardSolved { cell, schedule, method, makespan, lower_bound, completions })
}

/// Solve every cell of `plan` across up to `threads` pool workers.
/// Returns `None` if any cell is unsolvable (a memory-wedged cell the
/// partitioner's best-effort capacity pass could not repair).
pub fn solve_shards(
    ms: &InstanceMs,
    slot_ms: f64,
    admm_cfg: &AdmmCfg,
    plan: &ShardPlan,
    threads: usize,
) -> Option<Vec<ShardSolved>> {
    // Sub-instances are carved sequentially (cheap: one pass over the
    // parent's edges in total) so jobs own their data and the parent is
    // never shared across threads.
    let jobs: Vec<Box<dyn FnOnce() -> Option<ShardSolved> + Send>> = plan
        .cells
        .iter()
        .map(|cell| {
            let cell = cell.clone();
            let sub_ms = sub_instance(ms, &cell);
            let admm_cfg = admm_cfg.clone();
            Box::new(move || solve_prepared(&sub_ms, slot_ms, &admm_cfg, cell)) as _
        })
        .collect();
    pool::run_parallel(threads, jobs).into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};
    use crate::shard::partition::{partition, ShardCfg};

    fn plan_and_ms(j: usize, i: usize, per_shard: usize) -> (InstanceMs, ShardPlan) {
        let ms = ScenarioCfg::new(Scenario::S6MegaHomogeneous, Model::ResNet101, j, i, 11).generate();
        let cfg = ShardCfg { shard_clients: per_shard, ..ShardCfg::default() };
        let plan = partition(&ms, &cfg);
        (ms, plan)
    }

    #[test]
    fn every_shard_solves_and_is_locally_feasible() {
        let (ms, plan) = plan_and_ms(160, 4, 40);
        assert_eq!(plan.n_cells(), 4);
        let shards = solve_shards(&ms, 180.0, &AdmmCfg::default(), &plan, 2).unwrap();
        assert_eq!(shards.len(), 4);
        for sh in &shards {
            let sub = sub_instance(&ms, &sh.cell).quantize(180.0);
            assert!(sh.schedule.is_feasible(&sub), "shard infeasible");
            assert_eq!(sh.makespan, sh.schedule.makespan(&sub));
            assert!(sh.makespan >= sh.lower_bound);
            assert_eq!(sh.completions.len(), sh.cell.clients.len());
        }
    }

    #[test]
    fn shard_results_are_thread_count_invariant() {
        let (ms, plan) = plan_and_ms(120, 4, 30);
        let a = solve_shards(&ms, 180.0, &AdmmCfg::default(), &plan, 1).unwrap();
        let b = solve_shards(&ms, 180.0, &AdmmCfg::default(), &plan, 7).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.cell, y.cell);
            assert_eq!(x.method, y.method);
            assert_eq!(x.makespan, y.makespan);
            assert_eq!(x.completions, y.completions);
            assert_eq!(x.schedule.assignment, y.schedule.assignment);
        }
    }

    #[test]
    fn empty_cell_yields_empty_schedule() {
        let ms = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 4, 2, 5).generate();
        let cell = ShardCell { helpers: vec![0], clients: vec![] };
        let sh = solve_one(&ms, 180.0, &AdmmCfg::default(), cell).unwrap();
        assert_eq!(sh.makespan, 0);
        assert!(sh.schedule.fwd.is_empty());
    }
}
