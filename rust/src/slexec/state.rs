//! Training state of the parallel-SL entities.
//!
//! * [`ClientState`] — one client: part-1 and part-3 parameters plus its
//!   local dataset shard (the paper: samples and labels never leave the
//!   client).
//! * [`HelperState`] — one helper: a *separate copy* of part-2 per
//!   assigned client (parallel SL allocates d_j memory per client and
//!   reuses it across fwd/bwd — the coupling that forces one helper per
//!   client, §III).

use crate::data::SynthDataset;
use crate::runtime::Tensor;
use anyhow::Result;
use std::collections::BTreeMap;

pub struct ClientState {
    pub id: usize,
    pub p1: Vec<Tensor>,
    pub p3: Vec<Tensor>,
    pub dataset: SynthDataset,
    /// In-flight batch (x, y, a1) between fwd and bwd phases.
    pub inflight: Option<(Tensor, Tensor, Tensor)>,
}

impl ClientState {
    pub fn new(id: usize, p1: Vec<Tensor>, p3: Vec<Tensor>, seed: u64) -> ClientState {
        ClientState { id, p1, p3, dataset: SynthDataset::new(seed, 0.35), inflight: None }
    }

    pub fn sgd(&mut self, g1: &[Tensor], g3: &[Tensor], lr: f32) -> Result<()> {
        for (p, g) in self.p1.iter_mut().zip(g1) {
            p.sgd_step(g, lr)?;
        }
        for (p, g) in self.p3.iter_mut().zip(g3) {
            p.sgd_step(g, lr)?;
        }
        Ok(())
    }
}

pub struct HelperState {
    pub id: usize,
    /// Per-client part-2 model copies (parallel SL).
    pub p2_of: BTreeMap<usize, Vec<Tensor>>,
    /// Measured task wall-times (ms): (client, is_bwd) → samples.
    pub task_ms: BTreeMap<(usize, bool), Vec<f64>>,
}

impl HelperState {
    pub fn new(id: usize) -> HelperState {
        HelperState { id, p2_of: BTreeMap::new(), task_ms: BTreeMap::new() }
    }

    /// Allocate the client's part-2 copy (the d_j GB in the model).
    pub fn admit(&mut self, client: usize, p2: Vec<Tensor>) {
        self.p2_of.insert(client, p2);
    }

    pub fn sgd(&mut self, client: usize, g2: &[Tensor], lr: f32) -> Result<()> {
        let p2 = self.p2_of.get_mut(&client).expect("client admitted");
        for (p, g) in p2.iter_mut().zip(g2) {
            p.sgd_step(g, lr)?;
        }
        Ok(())
    }

    pub fn record(&mut self, client: usize, is_bwd: bool, ms: f64) {
        self.task_ms.entry((client, is_bwd)).or_default().push(ms);
    }

    /// Mean measured (fwd, bwd) ms for a client, if observed.
    pub fn measured_ms(&self, client: usize) -> (Option<f64>, Option<f64>) {
        let mean = |v: Option<&Vec<f64>>| v.filter(|v| !v.is_empty()).map(|v| v.iter().sum::<f64>() / v.len() as f64);
        (mean(self.task_ms.get(&(client, false))), mean(self.task_ms.get(&(client, true))))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_updates_all_leaves() {
        let p = vec![Tensor::from_f32(&[2], vec![1.0, 1.0]).unwrap()];
        let mut c = ClientState::new(0, p.clone(), p, 1);
        let g = vec![Tensor::from_f32(&[2], vec![1.0, 2.0]).unwrap()];
        c.sgd(&g, &g, 0.1).unwrap();
        assert_eq!(c.p1[0].as_f32().unwrap(), &[0.9, 0.8]);
        assert_eq!(c.p3[0].as_f32().unwrap(), &[0.9, 0.8]);
    }

    #[test]
    fn helper_tracks_measurements() {
        let mut h = HelperState::new(0);
        h.admit(3, vec![Tensor::zeros(&[2])]);
        h.record(3, false, 10.0);
        h.record(3, false, 20.0);
        h.record(3, true, 30.0);
        let (f, b) = h.measured_ms(3);
        assert_eq!(f, Some(15.0));
        assert_eq!(b, Some(30.0));
        assert_eq!(h.measured_ms(9), (None, None));
    }
}
