//! The end-to-end split-learning driver: executes *real* batch updates
//! (PJRT part functions) in the order dictated by an optimized schedule,
//! with FedAvg aggregation between rounds.
//!
//! Execution model: one process emulates the whole fleet. Helper tasks run
//! at their *completion* slot (an HLO call is atomic — preemption segments
//! affect ordering, which is preserved); client-side steps run inline at
//! their dependency points. Wall-clock per helper task is measured and
//! recorded, giving profiled (p, p') values that can be fed back into the
//! optimizer — closing the paper's profiling loop (§III: delays are
//! "available through profiling").

use super::aggregator::fedavg;
use super::model::SplitModel;
use super::state::{ClientState, HelperState};
use crate::instance::Instance;
use crate::runtime::Tensor;
use crate::solver::schedule::Schedule;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct TrainCfg {
    /// Batch updates per local epoch (per round).
    pub batches_per_round: usize,
    /// Training rounds (FedAvg at each round boundary).
    pub rounds: usize,
    pub lr: f32,
    pub seed: u64,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg { batches_per_round: 4, rounds: 4, lr: 0.05, seed: 7 }
    }
}

#[derive(Clone, Debug)]
pub struct TrainReport {
    /// Mean client loss per batch step (the headline loss curve).
    pub loss_curve: Vec<f64>,
    /// Wall time of the whole run (seconds).
    pub wall_s: f64,
    /// Mean measured helper fwd/bwd task times (ms) per (helper, client).
    pub measured_ms: Vec<(usize, usize, f64, f64)>,
    /// Batch updates executed.
    pub steps: usize,
}

/// One complete training run driven by `schedule`.
pub struct Driver {
    pub model: SplitModel,
    pub clients: Vec<ClientState>,
    pub helpers: Vec<HelperState>,
    pub schedule: Schedule,
}

impl Driver {
    /// Build the fleet: every client starts from the artifact's initial
    /// parameters (identical across clients, as in FL round 0), every
    /// helper admits its assigned clients' part-2 copies.
    pub fn new(model: SplitModel, inst: &Instance, schedule: Schedule, seed: u64) -> Result<Driver> {
        let p1 = model.manifest.load_init_params("p1")?;
        let p2 = model.manifest.load_init_params("p2")?;
        let p3 = model.manifest.load_init_params("p3")?;
        let clients: Vec<ClientState> = (0..inst.n_clients)
            .map(|j| ClientState::new(j, p1.clone(), p3.clone(), seed ^ (j as u64) << 16))
            .collect();
        let mut helpers: Vec<HelperState> = (0..inst.n_helpers).map(HelperState::new).collect();
        for j in 0..inst.n_clients {
            helpers[schedule.assignment.helper_of[j]].admit(j, p2.clone());
        }
        Ok(Driver { model, clients, helpers, schedule })
    }

    /// Execute one batch update for every client, respecting the
    /// schedule's per-helper task order. Returns the mean loss.
    pub fn batch_update(&mut self, lr: f32) -> Result<f64> {
        let batch = self.model.manifest.batch;
        // Client-side fwd of part-1 (the r_ij phase).
        let mut a1_of: Vec<Option<Tensor>> = vec![None; self.clients.len()];
        for c in self.clients.iter_mut() {
            let (x, y) = c.dataset.batch(batch);
            let a1 = self.model.part1_fwd(&c.p1, &x)?;
            c.inflight = Some((x, y, a1.clone()));
            a1_of[c.id] = Some(a1);
        }

        // Helper tasks in global slot order (cross-helper order is
        // irrelevant — helpers are independent — but this mirrors the
        // timeline and keeps the run deterministic). Tasks are derived
        // from the shared [`crate::sim::segments`] projection — the same
        // per-helper streams the replay engines execute — so the PJRT
        // driver and the simulators agree on what the schedule says down
        // to preemption segments. An HLO call is atomic, so each task
        // runs once, at its *final* segment's completion slot.
        #[derive(Clone, Copy)]
        struct Task {
            helper: usize,
            client: usize,
            is_bwd: bool,
            completion_slot: u32,
        }
        let streams = crate::sim::segments::streams(self.helpers.len(), &self.schedule);
        let mut completion: BTreeMap<(usize, usize, bool), u32> = BTreeMap::new();
        for (i, stream) in streams.iter().enumerate() {
            for seg in stream {
                let end = seg.start + seg.len - 1;
                let e = completion.entry((i, seg.client, seg.is_bwd)).or_insert(end);
                *e = (*e).max(end);
            }
        }
        let mut tasks: Vec<Task> = completion
            .into_iter()
            .map(|((helper, client, is_bwd), completion_slot)| Task { helper, client, is_bwd, completion_slot })
            .collect();
        tasks.sort_by_key(|t| (t.completion_slot, t.is_bwd, t.client));

        let mut a2_of: Vec<Option<Tensor>> = vec![None; self.clients.len()];
        let mut g_a2_of: Vec<Option<Tensor>> = vec![None; self.clients.len()];
        let mut losses = vec![0.0f64; self.clients.len()];
        for t in tasks {
            let h = &mut self.helpers[t.helper];
            let p2 = h.p2_of.get(&t.client).context("client admitted")?.clone();
            if !t.is_bwd {
                let a1 = a1_of[t.client].as_ref().context("a1 ready")?;
                let start = Instant::now();
                let a2 = self.model.part2_fwd(&p2, a1)?;
                h.record(t.client, false, start.elapsed().as_secs_f64() * 1e3);
                // Client-side part-3 turnaround (the l + l' phases).
                let c = &mut self.clients[t.client];
                let (_, y, _) = c.inflight.as_ref().context("inflight")?;
                let (loss, g3, g_a2) = self.model.part3_bwd(&c.p3, &a2, y)?;
                losses[t.client] = loss as f64;
                let g3_refs = g3;
                c.p3
                    .iter_mut()
                    .zip(&g3_refs)
                    .try_for_each(|(p, g)| p.sgd_step(g, lr))?;
                g_a2_of[t.client] = Some(g_a2);
                a2_of[t.client] = Some(a2);
            } else {
                let a1 = a1_of[t.client].as_ref().context("a1 ready")?;
                let g_a2 = g_a2_of[t.client].as_ref().context("g_a2 ready (precedence)")?;
                let start = Instant::now();
                let (g2, g_a1) = self.model.part2_bwd(&p2, a1, g_a2)?;
                h.record(t.client, true, start.elapsed().as_secs_f64() * 1e3);
                h.sgd(t.client, &g2, lr)?;
                // Client finishes: part-1 bwd + SGD (the r'_ij phase).
                let c = &mut self.clients[t.client];
                let (x, _, _) = c.inflight.as_ref().context("inflight")?;
                let g1 = self.model.part1_bwd(&c.p1, x, &g_a1)?;
                c.p1.iter_mut().zip(&g1).try_for_each(|(p, g)| p.sgd_step(g, lr))?;
                c.inflight = None;
            }
        }
        Ok(losses.iter().sum::<f64>() / losses.len().max(1) as f64)
    }

    /// FedAvg round boundary: average p1/p3 across clients and p2 across
    /// all per-client helper copies; broadcast back to everyone.
    pub fn aggregate(&mut self) -> Result<()> {
        let p1_copies: Vec<&[Tensor]> = self.clients.iter().map(|c| c.p1.as_slice()).collect();
        let p3_copies: Vec<&[Tensor]> = self.clients.iter().map(|c| c.p3.as_slice()).collect();
        let p1_avg = fedavg(&p1_copies)?;
        let p3_avg = fedavg(&p3_copies)?;
        let p2_copies: Vec<&[Tensor]> = self
            .helpers
            .iter()
            .flat_map(|h| h.p2_of.values().map(|v| v.as_slice()))
            .collect();
        let p2_avg = fedavg(&p2_copies)?;
        for c in self.clients.iter_mut() {
            c.p1 = p1_avg.clone();
            c.p3 = p3_avg.clone();
        }
        for h in self.helpers.iter_mut() {
            for p2 in h.p2_of.values_mut() {
                *p2 = p2_avg.clone();
            }
        }
        Ok(())
    }

    /// Full training run.
    pub fn train(&mut self, cfg: &TrainCfg) -> Result<TrainReport> {
        let start = Instant::now();
        self.model.warmup()?;
        let mut loss_curve = Vec::new();
        for round in 0..cfg.rounds {
            for _ in 0..cfg.batches_per_round {
                loss_curve.push(self.batch_update(cfg.lr)?);
            }
            self.aggregate()?;
            crate::log_info!(
                "round {}/{}: loss {:.4}",
                round + 1,
                cfg.rounds,
                loss_curve.last().copied().unwrap_or(f64::NAN)
            );
        }
        let mut measured = Vec::new();
        for h in &self.helpers {
            for &j in h.p2_of.keys() {
                let (f, b) = h.measured_ms(j);
                if let (Some(f), Some(b)) = (f, b) {
                    measured.push((h.id, j, f, b));
                }
            }
        }
        Ok(TrainReport {
            steps: loss_curve.len(),
            loss_curve,
            wall_s: start.elapsed().as_secs_f64(),
            measured_ms: measured,
        })
    }
}
