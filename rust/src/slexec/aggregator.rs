//! FedAvg aggregation (§III "Epochs & Aggregation"): at the end of each
//! training round the model parts from every entity are averaged at the
//! aggregator (node 0) and broadcast back — part-1/part-3 across clients,
//! part-2 across the helpers' per-client copies.

use crate::runtime::Tensor;
use anyhow::Result;

/// Average a set of equally-shaped parameter lists; panics on empty input.
pub fn fedavg(copies: &[&[Tensor]]) -> Result<Vec<Tensor>> {
    anyhow::ensure!(!copies.is_empty(), "fedavg of nothing");
    let n = copies.len() as f32;
    let mut acc: Vec<Tensor> = copies[0].iter().map(|t| {
        let mut z = Tensor::zeros(&t.shape);
        z.axpy(1.0 / n, t).unwrap();
        z
    }).collect();
    for copy in &copies[1..] {
        anyhow::ensure!(copy.len() == acc.len(), "leaf count mismatch in fedavg");
        for (a, t) in acc.iter_mut().zip(copy.iter()) {
            a.axpy(1.0 / n, t)?;
        }
    }
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn averages_correctly() {
        let a = vec![Tensor::from_f32(&[2], vec![1.0, 3.0]).unwrap()];
        let b = vec![Tensor::from_f32(&[2], vec![3.0, 5.0]).unwrap()];
        let avg = fedavg(&[&a, &b]).unwrap();
        assert_eq!(avg[0].as_f32().unwrap(), &[2.0, 4.0]);
    }

    #[test]
    fn single_copy_identity() {
        let a = vec![Tensor::from_f32(&[3], vec![1.0, 2.0, 3.0]).unwrap()];
        let avg = fedavg(&[&a]).unwrap();
        for (x, y) in avg[0].as_f32().unwrap().iter().zip(a[0].as_f32().unwrap()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a = vec![Tensor::zeros(&[2]), Tensor::zeros(&[2])];
        let b = vec![Tensor::zeros(&[2])];
        assert!(fedavg(&[&a, &b]).is_err());
    }
}
