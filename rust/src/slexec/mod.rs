//! The executable parallel-SL runtime: real split training driven by the
//! optimized schedules, entirely from rust (PJRT artifacts, no python).
//!
//! * [`model`] — typed wrappers over the six exported part functions.
//! * [`state`] — client/helper training state (per-client part-2 copies).
//! * [`aggregator`] — FedAvg round aggregation.
//! * [`driver`] — schedule-ordered batch updates + rounds + measurements.

pub mod aggregator;
pub mod driver;
pub mod model;
pub mod state;

pub use driver::{Driver, TrainCfg, TrainReport};
pub use model::SplitModel;
