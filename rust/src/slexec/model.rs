//! Typed wrappers around the six exported part functions: the rust-side
//! embodiment of the SL batch-update contract (see python/compile/model.py).
//!
//! Flattening convention: the HLO signature is the jax pytree flatten
//! order — parameter leaves first (layer order, dict keys sorted), then
//! activations/labels. The manifest records every input/output shape; we
//! slice outputs by the part's leaf count.

use crate::runtime::{Engine, Manifest, Tensor};
use anyhow::{Context, Result};
use std::sync::Arc;

/// A split model bound to its artifacts.
pub struct SplitModel {
    pub manifest: Manifest,
    pub engine: Arc<Engine>,
}

impl SplitModel {
    pub fn load(engine: Arc<Engine>, artifacts_dir: &std::path::Path, arch: &str) -> Result<SplitModel> {
        let manifest = Manifest::load(artifacts_dir, arch)?;
        Ok(SplitModel { manifest, engine })
    }

    /// Eagerly compile all six functions (done once at startup so the
    /// training hot path never compiles).
    pub fn warmup(&self) -> Result<()> {
        for f in self.manifest.functions.values() {
            self.engine.load(&f.hlo_path)?;
        }
        Ok(())
    }

    fn call(&self, name: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let f = self.manifest.function(name)?;
        anyhow::ensure!(
            inputs.len() == f.inputs.len(),
            "{name}: {} inputs given, manifest wants {}",
            inputs.len(),
            f.inputs.len()
        );
        for (k, (t, spec)) in inputs.iter().zip(&f.inputs).enumerate() {
            anyhow::ensure!(
                t.shape == spec.shape,
                "{name}: input {k} shape {:?} != manifest {:?}",
                t.shape,
                spec.shape
            );
        }
        let out = self.engine.execute(&f.hlo_path, &inputs)?;
        anyhow::ensure!(
            out.len() == f.outputs.len(),
            "{name}: {} outputs returned, manifest wants {}",
            out.len(),
            f.outputs.len()
        );
        Ok(out)
    }

    fn leaf_count(&self, part: &str) -> usize {
        self.manifest.params.get(part).map(|p| p.leaves.len()).unwrap_or(0)
    }

    /// a1 = part1_fwd(p1, x)
    pub fn part1_fwd(&self, p1: &[Tensor], x: &Tensor) -> Result<Tensor> {
        let mut inputs: Vec<Tensor> = p1.to_vec();
        inputs.push(x.clone());
        let mut out = self.call("part1_fwd", inputs)?;
        Ok(out.remove(0))
    }

    /// a2 = part2_fwd(p2, a1) — the helper's fwd-prop task (time p_ij).
    pub fn part2_fwd(&self, p2: &[Tensor], a1: &Tensor) -> Result<Tensor> {
        let mut inputs: Vec<Tensor> = p2.to_vec();
        inputs.push(a1.clone());
        let mut out = self.call("part2_fwd", inputs)?;
        Ok(out.remove(0))
    }

    /// loss = part3_loss(p3, a2, y)
    pub fn part3_loss(&self, p3: &[Tensor], a2: &Tensor, y: &Tensor) -> Result<f32> {
        let mut inputs: Vec<Tensor> = p3.to_vec();
        inputs.push(a2.clone());
        inputs.push(y.clone());
        let out = self.call("part3_loss", inputs)?;
        out[0].mean().context("loss scalar")
    }

    /// (loss, g3, g_a2) = part3_bwd(p3, a2, y)
    pub fn part3_bwd(&self, p3: &[Tensor], a2: &Tensor, y: &Tensor) -> Result<(f32, Vec<Tensor>, Tensor)> {
        let mut inputs: Vec<Tensor> = p3.to_vec();
        inputs.push(a2.clone());
        inputs.push(y.clone());
        let mut out = self.call("part3_bwd", inputs)?;
        let n3 = self.leaf_count("p3");
        anyhow::ensure!(out.len() == 1 + n3 + 1, "part3_bwd output arity");
        let loss = out[0].mean()?;
        let g_a2 = out.remove(out.len() - 1);
        let g3 = out.split_off(1);
        Ok((loss, g3, g_a2))
    }

    /// (g2, g_a1) = part2_bwd(p2, a1, g_a2) — the helper's bwd-prop task
    /// (time p'_ij).
    pub fn part2_bwd(&self, p2: &[Tensor], a1: &Tensor, g_a2: &Tensor) -> Result<(Vec<Tensor>, Tensor)> {
        let mut inputs: Vec<Tensor> = p2.to_vec();
        inputs.push(a1.clone());
        inputs.push(g_a2.clone());
        let mut out = self.call("part2_bwd", inputs)?;
        let n2 = self.leaf_count("p2");
        anyhow::ensure!(out.len() == n2 + 1, "part2_bwd output arity");
        let g_a1 = out.remove(out.len() - 1);
        Ok((out, g_a1))
    }

    /// g1 = part1_bwd(p1, x, g_a1)
    pub fn part1_bwd(&self, p1: &[Tensor], x: &Tensor, g_a1: &Tensor) -> Result<Vec<Tensor>> {
        let mut inputs: Vec<Tensor> = p1.to_vec();
        inputs.push(x.clone());
        inputs.push(g_a1.clone());
        self.call("part1_bwd", inputs)
    }
}

// Integration tests that exercise these against real artifacts live in
// rust/tests/runtime_artifacts.rs (gated on `make artifacts` having run).
