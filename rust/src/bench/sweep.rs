//! Multi-threaded scenario × solver sweep runner (`psl sweep`).
//!
//! Runs the full grid
//! `scenarios × models × (J, I) sizes × seeds × methods`
//! across a std::thread fan-out ([`crate::exec::pool`]) and merges the
//! per-cell results back into deterministic grid order. Every cell is
//! self-contained: its instance is regenerated from the `(scenario,
//! model, J, I, seed)` tuple and any solver randomness is seeded from a
//! per-cell hash of the cell coordinates — so the output is **byte
//! identical regardless of thread count or scheduling order**.
//!
//! Rows deliberately exclude wall-clock timings (those go to stdout, not
//! the JSON) to keep the artifact reproducible; diff two sweep JSONs to
//! catch solver regressions.

use crate::exec::pool;
use crate::instance::profiles::Model;
use crate::instance::scenario::{Scenario, ScenarioCfg};
use crate::solver::bwd;
use crate::solver::schedule::{fcfs_schedule, Schedule};
use crate::solver::{admm, baseline, greedy, strategy};
use crate::transport::TransportCfg;
use crate::util::json::Json;
use crate::util::rng::{fnv64 as fnv, Rng};

/// Sweep grid configuration.
#[derive(Clone, Debug)]
pub struct SweepCfg {
    pub scenarios: Vec<Scenario>,
    pub models: Vec<Model>,
    /// (n_clients, n_helpers) cells.
    pub sizes: Vec<(usize, usize)>,
    pub seeds: Vec<u64>,
    /// Solver names: "admm" | "greedy" | "baseline" | "strategy".
    pub methods: Vec<String>,
    /// None → each model's default |S_t|.
    pub slot_ms: Option<f64>,
    /// Link model every cell solves and is evaluated under. The default
    /// ([`TransportCfg::dedicated`](crate::transport::TransportCfg::dedicated))
    /// keeps the historical byte-identical rows.
    pub transport: crate::transport::TransportCfg,
    pub threads: usize,
}

impl Default for SweepCfg {
    fn default() -> Self {
        SweepCfg {
            scenarios: vec![
                Scenario::S1,
                Scenario::S2,
                Scenario::S3Clustered,
                Scenario::S4StragglerTail,
            ],
            models: vec![Model::ResNet101],
            sizes: vec![(10, 2), (20, 5)],
            seeds: vec![42],
            methods: vec!["admm".to_string(), "greedy".to_string()],
            slot_ms: None,
            transport: crate::transport::TransportCfg::dedicated(),
            threads: pool::default_workers(),
        }
    }
}

/// One grid cell (scenario, model, size, seed, method).
#[derive(Clone, Debug, PartialEq)]
pub struct Cell {
    pub scenario: Scenario,
    pub model: Model,
    pub n_clients: usize,
    pub n_helpers: usize,
    pub seed: u64,
    pub method: String,
}

/// One deterministic result row.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepRow {
    pub scenario: &'static str,
    pub model: &'static str,
    pub n_clients: usize,
    pub n_helpers: usize,
    pub seed: u64,
    pub slot_ms: f64,
    pub method: String,
    /// The concrete method the strategy routed to (when method == "strategy").
    pub picked: Option<&'static str>,
    pub horizon: u32,
    pub lower_bound: u32,
    /// None when the solver found no feasible schedule.
    pub makespan_slots: Option<u32>,
    pub makespan_ms: Option<f64>,
    pub preemptions: Option<u32>,
    pub heterogeneity: f64,
    pub placement_flexibility: f64,
    pub tail_ratio: f64,
    /// Shared-uplink capacity the cell ran under (0.0 = dedicated links;
    /// serialized only when > 0 so default sweeps keep their v6 bytes).
    pub uplink_capacity: f64,
}

/// Enumerate the grid in canonical (deterministic) order:
/// scenario → model → size → seed → method.
pub fn cells(cfg: &SweepCfg) -> Vec<Cell> {
    let mut out = Vec::new();
    for &scenario in &cfg.scenarios {
        for &model in &cfg.models {
            for &(j, i) in &cfg.sizes {
                for &seed in &cfg.seeds {
                    for method in &cfg.methods {
                        out.push(Cell {
                            scenario,
                            model,
                            n_clients: j,
                            n_helpers: i,
                            seed,
                            method: method.clone(),
                        });
                    }
                }
            }
        }
    }
    out
}

/// The cell's private solver-randomness seed: a pure function of the cell
/// coordinates, never of execution order. (Instance generation already
/// hashes scenario/model itself; this stream only feeds randomized
/// solvers like the FCFS baseline.)
pub fn cell_seed(c: &Cell) -> u64 {
    c.seed
        ^ fnv(c.scenario.name())
        ^ fnv(c.model.name()).rotate_left(13)
        ^ fnv(&c.method).rotate_left(29)
        ^ (c.n_clients as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (c.n_helpers as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F)
}

/// Solve one cell under a transport model. Panics only on unknown method
/// names (validated by the CLI before fan-out). The dedicated mode keeps
/// every solver path byte-identical to the historical (transport-free)
/// runner.
pub fn run_cell(c: &Cell, slot_override: Option<f64>, transport: &TransportCfg) -> SweepRow {
    let ms = ScenarioCfg::new(c.scenario, c.model, c.n_clients, c.n_helpers, c.seed).generate();
    let slot_ms = slot_override.unwrap_or(c.model.profile().default_slot_ms);
    let inst = ms.quantize(slot_ms);
    let sig = strategy::signals_under(&inst, transport);

    // Re-schedule a shaped assignment against its actual per-helper pool
    // loads (FCFS forward + optimal ℙ_b backward) — the same construction
    // as `strategy::solve_under`, so shared-mode rows are feasible under
    // `Schedule::violations_under` by construction. Identity when
    // dedicated.
    let under_transport = |s: Schedule| -> Schedule {
        if transport.is_dedicated() {
            return s;
        }
        let eff = transport.inflate_for_assignment(&inst, &s.assignment);
        let f = fcfs_schedule(&eff, s.assignment);
        bwd::complete_with_optimal_bwd(&eff, f.assignment, f.fwd)
    };

    let mut picked: Option<&'static str> = None;
    let schedule = match c.method.as_str() {
        "admm" => {
            if transport.is_dedicated() {
                admm::solve(&inst, &admm::AdmmCfg::default()).map(|r| r.schedule)
            } else {
                // Shape the assignment on the uniform-load contention
                // estimate, then re-schedule under the actual loads.
                let est = transport.inflate_uniform(&inst);
                admm::solve(&est, &admm::AdmmCfg::default()).map(|r| under_transport(r.schedule))
            }
        }
        "greedy" => greedy::solve_under(&inst, transport),
        "baseline" => {
            baseline::solve(&inst, &mut Rng::seeded(cell_seed(c))).map(|s| under_transport(s))
        }
        "strategy" => {
            if transport.is_dedicated() {
                strategy::solve_with_signals(&inst, &admm::AdmmCfg::default(), &sig).map(|(s, m)| {
                    picked = Some(m.name());
                    s
                })
            } else {
                strategy::solve_under(&inst, transport, &admm::AdmmCfg::default()).map(|(s, m)| {
                    picked = Some(m.name());
                    s
                })
            }
        }
        other => panic!("unknown sweep method {other:?} (admm|greedy|baseline|strategy)"),
    };

    // Shared-mode makespans are read off the transport-inflated instance
    // the schedule was actually built against.
    let makespan_slots = schedule.as_ref().map(|s| {
        if transport.is_dedicated() {
            s.makespan(&inst)
        } else {
            s.makespan(&transport.inflate_for_assignment(&inst, &s.assignment))
        }
    });
    SweepRow {
        scenario: c.scenario.name(),
        model: c.model.name(),
        n_clients: c.n_clients,
        n_helpers: c.n_helpers,
        seed: c.seed,
        slot_ms,
        method: c.method.clone(),
        picked,
        horizon: inst.horizon(),
        lower_bound: inst.makespan_lower_bound(),
        makespan_slots,
        makespan_ms: makespan_slots.map(|m| m as f64 * slot_ms),
        preemptions: schedule.as_ref().map(|s| s.preemptions()),
        heterogeneity: sig.heterogeneity,
        placement_flexibility: sig.placement_flexibility,
        tail_ratio: sig.tail_ratio,
        uplink_capacity: if transport.is_dedicated() { 0.0 } else { transport.capacity },
    }
}

/// Run the whole grid across `cfg.threads` workers. The worker pool
/// returns results in job order, so the merged output is the canonical
/// grid order no matter how cells were scheduled.
pub fn run(cfg: &SweepCfg) -> Vec<SweepRow> {
    let grid = cells(cfg);
    let slot = cfg.slot_ms;
    let jobs: Vec<Box<dyn FnOnce() -> SweepRow + Send>> = grid
        .into_iter()
        .map(|c| {
            let transport = cfg.transport.clone();
            Box::new(move || run_cell(&c, slot, &transport)) as Box<dyn FnOnce() -> SweepRow + Send>
        })
        .collect();
    pool::run_parallel(cfg.threads, jobs)
}

fn opt_u32(v: Option<u32>) -> Json {
    v.map(|x| Json::Num(x as f64)).unwrap_or(Json::Null)
}

/// Serialize rows to the sweep JSON document (deterministic: BTreeMap
/// keys, no timestamps, no wall-clock fields) under the registry
/// envelope ([`super::artifact::envelope`]).
pub fn rows_to_json(rows: &[SweepRow]) -> Json {
    super::artifact::envelope(super::artifact::ArtifactKind::Sweep, vec![
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        let mut fields = vec![
                            ("scenario", Json::Str(r.scenario.to_string())),
                            ("model", Json::Str(r.model.to_string())),
                            ("n_clients", Json::Num(r.n_clients as f64)),
                            ("n_helpers", Json::Num(r.n_helpers as f64)),
                            // String, not Num: Json numbers are f64 and would
                            // silently round seeds above 2^53 — the one field
                            // that must replay exactly.
                            ("seed", Json::Str(r.seed.to_string())),
                            ("slot_ms", Json::Num(r.slot_ms)),
                            ("method", Json::Str(r.method.clone())),
                            (
                                "picked",
                                r.picked.map(|p| Json::Str(p.to_string())).unwrap_or(Json::Null),
                            ),
                            ("horizon", Json::Num(r.horizon as f64)),
                            ("lower_bound", Json::Num(r.lower_bound as f64)),
                            ("makespan_slots", opt_u32(r.makespan_slots)),
                            (
                                "makespan_ms",
                                r.makespan_ms.map(Json::Num).unwrap_or(Json::Null),
                            ),
                            ("preemptions", opt_u32(r.preemptions)),
                            ("heterogeneity", Json::Num(r.heterogeneity)),
                            ("placement_flexibility", Json::Num(r.placement_flexibility)),
                            ("tail_ratio", Json::Num(r.tail_ratio)),
                        ];
                        // Emit only under the shared link model so
                        // dedicated sweeps keep their pre-v7 bytes.
                        if r.uplink_capacity > 0.0 {
                            fields.push(("uplink_capacity", Json::Num(r.uplink_capacity)));
                        }
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Persist under `target/psl-bench/<name>.json`. Returns the path.
pub fn save(rows: &[SweepRow], name: &str) -> std::io::Result<std::path::PathBuf> {
    super::save_artifact(name, &rows_to_json(rows))
}

// ---- sweep artifact diff (`psl sweep --diff`) ---------------------------

/// One per-cell makespan regression found by [`diff_documents`].
#[derive(Clone, Debug, PartialEq)]
pub struct Regression {
    /// Human-readable cell key (scenario/model/JxI/seed/slot/method).
    pub cell: String,
    /// `makespan_ms` in the old artifact (None = infeasible there).
    pub old_ms: Option<f64>,
    /// `makespan_ms` in the new artifact (None = infeasible now).
    pub new_ms: Option<f64>,
}

/// Cell-by-cell comparison of two sweep artifacts.
#[derive(Clone, Debug, Default)]
pub struct DiffReport {
    /// Cells present in both artifacts.
    pub compared: usize,
    /// Cells whose new makespan exceeds old × (1 + tol), or that lost
    /// feasibility.
    pub regressions: Vec<Regression>,
    /// Cells whose new makespan improved beyond the tolerance.
    pub improved: usize,
    /// Cells only in the old / only in the new artifact (grid drift —
    /// reported, not failed).
    pub only_old: usize,
    pub only_new: usize,
}

/// Index a sweep document's rows by their cell coordinates.
fn index_rows(doc: &Json) -> anyhow::Result<std::collections::BTreeMap<String, Option<f64>>> {
    // Other target/psl-bench artifacts (fleet, fleet-grid) also carry a
    // rows[]/detail array; diffing one here would silently compare
    // nothing, so pin the kind through the registry.
    super::artifact::expect_kind(doc, super::artifact::ArtifactKind::Sweep)?;
    let rows = doc.get("rows").as_arr().ok_or_else(|| anyhow::anyhow!("not a sweep artifact: missing rows[]"))?;
    let mut out = std::collections::BTreeMap::new();
    for r in rows {
        let mut key = format!(
            "{}/{} {}x{} seed={} slot={} {}",
            r.get("scenario").as_str().unwrap_or("?"),
            r.get("model").as_str().unwrap_or("?"),
            r.get("n_clients").as_f64().unwrap_or(-1.0),
            r.get("n_helpers").as_f64().unwrap_or(-1.0),
            r.get("seed").as_str().unwrap_or("?"),
            r.get("slot_ms").as_f64().unwrap_or(-1.0),
            r.get("method").as_str().unwrap_or("?"),
        );
        // The link model is part of the cell's identity: a shared-uplink
        // makespan must never be diffed against a dedicated one. The
        // suffix appears only when the row carries the (v7, shared-only)
        // key, so old-vs-old diffs keep their historical keys.
        if let Some(cap) = r.get("uplink_capacity").as_f64() {
            key.push_str(&format!(" cap={cap}"));
        }
        out.insert(key, r.get("makespan_ms").as_f64());
    }
    Ok(out)
}

/// Compare two sweep artifacts cell-by-cell: a cell regresses when its
/// new `makespan_ms` exceeds the old by more than `tol` (relative), or
/// when a previously feasible cell became infeasible. Cells present in
/// only one artifact are counted but do not fail the diff.
pub fn diff_documents(old: &Json, new: &Json, tol: f64) -> anyhow::Result<DiffReport> {
    let old_rows = index_rows(old)?;
    let new_rows = index_rows(new)?;
    let mut report = DiffReport::default();
    for (key, old_ms) in &old_rows {
        match new_rows.get(key) {
            None => report.only_old += 1,
            Some(new_ms) => {
                report.compared += 1;
                match (old_ms, new_ms) {
                    (Some(o), Some(n)) => {
                        if *n > o * (1.0 + tol) {
                            report.regressions.push(Regression {
                                cell: key.clone(),
                                old_ms: Some(*o),
                                new_ms: Some(*n),
                            });
                        } else if *n < o * (1.0 - tol) {
                            report.improved += 1;
                        }
                    }
                    (Some(o), None) => report.regressions.push(Regression {
                        cell: key.clone(),
                        old_ms: Some(*o),
                        new_ms: None,
                    }),
                    // Newly feasible counts as an improvement.
                    (None, Some(_)) => report.improved += 1,
                    (None, None) => {}
                }
            }
        }
    }
    report.only_new = new_rows.keys().filter(|k| !old_rows.contains_key(*k)).count();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(threads: usize) -> SweepCfg {
        SweepCfg {
            scenarios: vec![Scenario::S1, Scenario::S6MegaHomogeneous],
            models: vec![Model::Vgg19],
            sizes: vec![(4, 2)],
            seeds: vec![11],
            methods: vec!["greedy".to_string(), "baseline".to_string()],
            slot_ms: Some(550.0),
            transport: TransportCfg::dedicated(),
            threads,
        }
    }

    #[test]
    fn grid_enumeration_order() {
        let cfg = tiny_cfg(1);
        let cs = cells(&cfg);
        assert_eq!(cs.len(), 4);
        assert_eq!(cs[0].scenario, Scenario::S1);
        assert_eq!(cs[0].method, "greedy");
        assert_eq!(cs[1].method, "baseline");
        assert_eq!(cs[2].scenario, Scenario::S6MegaHomogeneous);
    }

    #[test]
    fn cell_seed_depends_on_every_coordinate() {
        let cs = cells(&tiny_cfg(1));
        let seeds: Vec<u64> = cs.iter().map(cell_seed).collect();
        for a in 0..seeds.len() {
            for b in (a + 1)..seeds.len() {
                assert_ne!(seeds[a], seeds[b], "cells {a} and {b} share a seed");
            }
        }
        let mut moved = cs[0].clone();
        moved.n_clients += 1;
        assert_ne!(cell_seed(&cs[0]), cell_seed(&moved));
    }

    #[test]
    fn single_thread_matches_multi_thread() {
        let a = run(&tiny_cfg(1));
        let b = run(&tiny_cfg(4));
        assert_eq!(a, b);
        assert_eq!(rows_to_json(&a).pretty(), rows_to_json(&b).pretty());
    }

    #[test]
    fn diff_self_is_clean() {
        let doc = rows_to_json(&run(&tiny_cfg(1)));
        let d = diff_documents(&doc, &doc, 0.02).unwrap();
        assert_eq!(d.compared, 4);
        assert!(d.regressions.is_empty());
        assert_eq!(d.improved, 0);
        assert_eq!(d.only_old + d.only_new, 0);
    }

    #[test]
    fn diff_flags_regressions_and_respects_tolerance() {
        let rows = run(&tiny_cfg(1));
        let old = rows_to_json(&rows);
        let mut worse = rows.clone();
        // Degrade one cell by 10%.
        let m = worse[0].makespan_ms.unwrap();
        worse[0].makespan_ms = Some(m * 1.10);
        let new = rows_to_json(&worse);
        let d = diff_documents(&old, &new, 0.02).unwrap();
        assert_eq!(d.regressions.len(), 1, "{:?}", d.regressions);
        assert!(d.regressions[0].cell.contains("scenario1"));
        // A 20% tolerance swallows the same delta.
        let loose = diff_documents(&old, &new, 0.2).unwrap();
        assert!(loose.regressions.is_empty());
        // The reverse direction is an improvement, not a regression.
        let rev = diff_documents(&new, &old, 0.02).unwrap();
        assert!(rev.regressions.is_empty());
        assert_eq!(rev.improved, 1);
    }

    #[test]
    fn diff_counts_lost_feasibility_and_grid_drift() {
        let rows = run(&tiny_cfg(1));
        let old = rows_to_json(&rows);
        let mut changed = rows.clone();
        changed[1].makespan_ms = None;
        changed[1].makespan_slots = None;
        changed.pop();
        let new = rows_to_json(&changed);
        let d = diff_documents(&old, &new, 0.02).unwrap();
        assert_eq!(d.regressions.len(), 1, "lost feasibility is a regression");
        assert_eq!(d.regressions[0].new_ms, None);
        assert_eq!(d.only_old, 1, "dropped cell is reported as grid drift");
    }

    #[test]
    fn diff_rejects_non_sweep_documents() {
        let doc = rows_to_json(&run(&tiny_cfg(1)));
        assert!(diff_documents(&Json::Num(3.0), &doc, 0.02).is_err());
        // A different psl-bench artifact kind with a rows[] array must be
        // rejected, not silently compared as zero cells.
        let fleet_grid = Json::obj(vec![
            ("kind", Json::Str("psl-fleet-grid".to_string())),
            ("rows", Json::Arr(vec![])),
        ]);
        let err = diff_documents(&fleet_grid, &doc, 0.02).unwrap_err();
        assert!(err.to_string().contains("psl-fleet-grid"), "{err}");
    }

    #[test]
    fn strategy_rows_record_pick() {
        let cfg = SweepCfg {
            scenarios: vec![Scenario::S1],
            models: vec![Model::Vgg19],
            sizes: vec![(4, 2)],
            seeds: vec![3],
            methods: vec!["strategy".to_string()],
            slot_ms: Some(550.0),
            transport: TransportCfg::dedicated(),
            threads: 1,
        };
        let rows = run(&cfg);
        assert_eq!(rows.len(), 1);
        assert!(rows[0].picked.is_some());
        assert!(rows[0].makespan_slots.is_some());
    }

    #[test]
    fn shared_transport_rows_are_feasible_deterministic_and_tagged() {
        let mut cfg = tiny_cfg(1);
        cfg.transport = TransportCfg::shared(2.0);
        let a = run(&cfg);
        let b = run(&SweepCfg { threads: 4, ..cfg.clone() });
        assert_eq!(a, b, "shared-mode sweep must be thread-invariant");
        for r in &a {
            assert_eq!(r.uplink_capacity, 2.0);
            assert!(r.makespan_slots.is_some(), "{}/{} infeasible under shared uplink", r.scenario, r.method);
            // Contention only inflates transfer times; the dedicated
            // lower bound still holds.
            assert!(r.makespan_slots.unwrap() >= r.lower_bound);
        }
        let shared_doc = rows_to_json(&a);
        assert!(shared_doc.pretty().contains("\"uplink_capacity\""));
        // Dedicated rows keep their historical shape: no transport key.
        let plain_doc = rows_to_json(&run(&tiny_cfg(1)));
        assert!(!plain_doc.pretty().contains("uplink_capacity"));
        // The link model is part of the cell identity: diffing across
        // modes compares nothing instead of silently mixing them.
        let d = diff_documents(&plain_doc, &shared_doc, 0.02).unwrap();
        assert_eq!(d.compared, 0);
        assert_eq!(d.only_old, 4);
        assert_eq!(d.only_new, 4);
    }

    #[test]
    fn strategy_and_admm_route_under_shared_transport() {
        let cfg = SweepCfg {
            scenarios: vec![Scenario::S1],
            models: vec![Model::Vgg19],
            sizes: vec![(4, 2)],
            seeds: vec![3],
            methods: vec!["strategy".to_string(), "admm".to_string()],
            slot_ms: Some(550.0),
            transport: TransportCfg::shared(1.5),
            threads: 1,
        };
        let rows = run(&cfg);
        assert_eq!(rows.len(), 2);
        assert!(rows[0].picked.is_some(), "strategy must record its routed method under contention");
        for r in &rows {
            assert!(r.makespan_slots.is_some());
            assert!(r.makespan_slots.unwrap() >= r.lower_bound);
        }
    }
}
