//! Multi-threaded fleet-orchestration grid (`psl fleet --grid`): run
//! `scenarios × churn-rates × policies × seeds` fleet simulations across
//! the worker pool and merge per-cell summaries back into canonical grid
//! order — the dataset that answers *when does incremental repair beat
//! full re-solving*.
//!
//! Like [`super::sweep`], every cell is self-contained (its world, event
//! stream and round loop derive from the cell coordinates alone), so the
//! output JSON is byte-identical regardless of thread count.

use crate::exec::pool;
use crate::fleet::events::{ChurnCfg, HelperChurnCfg};
use crate::fleet::orchestrator::{self, FleetCfg, Policy};
use crate::instance::profiles::Model;
use crate::instance::scenario::{Scenario, ScenarioCfg};
use crate::util::json::Json;

/// Fleet grid configuration.
#[derive(Clone, Debug)]
pub struct FleetGridCfg {
    pub scenarios: Vec<Scenario>,
    pub model: Model,
    /// (base clients, helpers).
    pub size: (usize, usize),
    /// Per-round departure probability; arrivals balance at `rate × J`
    /// so the expected roster stays stationary.
    pub churn_rates: Vec<f64>,
    /// Per-round helper outage probabilities (the helper-churn axis).
    /// 0.0 = the scenario's own default (static pool for most families,
    /// bursts for `s7-helper-bursts`); > 0.0 overrides with a transient
    /// outage model at that rate.
    pub helper_down_rates: Vec<f64>,
    /// Shared-uplink pool capacities (the transport axis). 0.0 = the
    /// dedicated transport (today's fixed per-edge delays); > 0.0 runs
    /// the cell under a shared uplink pool of that capacity.
    pub uplink_capacities: Vec<f64>,
    pub policies: Vec<Policy>,
    pub seeds: Vec<u64>,
    pub rounds: usize,
    /// None → the model's default |S_t|.
    pub slot_ms: Option<f64>,
    /// Frontier table consulted by `auto` cells (None → the builtin) —
    /// lets a measured table be evaluated in the very grid that will
    /// re-measure it.
    pub policy_table: Option<crate::fleet::policy::PolicyTable>,
    pub threads: usize,
}

impl Default for FleetGridCfg {
    fn default() -> Self {
        FleetGridCfg {
            scenarios: vec![Scenario::S1, Scenario::S4StragglerTail],
            model: Model::ResNet101,
            size: (10, 2),
            churn_rates: vec![0.05, 0.15, 0.3],
            helper_down_rates: vec![0.0],
            uplink_capacities: vec![0.0],
            policies: vec![Policy::Incremental, Policy::FullEveryRound],
            seeds: vec![42],
            rounds: 8,
            slot_ms: None,
            policy_table: None,
            threads: pool::default_workers(),
        }
    }
}

/// One grid cell.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetCell {
    pub scenario: Scenario,
    pub churn_rate: f64,
    /// The grid axis value (0.0 = scenario default; the row records the
    /// *effective* rate the cell actually ran).
    pub helper_down_rate: f64,
    /// The transport axis value (0.0 = dedicated).
    pub uplink_capacity: f64,
    pub policy: Policy,
    pub seed: u64,
}

/// One deterministic summary row.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetGridRow {
    pub scenario: &'static str,
    pub model: &'static str,
    pub n_clients: usize,
    pub n_helpers: usize,
    pub churn_rate: f64,
    /// Effective per-round helper outage probability the cell ran (the
    /// axis value, or the scenario's default when the axis is 0.0).
    pub helper_down_rate: f64,
    /// Shared-uplink pool capacity the cell ran (0.0 = dedicated).
    pub uplink_capacity: f64,
    pub policy: &'static str,
    pub seed: u64,
    pub rounds: usize,
    pub full_rounds: usize,
    pub repair_rounds: usize,
    pub empty_rounds: usize,
    pub mean_makespan_ms: f64,
    pub mean_period_ms: f64,
    /// Mean *observed* membership-churn fraction (rounds after the
    /// first) — the unit the analyze frontier is measured in, ≈ 2× this
    /// cell's stationary `churn_rate` axis value.
    pub mean_churn_frac: f64,
    pub total_work_units: u64,
}

/// Enumerate the grid in canonical order: scenario → churn rate →
/// helper outage rate → uplink capacity → policy → seed.
pub fn cells(cfg: &FleetGridCfg) -> Vec<FleetCell> {
    let mut out = Vec::new();
    for &scenario in &cfg.scenarios {
        for &churn_rate in &cfg.churn_rates {
            for &helper_down_rate in &cfg.helper_down_rates {
                for &uplink_capacity in &cfg.uplink_capacities {
                    for &policy in &cfg.policies {
                        for &seed in &cfg.seeds {
                            out.push(FleetCell {
                                scenario,
                                churn_rate,
                                helper_down_rate,
                                uplink_capacity,
                                policy,
                                seed,
                            });
                        }
                    }
                }
            }
        }
    }
    out
}

/// The orchestrator config behind one cell: the stationary defaults at
/// the cell's churn rate (departures at `rate`, arrivals at `rate × J`).
pub fn cell_cfg(grid: &FleetGridCfg, c: &FleetCell) -> FleetCfg {
    let (j, i) = grid.size;
    let scen = ScenarioCfg::new(c.scenario, grid.model, j, i, c.seed);
    let mut churn = ChurnCfg::stationary(j);
    churn.rounds = grid.rounds;
    churn.departure_prob = c.churn_rate;
    churn.arrival_rate = c.churn_rate * j as f64;
    let mut cfg = FleetCfg::new(scen, churn, c.policy);
    cfg.slot_ms = grid.slot_ms;
    cfg.policy_table = grid.policy_table.clone();
    if c.helper_down_rate > 0.0 {
        cfg.helper_churn = HelperChurnCfg {
            down_rate: c.helper_down_rate,
            outage_rounds: 2,
            join_rate: 0.0,
            max_helpers: 0,
            diurnal_period: 0,
        };
    }
    if c.uplink_capacity > 0.0 {
        cfg.transport = crate::transport::TransportCfg::shared(c.uplink_capacity);
    }
    cfg
}

/// Run one cell: a full fleet simulation, summarized.
pub fn run_cell(grid: &FleetGridCfg, c: &FleetCell) -> FleetGridRow {
    let cfg = cell_cfg(grid, c);
    let report = orchestrator::run(&cfg);
    FleetGridRow {
        scenario: c.scenario.name(),
        model: grid.model.name(),
        n_clients: grid.size.0,
        n_helpers: grid.size.1,
        churn_rate: c.churn_rate,
        helper_down_rate: cfg.helper_churn.down_rate,
        uplink_capacity: c.uplink_capacity,
        policy: c.policy.name(),
        seed: c.seed,
        rounds: report.rounds.len(),
        full_rounds: report.full_rounds(),
        repair_rounds: report.repair_rounds(),
        empty_rounds: report.empty_rounds(),
        mean_makespan_ms: report.mean_makespan_ms(),
        mean_period_ms: report.mean_period_ms(),
        mean_churn_frac: report.mean_churn_frac(),
        total_work_units: report.total_work_units(),
    }
}

/// Run the whole grid across `cfg.threads` workers; results merge in
/// canonical grid order regardless of scheduling.
pub fn run(cfg: &FleetGridCfg) -> Vec<FleetGridRow> {
    let grid = cells(cfg);
    let jobs: Vec<Box<dyn FnOnce() -> FleetGridRow + Send>> = grid
        .into_iter()
        .map(|c| {
            let cfg = cfg.clone();
            Box::new(move || run_cell(&cfg, &c)) as Box<dyn FnOnce() -> FleetGridRow + Send>
        })
        .collect();
    pool::run_parallel(cfg.threads, jobs)
}

/// Serialize rows to the deterministic fleet-grid JSON document under
/// the registry envelope ([`super::artifact::envelope`]).
pub fn rows_to_json(rows: &[FleetGridRow]) -> Json {
    super::artifact::envelope(super::artifact::ArtifactKind::FleetGrid, vec![
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("scenario", Json::Str(r.scenario.to_string())),
                            ("model", Json::Str(r.model.to_string())),
                            ("n_clients", Json::Num(r.n_clients as f64)),
                            ("n_helpers", Json::Num(r.n_helpers as f64)),
                            ("churn_rate", Json::Num(r.churn_rate)),
                            ("helper_down_rate", Json::Num(r.helper_down_rate)),
                            ("uplink_capacity", Json::Num(r.uplink_capacity)),
                            ("policy", Json::Str(r.policy.to_string())),
                            // Seeds replay exactly → string (sweep precedent).
                            ("seed", Json::Str(r.seed.to_string())),
                            ("rounds", Json::Num(r.rounds as f64)),
                            ("full_rounds", Json::Num(r.full_rounds as f64)),
                            ("repair_rounds", Json::Num(r.repair_rounds as f64)),
                            ("empty_rounds", Json::Num(r.empty_rounds as f64)),
                            ("mean_makespan_ms", Json::Num(r.mean_makespan_ms)),
                            ("mean_period_ms", Json::Num(r.mean_period_ms)),
                            ("mean_churn_frac", Json::Num(r.mean_churn_frac)),
                            ("total_work_units", Json::Str(r.total_work_units.to_string())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Persist under `target/psl-bench/<name>.json`. Returns the path.
pub fn save(rows: &[FleetGridRow], name: &str) -> std::io::Result<std::path::PathBuf> {
    super::save_artifact(name, &rows_to_json(rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(threads: usize) -> FleetGridCfg {
        FleetGridCfg {
            scenarios: vec![Scenario::S1, Scenario::S4StragglerTail],
            model: Model::Vgg19,
            size: (6, 2),
            churn_rates: vec![0.1, 0.25],
            helper_down_rates: vec![0.0],
            uplink_capacities: vec![0.0],
            policies: vec![Policy::Incremental, Policy::FullEveryRound],
            seeds: vec![7],
            rounds: 5,
            slot_ms: Some(550.0),
            policy_table: None,
            threads,
        }
    }

    #[test]
    fn grid_propagates_policy_table_to_auto_cells() {
        let mut cfg = tiny(1);
        cfg.policies = vec![Policy::Auto];
        cfg.policy_table = Some(crate::fleet::policy::PolicyTable::builtin());
        let cs = cells(&cfg);
        let cell = cell_cfg(&cfg, &cs[0]);
        assert_eq!(cell.policy, Policy::Auto);
        assert_eq!(cell.policy_table, cfg.policy_table);
    }

    #[test]
    fn canonical_cell_order() {
        let cs = cells(&tiny(1));
        assert_eq!(cs.len(), 8);
        assert_eq!(
            cs[0],
            FleetCell {
                scenario: Scenario::S1,
                churn_rate: 0.1,
                helper_down_rate: 0.0,
                uplink_capacity: 0.0,
                policy: Policy::Incremental,
                seed: 7,
            }
        );
        assert_eq!(cs[1].policy, Policy::FullEveryRound);
        assert_eq!(cs[2].churn_rate, 0.25);
        assert_eq!(cs[4].scenario, Scenario::S4StragglerTail);
    }

    #[test]
    fn helper_axis_multiplies_cells_and_overrides_the_churn_model() {
        let mut cfg = tiny(1);
        cfg.helper_down_rates = vec![0.0, 0.2];
        let cs = cells(&cfg);
        assert_eq!(cs.len(), 16, "helper axis doubles the grid");
        // Axis 0.0 keeps the scenario default (static for S1)...
        let static_cell = cell_cfg(&cfg, &cs[0]);
        assert!(static_cell.helper_churn.is_none());
        // ...and a positive axis value switches on transient outages.
        assert_eq!(cs[2].helper_down_rate, 0.2);
        let churned_cell = cell_cfg(&cfg, &cs[2]);
        assert_eq!(churned_cell.helper_churn.down_rate, 0.2);
        assert_eq!(churned_cell.helper_churn.outage_rounds, 2);
    }

    #[test]
    fn uplink_axis_multiplies_cells_and_switches_the_transport() {
        let mut cfg = tiny(1);
        cfg.uplink_capacities = vec![0.0, 2.0];
        let cs = cells(&cfg);
        assert_eq!(cs.len(), 16, "uplink axis doubles the grid");
        // Axis 0.0 keeps the dedicated transport (the byte-identical
        // historical path)...
        let dedicated_cell = cell_cfg(&cfg, &cs[0]);
        assert!(dedicated_cell.transport.is_dedicated());
        // ...and a positive axis value switches the cell to a shared
        // uplink pool of that capacity.
        assert_eq!(cs[2].uplink_capacity, 2.0);
        let shared_cell = cell_cfg(&cfg, &cs[2]);
        assert!(!shared_cell.transport.is_dedicated());
        assert_eq!(shared_cell.transport.capacity, 2.0);
        // The rows record the axis so analyze can split transport
        // regimes.
        cfg.scenarios = vec![Scenario::S1];
        cfg.churn_rates = vec![0.1];
        cfg.policies = vec![Policy::Incremental];
        cfg.rounds = 3;
        let rows = run(&cfg);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].uplink_capacity, 0.0);
        assert_eq!(rows[1].uplink_capacity, 2.0);
        for r in &rows {
            assert_eq!(r.full_rounds + r.repair_rounds + r.empty_rounds, r.rounds);
        }
    }

    #[test]
    fn s7_cells_record_their_effective_outage_rate() {
        // An s7-helper-bursts cell at axis 0.0 still runs the family's
        // burst model; the row reports the rate that actually ran.
        let mut cfg = tiny(1);
        cfg.scenarios = vec![Scenario::S7HelperBursts];
        cfg.churn_rates = vec![0.1];
        cfg.policies = vec![Policy::Incremental];
        cfg.rounds = 3;
        let rows = run(&cfg);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].scenario, "s7-helper-bursts");
        assert!(rows[0].helper_down_rate > 0.0, "{rows:?}");
        assert_eq!(rows[0].full_rounds + rows[0].repair_rounds + rows[0].empty_rounds, rows[0].rounds);
    }

    #[test]
    fn thread_count_invariant() {
        let a = rows_to_json(&run(&tiny(1))).pretty();
        let b = rows_to_json(&run(&tiny(4))).pretty();
        assert_eq!(a, b, "fleet grid must not depend on thread count");
    }

    #[test]
    fn rows_align_with_cells() {
        let cfg = tiny(2);
        let rows = run(&cfg);
        let grid = cells(&cfg);
        assert_eq!(rows.len(), grid.len());
        for (row, cell) in rows.iter().zip(&grid) {
            assert_eq!(row.scenario, cell.scenario.name());
            assert_eq!(row.policy, cell.policy.name());
            assert_eq!(row.seed, cell.seed);
            assert_eq!(row.rounds, 5);
            assert_eq!(row.full_rounds + row.repair_rounds + row.empty_rounds, row.rounds);
            assert!(row.mean_churn_frac.is_finite() && row.mean_churn_frac >= 0.0, "{row:?}");
        }
        // The event stream is policy-independent, so both arms of the same
        // (scenario, churn, seed) cell observe identical churn fractions.
        assert_eq!(rows[0].mean_churn_frac, rows[1].mean_churn_frac);
    }

    #[test]
    fn full_policy_rows_have_no_repairs() {
        for row in run(&tiny(2)).iter().filter(|r| r.policy == "full") {
            assert_eq!(row.repair_rounds, 0, "{row:?}");
            assert!(row.full_rounds >= 1);
        }
    }

    #[test]
    fn incremental_spends_less_work_than_full() {
        // The headline claim of the subsystem: at moderate churn the
        // incremental policy's deterministic cost proxy is below the
        // full-every-round arm on the same (scenario, churn, seed) cell.
        let rows = run(&tiny(1));
        let pair = |scenario: &str, churn: f64| {
            let find = |p: &str| {
                rows.iter()
                    .find(|r| r.scenario == scenario && (r.churn_rate - churn).abs() < 1e-12 && r.policy == p)
                    .unwrap()
                    .total_work_units
            };
            (find("incremental"), find("full"))
        };
        let (inc, full) = pair("scenario1", 0.1);
        assert!(inc < full, "incremental {inc} !< full {full}");
    }
}
