//! The `psl perf` regression harness: times the solver/checker/replay hot
//! paths across scenario families and instance sizes and writes the
//! repo's perf-trajectory artifact under `target/psl-bench/perf.json`.
//!
//! Two baseline phases (`check-dense`, `replay-dense`) run the
//! pre-refactor **dense slot-list** implementations — kept here, and only
//! here, as the measured reference — so every artifact records the
//! speedup of the run-length ([`SlotRuns`]) representation next to the
//! absolute numbers. The dense replay result is also asserted equal to
//! the run-based replay, so a `psl perf` run doubles as an end-to-end
//! equivalence check; any divergence (or a non-finite timing) fails the
//! run, which is what the CI smoke step relies on.
//!
//! Artifact schema (`kind: "psl-perf"`) is stable across PRs: one row per
//! (cell, phase) with summary timing statistics plus the structural
//! fields (`makespan_slots`, `total_runs`, `total_slots`) that make the
//! O(runs)-vs-O(slots) memory story visible in the data. Since schema v6
//! each row also carries the deterministic solver counters of the cell's
//! structural solve (`exact_nodes` / `exact_cutoffs` / `exact_max_depth`
//! / `admm_iters`, captured via a [`crate::obs::Recording`]), so
//! `psl analyze --perf-diff` can gate pruning efficiency alongside
//! wall-clock. The exact counters are legitimately 0 on cells whose
//! strategy never enters the exact search (it runs inside the sharded
//! stitch on mega cells); because the capture holds the global recording
//! lock, `psl perf` itself deliberately takes no `--trace` flag.

use super::harness::time_fn;
use crate::instance::profiles::Model;
use crate::instance::scenario::{Scenario, ScenarioCfg};
use crate::instance::{Instance, InstanceMs};
use crate::sim;
use crate::solver::admm::AdmmCfg;
use crate::solver::schedule::Schedule;
use crate::solver::strategy;
use crate::util::json::Json;
use crate::util::stats::Summary;

/// Perf-grid configuration.
#[derive(Clone, Debug)]
pub struct PerfCfg {
    pub scenarios: Vec<Scenario>,
    pub model: Model,
    /// (n_clients, n_helpers) cells.
    pub sizes: Vec<(usize, usize)>,
    pub seed: u64,
    /// Timed repetitions per phase.
    pub iters: usize,
    /// Untimed warmup repetitions per phase.
    pub warmup: usize,
}

impl Default for PerfCfg {
    fn default() -> Self {
        // s6-mega-homogeneous at J=256 is the acceptance cell (the term
        // that exploded under dense slot lists); the heterogeneous
        // families keep the preemptive paths honest.
        PerfCfg {
            scenarios: vec![Scenario::S1, Scenario::S2, Scenario::S6MegaHomogeneous],
            model: Model::ResNet101,
            sizes: vec![(32, 4), (256, 16)],
            seed: 42,
            iters: 3,
            warmup: 1,
        }
    }
}

impl PerfCfg {
    /// The extended grid behind `psl perf --full`: a strict superset of
    /// the default grid (every default cell stays, so a `--full` point
    /// still diffs cleanly against earlier default-grid points) plus the
    /// heterogeneous families at an ADMM-heavy size — (48, 6) keeps
    /// every family under the §VII greedy cutoff, so the preemptive ADMM
    /// solve path is what gets timed — a J=512 cell that stresses the
    /// O(runs)-vs-O(slots) read paths beyond the default 256, and two
    /// mega cells (J=8192 and J=65536, both over the
    /// [`SHARD_CLIENT_FRONTIER`](crate::solver::strategy::SHARD_CLIENT_FRONTIER))
    /// that route through `Method::Sharded`, so the perf trajectory
    /// measures where stitching loses vs. the monolithic solve. I=64
    /// keeps the edge matrices O(J·64) — the mega axis is clients, not
    /// the helper count.
    pub fn full() -> PerfCfg {
        PerfCfg {
            scenarios: vec![
                Scenario::S1,
                Scenario::S2,
                Scenario::S3Clustered,
                Scenario::S6MegaHomogeneous,
            ],
            model: Model::ResNet101,
            sizes: vec![(32, 4), (48, 6), (256, 16), (512, 32), (8192, 64), (65536, 64)],
            seed: 42,
            iters: 3,
            warmup: 1,
        }
    }

    /// Tiny grid for CI: one rep, small fleets, still exercises every
    /// phase (including the dense baselines and the equivalence assert).
    pub fn smoke() -> PerfCfg {
        PerfCfg {
            scenarios: vec![Scenario::S1, Scenario::S4StragglerTail, Scenario::S6MegaHomogeneous],
            model: Model::ResNet101,
            sizes: vec![(8, 2)],
            seed: 42,
            iters: 1,
            warmup: 0,
        }
    }
}

/// One (cell, phase) timing row.
#[derive(Clone, Debug)]
pub struct PerfRow {
    pub scenario: &'static str,
    pub model: &'static str,
    pub n_clients: usize,
    pub n_helpers: usize,
    pub seed: u64,
    pub slot_ms: f64,
    /// "solve" | "check" | "check-dense" | "replay" | "replay-dense".
    pub phase: &'static str,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    /// Structural fields of the solved schedule (identical across the
    /// cell's phases; repeated per row so rows are self-contained).
    pub makespan_slots: u32,
    pub total_runs: usize,
    pub total_slots: u64,
    /// Deterministic solver counters of the cell's structural solve
    /// (schema v6; identical across the cell's phases, like the
    /// structural fields). Zero when the cell's strategy never enters
    /// the corresponding search.
    pub exact_nodes: u64,
    pub exact_cutoffs: u64,
    pub exact_max_depth: u64,
    pub admm_iters: u64,
}

// ---------------------------------------------------------------------------
// Dense-representation baselines (pre-refactor semantics, bench-only)
// ---------------------------------------------------------------------------

/// Expand a schedule to the pre-refactor dense slot lists.
fn to_dense(s: &Schedule) -> (Vec<Vec<u32>>, Vec<Vec<u32>>) {
    (
        s.fwd.iter().map(|r| r.to_slots()).collect(),
        s.bwd.iter().map(|r| r.to_slots()).collect(),
    )
}

/// The pre-refactor checker: per-slot loops plus the per-(helper, slot)
/// hash map for constraint (3). O(total processing slots).
fn violations_dense(inst: &Instance, helper_of: &[usize], fwd: &[Vec<u32>], bwd: &[Vec<u32>]) -> usize {
    let mut errs = 0usize;
    let jn = inst.n_clients;
    for j in 0..jn {
        let i = helper_of[j];
        let e = inst.edge(i, j);
        for w in fwd[j].windows(2) {
            if w[1] <= w[0] {
                errs += 1;
                break;
            }
        }
        for w in bwd[j].windows(2) {
            if w[1] <= w[0] {
                errs += 1;
                break;
            }
        }
        if fwd[j].len() != inst.p[e] as usize {
            errs += 1;
        }
        if bwd[j].len() != inst.pp[e] as usize {
            errs += 1;
        }
        if let Some(&first) = fwd[j].first() {
            if first < inst.r[e] {
                errs += 1;
            }
        }
        if let Some(&bfirst) = bwd[j].first() {
            let ready = fwd[j].last().map(|&t| t + 1).unwrap_or(0) + inst.l[e] + inst.lp[e];
            if bfirst < ready {
                errs += 1;
            }
        }
    }
    let mut busy: std::collections::HashMap<(usize, u32), usize> = std::collections::HashMap::new();
    for j in 0..jn {
        let i = helper_of[j];
        for &t in fwd[j].iter().chain(bwd[j].iter()) {
            if busy.insert((i, t), j).is_some() {
                errs += 1;
            }
        }
    }
    errs
}

/// The pre-refactor replay: re-derive segments slot-by-slot from the
/// dense lists, then execute. Returns the realized makespan (ms).
fn replay_dense(ms: &InstanceMs, helper_of: &[usize], fwd: &[Vec<u32>], bwd: &[Vec<u32>]) -> f64 {
    struct Seg {
        client: usize,
        is_bwd: bool,
        first_slot: u32,
        frac: f64,
    }
    let jn = ms.n_clients;
    let mut makespan = 0.0f64;
    for i in 0..ms.n_helpers {
        let clients: Vec<usize> = (0..jn).filter(|&j| helper_of[j] == i).collect();
        if clients.is_empty() {
            continue;
        }
        let mut segments: Vec<Seg> = Vec::new();
        for &j in &clients {
            for (slots, is_bwd) in [(&fwd[j], false), (&bwd[j], true)] {
                if slots.is_empty() {
                    continue;
                }
                let n = slots.len() as f64;
                let mut run_start = 0usize;
                for k in 1..=slots.len() {
                    if k == slots.len() || slots[k] != slots[k - 1] + 1 {
                        segments.push(Seg {
                            client: j,
                            is_bwd,
                            first_slot: slots[run_start],
                            frac: (k - run_start) as f64 / n,
                        });
                        run_start = k;
                    }
                }
            }
        }
        segments.sort_by_key(|s| (s.first_slot, s.client, s.is_bwd));
        let idx_of = |j: usize| clients.iter().position(|&c| c == j).unwrap();
        let mut clock = 0.0f64;
        let mut fwd_done = vec![0.0f64; clients.len()];
        let mut fwd_rem: Vec<f64> = clients.iter().map(|&j| ms.p_ms[ms.edge(i, j)]).collect();
        let mut bwd_rem: Vec<f64> = clients.iter().map(|&j| ms.pp_ms[ms.edge(i, j)]).collect();
        for seg in &segments {
            let k = idx_of(seg.client);
            let e = ms.edge(i, seg.client);
            let ready = if seg.is_bwd {
                fwd_done[k] + ms.l_ms[e] + ms.lp_ms[e]
            } else {
                ms.r_ms[e]
            };
            let start = clock.max(ready);
            let dur = if seg.is_bwd { ms.pp_ms[e] * seg.frac } else { ms.p_ms[e] * seg.frac };
            clock = start + dur;
            if seg.is_bwd {
                bwd_rem[k] -= dur;
                if bwd_rem[k] <= 1e-9 {
                    makespan = makespan.max(clock + ms.rp_ms[e]);
                }
            } else {
                fwd_rem[k] -= dur;
                if fwd_rem[k] <= 1e-9 {
                    fwd_done[k] = clock;
                }
            }
        }
    }
    makespan
}

// ---------------------------------------------------------------------------
// Harness
// ---------------------------------------------------------------------------

/// Run the perf grid. Panics (deliberately) if the dense and run-based
/// replays diverge — the harness doubles as an equivalence check.
pub fn run(cfg: &PerfCfg) -> Vec<PerfRow> {
    let mut rows = Vec::new();
    for &scenario in &cfg.scenarios {
        for &(j, i) in &cfg.sizes {
            let ms = ScenarioCfg::new(scenario, cfg.model, j, i, cfg.seed).generate();
            let slot_ms = cfg.model.profile().default_slot_ms;
            let inst = ms.quantize(slot_ms);

            // Solve once for the structural fields + the timed schedule.
            // The recording captures the deterministic solver counters of
            // exactly this one solve (the timed repetitions below run
            // outside it, so their counts never leak into the row).
            let rec = crate::obs::Recording::start();
            let (schedule, _method) = strategy::solve(&inst, &AdmmCfg::default())
                .expect("scenario generators guarantee a feasible instance");
            let counters = rec.finish();
            let makespan = schedule.makespan(&inst);
            let total_runs = schedule.total_runs();
            let total_slots = schedule.total_slots();
            let (dense_fwd, dense_bwd) = to_dense(&schedule);
            let helper_of = schedule.assignment.helper_of.clone();

            // Equivalence: the dense reference replay must realize the
            // same makespan as the run-based engine.
            let run_ms = sim::replay(&ms, &schedule, None).makespan_ms;
            let dense_ms = replay_dense(&ms, &helper_of, &dense_fwd, &dense_bwd);
            assert!(
                (run_ms - dense_ms).abs() <= 1e-6 * run_ms.max(1.0),
                "replay divergence on {}/{}x{}: runs {} ms vs dense {} ms",
                scenario.name(),
                j,
                i,
                run_ms,
                dense_ms
            );

            let mut push = |phase: &'static str, summary: Summary| {
                rows.push(PerfRow {
                    scenario: scenario.name(),
                    model: cfg.model.name(),
                    n_clients: j,
                    n_helpers: i,
                    seed: cfg.seed,
                    slot_ms,
                    phase,
                    iters: cfg.iters,
                    mean_s: summary.mean,
                    p50_s: summary.p50,
                    min_s: summary.min,
                    max_s: summary.max,
                    makespan_slots: makespan,
                    total_runs,
                    total_slots,
                    exact_nodes: counters.counter("exact.nodes"),
                    exact_cutoffs: counters.counter("exact.cutoffs"),
                    exact_max_depth: counters.counter("exact.max_depth"),
                    admm_iters: counters.counter("admm.iters"),
                });
            };

            push(
                "solve",
                time_fn(
                    || {
                        strategy::solve(&inst, &AdmmCfg::default()).expect("feasible");
                    },
                    cfg.warmup,
                    cfg.iters,
                ),
            );
            push(
                "check",
                time_fn(
                    || {
                        assert!(schedule.violations(&inst).is_empty());
                    },
                    cfg.warmup,
                    cfg.iters,
                ),
            );
            push(
                "check-dense",
                time_fn(
                    || {
                        assert_eq!(violations_dense(&inst, &helper_of, &dense_fwd, &dense_bwd), 0);
                    },
                    cfg.warmup,
                    cfg.iters,
                ),
            );
            push(
                "replay",
                time_fn(
                    || {
                        sim::replay(&ms, &schedule, None);
                    },
                    cfg.warmup,
                    cfg.iters,
                ),
            );
            push(
                "replay-dense",
                time_fn(
                    || {
                        replay_dense(&ms, &helper_of, &dense_fwd, &dense_bwd);
                    },
                    cfg.warmup,
                    cfg.iters,
                ),
            );
        }
    }
    rows
}

/// Every timing must be finite and non-negative — a NaN here means a
/// broken clock or an arithmetic bug, and CI fails on it.
pub fn validate(rows: &[PerfRow]) -> anyhow::Result<()> {
    for r in rows {
        for (name, v) in [("mean", r.mean_s), ("p50", r.p50_s), ("min", r.min_s), ("max", r.max_s)] {
            anyhow::ensure!(
                v.is_finite() && v >= 0.0,
                "non-finite {name} timing {v} in {}/{}x{} phase {}",
                r.scenario,
                r.n_clients,
                r.n_helpers,
                r.phase
            );
        }
    }
    Ok(())
}

/// Serialize to the perf artifact (kind "psl-perf") under the registry
/// envelope ([`super::artifact::envelope`]).
pub fn rows_to_json(rows: &[PerfRow]) -> Json {
    super::artifact::envelope(super::artifact::ArtifactKind::Perf, vec![
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("scenario", Json::Str(r.scenario.to_string())),
                            ("model", Json::Str(r.model.to_string())),
                            ("n_clients", Json::Num(r.n_clients as f64)),
                            ("n_helpers", Json::Num(r.n_helpers as f64)),
                            ("seed", Json::Str(r.seed.to_string())),
                            ("slot_ms", Json::Num(r.slot_ms)),
                            ("phase", Json::Str(r.phase.to_string())),
                            ("iters", Json::Num(r.iters as f64)),
                            ("mean_s", Json::Num(r.mean_s)),
                            ("p50_s", Json::Num(r.p50_s)),
                            ("min_s", Json::Num(r.min_s)),
                            ("max_s", Json::Num(r.max_s)),
                            ("makespan_slots", Json::Num(r.makespan_slots as f64)),
                            ("total_runs", Json::Num(r.total_runs as f64)),
                            ("total_slots", Json::Num(r.total_slots as f64)),
                            ("exact_nodes", Json::Num(r.exact_nodes as f64)),
                            ("exact_cutoffs", Json::Num(r.exact_cutoffs as f64)),
                            ("exact_max_depth", Json::Num(r.exact_max_depth as f64)),
                            ("admm_iters", Json::Num(r.admm_iters as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Persist under `target/psl-bench/<name>.json`. Returns the path.
pub fn save(rows: &[PerfRow], name: &str) -> std::io::Result<std::path::PathBuf> {
    super::save_artifact(name, &rows_to_json(rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_grid_produces_valid_rows() {
        let cfg = PerfCfg::smoke();
        let rows = run(&cfg);
        // 3 scenarios × 1 size × 5 phases.
        assert_eq!(rows.len(), 15);
        validate(&rows).expect("finite timings");
        for r in &rows {
            assert!(r.makespan_slots > 0);
            assert!(r.total_runs > 0);
            assert!(r.total_slots >= r.total_runs as u64, "a run covers ≥ 1 slot");
        }
        // The smoke cells route through ADMM, so the solver-counter
        // columns must be populated (and serialized).
        assert!(rows.iter().any(|r| r.admm_iters > 0), "ADMM iteration counter missing");
        let doc = rows_to_json(&rows);
        assert_eq!(doc.get("kind").as_str(), Some("psl-perf"));
        let parsed = Json::parse(&doc.pretty()).unwrap();
        assert_eq!(parsed.get("rows").as_arr().unwrap().len(), 15);
        assert!(parsed.get("rows").as_arr().unwrap()[0].get("admm_iters").as_f64().is_some());
    }

    #[test]
    fn dense_baselines_agree_with_run_representation() {
        // The dense checker accepts every feasible schedule the run-based
        // checker accepts (the replay equivalence assert runs inside
        // `run`; this covers the checker side explicitly).
        let ms = ScenarioCfg::new(Scenario::S2, Model::Vgg19, 10, 3, 9).generate();
        let inst = ms.quantize(550.0);
        let (schedule, _) = strategy::solve(&inst, &AdmmCfg::default()).unwrap();
        assert!(schedule.is_feasible(&inst));
        let (df, db) = to_dense(&schedule);
        assert_eq!(violations_dense(&inst, &schedule.assignment.helper_of, &df, &db), 0);
    }

    #[test]
    fn full_grid_is_a_strict_superset_of_the_default() {
        let full = PerfCfg::full();
        let dflt = PerfCfg::default();
        for size in &dflt.sizes {
            assert!(full.sizes.contains(size), "default cell {size:?} must stay in --full");
        }
        for scenario in &dflt.scenarios {
            assert!(full.scenarios.contains(scenario), "default family {scenario:?} must stay in --full");
        }
        assert!(full.sizes.contains(&(48, 6)), "the ADMM-heavy size");
        assert!(full.sizes.contains(&(512, 32)), "the large monolithic cell");
        assert!(full.sizes.contains(&(8192, 64)), "the first sharded mega cell");
        assert!(full.sizes.contains(&(65536, 64)), "the second sharded mega cell");
        assert!(full.scenarios.contains(&Scenario::S3Clustered), "heterogeneous family added");
        assert_eq!(full.seed, dflt.seed, "same seed as the default trajectory");
    }

    #[test]
    fn full_grid_mega_cells_route_to_sharded_and_large_stays_flat() {
        use crate::solver::strategy::{pick_from_signals, Method, Signals, SHARD_CLIENT_FRONTIER};
        // Signals-level check (generating a real 65536-client instance is
        // a --full job, not a unit test): both mega sizes are over the
        // frontier with ≥ 2 helpers, the J=512 cell is not.
        for &(j, i) in &PerfCfg::full().sizes {
            let s = Signals {
                n_clients: j,
                n_helpers: i,
                heterogeneity: 0.2,
                placement_flexibility: 1.0,
                tail_ratio: 1.2,
                contention: 0.0,
            };
            let picked = pick_from_signals(&s);
            if j >= SHARD_CLIENT_FRONTIER {
                assert_eq!(picked, Method::Sharded, "{j}x{i}");
            } else {
                assert_ne!(picked, Method::Sharded, "{j}x{i}");
            }
        }
        assert!(PerfCfg::full().sizes.iter().any(|&(j, _)| j >= SHARD_CLIENT_FRONTIER));
    }

    #[test]
    fn full_grid_admm_heavy_cell_routes_to_admm() {
        // (48, 6) sits under the §VII greedy cutoff, so the heterogeneous
        // families exercise the preemptive ADMM solve path in `--full`.
        for scenario in [Scenario::S2, Scenario::S3Clustered] {
            let inst = ScenarioCfg::new(scenario, Model::ResNet101, 48, 6, 42).generate().quantize(180.0);
            assert_eq!(strategy::pick(&inst), strategy::Method::Admm, "{}", scenario.name());
        }
    }

    #[test]
    fn validate_rejects_nan() {
        let mut rows = vec![PerfRow {
            scenario: "scenario1",
            model: "resnet101",
            n_clients: 4,
            n_helpers: 2,
            seed: 1,
            slot_ms: 180.0,
            phase: "check",
            iters: 1,
            mean_s: 0.1,
            p50_s: 0.1,
            min_s: 0.1,
            max_s: 0.1,
            makespan_slots: 10,
            total_runs: 8,
            total_slots: 40,
            exact_nodes: 0,
            exact_cutoffs: 0,
            exact_max_depth: 0,
            admm_iters: 3,
        }];
        assert!(validate(&rows).is_ok());
        rows[0].p50_s = f64::NAN;
        assert!(validate(&rows).is_err());
    }
}
