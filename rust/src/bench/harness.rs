//! Mini benchmark harness (no `criterion` in this image): warmup +
//! repeated timing with summary statistics, aligned table output, and a
//! JSON dump per bench target under `target/psl-bench/`.

use crate::util::json::Json;
use crate::util::stats::Summary;
use std::time::Instant;

/// Time `f` for `iters` iterations after `warmup` runs; returns per-iter
/// seconds.
pub fn time_fn<F: FnMut()>(mut f: F, warmup: usize, iters: usize) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

/// A report table under construction.
pub struct Report {
    pub name: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
    json_rows: Vec<Json>,
}

impl Report {
    pub fn new(name: &str, columns: &[&str]) -> Report {
        Report {
            name: name.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            json_rows: Vec::new(),
        }
    }

    /// Add a row (stringified cells) plus its raw JSON record.
    pub fn row(&mut self, cells: Vec<String>, record: Json) {
        assert_eq!(cells.len(), self.columns.len(), "row width");
        self.rows.push(cells);
        self.json_rows.push(record);
    }

    /// Print the aligned table to stdout.
    pub fn print(&self) {
        println!("\n=== {} ===", self.name);
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (k, cell) in row.iter().enumerate() {
                widths[k] = widths[k].max(cell.len());
            }
        }
        let line = |cells: &[String]| {
            let parts: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(k, c)| format!("{:>w$}", c, w = widths[k]))
                .collect();
            println!("  {}", parts.join("  "));
        };
        line(&self.columns);
        println!("  {}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            line(row);
        }
    }

    /// Persist the raw records for EXPERIMENTS.md and regression diffing.
    pub fn save(&self) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("target/psl-bench");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.json", self.name));
        let doc = Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("columns", Json::Arr(self.columns.iter().map(|c| Json::Str(c.clone())).collect())),
            ("rows", Json::Arr(self.json_rows.clone())),
        ]);
        std::fs::write(&path, doc.pretty())?;
        Ok(path)
    }

    /// Print and save; logs the save path.
    pub fn finish(&self) {
        self.print();
        match self.save() {
            Ok(p) => println!("  [saved {}]", p.display()),
            Err(e) => println!("  [save failed: {e}]"),
        }
    }
}

/// Format seconds human-readably.
pub fn fmt_s(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_runs_expected_iterations() {
        let mut count = 0;
        let s = time_fn(|| count += 1, 2, 10);
        assert_eq!(count, 12);
        assert_eq!(s.n, 10);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn report_roundtrip() {
        let mut r = Report::new("unit-test-report", &["a", "b"]);
        r.row(vec!["1".into(), "x".into()], Json::obj(vec![("a", Json::Num(1.0))]));
        r.print();
        let path = r.save().unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        let v = Json::parse(&text).unwrap();
        assert_eq!(v.get("rows").as_arr().unwrap().len(), 1);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_s(0.0000005).ends_with("µs"));
        assert!(fmt_s(0.005).ends_with("ms"));
        assert!(fmt_s(2.0).ends_with("s"));
    }
}
