//! The `target/psl-bench` artifact registry: every JSON document the
//! runners persist — sweep grids, fleet runs, fleet grids, perf
//! trajectories, policy tables — carries the same envelope (`kind` tag +
//! `schema_version`), and every consumer loads through the same
//! schema-checked entry point instead of ad-hoc per-file parsing.
//!
//! Writers build their document with [`envelope`]; readers call [`load`]
//! (path → validated document) or [`expect_kind`] (document already in
//! hand). Validation is deliberately shallow — kind tag known, schema
//! version supported — so old artifacts keep loading; per-kind row
//! validation stays with the module that owns the rows (e.g.
//! [`crate::analyze::grid`] for fleet-grid rows).

use crate::util::json::Json;
use anyhow::{Context, Result};

/// Version stamped into every artifact this build writes.
///
/// History: v1 = the pre-registry shapes (artifacts from older builds
/// carry no `schema_version` field and are read as v1); v2 added the
/// per-row `mean_churn_frac` field to `psl-fleet-grid` rows (the
/// observed-churn unit the analyze frontier is measured in); v3 added
/// the `psl-fleet-checkpoint` kind (fleet-session warm state + completed
/// rounds) with no shape changes to existing kinds; v4 added the
/// `psl-shard` kind (sharded hierarchical solve: per-shard + stitched
/// metrics) and the per-round instance signals (`heterogeneity`,
/// `placement_flexibility`, `tail_ratio`) in fleet round reports; v5
/// added helper dynamics — per-round `helpers_live` /
/// `orphaned_clients` / `migrations` / `degraded` fields in fleet round
/// reports, the helper roster (live / down / id watermark) and
/// helper-churn knobs in `psl-fleet-checkpoint`, the `helper_down_rate`
/// axis in `psl-fleet-grid` rows, and the optional per-entry
/// `helper_down_rate` in `psl-policy-table`; v6 added the observability
/// surface — the `psl-trace` kind (Chrome trace-event spans + the
/// deterministic counter map) and the deterministic solver-counter
/// columns (`exact_nodes` / `exact_cutoffs` / `exact_max_depth` /
/// `admm_iters`) in `psl-perf` rows; v7 added the transport layer —
/// optional per-round `contention` / `repair_source` fields in fleet
/// round reports, the optional `link_model` / `uplink_capacity` config
/// and `last_full_method` state in `psl-fleet-checkpoint`, the
/// `uplink_capacity` axis in `psl-fleet-grid` rows, and the optional
/// per-entry `uplink_capacity` in `psl-policy-table` (all emitted only
/// when non-default, so dedicated-transport artifacts keep their v6
/// bytes).
/// Readers accept anything ≤ the current version; kind-specific readers
/// give a "re-generate with this build" error when a field their version
/// needs is absent.
pub const SCHEMA_VERSION: u32 = 7;

/// Every artifact kind the repo persists under `target/psl-bench/`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// `psl sweep` — scenario × solver grid rows.
    Sweep,
    /// `psl fleet` — one multi-round churn run (summary + rounds_detail;
    /// the `.rounds.jsonl` sidecar streams the same detail entries).
    Fleet,
    /// `psl fleet --grid` — scenario × churn-rate × policy summaries.
    FleetGrid,
    /// `psl perf` — solve/check/replay timing trajectory rows.
    Perf,
    /// `psl analyze` — per-(family, size) churn-rate frontier table
    /// consumed by the fleet `auto` policy.
    PolicyTable,
    /// `psl fleet --checkpoint-every` / `psl serve` — a paused fleet
    /// session's warm state + completed rounds, resumable via
    /// `psl fleet --resume`.
    FleetCheckpoint,
    /// `psl shard` — sharded hierarchical solve rows: per-shard makespans
    /// and methods plus the stitched global makespan and stitch gap.
    Shard,
    /// `psl solve|fleet|shard|serve --trace` — a Chrome trace-event
    /// capture ([`crate::obs`]): wall-clock spans (non-deterministic)
    /// plus the deterministic counter map.
    Trace,
}

impl ArtifactKind {
    pub const ALL: [ArtifactKind; 8] = [
        ArtifactKind::Sweep,
        ArtifactKind::Fleet,
        ArtifactKind::FleetGrid,
        ArtifactKind::Perf,
        ArtifactKind::PolicyTable,
        ArtifactKind::FleetCheckpoint,
        ArtifactKind::Shard,
        ArtifactKind::Trace,
    ];

    /// The `kind` tag written into the document.
    pub fn tag(self) -> &'static str {
        match self {
            ArtifactKind::Sweep => "psl-sweep",
            ArtifactKind::Fleet => "psl-fleet",
            ArtifactKind::FleetGrid => "psl-fleet-grid",
            ArtifactKind::Perf => "psl-perf",
            ArtifactKind::PolicyTable => "psl-policy-table",
            ArtifactKind::FleetCheckpoint => "psl-fleet-checkpoint",
            ArtifactKind::Shard => "psl-shard",
            ArtifactKind::Trace => "psl-trace",
        }
    }

    pub fn from_tag(s: &str) -> Option<ArtifactKind> {
        ArtifactKind::ALL.into_iter().find(|k| k.tag() == s)
    }
}

/// Build an artifact document: the shared envelope (`kind`,
/// `schema_version`) plus the kind's own fields. Key order in the output
/// is alphabetical regardless (BTreeMap), so the envelope adds no
/// ordering constraints on callers.
pub fn envelope(kind: ArtifactKind, fields: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![
        ("kind", Json::Str(kind.tag().to_string())),
        ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
    ];
    pairs.extend(fields);
    Json::obj(pairs)
}

/// Validate the envelope of an in-memory document: known `kind` tag and
/// a supported `schema_version` (absent = 1, the pre-registry shape).
/// Returns the kind so callers can dispatch.
pub fn validate(doc: &Json) -> Result<ArtifactKind> {
    let tag = doc
        .get("kind")
        .as_str()
        .context("not a psl-bench artifact: missing \"kind\" tag")?;
    let kind = ArtifactKind::from_tag(tag)
        .with_context(|| format!("unknown artifact kind {tag:?}"))?;
    let version = match doc.get("schema_version") {
        Json::Null => 1,
        v => v
            .as_usize()
            .with_context(|| format!("bad schema_version {v} (expected a non-negative integer)"))?,
    };
    anyhow::ensure!(
        version <= SCHEMA_VERSION as usize,
        "artifact schema version {version} is newer than this build supports ({SCHEMA_VERSION})"
    );
    Ok(kind)
}

/// Validate the envelope *and* pin the kind — the guard every consumer
/// uses so a fleet-grid document can never be silently diffed as a sweep
/// (and vice versa).
pub fn expect_kind(doc: &Json, want: ArtifactKind) -> Result<()> {
    let kind = validate(doc)?;
    anyhow::ensure!(
        kind == want,
        "not a {} artifact (kind {:?}, expected {:?})",
        want.tag(),
        kind.tag(),
        want.tag()
    );
    Ok(())
}

/// Read + parse + validate an artifact file. Returns the kind and the
/// document.
pub fn load(path: &str) -> Result<(ArtifactKind, Json)> {
    let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
    let doc = Json::parse(&text).with_context(|| format!("parse {path}"))?;
    let kind = validate(&doc).with_context(|| format!("validate {path}"))?;
    Ok((kind, doc))
}

/// [`load`] pinned to one kind.
pub fn load_expecting(path: &str, want: ArtifactKind) -> Result<Json> {
    let (_, doc) = load(path)?;
    expect_kind(&doc, want).with_context(|| format!("validate {path}"))?;
    Ok(doc)
}

/// Write a deterministic JSON artifact under
/// `target/psl-bench/<name>.json` (the single location every runner —
/// sweep, fleet, fleet grid, perf, analyze — persists to). Returns the
/// path.
pub fn save(name: &str, doc: &Json) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("target/psl-bench");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, doc.pretty())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_roundtrip() {
        for k in ArtifactKind::ALL {
            assert_eq!(ArtifactKind::from_tag(k.tag()), Some(k), "{}", k.tag());
        }
        assert_eq!(ArtifactKind::from_tag("psl-unknown"), None);
    }

    #[test]
    fn envelope_carries_kind_and_version() {
        let doc = envelope(ArtifactKind::Sweep, vec![("rows", Json::Arr(vec![]))]);
        assert_eq!(doc.get("kind").as_str(), Some("psl-sweep"));
        assert_eq!(doc.get("schema_version").as_usize(), Some(SCHEMA_VERSION as usize));
        assert_eq!(validate(&doc).unwrap(), ArtifactKind::Sweep);
        assert!(expect_kind(&doc, ArtifactKind::Sweep).is_ok());
    }

    #[test]
    fn expect_kind_rejects_mismatch_naming_both_kinds() {
        let doc = envelope(ArtifactKind::FleetGrid, vec![("rows", Json::Arr(vec![]))]);
        let err = expect_kind(&doc, ArtifactKind::Sweep).unwrap_err().to_string();
        assert!(err.contains("psl-fleet-grid"), "{err}");
        assert!(err.contains("psl-sweep"), "{err}");
    }

    #[test]
    fn pre_registry_documents_read_as_version_one() {
        // Artifacts written before the registry existed have a kind tag
        // but no schema_version field.
        let doc = Json::obj(vec![
            ("kind", Json::Str("psl-perf".to_string())),
            ("rows", Json::Arr(vec![])),
        ]);
        assert_eq!(validate(&doc).unwrap(), ArtifactKind::Perf);
    }

    #[test]
    fn rejects_unknown_kind_missing_kind_and_future_version() {
        assert!(validate(&Json::Num(3.0)).is_err());
        let unknown = Json::obj(vec![("kind", Json::Str("psl-nope".to_string()))]);
        assert!(validate(&unknown).unwrap_err().to_string().contains("psl-nope"));
        let future = Json::obj(vec![
            ("kind", Json::Str("psl-sweep".to_string())),
            ("schema_version", Json::Num(999.0)),
        ]);
        let err = validate(&future).unwrap_err().to_string();
        assert!(err.contains("newer"), "{err}");
    }

    #[test]
    fn save_load_roundtrip() {
        let doc = envelope(ArtifactKind::PolicyTable, vec![("entries", Json::Arr(vec![]))]);
        let name = format!("artifact-roundtrip-{}", std::process::id());
        let path = save(&name, &doc).unwrap();
        let (kind, loaded) = load(path.to_str().unwrap()).unwrap();
        assert_eq!(kind, ArtifactKind::PolicyTable);
        assert_eq!(loaded, doc);
        assert!(load_expecting(path.to_str().unwrap(), ArtifactKind::Sweep).is_err());
        std::fs::remove_file(&path).ok();
    }
}
