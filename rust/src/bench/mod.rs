//! Benchmark substrate used by the `rust/benches/*` targets (`cargo
//! bench` with `harness = false`) — see DESIGN.md §4 for the table/figure
//! mapping — plus the multi-threaded scenario × solver sweep runner
//! behind `psl sweep` ([`sweep`]).

pub mod harness;
pub mod sweep;

pub use harness::{fmt_s, time_fn, Report};
pub use sweep::{SweepCfg, SweepRow};
