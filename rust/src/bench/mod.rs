//! Benchmark substrate used by the `rust/benches/*` targets (`cargo
//! bench` with `harness = false`) — see DESIGN.md §4 for the table/figure
//! mapping.

pub mod harness;

pub use harness::{fmt_s, time_fn, Report};
