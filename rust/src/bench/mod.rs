//! Benchmark substrate used by the `rust/benches/*` targets (`cargo
//! bench` with `harness = false`) — see DESIGN.md §4 for the table/figure
//! mapping — plus the multi-threaded scenario × solver sweep runner
//! behind `psl sweep` ([`sweep`]), the fleet-orchestration grid behind
//! `psl fleet --grid` ([`fleet`]), and the solve/check/replay perf
//! trajectory behind `psl perf` ([`perf`]).

pub mod fleet;
pub mod harness;
pub mod perf;
pub mod sweep;

pub use fleet::{FleetGridCfg, FleetGridRow};
pub use harness::{fmt_s, time_fn, Report};
pub use perf::{PerfCfg, PerfRow};
pub use sweep::{SweepCfg, SweepRow};

/// Write a deterministic JSON artifact under
/// `target/psl-bench/<name>.json` (the single location every runner —
/// sweep, fleet, fleet grid — persists to). Returns the path.
pub fn save_artifact(name: &str, doc: &crate::util::json::Json) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("target/psl-bench");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, doc.pretty())?;
    Ok(path)
}
