//! Benchmark substrate used by the `rust/benches/*` targets (`cargo
//! bench` with `harness = false`) — see DESIGN.md §4 for the table/figure
//! mapping — plus the multi-threaded scenario × solver sweep runner
//! behind `psl sweep` ([`sweep`]), the fleet-orchestration grid behind
//! `psl fleet --grid` ([`fleet`]), the solve/check/replay perf trajectory
//! behind `psl perf` ([`perf`]), and the shared `target/psl-bench`
//! artifact registry ([`artifact`]) every writer and reader goes through.

pub mod artifact;
pub mod fleet;
pub mod harness;
pub mod perf;
pub mod sweep;

pub use artifact::{ArtifactKind, SCHEMA_VERSION};
pub use fleet::{FleetGridCfg, FleetGridRow};
pub use harness::{fmt_s, time_fn, Report};
pub use perf::{PerfCfg, PerfRow};
pub use sweep::{SweepCfg, SweepRow};

/// Write a deterministic JSON artifact under `target/psl-bench/<name>.json`
/// (delegates to [`artifact::save`], kept as the historical entry point).
pub fn save_artifact(name: &str, doc: &crate::util::json::Json) -> std::io::Result<std::path::PathBuf> {
    artifact::save(name, doc)
}
