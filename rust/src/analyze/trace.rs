//! Summarization of `psl-trace` artifacts (`psl analyze --trace <file>`).
//!
//! A trace capture ([`crate::obs`]) is a Chrome trace-event document:
//! great in Perfetto, unreadable in a terminal. This module reduces it to
//! the two tables a human actually asks for — per-phase wall-clock (one
//! row per distinct `cat/name` span: count, total/mean/max duration) and
//! the deterministic counter map — without losing the split the artifact
//! is built around: span durations are wall-clock and noisy, counters
//! are exact algorithm statistics.
//!
//! The summary is deterministic for the same artifact bytes (phases sort
//! by `(cat, name)`, counters are already a sorted map), so its rendered
//! output is itself diffable.

use crate::bench::artifact::{self, ArtifactKind};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// Aggregated wall-clock for one distinct span name.
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseSummary {
    pub cat: String,
    pub name: String,
    /// Completed spans with this (cat, name).
    pub count: usize,
    pub total_us: u64,
    pub max_us: u64,
}

impl PhaseSummary {
    pub fn mean_us(&self) -> f64 {
        self.total_us as f64 / self.count.max(1) as f64
    }
}

/// The reduced view of one `psl-trace` document.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceSummary {
    /// Per-(cat, name) span aggregates, sorted by (cat, name).
    pub phases: Vec<PhaseSummary>,
    /// The deterministic counter map, verbatim.
    pub counters: BTreeMap<String, u64>,
    /// Threads that recorded at least one span.
    pub threads: usize,
}

/// Reduce a validated `psl-trace` document. Rejects other kinds and
/// newer schema versions through the registry's usual validation.
pub fn summarize_doc(doc: &Json) -> Result<TraceSummary> {
    artifact::expect_kind(doc, ArtifactKind::Trace)?;
    let events = doc.get("traceEvents").as_arr().context("trace artifact missing traceEvents[]")?;
    let mut phases: BTreeMap<(String, String), PhaseSummary> = BTreeMap::new();
    let mut tids: std::collections::BTreeSet<u64> = std::collections::BTreeSet::new();
    for (k, e) in events.iter().enumerate() {
        // Only complete ("X") duration events aggregate; metadata ("M")
        // events name threads and carry no duration.
        if e.get("ph").as_str() != Some("X") {
            continue;
        }
        let cat = e.get("cat").as_str().unwrap_or("?").to_string();
        let name = e
            .get("name")
            .as_str()
            .with_context(|| format!("traceEvents[{k}]: missing span name"))?
            .to_string();
        let dur = e
            .get("dur")
            .as_f64()
            .with_context(|| format!("traceEvents[{k}]: missing/bad dur"))? as u64;
        if let Some(tid) = e.get("tid").as_f64() {
            tids.insert(tid as u64);
        }
        let entry = phases.entry((cat.clone(), name.clone())).or_insert(PhaseSummary {
            cat,
            name,
            count: 0,
            total_us: 0,
            max_us: 0,
        });
        entry.count += 1;
        entry.total_us += dur;
        entry.max_us = entry.max_us.max(dur);
    }
    let mut counters = BTreeMap::new();
    if let Json::Obj(m) = doc.get("counters") {
        for (k, v) in m {
            let n = v.as_f64().with_context(|| format!("counter {k:?}: not a number"))?;
            counters.insert(k.clone(), n as u64);
        }
    }
    Ok(TraceSummary { phases: phases.into_values().collect(), counters, threads: tids.len() })
}

/// [`summarize_doc`] from a path, through the registry loader.
pub fn summarize_file(path: &str) -> Result<TraceSummary> {
    let doc = artifact::load_expecting(path, ArtifactKind::Trace)?;
    summarize_doc(&doc)
}

/// Render the summary as the two aligned tables `psl analyze --trace`
/// prints.
pub fn render(s: &TraceSummary) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "phases ({} distinct, {} thread{}):\n",
        s.phases.len(),
        s.threads,
        if s.threads == 1 { "" } else { "s" }
    ));
    out.push_str(&format!(
        "  {:<10} {:<22} {:>7} {:>12} {:>12} {:>12}\n",
        "cat", "name", "count", "total_ms", "mean_ms", "max_ms"
    ));
    for p in &s.phases {
        out.push_str(&format!(
            "  {:<10} {:<22} {:>7} {:>12.3} {:>12.3} {:>12.3}\n",
            p.cat,
            p.name,
            p.count,
            p.total_us as f64 / 1000.0,
            p.mean_us() / 1000.0,
            p.max_us as f64 / 1000.0
        ));
    }
    out.push_str(&format!("counters ({}, deterministic):\n", s.counters.len()));
    for (k, v) in &s.counters {
        out.push_str(&format!("  {k:<28} {v}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{counter_add, span, trace_to_json, Recording};

    fn capture() -> Json {
        let rec = Recording::start();
        for k in 0..3u64 {
            let mut sp = span("solver", "solver/admm");
            sp.arg("k", k);
        }
        {
            let _sp = span("fleet", "fleet/decide");
        }
        counter_add("admm.iters", 12);
        counter_add("exact.nodes", 400);
        trace_to_json(&rec.finish())
    }

    #[test]
    fn summarizes_phases_and_counters() {
        let doc = capture();
        let s = summarize_doc(&doc).unwrap();
        assert_eq!(s.phases.len(), 2, "{:?}", s.phases);
        // Sorted by (cat, name): fleet first.
        assert_eq!(s.phases[0].name, "fleet/decide");
        assert_eq!(s.phases[0].count, 1);
        assert_eq!(s.phases[1].name, "solver/admm");
        assert_eq!(s.phases[1].count, 3);
        assert!(s.phases[1].total_us >= s.phases[1].max_us);
        assert_eq!(s.counters.get("admm.iters"), Some(&12));
        assert_eq!(s.counters.get("exact.nodes"), Some(&400));
        assert_eq!(s.threads, 1);
    }

    #[test]
    fn render_is_deterministic_and_names_everything() {
        let doc = capture();
        let s = summarize_doc(&doc).unwrap();
        let text = render(&s);
        assert_eq!(text, render(&summarize_doc(&doc).unwrap()));
        assert!(text.contains("solver/admm"), "{text}");
        assert!(text.contains("fleet/decide"), "{text}");
        assert!(text.contains("admm.iters"), "{text}");
        assert!(text.contains("deterministic"), "{text}");
    }

    #[test]
    fn rejects_wrong_kinds() {
        let sweep = artifact::envelope(ArtifactKind::Sweep, vec![("rows", Json::Arr(vec![]))]);
        let err = summarize_doc(&sweep).unwrap_err().to_string();
        assert!(err.contains("psl-sweep"), "{err}");
    }
}
