//! The churn-rate **policy frontier**: where full re-solving overtakes
//! incremental repair, per scenario family × fleet size.
//!
//! The paper's §VII strategy is built the same way — run the methods over
//! measured scenarios, record where each wins, encode the boundary as a
//! rule. Here the two "methods" are the fleet orchestrator's arms
//! (`incremental` warm-started repair vs `full` re-solve every round),
//! the axis is the grid's churn rate — with the crossover reported in
//! the *observed* per-round churn-fraction unit the orchestrator
//! compares against — and the win criterion is the
//! **work-discounted makespan** ([`score`](super::grid::RegimeCell::score)): full wins a
//! regime only when the makespan it recovers justifies the solve work it
//! spends. The output is a [`PolicyTable`] the `auto` policy consults at
//! run time — measured thresholds instead of the hard-coded 0.35.

use super::grid::RegimeTable;
use crate::fleet::policy::{PolicyEntry, PolicyTable};

/// Outcome of the frontier scan for one regime table.
#[derive(Clone, Debug, PartialEq)]
pub struct Frontier {
    pub scenario: String,
    pub n_clients: usize,
    pub n_helpers: usize,
    /// Helper outage rate of the regime the frontier was measured in
    /// (0.0 = static pool) — carried into the policy entry so the `auto`
    /// policy can pick the frontier matching a run's helper churn.
    pub helper_down_rate: f64,
    /// Uplink pool capacity of the regime the frontier was measured in
    /// (0.0 = dedicated transport) — carried into the policy entry so
    /// the `auto` policy can pick the frontier matching a run's link
    /// model.
    pub uplink_capacity: f64,
    /// The *observed* per-round churn fraction at the lowest measured
    /// rate where `full` beats `incremental` on score — the same unit
    /// the orchestrator's per-round `churn_frac` signal uses, so the
    /// `auto` policy compares like with like (the grid's stationary rate
    /// axis is ≈ half this value: departures and arrivals both count
    /// toward the membership delta). `None` = incremental won at every
    /// rate that had both arms.
    pub crossover: Option<f64>,
    /// Churn rates where both arms were measured (the frontier's
    /// resolution — a single-rate grid gives a very coarse frontier).
    pub rates_compared: usize,
}

/// Scan one regime table for the crossover. Rates missing either arm are
/// skipped (they carry no comparison); the crossover is taken at the
/// *first* rate, ascending, where full's score is strictly lower — the
/// conservative choice if the measured scores are non-monotone — and is
/// reported as that rate's observed churn fraction (both arms replay the
/// same policy-independent event stream, so their observed fractions
/// agree; the mean is taken for robustness to partial grids).
pub fn frontier(table: &RegimeTable) -> Frontier {
    let mut crossover = None;
    let mut rates_compared = 0;
    for rate in table.churn_rates() {
        let (Some(inc), Some(full)) = (table.cell(rate, "incremental"), table.cell(rate, "full")) else {
            continue;
        };
        rates_compared += 1;
        if crossover.is_none() && full.score < inc.score {
            crossover = Some((inc.mean_churn_frac + full.mean_churn_frac) / 2.0);
        }
    }
    Frontier {
        scenario: table.scenario.clone(),
        n_clients: table.n_clients,
        n_helpers: table.n_helpers,
        helper_down_rate: table.helper_down_rate,
        uplink_capacity: table.uplink_capacity,
        crossover,
        rates_compared,
    }
}

/// Compute frontiers for every regime table that compared the two arms at
/// least once; tables with no comparable rate (e.g. a repair-only-vs-full
/// grid) are dropped — they say nothing about this frontier.
pub fn frontiers(tables: &[RegimeTable]) -> Vec<Frontier> {
    tables.iter().map(frontier).filter(|f| f.rates_compared > 0).collect()
}

/// Frontiers → a serializable [`PolicyTable`] (`source` records
/// provenance, e.g. the artifact filename). Takes the computed
/// [`frontiers`] so a caller that also prints them ([`psl analyze`])
/// serializes provably the same scan it displayed.
///
/// [`psl analyze`]: crate::analyze
pub fn compute_policy_table(frontiers: Vec<Frontier>, source: &str) -> PolicyTable {
    let entries = frontiers
        .into_iter()
        .map(|f| PolicyEntry {
            scenario: f.scenario,
            n_clients: f.n_clients,
            n_helpers: f.n_helpers,
            frontier_churn: f.crossover,
            helper_down_rate: f.helper_down_rate,
            uplink_capacity: f.uplink_capacity,
        })
        .collect();
    PolicyTable::new(source.to_string(), entries)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyze::grid::{regime_tables, tests::row, GridRow};

    /// A synthetic hand-built grid with a known crossover: incremental
    /// wins at 0.05 and 0.15, full wins at 0.3.
    fn synthetic() -> Vec<GridRow> {
        let mut rows = Vec::new();
        for seed in [1u64, 2] {
            // score = makespan × work. incremental: cheap but degrading
            // with churn; full: constant cost, constant makespan.
            rows.push(row("scenario1", 0.05, "incremental", seed, 1000.0, 100));
            rows.push(row("scenario1", 0.05, "full", seed, 950.0, 900));
            rows.push(row("scenario1", 0.15, "incremental", seed, 1100.0, 300));
            rows.push(row("scenario1", 0.15, "full", seed, 950.0, 900));
            rows.push(row("scenario1", 0.3, "incremental", seed, 1400.0, 700));
            rows.push(row("scenario1", 0.3, "full", seed, 950.0, 900));
        }
        rows
    }

    #[test]
    fn synthetic_crossover_lands_where_designed() {
        // 0.05: inc 1000×100 = 1e5 < full 950×900 = 8.55e5 → inc wins.
        // 0.15: inc 1100×300 = 3.3e5 < 8.55e5 → inc wins.
        // 0.3:  inc 1400×700 = 9.8e5 > 8.55e5 → full wins. The frontier
        // is reported in *observed* churn-fraction units: 2 × 0.3 = 0.6.
        let tables = regime_tables(&synthetic());
        let f = frontier(&tables[0]);
        assert_eq!(f.crossover, Some(0.6));
        assert_eq!(f.rates_compared, 3);
    }

    fn table_of(rows: &[GridRow], source: &str) -> PolicyTable {
        compute_policy_table(frontiers(&regime_tables(rows)), source)
    }

    #[test]
    fn frontier_is_deterministic() {
        let rows = synthetic();
        let a = table_of(&rows, "synthetic");
        let b = table_of(&rows, "synthetic");
        assert_eq!(a, b);
        assert_eq!(a.to_json().pretty(), b.to_json().pretty(), "byte-identical table artifact");
        // Row order must not matter either.
        let mut shuffled = rows.clone();
        shuffled.reverse();
        assert_eq!(table_of(&shuffled, "synthetic"), a);
    }

    #[test]
    fn incremental_sweeping_every_rate_yields_open_frontier() {
        let rows = vec![
            row("scenario1", 0.1, "incremental", 1, 1000.0, 100),
            row("scenario1", 0.1, "full", 1, 990.0, 900),
            row("scenario1", 0.3, "incremental", 1, 1050.0, 150),
            row("scenario1", 0.3, "full", 1, 990.0, 900),
        ];
        let f = frontier(&regime_tables(&rows)[0]);
        assert_eq!(f.crossover, None, "incremental won everywhere");
        assert_eq!(f.rates_compared, 2);
    }

    #[test]
    fn rates_missing_an_arm_are_skipped() {
        let rows = vec![
            row("scenario1", 0.1, "incremental", 1, 1000.0, 100),
            // 0.2 has only the full arm → no comparison there.
            row("scenario1", 0.2, "full", 1, 1.0, 1),
            row("scenario1", 0.3, "incremental", 1, 2000.0, 900),
            row("scenario1", 0.3, "full", 1, 900.0, 800),
        ];
        let f = frontier(&regime_tables(&rows)[0]);
        assert_eq!(f.rates_compared, 1);
        assert_eq!(f.crossover, Some(0.6), "observed fraction at the winning rate");
    }

    #[test]
    fn tables_without_both_arms_are_dropped_from_the_policy_table() {
        let rows = vec![
            row("scenario1", 0.1, "repair-only", 1, 1000.0, 100),
            row("scenario1", 0.1, "full", 1, 900.0, 900),
            row("s4-straggler-tail", 0.1, "incremental", 1, 1500.0, 100),
            row("s4-straggler-tail", 0.1, "full", 1, 900.0, 900),
        ];
        let t = table_of(&rows, "partial");
        assert_eq!(t.entries.len(), 1, "only s4 compared both arms");
        assert_eq!(t.entries[0].scenario, "s4-straggler-tail");
        assert_eq!(t.source, "partial");
    }

    #[test]
    fn helper_regimes_get_their_own_frontiers() {
        // The same family at two helper outage rates: the static regime
        // crosses over, the churned regime never does — two entries, each
        // tagged with its regime's rate.
        let mut rows = vec![
            row("scenario1", 0.1, "incremental", 1, 1000.0, 100),
            row("scenario1", 0.1, "full", 1, 900.0, 50),
        ];
        for base in [
            row("scenario1", 0.1, "incremental", 1, 1000.0, 100),
            row("scenario1", 0.1, "full", 1, 990.0, 900),
        ] {
            rows.push(GridRow { helper_down_rate: 0.2, ..base });
        }
        let t = table_of(&rows, "regimes");
        assert_eq!(t.entries.len(), 2);
        assert_eq!(t.entries[0].helper_down_rate, 0.0);
        assert!(t.entries[0].frontier_churn.is_some());
        assert_eq!(t.entries[1].helper_down_rate, 0.2);
        assert_eq!(t.entries[1].frontier_churn, None);
    }

    #[test]
    fn transport_regimes_get_their_own_frontiers() {
        // The same family under the dedicated transport and a shared
        // uplink pool: contention makes incremental's degradation
        // steeper, so the regimes can cross over differently — each
        // entry carries its capacity axis.
        let mut rows = vec![
            row("scenario1", 0.1, "incremental", 1, 1000.0, 100),
            row("scenario1", 0.1, "full", 1, 990.0, 900),
        ];
        for base in [
            row("scenario1", 0.1, "incremental", 1, 1500.0, 100),
            row("scenario1", 0.1, "full", 1, 990.0, 50),
        ] {
            rows.push(GridRow { uplink_capacity: 2.0, ..base });
        }
        let t = table_of(&rows, "transport");
        assert_eq!(t.entries.len(), 2);
        assert_eq!(t.entries[0].uplink_capacity, 0.0);
        assert_eq!(t.entries[0].frontier_churn, None, "dedicated regime: incremental wins");
        assert_eq!(t.entries[1].uplink_capacity, 2.0);
        assert!(t.entries[1].frontier_churn.is_some(), "contended regime crosses over");
    }

    #[test]
    fn ties_go_to_incremental() {
        // Strictly-lower is required: equal scores keep the cheap arm.
        let rows = vec![
            row("scenario1", 0.2, "incremental", 1, 900.0, 900),
            row("scenario1", 0.2, "full", 1, 900.0, 900),
        ];
        assert_eq!(frontier(&regime_tables(&rows)[0]).crossover, None);
    }
}
