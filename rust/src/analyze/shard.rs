//! `psl analyze --shard`: summarize a `psl-shard` artifact — where the
//! stitched solve sits relative to its bounds, per grid cell.

use crate::bench::artifact::{self, ArtifactKind};
use crate::util::json::Json;
use anyhow::{Context, Result};

/// One grid cell of a shard artifact, reduced to the numbers that answer
/// "what did sharding cost here?".
#[derive(Clone, Debug, PartialEq)]
pub struct ShardCellSummary {
    pub scenario: String,
    pub n_clients: usize,
    pub n_helpers: usize,
    pub n_shards: usize,
    pub migrations: usize,
    pub stitched_makespan_slots: usize,
    /// stitched / max per-shard lower bound.
    pub stitch_gap: f64,
    /// stitched / monolithic lower bound — an upper bound on what
    /// sharding can have cost vs. a perfect monolithic solve.
    pub monolithic_gap: f64,
    /// Max − min shard makespan, slots: the imbalance rebalancing works
    /// against.
    pub shard_spread_slots: usize,
    /// Methods the shards picked, deduplicated in first-seen order.
    pub methods: Vec<String>,
}

/// Parse the rows of a validated `psl-shard` document.
pub fn summaries_from_doc(doc: &Json) -> Result<Vec<ShardCellSummary>> {
    artifact::expect_kind(doc, ArtifactKind::Shard)?;
    let rows = doc.get("rows").as_arr().context("psl-shard artifact: missing \"rows\"")?;
    rows.iter().enumerate().map(|(k, row)| summary_of(row).with_context(|| format!("row {k}"))).collect()
}

fn summary_of(row: &Json) -> Result<ShardCellSummary> {
    let int = |key: &str| -> Result<usize> {
        row.get(key).as_usize().with_context(|| format!("bad {key:?}"))
    };
    let num = |key: &str| -> Result<f64> {
        row.get(key).as_f64().with_context(|| format!("bad {key:?}"))
    };
    let shards = row.get("shards").as_arr().context("bad \"shards\"")?;
    let mut methods: Vec<String> = Vec::new();
    let mut min_mk = usize::MAX;
    let mut max_mk = 0usize;
    for s in shards {
        let m = s.get("method").as_str().context("bad shard method")?.to_string();
        if !methods.contains(&m) {
            methods.push(m);
        }
        let mk = s.get("makespan_slots").as_usize().context("bad shard makespan")?;
        min_mk = min_mk.min(mk);
        max_mk = max_mk.max(mk);
    }
    let stitched = int("stitched_makespan_slots")?;
    let mono_lb = int("monolithic_lb_slots")?.max(1);
    Ok(ShardCellSummary {
        scenario: row.get("scenario").as_str().context("bad \"scenario\"")?.to_string(),
        n_clients: int("n_clients")?,
        n_helpers: int("n_helpers")?,
        n_shards: int("n_shards")?,
        migrations: int("migrations")?,
        stitched_makespan_slots: stitched,
        stitch_gap: num("stitch_gap")?,
        monolithic_gap: stitched as f64 / mono_lb as f64,
        shard_spread_slots: if shards.is_empty() { 0 } else { max_mk - min_mk },
        methods,
    })
}

/// Render the summaries as the table `psl analyze --shard` prints.
pub fn render_table(rows: &[ShardCellSummary]) -> String {
    let mut out = String::new();
    out.push_str(
        "scenario              JxI        shards  migr  stitched  stitch-gap  mono-gap  spread  methods\n",
    );
    for r in rows {
        out.push_str(&format!(
            "{:<20}  {:>5}x{:<3}  {:>6}  {:>4}  {:>8}  {:>10.3}  {:>8.3}  {:>6}  {}\n",
            r.scenario,
            r.n_clients,
            r.n_helpers,
            r.n_shards,
            r.migrations,
            r.stitched_makespan_slots,
            r.stitch_gap,
            r.monolithic_gap,
            r.shard_spread_slots,
            r.methods.join(","),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::Scenario;
    use crate::shard::grid::{self, ShardGridCfg};
    use crate::shard::ShardCfg;

    /// Summaries are pinned to the real producer's bytes, not a
    /// hand-written fixture.
    fn real_doc() -> Json {
        let cfg = ShardGridCfg {
            scenarios: vec![Scenario::S6MegaHomogeneous],
            model: Model::ResNet101,
            sizes: vec![(80, 4)],
            seed: 7,
            slot_ms: None,
            shard: ShardCfg { shard_clients: 20, ..ShardCfg::default() },
            threads: 2,
        };
        grid::rows_to_json(&grid::run(&cfg).unwrap())
    }

    #[test]
    fn summarizes_producer_rows() {
        let rows = summaries_from_doc(&real_doc()).unwrap();
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        assert_eq!(r.scenario, "s6-mega-homogeneous");
        assert_eq!(r.n_shards, 4);
        assert!(r.stitch_gap >= 1.0);
        assert!(r.monolithic_gap >= 1.0);
        assert!(!r.methods.is_empty());
        let table = render_table(&rows);
        assert!(table.contains("s6-mega-homogeneous"), "{table}");
    }

    #[test]
    fn rejects_wrong_kind() {
        let doc = artifact::envelope(artifact::ArtifactKind::Sweep, vec![("rows", Json::Arr(vec![]))]);
        assert!(summaries_from_doc(&doc).is_err());
    }
}
