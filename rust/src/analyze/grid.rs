//! Typed loading and aggregation of `psl fleet --grid` artifacts.
//!
//! The grid runner writes one summary row per (scenario, churn rate,
//! helper outage rate, policy, seed) cell; this module parses those rows
//! back into a typed form through the artifact registry and collapses
//! them into per-(family × fleet size × helper outage rate) **regime
//! tables**: one aggregate per (churn rate, policy) with seeds averaged
//! out, scored by the work-discounted makespan the frontier computation
//! compares.

use crate::bench::artifact::{self, ArtifactKind};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// One fleet-grid row, parsed back from the artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct GridRow {
    pub scenario: String,
    pub model: String,
    pub n_clients: usize,
    pub n_helpers: usize,
    pub churn_rate: f64,
    /// Effective per-round helper outage probability the cell ran (v5's
    /// helper-churn grid axis; 0.0 = a static helper pool).
    pub helper_down_rate: f64,
    /// Shared-uplink pool capacity the cell ran (v7's transport grid
    /// axis; 0.0 = the dedicated transport).
    pub uplink_capacity: f64,
    pub policy: String,
    pub seed: String,
    pub rounds: usize,
    pub full_rounds: usize,
    pub repair_rounds: usize,
    pub empty_rounds: usize,
    pub mean_makespan_ms: f64,
    pub mean_period_ms: f64,
    /// Mean *observed* membership-churn fraction of the cell's rounds —
    /// the unit the frontier (and the `auto` policy's per-round
    /// comparison) is measured in, ≈ 2× the stationary `churn_rate` axis.
    pub mean_churn_frac: f64,
    pub total_work_units: u64,
}

/// Parse a fleet-grid document's rows. Validates the registry envelope
/// and every field each row needs downstream.
pub fn rows_from_doc(doc: &Json) -> Result<Vec<GridRow>> {
    artifact::expect_kind(doc, ArtifactKind::FleetGrid)?;
    let rows = doc.get("rows").as_arr().context("fleet-grid artifact missing rows[]")?;
    let mut out = Vec::with_capacity(rows.len());
    for (k, r) in rows.iter().enumerate() {
        let str_field = |name: &str| -> Result<String> {
            r.get(name).as_str().map(str::to_string).with_context(|| format!("row {k}: missing/bad {name}"))
        };
        let num = |name: &str| -> Result<f64> {
            r.get(name).as_f64().with_context(|| format!("row {k}: missing/bad {name}"))
        };
        let count = |name: &str| -> Result<usize> {
            r.get(name).as_usize().with_context(|| format!("row {k}: missing/bad {name}"))
        };
        let churn_rate = num("churn_rate")?;
        anyhow::ensure!(
            churn_rate.is_finite() && (0.0..=1.0).contains(&churn_rate),
            "row {k}: churn_rate {churn_rate} outside [0, 1]"
        );
        let mean_makespan_ms = num("mean_makespan_ms")?;
        let mean_period_ms = num("mean_period_ms")?;
        // Absent (not just malformed) means a pre-v2 artifact: say so,
        // rather than surfacing a generic field error.
        let mean_churn_frac = match r.get("mean_churn_frac") {
            Json::Null => anyhow::bail!(
                "row {k}: no mean_churn_frac — this fleet-grid artifact predates schema v{} \
                 (re-run `psl fleet --grid` with this build)",
                artifact::SCHEMA_VERSION
            ),
            v => v.as_f64().with_context(|| format!("row {k}: bad mean_churn_frac {v}"))?,
        };
        // A NaN here would poison every score comparison downstream and
        // read as "incremental wins everywhere" — reject it loudly.
        for (name, v) in [
            ("mean_makespan_ms", mean_makespan_ms),
            ("mean_period_ms", mean_period_ms),
            ("mean_churn_frac", mean_churn_frac),
        ] {
            anyhow::ensure!(v.is_finite() && v >= 0.0, "row {k}: non-finite/negative {name} {v}");
        }
        // Absent = a pre-v5 artifact (no helper-churn axis): say so.
        let helper_down_rate = match r.get("helper_down_rate") {
            Json::Null => anyhow::bail!(
                "row {k}: no helper_down_rate — this fleet-grid artifact predates schema v{} \
                 (re-run `psl fleet --grid` with this build)",
                artifact::SCHEMA_VERSION
            ),
            v => v.as_f64().with_context(|| format!("row {k}: bad helper_down_rate {v}"))?,
        };
        anyhow::ensure!(
            helper_down_rate.is_finite() && (0.0..=1.0).contains(&helper_down_rate),
            "row {k}: helper_down_rate {helper_down_rate} outside [0, 1]"
        );
        // Absent = a pre-v7 artifact (no transport axis): say so.
        let uplink_capacity = match r.get("uplink_capacity") {
            Json::Null => anyhow::bail!(
                "row {k}: no uplink_capacity — this fleet-grid artifact predates schema v{} \
                 (re-run `psl fleet --grid` with this build)",
                artifact::SCHEMA_VERSION
            ),
            v => v.as_f64().with_context(|| format!("row {k}: bad uplink_capacity {v}"))?,
        };
        anyhow::ensure!(
            uplink_capacity.is_finite() && uplink_capacity >= 0.0,
            "row {k}: uplink_capacity {uplink_capacity} must be finite and >= 0"
        );
        let work = str_field("total_work_units")?;
        out.push(GridRow {
            scenario: str_field("scenario")?,
            model: str_field("model")?,
            n_clients: count("n_clients")?,
            n_helpers: count("n_helpers")?,
            churn_rate,
            helper_down_rate,
            uplink_capacity,
            policy: str_field("policy")?,
            seed: str_field("seed")?,
            rounds: count("rounds")?,
            full_rounds: count("full_rounds")?,
            repair_rounds: count("repair_rounds")?,
            empty_rounds: count("empty_rounds")?,
            mean_makespan_ms,
            mean_period_ms,
            mean_churn_frac,
            total_work_units: work.parse().with_context(|| format!("row {k}: bad total_work_units {work:?}"))?,
        });
    }
    Ok(out)
}

/// One aggregated (churn rate, policy) arm of a regime table.
#[derive(Clone, Debug, PartialEq)]
pub struct RegimeCell {
    pub churn_rate: f64,
    pub policy: String,
    /// Seeds averaged into this cell.
    pub seeds: usize,
    /// Seed-averaged *observed* churn fraction — the frontier's unit.
    pub mean_churn_frac: f64,
    pub mean_makespan_ms: f64,
    pub mean_work_units: f64,
    /// Work-discounted makespan: `mean_makespan_ms × max(mean_work, 1)`.
    /// Lower is better — a policy only wins a regime if whatever makespan
    /// it buys justifies the solve effort it spends, which is exactly the
    /// §VII trade the frontier encodes. All-empty runs (work 0) clamp to
    /// the makespan alone instead of collapsing the score to zero.
    pub score: f64,
}

/// All measured (churn rate, policy) arms for one scenario family at one
/// fleet size and helper outage rate, in ascending (churn rate, policy)
/// order.
#[derive(Clone, Debug, PartialEq)]
pub struct RegimeTable {
    pub scenario: String,
    pub n_clients: usize,
    pub n_helpers: usize,
    /// Helper outage rate shared by every cell in this table (the v5
    /// grouping axis — frontiers are measured per outage regime).
    pub helper_down_rate: f64,
    /// Uplink pool capacity shared by every cell in this table (the v7
    /// grouping axis; 0.0 = dedicated — frontiers are measured per
    /// transport regime).
    pub uplink_capacity: f64,
    pub cells: Vec<RegimeCell>,
}

impl RegimeTable {
    /// The table's churn rates, ascending and deduplicated.
    pub fn churn_rates(&self) -> Vec<f64> {
        let mut rates: Vec<f64> = self.cells.iter().map(|c| c.churn_rate).collect();
        rates.sort_by(|a, b| a.partial_cmp(b).unwrap());
        rates.dedup();
        rates
    }

    /// The aggregated arm for (churn rate, policy), if measured.
    pub fn cell(&self, churn_rate: f64, policy: &str) -> Option<&RegimeCell> {
        self.cells.iter().find(|c| c.churn_rate == churn_rate && c.policy == policy)
    }
}

/// Collapse grid rows into regime tables: group by (scenario, J, I,
/// helper outage rate, uplink capacity), then average seeds within each
/// (churn rate, policy) arm. Ordering is fully deterministic (BTreeMap
/// on bit-exact rate keys), so the same artifact always yields the same
/// tables.
pub fn regime_tables(rows: &[GridRow]) -> Vec<RegimeTable> {
    // Churn/outage/capacity values come verbatim from one artifact, so
    // bit-exact f64 keys group correctly (no arithmetic touches them
    // between rows; they are non-negative, so bit order is value order).
    let mut groups: BTreeMap<(String, usize, usize, u64, u64), BTreeMap<(u64, String), Vec<&GridRow>>> =
        BTreeMap::new();
    for r in rows {
        groups
            .entry((
                r.scenario.clone(),
                r.n_clients,
                r.n_helpers,
                r.helper_down_rate.to_bits(),
                r.uplink_capacity.to_bits(),
            ))
            .or_default()
            .entry((r.churn_rate.to_bits(), r.policy.clone()))
            .or_default()
            .push(r);
    }
    groups
        .into_iter()
        .map(|((scenario, n_clients, n_helpers, helper_bits, cap_bits), arms)| {
            let cells = arms
                .into_iter()
                .map(|((churn_bits, policy), members)| {
                    let n = members.len() as f64;
                    let mean_makespan_ms = members.iter().map(|m| m.mean_makespan_ms).sum::<f64>() / n;
                    let mean_work_units = members.iter().map(|m| m.total_work_units as f64).sum::<f64>() / n;
                    let mean_churn_frac = members.iter().map(|m| m.mean_churn_frac).sum::<f64>() / n;
                    RegimeCell {
                        churn_rate: f64::from_bits(churn_bits),
                        policy,
                        seeds: members.len(),
                        mean_churn_frac,
                        mean_makespan_ms,
                        mean_work_units,
                        score: mean_makespan_ms * mean_work_units.max(1.0),
                    }
                })
                .collect();
            RegimeTable {
                scenario,
                n_clients,
                n_helpers,
                helper_down_rate: f64::from_bits(helper_bits),
                uplink_capacity: f64::from_bits(cap_bits),
                cells,
            }
        })
        .collect()
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// Shared across analyze test modules: a hand-built grid row. The
    /// observed churn fraction follows the stationary mapping (≈ 2× the
    /// rate axis), like the real grid runner produces.
    pub(crate) fn row(scenario: &str, churn: f64, policy: &str, seed: u64, makespan: f64, work: u64) -> GridRow {
        GridRow {
            scenario: scenario.to_string(),
            model: "resnet101".to_string(),
            n_clients: 10,
            n_helpers: 2,
            churn_rate: churn,
            helper_down_rate: 0.0,
            uplink_capacity: 0.0,
            policy: policy.to_string(),
            seed: seed.to_string(),
            rounds: 8,
            full_rounds: if policy == "full" { 8 } else { 1 },
            repair_rounds: if policy == "full" { 0 } else { 7 },
            empty_rounds: 0,
            mean_makespan_ms: makespan,
            mean_period_ms: makespan * 0.8,
            mean_churn_frac: churn * 2.0,
            total_work_units: work,
        }
    }

    #[test]
    fn aggregation_averages_seeds() {
        let rows = vec![
            row("scenario1", 0.1, "incremental", 1, 1000.0, 100),
            row("scenario1", 0.1, "incremental", 2, 1200.0, 300),
            row("scenario1", 0.1, "full", 1, 900.0, 1000),
        ];
        let tables = regime_tables(&rows);
        assert_eq!(tables.len(), 1);
        let t = &tables[0];
        assert_eq!((t.scenario.as_str(), t.n_clients, t.n_helpers), ("scenario1", 10, 2));
        let inc = t.cell(0.1, "incremental").unwrap();
        assert_eq!(inc.seeds, 2);
        assert!((inc.mean_makespan_ms - 1100.0).abs() < 1e-9);
        assert!((inc.mean_work_units - 200.0).abs() < 1e-9);
        assert!((inc.mean_churn_frac - 0.2).abs() < 1e-9, "observed fraction averaged");
        assert!((inc.score - 1100.0 * 200.0).abs() < 1e-6);
        assert_eq!(t.cell(0.1, "full").unwrap().seeds, 1);
        assert!(t.cell(0.2, "incremental").is_none());
    }

    #[test]
    fn zero_work_clamps_score_to_makespan() {
        let tables = regime_tables(&[row("scenario1", 0.1, "incremental", 1, 500.0, 0)]);
        assert!((tables[0].cells[0].score - 500.0).abs() < 1e-9);
    }

    #[test]
    fn tables_split_by_family_size_and_helper_rate() {
        let mut rows = vec![row("scenario1", 0.1, "full", 1, 900.0, 10), row("s4-straggler-tail", 0.1, "full", 1, 900.0, 10)];
        rows.push(GridRow { n_clients: 20, ..rows[0].clone() });
        rows.push(GridRow { helper_down_rate: 0.2, ..rows[0].clone() });
        rows.push(GridRow { uplink_capacity: 2.0, ..rows[0].clone() });
        let tables = regime_tables(&rows);
        assert_eq!(tables.len(), 5);
        // BTreeMap order: s4 sorts after scenario1; sizes ascend within a
        // family, helper outage rates ascend within a size, uplink
        // capacities within an outage rate.
        assert_eq!((tables[0].n_clients, tables[0].helper_down_rate, tables[0].uplink_capacity), (10, 0.0, 0.0));
        assert_eq!((tables[1].n_clients, tables[1].helper_down_rate, tables[1].uplink_capacity), (10, 0.0, 2.0));
        assert_eq!((tables[2].n_clients, tables[2].helper_down_rate), (10, 0.2));
        assert_eq!(tables[3].n_clients, 20);
        assert_eq!(tables[4].scenario, "s4-straggler-tail");
    }

    #[test]
    fn churn_rates_sorted_and_deduped() {
        let rows = vec![
            row("scenario1", 0.3, "full", 1, 1.0, 1),
            row("scenario1", 0.1, "full", 1, 1.0, 1),
            row("scenario1", 0.1, "incremental", 1, 1.0, 1),
        ];
        assert_eq!(regime_tables(&rows)[0].churn_rates(), vec![0.1, 0.3]);
    }

    #[test]
    fn roundtrip_through_real_grid_artifact() {
        // The registry writer and this reader must agree field-for-field.
        let cfg = crate::bench::fleet::FleetGridCfg {
            scenarios: vec![crate::instance::scenario::Scenario::S1],
            model: crate::instance::profiles::Model::Vgg19,
            size: (4, 2),
            churn_rates: vec![0.2],
            helper_down_rates: vec![0.0],
            uplink_capacities: vec![0.0],
            policies: vec![crate::fleet::Policy::Incremental],
            seeds: vec![3],
            rounds: 3,
            slot_ms: Some(550.0),
            policy_table: None,
            threads: 1,
        };
        let grid_rows = crate::bench::fleet::run(&cfg);
        let doc = crate::bench::fleet::rows_to_json(&grid_rows);
        let parsed = rows_from_doc(&Json::parse(&doc.pretty()).unwrap()).unwrap();
        assert_eq!(parsed.len(), 1);
        assert_eq!(parsed[0].scenario, "scenario1");
        assert_eq!(parsed[0].rounds, 3);
        assert_eq!(parsed[0].total_work_units, grid_rows[0].total_work_units);
        assert!((parsed[0].mean_makespan_ms - grid_rows[0].mean_makespan_ms).abs() < 1e-9);
        assert_eq!(parsed[0].mean_churn_frac, grid_rows[0].mean_churn_frac, "observed churn roundtrips");
        assert_eq!(parsed[0].helper_down_rate, 0.0, "static pool rows carry the zero axis");
    }

    #[test]
    fn rejects_non_finite_metrics() {
        let mut bad = row("scenario1", 0.1, "incremental", 1, 1000.0, 100);
        bad.mean_makespan_ms = f64::NAN;
        // Rebuild the artifact shape by hand around the poisoned row.
        let doc = crate::bench::artifact::envelope(ArtifactKind::FleetGrid, vec![(
            "rows",
            Json::Arr(vec![Json::obj(vec![
                ("scenario", Json::Str(bad.scenario.clone())),
                ("model", Json::Str(bad.model.clone())),
                ("n_clients", Json::Num(bad.n_clients as f64)),
                ("n_helpers", Json::Num(bad.n_helpers as f64)),
                ("churn_rate", Json::Num(bad.churn_rate)),
                ("helper_down_rate", Json::Num(bad.helper_down_rate)),
                ("policy", Json::Str(bad.policy.clone())),
                ("seed", Json::Str(bad.seed.clone())),
                ("rounds", Json::Num(bad.rounds as f64)),
                ("full_rounds", Json::Num(bad.full_rounds as f64)),
                ("repair_rounds", Json::Num(bad.repair_rounds as f64)),
                ("empty_rounds", Json::Num(bad.empty_rounds as f64)),
                ("mean_makespan_ms", Json::Num(bad.mean_makespan_ms)),
                ("mean_period_ms", Json::Num(bad.mean_period_ms)),
                ("mean_churn_frac", Json::Num(bad.mean_churn_frac)),
                ("total_work_units", Json::Str(bad.total_work_units.to_string())),
            ])]),
        )]);
        let err = rows_from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("mean_makespan_ms"), "{err}");
    }

    #[test]
    fn pre_v2_artifact_gets_a_regenerate_error() {
        // A v1 fleet-grid row (no mean_churn_frac) must fail with a
        // message naming the schema change, not a generic field error.
        let doc = crate::bench::artifact::envelope(ArtifactKind::FleetGrid, vec![(
            "rows",
            Json::Arr(vec![Json::obj(vec![
                ("scenario", Json::Str("scenario1".into())),
                ("model", Json::Str("resnet101".into())),
                ("n_clients", Json::Num(10.0)),
                ("n_helpers", Json::Num(2.0)),
                ("churn_rate", Json::Num(0.1)),
                ("policy", Json::Str("incremental".into())),
                ("seed", Json::Str("1".into())),
                ("rounds", Json::Num(8.0)),
                ("full_rounds", Json::Num(1.0)),
                ("repair_rounds", Json::Num(7.0)),
                ("empty_rounds", Json::Num(0.0)),
                ("mean_makespan_ms", Json::Num(1000.0)),
                ("mean_period_ms", Json::Num(800.0)),
                ("total_work_units", Json::Str("100".into())),
            ])]),
        )]);
        let err = rows_from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("predates schema"), "{err}");
    }

    #[test]
    fn pre_v5_artifact_gets_a_regenerate_error() {
        // A v4 fleet-grid row (mean_churn_frac present, no
        // helper_down_rate) must name the missing helper axis.
        let doc = crate::bench::artifact::envelope(ArtifactKind::FleetGrid, vec![(
            "rows",
            Json::Arr(vec![Json::obj(vec![
                ("scenario", Json::Str("scenario1".into())),
                ("model", Json::Str("resnet101".into())),
                ("n_clients", Json::Num(10.0)),
                ("n_helpers", Json::Num(2.0)),
                ("churn_rate", Json::Num(0.1)),
                ("policy", Json::Str("incremental".into())),
                ("seed", Json::Str("1".into())),
                ("rounds", Json::Num(8.0)),
                ("full_rounds", Json::Num(1.0)),
                ("repair_rounds", Json::Num(7.0)),
                ("empty_rounds", Json::Num(0.0)),
                ("mean_makespan_ms", Json::Num(1000.0)),
                ("mean_period_ms", Json::Num(800.0)),
                ("mean_churn_frac", Json::Num(0.2)),
                ("total_work_units", Json::Str("100".into())),
            ])]),
        )]);
        let err = rows_from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("helper_down_rate"), "{err}");
        assert!(err.contains("predates schema"), "{err}");
    }

    #[test]
    fn pre_v7_artifact_gets_a_regenerate_error() {
        // A v6 fleet-grid row (helper_down_rate present, no
        // uplink_capacity) must name the missing transport axis.
        let doc = crate::bench::artifact::envelope(ArtifactKind::FleetGrid, vec![(
            "rows",
            Json::Arr(vec![Json::obj(vec![
                ("scenario", Json::Str("scenario1".into())),
                ("model", Json::Str("resnet101".into())),
                ("n_clients", Json::Num(10.0)),
                ("n_helpers", Json::Num(2.0)),
                ("churn_rate", Json::Num(0.1)),
                ("helper_down_rate", Json::Num(0.0)),
                ("policy", Json::Str("incremental".into())),
                ("seed", Json::Str("1".into())),
                ("rounds", Json::Num(8.0)),
                ("full_rounds", Json::Num(1.0)),
                ("repair_rounds", Json::Num(7.0)),
                ("empty_rounds", Json::Num(0.0)),
                ("mean_makespan_ms", Json::Num(1000.0)),
                ("mean_period_ms", Json::Num(800.0)),
                ("mean_churn_frac", Json::Num(0.2)),
                ("total_work_units", Json::Str("100".into())),
            ])]),
        )]);
        let err = rows_from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("uplink_capacity"), "{err}");
        assert!(err.contains("predates schema"), "{err}");
    }

    #[test]
    fn rejects_wrong_kind_and_bad_rows() {
        let sweep = crate::bench::artifact::envelope(ArtifactKind::Sweep, vec![("rows", Json::Arr(vec![]))]);
        assert!(rows_from_doc(&sweep).is_err());
        let bad = crate::bench::artifact::envelope(ArtifactKind::FleetGrid, vec![(
            "rows",
            Json::Arr(vec![Json::obj(vec![("scenario", Json::Str("s".into()))])]),
        )]);
        assert!(rows_from_doc(&bad).is_err());
    }
}
