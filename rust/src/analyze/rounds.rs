//! Typed loading and summarization of the fleet `.rounds.jsonl` sidecar
//! (`psl analyze --rounds <file>`).
//!
//! `psl fleet` streams one JSON line per finished round, each line equal
//! to the corresponding `rounds_detail` entry of the final report — so a
//! run interrupted mid-horizon still leaves a usable trace. This module
//! parses that stream back into typed rows and collapses it into a
//! per-decision summary: how often each decision fired (`repair`,
//! `full-auto`, `full-gap`, …), at what observed churn, and what it cost
//! — the quickest way to audit what a long-horizon orchestrator run
//! actually did.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// One streamed round, parsed back from its JSONL line.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundRow {
    pub round: usize,
    pub n_clients: usize,
    pub decision: String,
    pub method: Option<String>,
    pub makespan_ms: f64,
    pub churn_frac: f64,
    pub period_ms: f64,
    pub work_units: u64,
    /// Clients orphaned by helper outages this round (0 on pre-v5 lines,
    /// which carried no helper dynamics).
    pub orphaned_clients: usize,
    /// Whether part of the helper pool was down this round (false on
    /// pre-v5 lines).
    pub degraded: bool,
    /// Shared-uplink contention signal (0.0 on dedicated-transport and
    /// pre-v7 lines, which omit the key).
    pub contention: f64,
    /// Arrival-placement source of a kept repair (`"admm-y"` when the
    /// ADMM warm start placed the arrivals; None on FCFS repairs,
    /// non-repair rounds, and pre-v7 lines).
    pub repair_source: Option<String>,
}

/// Parse a `.rounds.jsonl` stream (blank lines ignored). Errors name the
/// offending 1-based line.
pub fn rows_from_jsonl(text: &str) -> Result<Vec<RoundRow>> {
    let mut out = Vec::new();
    for (idx, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let n = idx + 1;
        let doc = Json::parse(line).with_context(|| format!("line {n}: not valid JSON"))?;
        let num = |name: &str| -> Result<f64> {
            let v = doc.get(name).as_f64().with_context(|| format!("line {n}: missing/bad {name}"))?;
            anyhow::ensure!(v.is_finite() && v >= 0.0, "line {n}: non-finite/negative {name} {v}");
            Ok(v)
        };
        let work = doc
            .get("work_units")
            .as_str()
            .with_context(|| format!("line {n}: missing/bad work_units"))?;
        out.push(RoundRow {
            round: doc.get("round").as_usize().with_context(|| format!("line {n}: missing/bad round"))?,
            n_clients: doc.get("n_clients").as_usize().with_context(|| format!("line {n}: missing/bad n_clients"))?,
            decision: doc
                .get("decision")
                .as_str()
                .with_context(|| format!("line {n}: missing/bad decision"))?
                .to_string(),
            method: doc.get("method").as_str().map(str::to_string),
            makespan_ms: num("makespan_ms")?,
            churn_frac: num("churn_frac")?,
            period_ms: num("period_ms")?,
            work_units: work.parse().with_context(|| format!("line {n}: bad work_units {work:?}"))?,
            // Absent on pre-v5 sidecars: default rather than reject — a
            // bare stream has no schema envelope to version-gate on.
            orphaned_clients: doc.get("orphaned_clients").as_usize().unwrap_or(0),
            degraded: matches!(doc.get("degraded"), Json::Bool(true)),
            // Absent on dedicated-transport (and pre-v7) lines: the
            // producer emits these keys only when non-default.
            contention: doc.get("contention").as_f64().unwrap_or(0.0),
            repair_source: doc.get("repair_source").as_str().map(str::to_string),
        });
    }
    Ok(out)
}

/// Aggregate view of every round that reached one decision.
#[derive(Clone, Debug, PartialEq)]
pub struct DecisionSummary {
    pub decision: String,
    pub rounds: usize,
    pub mean_churn_frac: f64,
    pub mean_makespan_ms: f64,
    pub mean_period_ms: f64,
    pub total_work_units: u64,
    /// Rounds of this decision that ran on a degraded helper pool.
    pub degraded_rounds: usize,
    /// Total clients this decision re-homed after helper outages.
    pub orphaned_clients: usize,
    /// Rounds of this decision whose kept repair placed arrivals with
    /// the ADMM y-assignment warm start (`repair_source == "admm-y"`).
    pub admm_y_repairs: usize,
    /// Mean shared-uplink contention signal over this decision's rounds
    /// (0.0 for dedicated-transport streams).
    pub mean_contention: f64,
}

/// Collapse rows into per-decision summaries, in decision-name order
/// (BTreeMap — deterministic for the same stream).
pub fn summarize(rows: &[RoundRow]) -> Vec<DecisionSummary> {
    let mut groups: BTreeMap<&str, Vec<&RoundRow>> = BTreeMap::new();
    for r in rows {
        groups.entry(&r.decision).or_default().push(r);
    }
    groups
        .into_iter()
        .map(|(decision, members)| {
            let n = members.len() as f64;
            DecisionSummary {
                decision: decision.to_string(),
                rounds: members.len(),
                mean_churn_frac: members.iter().map(|m| m.churn_frac).sum::<f64>() / n,
                mean_makespan_ms: members.iter().map(|m| m.makespan_ms).sum::<f64>() / n,
                mean_period_ms: members.iter().map(|m| m.period_ms).sum::<f64>() / n,
                total_work_units: members.iter().map(|m| m.work_units).sum(),
                degraded_rounds: members.iter().filter(|m| m.degraded).count(),
                orphaned_clients: members.iter().map(|m| m.orphaned_clients).sum(),
                admm_y_repairs: members
                    .iter()
                    .filter(|m| m.repair_source.as_deref() == Some("admm-y"))
                    .count(),
                mean_contention: members.iter().map(|m| m.contention).sum::<f64>() / n,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::report::RoundReport;

    /// Build lines through the real producer so the reader is pinned to
    /// the exact shape `psl fleet` streams.
    fn line(round: usize, decision: &'static str, churn: f64, makespan: f64, work: u64) -> String {
        RoundReport {
            round,
            n_clients: if decision == "empty" { 0 } else { 5 },
            arrivals: 1,
            departures: 0,
            decision,
            method: if decision.starts_with("full") { Some("admm") } else { None },
            makespan_slots: (makespan / 100.0) as u32,
            makespan_ms: makespan,
            lower_bound: 2,
            churn_frac: churn,
            repair_moves: 0,
            placed_arrivals: 1,
            work_units: work,
            period_ms: makespan * 0.8,
            preemptions: 0,
            heterogeneity: 0.3,
            placement_flexibility: 1.0,
            tail_ratio: 1.1,
            helpers_live: 2,
            orphaned_clients: if decision == "helper-degraded" { 1 } else { 0 },
            migrations: if decision == "helper-degraded" { 1 } else { 0 },
            degraded: decision.starts_with("helper"),
            contention: 0.0,
            repair_source: None,
        }
        .jsonl_line()
    }

    #[test]
    fn parses_producer_lines_and_summarizes_by_decision() {
        let text = [
            line(0, "full-initial", 0.0, 1000.0, 500),
            String::new(), // blank lines tolerated (trailing newline etc.)
            line(1, "repair", 0.2, 1100.0, 30),
            line(2, "repair", 0.4, 1200.0, 40),
            line(3, "full-auto", 0.6, 950.0, 480),
            line(4, "helper-degraded", 0.0, 1300.0, 60),
        ]
        .join("\n");
        let rows = rows_from_jsonl(&text).unwrap();
        assert_eq!(rows.len(), 5);
        assert_eq!(rows[1].decision, "repair");
        assert_eq!(rows[1].method, None);
        assert_eq!(rows[3].work_units, 480);
        assert_eq!(rows[4].orphaned_clients, 1);
        assert!(rows[4].degraded);
        let summary = summarize(&rows);
        // BTreeMap order: full-auto, full-initial, helper-degraded, repair.
        assert_eq!(summary.len(), 4);
        assert_eq!(summary[0].decision, "full-auto");
        assert_eq!(summary[2].decision, "helper-degraded");
        assert_eq!(summary[2].degraded_rounds, 1);
        assert_eq!(summary[2].orphaned_clients, 1);
        assert_eq!(summary[3].decision, "repair");
        assert_eq!(summary[3].rounds, 2);
        assert_eq!(summary[3].degraded_rounds, 0);
        assert!((summary[3].mean_churn_frac - 0.3).abs() < 1e-9);
        assert!((summary[3].mean_makespan_ms - 1150.0).abs() < 1e-9);
        assert_eq!(summary[3].total_work_units, 70);
    }

    #[test]
    fn repair_source_and_contention_summarize_per_decision() {
        // Forge a shared-transport stream through the real producer:
        // two admm-y repairs, one FCFS repair, contention on every line.
        let mk = |round: usize, src: Option<&'static str>, contention: f64| {
            let doc = Json::parse(&line(round, "repair", 0.2, 1000.0, 20)).unwrap();
            let mut obj = match doc {
                Json::Obj(o) => o,
                _ => unreachable!(),
            };
            if let Some(s) = src {
                obj.insert("repair_source".into(), Json::Str(s.into()));
            }
            if contention > 0.0 {
                obj.insert("contention".into(), Json::Num(contention));
            }
            Json::Obj(obj).dump()
        };
        let text = [
            mk(0, Some("admm-y"), 0.5),
            mk(1, None, 0.25),
            mk(2, Some("admm-y"), 0.75),
        ]
        .join("\n");
        let rows = rows_from_jsonl(&text).unwrap();
        assert_eq!(rows[0].repair_source.as_deref(), Some("admm-y"));
        assert_eq!(rows[1].repair_source, None);
        assert_eq!(rows[1].contention, 0.25);
        let summary = summarize(&rows);
        assert_eq!(summary.len(), 1);
        assert_eq!(summary[0].admm_y_repairs, 2);
        assert!((summary[0].mean_contention - 0.5).abs() < 1e-9);
        // Dedicated streams (no keys) default cleanly.
        let plain = rows_from_jsonl(&line(0, "repair", 0.1, 500.0, 10)).unwrap();
        assert_eq!(plain[0].contention, 0.0);
        assert_eq!(plain[0].repair_source, None);
        assert_eq!(summarize(&plain)[0].admm_y_repairs, 0);
    }

    #[test]
    fn rejects_malformed_lines_with_line_numbers() {
        let good = line(0, "repair", 0.1, 500.0, 10);
        let err = rows_from_jsonl(&format!("{good}\nnot json")).unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        let missing = rows_from_jsonl("{\"round\": 1}").unwrap_err().to_string();
        assert!(missing.contains("line 1"), "{missing}");
    }
}
