//! Artifact analytics (`psl analyze`): the consumer side of everything
//! the runners persist under `target/psl-bench/`.
//!
//! The paper's §VII builds its solution *strategy* empirically — run the
//! methods over measured scenarios, record where each wins, encode the
//! boundary as a rule. This subsystem does the same with the repo's own
//! artifacts: load any registry kind ([`crate::bench::artifact`]),
//! aggregate fleet-grid cells into per-family **regime tables**
//! ([`grid`]), find the churn-rate **policy frontier** where full
//! re-solving overtakes incremental repair and serialize it as the
//! [`PolicyTable`](crate::fleet::policy::PolicyTable) the fleet `auto`
//! policy consults ([`frontier`]), diff perf-trajectory points across
//! PRs ([`perfdiff`]), summarize a fleet run's streamed
//! `.rounds.jsonl` sidecar per decision ([`rounds`]), reduce
//! `psl-shard` artifacts to per-cell stitching costs ([`shard`]), and
//! reduce `psl-trace` captures to per-phase duration + counter tables
//! ([`trace`]).
//!
//! | Module | Role |
//! |---|---|
//! | [`grid`] | typed fleet-grid rows, per-(family × size) regime tables |
//! | [`frontier`] | churn-rate crossover scan → `PolicyTable` |
//! | [`perfdiff`] | `--perf-diff` gate on solve/check/replay timings + solver counters |
//! | [`rounds`] | `--rounds` per-decision summary of `.rounds.jsonl` sidecars |
//! | [`shard`] | `--shard` stitch-gap / migration summary of `psl-shard` artifacts |
//! | [`trace`] | `--trace` per-phase duration + counter summary of `psl-trace` captures |
//!
//! Everything is deterministic: the same artifact bytes always produce
//! the same tables, frontiers and `PolicyTable` bytes, so analysis
//! outputs are themselves diffable artifacts.

pub mod frontier;
pub mod grid;
pub mod perfdiff;
pub mod rounds;
pub mod shard;
pub mod trace;

pub use frontier::{compute_policy_table, frontiers, Frontier};
pub use grid::{regime_tables, rows_from_doc, GridRow, RegimeCell, RegimeTable};
pub use perfdiff::{CounterRegression, PerfDiffReport, PerfRegression};
pub use rounds::{summarize, DecisionSummary, RoundRow};
pub use shard::{summaries_from_doc, ShardCellSummary};
pub use trace::{summarize_doc, summarize_file, PhaseSummary, TraceSummary};
