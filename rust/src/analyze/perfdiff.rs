//! Perf-trajectory diffs (`psl analyze --perf-diff old.json new.json`):
//! compare two `psl perf` artifacts cell-by-cell and fail on hot-path
//! slowdowns, mirroring `sweep --diff` for timings.
//!
//! Only the product phases — `solve`, `check`, `replay` — gate: the
//! `check-dense`/`replay-dense` rows are the frozen pre-refactor
//! reference and their drift is not a product regression (they still
//! show up in `only_*` counts when the grid shape moves). The compared
//! statistic is `min_s`, the standard low-noise benchmark statistic —
//! means absorb scheduler jitter that would flap CI.
//!
//! Since schema v6 the solve rows also carry deterministic solver
//! counters ([`GATED_COUNTERS`]: `exact_nodes`, `admm_iters`); those
//! diff exactly (no timing noise), so a blow-up in search effort —
//! pruning broken, convergence lost — fails the gate even when
//! wall-clock on the CI runner happens to absorb it. Counter gating
//! skips silently when either artifact predates v6 or the old value is
//! zero (a routing change, not an efficiency regression).

use crate::bench::artifact::{self, ArtifactKind};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// Phases whose slowdown fails the diff.
pub const GATED_PHASES: [&str; 3] = ["solve", "check", "replay"];

/// Deterministic solver-counter columns (schema v6) gated on `solve`
/// rows: search-effort blow-ups fail the diff exactly, without timing
/// noise.
pub const GATED_COUNTERS: [&str; 2] = ["exact_nodes", "admm_iters"];

/// One per-cell timing regression.
#[derive(Clone, Debug, PartialEq)]
pub struct PerfRegression {
    /// Human-readable cell key (scenario/model/JxI/seed/slot/phase).
    pub cell: String,
    pub old_s: f64,
    pub new_s: f64,
}

/// One per-cell solver-counter regression (search effort grew beyond
/// tolerance on a solve row).
#[derive(Clone, Debug, PartialEq)]
pub struct CounterRegression {
    pub cell: String,
    /// Which [`GATED_COUNTERS`] column regressed.
    pub counter: &'static str,
    pub old: u64,
    pub new: u64,
}

/// Cell-by-cell comparison of two perf artifacts.
#[derive(Clone, Debug, Default)]
pub struct PerfDiffReport {
    /// Gated cells present in both artifacts.
    pub compared: usize,
    /// Gated cells whose new `min_s` exceeds old × (1 + tol).
    pub regressions: Vec<PerfRegression>,
    /// Solve cells whose deterministic solver counters grew beyond
    /// tolerance (empty when either artifact predates the v6 columns).
    pub counter_regressions: Vec<CounterRegression>,
    /// Gated cells that sped up beyond the tolerance.
    pub improved: usize,
    /// Cells (gated or not) present in only one artifact — grid drift,
    /// reported but never failed.
    pub only_old: usize,
    pub only_new: usize,
}

impl PerfDiffReport {
    /// True when nothing gated regressed (timings or counters).
    pub fn clean(&self) -> bool {
        self.regressions.is_empty() && self.counter_regressions.is_empty()
    }
}

/// One indexed perf row: the compared timing, the gated flag, and — on
/// solve rows of v6+ artifacts — the deterministic counter columns.
struct IndexedRow {
    min_s: f64,
    gated: bool,
    /// `(column, value)` for each [`GATED_COUNTERS`] column present in
    /// the row (absent on pre-v6 artifacts and non-solve phases).
    counters: Vec<(&'static str, u64)>,
}

/// Index a perf document's rows by cell key, keeping every phase (so
/// grid drift on dense baselines is still visible). The gated flag comes
/// from the row's `phase` field directly — the display key is never
/// re-parsed.
fn index_rows(doc: &Json) -> Result<BTreeMap<String, IndexedRow>> {
    artifact::expect_kind(doc, ArtifactKind::Perf)?;
    let rows = doc.get("rows").as_arr().context("perf artifact missing rows[]")?;
    let mut out = BTreeMap::new();
    for (k, r) in rows.iter().enumerate() {
        let phase = r.get("phase").as_str().unwrap_or("?");
        let key = format!(
            "{}/{} {}x{} seed={} slot={} {}",
            r.get("scenario").as_str().unwrap_or("?"),
            r.get("model").as_str().unwrap_or("?"),
            r.get("n_clients").as_f64().unwrap_or(-1.0),
            r.get("n_helpers").as_f64().unwrap_or(-1.0),
            r.get("seed").as_str().unwrap_or("?"),
            r.get("slot_ms").as_f64().unwrap_or(-1.0),
            phase,
        );
        let min_s = r.get("min_s").as_f64().with_context(|| format!("row {k}: missing/bad min_s"))?;
        anyhow::ensure!(min_s.is_finite() && min_s >= 0.0, "row {k}: non-finite min_s {min_s}");
        let gated = GATED_PHASES.contains(&phase);
        // Counter columns gate only on the solve row (they repeat on
        // every phase row of a cell; comparing once avoids 5× duplicate
        // findings) and only when actually present (pre-v6 compat).
        let counters = if phase == "solve" {
            GATED_COUNTERS
                .iter()
                .filter_map(|&c| r.get(c).as_f64().map(|v| (c, v as u64)))
                .collect()
        } else {
            Vec::new()
        };
        // A silently-overwritten duplicate would shadow a row from the
        // comparison entirely (e.g. `--scenarios 1,1`): reject instead.
        anyhow::ensure!(
            out.insert(key.clone(), IndexedRow { min_s, gated, counters }).is_none(),
            "duplicate perf cell {key:?} in artifact"
        );
    }
    Ok(out)
}

/// Compare two perf artifacts: a gated cell regresses when its new
/// `min_s` exceeds the old by more than `tol` (relative). Cells present
/// in only one artifact are counted but do not fail the diff.
pub fn diff_documents(old: &Json, new: &Json, tol: f64) -> Result<PerfDiffReport> {
    let old_rows = index_rows(old)?;
    let new_rows = index_rows(new)?;
    let mut report = PerfDiffReport::default();
    for (key, old_row) in &old_rows {
        match new_rows.get(key) {
            None => report.only_old += 1,
            Some(new_row) if old_row.gated => {
                report.compared += 1;
                if new_row.min_s > old_row.min_s * (1.0 + tol) {
                    report.regressions.push(PerfRegression {
                        cell: key.clone(),
                        old_s: old_row.min_s,
                        new_s: new_row.min_s,
                    });
                } else if new_row.min_s < old_row.min_s * (1.0 - tol) {
                    report.improved += 1;
                }
                // Counter gating: deterministic, so the same tolerance is
                // generous — a genuine pruning/convergence regression
                // jumps far past it. `old == 0` means the cell's strategy
                // did not enter that search before (routing change, not
                // an efficiency loss): skip.
                for &(c, old_v) in &old_row.counters {
                    if old_v == 0 {
                        continue;
                    }
                    if let Some(&(_, new_v)) =
                        new_row.counters.iter().find(|&&(name, _)| name == c)
                    {
                        if new_v as f64 > old_v as f64 * (1.0 + tol) {
                            report.counter_regressions.push(CounterRegression {
                                cell: key.clone(),
                                counter: c,
                                old: old_v,
                                new: new_v,
                            });
                        }
                    }
                }
            }
            Some(_) => {}
        }
    }
    report.only_new = new_rows.keys().filter(|k| !old_rows.contains_key(*k)).count();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::perf::{rows_to_json, PerfRow};

    fn perf_row(scenario: &'static str, phase: &'static str, min_s: f64) -> PerfRow {
        PerfRow {
            scenario,
            model: "resnet101",
            n_clients: 8,
            n_helpers: 2,
            seed: 42,
            slot_ms: 180.0,
            phase,
            iters: 3,
            mean_s: min_s * 1.1,
            p50_s: min_s * 1.05,
            min_s,
            max_s: min_s * 1.3,
            makespan_slots: 40,
            total_runs: 16,
            total_slots: 200,
            exact_nodes: 120,
            exact_cutoffs: 40,
            exact_max_depth: 9,
            admm_iters: 4,
        }
    }

    fn doc(solve: f64, check: f64) -> Json {
        rows_to_json(&[
            perf_row("scenario1", "solve", solve),
            perf_row("scenario1", "check", check),
            perf_row("scenario1", "check-dense", 0.5),
        ])
    }

    #[test]
    fn self_diff_is_clean() {
        let d = doc(0.1, 0.01);
        let r = diff_documents(&d, &d, 0.25).unwrap();
        assert_eq!(r.compared, 2, "dense baseline rows are not gated");
        assert!(r.regressions.is_empty());
        assert!(r.counter_regressions.is_empty());
        assert!(r.clean());
        assert_eq!(r.improved + r.only_old + r.only_new, 0);
    }

    #[test]
    fn counter_blowup_regresses_even_when_timing_is_flat() {
        let old = doc(0.1, 0.01);
        let mut rows = vec![
            perf_row("scenario1", "solve", 0.1),
            perf_row("scenario1", "check", 0.01),
            perf_row("scenario1", "check-dense", 0.5),
        ];
        // Pruning broke: 10× the exact-search nodes at identical timings.
        rows[0].exact_nodes = 1200;
        let r = diff_documents(&old, &rows_to_json(&rows), 0.25).unwrap();
        assert!(r.regressions.is_empty(), "timings did not move");
        assert_eq!(r.counter_regressions.len(), 1, "{:?}", r.counter_regressions);
        assert_eq!(r.counter_regressions[0].counter, "exact_nodes");
        assert_eq!(r.counter_regressions[0].old, 120);
        assert_eq!(r.counter_regressions[0].new, 1200);
        assert!(!r.clean());
    }

    #[test]
    fn counter_gating_skips_pre_v6_artifacts_and_zero_baselines() {
        // Pre-v6 old artifact: strip the counter columns from the rows.
        let strip = |doc: &Json| -> Json {
            let mut d = doc.clone();
            if let Json::Obj(m) = &mut d {
                if let Some(Json::Arr(rows)) = m.get_mut("rows") {
                    for r in rows.iter_mut() {
                        if let Json::Obj(rm) = r {
                            for c in GATED_COUNTERS {
                                rm.remove(c);
                            }
                        }
                    }
                }
            }
            d
        };
        let old_pre_v6 = strip(&doc(0.1, 0.01));
        let mut rows = vec![
            perf_row("scenario1", "solve", 0.1),
            perf_row("scenario1", "check", 0.01),
            perf_row("scenario1", "check-dense", 0.5),
        ];
        rows[0].exact_nodes = 999_999;
        let r = diff_documents(&old_pre_v6, &rows_to_json(&rows), 0.25).unwrap();
        assert!(r.clean(), "no counter columns in the old artifact → no counter gate");

        // Zero baseline (the cell's strategy never entered the exact
        // search before): new activity is a routing change, not gated.
        let mut old_rows = vec![
            perf_row("scenario1", "solve", 0.1),
            perf_row("scenario1", "check", 0.01),
        ];
        old_rows[0].exact_nodes = 0;
        let r2 = diff_documents(&rows_to_json(&old_rows), &rows_to_json(&rows), 0.25).unwrap();
        assert!(
            r2.counter_regressions.is_empty(),
            "zero-baseline counters never gate: {:?}",
            r2.counter_regressions
        );
    }

    #[test]
    fn slowdown_beyond_tol_regresses_and_speedup_improves() {
        let old = doc(0.1, 0.01);
        let new = doc(0.2, 0.004);
        let r = diff_documents(&old, &new, 0.25).unwrap();
        assert_eq!(r.regressions.len(), 1, "{:?}", r.regressions);
        assert!(r.regressions[0].cell.ends_with(" solve"), "{}", r.regressions[0].cell);
        assert_eq!(r.improved, 1, "check sped up beyond tolerance");
        // A huge tolerance swallows the slowdown.
        assert!(diff_documents(&old, &new, 2.0).unwrap().regressions.is_empty());
    }

    #[test]
    fn dense_baseline_drift_never_fails() {
        let old = doc(0.1, 0.01);
        let mut rows = vec![
            perf_row("scenario1", "solve", 0.1),
            perf_row("scenario1", "check", 0.01),
            perf_row("scenario1", "check-dense", 50.0), // 100× slower — ignored
        ];
        let r = diff_documents(&old, &rows_to_json(&rows), 0.25).unwrap();
        assert!(r.regressions.is_empty(), "dense phases are reference-only");
        // Dropping the dense row entirely is drift, not failure.
        rows.pop();
        let r2 = diff_documents(&old, &rows_to_json(&rows), 0.25).unwrap();
        assert_eq!(r2.only_old, 1);
        assert!(r2.regressions.is_empty());
    }

    #[test]
    fn duplicate_cells_are_rejected() {
        // `perf --scenarios 1,1` would write two rows with the same cell
        // key; the diff must refuse rather than shadow one of them.
        let d = rows_to_json(&[perf_row("scenario1", "solve", 0.1), perf_row("scenario1", "solve", 0.2)]);
        let err = diff_documents(&d, &d, 0.25).unwrap_err().to_string();
        assert!(err.contains("duplicate"), "{err}");
    }

    #[test]
    fn rejects_non_perf_documents() {
        let sweep = artifact::envelope(ArtifactKind::Sweep, vec![("rows", Json::Arr(vec![]))]);
        let err = diff_documents(&sweep, &sweep, 0.25).unwrap_err().to_string();
        assert!(err.contains("psl-sweep"), "{err}");
    }

    #[test]
    fn real_smoke_artifact_self_diffs_clean() {
        let rows = crate::bench::perf::run(&crate::bench::perf::PerfCfg {
            scenarios: vec![crate::instance::scenario::Scenario::S1],
            model: crate::instance::profiles::Model::Vgg19,
            sizes: vec![(4, 2)],
            seed: 11,
            iters: 1,
            warmup: 0,
        });
        let d = rows_to_json(&rows);
        let parsed = Json::parse(&d.pretty()).unwrap();
        let r = diff_documents(&parsed, &parsed, 0.0).unwrap();
        assert_eq!(r.compared, 3, "solve/check/replay gated");
        assert!(r.regressions.is_empty(), "self-diff at zero tolerance is clean");
    }
}
