//! # psl — Workflow Optimization for Parallel Split Learning
//!
//! A production-grade reproduction of *"Workflow Optimization for Parallel
//! Split Learning"* (Tirana, Tsigkari, Iosifidis, Chatzopoulos — IEEE
//! INFOCOM 2024): joint client→helper assignment and preemptive
//! time-slotted scheduling that minimizes the batch-training makespan of
//! parallel split learning, plus the full substrate needed to evaluate it
//! (testbed profile bank, scenario generators, an exact reference solver,
//! a discrete-event simulator, and a real rust+JAX+Pallas split-learning
//! runtime over PJRT).
//!
//! ## Layers
//!
//! * **L3 (this crate)** — the coordinator: solvers
//!   ([`solver::admm`], [`solver::greedy`], [`solver::exact`], …),
//!   simulator ([`sim`]), SL execution runtime ([`slexec`]), metrics, CLI.
//! * **L2 (python/compile/model.py)** — the split NN (part-1/2/3) in JAX,
//!   AOT-lowered to HLO text artifacts.
//! * **L1 (python/compile/kernels/)** — the helper-side Pallas kernel
//!   (fused conv-as-matmul block), interpret-mode on CPU.
//!
//! Python never runs at request time: the [`runtime`] module loads the HLO
//! artifacts through PJRT (`xla` crate, behind the `pjrt` cargo feature)
//! and [`slexec`] drives real training from Rust according to the
//! optimized schedules.
//!
//! ## Scenarios
//!
//! Workloads come from the composable
//! [`ScenarioSpec`](instance::scenario::ScenarioSpec): device-mix
//! distributions, per-entity memory models, link regimes, cut-layer
//! policies and client-churn knobs. Six named families ship as presets —
//! the paper's `scenario1`/`scenario2` plus `s3-clustered`,
//! `s4-straggler-tail`, `s5-memory-starved` and `s6-mega-homogeneous` —
//! and `psl sweep` ([`bench::sweep`]) runs the full scenario × solver
//! grid across worker threads with deterministic, thread-count-independent
//! JSON output.
//!
//! ## Fleets
//!
//! [`fleet`] turns the one-shot solver into a long-running orchestration
//! system: seeded multi-round churn (arrivals minted from the scenario's
//! distributions with stable client ids, departures evicted), warm-started
//! incremental re-solving with a drift-triggered full-solve fallback, and
//! per-round reports (makespan, re-solve cost proxy, epoch-pipelined
//! period). The round loop is a stepwise state machine
//! ([`fleet::session::FleetSession`]) whose warm state checkpoints as a
//! schema-checked artifact ([`fleet::checkpoint`]): `psl fleet` drives a
//! single run (streaming round and event JSONL sidecars, snapshotting
//! with `--checkpoint-every`, continuing byte-identically with
//! `--resume`), `psl serve` ([`fleet::serve`]) exposes the same session
//! as a stdin/stdout JSONL decision service, and [`bench::fleet`] runs
//! the scenario × churn-rate × policy grid.
//!
//! ## Analytics
//!
//! Every runner persists through one artifact registry
//! ([`bench::artifact`]: kind tag + schema version + single load/validate
//! path), and [`analyze`] (`psl analyze`) consumes it: fleet-grid cells
//! aggregate into per-family regime tables, the churn-rate **policy
//! frontier** (where full re-solving overtakes incremental repair) is
//! serialized as a [`fleet::policy::PolicyTable`], the fleet `auto`
//! policy consults that table per round, and `--perf-diff` gates
//! solve/check/replay timings across perf-trajectory points.
//!
//! ## Sharding
//!
//! Above the monolithic solvers sits the sharded hierarchical layer
//! ([`shard`]): mega-scale instances (≥
//! [`strategy::SHARD_CLIENT_FRONTIER`](solver::strategy::SHARD_CLIENT_FRONTIER)
//! clients) partition into helper cells by link-regime/device-tier
//! affinity, cells solve concurrently over [`exec::pool`] (each picking
//! its own method from its own signals), and a coordinator stitching
//! pass merges the per-cell schedules, measures the **stitch gap**
//! (stitched makespan / max per-shard lower bound) and migrates
//! boundary clients out of the worst cell when the gap warrants it.
//! `psl shard` runs a scenario × size grid through this pipeline and
//! persists the `psl-shard` artifact.
//!
//! ## Transport
//!
//! All transfer-time computation flows through one abstraction
//! ([`transport`]): [`transport::LinkMode::Dedicated`] is the paper's
//! fixed per-edge delay model (byte-identical to the pre-transport code
//! path), and [`transport::LinkMode::Shared`] models per-helper uplink
//! pools where `k` concurrent transfers each progress at `capacity/k`
//! of their dedicated rate (exact fluid law in [`transport::pool`];
//! solvers consume the conservative static projection
//! [`transport::TransportCfg::inflate`]). The `--link-model` /
//! `--uplink-capacity` knobs on `psl solve|sweep|fleet|serve` select the
//! mode, `Schedule::violations_under` checks feasibility against it,
//! the sim replay engines resolve transfer phases through it, and the
//! `--uplink-capacities` fleet-grid axis flows through `psl analyze`
//! regime tables into per-capacity policy-table frontiers.
//!
//! ## Performance
//!
//! Schedules are run-length encoded ([`solver::schedule::SlotRuns`]):
//! checker, replay and fleet costs scale with preemption runs, not total
//! processing slots, and the ADMM local search evaluates moves
//! allocation-free. `psl perf` ([`bench::perf`]) times these hot paths
//! against the dense baseline and writes the repo's perf trajectory to
//! `target/psl-bench/perf.json`.
//!
//! ## Observability
//!
//! [`obs`] is the in-process tracing and metrics layer: RAII span guards
//! measure the solver / shard / fleet / exec phases on per-thread
//! buffers, and a counter registry records deterministic algorithm
//! statistics (exact-solver nodes / cutoffs / depth, ADMM iterations and
//! residuals, repair moves, shard migrations). Counters are commutative
//! totals, so they are byte-identical across thread counts; spans are
//! wall-clock and explicitly non-deterministic; neither is ever read by
//! a decision path, so artifacts are byte-identical with tracing on or
//! off. `--trace FILE` on `psl solve|fleet|shard|serve` emits the
//! Chrome trace-event `psl-trace` artifact, `psl analyze --trace`
//! summarizes it, and `psl perf` folds the solver counters into
//! `psl-perf` rows so `analyze --perf-diff` gates pruning efficiency
//! alongside wall-clock.
//!
//! ## Quickstart
//!
//! ```no_run
//! use psl::instance::scenario::{Scenario, ScenarioCfg};
//! use psl::instance::profiles::Model;
//! use psl::solver::{admm, greedy, strategy};
//!
//! let inst = ScenarioCfg::new(Scenario::S2, Model::ResNet101, 20, 5, 42)
//!     .generate()
//!     .quantize(180.0);
//! let (schedule, method) = strategy::solve(&inst, &admm::AdmmCfg::default()).unwrap();
//! println!("method {:?}: makespan {} slots ({:.1} s)",
//!     method,
//!     schedule.makespan(&inst),
//!     schedule.makespan(&inst) as f64 * inst.slot_ms / 1000.0);
//! let g = greedy::solve(&inst).unwrap();
//! assert!(schedule.makespan(&inst) <= g.makespan(&inst));
//! ```

pub mod analyze;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod fleet;
pub mod instance;
pub mod obs;
pub mod runtime;
pub mod shard;
pub mod sim;
pub mod slexec;
pub mod solver;
pub mod transport;
pub mod util;
