//! The paper's *solution strategy* (§VII, Observation 3): pick the method
//! from the instance's **shape signals** — never from the scenario label,
//! so custom [`ScenarioSpec`](crate::instance::scenario::ScenarioSpec)
//! compositions route exactly like the named families.
//!
//! * Medium instances (≲ 50 clients) and/or high heterogeneity → the
//!   ADMM-based method (it shapes assignments around the delay structure
//!   and schedules preemptively).
//! * Very large (≳ 100 clients) or large-and-homogeneous → balanced-greedy
//!   (queuing dominates; load balancing wins and costs almost nothing).
//! * Memory-starved shapes (few helpers can host a typical client) →
//!   ADMM regardless of size: assignment feasibility is the binding
//!   constraint and load balancing alone can wedge.
//! * Mega-scale instances (≥ [`SHARD_CLIENT_FRONTIER`] clients with at
//!   least two helpers) → the sharded hierarchical solver
//!   ([`crate::shard`]): partition into helper cells, solve cells
//!   concurrently, stitch.
//!
//! The raw signals are exposed as [`Signals`] so sweeps and reports can
//! record *why* a method was picked.

use super::admm::{self, AdmmCfg};
use super::bwd;
use super::greedy;
use super::schedule::{fcfs_schedule, Schedule};
use crate::instance::Instance;
use crate::transport::TransportCfg;

/// Client count at and above which [`pick_from_signals`] routes to the
/// sharded hierarchical solver (provided ≥ 2 helpers exist to form
/// cells). Below it the monolithic solvers are both affordable and at
/// least as good — sharding only forfeits cross-cell assignment freedom
/// to buy solve-time parallelism.
pub const SHARD_CLIENT_FRONTIER: usize = 4096;

/// Which method the strategy picked.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Admm,
    BalancedGreedy,
    /// Hierarchical: partition into helper cells, solve per cell, stitch
    /// ([`crate::shard`]).
    Sharded,
}

impl Method {
    pub fn name(self) -> &'static str {
        match self {
            Method::Admm => "admm",
            Method::BalancedGreedy => "balanced-greedy",
            Method::Sharded => "sharded",
        }
    }

    /// Inverse of [`Method::name`] — fleet checkpoints round-trip the
    /// recorded method string through this.
    pub fn parse(s: &str) -> Option<Method> {
        match s {
            "admm" => Some(Method::Admm),
            "balanced-greedy" => Some(Method::BalancedGreedy),
            "sharded" => Some(Method::Sharded),
            _ => None,
        }
    }
}

/// Instance-shape signals consumed by the §VII pick rule (and recorded in
/// sweep rows).
#[derive(Clone, Copy, Debug)]
pub struct Signals {
    pub n_clients: usize,
    pub n_helpers: usize,
    /// Coefficient of variation of the helper processing times p — the
    /// paper's heterogeneity axis.
    pub heterogeneity: f64,
    /// Mean over clients of the fraction of helpers whose memory can host
    /// them (1.0 = any client fits anywhere; low = starved).
    pub placement_flexibility: f64,
    /// p95 / median of the per-client best-edge end-to-end times — a
    /// straggler-tail diagnostic.
    pub tail_ratio: f64,
    /// Excess transfer slowdown of a uniformly-loaded helper under the
    /// active transport ([`TransportCfg::contention`]); 0 under the
    /// dedicated link model.
    pub contention: f64,
}

/// Heterogeneity proxy: coefficient of variation of the helper processing
/// times p (the paper's scenarios differ exactly in this dimension).
pub fn heterogeneity(inst: &Instance) -> f64 {
    let xs: Vec<f64> = inst.p.iter().map(|&v| v as f64).collect();
    if xs.is_empty() {
        return 0.0;
    }
    let m = xs.iter().sum::<f64>() / xs.len() as f64;
    if m == 0.0 {
        return 0.0;
    }
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
    var.sqrt() / m
}

/// Compute all pick-rule signals for an instance.
pub fn signals(inst: &Instance) -> Signals {
    if inst.n_clients == 0 || inst.n_helpers == 0 {
        // Degenerate instances carry no shape information; report neutral
        // signals instead of indexing empty percentile vectors.
        return Signals {
            n_clients: inst.n_clients,
            n_helpers: inst.n_helpers,
            heterogeneity: 0.0,
            placement_flexibility: 1.0,
            tail_ratio: 1.0,
            contention: 0.0,
        };
    }
    let j_n = inst.n_clients;
    let i_n = inst.n_helpers;
    let mut flex = 0.0;
    for j in 0..j_n {
        flex += inst.feasible_helpers(j).len() as f64 / i_n as f64;
    }
    let placement_flexibility = flex / j_n as f64;

    let mut best: Vec<f64> = (0..inst.n_clients)
        .map(|j| {
            (0..inst.n_helpers)
                .map(|i| {
                    let e = inst.edge(i, j);
                    (inst.r[e] + inst.p[e] + inst.l[e] + inst.lp[e] + inst.pp[e] + inst.rp[e]) as f64
                })
                .fold(f64::MAX, f64::min)
        })
        .collect();
    best.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = best[best.len() / 2].max(1.0);
    let p95 = best[((best.len() - 1) as f64 * 0.95).round() as usize];
    let tail_ratio = p95 / median;

    Signals {
        n_clients: inst.n_clients,
        n_helpers: inst.n_helpers,
        heterogeneity: heterogeneity(inst),
        placement_flexibility,
        tail_ratio,
        contention: 0.0,
    }
}

/// [`signals`] under a transport model: identical shape signals plus the
/// transport's contention estimate (0 under the dedicated mode, so
/// `signals_under(inst, &TransportCfg::dedicated()) == signals(inst)`).
pub fn signals_under(inst: &Instance, transport: &TransportCfg) -> Signals {
    let mut s = signals(inst);
    s.contention = transport.contention(inst.n_clients, inst.n_helpers);
    s
}

/// Decide the method per §VII from the instance's signals:
/// balanced-greedy for very large scenarios (≥ 100 clients in the paper's
/// setting) and for large homogeneous ones; ADMM otherwise — and always
/// ADMM when placement flexibility is low (memory-starved shapes), where
/// the assignment subproblem is what matters.
pub fn pick(inst: &Instance) -> Method {
    let s = signals(inst);
    pick_from_signals(&s)
}

/// The pick rule on precomputed signals (kept separate so sweeps can
/// record the signals alongside the decision without recomputing).
/// Mega-scale shapes route to [`Method::Sharded`] before the flat rule
/// is consulted — at that size the question is no longer *which*
/// monolithic solver but whether to decompose at all.
pub fn pick_from_signals(s: &Signals) -> Method {
    if s.n_clients >= SHARD_CLIENT_FRONTIER && s.n_helpers >= 2 {
        return Method::Sharded;
    }
    pick_flat(s)
}

/// The flat (single-level) §VII rule: Admm vs. balanced-greedy only,
/// never [`Method::Sharded`]. The shard layer consults this per cell so
/// one hierarchy level cannot nest another indefinitely.
pub fn pick_flat(s: &Signals) -> Method {
    if s.placement_flexibility < 0.35 {
        return Method::Admm;
    }
    if s.contention > 0.5 {
        // Heavy uplink contention: how clients spread over pools is what
        // determines the makespan, so route to the assignment-shaping
        // solver even at sizes where queuing would favour greedy.
        return Method::Admm;
    }
    if s.n_clients >= 100 {
        return Method::BalancedGreedy;
    }
    if s.n_clients > 50 && s.heterogeneity < 0.35 {
        return Method::BalancedGreedy;
    }
    Method::Admm
}

/// Run the strategy. Returns the schedule and the method used.
pub fn solve(inst: &Instance, admm_cfg: &AdmmCfg) -> Option<(Schedule, Method)> {
    solve_with_signals(inst, admm_cfg, &signals(inst))
}

/// Run the strategy under a transport model. The dedicated mode
/// delegates to [`solve`] unchanged (byte-identical decisions); the
/// shared mode shapes the assignment on the uniform-load contention
/// estimate ([`TransportCfg::inflate_uniform`]) and then re-schedules
/// that assignment against its **actual** per-helper pool loads
/// ([`TransportCfg::inflate_for_assignment`]) — FCFS forward plus the
/// optimal ℙ_b backward pass — so the result is feasible under
/// [`Schedule::violations_under`] by construction and deterministic
/// regardless of thread count.
pub fn solve_under(
    inst: &Instance,
    transport: &TransportCfg,
    admm_cfg: &AdmmCfg,
) -> Option<(Schedule, Method)> {
    if transport.is_dedicated() {
        return solve(inst, admm_cfg);
    }
    let _sp = crate::obs::span("solver", "solver/transport");
    let sig = signals_under(inst, transport);
    let est = transport.inflate_uniform(inst);
    let (shaped, method) = solve_with_signals(&est, admm_cfg, &sig)?;
    let eff = transport.inflate_for_assignment(inst, &shaped.assignment);
    let f = fcfs_schedule(&eff, shaped.assignment);
    Some((bwd::complete_with_optimal_bwd(&eff, f.assignment, f.fwd), method))
}

/// [`solve`] on precomputed signals — callers that already computed
/// [`signals`] for reporting (the sweep runner) avoid the second
/// O(J·I) scan.
pub fn solve_with_signals(inst: &Instance, admm_cfg: &AdmmCfg, s: &Signals) -> Option<(Schedule, Method)> {
    match pick_from_signals(s) {
        Method::Sharded => {
            let _sp = crate::obs::span("solver", "solver/sharded");
            let out = crate::shard::solve_quantized(
                inst,
                &crate::shard::ShardCfg::default(),
                crate::exec::pool::default_workers(),
            )?;
            Some((out.stitch.schedule, Method::Sharded))
        }
        _ => solve_flat(inst, admm_cfg, s),
    }
}

/// The flat solve behind [`pick_flat`]: Admm or balanced-greedy, never
/// sharded. Per-cell solves in [`crate::shard::solve`] land here when a
/// degenerate partition leaves a cell above the frontier, which is what
/// makes the hierarchy structurally non-recursive.
pub fn solve_flat(inst: &Instance, admm_cfg: &AdmmCfg, s: &Signals) -> Option<(Schedule, Method)> {
    match pick_flat(s) {
        Method::Sharded => unreachable!("pick_flat never picks Sharded"),
        Method::BalancedGreedy => {
            let _sp = crate::obs::span("solver", "solver/greedy");
            greedy::solve(inst).map(|s| (s, Method::BalancedGreedy))
        }
        Method::Admm => {
            let _sp = crate::obs::span("solver", "solver/admm");
            let a = admm::solve(inst, admm_cfg)?;
            // Defensive: if greedy happens to beat ADMM here, take it —
            // the strategy is free to keep the better of its two tools.
            if let Some(g) = greedy::solve(inst) {
                if g.makespan(inst) < a.schedule.makespan(inst) {
                    return Some((g, Method::BalancedGreedy));
                }
            }
            Some((a.schedule, Method::Admm))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};

    #[test]
    fn picks_greedy_for_huge() {
        let inst = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 120, 10, 1).generate().quantize(180.0);
        assert_eq!(pick(&inst), Method::BalancedGreedy);
    }

    #[test]
    fn picks_admm_for_medium_heterogeneous() {
        let inst = ScenarioCfg::new(Scenario::S2, Model::ResNet101, 20, 5, 1).generate().quantize(180.0);
        assert_eq!(pick(&inst), Method::Admm);
    }

    #[test]
    fn strategy_not_worse_than_either_tool_alone() {
        for seed in 0..4u64 {
            let inst = ScenarioCfg::new(Scenario::S2, Model::Vgg19, 15, 4, 60 + seed).generate().quantize(550.0);
            let (s, _) = solve(&inst, &crate::solver::admm::AdmmCfg::default()).unwrap();
            let g = crate::solver::greedy::solve(&inst).unwrap();
            assert!(s.makespan(&inst) <= g.makespan(&inst));
            assert!(s.is_feasible(&inst));
        }
    }

    #[test]
    fn heterogeneity_ordering() {
        let s1 = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 20, 5, 2).generate().quantize(180.0);
        let s2 = ScenarioCfg::new(Scenario::S2, Model::ResNet101, 20, 5, 2).generate().quantize(180.0);
        assert!(heterogeneity(&s2) > heterogeneity(&s1) * 0.8, "S2 should not be much less heterogeneous");
    }

    #[test]
    fn signals_full_flexibility_when_memory_loose() {
        // S1: every helper carries full RAM and every client fits anywhere.
        let inst = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 12, 4, 3).generate().quantize(180.0);
        let s = signals(&inst);
        assert!((s.placement_flexibility - 1.0).abs() < 1e-9, "flex {}", s.placement_flexibility);
        assert_eq!(s.n_clients, 12);
        assert_eq!(s.n_helpers, 4);
        assert!(s.tail_ratio >= 1.0);
    }

    #[test]
    fn starved_placement_routes_to_admm_even_when_large() {
        // Force low flexibility by shrinking all but one helper below every
        // client's footprint: only 1/4 of helpers can host anyone.
        let mut inst = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 60, 4, 5).generate().quantize(180.0);
        let max_d = inst.d.iter().cloned().fold(0.0, f64::max);
        for m in inst.mem.iter_mut() {
            *m = max_d * 0.5;
        }
        inst.mem[0] = max_d * 2.0;
        let s = signals(&inst);
        assert!(s.placement_flexibility < 0.35, "flex {}", s.placement_flexibility);
        assert_eq!(pick(&inst), Method::Admm);
    }

    #[test]
    fn pick_consumes_signals_not_labels() {
        // The same signals give the same pick regardless of which scenario
        // family produced the instance.
        let inst = ScenarioCfg::new(Scenario::S6MegaHomogeneous, Model::ResNet101, 120, 8, 2)
            .generate()
            .quantize(180.0);
        let s = signals(&inst);
        assert_eq!(pick(&inst), pick_from_signals(&s));
        assert_eq!(pick(&inst), Method::BalancedGreedy, "huge homogeneous fleet routes to greedy");
    }

    #[test]
    fn signals_tolerate_degenerate_instances() {
        // A zero-client grid cell must not panic inside a sweep worker.
        let inst = Instance {
            n_clients: 0,
            n_helpers: 2,
            slot_ms: 100.0,
            r: vec![],
            l: vec![],
            lp: vec![],
            rp: vec![],
            p: vec![],
            pp: vec![],
            d: vec![],
            mem: vec![1.0, 1.0],
            mu: vec![0, 0],
            label: "empty".into(),
        };
        let s = signals(&inst);
        assert_eq!(s.tail_ratio, 1.0);
        assert_eq!(s.heterogeneity, 0.0);
        assert_eq!(pick(&inst), Method::Admm);
    }

    #[test]
    fn method_names_stable() {
        assert_eq!(Method::Admm.name(), "admm");
        assert_eq!(Method::BalancedGreedy.name(), "balanced-greedy");
        assert_eq!(Method::Sharded.name(), "sharded");
        assert_eq!(Method::parse("sharded"), Some(Method::Sharded));
    }

    #[test]
    fn mega_scale_routes_to_sharded() {
        let s = Signals {
            n_clients: SHARD_CLIENT_FRONTIER,
            n_helpers: 64,
            heterogeneity: 0.1,
            placement_flexibility: 1.0,
            tail_ratio: 1.2,
            contention: 0.0,
        };
        assert_eq!(pick_from_signals(&s), Method::Sharded);
        // The flat rule never shards, whatever the size.
        assert_eq!(pick_flat(&s), Method::BalancedGreedy);
    }

    #[test]
    fn sharding_needs_at_least_two_helpers() {
        // One helper means one cell means no decomposition to exploit —
        // a mega single-helper instance stays on the flat rule.
        let s = Signals {
            n_clients: SHARD_CLIENT_FRONTIER * 2,
            n_helpers: 1,
            heterogeneity: 0.1,
            placement_flexibility: 1.0,
            tail_ratio: 1.0,
            contention: 0.0,
        };
        assert_eq!(pick_from_signals(&s), Method::BalancedGreedy);
    }

    #[test]
    fn contention_routes_large_homogeneous_to_admm() {
        // Without contention this shape is a textbook greedy pick; under
        // a 2×-overloaded shared uplink the assignment shaping wins.
        let mut s = Signals {
            n_clients: 120,
            n_helpers: 10,
            heterogeneity: 0.1,
            placement_flexibility: 1.0,
            tail_ratio: 1.1,
            contention: 0.0,
        };
        assert_eq!(pick_flat(&s), Method::BalancedGreedy);
        s.contention = 1.0;
        assert_eq!(pick_flat(&s), Method::Admm);
    }

    #[test]
    fn signals_under_dedicated_matches_plain_signals() {
        let inst = ScenarioCfg::new(Scenario::S2, Model::ResNet101, 20, 5, 4).generate().quantize(180.0);
        let a = signals(&inst);
        let b = signals_under(&inst, &crate::transport::TransportCfg::dedicated());
        assert_eq!(a.contention, b.contention);
        assert_eq!(a.heterogeneity, b.heterogeneity);
        assert_eq!(a.tail_ratio, b.tail_ratio);
        let c = signals_under(&inst, &crate::transport::TransportCfg::shared(1.0));
        assert!(c.contention > 0.0, "ceil(20/5)=4 on a 1-pool must contend");
    }

    #[test]
    fn solve_under_dedicated_is_solve() {
        let inst = ScenarioCfg::new(Scenario::S2, Model::Vgg19, 12, 3, 8).generate().quantize(550.0);
        let cfg = crate::solver::admm::AdmmCfg::default();
        let (a, ma) = solve(&inst, &cfg).unwrap();
        let (b, mb) = solve_under(&inst, &crate::transport::TransportCfg::dedicated(), &cfg).unwrap();
        assert_eq!(ma, mb);
        assert_eq!(a.assignment, b.assignment);
        assert_eq!(a.fwd, b.fwd);
        assert_eq!(a.bwd, b.bwd);
    }

    #[test]
    fn solve_under_shared_is_feasible_under_checker() {
        for seed in 0..3u64 {
            let inst = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 14, 3, 20 + seed)
                .generate()
                .quantize(180.0);
            let t = crate::transport::TransportCfg::shared(2.0);
            let (s, _) = solve_under(&inst, &t, &crate::solver::admm::AdmmCfg::default()).unwrap();
            let v = s.violations_under(&inst, &t);
            assert!(v.is_empty(), "shared-mode schedule infeasible: {v:?}");
            // Contention can only lengthen the makespan measured on the
            // effective instance vs the dedicated solve's nominal one.
            assert!(s.makespan(&t.inflate_for_assignment(&inst, &s.assignment)) >= inst.makespan_lower_bound());
        }
    }

    #[test]
    fn frontier_sits_above_every_flat_grid_cell() {
        // The J=512 perf cell and the J≤200 strategy goldens must keep
        // routing through the flat rule.
        assert!(SHARD_CLIENT_FRONTIER > 512);
    }
}
