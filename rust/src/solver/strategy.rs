//! The paper's *solution strategy* (§VII, Observation 3): pick the method
//! by the scenario's size and heterogeneity.
//!
//! * Medium instances (≲ 50 clients) and/or high heterogeneity → the
//!   ADMM-based method (it shapes assignments around the delay structure
//!   and schedules preemptively).
//! * Very large (≳ 100 clients) or large-and-homogeneous → balanced-greedy
//!   (queuing dominates; load balancing wins and costs almost nothing).

use super::admm::{self, AdmmCfg};
use super::greedy;
use super::schedule::Schedule;
use crate::instance::Instance;

/// Which method the strategy picked.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Admm,
    BalancedGreedy,
}

/// Heterogeneity proxy: coefficient of variation of the helper processing
/// times p (the paper's scenarios differ exactly in this dimension).
pub fn heterogeneity(inst: &Instance) -> f64 {
    let xs: Vec<f64> = inst.p.iter().map(|&v| v as f64).collect();
    let m = xs.iter().sum::<f64>() / xs.len() as f64;
    if m == 0.0 {
        return 0.0;
    }
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
    var.sqrt() / m
}

/// Decide the method per §VII: balanced-greedy for very large scenarios
/// (≥ 100 clients in the paper's setting) and for large homogeneous ones;
/// ADMM otherwise.
pub fn pick(inst: &Instance) -> Method {
    let j = inst.n_clients;
    let het = heterogeneity(inst);
    if j >= 100 {
        Method::BalancedGreedy
    } else if j > 50 && het < 0.35 {
        Method::BalancedGreedy
    } else {
        Method::Admm
    }
}

/// Run the strategy. Returns the schedule and the method used.
pub fn solve(inst: &Instance, admm_cfg: &AdmmCfg) -> Option<(Schedule, Method)> {
    match pick(inst) {
        Method::BalancedGreedy => greedy::solve(inst).map(|s| (s, Method::BalancedGreedy)),
        Method::Admm => {
            let a = admm::solve(inst, admm_cfg)?;
            // Defensive: if greedy happens to beat ADMM here, take it —
            // the strategy is free to keep the better of its two tools.
            if let Some(g) = greedy::solve(inst) {
                if g.makespan(inst) < a.schedule.makespan(inst) {
                    return Some((g, Method::BalancedGreedy));
                }
            }
            Some((a.schedule, Method::Admm))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};

    #[test]
    fn picks_greedy_for_huge() {
        let inst = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 120, 10, 1).generate().quantize(180.0);
        assert_eq!(pick(&inst), Method::BalancedGreedy);
    }

    #[test]
    fn picks_admm_for_medium_heterogeneous() {
        let inst = ScenarioCfg::new(Scenario::S2, Model::ResNet101, 20, 5, 1).generate().quantize(180.0);
        assert_eq!(pick(&inst), Method::Admm);
    }

    #[test]
    fn strategy_not_worse_than_either_tool_alone() {
        for seed in 0..4u64 {
            let inst = ScenarioCfg::new(Scenario::S2, Model::Vgg19, 15, 4, 60 + seed).generate().quantize(550.0);
            let (s, _) = solve(&inst, &crate::solver::admm::AdmmCfg::default()).unwrap();
            let g = crate::solver::greedy::solve(&inst).unwrap();
            assert!(s.makespan(&inst) <= g.makespan(&inst));
            assert!(s.is_feasible(&inst));
        }
    }

    #[test]
    fn heterogeneity_ordering() {
        let s1 = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 20, 5, 2).generate().quantize(180.0);
        let s2 = ScenarioCfg::new(Scenario::S2, Model::ResNet101, 20, 5, 2).generate().quantize(180.0);
        assert!(heterogeneity(&s2) > heterogeneity(&s1) * 0.8, "S2 should not be much less heterogeneous");
    }
}
