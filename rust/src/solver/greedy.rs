//! The paper's scalable heuristic, **balanced-greedy** (§VI): static load
//! balancing for the assignments (helper with the fewest assigned clients
//! among those with enough free memory), then non-preemptive FCFS
//! scheduling at each helper. O(J·I) assignment + O(J log J) scheduling —
//! the method of choice for very large and/or homogeneous scenarios.

use super::schedule::{fcfs_schedule, Assignment, Schedule};
use crate::instance::Instance;

/// Balanced-greedy assignment (§VI step 1): clients in index order; each
/// goes to the least-loaded helper among Q_j = {i : m_i − used_i ≥ d_j};
/// load G_i = number of assigned clients. Returns None if some client fits
/// no helper (generator guarantees this cannot happen for our scenarios).
pub fn balanced_assignment(inst: &Instance) -> Option<Assignment> {
    let mut free = inst.mem.clone();
    let mut load = vec![0usize; inst.n_helpers];
    let mut helper_of = Vec::with_capacity(inst.n_clients);
    for j in 0..inst.n_clients {
        let eta = (0..inst.n_helpers)
            .filter(|&i| free[i] >= inst.d[j])
            .min_by(|&a, &b| load[a].cmp(&load[b]).then(a.cmp(&b)))?;
        free[eta] -= inst.d[j];
        load[eta] += 1;
        helper_of.push(eta);
    }
    Some(Assignment::new(helper_of))
}

/// Full balanced-greedy solve: assignment + FCFS schedule.
pub fn solve(inst: &Instance) -> Option<Schedule> {
    Some(fcfs_schedule(inst, balanced_assignment(inst)?))
}

/// Balanced-greedy under a transport model. The assignment step depends
/// only on memory, which contention never changes, so the assignment is
/// identical to [`solve`]'s; the FCFS schedule then runs against the
/// contention-inflated effective instance for that assignment's
/// per-helper pool loads ([`crate::transport::TransportCfg::inflate_for_assignment`]).
/// Dedicated mode is byte-identical to [`solve`].
pub fn solve_under(inst: &Instance, transport: &crate::transport::TransportCfg) -> Option<Schedule> {
    let a = balanced_assignment(inst)?;
    if transport.is_dedicated() {
        return Some(fcfs_schedule(inst, a));
    }
    let eff = transport.inflate_for_assignment(inst, &a);
    Some(fcfs_schedule(&eff, a))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};
    use crate::util::prop;

    #[test]
    fn feasible_on_scenarios() {
        prop::check(40, |rng| {
            let j = rng.range_usize(2, 30);
            let i = rng.range_usize(1, 6);
            let scen = if rng.chance(0.5) { Scenario::S1 } else { Scenario::S2 };
            let model = if rng.chance(0.5) { Model::ResNet101 } else { Model::Vgg19 };
            let inst = ScenarioCfg::new(scen, model, j, i, rng.next_u64()).generate().quantize(200.0);
            let s = solve(&inst).expect("generator guarantees feasibility");
            prop::assert_prop(s.is_feasible(&inst), &format!("violations: {:?}", s.violations(&inst)));
        });
    }

    #[test]
    fn loads_are_balanced_when_memory_is_loose() {
        let inst = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 20, 4, 9).generate().quantize(180.0);
        let a = balanced_assignment(&inst).unwrap();
        let mut counts = vec![0usize; inst.n_helpers];
        for &i in &a.helper_of {
            counts[i] += 1;
        }
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(max - min <= 1, "loads {counts:?} not balanced");
    }

    #[test]
    fn respects_memory() {
        prop::check(40, |rng| {
            let inst = ScenarioCfg::new(Scenario::S2, Model::Vgg19, rng.range_usize(2, 25), rng.range_usize(1, 5), rng.next_u64())
                .generate()
                .quantize(550.0);
            let a = balanced_assignment(&inst).unwrap();
            prop::assert_prop(a.memory_ok(&inst), "memory constraint");
        });
    }

    #[test]
    fn returns_none_when_truly_infeasible() {
        use crate::instance::Instance;
        let inst = Instance {
            n_clients: 1,
            n_helpers: 1,
            slot_ms: 100.0,
            r: vec![0],
            l: vec![0],
            lp: vec![0],
            rp: vec![0],
            p: vec![1],
            pp: vec![1],
            d: vec![10.0],
            mem: vec![1.0],
            mu: vec![0],
            label: "infeasible".into(),
        };
        assert!(balanced_assignment(&inst).is_none());
    }
}
