//! The paper's baseline scheme (§VII): client-helper assignments chosen
//! uniformly at random (subject to memory feasibility), then FCFS
//! scheduling — "a naive real-time implementation of parallel SL without
//! proactive decisions on assignments or scheduling".

use super::schedule::{fcfs_schedule, Assignment, Schedule};
use crate::instance::Instance;
use crate::util::rng::Rng;

/// Random memory-feasible assignment. Clients are visited in random order
/// and pick a uniformly random helper among those with enough remaining
/// memory; a handful of restarts deals with unlucky packing orders.
pub fn random_assignment(inst: &Instance, rng: &mut Rng) -> Option<Assignment> {
    'restart: for _ in 0..64 {
        let mut free = inst.mem.clone();
        let mut helper_of = vec![usize::MAX; inst.n_clients];
        let order = rng.permutation(inst.n_clients);
        for j in order {
            let feas: Vec<usize> = (0..inst.n_helpers).filter(|&i| free[i] >= inst.d[j]).collect();
            if feas.is_empty() {
                continue 'restart;
            }
            let i = *rng.choice(&feas);
            free[i] -= inst.d[j];
            helper_of[j] = i;
        }
        return Some(Assignment::new(helper_of));
    }
    None
}

/// Full baseline solve: random assignment + FCFS schedule.
pub fn solve(inst: &Instance, rng: &mut Rng) -> Option<Schedule> {
    Some(fcfs_schedule(inst, random_assignment(inst, rng)?))
}

/// The baseline averaged over `reps` random draws (the paper reports its
/// expected behaviour; a single draw is noisy).
pub fn solve_mean_makespan(inst: &Instance, rng: &mut Rng, reps: usize) -> f64 {
    let mut acc = 0.0;
    for _ in 0..reps {
        let s = solve(inst, rng).expect("feasible instance");
        acc += s.makespan(inst) as f64;
    }
    acc / reps as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};
    use crate::util::prop;

    #[test]
    fn feasible_and_memory_ok() {
        prop::check(40, |rng| {
            let inst = ScenarioCfg::new(Scenario::S2, Model::ResNet101, rng.range_usize(2, 25), rng.range_usize(1, 5), rng.next_u64())
                .generate()
                .quantize(180.0);
            let s = solve(&inst, rng).expect("feasible");
            prop::assert_prop(s.is_feasible(&inst), &format!("{:?}", s.violations(&inst)));
        });
    }

    #[test]
    fn randomness_spreads_assignments() {
        let inst = ScenarioCfg::new(Scenario::S1, Model::Vgg19, 12, 4, 3).generate().quantize(550.0);
        let mut rng = crate::util::rng::Rng::seeded(1);
        let a = random_assignment(&inst, &mut rng).unwrap();
        let b = random_assignment(&inst, &mut rng).unwrap();
        assert_ne!(a.helper_of, b.helper_of, "two draws should differ (overwhelmingly)");
    }

    #[test]
    fn mean_makespan_is_positive() {
        let inst = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 8, 2, 5).generate().quantize(180.0);
        let mut rng = crate::util::rng::Rng::seeded(2);
        assert!(solve_mean_makespan(&inst, &mut rng, 5) > 0.0);
    }
}
