//! Algorithm 1: the ADMM-based fwd-prop workflow optimizer, followed by
//! the optimal bwd-prop schedule (ℙ_f → ℙ_b pipeline of §V, Fig. 3).
//!
//! ℙ_f relaxes the schedule↔assignment coupling Σ_t x_ijt = y_ij p_ij (6)
//! with dual variables λ_ij and an ℓ1 augmented-Lagrangian penalty (the
//! paper deliberately uses ℓ1, not ℓ2 — eq. (16)):
//!
//!   L(w, y, λ) = max_j c^f_j + Σ_ij λ_ij (Σ_t x_ijt − y_ij p_ij)
//!                + ρ/2 Σ_ij |Σ_t x_ijt − y_ij p_ij|
//!
//! and alternates:
//!   line 2 (w-subproblem)  schedule update under (1),(12)–(15),(20);
//!   line 3 (y-subproblem)  assignment update under (4),(5),(11);
//!   line 4 (dual update)   λ_ij += (Σ_t x_ijt − y_ij p_ij);
//!   line 5 (convergence)   (17) stationary y and (18) stationary objective;
//!   line 6 (correction)    re-solve w with (6) imposed (schedule follows y*).
//!
//! Subproblem solvers (footnote 7 allows inexact methods):
//!
//! * **w-subproblem.** Constraint (20) pins each client's full fwd
//!   processing to (effectively) one helper, so w decomposes into a
//!   per-client helper choice κ_j plus per-helper preemptive fwd
//!   scheduling. For a fixed κ the optimal fwd objective per helper is
//!   evaluated by the preemptive LDT rule
//!   ([`bwd::preemptive_cost_contiguous`]) — cost-only, allocation-free.
//!   Over κ we run greedy insertion + steepest-descent local search on an
//!   **incrementally maintained per-helper membership structure**
//!   ([`Members`]): a candidate move rebuilds only the two touched
//!   helpers' job lists in a reusable scratch buffer, O(move) instead of
//!   the former O(J) full-fleet scans per candidate.
//! * **y-subproblem.** Separable per client given the schedule volumes
//!   n_ij = Σ_t x_ijt, under the knapsack-style memory constraint (5):
//!   a generalized assignment problem, solved by depth-first
//!   branch-and-bound with a min-cost completion bound (exact for the
//!   paper's sizes; falls back to its own greedy incumbent on node-cap).

use super::bwd::{self, CostScratch};
use super::schedule::{Assignment, Schedule, SlotRuns};
use crate::instance::Instance;

/// Algorithm 1 inputs (paper notation in comments).
#[derive(Clone, Debug)]
pub struct AdmmCfg {
    /// ADMM penalty parameter ρ.
    pub rho: f64,
    /// ε1: assignments are stationary when fewer than this many y-entries
    /// change between iterations (paper uses Σ|Δy| < ε1; one reassignment
    /// flips two entries).
    pub eps_assign: usize,
    /// ε2: objective stationarity threshold (slots).
    pub eps_obj: f64,
    /// τ_max.
    pub max_iters: usize,
    /// Local-search sweeps per w-subproblem solve.
    pub w_sweeps: usize,
    /// Node cap for the exact y-subproblem B&B.
    pub y_node_cap: usize,
}

impl Default for AdmmCfg {
    fn default() -> Self {
        AdmmCfg { rho: 0.25, eps_assign: 1, eps_obj: 0.5, max_iters: 8, w_sweeps: 3, y_node_cap: 200_000 }
    }
}

/// Solve result with convergence diagnostics.
#[derive(Clone, Debug)]
pub struct AdmmResult {
    pub schedule: Schedule,
    /// ADMM iterations executed (≤ τ_max).
    pub iters: usize,
    /// Whether (17) ∧ (18) triggered the early exit.
    pub converged: bool,
    /// max_j c^f_j after each w-subproblem solve.
    pub fwd_history: Vec<u32>,
}

/// Entry point: Algorithm 1 then Algorithm 2 (ℙ_b) for the bwd direction.
pub fn solve(inst: &Instance, cfg: &AdmmCfg) -> Option<AdmmResult> {
    let (assignment, fwd, iters, converged, fwd_history) = solve_fwd(inst, cfg)?;
    let schedule = bwd::complete_with_optimal_bwd(inst, assignment, fwd);
    Some(AdmmResult { schedule, iters, converged, fwd_history })
}

/// Algorithm 1 proper: returns (y*, x*) plus diagnostics.
#[allow(clippy::type_complexity)]
pub fn solve_fwd(inst: &Instance, cfg: &AdmmCfg) -> Option<(Assignment, Vec<SlotRuns>, usize, bool, Vec<u32>)> {
    let jn = inst.n_clients;
    let in_ = inst.n_helpers;
    let ne = jn * in_;
    let mut lambda = vec![0.0f64; ne];
    // y^(0) = 0 — no client assigned yet (paper's initialization).
    let mut y: Vec<Option<usize>> = vec![None; jn];
    let mut kappa: Vec<usize> = vec![0; jn];
    let mut fwd_history = Vec::new();
    let mut iters = 0;
    let mut converged = false;
    let mut prev_obj: Option<u32> = None;
    let mut scratch = WScratch::default();
    // ℓ1 dual residual Σ|n_ij − y_ij p_ij| accumulated across iterations
    // (slot units are integral, so the u64 cast at report time is exact).
    let mut residual_sum = 0.0f64;
    let mut sp = crate::obs::span("solver", "admm/solve-fwd");

    for _tau in 0..cfg.max_iters {
        iters += 1;
        // --- line 2: w-subproblem --------------------------------------
        kappa = solve_w(inst, cfg, &lambda, &y, &mut scratch);
        let fwd_obj = eval_fwd(inst, &kappa, &mut scratch);
        fwd_history.push(fwd_obj);

        // --- line 3: y-subproblem ----------------------------------------
        let new_y = solve_y(inst, cfg, &lambda, &kappa)?;

        // --- line 4: dual update -----------------------------------------
        // n_ij = p_ij if κ_j = i else 0; target y_ij p_ij.
        for j in 0..jn {
            for i in 0..in_ {
                let e = inst.edge(i, j);
                let n = if kappa[j] == i { inst.p[e] as f64 } else { 0.0 };
                let target = if new_y[j] == Some(i) { inst.p[e] as f64 } else { 0.0 };
                lambda[e] += n - target;
                residual_sum += (n - target).abs();
            }
        }

        // --- line 5: convergence flags (17) & (18) ------------------------
        let changed: usize = (0..jn).filter(|&j| y[j] != new_y[j]).count() * 2;
        let obj_stationary = prev_obj.map(|p| (p as f64 - fwd_obj as f64).abs() < cfg.eps_obj).unwrap_or(false);
        y = new_y;
        prev_obj = Some(fwd_obj);
        if changed < cfg.eps_assign.max(1) && obj_stationary {
            converged = true;
            break;
        }
    }
    sp.arg("iters", iters as u64);
    drop(sp);
    crate::obs::counter_add("admm.iters", iters as u64);
    crate::obs::counter_add("admm.residual", residual_sum as u64);

    // --- line 6: feasibility correction (19) — impose (6): κ := y* -----
    let final_assignment: Vec<usize> = (0..jn)
        .map(|j| y[j].unwrap_or(kappa[j]))
        .collect();
    // Memory could still be violated if y never became feasible (cannot
    // happen: solve_y enforces (5)); assert in debug builds.
    let assignment = Assignment::new(final_assignment);
    debug_assert!(assignment.memory_ok(inst), "y-subproblem must enforce memory");
    let fwd = schedule_fwd_given_assignment(inst, &assignment.helper_of);
    Some((assignment, fwd, iters, converged, fwd_history))
}

// ---------------------------------------------------------------------------
// w-subproblem
// ---------------------------------------------------------------------------

/// Per-edge penalty cost of the w-subproblem for scheduling client j's fwd
/// task on helper i, given (λ, y): the λ/ρ terms of (16) with
/// n_ij = p_ij · [κ_j = i] (constant-per-client terms dropped).
fn w_edge_cost(inst: &Instance, lambda: &[f64], y: &[Option<usize>], i: usize, j: usize, rho: f64) -> f64 {
    let e = inst.edge(i, j);
    let p = inst.p[e] as f64;
    match y[j] {
        Some(h) if h == i => 0.0,
        Some(h) => {
            let eh = inst.edge(h, j);
            let ph = inst.p[eh] as f64;
            lambda[e] * p + rho / 2.0 * p - lambda[eh] * ph + rho / 2.0 * ph
        }
        None => lambda[e] * p + rho / 2.0 * p,
    }
}

/// Incrementally maintained per-helper membership: O(1) insert/remove
/// (swap-remove via a per-client position index), so a local-search move
/// touches only the two helpers involved — never the whole fleet. Member
/// order within a helper is irrelevant: every evaluator re-sorts jobs by
/// (release, id) internally.
struct Members {
    lists: Vec<Vec<usize>>,
    pos: Vec<usize>,
}

impl Members {
    fn new(n_helpers: usize, n_clients: usize) -> Members {
        Members { lists: vec![Vec::new(); n_helpers], pos: vec![usize::MAX; n_clients] }
    }

    fn insert(&mut self, i: usize, j: usize) {
        self.pos[j] = self.lists[i].len();
        self.lists[i].push(j);
    }

    fn remove(&mut self, i: usize, j: usize) {
        let k = self.pos[j];
        debug_assert_eq!(self.lists[i][k], j);
        self.lists[i].swap_remove(k);
        if let Some(&moved) = self.lists[i].get(k) {
            self.pos[moved] = k;
        }
        self.pos[j] = usize::MAX;
    }

    fn move_client(&mut self, j: usize, from: usize, to: usize) {
        self.remove(from, j);
        self.insert(to, j);
    }
}

/// Reusable buffers for the w-subproblem's candidate evaluations.
#[derive(Default)]
struct WScratch {
    jobs: Vec<bwd::Job>,
    cost: CostScratch,
}

impl WScratch {
    /// Fill the job buffer from `clients` on helper `i`, optionally
    /// skipping one client and/or appending an extra one.
    fn fill_jobs(&mut self, inst: &Instance, i: usize, clients: &[usize], skip: Option<usize>, extra: Option<usize>) {
        self.jobs.clear();
        for &j in clients {
            if Some(j) == skip {
                continue;
            }
            let e = inst.edge(i, j);
            self.jobs.push(bwd::Job { id: j, release: inst.r[e], proc: inst.p[e], tail: inst.l[e] });
        }
        if let Some(j) = extra {
            let e = inst.edge(i, j);
            self.jobs.push(bwd::Job { id: j, release: inst.r[e], proc: inst.p[e], tail: inst.l[e] });
        }
    }
}

/// max c^f over one helper's client set (exact optimal value via the
/// preemptive LDT rule — allocation-free).
fn helper_fwd_obj(
    inst: &Instance,
    i: usize,
    clients: &[usize],
    skip: Option<usize>,
    extra: Option<usize>,
    scratch: &mut WScratch,
) -> u32 {
    scratch.fill_jobs(inst, i, clients, skip, extra);
    if scratch.jobs.is_empty() {
        return 0;
    }
    let (jobs, cost) = (&scratch.jobs, &mut scratch.cost);
    bwd::preemptive_cost_contiguous(jobs, cost)
}

/// Evaluate a helper-choice vector κ: optimal per-helper preemptive fwd
/// objective (max_j c^f_j).
fn eval_fwd(inst: &Instance, kappa: &[usize], scratch: &mut WScratch) -> u32 {
    let members = Assignment::new(kappa.to_vec()).members_by_helper(inst.n_helpers);
    let mut obj = 0;
    for (i, clients) in members.iter().enumerate() {
        obj = obj.max(helper_fwd_obj(inst, i, clients, None, None, scratch));
    }
    obj
}

/// Optimal preemptive fwd schedule for a fixed assignment: per helper,
/// Baker's block algorithm with release r_ij, proc p_ij, tail l_ij
/// (minimizes max c^f on that helper — optimal for ℙ_f given y).
pub fn schedule_fwd_given_assignment(inst: &Instance, helper_of: &[usize]) -> Vec<SlotRuns> {
    let mut out = vec![SlotRuns::new(); inst.n_clients];
    let members = Assignment::new(helper_of.to_vec()).members_by_helper(inst.n_helpers);
    for (i, clients) in members.iter().enumerate() {
        if clients.is_empty() {
            continue;
        }
        let jobs: Vec<bwd::Job> = clients
            .iter()
            .map(|&j| {
                let e = inst.edge(i, j);
                bwd::Job { id: j, release: inst.r[e], proc: inst.p[e], tail: inst.l[e] }
            })
            .collect();
        let solved = bwd::preemptive_min_max_tail_contiguous(&jobs);
        for (k, &j) in clients.iter().enumerate() {
            out[j] = solved[k].clone();
        }
    }
    out
}

/// w-subproblem: choose κ minimizing max_j c^f + Σ_j w_edge_cost(κ_j, j).
/// Greedy insertion (clients by descending p on their fastest helper) then
/// steepest-descent relocation sweeps with exact incremental evaluation
/// over the [`Members`] structure.
fn solve_w(inst: &Instance, cfg: &AdmmCfg, lambda: &[f64], y: &[Option<usize>], scratch: &mut WScratch) -> Vec<usize> {
    let jn = inst.n_clients;
    let in_ = inst.n_helpers;

    // Greedy: order clients by the work they bring (big first).
    let mut order: Vec<usize> = (0..jn).collect();
    order.sort_by_key(|&j| {
        let w: u32 = (0..in_).map(|i| inst.p[inst.edge(i, j)]).min().unwrap_or(0);
        std::cmp::Reverse(w)
    });
    // Per-helper running membership; evaluate insertion exactly per helper.
    let mut members = Members::new(in_, jn);
    let mut helper_cf: Vec<u32> = vec![0; in_]; // max c^f on that helper
    let mut kappa = vec![0usize; jn];
    for &j in &order {
        let mut best: Option<(f64, usize, u32)> = None;
        for i in 0..in_ {
            let cf_i = helper_fwd_obj(inst, i, &members.lists[i], None, Some(j), scratch);
            let global = helper_cf
                .iter()
                .enumerate()
                .map(|(k, &v)| if k == i { cf_i } else { v })
                .max()
                .unwrap_or(0);
            let cost = global as f64 + w_edge_cost(inst, lambda, y, i, j, cfg.rho);
            if best.map(|(b, _, _)| cost < b).unwrap_or(true) {
                best = Some((cost, i, cf_i));
            }
        }
        let (_, i, cf_i) = best.unwrap();
        members.insert(i, j);
        helper_cf[i] = cf_i;
        kappa[j] = i;
    }

    // Local search: relocate single clients while it helps. Incremental
    // evaluation — a move only perturbs the source and destination
    // helpers, so we keep per-helper max-c^f values and per-client
    // penalties and recompute exactly two helpers per candidate.
    let mut helper_cf: Vec<u32> = (0..in_)
        .map(|i| helper_fwd_obj(inst, i, &members.lists[i], None, None, scratch))
        .collect();
    let mut penalty: Vec<f64> = (0..jn).map(|j| w_edge_cost(inst, lambda, y, kappa[j], j, cfg.rho)).collect();
    let total = |helper_cf: &[u32], penalty: &[f64]| -> f64 {
        *helper_cf.iter().max().unwrap_or(&0) as f64 + penalty.iter().sum::<f64>()
    };
    let mut cur = total(&helper_cf, &penalty);
    for _ in 0..cfg.w_sweeps {
        let mut improved = false;
        for j in 0..jn {
            let orig = kappa[j];
            let mut best: (f64, usize, u32, u32) = (cur, orig, helper_cf[orig], 0);
            let src_cf = helper_fwd_obj(inst, orig, &members.lists[orig], Some(j), None, scratch);
            // Σ penalties in client-index order (kept as one pass per j so
            // float rounding matches the pre-refactor evaluation exactly).
            let psum: f64 = penalty.iter().sum();
            for i in 0..in_ {
                if i == orig {
                    continue;
                }
                let dst_cf = helper_fwd_obj(inst, i, &members.lists[i], None, Some(j), scratch);
                let max_cf = (0..in_)
                    .map(|h| {
                        if h == orig {
                            src_cf
                        } else if h == i {
                            dst_cf
                        } else {
                            helper_cf[h]
                        }
                    })
                    .max()
                    .unwrap_or(0);
                let v = max_cf as f64 + psum - penalty[j]
                    + w_edge_cost(inst, lambda, y, i, j, cfg.rho);
                if v + 1e-9 < best.0 {
                    best = (v, i, src_cf, dst_cf);
                }
            }
            if best.1 != orig {
                let (v, i, src_cf, dst_cf) = best;
                helper_cf[orig] = src_cf;
                helper_cf[i] = dst_cf;
                penalty[j] = w_edge_cost(inst, lambda, y, i, j, cfg.rho);
                members.move_client(j, orig, i);
                kappa[j] = i;
                cur = v;
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }
    kappa
}

// ---------------------------------------------------------------------------
// y-subproblem
// ---------------------------------------------------------------------------

/// Relative cost of assigning y_j = i given the schedule volumes implied
/// by κ (n_ij = p_ij·[κ_j = i]); the i = κ_j choice costs 0 by
/// construction, others pay the λ/ρ mismatch on both edges.
fn y_edge_cost(inst: &Instance, lambda: &[f64], kappa: &[usize], rho: f64, i: usize, j: usize) -> f64 {
    if kappa[j] == i {
        return 0.0;
    }
    let e = inst.edge(i, j);
    let ek = inst.edge(kappa[j], j);
    let p = inst.p[e] as f64;
    let pk = inst.p[ek] as f64;
    -lambda[e] * p + rho / 2.0 * p + lambda[ek] * pk + rho / 2.0 * pk
}

/// Exact (node-capped) B&B for the memory-constrained assignment — the
/// generalized assignment y-subproblem. Clients are branched in order of
/// decreasing footprint d_j; the bound adds each remaining client's
/// cheapest edge.
fn solve_y(inst: &Instance, cfg: &AdmmCfg, lambda: &[f64], kappa: &[usize]) -> Option<Vec<Option<usize>>> {
    let jn = inst.n_clients;
    let in_ = inst.n_helpers;
    let mut order: Vec<usize> = (0..jn).collect();
    order.sort_by(|&a, &b| inst.d[b].partial_cmp(&inst.d[a]).unwrap());

    // Greedy incumbent: cheapest feasible helper per client (big first).
    let greedy = {
        let mut free = inst.mem.clone();
        let mut out = vec![usize::MAX; jn];
        for &j in &order {
            let mut feas: Vec<usize> = (0..in_).filter(|&i| free[i] >= inst.d[j]).collect();
            feas.sort_by(|&a, &b| {
                y_edge_cost(inst, lambda, kappa, cfg.rho, a, j)
                    .partial_cmp(&y_edge_cost(inst, lambda, kappa, cfg.rho, b, j))
                    .unwrap()
            });
            let i = *feas.first()?;
            free[i] -= inst.d[j];
            out[j] = i;
        }
        Some(out)
    }?;
    let greedy_cost: f64 = (0..jn).map(|j| y_edge_cost(inst, lambda, kappa, cfg.rho, greedy[j], j)).sum();

    // Min possible cost per client (ignoring memory) for the bound.
    let min_cost: Vec<f64> = (0..jn)
        .map(|j| {
            (0..in_)
                .map(|i| y_edge_cost(inst, lambda, kappa, cfg.rho, i, j))
                .fold(f64::INFINITY, f64::min)
        })
        .collect();
    let suffix_min: Vec<f64> = {
        let mut s = vec![0.0; jn + 1];
        for k in (0..jn).rev() {
            s[k] = s[k + 1] + min_cost[order[k]];
        }
        s
    };

    struct Bb<'a> {
        inst: &'a Instance,
        lambda: &'a [f64],
        kappa: &'a [usize],
        rho: f64,
        order: &'a [usize],
        suffix_min: &'a [f64],
        best_cost: f64,
        best: Vec<usize>,
        nodes: usize,
        cap: usize,
    }
    impl<'a> Bb<'a> {
        fn dfs(&mut self, k: usize, free: &mut Vec<f64>, cur: &mut Vec<usize>, cost: f64) {
            self.nodes += 1;
            if self.nodes > self.cap {
                return;
            }
            if cost + self.suffix_min[k] >= self.best_cost - 1e-12 {
                return;
            }
            if k == self.order.len() {
                self.best_cost = cost;
                self.best = cur.clone();
                return;
            }
            let j = self.order[k];
            let mut choices: Vec<(f64, usize)> = (0..self.inst.n_helpers)
                .filter(|&i| free[i] >= self.inst.d[j])
                .map(|i| (y_edge_cost(self.inst, self.lambda, self.kappa, self.rho, i, j), i))
                .collect();
            choices.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for (c, i) in choices {
                free[i] -= self.inst.d[j];
                cur[j] = i;
                self.dfs(k + 1, free, cur, cost + c);
                free[i] += self.inst.d[j];
            }
        }
    }
    let mut bb = Bb {
        inst,
        lambda,
        kappa,
        rho: cfg.rho,
        order: &order,
        suffix_min: &suffix_min,
        best_cost: greedy_cost + 1e-9,
        best: greedy,
        nodes: 0,
        cap: cfg.y_node_cap,
    };
    let mut free = inst.mem.clone();
    let mut cur = vec![usize::MAX; jn];
    bb.dfs(0, &mut free, &mut cur, 0.0);
    crate::obs::counter_add("admm.y_nodes", bb.nodes as u64);
    Some(bb.best.into_iter().map(Some).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};
    use crate::solver::{baseline, greedy};
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn feasible_on_scenarios() {
        prop::check(25, |rng| {
            let j = rng.range_usize(2, 16);
            let i = rng.range_usize(1, 4);
            let scen = if rng.chance(0.5) { Scenario::S1 } else { Scenario::S2 };
            let inst = ScenarioCfg::new(scen, Model::ResNet101, j, i, rng.next_u64()).generate().quantize(180.0);
            let res = solve(&inst, &AdmmCfg::default()).expect("feasible");
            prop::assert_prop(
                res.schedule.is_feasible(&inst),
                &format!("violations: {:?}", res.schedule.violations(&inst)),
            );
        });
    }

    #[test]
    fn beats_or_matches_baseline_on_heterogeneous() {
        // The headline behaviour (§VII Fig 7, Scenario 2): ADMM ≤ baseline
        // on average over seeds.
        let mut rng = Rng::seeded(99);
        let mut admm_total = 0.0;
        let mut base_total = 0.0;
        for seed in 0..6u64 {
            let inst = ScenarioCfg::new(Scenario::S2, Model::Vgg19, 12, 3, 400 + seed).generate().quantize(550.0);
            let a = solve(&inst, &AdmmCfg::default()).unwrap();
            admm_total += a.schedule.makespan(&inst) as f64;
            base_total += baseline::solve_mean_makespan(&inst, &mut rng, 5);
        }
        assert!(
            admm_total <= base_total * 1.02,
            "ADMM {admm_total} should not lose to baseline {base_total}"
        );
    }

    #[test]
    fn competitive_with_balanced_greedy() {
        // On medium heterogeneous instances ADMM should win or tie.
        let mut wins = 0;
        let mut total = 0;
        for seed in 0..8u64 {
            let inst = ScenarioCfg::new(Scenario::S2, Model::ResNet101, 15, 4, 500 + seed).generate().quantize(180.0);
            let a = solve(&inst, &AdmmCfg::default()).unwrap().schedule.makespan(&inst);
            let g = greedy::solve(&inst).unwrap().makespan(&inst);
            if a <= g {
                wins += 1;
            }
            total += 1;
        }
        assert!(wins * 2 >= total, "ADMM won only {wins}/{total} vs balanced-greedy");
    }

    #[test]
    fn converges_within_few_iterations() {
        // Paper: "< 5 iterations of Algorithm 1" on their instances.
        let inst = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 10, 2, 42).generate().quantize(180.0);
        let res = solve(&inst, &AdmmCfg::default()).unwrap();
        assert!(res.iters <= 8);
        assert!(!res.fwd_history.is_empty());
    }

    #[test]
    fn respects_memory() {
        prop::check(20, |rng| {
            let inst = ScenarioCfg::new(Scenario::S2, Model::Vgg19, rng.range_usize(4, 14), rng.range_usize(2, 4), rng.next_u64())
                .generate()
                .quantize(550.0);
            let res = solve(&inst, &AdmmCfg::default()).unwrap();
            prop::assert_prop(res.schedule.assignment.memory_ok(&inst), "memory (5)");
        });
    }

    #[test]
    fn fwd_schedule_optimal_per_helper() {
        // For a fixed assignment, our fwd scheduler is Baker-optimal per
        // helper; cross-check that no FCFS ordering beats it on makespan.
        let mut rng = Rng::seeded(7);
        let inst = crate::solver::schedule::tests::tiny_instance(&mut rng, 6, 1);
        let helper_of = vec![0; 6];
        let slots = schedule_fwd_given_assignment(&inst, &helper_of);
        let assignment = Assignment::new(helper_of.clone());
        let fcfs = crate::solver::schedule::fcfs_schedule(&inst, assignment);
        let cf_opt = (0..6)
            .map(|j| slots[j].finish() + inst.l[inst.edge(0, j)])
            .max()
            .unwrap();
        let cf_fcfs = fcfs.fwd_makespan(&inst);
        assert!(cf_opt <= cf_fcfs, "opt fwd {cf_opt} > fcfs {cf_fcfs}");
    }

    #[test]
    fn members_structure_tracks_moves() {
        let mut m = Members::new(3, 5);
        for j in 0..5 {
            m.insert(j % 3, j);
        }
        assert_eq!(m.lists[0], vec![0, 3]);
        m.move_client(0, 0, 2);
        assert_eq!(m.lists[0], vec![3]);
        assert!(m.lists[2].contains(&0) && m.lists[2].contains(&2));
        m.move_client(3, 0, 1);
        assert!(m.lists[0].is_empty());
        assert_eq!(m.pos[3], m.lists[1].iter().position(|&x| x == 3).unwrap());
    }

    #[test]
    fn cost_only_eval_matches_materialized_schedule() {
        // helper_fwd_obj (LDT, cost-only) must equal the max c^f of the
        // materialized Baker schedule for the same member set.
        prop::check(40, |rng| {
            let jn = rng.range_usize(1, 10);
            let inst = crate::solver::schedule::tests::tiny_instance(rng, jn, 2);
            let helper_of: Vec<usize> = (0..jn).map(|_| rng.below(2)).collect();
            let slots = schedule_fwd_given_assignment(&inst, &helper_of);
            let mut scratch = WScratch::default();
            for i in 0..2 {
                let clients: Vec<usize> = (0..jn).filter(|&j| helper_of[j] == i).collect();
                let cost = helper_fwd_obj(&inst, i, &clients, None, None, &mut scratch);
                let want = clients
                    .iter()
                    .map(|&j| slots[j].finish() + inst.l[inst.edge(i, j)])
                    .max()
                    .unwrap_or(0);
                prop::assert_prop(cost == want, &format!("cost {cost} != materialized {want}"));
            }
        });
    }
}
