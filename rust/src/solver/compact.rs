//! Schedule compaction for uneven datasets (§V-B, last paragraph):
//!
//! > "we can simply remove from the obtained schedules x* and z* the
//! > clients whose samples are completely processed (after a number of
//! > batch updates) and 'move' the remaining clients earlier in the
//! > schedules (subject to availability of their tasks at the helpers).
//! > Moreover, the assignments y* do not need to change since helpers
//! > have already allocated memory for the model copies."
//!
//! We drop the inactive clients' slots and re-pack the survivors,
//! preserving each helper's processing order (segment by segment) while
//! respecting release times (1) and fwd→bwd precedence (2). Order
//! preservation keeps the compaction O(work) and never reorders
//! priorities decided by the solver.

use super::schedule::{Schedule, SlotRuns};
use crate::instance::Instance;

/// Compact `schedule` to the subset of clients with `active[j] == true`.
/// Inactive clients end up with empty run sets; assignments (and thus
/// helper memory reservations) are preserved verbatim. The segment stream
/// is read straight off the run-length representation — no slot-by-slot
/// re-derivation — so compaction is O(#runs log #runs) per helper.
pub fn compact(inst: &Instance, schedule: &Schedule, active: &[bool]) -> Schedule {
    assert_eq!(active.len(), inst.n_clients);
    let mut fwd = vec![SlotRuns::new(); inst.n_clients];
    let mut bwd = vec![SlotRuns::new(); inst.n_clients];

    for (i, clients) in schedule.assignment.members_by_helper(inst.n_helpers).into_iter().enumerate() {
        // Original segment stream of this helper, in slot order.
        #[derive(Clone, Copy)]
        struct Seg {
            client: usize,
            is_bwd: bool,
            start: u32,
            len: u32,
        }
        let mut segs: Vec<Seg> = Vec::new();
        for &j in &clients {
            if !active[j] {
                continue;
            }
            for (runs, is_bwd) in [(&schedule.fwd[j], false), (&schedule.bwd[j], true)] {
                for &(start, len) in runs.runs() {
                    segs.push(Seg { client: j, is_bwd, start, len });
                }
            }
        }
        segs.sort_by_key(|s| s.start);

        // Re-pack: each segment starts at max(helper clock, its task's
        // earliest legal slot). fwd ready at r_ij; bwd ready at
        // (new) fwd finish + l + l'. Within a task, later segments are
        // additionally constrained by the helper clock only (they already
        // follow their predecessors in stream order).
        let mut clock: u32 = 0;
        for seg in &segs {
            let e = inst.edge(i, seg.client);
            let ready = if seg.is_bwd {
                fwd[seg.client].finish() + inst.l[e] + inst.lp[e]
            } else {
                inst.r[e]
            };
            let start = clock.max(ready);
            let out = if seg.is_bwd { &mut bwd[seg.client] } else { &mut fwd[seg.client] };
            out.push_run(start, seg.len);
            clock = start + seg.len;
        }
    }
    Schedule { assignment: schedule.assignment.clone(), fwd, bwd }
}

/// Simulate an uneven-dataset epoch: clients own `batches[j]` batches;
/// after each batch update, finished clients drop out and the schedule is
/// compacted. Returns the total epoch makespan in slots (sum of the
/// per-phase makespans) and the number of compaction phases.
pub fn uneven_epoch_makespan(inst: &Instance, schedule: &Schedule, batches: &[usize]) -> (u64, usize) {
    assert_eq!(batches.len(), inst.n_clients);
    let mut remaining: Vec<usize> = batches.to_vec();
    let mut total: u64 = 0;
    let mut phases = 0;
    loop {
        let active: Vec<bool> = remaining.iter().map(|&b| b > 0).collect();
        if !active.iter().any(|&a| a) {
            break;
        }
        let compacted = compact(inst, schedule, &active);
        // Batch updates this phase: min remaining among active clients —
        // the schedule repeats unchanged until the next client finishes.
        let step = remaining.iter().filter(|&&b| b > 0).min().copied().unwrap();
        let span = compacted.makespan(inst) as u64;
        total += span * step as u64;
        phases += 1;
        for b in remaining.iter_mut() {
            *b = b.saturating_sub(step);
        }
    }
    (total, phases)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};
    use crate::solver::{admm, greedy};
    use crate::util::prop;

    fn setup(seed: u64) -> (Instance, Schedule) {
        let inst = ScenarioCfg::new(Scenario::S2, Model::ResNet101, 12, 3, seed).generate().quantize(180.0);
        let s = admm::solve(&inst, &admm::AdmmCfg::default()).unwrap().schedule;
        (inst, s)
    }

    #[test]
    fn all_active_is_feasible_and_not_worse() {
        prop::check(12, |rng| {
            let (inst, s) = setup(rng.next_u64());
            let c = compact(&inst, &s, &vec![true; inst.n_clients]);
            prop::assert_prop(c.is_feasible(&inst), &format!("{:?}", c.violations(&inst)));
            prop::assert_prop(c.makespan(&inst) <= s.makespan(&inst), "compaction never hurts");
        });
    }

    #[test]
    fn dropping_clients_shrinks_makespan_monotonically() {
        prop::check(10, |rng| {
            let (inst, s) = setup(rng.next_u64());
            let mut active = vec![true; inst.n_clients];
            let full = compact(&inst, &s, &active).makespan(&inst);
            // Drop a random half.
            let mut dropped = 0;
            for j in 0..inst.n_clients {
                if rng.chance(0.5) && dropped + 1 < inst.n_clients {
                    active[j] = false;
                    dropped += 1;
                }
            }
            let half = compact(&inst, &s, &active);
            // Feasibility must hold on the surviving subset; inactive
            // clients have no slots (checker sees count mismatch), so
            // check manually: survivors only.
            for j in 0..inst.n_clients {
                if !active[j] {
                    prop::assert_prop(half.fwd[j].is_empty() && half.bwd[j].is_empty(), "inactive cleared");
                }
            }
            let surv_makespan = (0..inst.n_clients)
                .filter(|&j| active[j])
                .map(|j| half.completion(&inst, j))
                .max()
                .unwrap_or(0);
            prop::assert_prop(surv_makespan <= full, "fewer clients, earlier finish");
        });
    }

    #[test]
    fn assignment_preserved() {
        let (inst, s) = setup(5);
        let mut active = vec![true; inst.n_clients];
        active[0] = false;
        let c = compact(&inst, &s, &active);
        assert_eq!(c.assignment.helper_of, s.assignment.helper_of);
    }

    #[test]
    fn survivors_respect_constraints() {
        prop::check(10, |rng| {
            let (inst, s) = setup(rng.next_u64());
            let active: Vec<bool> = (0..inst.n_clients).map(|j| j % 2 == 0 || rng.chance(0.5)).collect();
            let c = compact(&inst, &s, &active);
            for j in 0..inst.n_clients {
                if !active[j] {
                    continue;
                }
                let i = c.assignment.helper_of[j];
                let e = inst.edge(i, j);
                prop::assert_prop(c.fwd[j].len() == inst.p[e], "(6)");
                prop::assert_prop(c.bwd[j].len() == inst.pp[e], "(7)");
                if let Some(first) = c.fwd[j].first_slot() {
                    prop::assert_prop(first >= inst.r[e], "(1)");
                }
                if let Some(bfirst) = c.bwd[j].first_slot() {
                    let ready = c.fwd_finish(j) + inst.l[e] + inst.lp[e];
                    prop::assert_prop(bfirst >= ready, "(2)");
                }
            }
            // (3): no helper slot double-booked among survivors.
            let mut busy = std::collections::HashSet::new();
            for j in 0..inst.n_clients {
                let i = c.assignment.helper_of[j];
                for t in c.fwd[j].iter_slots().chain(c.bwd[j].iter_slots()) {
                    prop::assert_prop(busy.insert((i, t)), "(3) overlap");
                }
            }
        });
    }

    #[test]
    fn uneven_epoch_accounts_all_batches() {
        let (inst, s) = setup(9);
        let batches: Vec<usize> = (0..inst.n_clients).map(|j| 1 + j % 3).collect();
        let (total, phases) = uneven_epoch_makespan(&inst, &s, &batches);
        assert!(phases >= 1 && phases <= 3);
        let single = s.makespan(&inst) as u64;
        let max_batches = *batches.iter().max().unwrap() as u64;
        assert!(total <= single * max_batches, "compaction saves vs naive repeat");
        assert!(total >= single, "at least one full batch span");
    }

    #[test]
    fn compaction_beats_naive_repeat_for_greedy_too() {
        let inst = ScenarioCfg::new(Scenario::S1, Model::Vgg19, 10, 2, 3).generate().quantize(550.0);
        let s = greedy::solve(&inst).unwrap();
        let batches = vec![3, 1, 1, 2, 1, 3, 1, 2, 1, 1];
        let (total, _) = uneven_epoch_makespan(&inst, &s, &batches);
        let naive = s.makespan(&inst) as u64 * 3;
        assert!(total <= naive);
    }
}
