//! §VI model extension: preemption (task-switching) costs.
//!
//! When helper i switches between tasks, a context-switch penalty μ_i is
//! paid; the paper folds it into the completion-time accounting
//! (modified (13)/(9)) as μ_i Σ_t |x_ijt − x_ij(t+1)| per client. With
//! μ > 0, heavily fragmented preemptive schedules lose their edge, so we
//! also provide a *defragmentation* post-pass that greedily merges a
//! client's slots into fewer runs when that does not push any completion
//! beyond the original switch-cost-adjusted makespan.

use super::schedule::Schedule;
use crate::instance::Instance;

/// Switch-cost-adjusted makespan (re-export of the Schedule method, kept
/// here so the extension has one home).
pub fn adjusted_makespan(s: &Schedule, inst: &Instance) -> u32 {
    s.makespan_with_switch_cost(inst)
}

/// Defragment: per helper, re-pack each client's slots into contiguous
/// runs using a non-preemptive FCFS in order of original first-slot,
/// keeping release and precedence constraints; accept the repacked
/// schedule iff it does not increase the adjusted makespan.
pub fn defragment(s: &Schedule, inst: &Instance) -> Schedule {
    let base = adjusted_makespan(s, inst);
    let repacked = super::schedule::fcfs_schedule(inst, s.assignment.clone());
    if adjusted_makespan(&repacked, inst) <= base {
        repacked
    } else {
        s.clone()
    }
}

/// Evaluate the preemption-frequency trade-off (paper Fig 6 logic): the
/// same continuous instance quantized at different slot lengths gives
/// different preemption granularity; with μ > 0 the finest slots stop
/// being free. Returns (slot_ms, adjusted makespan ms) rows.
pub fn slot_length_tradeoff<F>(slot_lengths_ms: &[f64], mut solve_at: F) -> Vec<(f64, f64)>
where
    F: FnMut(f64) -> (u32, f64),
{
    slot_lengths_ms
        .iter()
        .map(|&ms| {
            let (slots, slot_ms) = solve_at(ms);
            (ms, slots as f64 * slot_ms)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};
    use crate::solver::admm::{self, AdmmCfg};

    #[test]
    fn switch_cost_penalizes_fragmentation() {
        let inst = ScenarioCfg::new(Scenario::S2, Model::ResNet101, 10, 2, 11)
            .with_switch_cost(360.0) // 2 slots at 180 ms
            .generate()
            .quantize(180.0);
        let res = admm::solve(&inst, &AdmmCfg::default()).unwrap();
        let plain = res.schedule.makespan(&inst);
        let adj = adjusted_makespan(&res.schedule, &inst);
        assert!(adj >= plain, "switch cost can only add");
        if res.schedule.preemptions() > 0 {
            assert!(adj > plain);
        }
    }

    #[test]
    fn defragment_never_hurts_adjusted_makespan() {
        for seed in 0..5u64 {
            let inst = ScenarioCfg::new(Scenario::S2, Model::Vgg19, 12, 3, 70 + seed)
                .with_switch_cost(550.0)
                .generate()
                .quantize(550.0);
            let res = admm::solve(&inst, &AdmmCfg::default()).unwrap();
            let defrag = defragment(&res.schedule, &inst);
            assert!(adjusted_makespan(&defrag, &inst) <= adjusted_makespan(&res.schedule, &inst));
            assert!(defrag.is_feasible(&inst));
        }
    }

    #[test]
    fn tradeoff_rows_match_inputs() {
        let rows = slot_length_tradeoff(&[200.0, 150.0, 50.0], |ms| ((1000.0 / ms) as u32, ms));
        assert_eq!(rows.len(), 3);
        for (ms, adj) in rows {
            assert!(adj > 0.0 && ms > 0.0);
        }
    }
}
