//! The paper's contribution: solution methods for the joint client-helper
//! assignment + scheduling problem ℙ (minimize the batch-training
//! makespan of parallel split learning).
//!
//! | Module | Paper artifact |
//! |---|---|
//! | [`schedule`] | decision variables x, z, y as run-length [`schedule::SlotRuns`]; constraints (1)–(9) with an interval-sweep checker (plus the transport-aware `violations_under` with a per-helper concurrent-transfer occupancy sweep); FCFS |
//! | [`admm`] | Algorithm 1 (ADMM-based ℙ_f); allocation-free w-subproblem over an incremental membership structure |
//! | [`bwd`] | Algorithm 2 (optimal ℙ_b, Theorem 2) over free *runs*, plus the cost-only preemptive-LDT evaluator |
//! | [`greedy`] | balanced-greedy heuristic (§VI) |
//! | [`baseline`] | random + FCFS baseline (§VII) |
//! | [`exact`] | the exact/anytime reference optimum (Gurobi's role) |
//! | [`lp`], [`milp`], [`model`] | time-indexed ILP of §IV + own solver |
//! | [`strategy`] | the signal-driven solution strategy (Obs. 3): picks a method from instance shape — size, heterogeneity, placement flexibility, straggler tail, uplink contention ([`strategy::Signals`]) — never from the scenario label; ≥ [`strategy::SHARD_CLIENT_FRONTIER`] clients routes to `Method::Sharded` ([`crate::shard`]: helper-cell partition → concurrent per-cell solves → stitched global schedule); `strategy::solve_under` re-schedules against the contention-inflated instance from [`crate::transport`] |
//! | [`preemption`] | §VI switching-cost extension |
//!
//! **Schedule representation.** Every schedule stores per-client sorted
//! `(start, len)` intervals ([`schedule::SlotRuns`]; preemption = more
//! than one run) instead of one entry per occupied slot, so checker,
//! replay and fleet costs scale with the number of preemption runs, not
//! with total processing slots. `psl perf` ([`crate::bench::perf`])
//! times these hot paths against the dense baseline and records the
//! repo's perf trajectory under `target/psl-bench/perf.json`.
//!
//! The scenario × solver evaluation grid behind `psl sweep` lives in
//! [`crate::bench::sweep`]; its rows record each instance's
//! [`strategy::Signals`] next to every method's makespan.
//!
//! [`crate::fleet`] consumes these solvers online: its orchestrator
//! warm-starts from the previous round's [`Assignment`] (greedy arrival
//! placement + overload rebalancing + [`schedule::fcfs_schedule`]) and
//! falls back to a full [`strategy`] re-solve when churn or the
//! lower-bound gap drifts.

pub mod admm;
pub mod baseline;
pub mod bwd;
pub mod compact;
pub mod exact;
pub mod greedy;
pub mod lp;
pub mod milp;
pub mod model;
pub mod preemption;
pub mod schedule;
pub mod strategy;

pub use admm::{AdmmCfg, AdmmResult};
pub use exact::{ExactCfg, ExactResult};
pub use schedule::{Assignment, Schedule, SlotRuns};
