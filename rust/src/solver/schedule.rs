//! Schedule/assignment types shared by every solution method, plus the
//! feasibility checker that enforces the paper's constraints (1)–(9) and
//! the FCFS (non-preemptive) scheduler used by balanced-greedy and the
//! baseline.
//!
//! Representation: instead of dense x_ijt / z_ijt tensors we store, per
//! client, the sorted list of slots where its fwd (x) and bwd (z) task
//! runs on its assigned helper. This is equivalent (y fixes the helper,
//! (4)) and keeps memory O(work) instead of O(|E|·T).

use crate::instance::Instance;

/// Client→helper assignment (the y variables; (4) one helper per client).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Assignment {
    pub helper_of: Vec<usize>,
}

impl Assignment {
    pub fn new(helper_of: Vec<usize>) -> Self {
        Assignment { helper_of }
    }

    /// Clients assigned to helper i, in client order.
    pub fn clients_of(&self, i: usize) -> Vec<usize> {
        (0..self.helper_of.len()).filter(|&j| self.helper_of[j] == i).collect()
    }

    /// Memory feasibility (5): Σ_j y_ij d_j ≤ m_i.
    pub fn memory_ok(&self, inst: &Instance) -> bool {
        let mut used = vec![0.0f64; inst.n_helpers];
        for (j, &i) in self.helper_of.iter().enumerate() {
            used[i] += inst.d[j];
        }
        used.iter().zip(&inst.mem).all(|(u, m)| *u <= *m + 1e-9)
    }

    /// Per-helper memory slack (m_i − Σ d_j).
    pub fn memory_slack(&self, inst: &Instance) -> Vec<f64> {
        let mut slack = inst.mem.clone();
        for (j, &i) in self.helper_of.iter().enumerate() {
            slack[i] -= inst.d[j];
        }
        slack
    }
}

/// A complete solution of ℙ: assignment + per-client fwd/bwd slot lists.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub assignment: Assignment,
    /// Sorted slots where client j's fwd-prop task runs (x_ijt = 1).
    pub fwd_slots: Vec<Vec<u32>>,
    /// Sorted slots where client j's bwd-prop task runs (z_ijt = 1).
    pub bwd_slots: Vec<Vec<u32>>,
}

impl Schedule {
    /// φ^f_j: slot when fwd-prop finishes (last fwd slot + 1); (12).
    pub fn fwd_finish(&self, j: usize) -> u32 {
        self.fwd_slots[j].last().map(|&t| t + 1).unwrap_or(0)
    }

    /// c^f_j = φ^f_j + l_ij (13).
    pub fn fwd_completion(&self, inst: &Instance, j: usize) -> u32 {
        let e = inst.edge(self.assignment.helper_of[j], j);
        self.fwd_finish(j) + inst.l[e]
    }

    /// φ_j: slot when bwd-prop finishes (8).
    pub fn bwd_finish(&self, j: usize) -> u32 {
        self.bwd_slots[j].last().map(|&t| t + 1).unwrap_or(0)
    }

    /// c_j = φ_j + r'_ij (9): overall batch completion of client j.
    pub fn completion(&self, inst: &Instance, j: usize) -> u32 {
        let e = inst.edge(self.assignment.helper_of[j], j);
        self.bwd_finish(j) + inst.rp[e]
    }

    /// Batch makespan max_j c_j — the objective of ℙ.
    pub fn makespan(&self, inst: &Instance) -> u32 {
        (0..inst.n_clients).map(|j| self.completion(inst, j)).max().unwrap_or(0)
    }

    /// Fwd makespan max_j c^f_j — the objective of ℙ_f.
    pub fn fwd_makespan(&self, inst: &Instance) -> u32 {
        (0..inst.n_clients).map(|j| self.fwd_completion(inst, j)).max().unwrap_or(0)
    }

    /// Total queuing delay of client j (paper §IV): φ_j − Σ_i y_ij
    /// (r+p+l+l'+p') — slots spent waiting at the helper.
    pub fn queuing_delay(&self, inst: &Instance, j: usize) -> i64 {
        let e = inst.edge(self.assignment.helper_of[j], j);
        let ideal = inst.r[e] + inst.p[e] + inst.l[e] + inst.lp[e] + inst.pp[e];
        self.bwd_finish(j) as i64 - ideal as i64
    }

    /// Number of maximal contiguous segments in a slot list — 1 means
    /// non-preempted.
    pub fn segments(slots: &[u32]) -> u32 {
        if slots.is_empty() {
            return 0;
        }
        1 + slots.windows(2).filter(|w| w[1] != w[0] + 1).count() as u32
    }

    /// Preemption count across all clients (segments beyond the first).
    pub fn preemptions(&self) -> u32 {
        (0..self.fwd_slots.len())
            .map(|j| {
                (Self::segments(&self.fwd_slots[j]).saturating_sub(1))
                    + (Self::segments(&self.bwd_slots[j]).saturating_sub(1))
            })
            .sum()
    }

    /// Makespan with the §VI switching-cost extension: each client's
    /// completion is inflated by μ_i · (switch transitions of its tasks),
    /// where transitions = 2 × segments (on + off edges of every maximal
    /// run, matching Σ_t |x_ijt − x_ij(t+1)| with x ≡ 0 outside the
    /// horizon).
    pub fn makespan_with_switch_cost(&self, inst: &Instance) -> u32 {
        (0..inst.n_clients)
            .map(|j| {
                let i = self.assignment.helper_of[j];
                let switches = 2 * (Self::segments(&self.fwd_slots[j]) + Self::segments(&self.bwd_slots[j]));
                self.completion(inst, j) + inst.mu[i] * switches
            })
            .max()
            .unwrap_or(0)
    }

    /// Full feasibility check of the paper's constraints. Returns the list
    /// of violated constraints (empty = feasible).
    pub fn violations(&self, inst: &Instance) -> Vec<String> {
        let mut errs = Vec::new();
        let jn = inst.n_clients;
        if self.assignment.helper_of.len() != jn || self.fwd_slots.len() != jn || self.bwd_slots.len() != jn {
            errs.push("shape mismatch".into());
            return errs;
        }
        // (5) memory.
        if !self.assignment.memory_ok(inst) {
            errs.push("(5) helper memory exceeded".into());
        }
        for j in 0..jn {
            let i = self.assignment.helper_of[j];
            if i >= inst.n_helpers {
                errs.push(format!("client {j}: invalid helper {i}"));
                continue;
            }
            let e = inst.edge(i, j);
            // sortedness + uniqueness.
            for w in self.fwd_slots[j].windows(2) {
                if w[1] <= w[0] {
                    errs.push(format!("client {j}: fwd slots not strictly sorted"));
                    break;
                }
            }
            for w in self.bwd_slots[j].windows(2) {
                if w[1] <= w[0] {
                    errs.push(format!("client {j}: bwd slots not strictly sorted"));
                    break;
                }
            }
            // (6)/(7) exact processing amounts on the assigned helper.
            if self.fwd_slots[j].len() != inst.p[e] as usize {
                errs.push(format!("(6) client {j}: {} fwd slots != p {}", self.fwd_slots[j].len(), inst.p[e]));
            }
            if self.bwd_slots[j].len() != inst.pp[e] as usize {
                errs.push(format!("(7) client {j}: {} bwd slots != p' {}", self.bwd_slots[j].len(), inst.pp[e]));
            }
            // (1) release times.
            if let Some(&first) = self.fwd_slots[j].first() {
                if first < inst.r[e] {
                    errs.push(format!("(1) client {j}: fwd starts at {first} < release {}", inst.r[e]));
                }
            }
            // (2) precedence: bwd may start only l+l' after fwd completed.
            if let Some(&bfirst) = self.bwd_slots[j].first() {
                let ready = self.fwd_finish(j) + inst.l[e] + inst.lp[e];
                if bfirst < ready {
                    errs.push(format!("(2) client {j}: bwd starts at {bfirst} < ready {ready}"));
                }
            }
        }
        // (3) one task per helper per slot.
        let mut busy: std::collections::HashMap<(usize, u32), usize> = std::collections::HashMap::new();
        for j in 0..jn {
            let i = self.assignment.helper_of[j];
            for &t in self.fwd_slots[j].iter().chain(self.bwd_slots[j].iter()) {
                if let Some(other) = busy.insert((i, t), j) {
                    if other != j || self.fwd_slots[j].contains(&t) && self.bwd_slots[j].contains(&t) {
                        errs.push(format!("(3) helper {i} slot {t}: clients {other} and {j} overlap"));
                    }
                }
            }
        }
        errs
    }

    pub fn is_feasible(&self, inst: &Instance) -> bool {
        self.violations(inst).is_empty()
    }
}

/// Non-preemptive FCFS scheduling given an assignment (paper §VI step 2
/// of balanced-greedy, also used by the baseline): fwd tasks run in
/// release-time order back-to-back; bwd tasks in bwd-arrival order
/// (c^f + l'), each in one contiguous run, interleaved with any remaining
/// fwd tasks on the same helper in arrival order.
///
/// The helper's timeline is a single FCFS queue over *task arrivals*
/// (fwd arrival = r_ij, bwd arrival = c^f_j + l'_ij = φ^f_j + l + l'),
/// which is exactly a "naive real-time implementation without proactive
/// decisions" (§VII baseline description).
pub fn fcfs_schedule(inst: &Instance, assignment: Assignment) -> Schedule {
    let jn = inst.n_clients;
    let mut fwd_slots = vec![Vec::new(); jn];
    let mut bwd_slots = vec![Vec::new(); jn];

    for i in 0..inst.n_helpers {
        let clients = assignment.clients_of(i);
        // Event-driven FCFS: maintain helper clock; a queue of arrived
        // tasks (fwd first by r, bwd arrives after its client-side turn-
        // around). Non-preemptive: once started, a task runs p (or p')
        // consecutive slots.
        #[derive(Clone, Copy)]
        struct Pending {
            j: usize,
            arrival: u32,
            proc: u32,
            is_bwd: bool,
        }
        let mut pending: Vec<Pending> = clients
            .iter()
            .map(|&j| {
                let e = inst.edge(i, j);
                Pending { j, arrival: inst.r[e], proc: inst.p[e], is_bwd: false }
            })
            .collect();
        let mut clock: u32 = 0;
        while !pending.is_empty() {
            // FCFS: earliest arrival; ties by client id for determinism.
            // (A task that arrived while another was processing waits.)
            let (idx, _) = pending
                .iter()
                .enumerate()
                .min_by_key(|(_, t)| (t.arrival, t.is_bwd, t.j))
                .map(|(k, t)| (k, *t))
                .unwrap();
            let task = pending.swap_remove(idx);
            let start = clock.max(task.arrival);
            let slots: Vec<u32> = (start..start + task.proc).collect();
            clock = start + task.proc;
            let e = inst.edge(i, task.j);
            if task.is_bwd {
                bwd_slots[task.j] = slots;
            } else {
                fwd_slots[task.j] = slots;
                // bwd arrives after downlink + part-3 fwd/bwd + uplink.
                let bwd_arrival = clock + inst.l[e] + inst.lp[e];
                pending.push(Pending { j: task.j, arrival: bwd_arrival, proc: inst.pp[e], is_bwd: true });
            }
        }
    }
    Schedule { assignment, fwd_slots, bwd_slots }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};
    use crate::util::prop;
    use crate::util::rng::Rng;

    pub(crate) fn tiny_instance(rng: &mut Rng, jn: usize, in_: usize) -> Instance {
        // Direct random slotted instance for unit tests (small numbers).
        let e = jn * in_;
        let gen = |rng: &mut Rng, lo: u32, hi: u32| -> Vec<u32> {
            (0..e).map(|_| rng.range_usize(lo as usize, hi as usize) as u32).collect()
        };
        Instance {
            n_clients: jn,
            n_helpers: in_,
            slot_ms: 100.0,
            r: gen(rng, 0, 6),
            l: gen(rng, 0, 3),
            lp: gen(rng, 0, 3),
            rp: gen(rng, 0, 4),
            p: gen(rng, 1, 4),
            pp: gen(rng, 1, 5),
            d: (0..jn).map(|_| rng.range_f64(0.5, 2.0)).collect(),
            mem: (0..in_).map(|_| rng.range_f64(4.0, 16.0)).collect(),
            mu: vec![0; in_],
            label: "tiny".into(),
        }
    }

    #[test]
    fn fcfs_is_feasible_on_random_instances() {
        prop::check(120, |rng| {
            let jn = rng.range_usize(1, 12);
            let in_ = rng.range_usize(1, 4);
            let inst = tiny_instance(rng, jn, in_);
            let assignment = Assignment::new((0..jn).map(|_| rng.below(in_)).collect());
            let s = fcfs_schedule(&inst, assignment);
            let v = s.violations(&inst);
            // memory may be violated by the random assignment; ignore (5).
            let hard: Vec<_> = v.iter().filter(|m| !m.starts_with("(5)")).collect();
            prop::assert_prop(hard.is_empty(), &format!("fcfs violations: {hard:?}"));
        });
    }

    #[test]
    fn fcfs_nonpreemptive() {
        prop::check(60, |rng| {
            let inst = tiny_instance(rng, 8, 2);
            let assignment = Assignment::new((0..8).map(|j| j % 2).collect());
            let s = fcfs_schedule(&inst, assignment);
            for j in 0..8 {
                prop::assert_prop(Schedule::segments(&s.fwd_slots[j]) == 1, "fwd contiguous");
                prop::assert_prop(Schedule::segments(&s.bwd_slots[j]) == 1, "bwd contiguous");
            }
            prop::assert_prop(s.preemptions() == 0, "no preemptions in FCFS");
        });
    }

    #[test]
    fn makespan_matches_components() {
        let mut rng = Rng::seeded(5);
        let inst = tiny_instance(&mut rng, 5, 2);
        let a = Assignment::new(vec![0, 1, 0, 1, 0]);
        let s = fcfs_schedule(&inst, a);
        let m = s.makespan(&inst);
        let by_hand = (0..5).map(|j| s.completion(&inst, j)).max().unwrap();
        assert_eq!(m, by_hand);
        assert!(m >= inst.makespan_lower_bound());
    }

    #[test]
    fn segments_counts() {
        assert_eq!(Schedule::segments(&[]), 0);
        assert_eq!(Schedule::segments(&[3]), 1);
        assert_eq!(Schedule::segments(&[3, 4, 5]), 1);
        assert_eq!(Schedule::segments(&[1, 2, 5, 6, 9]), 3);
    }

    #[test]
    fn violations_catch_bad_schedules() {
        let mut rng = Rng::seeded(11);
        let inst = tiny_instance(&mut rng, 3, 2);
        let a = Assignment::new(vec![0, 0, 1]);
        let mut s = fcfs_schedule(&inst, a);
        // Break (1): start before release.
        let e = inst.edge(0, 0);
        if inst.r[e] > 0 {
            s.fwd_slots[0] = (0..inst.p[e]).collect();
            assert!(s.violations(&inst).iter().any(|v| v.starts_with("(1)")));
        }
        // Break (6): drop a slot.
        let mut s2 = fcfs_schedule(&inst, Assignment::new(vec![0, 0, 1]));
        s2.fwd_slots[1].pop();
        assert!(s2.violations(&inst).iter().any(|v| v.starts_with("(6)")));
        // Break (3): force overlap.
        let mut s3 = fcfs_schedule(&inst, Assignment::new(vec![0, 0, 1]));
        s3.fwd_slots[1] = s3.fwd_slots[0].clone();
        assert!(!s3.violations(&inst).is_empty());
    }

    #[test]
    fn queuing_delay_nonnegative_for_fcfs() {
        prop::check(60, |rng| {
            let inst = tiny_instance(rng, 6, 2);
            let a = Assignment::new((0..6).map(|_| rng.below(2)).collect());
            let s = fcfs_schedule(&inst, a);
            for j in 0..6 {
                prop::assert_prop(s.queuing_delay(&inst, j) >= 0, "queuing delay >= 0");
            }
        });
    }

    #[test]
    fn switch_cost_zero_when_mu_zero() {
        let mut rng = Rng::seeded(3);
        let inst = tiny_instance(&mut rng, 5, 2);
        let s = fcfs_schedule(&inst, Assignment::new(vec![0, 1, 0, 1, 0]));
        assert_eq!(s.makespan(&inst), s.makespan_with_switch_cost(&inst));
    }

    #[test]
    fn scenario_instances_schedule_feasibly() {
        for (scen, model) in [(Scenario::S1, Model::ResNet101), (Scenario::S2, Model::Vgg19)] {
            let inst = ScenarioCfg::new(scen, model, 10, 3, 5).generate().quantize(180.0);
            // Round-robin over feasible helpers.
            let a = Assignment::new((0..10).map(|j| inst.feasible_helpers(j)[j % inst.feasible_helpers(j).len()]).collect());
            let s = fcfs_schedule(&inst, a);
            let v = s.violations(&inst);
            let hard: Vec<_> = v.iter().filter(|m| !m.starts_with("(5)")).collect();
            assert!(hard.is_empty(), "{hard:?}");
        }
    }
}
