//! Schedule/assignment types shared by every solution method, plus the
//! feasibility checker that enforces the paper's constraints (1)–(9) and
//! the FCFS (non-preemptive) scheduler used by balanced-greedy and the
//! baseline.
//!
//! Representation: instead of dense x_ijt / z_ijt tensors — or even dense
//! per-slot lists — we store, per client, the **run-length-encoded** slot
//! set where its fwd (x) and bwd (z) task runs on its assigned helper
//! ([`SlotRuns`]: sorted maximal `(start, len)` intervals; preemption =
//! more than one run). This is equivalent (y fixes the helper, (4)) and
//! keeps memory O(#preemption runs) instead of O(total processing slots):
//! a non-preempted task is exactly one run no matter how many slots its
//! processing time quantizes to, which is what makes the checker, the
//! replay engines and the fleet loop scale to `s6-mega-homogeneous`-sized
//! fleets.

use crate::instance::Instance;

/// Run-length-encoded slot set: sorted, disjoint, **maximal** `(start,
/// len)` intervals with `len ≥ 1` (adjacent runs are always merged, so
/// the number of runs equals the number of contiguous execution
/// segments). The append API ([`push_run`](SlotRuns::push_run) /
/// [`push_slot`](SlotRuns::push_slot)) requires nondecreasing-start
/// appends and merges adjacency automatically — every producer in this
/// crate emits runs in time order, so normalization is free.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct SlotRuns {
    runs: Vec<(u32, u32)>,
}

impl SlotRuns {
    pub fn new() -> SlotRuns {
        SlotRuns { runs: Vec::new() }
    }

    /// A single contiguous run `[start, start+len)`; empty when `len = 0`.
    pub fn one(start: u32, len: u32) -> SlotRuns {
        let mut s = SlotRuns::new();
        s.push_run(start, len);
        s
    }

    /// Wrap an already-normalized run list (debug-asserted).
    pub fn from_runs(runs: Vec<(u32, u32)>) -> SlotRuns {
        let s = SlotRuns { runs };
        debug_assert!(s.is_normalized(), "runs not normalized: {:?}", s.runs);
        s
    }

    /// Encode a strictly-sorted dense slot list (the pre-refactor
    /// representation; kept for ILP extraction and tests).
    pub fn from_slots(slots: &[u32]) -> SlotRuns {
        let mut s = SlotRuns::new();
        for &t in slots {
            s.push_slot(t);
        }
        s
    }

    /// Append a run, merging with the last when exactly adjacent. Appends
    /// must be in time order (`start` ≥ end of the last run).
    pub fn push_run(&mut self, start: u32, len: u32) {
        if len == 0 {
            return;
        }
        if let Some(last) = self.runs.last_mut() {
            debug_assert!(start >= last.0 + last.1, "out-of-order run append");
            if last.0 + last.1 == start {
                last.1 += len;
                return;
            }
        }
        self.runs.push((start, len));
    }

    /// Append a single slot (merging with the last run when adjacent).
    pub fn push_slot(&mut self, t: u32) {
        self.push_run(t, 1);
    }

    pub fn clear(&mut self) {
        self.runs.clear();
    }

    /// The normalized `(start, len)` intervals, in time order.
    pub fn runs(&self) -> &[(u32, u32)] {
        &self.runs
    }

    /// Total number of occupied slots (Σ len).
    pub fn len(&self) -> u32 {
        self.runs.iter().map(|&(_, l)| l).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.runs.is_empty()
    }

    /// Number of maximal contiguous segments — 1 means non-preempted.
    pub fn segments(&self) -> u32 {
        self.runs.len() as u32
    }

    pub fn first_slot(&self) -> Option<u32> {
        self.runs.first().map(|&(s, _)| s)
    }

    pub fn last_slot(&self) -> Option<u32> {
        self.runs.last().map(|&(s, l)| s + l - 1)
    }

    /// Finish slot index: last occupied slot + 1, or 0 when empty.
    pub fn finish(&self) -> u32 {
        self.runs.last().map(|&(s, l)| s + l).unwrap_or(0)
    }

    /// Sorted, disjoint, maximal, and every run non-empty.
    pub fn is_normalized(&self) -> bool {
        self.runs.iter().all(|&(_, l)| l >= 1)
            && self.runs.windows(2).all(|w| w[1].0 > w[0].0 + w[0].1)
    }

    /// Iterate the individual slots (dense decode; O(total slots) — for
    /// tests and boundary conversions only, never hot paths).
    pub fn iter_slots(&self) -> impl Iterator<Item = u32> + '_ {
        self.runs.iter().flat_map(|&(s, l)| s..s + l)
    }

    /// Dense decode into the pre-refactor sorted slot list.
    pub fn to_slots(&self) -> Vec<u32> {
        self.iter_slots().collect()
    }

    /// Union of many disjoint-or-overlapping run sets (used to build a
    /// helper's busy mask from its clients' fwd runs). O(R log R).
    pub fn union_of<'a, I: IntoIterator<Item = &'a SlotRuns>>(sets: I) -> SlotRuns {
        let mut all: Vec<(u32, u32)> = sets.into_iter().flat_map(|s| s.runs.iter().copied()).collect();
        all.sort_unstable();
        let mut out = SlotRuns::new();
        for (s, l) in all {
            match out.runs.last_mut() {
                Some(last) if s <= last.0 + last.1 => {
                    let end = (s + l).max(last.0 + last.1);
                    last.1 = end - last.0;
                }
                _ => out.runs.push((s, l)),
            }
        }
        out
    }

    /// Complement within `[0, horizon)`: the free-slot runs of a machine
    /// whose busy set is `self`.
    pub fn complement(&self, horizon: u32) -> SlotRuns {
        let mut out = SlotRuns::new();
        let mut cursor = 0u32;
        for &(s, l) in &self.runs {
            if s >= horizon {
                break;
            }
            if s > cursor {
                out.push_run(cursor, s - cursor);
            }
            cursor = cursor.max(s + l);
        }
        if cursor < horizon {
            out.push_run(cursor, horizon - cursor);
        }
        out
    }
}

/// Client→helper assignment (the y variables; (4) one helper per client).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Assignment {
    pub helper_of: Vec<usize>,
}

impl Assignment {
    pub fn new(helper_of: Vec<usize>) -> Self {
        Assignment { helper_of }
    }

    /// Per-helper membership lists (clients in index order), built in one
    /// O(J + I) pass — replaces the old per-helper `clients_of` scan that
    /// cost O(J) per call and allocated per helper.
    pub fn members_by_helper(&self, n_helpers: usize) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); n_helpers];
        for (j, &i) in self.helper_of.iter().enumerate() {
            if i < n_helpers {
                out[i].push(j);
            }
        }
        out
    }

    /// Memory feasibility (5): Σ_j y_ij d_j ≤ m_i.
    pub fn memory_ok(&self, inst: &Instance) -> bool {
        let mut used = vec![0.0f64; inst.n_helpers];
        for (j, &i) in self.helper_of.iter().enumerate() {
            used[i] += inst.d[j];
        }
        used.iter().zip(&inst.mem).all(|(u, m)| *u <= *m + 1e-9)
    }

    /// Per-helper memory slack (m_i − Σ d_j).
    pub fn memory_slack(&self, inst: &Instance) -> Vec<f64> {
        let mut slack = inst.mem.clone();
        for (j, &i) in self.helper_of.iter().enumerate() {
            slack[i] -= inst.d[j];
        }
        slack
    }
}

/// A complete solution of ℙ: assignment + per-client fwd/bwd run sets.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub assignment: Assignment,
    /// Run-length-encoded slots where client j's fwd-prop task runs
    /// (x_ijt = 1).
    pub fwd: Vec<SlotRuns>,
    /// Run-length-encoded slots where client j's bwd-prop task runs
    /// (z_ijt = 1).
    pub bwd: Vec<SlotRuns>,
}

impl Schedule {
    /// φ^f_j: slot when fwd-prop finishes (last fwd slot + 1); (12).
    pub fn fwd_finish(&self, j: usize) -> u32 {
        self.fwd[j].finish()
    }

    /// c^f_j = φ^f_j + l_ij (13).
    pub fn fwd_completion(&self, inst: &Instance, j: usize) -> u32 {
        let e = inst.edge(self.assignment.helper_of[j], j);
        self.fwd_finish(j) + inst.l[e]
    }

    /// φ_j: slot when bwd-prop finishes (8).
    pub fn bwd_finish(&self, j: usize) -> u32 {
        self.bwd[j].finish()
    }

    /// c_j = φ_j + r'_ij (9): overall batch completion of client j.
    pub fn completion(&self, inst: &Instance, j: usize) -> u32 {
        let e = inst.edge(self.assignment.helper_of[j], j);
        self.bwd_finish(j) + inst.rp[e]
    }

    /// Batch makespan max_j c_j — the objective of ℙ.
    pub fn makespan(&self, inst: &Instance) -> u32 {
        (0..inst.n_clients).map(|j| self.completion(inst, j)).max().unwrap_or(0)
    }

    /// Fwd makespan max_j c^f_j — the objective of ℙ_f.
    pub fn fwd_makespan(&self, inst: &Instance) -> u32 {
        (0..inst.n_clients).map(|j| self.fwd_completion(inst, j)).max().unwrap_or(0)
    }

    /// Total queuing delay of client j (paper §IV): φ_j − Σ_i y_ij
    /// (r+p+l+l'+p') — slots spent waiting at the helper.
    pub fn queuing_delay(&self, inst: &Instance, j: usize) -> i64 {
        let e = inst.edge(self.assignment.helper_of[j], j);
        let ideal = inst.r[e] + inst.p[e] + inst.l[e] + inst.lp[e] + inst.pp[e];
        self.bwd_finish(j) as i64 - ideal as i64
    }

    /// Preemption count across all clients (segments beyond the first).
    pub fn preemptions(&self) -> u32 {
        (0..self.fwd.len())
            .map(|j| {
                self.fwd[j].segments().saturating_sub(1) + self.bwd[j].segments().saturating_sub(1)
            })
            .sum()
    }

    /// Total number of runs stored (the schedule's O(memory) footprint).
    pub fn total_runs(&self) -> usize {
        self.fwd.iter().chain(self.bwd.iter()).map(|r| r.runs().len()).sum()
    }

    /// Total number of occupied slots (the pre-refactor O(memory)).
    pub fn total_slots(&self) -> u64 {
        self.fwd.iter().chain(self.bwd.iter()).map(|r| r.len() as u64).sum()
    }

    /// Makespan with the §VI switching-cost extension: each client's
    /// completion is inflated by μ_i · (switch transitions of its tasks),
    /// where transitions = 2 × segments (on + off edges of every maximal
    /// run, matching Σ_t |x_ijt − x_ij(t+1)| with x ≡ 0 outside the
    /// horizon).
    pub fn makespan_with_switch_cost(&self, inst: &Instance) -> u32 {
        (0..inst.n_clients)
            .map(|j| {
                let i = self.assignment.helper_of[j];
                let switches = 2 * (self.fwd[j].segments() + self.bwd[j].segments());
                self.completion(inst, j) + inst.mu[i] * switches
            })
            .max()
            .unwrap_or(0)
    }

    /// Full feasibility check of the paper's constraints. Returns the list
    /// of violated constraints (empty = feasible).
    ///
    /// Constraint (3) — one task per helper per slot — is verified by an
    /// interval sweep over the run endpoints (sort all of a helper's runs
    /// by start, adjacent pairs may not overlap): O(R log R) in the number
    /// of runs, replacing the per-`(helper, slot)` hash map that cost
    /// O(total slots).
    pub fn violations(&self, inst: &Instance) -> Vec<String> {
        let mut errs = Vec::new();
        let jn = inst.n_clients;
        if self.assignment.helper_of.len() != jn || self.fwd.len() != jn || self.bwd.len() != jn {
            errs.push("shape mismatch".into());
            return errs;
        }
        // (5) memory.
        if !self.assignment.memory_ok(inst) {
            errs.push("(5) helper memory exceeded".into());
        }
        for j in 0..jn {
            let i = self.assignment.helper_of[j];
            if i >= inst.n_helpers {
                errs.push(format!("client {j}: invalid helper {i}"));
                continue;
            }
            let e = inst.edge(i, j);
            // run-list well-formedness (the dense checker's sortedness).
            if !self.fwd[j].is_normalized() {
                errs.push(format!("client {j}: fwd slots not strictly sorted"));
            }
            if !self.bwd[j].is_normalized() {
                errs.push(format!("client {j}: bwd slots not strictly sorted"));
            }
            // (6)/(7) exact processing amounts on the assigned helper.
            if self.fwd[j].len() != inst.p[e] {
                errs.push(format!("(6) client {j}: {} fwd slots != p {}", self.fwd[j].len(), inst.p[e]));
            }
            if self.bwd[j].len() != inst.pp[e] {
                errs.push(format!("(7) client {j}: {} bwd slots != p' {}", self.bwd[j].len(), inst.pp[e]));
            }
            // (1) release times.
            if let Some(first) = self.fwd[j].first_slot() {
                if first < inst.r[e] {
                    errs.push(format!("(1) client {j}: fwd starts at {first} < release {}", inst.r[e]));
                }
            }
            // (2) precedence: bwd may start only l+l' after fwd completed.
            if let Some(bfirst) = self.bwd[j].first_slot() {
                let ready = self.fwd_finish(j) + inst.l[e] + inst.lp[e];
                if bfirst < ready {
                    errs.push(format!("(2) client {j}: bwd starts at {bfirst} < ready {ready}"));
                }
            }
        }
        // (3) one task per helper per slot: interval sweep per helper.
        let mut spans: Vec<(usize, u32, u32, usize)> = Vec::new(); // (helper, start, end, client)
        for j in 0..jn {
            let i = self.assignment.helper_of[j];
            for runs in [&self.fwd[j], &self.bwd[j]] {
                for &(s, l) in runs.runs() {
                    spans.push((i, s, s + l, j));
                }
            }
        }
        spans.sort_unstable();
        let mut active: Option<(usize, u32, usize)> = None; // (helper, max end so far, its client)
        for &(hi, s, e, j) in &spans {
            match active {
                Some((ha, end, ja)) if ha == hi => {
                    if s < end {
                        errs.push(format!("(3) helper {hi} slot {s}: clients {ja} and {j} overlap"));
                    }
                    if e > end {
                        active = Some((hi, e, j));
                    }
                }
                _ => active = Some((hi, e, j)),
            }
        }
        errs
    }

    pub fn is_feasible(&self, inst: &Instance) -> bool {
        self.violations(inst).is_empty()
    }

    /// Peak number of *concurrent transfer windows* per helper: each
    /// client contributes an upload window `[0, r)`, a turnaround window
    /// `[φ^f, φ^f + l + l')` and a downlink window `[φ, φ + r')` on its
    /// assigned helper. The sweep is O(J log J) over window endpoints and
    /// is what the shared-uplink checker budgets its inflation factor
    /// against (a client's three windows are sequential by construction,
    /// so the peak never exceeds the helper's member count).
    pub fn transfer_occupancy(&self, inst: &Instance) -> Vec<u32> {
        let mut events: Vec<(usize, u32, i32)> = Vec::new(); // (helper, slot, ±1)
        for j in 0..inst.n_clients.min(self.assignment.helper_of.len()) {
            let i = self.assignment.helper_of[j];
            if i >= inst.n_helpers {
                continue;
            }
            let e = inst.edge(i, j);
            let windows = [
                (0u32, inst.r[e]),
                (self.fwd_finish(j), self.fwd_finish(j) + inst.l[e] + inst.lp[e]),
                (self.bwd_finish(j), self.bwd_finish(j) + inst.rp[e]),
            ];
            for (s, end) in windows {
                if end > s {
                    events.push((i, s, 1));
                    events.push((i, end, -1));
                }
            }
        }
        // End events sort before start events at the same slot (−1 < +1),
        // so back-to-back windows never double-count.
        events.sort_unstable();
        let mut peak = vec![0u32; inst.n_helpers];
        let mut cur = vec![0i32; inst.n_helpers];
        for (i, _, d) in events {
            cur[i] += d;
            peak[i] = peak[i].max(cur[i].max(0) as u32);
        }
        peak
    }

    /// [`violations`](Self::violations) under a transport model. The
    /// dedicated mode delegates unchanged; the shared mode checks the
    /// paper's constraints against the **effective** (contention-
    /// inflated) instance for this schedule's per-helper pool loads, and
    /// adds the occupancy sweep: no helper's peak concurrent-transfer
    /// count may exceed the pool population its inflation budgeted for.
    pub fn violations_under(
        &self,
        inst: &Instance,
        transport: &crate::transport::TransportCfg,
    ) -> Vec<String> {
        if transport.is_dedicated() {
            return self.violations(inst);
        }
        let eff = transport.inflate_for_assignment(inst, &self.assignment);
        let mut errs = self.violations(&eff);
        let loads = crate::transport::TransportCfg::loads_of(&self.assignment, inst.n_helpers);
        for (i, &peak) in self.transfer_occupancy(&eff).iter().enumerate() {
            if peak as usize > loads[i] {
                errs.push(format!(
                    "(T) helper {i}: {peak} concurrent transfers exceed the pool population {} budgeted by the inflation factor",
                    loads[i]
                ));
            }
        }
        errs
    }
}

/// Non-preemptive FCFS scheduling given an assignment (paper §VI step 2
/// of balanced-greedy, also used by the baseline): fwd tasks run in
/// release-time order back-to-back; bwd tasks in bwd-arrival order
/// (c^f + l'), each in one contiguous run, interleaved with any remaining
/// fwd tasks on the same helper in arrival order.
///
/// The helper's timeline is a single FCFS queue over *task arrivals*
/// (fwd arrival = r_ij, bwd arrival = c^f_j + l'_ij = φ^f_j + l + l'),
/// which is exactly a "naive real-time implementation without proactive
/// decisions" (§VII baseline description). Each task produces exactly one
/// run, so the schedule is O(J) memory regardless of task lengths.
pub fn fcfs_schedule(inst: &Instance, assignment: Assignment) -> Schedule {
    let jn = inst.n_clients;
    let mut fwd = vec![SlotRuns::new(); jn];
    let mut bwd = vec![SlotRuns::new(); jn];

    for (i, clients) in assignment.members_by_helper(inst.n_helpers).into_iter().enumerate() {
        // Event-driven FCFS: maintain helper clock; a queue of arrived
        // tasks (fwd first by r, bwd arrives after its client-side turn-
        // around). Non-preemptive: once started, a task runs p (or p')
        // consecutive slots.
        #[derive(Clone, Copy)]
        struct Pending {
            j: usize,
            arrival: u32,
            proc: u32,
            is_bwd: bool,
        }
        let mut pending: Vec<Pending> = clients
            .iter()
            .map(|&j| {
                let e = inst.edge(i, j);
                Pending { j, arrival: inst.r[e], proc: inst.p[e], is_bwd: false }
            })
            .collect();
        let mut clock: u32 = 0;
        while !pending.is_empty() {
            // FCFS: earliest arrival; ties by client id for determinism.
            // (A task that arrived while another was processing waits.)
            let (idx, _) = pending
                .iter()
                .enumerate()
                .min_by_key(|(_, t)| (t.arrival, t.is_bwd, t.j))
                .map(|(k, t)| (k, *t))
                .unwrap();
            let task = pending.swap_remove(idx);
            let start = clock.max(task.arrival);
            clock = start + task.proc;
            let e = inst.edge(i, task.j);
            if task.is_bwd {
                bwd[task.j] = SlotRuns::one(start, task.proc);
            } else {
                fwd[task.j] = SlotRuns::one(start, task.proc);
                // bwd arrives after downlink + part-3 fwd/bwd + uplink.
                let bwd_arrival = clock + inst.l[e] + inst.lp[e];
                pending.push(Pending { j: task.j, arrival: bwd_arrival, proc: inst.pp[e], is_bwd: true });
            }
        }
    }
    Schedule { assignment, fwd, bwd }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};
    use crate::util::prop;
    use crate::util::rng::Rng;

    pub(crate) fn tiny_instance(rng: &mut Rng, jn: usize, in_: usize) -> Instance {
        // Direct random slotted instance for unit tests (small numbers).
        let e = jn * in_;
        let gen = |rng: &mut Rng, lo: u32, hi: u32| -> Vec<u32> {
            (0..e).map(|_| rng.range_usize(lo as usize, hi as usize) as u32).collect()
        };
        Instance {
            n_clients: jn,
            n_helpers: in_,
            slot_ms: 100.0,
            r: gen(rng, 0, 6),
            l: gen(rng, 0, 3),
            lp: gen(rng, 0, 3),
            rp: gen(rng, 0, 4),
            p: gen(rng, 1, 4),
            pp: gen(rng, 1, 5),
            d: (0..jn).map(|_| rng.range_f64(0.5, 2.0)).collect(),
            mem: (0..in_).map(|_| rng.range_f64(4.0, 16.0)).collect(),
            mu: vec![0; in_],
            label: "tiny".into(),
        }
    }

    #[test]
    fn slot_runs_roundtrip_and_merge() {
        let dense = vec![1, 2, 5, 6, 9];
        let r = SlotRuns::from_slots(&dense);
        assert_eq!(r.runs(), &[(1, 2), (5, 2), (9, 1)]);
        assert_eq!(r.to_slots(), dense);
        assert_eq!(r.len(), 5);
        assert_eq!(r.segments(), 3);
        assert_eq!(r.first_slot(), Some(1));
        assert_eq!(r.last_slot(), Some(9));
        assert_eq!(r.finish(), 10);
        assert!(r.is_normalized());

        let mut m = SlotRuns::new();
        m.push_run(0, 3);
        m.push_run(3, 2); // adjacent → merged
        m.push_run(7, 1);
        assert_eq!(m.runs(), &[(0, 5), (7, 1)]);
        assert_eq!(SlotRuns::new().finish(), 0);
        assert!(SlotRuns::new().is_empty());
        assert_eq!(SlotRuns::one(4, 0), SlotRuns::new());
    }

    #[test]
    fn slot_runs_union_and_complement() {
        let a = SlotRuns::from_runs(vec![(0, 2), (5, 2)]);
        let b = SlotRuns::from_runs(vec![(2, 1), (6, 3)]);
        let u = SlotRuns::union_of([&a, &b]);
        assert_eq!(u.runs(), &[(0, 3), (5, 4)]);
        let free = u.complement(12);
        assert_eq!(free.runs(), &[(3, 2), (9, 3)]);
        // Complement of empty is the full horizon; of full is empty.
        assert_eq!(SlotRuns::new().complement(4).runs(), &[(0, 4)]);
        assert_eq!(SlotRuns::one(0, 4).complement(4).runs(), &[] as &[(u32, u32)]);
        // Dense cross-check on random masks.
        prop::check(60, |rng| {
            let slots: Vec<u32> = (0..30u32).filter(|_| rng.chance(0.4)).collect();
            let runs = SlotRuns::from_slots(&slots);
            prop::assert_prop(runs.to_slots() == slots, "roundtrip");
            let free = runs.complement(30);
            let dense_free: Vec<u32> = (0..30u32).filter(|t| !slots.contains(t)).collect();
            prop::assert_prop(free.to_slots() == dense_free, "complement matches dense");
        });
    }

    #[test]
    fn fcfs_is_feasible_on_random_instances() {
        prop::check(120, |rng| {
            let jn = rng.range_usize(1, 12);
            let in_ = rng.range_usize(1, 4);
            let inst = tiny_instance(rng, jn, in_);
            let assignment = Assignment::new((0..jn).map(|_| rng.below(in_)).collect());
            let s = fcfs_schedule(&inst, assignment);
            let v = s.violations(&inst);
            // memory may be violated by the random assignment; ignore (5).
            let hard: Vec<_> = v.iter().filter(|m| !m.starts_with("(5)")).collect();
            prop::assert_prop(hard.is_empty(), &format!("fcfs violations: {hard:?}"));
        });
    }

    #[test]
    fn fcfs_nonpreemptive() {
        prop::check(60, |rng| {
            let inst = tiny_instance(rng, 8, 2);
            let assignment = Assignment::new((0..8).map(|j| j % 2).collect());
            let s = fcfs_schedule(&inst, assignment);
            for j in 0..8 {
                prop::assert_prop(s.fwd[j].segments() == 1, "fwd contiguous");
                prop::assert_prop(s.bwd[j].segments() == 1, "bwd contiguous");
            }
            prop::assert_prop(s.preemptions() == 0, "no preemptions in FCFS");
        });
    }

    #[test]
    fn makespan_matches_components() {
        let mut rng = Rng::seeded(5);
        let inst = tiny_instance(&mut rng, 5, 2);
        let a = Assignment::new(vec![0, 1, 0, 1, 0]);
        let s = fcfs_schedule(&inst, a);
        let m = s.makespan(&inst);
        let by_hand = (0..5).map(|j| s.completion(&inst, j)).max().unwrap();
        assert_eq!(m, by_hand);
        assert!(m >= inst.makespan_lower_bound());
    }

    #[test]
    fn segments_counts() {
        assert_eq!(SlotRuns::from_slots(&[]).segments(), 0);
        assert_eq!(SlotRuns::from_slots(&[3]).segments(), 1);
        assert_eq!(SlotRuns::from_slots(&[3, 4, 5]).segments(), 1);
        assert_eq!(SlotRuns::from_slots(&[1, 2, 5, 6, 9]).segments(), 3);
    }

    #[test]
    fn members_by_helper_groups_in_client_order() {
        let a = Assignment::new(vec![1, 0, 1, 1, 0]);
        let m = a.members_by_helper(3);
        assert_eq!(m, vec![vec![1, 4], vec![0, 2, 3], vec![]]);
    }

    #[test]
    fn violations_catch_bad_schedules() {
        let mut rng = Rng::seeded(11);
        let inst = tiny_instance(&mut rng, 3, 2);
        let a = Assignment::new(vec![0, 0, 1]);
        let mut s = fcfs_schedule(&inst, a);
        // Break (1): start before release.
        let e = inst.edge(0, 0);
        if inst.r[e] > 0 {
            s.fwd[0] = SlotRuns::one(0, inst.p[e]);
            assert!(s.violations(&inst).iter().any(|v| v.starts_with("(1)")));
        }
        // Break (6): drop a slot.
        let mut s2 = fcfs_schedule(&inst, Assignment::new(vec![0, 0, 1]));
        let mut short = s2.fwd[1].to_slots();
        short.pop();
        s2.fwd[1] = SlotRuns::from_slots(&short);
        assert!(s2.violations(&inst).iter().any(|v| v.starts_with("(6)")));
        // Break (3): force overlap.
        let mut s3 = fcfs_schedule(&inst, Assignment::new(vec![0, 0, 1]));
        s3.fwd[1] = s3.fwd[0].clone();
        assert!(!s3.violations(&inst).is_empty());
        assert!(s3.violations(&inst).iter().any(|v| v.starts_with("(3)")));
    }

    #[test]
    fn violations_under_dedicated_matches_plain_checker() {
        prop::check(40, |rng| {
            let jn = rng.range_usize(1, 10);
            let inst = tiny_instance(rng, jn, 2);
            let a = Assignment::new((0..jn).map(|_| rng.below(2)).collect());
            let s = fcfs_schedule(&inst, a);
            let t = crate::transport::TransportCfg::dedicated();
            prop::assert_prop(
                s.violations(&inst) == s.violations_under(&inst, &t),
                "dedicated checker is the plain checker",
            );
        });
    }

    #[test]
    fn transfer_occupancy_bounded_by_membership() {
        prop::check(40, |rng| {
            let jn = rng.range_usize(2, 12);
            let inst = tiny_instance(rng, jn, 3);
            let a = Assignment::new((0..jn).map(|_| rng.below(3)).collect());
            let members = a.members_by_helper(3);
            let s = fcfs_schedule(&inst, a);
            let occ = s.transfer_occupancy(&inst);
            for i in 0..3 {
                prop::assert_prop(
                    occ[i] as usize <= members[i].len(),
                    "a client's windows are sequential, so peak ≤ members",
                );
            }
        });
    }

    #[test]
    fn shared_checker_rejects_dedicated_built_schedule_under_contention() {
        // A schedule built against the uninflated delays generally starts
        // fwd tasks before the *effective* release under contention; the
        // occupancy-aware checker must catch that, and a schedule rebuilt
        // on the effective instance must pass.
        let mut rng = Rng::seeded(21);
        let mut inst = tiny_instance(&mut rng, 8, 2);
        for e in inst.r.iter_mut() {
            *e += 2; // ensure nonzero uplink so inflation bites
        }
        let t = crate::transport::TransportCfg::shared(1.0); // 4 members → 4× slower
        let a = Assignment::new((0..8).map(|j| j % 2).collect());
        let naive = fcfs_schedule(&inst, a.clone());
        assert!(
            !naive.violations_under(&inst, &t).is_empty(),
            "naive schedule should violate effective releases"
        );
        let eff = t.inflate_for_assignment(&inst, &a);
        let rebuilt = fcfs_schedule(&eff, a);
        let v = rebuilt.violations_under(&inst, &t);
        assert!(v.is_empty(), "rebuilt-on-effective schedule must pass: {v:?}");
    }

    #[test]
    fn queuing_delay_nonnegative_for_fcfs() {
        prop::check(60, |rng| {
            let inst = tiny_instance(rng, 6, 2);
            let a = Assignment::new((0..6).map(|_| rng.below(2)).collect());
            let s = fcfs_schedule(&inst, a);
            for j in 0..6 {
                prop::assert_prop(s.queuing_delay(&inst, j) >= 0, "queuing delay >= 0");
            }
        });
    }

    #[test]
    fn switch_cost_zero_when_mu_zero() {
        let mut rng = Rng::seeded(3);
        let inst = tiny_instance(&mut rng, 5, 2);
        let s = fcfs_schedule(&inst, Assignment::new(vec![0, 1, 0, 1, 0]));
        assert_eq!(s.makespan(&inst), s.makespan_with_switch_cost(&inst));
    }

    #[test]
    fn scenario_instances_schedule_feasibly() {
        for (scen, model) in [(Scenario::S1, Model::ResNet101), (Scenario::S2, Model::Vgg19)] {
            let inst = ScenarioCfg::new(scen, model, 10, 3, 5).generate().quantize(180.0);
            // Round-robin over feasible helpers.
            let a = Assignment::new((0..10).map(|j| inst.feasible_helpers(j)[j % inst.feasible_helpers(j).len()]).collect());
            let s = fcfs_schedule(&inst, a);
            let v = s.violations(&inst);
            let hard: Vec<_> = v.iter().filter(|m| !m.starts_with("(5)")).collect();
            assert!(hard.is_empty(), "{hard:?}");
        }
    }
}
