//! Algorithm 2: optimal preemptive single-machine scheduling minimizing
//! the maximum completion cost subject to release dates — the paper's
//! polynomial-time solution of ℙ_b (Theorem 2), based on the block
//! decomposition of Baker, Lawler, Lenstra & Rinnooy Kan (Oper. Res. '83).
//!
//! We implement it generically over a *free-run list* (the machine may be
//! pre-occupied by fwd-prop runs — constraint (3) couples the two
//! directions), with cost functions of the form `finish + tail`:
//!
//! * **bwd-prop** (the paper's use): job j has release `φ^f_j + l + l'`
//!   (gradients arrive at the helper), processing `p'_ij`, tail `r'_ij`
//!   (cost = φ_j + π_j = the client's batch completion).
//! * **fwd-prop per helper** (our reuse inside ADMM and the exact solver):
//!   release `r_ij`, processing `p_ij`, tail `l_ij` (cost = c^f_j).
//!
//! The block decomposition is exactly the worked example of the paper's
//! Fig. 4: build the FCFS-by-arrival schedule, split into maximal non-idle
//! *blocks*; within each block pick ℓ = argmin_{j∈β} (e(β) + tail_j),
//! schedule the remaining jobs FCFS (forming sub-blocks, recursed on) and
//! let ℓ soak up the leftover slots, finishing at e(β).
//!
//! Everything operates on run-length-encoded slot sets ([`SlotRuns`]):
//! blocks, sub-blocks and job schedules are `(start, len)` interval lists,
//! and the simulation advances in *chunks* (to the next release,
//! completion, or free-run boundary) instead of slot by slot — O(jobs +
//! runs) work per block rather than O(total processing slots).
//!
//! For hot loops that only need the optimal *objective value* (the ADMM
//! w-subproblem evaluates thousands of candidate assignments per solve),
//! [`preemptive_cost_contiguous`] computes it by the preemptive
//! largest-delivery-time rule (Jackson/Schrage; optimal for
//! 1|r_j, pmtn|max(C_j + q_j), the same optimum the block algorithm
//! attains) without materializing any schedule — no allocations beyond a
//! reusable [`CostScratch`].

use super::schedule::SlotRuns;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// One schedulable task.
#[derive(Clone, Copy, Debug)]
pub struct Job {
    /// Caller-defined identifier (client id).
    pub id: usize,
    /// Earliest slot the task may run in.
    pub release: u32,
    /// Number of slots of work.
    pub proc: u32,
    /// Cost tail: job cost = (last slot + 1) + tail. Must be nonnegative.
    pub tail: u32,
}

/// Schedule `jobs` preemptively over the free runs `free`, minimizing
/// `max_j (finish_j + tail_j)`. Returns the run set per job (indexed like
/// `jobs`). Panics if `free` has too few slots ≥ releases.
pub fn preemptive_min_max_tail(jobs: &[Job], free: &SlotRuns) -> Vec<SlotRuns> {
    let mut out = vec![SlotRuns::new(); jobs.len()];
    if jobs.is_empty() {
        return out;
    }
    // Order job indices by release (ties by id for determinism).
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    order.sort_by_key(|&k| (jobs[k].release, jobs[k].id));

    // --- Phase 1: FCFS simulation to find blocks --------------------------
    // A block is a maximal group of jobs processed with no (voluntary)
    // idle slot in between; blocks are independent (Baker et al.). The
    // scan walks the free runs once, consuming chunks bounded by the next
    // job release (absorption points) and run boundaries.
    let runs = free.runs();
    let mut blocks: Vec<(Vec<usize>, SlotRuns)> = Vec::new(); // (job idxs, runs used)
    let mut ri = 0usize; // current free run index
    let mut pos = 0u32; // next candidate slot within runs[ri]
    let mut k = 0usize;
    while k < order.len() {
        // Start a new block at the first free slot ≥ this job's release.
        let first_rel = jobs[order[k]].release;
        loop {
            assert!(ri < runs.len(), "free-slot list exhausted (horizon too small)");
            let (s, l) = runs[ri];
            let lo = pos.max(s).max(first_rel);
            if lo < s + l {
                pos = lo;
                break;
            }
            ri += 1;
            pos = 0;
        }
        let mut members = vec![order[k]];
        let mut remaining: u32 = jobs[order[k]].proc;
        k += 1;
        let mut slots = SlotRuns::new();
        while remaining > 0 {
            assert!(ri < runs.len(), "free-slot list exhausted (horizon too small)");
            let (s, l) = runs[ri];
            if pos < s {
                pos = s;
            }
            // Absorb any job released by the current slot into the block.
            while k < order.len() && jobs[order[k]].release <= pos {
                members.push(order[k]);
                remaining += jobs[order[k]].proc;
                k += 1;
            }
            let run_end = s + l;
            // The chunk may not cross the next absorption point.
            let next_rel = if k < order.len() { jobs[order[k]].release } else { u32::MAX };
            let cap = run_end.min(next_rel);
            let chunk = remaining.min(cap - pos);
            slots.push_run(pos, chunk);
            remaining -= chunk;
            pos += chunk;
            if pos == run_end {
                ri += 1;
                pos = 0;
            }
        }
        blocks.push((members, slots));
    }

    // --- Phase 2: recursive ordering within each block ---------------------
    for (members, slots) in blocks {
        schedule_block(jobs, &members, &slots, &mut out);
    }
    out
}

/// Recursively schedule `members` (indices into `jobs`) over exactly the
/// runs `block_runs` (Σ len = Σ proc), writing per-job run sets into `out`.
fn schedule_block(jobs: &[Job], members: &[usize], block_runs: &SlotRuns, out: &mut Vec<SlotRuns>) {
    debug_assert_eq!(
        block_runs.len() as u64,
        members.iter().map(|&k| jobs[k].proc as u64).sum::<u64>()
    );
    if members.len() == 1 {
        out[members[0]] = block_runs.clone();
        return;
    }
    // ℓ = argmin_{j ∈ β} (e(β) + tail_j): since e(β) is common, the job
    // with the smallest tail — it is pushed last and finishes at e(β).
    let ell = *members
        .iter()
        .min_by_key(|&&k| (jobs[k].tail, jobs[k].id))
        .unwrap();

    // FCFS the remaining jobs over the block's runs; untaken spans go to ℓ.
    let mut rest: Vec<usize> = members.iter().copied().filter(|&k| k != ell).collect();
    rest.sort_by_key(|&k| (jobs[k].release, jobs[k].id));
    let mut ell_runs = SlotRuns::new();
    // Sub-blocks of `rest`: maximal spans where some rest-job runs.
    let mut sub: Vec<(Vec<usize>, SlotRuns)> = Vec::new();
    let mut cur_members: Vec<usize> = Vec::new();
    let mut cur_runs = SlotRuns::new();
    let mut queue: std::collections::VecDeque<usize> = std::collections::VecDeque::new();
    let mut next = 0usize; // next rest job to arrive
    let mut rem: Vec<u32> = jobs.iter().map(|j| j.proc).collect();
    for &(s, l) in block_runs.runs() {
        let run_end = s + l;
        let mut t = s;
        while t < run_end {
            while next < rest.len() && jobs[rest[next]].release <= t {
                queue.push_back(rest[next]);
                next += 1;
            }
            if let Some(&front) = queue.front() {
                // The front job owns the machine until it completes or the
                // free run ends; releases meanwhile only append behind it.
                let chunk = rem[front].min(run_end - t);
                if !cur_members.contains(&front) {
                    cur_members.push(front);
                }
                cur_runs.push_run(t, chunk);
                rem[front] -= chunk;
                t += chunk;
                if rem[front] == 0 {
                    queue.pop_front();
                }
            } else {
                // ℓ runs until the next rest release (or the run ends);
                // any in-flight sub-block is closed.
                let next_rel = if next < rest.len() { jobs[rest[next]].release } else { u32::MAX };
                let span_end = run_end.min(next_rel);
                ell_runs.push_run(t, span_end - t);
                if !cur_members.is_empty() {
                    sub.push((std::mem::take(&mut cur_members), std::mem::take(&mut cur_runs)));
                }
                t = span_end;
            }
        }
    }
    if !cur_members.is_empty() {
        sub.push((cur_members, cur_runs));
    }
    debug_assert_eq!(ell_runs.len(), jobs[ell].proc);
    out[ell] = ell_runs;
    for (m, s) in sub {
        schedule_block(jobs, &m, &s, out);
    }
}

/// Fast path for a fully-free machine (no busy mask): block boundaries
/// are computed arithmetically, so the cost is O(n log n + #runs)
/// independent of the horizon. Used wherever fwd scheduling happens on an
/// empty machine (ADMM's final schedule, the exact solver's incumbent).
pub fn preemptive_min_max_tail_contiguous(jobs: &[Job]) -> Vec<SlotRuns> {
    let mut out = vec![SlotRuns::new(); jobs.len()];
    if jobs.is_empty() {
        return out;
    }
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    order.sort_by_key(|&k| (jobs[k].release, jobs[k].id));
    let mut k = 0usize;
    while k < order.len() {
        let s = jobs[order[k]].release;
        let mut e = s + jobs[order[k]].proc;
        let mut members = vec![order[k]];
        k += 1;
        while k < order.len() && jobs[order[k]].release < e {
            e += jobs[order[k]].proc;
            members.push(order[k]);
            k += 1;
        }
        schedule_block(jobs, &members, &SlotRuns::one(s, e - s), &mut out);
    }
    out
}

/// Reusable buffers for [`preemptive_cost_contiguous`] — keep one per
/// worker and the hot loop allocates nothing.
#[derive(Default)]
pub struct CostScratch {
    order: Vec<usize>,
    rem: Vec<u32>,
    heap: BinaryHeap<(u32, Reverse<usize>)>,
}

/// Optimal objective value `max_j (finish_j + tail_j)` of preemptively
/// scheduling `jobs` on a fully-free machine — the preemptive
/// largest-delivery-time (Jackson) rule: at every instant run the
/// released job with the largest tail. Matches the block algorithm's
/// optimum exactly (both are optimal for this problem) but computes it in
/// O(n log n) with no schedule materialization and no allocation (beyond
/// the scratch). This is the ADMM w-subproblem's per-candidate evaluator.
pub fn preemptive_cost_contiguous(jobs: &[Job], scratch: &mut CostScratch) -> u32 {
    let n = jobs.len();
    if n == 0 {
        return 0;
    }
    scratch.order.clear();
    scratch.order.extend(0..n);
    scratch.order.sort_by_key(|&k| (jobs[k].release, jobs[k].id));
    scratch.rem.clear();
    scratch.rem.extend(jobs.iter().map(|j| j.proc));
    scratch.heap.clear();

    let mut t = 0u32;
    let mut next = 0usize;
    let mut cost = 0u32;
    while next < n || !scratch.heap.is_empty() {
        if scratch.heap.is_empty() {
            t = t.max(jobs[scratch.order[next]].release);
        }
        while next < n && jobs[scratch.order[next]].release <= t {
            let k = scratch.order[next];
            scratch.heap.push((jobs[k].tail, Reverse(k)));
            next += 1;
        }
        let (tail, Reverse(k)) = scratch.heap.pop().unwrap();
        let next_rel = if next < n { jobs[scratch.order[next]].release } else { u32::MAX };
        // Run until completion or the next release (which may preempt).
        let run = if next_rel == u32::MAX { scratch.rem[k] } else { scratch.rem[k].min(next_rel - t) };
        t += run;
        scratch.rem[k] -= run;
        if scratch.rem[k] == 0 {
            cost = cost.max(t + tail);
        } else {
            scratch.heap.push((tail, Reverse(k)));
        }
    }
    cost
}

/// Objective value of a per-job run listing: max_j (finish + tail).
pub fn max_tail_cost(jobs: &[Job], slots: &[SlotRuns]) -> u32 {
    jobs.iter()
        .zip(slots)
        .map(|(j, s)| s.last_slot().map(|t| t + 1).unwrap_or(j.release) + j.tail)
        .max()
        .unwrap_or(0)
}

// ----------------------------------------------------------------------------
// Algorithm 2 entry point: optimal bwd-prop schedule given (y*, x*).
// ----------------------------------------------------------------------------

use super::schedule::{Assignment, Schedule};
use crate::instance::Instance;

/// Solve ℙ_b: given the assignment and the fwd runs, compute the optimal
/// preemptive bwd schedule per helper (in parallel across helpers in the
/// paper; sequentially here — each helper is independent).
pub fn optimal_bwd(inst: &Instance, assignment: &Assignment, fwd: &[SlotRuns]) -> Vec<SlotRuns> {
    let mut bwd = vec![SlotRuns::new(); inst.n_clients];
    for (i, clients) in assignment.members_by_helper(inst.n_helpers).into_iter().enumerate() {
        if clients.is_empty() {
            continue;
        }
        let busy = SlotRuns::union_of(clients.iter().map(|&j| &fwd[j]));
        let jobs: Vec<Job> = clients
            .iter()
            .map(|&j| {
                let e = inst.edge(i, j);
                Job {
                    id: j,
                    // gradients arrive l + l' after fwd finishes (constraint (2)).
                    release: fwd[j].finish() + inst.l[e] + inst.lp[e],
                    proc: inst.pp[e],
                    tail: inst.rp[e],
                }
            })
            .collect();
        // Horizon: everything fits within max release + total work + busy.
        let max_rel = jobs.iter().map(|j| j.release).max().unwrap_or(0);
        let total: u32 = jobs.iter().map(|j| j.proc).sum();
        let horizon = max_rel + total + busy.len() + 1;
        let free = busy.complement(horizon);
        let solved = preemptive_min_max_tail(&jobs, &free);
        for (k, &j) in clients.iter().enumerate() {
            bwd[j] = solved[k].clone();
        }
    }
    bwd
}

/// Convenience: assemble a full [`Schedule`] from assignment + fwd runs by
/// optimally scheduling the bwd direction (the ℙ_f → ℙ_b pipeline).
pub fn complete_with_optimal_bwd(inst: &Instance, assignment: Assignment, fwd: Vec<SlotRuns>) -> Schedule {
    let bwd = optimal_bwd(inst, &assignment, &fwd);
    Schedule { assignment, fwd, bwd }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    /// Exhaustive optimal preemptive min-max-tail by DFS over decision
    /// points (only for tiny cases): at each free slot pick any released
    /// unfinished job (idling is dominated, but we allow skipping the slot
    /// when nothing is released).
    fn brute_force(jobs: &[Job], free: &[u32]) -> u32 {
        fn dfs(jobs: &[Job], free: &[u32], k: usize, rem: &mut Vec<u32>, finish: &mut Vec<u32>, best: &mut u32) {
            if rem.iter().all(|&r| r == 0) {
                let cost = jobs
                    .iter()
                    .enumerate()
                    .map(|(idx, j)| finish[idx] + j.tail)
                    .max()
                    .unwrap_or(0);
                *best = (*best).min(cost);
                return;
            }
            if k >= free.len() {
                return;
            }
            // Cheap bound: current partial max cost.
            let partial = jobs
                .iter()
                .enumerate()
                .filter(|(idx, _)| rem[*idx] == 0)
                .map(|(idx, j)| finish[idx] + j.tail)
                .max()
                .unwrap_or(0);
            if partial >= *best {
                return;
            }
            let t = free[k];
            let mut any = false;
            for idx in 0..jobs.len() {
                if rem[idx] > 0 && jobs[idx].release <= t {
                    any = true;
                    rem[idx] -= 1;
                    let old = finish[idx];
                    if rem[idx] == 0 {
                        finish[idx] = t + 1;
                    }
                    dfs(jobs, free, k + 1, rem, finish, best);
                    finish[idx] = old;
                    rem[idx] += 1;
                }
            }
            if !any {
                dfs(jobs, free, k + 1, rem, finish, best);
            }
        }
        let mut rem: Vec<u32> = jobs.iter().map(|j| j.proc).collect();
        let mut finish = vec![0u32; jobs.len()];
        let mut best = u32::MAX;
        dfs(jobs, free, 0, &mut rem, &mut finish, &mut best);
        best
    }

    #[test]
    fn paper_fig4_worked_example() {
        // 5 clients, 1 helper. Releases/procs/tails chosen to match Fig 4:
        // blocks β1 = {1,4,2,3} (s=0, e=8), β2 = {5} (s=9, e=10);
        // ℓ(β1) = client 4 (min tail: e+r' = 8+1 = 9), final makespan 14.
        let jobs = [
            Job { id: 1, release: 0, proc: 2, tail: 5 },
            Job { id: 2, release: 3, proc: 2, tail: 3 },
            Job { id: 3, release: 5, proc: 1, tail: 8 },
            Job { id: 4, release: 1, proc: 2, tail: 1 },
            Job { id: 5, release: 9, proc: 1, tail: 1 },
        ];
        let free = SlotRuns::one(0, 20);
        let slots = preemptive_min_max_tail(&jobs, &free);
        let cost = max_tail_cost(&jobs, &slots);
        let dense_free: Vec<u32> = (0..20).collect();
        assert_eq!(cost, brute_force(&jobs, &dense_free), "block algorithm must be optimal");
        // Client 3 (index 2) drives the makespan: finish 6, cost 14.
        assert_eq!(cost, 14);
    }

    #[test]
    fn optimal_on_random_tiny_instances() {
        prop::check(150, |rng| {
            let n = rng.range_usize(1, 4);
            let jobs: Vec<Job> = (0..n)
                .map(|id| Job {
                    id,
                    release: rng.below(6) as u32,
                    proc: rng.range_usize(1, 3) as u32,
                    tail: rng.below(6) as u32,
                })
                .collect();
            let slots = preemptive_min_max_tail(&jobs, &SlotRuns::one(0, 24));
            let got = max_tail_cost(&jobs, &slots);
            let dense_free: Vec<u32> = (0..24).collect();
            let want = brute_force(&jobs, &dense_free);
            prop::assert_prop(got == want, &format!("block alg {got} != brute {want} for {jobs:?}"));
        });
    }

    #[test]
    fn optimal_with_busy_mask() {
        prop::check(80, |rng| {
            let n = rng.range_usize(1, 3);
            let jobs: Vec<Job> = (0..n)
                .map(|id| Job {
                    id,
                    release: rng.below(5) as u32,
                    proc: rng.range_usize(1, 3) as u32,
                    tail: rng.below(4) as u32,
                })
                .collect();
            // Knock out ~1/3 of slots.
            let dense_free: Vec<u32> = (0..30).filter(|_| !rng.chance(0.33)).collect();
            let total: u32 = jobs.iter().map(|j| j.proc).sum();
            if (dense_free.len() as u32) < total + 10 {
                return; // not enough room; skip case
            }
            let free = SlotRuns::from_slots(&dense_free);
            let slots = preemptive_min_max_tail(&jobs, &free);
            let got = max_tail_cost(&jobs, &slots);
            let want = brute_force(&jobs, &dense_free);
            prop::assert_prop(got == want, &format!("masked {got} != brute {want}"));
        });
    }

    #[test]
    fn respects_releases_and_free_slots() {
        prop::check(100, |rng| {
            let n = rng.range_usize(1, 6);
            let jobs: Vec<Job> = (0..n)
                .map(|id| Job {
                    id,
                    release: rng.below(10) as u32,
                    proc: rng.range_usize(1, 4) as u32,
                    tail: rng.below(8) as u32,
                })
                .collect();
            let dense_free: Vec<u32> = (0..60).filter(|_| !rng.chance(0.2)).collect();
            let free = SlotRuns::from_slots(&dense_free);
            let slots = preemptive_min_max_tail(&jobs, &free);
            let free_set: std::collections::HashSet<u32> = dense_free.iter().copied().collect();
            let mut used = std::collections::HashSet::new();
            for (k, s) in slots.iter().enumerate() {
                prop::assert_prop(s.is_normalized(), "output runs normalized");
                prop::assert_prop(s.len() == jobs[k].proc, "full processing");
                for t in s.iter_slots() {
                    prop::assert_prop(t >= jobs[k].release, "release respected");
                    prop::assert_prop(free_set.contains(&t), "only free slots used");
                    prop::assert_prop(used.insert(t), "no slot reused");
                }
            }
        });
    }

    #[test]
    fn contiguous_fast_path_matches_general_path() {
        prop::check(120, |rng| {
            let n = rng.range_usize(1, 8);
            let jobs: Vec<Job> = (0..n)
                .map(|id| Job {
                    id,
                    release: rng.below(20) as u32,
                    proc: rng.range_usize(1, 5) as u32,
                    tail: rng.below(10) as u32,
                })
                .collect();
            let total: u32 = jobs.iter().map(|j| j.proc).sum();
            let horizon = 20 + total + 1;
            let a = preemptive_min_max_tail(&jobs, &SlotRuns::one(0, horizon));
            let b = preemptive_min_max_tail_contiguous(&jobs);
            prop::assert_prop(
                max_tail_cost(&jobs, &a) == max_tail_cost(&jobs, &b),
                &format!("fast path cost mismatch on {jobs:?}"),
            );
            // Run sets must be identical (same deterministic algorithm).
            prop::assert_prop(a == b, "fast path runs differ");
        });
    }

    #[test]
    fn ldt_cost_matches_block_algorithm() {
        // The cost-only evaluator must agree with the materializing block
        // algorithm on every input (both are optimal; the values coincide).
        let mut scratch = CostScratch::default();
        prop::check(200, |rng| {
            let n = rng.range_usize(0, 9);
            let jobs: Vec<Job> = (0..n)
                .map(|id| Job {
                    id,
                    release: rng.below(25) as u32,
                    proc: rng.range_usize(1, 6) as u32,
                    tail: rng.below(12) as u32,
                })
                .collect();
            let slots = preemptive_min_max_tail_contiguous(&jobs);
            let want = max_tail_cost(&jobs, &slots);
            let mut local = CostScratch::default();
            let got = preemptive_cost_contiguous(&jobs, &mut local);
            prop::assert_prop(got == want, &format!("LDT {got} != block {want} on {jobs:?}"));
        });
        // Scratch reuse across calls gives the same answers.
        let jobs = [
            Job { id: 0, release: 0, proc: 3, tail: 4 },
            Job { id: 1, release: 1, proc: 2, tail: 9 },
        ];
        let a = preemptive_cost_contiguous(&jobs, &mut scratch);
        let b = preemptive_cost_contiguous(&jobs, &mut scratch);
        assert_eq!(a, b);
        assert_eq!(a, max_tail_cost(&jobs, &preemptive_min_max_tail_contiguous(&jobs)));
    }

    #[test]
    fn optimal_bwd_feasible_end_to_end() {
        use crate::solver::schedule::{fcfs_schedule, Assignment};
        prop::check(60, |rng| {
            let inst = crate::solver::schedule::tests::tiny_instance(rng, 8, 2);
            let a = Assignment::new((0..8).map(|_| rng.below(2)).collect());
            // Take the FCFS fwd schedule, re-optimize bwd via Alg. 2.
            let fcfs = fcfs_schedule(&inst, a.clone());
            let opt = complete_with_optimal_bwd(&inst, a, fcfs.fwd.clone());
            let hard: Vec<_> = opt
                .violations(&inst)
                .into_iter()
                .filter(|m| !m.starts_with("(5)"))
                .collect();
            prop::assert_prop(hard.is_empty(), &format!("violations {hard:?}"));
            // Alg. 2 can only improve on the FCFS bwd schedule.
            prop::assert_prop(
                opt.makespan(&inst) <= fcfs.makespan(&inst),
                &format!("optimal bwd {} worse than FCFS {}", opt.makespan(&inst), fcfs.makespan(&inst)),
            );
        });
    }
}
