//! Algorithm 2: optimal preemptive single-machine scheduling minimizing
//! the maximum completion cost subject to release dates — the paper's
//! polynomial-time solution of ℙ_b (Theorem 2), based on the block
//! decomposition of Baker, Lawler, Lenstra & Rinnooy Kan (Oper. Res. '83).
//!
//! We implement it generically over a *free-slot list* (the machine may be
//! pre-occupied by fwd-prop slots — constraint (3) couples the two
//! directions), with cost functions of the form `finish + tail`:
//!
//! * **bwd-prop** (the paper's use): job j has release `φ^f_j + l + l'`
//!   (gradients arrive at the helper), processing `p'_ij`, tail `r'_ij`
//!   (cost = φ_j + π_j = the client's batch completion).
//! * **fwd-prop per helper** (our reuse inside ADMM and the exact solver):
//!   release `r_ij`, processing `p_ij`, tail `l_ij` (cost = c^f_j).
//!
//! The block decomposition is exactly the worked example of the paper's
//! Fig. 4: build the FCFS-by-arrival schedule, split into maximal non-idle
//! *blocks*; within each block pick ℓ = argmin_{j∈β} (e(β) + tail_j),
//! schedule the remaining jobs FCFS (forming sub-blocks, recursed on) and
//! let ℓ soak up the leftover slots, finishing at e(β).

/// One schedulable task.
#[derive(Clone, Copy, Debug)]
pub struct Job {
    /// Caller-defined identifier (client id).
    pub id: usize,
    /// Earliest slot the task may run in.
    pub release: u32,
    /// Number of slots of work.
    pub proc: u32,
    /// Cost tail: job cost = (last slot + 1) + tail. Must be nonnegative.
    pub tail: u32,
}

/// Schedule `jobs` preemptively over the sorted free-slot list `free`,
/// minimizing `max_j (finish_j + tail_j)`. Returns the slot list per job
/// (indexed like `jobs`). Panics if `free` has too few slots ≥ releases.
pub fn preemptive_min_max_tail(jobs: &[Job], free: &[u32]) -> Vec<Vec<u32>> {
    debug_assert!(free.windows(2).all(|w| w[1] > w[0]), "free slots must be sorted");
    let mut out = vec![Vec::new(); jobs.len()];
    if jobs.is_empty() {
        return out;
    }
    // Order job indices by release (ties by id for determinism).
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    order.sort_by_key(|&k| (jobs[k].release, jobs[k].id));

    // --- Phase 1: FCFS simulation to find blocks --------------------------
    // A block is a maximal group of jobs processed with no (voluntary)
    // idle slot in between; blocks are independent (Baker et al.).
    let mut blocks: Vec<(Vec<usize>, Vec<u32>)> = Vec::new(); // (job idxs, slots used)
    let mut cursor = 0usize; // index into `free`
    let mut k = 0usize;
    while k < order.len() {
        // Start a new block at the first free slot ≥ this job's release.
        let mut members = Vec::new();
        let mut slots = Vec::new();
        let mut remaining: u32 = 0;
        let first_rel = jobs[order[k]].release;
        while cursor < free.len() && free[cursor] < first_rel {
            cursor += 1;
        }
        members.push(order[k]);
        remaining += jobs[order[k]].proc;
        k += 1;
        while remaining > 0 {
            assert!(cursor < free.len(), "free-slot list exhausted (horizon too small)");
            let t = free[cursor];
            // Absorb any job released by slot t into the running block.
            while k < order.len() && jobs[order[k]].release <= t {
                members.push(order[k]);
                remaining += jobs[order[k]].proc;
                k += 1;
            }
            slots.push(t);
            remaining -= 1;
            cursor += 1;
        }
        blocks.push((members, slots));
    }

    // --- Phase 2: recursive ordering within each block ---------------------
    for (members, slots) in blocks {
        schedule_block(jobs, &members, &slots, &mut out);
    }
    out
}

/// Recursively schedule `members` (indices into `jobs`) over exactly
/// `slots` (|slots| = Σ proc), writing the per-job slot lists into `out`.
fn schedule_block(jobs: &[Job], members: &[usize], slots: &[u32], out: &mut Vec<Vec<u32>>) {
    debug_assert_eq!(slots.len() as u64, members.iter().map(|&k| jobs[k].proc as u64).sum::<u64>());
    if members.len() == 1 {
        out[members[0]] = slots.to_vec();
        return;
    }
    // ℓ = argmin_{j ∈ β} (e(β) + tail_j): since e(β) is common, the job
    // with the smallest tail — it is pushed last and finishes at e(β).
    let ell = *members
        .iter()
        .min_by_key(|&&k| (jobs[k].tail, jobs[k].id))
        .unwrap();

    // FCFS the remaining jobs over the block's slots; untaken slots go to ℓ.
    let mut rest: Vec<usize> = members.iter().copied().filter(|&k| k != ell).collect();
    rest.sort_by_key(|&k| (jobs[k].release, jobs[k].id));
    let mut ell_slots: Vec<u32> = Vec::new();
    // Sub-blocks of `rest`: maximal runs of slots where some rest-job runs.
    let mut sub: Vec<(Vec<usize>, Vec<u32>)> = Vec::new();
    let mut cur_members: Vec<usize> = Vec::new();
    let mut cur_slots: Vec<u32> = Vec::new();
    let mut queue: std::collections::VecDeque<usize> = std::collections::VecDeque::new();
    let mut next = 0usize; // next rest job to arrive
    let mut rem: Vec<u32> = jobs.iter().map(|j| j.proc).collect();
    for &t in slots {
        while next < rest.len() && jobs[rest[next]].release <= t {
            queue.push_back(rest[next]);
            next += 1;
        }
        if let Some(&front) = queue.front() {
            if !cur_members.contains(&front) {
                cur_members.push(front);
            }
            cur_slots.push(t);
            rem[front] -= 1;
            if rem[front] == 0 {
                queue.pop_front();
            }
        } else {
            // ℓ runs here; any in-flight sub-block is closed.
            ell_slots.push(t);
            if !cur_members.is_empty() {
                sub.push((std::mem::take(&mut cur_members), std::mem::take(&mut cur_slots)));
            }
        }
    }
    if !cur_members.is_empty() {
        sub.push((cur_members, cur_slots));
    }
    debug_assert_eq!(ell_slots.len(), jobs[ell].proc as usize);
    out[ell] = ell_slots;
    for (m, s) in sub {
        schedule_block(jobs, &m, &s, out);
    }
}

/// Fast path for a fully-free machine (no busy mask): block boundaries
/// are computed arithmetically instead of scanning a free-slot list, so
/// the cost is O(n log n + Σ proc) independent of the horizon. This is
/// the ADMM w-subproblem's hot loop (fwd scheduling is always on an
/// empty machine).
pub fn preemptive_min_max_tail_contiguous(jobs: &[Job]) -> Vec<Vec<u32>> {
    let mut out = vec![Vec::new(); jobs.len()];
    if jobs.is_empty() {
        return out;
    }
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    order.sort_by_key(|&k| (jobs[k].release, jobs[k].id));
    let mut k = 0usize;
    while k < order.len() {
        let s = jobs[order[k]].release;
        let mut e = s + jobs[order[k]].proc;
        let mut members = vec![order[k]];
        k += 1;
        while k < order.len() && jobs[order[k]].release < e {
            e += jobs[order[k]].proc;
            members.push(order[k]);
            k += 1;
        }
        let slots: Vec<u32> = (s..e).collect();
        schedule_block(jobs, &members, &slots, &mut out);
    }
    out
}

/// Objective value of a per-job slot listing: max_j (finish + tail).
pub fn max_tail_cost(jobs: &[Job], slots: &[Vec<u32>]) -> u32 {
    jobs.iter()
        .zip(slots)
        .map(|(j, s)| s.last().map(|&t| t + 1).unwrap_or(j.release) + j.tail)
        .max()
        .unwrap_or(0)
}

/// Build the sorted free-slot list `[0, horizon)` minus `busy`.
pub fn free_slots(horizon: u32, busy: &std::collections::HashSet<u32>) -> Vec<u32> {
    (0..horizon).filter(|t| !busy.contains(t)).collect()
}

// ----------------------------------------------------------------------------
// Algorithm 2 entry point: optimal bwd-prop schedule given (y*, x*).
// ----------------------------------------------------------------------------

use super::schedule::{Assignment, Schedule};
use crate::instance::Instance;

/// Solve ℙ_b: given the assignment and the fwd slots, compute the optimal
/// preemptive bwd schedule per helper (in parallel across helpers in the
/// paper; sequentially here — each helper is independent).
pub fn optimal_bwd(inst: &Instance, assignment: &Assignment, fwd_slots: &[Vec<u32>]) -> Vec<Vec<u32>> {
    let mut bwd = vec![Vec::new(); inst.n_clients];
    for i in 0..inst.n_helpers {
        let clients = assignment.clients_of(i);
        if clients.is_empty() {
            continue;
        }
        let mut busy: std::collections::HashSet<u32> = std::collections::HashSet::new();
        for &j in &clients {
            busy.extend(fwd_slots[j].iter().copied());
        }
        let jobs: Vec<Job> = clients
            .iter()
            .map(|&j| {
                let e = inst.edge(i, j);
                let phi_f = fwd_slots[j].last().map(|&t| t + 1).unwrap_or(0);
                Job {
                    id: j,
                    // gradients arrive l + l' after fwd finishes (constraint (2)).
                    release: phi_f + inst.l[e] + inst.lp[e],
                    proc: inst.pp[e],
                    tail: inst.rp[e],
                }
            })
            .collect();
        // Horizon: everything fits within max release + total work + busy.
        let max_rel = jobs.iter().map(|j| j.release).max().unwrap_or(0);
        let total: u32 = jobs.iter().map(|j| j.proc).sum();
        let horizon = max_rel + total + fwd_slots.iter().map(|s| s.len() as u32).sum::<u32>() + 1;
        let free = free_slots(horizon, &busy);
        let solved = preemptive_min_max_tail(&jobs, &free);
        for (k, &j) in clients.iter().enumerate() {
            bwd[j] = solved[k].clone();
        }
    }
    bwd
}

/// Convenience: assemble a full [`Schedule`] from assignment + fwd slots by
/// optimally scheduling the bwd direction (the ℙ_f → ℙ_b pipeline).
pub fn complete_with_optimal_bwd(inst: &Instance, assignment: Assignment, fwd_slots: Vec<Vec<u32>>) -> Schedule {
    let bwd_slots = optimal_bwd(inst, &assignment, &fwd_slots);
    Schedule { assignment, fwd_slots, bwd_slots }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    /// Exhaustive optimal preemptive min-max-tail by DFS over decision
    /// points (only for tiny cases): at each free slot pick any released
    /// unfinished job (idling is dominated, but we allow skipping the slot
    /// when nothing is released).
    fn brute_force(jobs: &[Job], free: &[u32]) -> u32 {
        fn dfs(jobs: &[Job], free: &[u32], k: usize, rem: &mut Vec<u32>, finish: &mut Vec<u32>, best: &mut u32) {
            if rem.iter().all(|&r| r == 0) {
                let cost = jobs
                    .iter()
                    .enumerate()
                    .map(|(idx, j)| finish[idx] + j.tail)
                    .max()
                    .unwrap_or(0);
                *best = (*best).min(cost);
                return;
            }
            if k >= free.len() {
                return;
            }
            // Cheap bound: current partial max cost.
            let partial = jobs
                .iter()
                .enumerate()
                .filter(|(idx, _)| rem[*idx] == 0)
                .map(|(idx, j)| finish[idx] + j.tail)
                .max()
                .unwrap_or(0);
            if partial >= *best {
                return;
            }
            let t = free[k];
            let mut any = false;
            for idx in 0..jobs.len() {
                if rem[idx] > 0 && jobs[idx].release <= t {
                    any = true;
                    rem[idx] -= 1;
                    let old = finish[idx];
                    if rem[idx] == 0 {
                        finish[idx] = t + 1;
                    }
                    dfs(jobs, free, k + 1, rem, finish, best);
                    finish[idx] = old;
                    rem[idx] += 1;
                }
            }
            if !any {
                dfs(jobs, free, k + 1, rem, finish, best);
            }
        }
        let mut rem: Vec<u32> = jobs.iter().map(|j| j.proc).collect();
        let mut finish = vec![0u32; jobs.len()];
        let mut best = u32::MAX;
        dfs(jobs, free, 0, &mut rem, &mut finish, &mut best);
        best
    }

    #[test]
    fn paper_fig4_worked_example() {
        // 5 clients, 1 helper. Releases/procs/tails chosen to match Fig 4:
        // blocks β1 = {1,4,2,3} (s=0, e=8), β2 = {5} (s=9, e=10);
        // ℓ(β1) = client 4 (min tail: e+r' = 8+1 = 9), final makespan 14.
        // Client ids 1..5 → indices 0..4; tails r' = {5, 3, 8, 1, 1}? —
        // reconstruct from the example: min{8+5, 8+3, 8+8, 8+1} = 9 at
        // client 4; within β12, ℓ' = 2 since min{7+3, 7+8} = 10; client 3
        // finishes last: makespan 14 (= φ_3 + r'_3).
        let jobs = [
            Job { id: 1, release: 0, proc: 2, tail: 5 },
            Job { id: 2, release: 3, proc: 2, tail: 3 },
            Job { id: 3, release: 5, proc: 1, tail: 8 },
            Job { id: 4, release: 1, proc: 2, tail: 1 },
            Job { id: 5, release: 9, proc: 1, tail: 1 },
        ];
        let free: Vec<u32> = (0..20).collect();
        let slots = preemptive_min_max_tail(&jobs, &free);
        let cost = max_tail_cost(&jobs, &slots);
        assert_eq!(cost, brute_force(&jobs, &free), "block algorithm must be optimal");
        // Client 3 (index 2) drives the makespan: finish 6, cost 14.
        assert_eq!(cost, 14);
    }

    #[test]
    fn optimal_on_random_tiny_instances() {
        prop::check(150, |rng| {
            let n = rng.range_usize(1, 4);
            let jobs: Vec<Job> = (0..n)
                .map(|id| Job {
                    id,
                    release: rng.below(6) as u32,
                    proc: rng.range_usize(1, 3) as u32,
                    tail: rng.below(6) as u32,
                })
                .collect();
            let free: Vec<u32> = (0..24).collect();
            let slots = preemptive_min_max_tail(&jobs, &free);
            let got = max_tail_cost(&jobs, &slots);
            let want = brute_force(&jobs, &free);
            prop::assert_prop(got == want, &format!("block alg {got} != brute {want} for {jobs:?}"));
        });
    }

    #[test]
    fn optimal_with_busy_mask() {
        prop::check(80, |rng| {
            let n = rng.range_usize(1, 3);
            let jobs: Vec<Job> = (0..n)
                .map(|id| Job {
                    id,
                    release: rng.below(5) as u32,
                    proc: rng.range_usize(1, 3) as u32,
                    tail: rng.below(4) as u32,
                })
                .collect();
            // Knock out ~1/3 of slots.
            let free: Vec<u32> = (0..30).filter(|_| !rng.chance(0.33)).collect();
            let total: u32 = jobs.iter().map(|j| j.proc).sum();
            if (free.len() as u32) < total + 10 {
                return; // not enough room; skip case
            }
            let slots = preemptive_min_max_tail(&jobs, &free);
            let got = max_tail_cost(&jobs, &slots);
            let want = brute_force(&jobs, &free);
            prop::assert_prop(got == want, &format!("masked {got} != brute {want}"));
        });
    }

    #[test]
    fn respects_releases_and_free_slots() {
        prop::check(100, |rng| {
            let n = rng.range_usize(1, 6);
            let jobs: Vec<Job> = (0..n)
                .map(|id| Job {
                    id,
                    release: rng.below(10) as u32,
                    proc: rng.range_usize(1, 4) as u32,
                    tail: rng.below(8) as u32,
                })
                .collect();
            let free: Vec<u32> = (0..60).filter(|_| !rng.chance(0.2)).collect();
            let slots = preemptive_min_max_tail(&jobs, &free);
            let free_set: std::collections::HashSet<u32> = free.iter().copied().collect();
            let mut used = std::collections::HashSet::new();
            for (k, s) in slots.iter().enumerate() {
                prop::assert_prop(s.len() == jobs[k].proc as usize, "full processing");
                for &t in s {
                    prop::assert_prop(t >= jobs[k].release, "release respected");
                    prop::assert_prop(free_set.contains(&t), "only free slots used");
                    prop::assert_prop(used.insert(t), "no slot reused");
                }
            }
        });
    }

    #[test]
    fn contiguous_fast_path_matches_general_path() {
        prop::check(120, |rng| {
            let n = rng.range_usize(1, 8);
            let jobs: Vec<Job> = (0..n)
                .map(|id| Job {
                    id,
                    release: rng.below(20) as u32,
                    proc: rng.range_usize(1, 5) as u32,
                    tail: rng.below(10) as u32,
                })
                .collect();
            let total: u32 = jobs.iter().map(|j| j.proc).sum();
            let horizon = 20 + total + 1;
            let free: Vec<u32> = (0..horizon).collect();
            let a = preemptive_min_max_tail(&jobs, &free);
            let b = preemptive_min_max_tail_contiguous(&jobs);
            prop::assert_prop(
                max_tail_cost(&jobs, &a) == max_tail_cost(&jobs, &b),
                &format!("fast path cost mismatch on {jobs:?}"),
            );
            // Slot sets must be identical (same deterministic algorithm).
            prop::assert_prop(a == b, "fast path slots differ");
        });
    }

    #[test]
    fn optimal_bwd_feasible_end_to_end() {
        use crate::solver::schedule::{fcfs_schedule, Assignment};
        prop::check(60, |rng| {
            let inst = crate::solver::schedule::tests::tiny_instance(rng, 8, 2);
            let a = Assignment::new((0..8).map(|_| rng.below(2)).collect());
            // Take the FCFS fwd schedule, re-optimize bwd via Alg. 2.
            let fcfs = fcfs_schedule(&inst, a.clone());
            let opt = complete_with_optimal_bwd(&inst, a, fcfs.fwd_slots.clone());
            let hard: Vec<_> = opt
                .violations(&inst)
                .into_iter()
                .filter(|m| !m.starts_with("(5)"))
                .collect();
            prop::assert_prop(hard.is_empty(), &format!("violations {hard:?}"));
            // Alg. 2 can only improve on the FCFS bwd schedule.
            prop::assert_prop(
                opt.makespan(&inst) <= fcfs.makespan(&inst),
                &format!("optimal bwd {} worse than FCFS {}", opt.makespan(&inst), fcfs.makespan(&inst)),
            );
        });
    }
}
