//! Dense-tableau simplex LP solver (substrate: the image has no LP/MILP
//! library, and the paper's exact baseline was Gurobi).
//!
//! Scope: the time-indexed ILP relaxations built by [`super::model`] for
//! *small* instances, used to cross-validate the specialized exact solver
//! and to power the generic branch-and-bound in [`super::milp`]. This is
//! a textbook two-phase-by-Big-M implementation with Bland's rule as the
//! anti-cycling fallback — O(m·n) per pivot, dense storage; perfectly
//! adequate for a few hundred variables, *not* intended for large models
//! (that is exactly why the repo has the specialized solvers).

/// Constraint sense.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sense {
    Le,
    Ge,
    Eq,
}

/// One linear constraint: Σ coeffs·x (sense) rhs.
#[derive(Clone, Debug)]
pub struct Constraint {
    /// Sparse (var, coeff) pairs.
    pub terms: Vec<(usize, f64)>,
    pub sense: Sense,
    pub rhs: f64,
}

/// An LP: minimize objective·x subject to constraints, 0 ≤ x ≤ upper.
#[derive(Clone, Debug, Default)]
pub struct Lp {
    pub n_vars: usize,
    pub objective: Vec<f64>,
    pub constraints: Vec<Constraint>,
    /// Optional per-var upper bound (None = +inf).
    pub upper: Vec<Option<f64>>,
}

#[derive(Clone, Debug, PartialEq)]
pub enum LpOutcome {
    Optimal { x: Vec<f64>, obj: f64 },
    Infeasible,
    Unbounded,
}

impl Lp {
    pub fn new(n_vars: usize) -> Lp {
        Lp { n_vars, objective: vec![0.0; n_vars], constraints: Vec::new(), upper: vec![None; n_vars] }
    }

    pub fn add(&mut self, terms: Vec<(usize, f64)>, sense: Sense, rhs: f64) {
        debug_assert!(terms.iter().all(|&(v, _)| v < self.n_vars));
        self.constraints.push(Constraint { terms, sense, rhs });
    }

    /// Solve with the Big-M simplex. Upper bounds are handled by adding
    /// explicit x ≤ u rows (dense tableau keeps the code simple).
    pub fn solve(&self) -> LpOutcome {
        const BIG_M: f64 = 1e7;
        const EPS: f64 = 1e-7;

        // Materialize upper bounds as rows.
        let mut rows: Vec<Constraint> = self.constraints.clone();
        for (v, u) in self.upper.iter().enumerate() {
            if let Some(u) = u {
                rows.push(Constraint { terms: vec![(v, 1.0)], sense: Sense::Le, rhs: *u });
            }
        }
        // Normalize to nonnegative rhs.
        for c in &mut rows {
            if c.rhs < 0.0 {
                c.rhs = -c.rhs;
                for t in &mut c.terms {
                    t.1 = -t.1;
                }
                c.sense = match c.sense {
                    Sense::Le => Sense::Ge,
                    Sense::Ge => Sense::Le,
                    Sense::Eq => Sense::Eq,
                };
            }
        }
        let m = rows.len();
        // Columns: structural | slack/surplus | artificial.
        let n_slack = rows.iter().filter(|c| c.sense != Sense::Eq).count();
        let n_art = rows.iter().filter(|c| c.sense != Sense::Le).count();
        let n_total = self.n_vars + n_slack + n_art;
        let mut tab = vec![vec![0.0f64; n_total + 1]; m];
        let mut cost = vec![0.0f64; n_total];
        cost[..self.n_vars].copy_from_slice(&self.objective);
        let mut basis = vec![usize::MAX; m];
        let (mut s_idx, mut a_idx) = (self.n_vars, self.n_vars + n_slack);
        for (row, c) in rows.iter().enumerate() {
            for &(v, coef) in &c.terms {
                tab[row][v] += coef;
            }
            tab[row][n_total] = c.rhs;
            match c.sense {
                Sense::Le => {
                    tab[row][s_idx] = 1.0;
                    basis[row] = s_idx;
                    s_idx += 1;
                }
                Sense::Ge => {
                    tab[row][s_idx] = -1.0;
                    s_idx += 1;
                    tab[row][a_idx] = 1.0;
                    cost[a_idx] = BIG_M;
                    basis[row] = a_idx;
                    a_idx += 1;
                }
                Sense::Eq => {
                    tab[row][a_idx] = 1.0;
                    cost[a_idx] = BIG_M;
                    basis[row] = a_idx;
                    a_idx += 1;
                }
            }
        }

        // Reduced costs z_j - c_j maintained via a price row.
        let mut price = vec![0.0f64; n_total + 1];
        for j in 0..=n_total {
            let mut z = 0.0;
            for row in 0..m {
                z += cost[basis[row]] * tab[row][j];
            }
            price[j] = z - if j < n_total { cost[j] } else { 0.0 };
        }

        let mut iters = 0usize;
        let max_iters = 200 * (m + n_total).max(50);
        loop {
            iters += 1;
            if iters > max_iters {
                // Numerical trouble; declare the worst.
                return LpOutcome::Infeasible;
            }
            // Entering: most positive reduced cost (Dantzig); Bland after
            // long stalls.
            let bland = iters > max_iters / 2;
            let mut enter = None;
            if bland {
                for j in 0..n_total {
                    if price[j] > EPS {
                        enter = Some(j);
                        break;
                    }
                }
            } else {
                let mut best = EPS;
                for j in 0..n_total {
                    if price[j] > best {
                        best = price[j];
                        enter = Some(j);
                    }
                }
            }
            let Some(e) = enter else { break };
            // Ratio test.
            let mut leave = None;
            let mut best_ratio = f64::INFINITY;
            for row in 0..m {
                if tab[row][e] > EPS {
                    let ratio = tab[row][n_total] / tab[row][e];
                    if ratio < best_ratio - EPS || (bland && (ratio - best_ratio).abs() <= EPS && leave.map(|l| basis[l] > basis[row]).unwrap_or(false)) {
                        best_ratio = ratio;
                        leave = Some(row);
                    }
                }
            }
            let Some(lv) = leave else {
                return LpOutcome::Unbounded;
            };
            // Pivot.
            let piv = tab[lv][e];
            for j in 0..=n_total {
                tab[lv][j] /= piv;
            }
            for row in 0..m {
                if row != lv && tab[row][e].abs() > 1e-12 {
                    let f = tab[row][e];
                    for j in 0..=n_total {
                        tab[row][j] -= f * tab[lv][j];
                    }
                }
            }
            let f = price[e];
            for j in 0..=n_total {
                price[j] -= f * tab[lv][j];
            }
            basis[lv] = e;
        }

        // Infeasible if an artificial stays basic at positive level.
        for row in 0..m {
            if basis[row] >= self.n_vars + n_slack && tab[row][n_total] > 1e-5 {
                return LpOutcome::Infeasible;
            }
        }
        let mut x = vec![0.0f64; self.n_vars];
        for row in 0..m {
            if basis[row] < self.n_vars {
                x[basis[row]] = tab[row][n_total];
            }
        }
        let obj = self.objective.iter().zip(&x).map(|(c, v)| c * v).sum();
        LpOutcome::Optimal { x, obj }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_2d() {
        // min -x - 2y st x + y <= 4, x <= 3, y <= 2 → x=2? optimum at
        // (2, 2): obj -6.
        let mut lp = Lp::new(2);
        lp.objective = vec![-1.0, -2.0];
        lp.add(vec![(0, 1.0), (1, 1.0)], Sense::Le, 4.0);
        lp.upper[0] = Some(3.0);
        lp.upper[1] = Some(2.0);
        match lp.solve() {
            LpOutcome::Optimal { x, obj } => {
                assert!((obj + 6.0).abs() < 1e-6, "obj {obj}");
                assert!((x[0] - 2.0).abs() < 1e-6 && (x[1] - 2.0).abs() < 1e-6, "{x:?}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn equality_and_ge() {
        // min x + y st x + y = 3, x >= 1 → obj 3 with x in [1,3].
        let mut lp = Lp::new(2);
        lp.objective = vec![1.0, 1.0];
        lp.add(vec![(0, 1.0), (1, 1.0)], Sense::Eq, 3.0);
        lp.add(vec![(0, 1.0)], Sense::Ge, 1.0);
        match lp.solve() {
            LpOutcome::Optimal { x, obj } => {
                assert!((obj - 3.0).abs() < 1e-6);
                assert!(x[0] >= 1.0 - 1e-6);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn detects_infeasible() {
        let mut lp = Lp::new(1);
        lp.objective = vec![1.0];
        lp.add(vec![(0, 1.0)], Sense::Ge, 5.0);
        lp.upper[0] = Some(2.0);
        assert_eq!(lp.solve(), LpOutcome::Infeasible);
    }

    #[test]
    fn detects_unbounded() {
        let mut lp = Lp::new(1);
        lp.objective = vec![-1.0];
        lp.add(vec![(0, 1.0)], Sense::Ge, 0.0);
        assert_eq!(lp.solve(), LpOutcome::Unbounded);
    }

    #[test]
    fn degenerate_does_not_cycle() {
        // A classically degenerate LP (multiple optimal bases).
        let mut lp = Lp::new(3);
        lp.objective = vec![-0.75, 150.0, -0.02];
        lp.add(vec![(0, 0.25), (1, -60.0), (2, -0.04)], Sense::Le, 0.0);
        lp.add(vec![(0, 0.5), (1, -90.0), (2, -0.02)], Sense::Le, 0.0);
        lp.add(vec![(2, 1.0)], Sense::Le, 1.0);
        match lp.solve() {
            LpOutcome::Optimal { .. } => {}
            other => panic!("degenerate LP failed: {other:?}"),
        }
    }

    #[test]
    fn negative_rhs_normalization() {
        // min x st -x <= -2 (i.e. x >= 2).
        let mut lp = Lp::new(1);
        lp.objective = vec![1.0];
        lp.add(vec![(0, -1.0)], Sense::Le, -2.0);
        match lp.solve() {
            LpOutcome::Optimal { x, .. } => assert!((x[0] - 2.0).abs() < 1e-6),
            other => panic!("{other:?}"),
        }
    }
}
