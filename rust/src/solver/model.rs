//! Time-indexed ILP formulation of ℙ — a direct transcription of the
//! paper's constraints (1)–(11) plus the min-max transformation (ξ ≥ c_j,
//! minimize ξ) described in §IV.
//!
//! This is the formulation the paper hands to Gurobi. We hand it to our
//! own [`super::milp`] solver. Dense time-indexed models explode with T
//! (the paper's J=20 instance already took Gurobi 14 h to a 40% gap), so
//! this builder is used for *tiny* instances only: unit-level ground truth
//! for the specialized exact solver in [`super::exact`] and for the
//! decomposition heuristics.

use super::lp::{Lp, Sense};
use super::milp::{Milp, MilpCfg, MilpOutcome};
use super::schedule::{Assignment, Schedule, SlotRuns};
use crate::instance::Instance;

/// Variable layout for the time-indexed model.
pub struct TimeIndexedModel {
    pub milp: Milp,
    t_horizon: usize,
    n_edges: usize,
    n_clients: usize,
    // offsets
    x0: usize,
    z0: usize,
    y0: usize,
    phi0: usize,
    c0: usize,
    xi: usize,
}

impl TimeIndexedModel {
    /// Build the ILP for instance `inst` with horizon `t_horizon` slots
    /// (use `inst.horizon()` for the paper's bound; smaller horizons make
    /// the model smaller but may be infeasible).
    pub fn build(inst: &Instance, t_horizon: u32) -> TimeIndexedModel {
        let t_n = t_horizon as usize;
        let e_n = inst.n_clients * inst.n_helpers;
        let j_n = inst.n_clients;
        let x0 = 0;
        let z0 = e_n * t_n;
        let y0 = 2 * e_n * t_n;
        let phi0 = y0 + e_n;
        let c0 = phi0 + j_n;
        let xi = c0 + j_n;
        let n_vars = xi + 1;
        let mut lp = Lp::new(n_vars);
        let mut integer = vec![false; n_vars];

        // Objective: minimize ξ.
        lp.objective[xi] = 1.0;

        // Variable bounds.
        for e in 0..e_n {
            for t in 0..t_n {
                lp.upper[x0 + e * t_n + t] = Some(1.0);
                lp.upper[z0 + e * t_n + t] = Some(1.0);
                integer[x0 + e * t_n + t] = true;
                integer[z0 + e * t_n + t] = true;
            }
            lp.upper[y0 + e] = Some(1.0);
            integer[y0 + e] = true;
        }
        for j in 0..j_n {
            lp.upper[phi0 + j] = Some(t_n as f64);
            lp.upper[c0 + j] = Some(t_n as f64);
        }
        lp.upper[xi] = Some(t_n as f64);

        for i in 0..inst.n_helpers {
            for j in 0..j_n {
                let e = inst.edge(i, j);
                let (r, l, lpp, p) = (inst.r[e], inst.l[e], inst.lp[e], inst.p[e]);
                // (1) x_ijt = 0 for t < r_ij (fix via upper bound 0).
                for t in 0..(r as usize).min(t_n) {
                    lp.upper[x0 + e * t_n + t] = Some(0.0);
                }
                // Implied: z before r + p + l + l' is impossible.
                let z_min = (r + p + l + lpp) as usize;
                for s in 0..z_min.min(t_n) {
                    lp.upper[z0 + e * t_n + s] = Some(0.0);
                }
                // (2) p_ij · z_ij(t+l+l') − Σ_{τ<t} x_ijτ ≤ 0.
                for t in 0..t_n {
                    let s = t + (l + lpp) as usize;
                    if s >= t_n {
                        break;
                    }
                    let mut terms = vec![(z0 + e * t_n + s, p as f64)];
                    for tau in 0..t {
                        terms.push((x0 + e * t_n + tau, -1.0));
                    }
                    lp.add(terms, Sense::Le, 0.0);
                }
                // (6) Σ_t x = y p;  (7) Σ_t z = y p'.
                let mut t6: Vec<(usize, f64)> = (0..t_n).map(|t| (x0 + e * t_n + t, 1.0)).collect();
                t6.push((y0 + e, -(inst.p[e] as f64)));
                lp.add(t6, Sense::Eq, 0.0);
                let mut t7: Vec<(usize, f64)> = (0..t_n).map(|t| (z0 + e * t_n + t, 1.0)).collect();
                t7.push((y0 + e, -(inst.pp[e] as f64)));
                lp.add(t7, Sense::Eq, 0.0);
                // (8) φ_j ≥ (t+1) z_ijt.
                for t in z_min..t_n {
                    lp.add(vec![(phi0 + j, 1.0), (z0 + e * t_n + t, -((t + 1) as f64))], Sense::Ge, 0.0);
                }
            }
        }
        // (3) Σ_j (x + z) ≤ 1 per helper/slot.
        for i in 0..inst.n_helpers {
            for t in 0..t_n {
                let mut terms = Vec::with_capacity(2 * j_n);
                for j in 0..j_n {
                    let e = inst.edge(i, j);
                    terms.push((x0 + e * t_n + t, 1.0));
                    terms.push((z0 + e * t_n + t, 1.0));
                }
                lp.add(terms, Sense::Le, 1.0);
            }
        }
        // (4) Σ_i y_ij = 1.
        for j in 0..j_n {
            let terms: Vec<(usize, f64)> = (0..inst.n_helpers).map(|i| (y0 + inst.edge(i, j), 1.0)).collect();
            lp.add(terms, Sense::Eq, 1.0);
        }
        // (5) Σ_j y_ij d_j ≤ m_i.
        for i in 0..inst.n_helpers {
            let terms: Vec<(usize, f64)> = (0..j_n).map(|j| (y0 + inst.edge(i, j), inst.d[j])).collect();
            lp.add(terms, Sense::Le, inst.mem[i]);
        }
        // (9) c_j = φ_j + Σ_i r'_ij y_ij;  ξ ≥ c_j.
        for j in 0..j_n {
            let mut terms = vec![(c0 + j, 1.0), (phi0 + j, -1.0)];
            for i in 0..inst.n_helpers {
                let e = inst.edge(i, j);
                terms.push((y0 + e, -(inst.rp[e] as f64)));
            }
            lp.add(terms, Sense::Eq, 0.0);
            lp.add(vec![(xi, 1.0), (c0 + j, -1.0)], Sense::Ge, 0.0);
        }

        TimeIndexedModel {
            milp: Milp { lp, integer },
            t_horizon: t_n,
            n_edges: e_n,
            n_clients: j_n,
            x0,
            z0,
            y0,
            phi0: phi0,
            c0,
            xi,
        }
    }

    /// Solve and extract (schedule, makespan). None if infeasible/capped
    /// without incumbent.
    pub fn solve(&self, inst: &Instance, cfg: &MilpCfg) -> Option<(Schedule, u32, bool)> {
        let (x, _obj, proven) = match self.milp.solve(cfg) {
            MilpOutcome::Optimal { x, obj, .. } => (x, obj, true),
            MilpOutcome::Capped { best: Some((x, obj)), .. } => (x, obj, false),
            _ => return None,
        };
        let t_n = self.t_horizon;
        let mut helper_of = vec![usize::MAX; self.n_clients];
        for i in 0..inst.n_helpers {
            for j in 0..self.n_clients {
                let e = inst.edge(i, j);
                if x[self.y0 + e] > 0.5 {
                    helper_of[j] = i;
                }
            }
        }
        let mut fwd = vec![SlotRuns::new(); self.n_clients];
        let mut bwd = vec![SlotRuns::new(); self.n_clients];
        for j in 0..self.n_clients {
            let i = helper_of[j];
            let e = inst.edge(i, j);
            // Dense extraction is inherent to the time-indexed model; the
            // slots arrive in time order so run-length encoding is free.
            for t in 0..t_n {
                if x[self.x0 + e * t_n + t] > 0.5 {
                    fwd[j].push_slot(t as u32);
                }
                if x[self.z0 + e * t_n + t] > 0.5 {
                    bwd[j].push_slot(t as u32);
                }
            }
        }
        let s = Schedule { assignment: Assignment::new(helper_of), fwd, bwd };
        let m = s.makespan(inst);
        let _ = (self.phi0, self.c0, self.xi, self.n_edges);
        Some((s, m, proven))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::exact::{self, ExactCfg};
    use crate::util::prop;

    fn tiny(rng: &mut crate::util::rng::Rng, jn: usize, in_: usize) -> Instance {
        // Unit tasks and near-zero lags: keeps the dense time-indexed
        // model small enough for the textbook simplex underneath.
        let e = jn * in_;
        let gen = |rng: &mut crate::util::rng::Rng, lo: u32, hi: u32| -> Vec<u32> {
            (0..e).map(|_| rng.range_usize(lo as usize, hi as usize) as u32).collect()
        };
        Instance {
            n_clients: jn,
            n_helpers: in_,
            slot_ms: 100.0,
            r: gen(rng, 0, 2),
            l: vec![0; e],
            lp: gen(rng, 0, 1),
            rp: gen(rng, 0, 1),
            p: vec![1; e],
            pp: vec![1; e],
            d: (0..jn).map(|_| 1.0).collect(),
            mem: (0..in_).map(|_| jn as f64).collect(),
            mu: vec![0; in_],
            label: "ilp-tiny".into(),
        }
    }

    #[test]
    fn ilp_matches_specialized_exact_solver() {
        // The crucial cross-validation: the generic time-indexed ILP and
        // the event-based exact B&B must agree on the optimum.
        prop::check(3, |rng| {
            let inst = tiny(rng, 2, 2);
            let horizon = inst.horizon();
            let model = TimeIndexedModel::build(&inst, horizon);
            let solved = model.solve(&inst, &MilpCfg { node_cap: 4_000, tol: 1e-6 });
            let Some((s_ilp, m_ilp, proven)) = solved else {
                return; // capped without incumbent — inconclusive case
            };
            if !proven {
                return;
            }
            prop::assert_prop(s_ilp.is_feasible(&inst), &format!("{:?}", s_ilp.violations(&inst)));
            let res = exact::solve(&inst, &ExactCfg::default());
            prop::assert_prop(res.proven_optimal, "exact should prove tiny instances");
            prop::assert_prop(
                m_ilp == res.makespan,
                &format!("ILP {m_ilp} != exact {} on {inst:?}", res.makespan),
            );
        });
    }

    #[test]
    fn ilp_schedule_is_feasible() {
        let mut rng = crate::util::rng::Rng::seeded(3);
        let inst = tiny(&mut rng, 2, 1);
        let model = TimeIndexedModel::build(&inst, inst.horizon());
        if let Some((s, m, _)) = model.solve(&inst, &MilpCfg { node_cap: 4_000, tol: 1e-6 }) {
            assert!(s.is_feasible(&inst), "{:?}", s.violations(&inst));
            assert!(m >= inst.makespan_lower_bound());
        }
    }

    #[test]
    fn too_small_horizon_is_infeasible() {
        let mut rng = crate::util::rng::Rng::seeded(5);
        let inst = tiny(&mut rng, 2, 1);
        // Horizon 1 cannot fit fwd + bwd of both clients.
        let model = TimeIndexedModel::build(&inst, 2);
        assert!(model.solve(&inst, &MilpCfg::default()).is_none());
    }
}
