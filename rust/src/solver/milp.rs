//! Generic branch-and-bound MILP solver on top of the simplex LP
//! ([`super::lp`]). Plays the role of the paper's off-the-shelf ILP
//! solver for *tiny* time-indexed models (cross-validation of the
//! specialized exact solver, unit tests of the model builder). Best-first
//! on the LP bound, branching on the most fractional integer variable.

use super::lp::{Lp, LpOutcome};
use std::collections::BinaryHeap;

#[derive(Clone, Debug)]
pub struct Milp {
    pub lp: Lp,
    /// Variables required to be integral.
    pub integer: Vec<bool>,
}

#[derive(Clone, Debug)]
pub struct MilpCfg {
    pub node_cap: usize,
    /// Absolute optimality tolerance on the objective.
    pub tol: f64,
}

impl Default for MilpCfg {
    fn default() -> Self {
        MilpCfg { node_cap: 20_000, tol: 1e-6 }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum MilpOutcome {
    Optimal { x: Vec<f64>, obj: f64, nodes: usize },
    Infeasible,
    /// Node cap hit; best incumbent (if any) and the proven bound.
    Capped { best: Option<(Vec<f64>, f64)>, bound: f64, nodes: usize },
}

struct Node {
    bound: f64,
    /// (var, is_upper, value): extra bound constraints along this branch.
    branches: Vec<(usize, bool, f64)>,
}
impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for Node {}
impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Node {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Min-heap on bound via reversed compare.
        other.bound.partial_cmp(&self.bound).unwrap_or(std::cmp::Ordering::Equal)
    }
}

impl Milp {
    pub fn solve(&self, cfg: &MilpCfg) -> MilpOutcome {
        let mut heap: BinaryHeap<Node> = BinaryHeap::new();
        let mut best: Option<(Vec<f64>, f64)> = None;
        let mut nodes = 0usize;
        heap.push(Node { bound: f64::NEG_INFINITY, branches: vec![] });
        let mut proven_bound = f64::NEG_INFINITY;

        while let Some(node) = heap.pop() {
            if let Some((_, inc)) = &best {
                if node.bound >= *inc - cfg.tol {
                    proven_bound = proven_bound.max(node.bound);
                    continue;
                }
            }
            nodes += 1;
            if nodes > cfg.node_cap {
                let bound = heap.iter().map(|n| n.bound).fold(node.bound, f64::min);
                return MilpOutcome::Capped { best, bound, nodes };
            }
            // Build the branch LP.
            let mut lp = self.lp.clone();
            for &(v, is_upper, val) in &node.branches {
                if is_upper {
                    lp.upper[v] = Some(lp.upper[v].map(|u| u.min(val)).unwrap_or(val));
                } else {
                    lp.add(vec![(v, 1.0)], super::lp::Sense::Ge, val);
                }
            }
            let (x, obj) = match lp.solve() {
                LpOutcome::Optimal { x, obj } => (x, obj),
                LpOutcome::Infeasible => continue,
                LpOutcome::Unbounded => {
                    // Unbounded relaxation of a bounded-integer model: only
                    // possible if the model itself is unbounded; treat as
                    // failure.
                    return MilpOutcome::Infeasible;
                }
            };
            if let Some((_, inc)) = &best {
                if obj >= *inc - cfg.tol {
                    continue;
                }
            }
            // Most fractional integer variable.
            let frac = |v: f64| (v - v.round()).abs();
            let branch_var = (0..x.len())
                .filter(|&v| self.integer[v] && frac(x[v]) > 1e-6)
                .max_by(|&a, &b| frac(x[a]).partial_cmp(&frac(x[b])).unwrap());
            match branch_var {
                None => {
                    // Integral: new incumbent.
                    if best.as_ref().map(|(_, inc)| obj < *inc - cfg.tol).unwrap_or(true) {
                        best = Some((x, obj));
                    }
                }
                Some(v) => {
                    let floor = x[v].floor();
                    let mut lo = node.branches.clone();
                    lo.push((v, true, floor));
                    heap.push(Node { bound: obj, branches: lo });
                    let mut hi = node.branches.clone();
                    hi.push((v, false, floor + 1.0));
                    heap.push(Node { bound: obj, branches: hi });
                }
            }
        }
        match best {
            Some((x, obj)) => MilpOutcome::Optimal { x, obj, nodes },
            None => MilpOutcome::Infeasible,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::lp::Sense;

    #[test]
    fn knapsack() {
        // max 10a + 6b + 4c st 5a + 4b + 3c <= 10, binary →
        // min -(...); optimum picks a + b + ... : a=1,b=1 (weight 9, val 16);
        // a=1,c=1 weight 8 val 14; all three weight 12 infeasible. Best 16.
        let mut lp = Lp::new(3);
        lp.objective = vec![-10.0, -6.0, -4.0];
        lp.add(vec![(0, 5.0), (1, 4.0), (2, 3.0)], Sense::Le, 10.0);
        for v in 0..3 {
            lp.upper[v] = Some(1.0);
        }
        let milp = Milp { lp, integer: vec![true; 3] };
        match milp.solve(&MilpCfg::default()) {
            MilpOutcome::Optimal { x, obj, .. } => {
                assert!((obj + 16.0).abs() < 1e-5, "obj {obj}");
                assert!((x[0] - 1.0).abs() < 1e-5 && (x[1] - 1.0).abs() < 1e-5);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn integrality_matters() {
        // LP relax gives fractional 2.5; ILP must give 2 (floor) with
        // min -x st 2x <= 5, x integer ≤ 10.
        let mut lp = Lp::new(1);
        lp.objective = vec![-1.0];
        lp.add(vec![(0, 2.0)], Sense::Le, 5.0);
        lp.upper[0] = Some(10.0);
        let milp = Milp { lp, integer: vec![true] };
        match milp.solve(&MilpCfg::default()) {
            MilpOutcome::Optimal { x, .. } => assert!((x[0] - 2.0).abs() < 1e-5),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn infeasible_integer_model() {
        // 0.4 <= x <= 0.6, x integer → infeasible.
        let mut lp = Lp::new(1);
        lp.objective = vec![1.0];
        lp.add(vec![(0, 1.0)], Sense::Ge, 0.4);
        lp.upper[0] = Some(0.6);
        let milp = Milp { lp, integer: vec![true] };
        assert_eq!(milp.solve(&MilpCfg::default()), MilpOutcome::Infeasible);
    }

    #[test]
    fn mixed_integer() {
        // min -x - y, x integer, y continuous; x + y <= 2.5, x <= 2 →
        // x = 2, y = 0.5.
        let mut lp = Lp::new(2);
        lp.objective = vec![-1.0, -1.0];
        lp.add(vec![(0, 1.0), (1, 1.0)], Sense::Le, 2.5);
        lp.upper[0] = Some(2.0);
        lp.upper[1] = Some(2.0);
        let milp = Milp { lp, integer: vec![true, false] };
        match milp.solve(&MilpCfg::default()) {
            MilpOutcome::Optimal { x, obj, .. } => {
                assert!((obj + 2.5).abs() < 1e-5);
                assert!((x[0] - 2.0).abs() < 1e-5);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn cap_returns_bound() {
        let mut lp = Lp::new(6);
        lp.objective = (0..6).map(|k| -(1.0 + k as f64 * 0.3)).collect();
        lp.add((0..6).map(|v| (v, 1.0 + (v % 3) as f64)).collect(), Sense::Le, 5.5);
        for v in 0..6 {
            lp.upper[v] = Some(1.0);
        }
        let milp = Milp { lp, integer: vec![true; 6] };
        match milp.solve(&MilpCfg { node_cap: 2, tol: 1e-6 }) {
            MilpOutcome::Capped { nodes, .. } => assert!(nodes >= 2),
            MilpOutcome::Optimal { nodes, .. } => assert!(nodes <= 3),
            other => panic!("{other:?}"),
        }
    }
}
