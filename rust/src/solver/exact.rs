//! Exact (anytime) solver for ℙ — the reference optimum that plays the
//! role of the paper's Gurobi baseline in Table II.
//!
//! Two nested branch-and-bound searches exploit the structure of ℙ:
//!
//! 1. **Outer:** DFS over memory-feasible client→helper assignments
//!    (constraints (4)–(5)). Given a full assignment the problem
//!    decomposes per helper (each helper is an independent single
//!    machine — the same observation behind Theorem 2).
//! 2. **Inner ([`helper_exact`]):** optimal preemptive schedule of one
//!    helper's two-phase jobs (fwd: release r_j, work p_j; then a fixed
//!    lag l_j + l'_j; bwd: work p'_j, tail r'_j), minimizing
//!    max_j (φ_j + r'_j). Branching happens only at *decision points*
//!    (releases and completions — sufficient for preemptive scheduling
//!    with regular objectives) on which available operation to run next.
//!
//! Both layers carry admissible lower bounds; with a node cap the solver
//! is *anytime*: it returns the incumbent, the proven lower bound and an
//! optimality flag — exactly how the paper reports Gurobi (which also
//! timed out with a 40% gap on J=20 after 14h).

use super::admm::{self, AdmmCfg};
use super::bwd;
use super::greedy;
use super::schedule::{Assignment, Schedule, SlotRuns};
use crate::instance::Instance;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct ExactCfg {
    /// Outer-search node cap.
    pub node_cap: usize,
    /// Inner (per-helper) node cap per evaluation.
    pub helper_node_cap: usize,
    /// Wall-clock budget; the solver returns the incumbent when exceeded.
    pub time_budget: Duration,
}

impl Default for ExactCfg {
    fn default() -> Self {
        ExactCfg { node_cap: 2_000_000, helper_node_cap: 400_000, time_budget: Duration::from_secs(120) }
    }
}

#[derive(Clone, Debug)]
pub struct ExactResult {
    pub schedule: Schedule,
    pub makespan: u32,
    /// Proven lower bound on the optimum (= makespan iff proven_optimal).
    pub lower_bound: u32,
    pub proven_optimal: bool,
    pub nodes: usize,
    pub elapsed: Duration,
}

/// Exact makespan of one helper processing `clients` (indices into the
/// instance), with optimal preemptive two-phase scheduling. Returns
/// (makespan contribution, fwd runs, bwd runs, proven) — runs indexed
/// like `clients`.
pub fn helper_exact(
    inst: &Instance,
    i: usize,
    clients: &[usize],
    node_cap: usize,
) -> (u32, Vec<SlotRuns>, Vec<SlotRuns>, bool) {
    let n = clients.len();
    if n == 0 {
        return (0, vec![], vec![], true);
    }
    // Pull per-job parameters.
    let r: Vec<u32> = clients.iter().map(|&j| inst.r[inst.edge(i, j)]).collect();
    let p: Vec<u32> = clients.iter().map(|&j| inst.p[inst.edge(i, j)]).collect();
    let lag: Vec<u32> = clients
        .iter()
        .map(|&j| inst.l[inst.edge(i, j)] + inst.lp[inst.edge(i, j)])
        .collect();
    let pp: Vec<u32> = clients.iter().map(|&j| inst.pp[inst.edge(i, j)]).collect();
    let tail: Vec<u32> = clients.iter().map(|&j| inst.rp[inst.edge(i, j)]).collect();

    // Incumbent from the decomposition heuristic: optimal fwd (min max
    // c^f) then optimal bwd (Algorithm 2). Often optimal already.
    let (inc_cost, inc_f, inc_b) = decomposed_schedule(&r, &p, &lag, &pp, &tail);

    struct Search<'a> {
        r: &'a [u32],
        lag: &'a [u32],
        tail: &'a [u32],
        best: u32,
        best_f: Vec<SlotRuns>,
        best_b: Vec<SlotRuns>,
        nodes: usize,
        cap: usize,
        capped: bool,
        /// Nodes pruned by the admissible bound (search statistics — the
        /// DFS is deterministic, so these are too).
        cutoffs: usize,
        max_depth: usize,
    }

    #[derive(Clone)]
    struct State {
        t: u32,
        rem_f: Vec<u32>,
        rem_b: Vec<u32>,
        /// fwd finish slot (valid when rem_f == 0).
        fin_f: Vec<u32>,
        /// cost of completed jobs so far.
        done_max: u32,
        /// (job, is_bwd, start, len) chunk log for schedule extraction —
        /// one entry per contiguous run, not per slot.
        log: Vec<(usize, bool, u32, u32)>,
    }

    impl<'a> Search<'a> {
        fn lower_bound(&self, s: &State) -> u32 {
            let n = self.r.len();
            let mut lb = s.done_max;
            let mut total_rem: u32 = 0;
            let mut min_tail_rem = u32::MAX;
            for k in 0..n {
                if s.rem_f[k] == 0 && s.rem_b[k] == 0 {
                    continue;
                }
                // Earliest possible finish of job k from state s.
                let bwd_release = if s.rem_f[k] > 0 {
                    s.t.max(self.r[k]) + s.rem_f[k] + self.lag[k]
                } else {
                    s.fin_f[k] + self.lag[k]
                };
                let fin = bwd_release.max(s.t) + s.rem_b[k];
                lb = lb.max(fin + self.tail[k]);
                total_rem += s.rem_f[k] + s.rem_b[k];
                min_tail_rem = min_tail_rem.min(self.tail[k]);
            }
            if total_rem > 0 {
                // Machine-load bound: the machine needs total_rem more busy
                // slots starting no earlier than t.
                lb = lb.max(s.t + total_rem + min_tail_rem);
            }
            lb
        }

        fn dfs(&mut self, s: &mut State, depth: usize) {
            self.nodes += 1;
            self.max_depth = self.max_depth.max(depth);
            if self.nodes > self.cap {
                self.capped = true;
                return;
            }
            if self.lower_bound(s) >= self.best {
                self.cutoffs += 1;
                return;
            }
            let n = self.r.len();
            if (0..n).all(|k| s.rem_f[k] == 0 && s.rem_b[k] == 0) {
                // done_max is the exact cost.
                if s.done_max < self.best {
                    self.best = s.done_max;
                    let (f, b) = extract(n, &s.log);
                    self.best_f = f;
                    self.best_b = b;
                }
                return;
            }
            // Available operations at time t.
            let mut avail: Vec<(usize, bool)> = Vec::new();
            for k in 0..n {
                if s.rem_f[k] > 0 && self.r[k] <= s.t {
                    avail.push((k, false));
                }
                if s.rem_b[k] > 0 && s.rem_f[k] == 0 && s.t >= s.fin_f[k] + self.lag[k] {
                    avail.push((k, true));
                }
            }
            // Future event times (releases that may change the avail set).
            let mut next_event = u32::MAX;
            for k in 0..n {
                if s.rem_f[k] > 0 && self.r[k] > s.t {
                    next_event = next_event.min(self.r[k]);
                }
                if s.rem_b[k] > 0 && s.rem_f[k] == 0 {
                    let br = s.fin_f[k] + self.lag[k];
                    if br > s.t {
                        next_event = next_event.min(br);
                    }
                }
            }
            if avail.is_empty() {
                debug_assert!(next_event != u32::MAX, "deadlock in helper_exact");
                let old_t = s.t;
                s.t = next_event;
                self.dfs(s, depth + 1);
                s.t = old_t;
                return;
            }
            // Order: bwd ops with large tails first (good incumbents early).
            avail.sort_by_key(|&(k, is_bwd)| std::cmp::Reverse((self.tail[k], is_bwd as u32)));
            for (k, is_bwd) in avail {
                let rem = if is_bwd { s.rem_b[k] } else { s.rem_f[k] };
                // Run until completion or the next release event.
                let run = if next_event == u32::MAX { rem } else { rem.min(next_event - s.t) };
                debug_assert!(run > 0);
                // Apply (one chunk entry, not one entry per slot).
                let log_len = s.log.len();
                s.log.push((k, is_bwd, s.t, run));
                let old_t = s.t;
                let old_done = s.done_max;
                s.t += run;
                if is_bwd {
                    s.rem_b[k] -= run;
                    if s.rem_b[k] == 0 {
                        s.done_max = s.done_max.max(s.t + self.tail[k]);
                    }
                } else {
                    s.rem_f[k] -= run;
                    if s.rem_f[k] == 0 {
                        s.fin_f[k] = s.t;
                    }
                }
                self.dfs(s, depth + 1);
                // Undo.
                s.log.truncate(log_len);
                s.t = old_t;
                s.done_max = old_done;
                if is_bwd {
                    s.rem_b[k] += run;
                } else {
                    if s.rem_f[k] == 0 {
                        s.fin_f[k] = 0;
                    }
                    s.rem_f[k] += run;
                }
            }
        }
    }

    // The log is in time order along the DFS path, so per-job chunks
    // arrive start-sorted and push_run normalizes/merges them directly.
    fn extract(n: usize, log: &[(usize, bool, u32, u32)]) -> (Vec<SlotRuns>, Vec<SlotRuns>) {
        let mut f = vec![SlotRuns::new(); n];
        let mut b = vec![SlotRuns::new(); n];
        for &(k, is_bwd, start, len) in log {
            if is_bwd {
                b[k].push_run(start, len);
            } else {
                f[k].push_run(start, len);
            }
        }
        (f, b)
    }

    let mut search = Search {
        r: &r,
        lag: &lag,
        tail: &tail,
        best: inc_cost + 1, // strict improvement over the incumbent
        best_f: inc_f,
        best_b: inc_b,
        nodes: 0,
        cap: node_cap,
        capped: false,
        cutoffs: 0,
        max_depth: 0,
    };
    let mut state = State {
        t: 0,
        rem_f: p.clone(),
        rem_b: pp.clone(),
        fin_f: vec![0; n],
        done_max: 0,
        log: Vec::new(),
    };
    search.dfs(&mut state, 0);
    // Search statistics (deterministic: the DFS order and bounds depend
    // only on the instance, never on wall clock).
    crate::obs::counter_add("exact.nodes", search.nodes as u64);
    crate::obs::counter_add("exact.cutoffs", search.cutoffs as u64);
    crate::obs::counter_max("exact.max_depth", search.max_depth as u64);
    let best = search.best.min(inc_cost);
    (best, search.best_f, search.best_b, !search.capped)
}

/// The ℙ_f → ℙ_b decomposition applied to a single helper: optimal fwd
/// (Baker, tails l folded into the lag), then Algorithm 2 for bwd.
/// Used as the inner incumbent and by `makespan_given_assignment`.
fn decomposed_schedule(
    r: &[u32],
    p: &[u32],
    lag: &[u32],
    pp: &[u32],
    tail: &[u32],
) -> (u32, Vec<SlotRuns>, Vec<SlotRuns>) {
    let n = r.len();
    let fwd_jobs: Vec<bwd::Job> = (0..n)
        .map(|k| bwd::Job { id: k, release: r[k], proc: p[k], tail: lag[k] })
        .collect();
    let fruns = bwd::preemptive_min_max_tail_contiguous(&fwd_jobs);

    let busy = SlotRuns::union_of(fruns.iter());
    let bwd_jobs: Vec<bwd::Job> = (0..n)
        .map(|k| bwd::Job { id: k, release: fruns[k].finish() + lag[k], proc: pp[k], tail: tail[k] })
        .collect();
    let horizon_b = bwd_jobs.iter().map(|j| j.release).max().unwrap() + pp.iter().sum::<u32>() + busy.len() + 1;
    let free_b = busy.complement(horizon_b);
    let bruns = bwd::preemptive_min_max_tail(&bwd_jobs, &free_b);
    let cost = bwd::max_tail_cost(&bwd_jobs, &bruns);
    (cost, fruns, bruns)
}

/// Exact makespan for a *fixed* assignment (per-helper exact search).
/// Returns (schedule, makespan, proven).
pub fn schedule_given_assignment(inst: &Instance, assignment: &Assignment, helper_cap: usize) -> (Schedule, u32, bool) {
    let mut fwd = vec![SlotRuns::new(); inst.n_clients];
    let mut bwdv = vec![SlotRuns::new(); inst.n_clients];
    let mut makespan = 0;
    let mut proven = true;
    for (i, clients) in assignment.members_by_helper(inst.n_helpers).into_iter().enumerate() {
        let (m, f, b, ok) = helper_exact(inst, i, &clients, helper_cap);
        makespan = makespan.max(m);
        proven &= ok;
        for (k, &j) in clients.iter().enumerate() {
            fwd[j] = f.get(k).cloned().unwrap_or_default();
            bwdv[j] = b.get(k).cloned().unwrap_or_default();
        }
    }
    (Schedule { assignment: assignment.clone(), fwd, bwd: bwdv }, makespan, proven)
}

/// Admissible per-client completion lower bound over a helper choice set.
fn client_lb(inst: &Instance, j: usize, helpers: &[usize]) -> u32 {
    helpers
        .iter()
        .map(|&i| {
            let e = inst.edge(i, j);
            inst.r[e] + inst.p[e] + inst.l[e] + inst.lp[e] + inst.pp[e] + inst.rp[e]
        })
        .min()
        .unwrap_or(u32::MAX)
}

/// Lower bound for a helper's currently-assigned subset: load bound
/// (earliest release + total work + smallest tail) and per-client bound.
fn helper_lb(inst: &Instance, i: usize, clients: &[usize]) -> u32 {
    if clients.is_empty() {
        return 0;
    }
    let mut min_rel = u32::MAX;
    let mut work = 0u32;
    let mut min_tail = u32::MAX;
    let mut per_client = 0u32;
    for &j in clients {
        let e = inst.edge(i, j);
        min_rel = min_rel.min(inst.r[e]);
        work += inst.p[e] + inst.pp[e];
        min_tail = min_tail.min(inst.rp[e]);
        per_client = per_client.max(inst.r[e] + inst.p[e] + inst.l[e] + inst.lp[e] + inst.pp[e] + inst.rp[e]);
    }
    per_client.max(min_rel + work + min_tail)
}

/// Full exact solve of ℙ.
pub fn solve(inst: &Instance, cfg: &ExactCfg) -> ExactResult {
    let start = Instant::now();
    let jn = inst.n_clients;
    let in_ = inst.n_helpers;

    // Incumbent: best of balanced-greedy and ADMM, re-scheduled exactly
    // per helper (the assignment is kept, the schedule is optimized).
    let mut best_assignment: Option<Assignment> = None;
    let mut best_make = u32::MAX;
    let mut incumbents: Vec<Assignment> = Vec::new();
    if let Some(g) = greedy::solve(inst) {
        incumbents.push(g.assignment);
    }
    if let Some(a) = admm::solve(inst, &AdmmCfg::default()) {
        incumbents.push(a.schedule.assignment);
    }
    for a in incumbents {
        let (_, m, _) = schedule_given_assignment(inst, &a, cfg.helper_node_cap);
        if m < best_make {
            best_make = m;
            best_assignment = Some(a);
        }
    }

    // Root lower bound.
    let all_helpers: Vec<usize> = (0..in_).collect();
    let root_lb = (0..jn).map(|j| client_lb(inst, j, &all_helpers)).max().unwrap_or(0);

    // Branch order: clients with the largest work first.
    let mut order: Vec<usize> = (0..jn).collect();
    order.sort_by_key(|&j| {
        let w: u32 = (0..in_).map(|i| inst.p[inst.edge(i, j)] + inst.pp[inst.edge(i, j)]).min().unwrap_or(0);
        std::cmp::Reverse(w)
    });

    struct Outer<'a> {
        inst: &'a Instance,
        cfg: &'a ExactCfg,
        order: &'a [usize],
        best: u32,
        best_assignment: Option<Assignment>,
        nodes: usize,
        capped: bool,
        cutoffs: usize,
        start: Instant,
    }
    impl<'a> Outer<'a> {
        fn dfs(&mut self, k: usize, helper_of: &mut Vec<usize>, per_helper: &mut Vec<Vec<usize>>, free: &mut Vec<f64>) {
            self.nodes += 1;
            if self.nodes > self.cfg.node_cap || self.start.elapsed() > self.cfg.time_budget {
                self.capped = true;
                return;
            }
            // Bound: per-helper LBs of the partial assignment + remaining
            // clients' best-case completions.
            let mut lb = (0..self.inst.n_helpers)
                .map(|i| helper_lb(self.inst, i, &per_helper[i]))
                .max()
                .unwrap_or(0);
            for &j in &self.order[k..] {
                let allowed: Vec<usize> = (0..self.inst.n_helpers).filter(|&i| free[i] >= self.inst.d[j]).collect();
                if allowed.is_empty() {
                    return; // memory-infeasible branch
                }
                lb = lb.max(client_lb(self.inst, j, &allowed));
            }
            if lb >= self.best {
                self.cutoffs += 1;
                return;
            }
            if k == self.order.len() {
                // Leaf: exact per-helper schedule.
                let a = Assignment::new(helper_of.clone());
                let (_, m, _) = schedule_given_assignment(self.inst, &a, self.cfg.helper_node_cap);
                if m < self.best {
                    self.best = m;
                    self.best_assignment = Some(a);
                }
                return;
            }
            let j = self.order[k];
            // Try helpers in order of the cheapest LB increase.
            let mut choices: Vec<(u32, usize)> = (0..self.inst.n_helpers)
                .filter(|&i| free[i] >= self.inst.d[j])
                .map(|i| {
                    per_helper[i].push(j);
                    let b = helper_lb(self.inst, i, &per_helper[i]);
                    per_helper[i].pop();
                    (b, i)
                })
                .collect();
            choices.sort();
            for (_, i) in choices {
                helper_of[j] = i;
                per_helper[i].push(j);
                free[i] -= self.inst.d[j];
                self.dfs(k + 1, helper_of, per_helper, free);
                free[i] += self.inst.d[j];
                per_helper[i].pop();
                if self.capped {
                    return;
                }
            }
        }
    }

    let mut outer = Outer {
        inst,
        cfg,
        order: &order,
        best: best_make,
        best_assignment: best_assignment.clone(),
        nodes: 0,
        capped: false,
        cutoffs: 0,
        start,
    };
    let mut helper_of = vec![0usize; jn];
    let mut per_helper = vec![Vec::new(); in_];
    let mut free = inst.mem.clone();
    {
        let mut sp = crate::obs::span("solver", "exact/outer-dfs");
        outer.dfs(0, &mut helper_of, &mut per_helper, &mut free);
        sp.arg("nodes", outer.nodes as u64);
    }
    // Outer assignment-search statistics. Depth is bounded by the client
    // count, so the outer contribution to exact.max_depth is the number
    // of assigned clients on the deepest explored branch.
    crate::obs::counter_add("exact.nodes", outer.nodes as u64);
    crate::obs::counter_add("exact.cutoffs", outer.cutoffs as u64);

    let assignment = outer.best_assignment.expect("at least the incumbent exists");
    let (schedule, makespan, leaf_proven) = schedule_given_assignment(inst, &assignment, cfg.helper_node_cap);
    let proven = !outer.capped && leaf_proven;
    ExactResult {
        schedule,
        makespan,
        lower_bound: if proven { makespan } else { root_lb.min(makespan) },
        proven_optimal: proven,
        nodes: outer.nodes,
        elapsed: start.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};
    use crate::util::prop;

    #[test]
    fn exact_beats_or_matches_heuristics() {
        prop::check(15, |rng| {
            let jn = rng.range_usize(2, 6);
            let inst = crate::solver::schedule::tests::tiny_instance(rng, jn, 2);
            let res = solve(&inst, &ExactCfg::default());
            prop::assert_prop(res.schedule.is_feasible(&inst) || !res.schedule.assignment.memory_ok(&inst),
                "exact schedule feasible");
            let g = greedy::solve(&inst).map(|s| s.makespan(&inst)).unwrap_or(u32::MAX);
            let a = admm::solve(&inst, &AdmmCfg::default()).map(|r| r.schedule.makespan(&inst)).unwrap_or(u32::MAX);
            prop::assert_prop(res.makespan <= g.min(a), &format!("exact {} > min(greedy {g}, admm {a})", res.makespan));
        });
    }

    #[test]
    fn helper_exact_at_least_lb_and_feasible() {
        prop::check(30, |rng| {
            let inst = crate::solver::schedule::tests::tiny_instance(rng, 4, 1);
            let clients: Vec<usize> = (0..4).collect();
            let (m, f, b, proven) = helper_exact(&inst, 0, &clients, 1_000_000);
            prop::assert_prop(proven, "tiny case should be proven");
            prop::assert_prop(m >= helper_lb(&inst, 0, &clients), "makespan >= LB");
            // Assemble and check.
            let sched = Schedule {
                assignment: Assignment::new(vec![0; 4]),
                fwd: f,
                bwd: b,
            };
            let hard: Vec<_> = sched.violations(&inst).into_iter().filter(|v| !v.starts_with("(5)")).collect();
            prop::assert_prop(hard.is_empty(), &format!("{hard:?}"));
            prop::assert_prop(sched.makespan(&inst) == m, "extracted schedule matches cost");
        });
    }

    #[test]
    fn helper_exact_never_worse_than_decomposition() {
        prop::check(40, |rng| {
            let inst = crate::solver::schedule::tests::tiny_instance(rng, 5, 1);
            let clients: Vec<usize> = (0..5).collect();
            let (m, _, _, _) = helper_exact(&inst, 0, &clients, 500_000);
            let a = Assignment::new(vec![0; 5]);
            let fwd = admm::schedule_fwd_given_assignment(&inst, &a.helper_of);
            let dec = bwd::complete_with_optimal_bwd(&inst, a, fwd);
            prop::assert_prop(m <= dec.makespan(&inst), "exact <= decomposed");
        });
    }

    #[test]
    fn proven_on_small_scenario_instance() {
        let inst = ScenarioCfg::new(Scenario::S1, Model::Vgg19, 6, 2, 21).generate().quantize(550.0);
        let res = solve(&inst, &ExactCfg { time_budget: Duration::from_secs(30), ..Default::default() });
        assert!(res.makespan >= res.lower_bound);
        assert!(res.schedule.is_feasible(&inst), "{:?}", res.schedule.violations(&inst));
    }

    #[test]
    fn anytime_under_tight_caps() {
        let inst = ScenarioCfg::new(Scenario::S2, Model::ResNet101, 12, 4, 8).generate().quantize(180.0);
        let res = solve(&inst, &ExactCfg { node_cap: 50, helper_node_cap: 100, time_budget: Duration::from_secs(5) });
        // Still returns a feasible incumbent.
        assert!(res.schedule.is_feasible(&inst));
        assert!(res.makespan > 0);
    }
}
