//! A tiny property-based testing kit (no `proptest` in this image).
//!
//! Usage inside a `#[test]`:
//! ```ignore
//! prop::check(256, |rng| {
//!     let inst = Instance::random(rng, ...);
//!     let sched = solve(&inst);
//!     prop::assert_prop(sched.is_feasible(&inst), "schedule must be feasible");
//! });
//! ```
//!
//! Every case runs with an independent, *deterministic* RNG derived from a
//! base seed and the case index, so a failure report (`case #k, seed s`)
//! reproduces exactly. `PSL_PROP_SEED` overrides the base seed and
//! `PSL_PROP_CASES` scales the number of cases (useful for a long fuzzing
//! soak).

use super::rng::Rng;

/// The base seed; override with env `PSL_PROP_SEED`.
pub fn base_seed() -> u64 {
    std::env::var("PSL_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0x5EED_CAFE)
}

/// Number-of-cases multiplier; override with env `PSL_PROP_CASES` (a float,
/// e.g. `4` runs 4x more cases).
pub fn case_multiplier() -> f64 {
    std::env::var("PSL_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(1.0)
}

/// Run `f` over `cases` deterministic random cases. `f` receives a fresh
/// RNG per case; panics are annotated with the case index and seed so the
/// failing case can be replayed.
pub fn check<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(cases: usize, f: F) {
    let seed = base_seed();
    let n = ((cases as f64) * case_multiplier()).ceil() as usize;
    for k in 0..n {
        let case_seed = seed ^ (k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::seeded(case_seed);
            f(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property failed at case #{k} (seed {case_seed:#x}, base {seed:#x}): {msg}");
        }
    }
}

/// Assertion helper carrying a property label.
#[track_caller]
pub fn assert_prop(cond: bool, label: &str) {
    assert!(cond, "property violated: {label}");
}

/// Assert |a - b| <= tol with a labelled message.
#[track_caller]
pub fn assert_close(a: f64, b: f64, tol: f64, label: &str) {
    assert!((a - b).abs() <= tol, "property violated: {label}: |{a} - {b}| > {tol}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let counter = std::sync::atomic::AtomicUsize::new(0);
        check(16, |_| {
            counter.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        // >= because PSL_PROP_CASES may scale it up in a soak run.
        assert!(counter.load(std::sync::atomic::Ordering::SeqCst) >= 16);
    }

    #[test]
    fn failure_reports_case() {
        let r = std::panic::catch_unwind(|| {
            check(8, |rng| {
                // Fails deterministically on some case.
                assert!(rng.f64() < 0.5, "coin");
            });
        });
        let msg = match r {
            Err(e) => e.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(_) => panic!("expected failure"),
        };
        assert!(msg.contains("case #"), "got: {msg}");
        assert!(msg.contains("seed"), "got: {msg}");
    }

    #[test]
    fn deterministic_cases() {
        let mut first: Vec<u64> = vec![];
        check(4, |rng| {
            let _ = rng; // values recorded below by replaying same seeds
        });
        // replay manually: same derivation must give same streams
        let seed = base_seed();
        for k in 0..4u64 {
            let cs = seed ^ k.wrapping_mul(0x9E37_79B9_7F4A_7C15);
            first.push(Rng::seeded(cs).next_u64());
        }
        let second: Vec<u64> = (0..4u64)
            .map(|k| Rng::seeded(seed ^ k.wrapping_mul(0x9E37_79B9_7F4A_7C15)).next_u64())
            .collect();
        assert_eq!(first, second);
    }
}
