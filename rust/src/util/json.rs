//! Minimal JSON value model, parser and serializer.
//!
//! The image's registry ships no `serde`/`serde_json`, so configuration
//! files, metrics dumps and experiment records go through this ~400-line
//! subset implementation. It supports the full JSON grammar except for
//! `\u` surrogate pairs beyond the BMP (sufficient for config files, which
//! are ASCII).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so serialization is deterministic
/// (stable key order) — important for artifact fingerprinting in `make`.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| if x >= 0.0 && x.fract() == 0.0 { Some(x as usize) } else { None })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; Null for anything that is not an object or a
    /// missing key (convenient for optional config fields).
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.dump())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 character.
                    let rest = std::str::from_utf8(&self.b[self.pos..]).map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "1e3", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            let v2 = Json::parse(&v.dump()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": -2.5e-1}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("d").as_f64(), Some(-0.25));
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "{\"a\" 1}", "tru", "1.2.3x", "[1] x"] {
            assert!(Json::parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd\u{0007}".to_string());
        let parsed = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, parsed);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "b": true}"#).unwrap();
        assert_eq!(v.get("n").as_usize(), Some(3));
        assert_eq!(v.get("s").as_str(), Some("x"));
        assert_eq!(v.get("b").as_bool(), Some(true));
        assert_eq!(v.get("missing").as_str(), None);
    }

    #[test]
    fn deterministic_key_order() {
        let a = Json::parse(r#"{"z":1,"a":2}"#).unwrap().dump();
        let b = Json::parse(r#"{"a":2,"z":1}"#).unwrap().dump();
        assert_eq!(a, b);
    }
}
