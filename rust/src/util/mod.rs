//! Foundational substrates: deterministic RNG, statistics, JSON, a
//! property-testing kit and a logger. Everything here is dependency-free
//! (the image's registry has no rand/serde/proptest/criterion).

pub mod json;
pub mod logger;
pub mod prop;
pub mod rng;
pub mod stats;
