//! Deterministic pseudo-random number generation.
//!
//! The image's cargo registry ships no `rand` crate, so we implement the
//! generators we need from scratch:
//!
//! * [`SplitMix64`] — tiny, used to seed the main generator and to derive
//!   independent streams from a single scenario seed.
//! * [`Xoshiro256``**```][Xoshiro256] — the workhorse generator
//!   (Blackman & Vigna, 2018). Fast, 256-bit state, passes BigCrush.
//!
//! On top of the raw generator we provide the distributions the scenario
//! generator and the simulator need: uniform ints/floats, Bernoulli,
//! normal (Box–Muller), lognormal (for delay jitter), exponential, choice,
//! weighted choice, and Fisher–Yates shuffle.
//!
//! All of this is deterministic given a seed — every experiment in
//! EXPERIMENTS.md records its seed and is exactly reproducible.

/// FNV-1a over a label — the crate's standard way to fold a string into a
/// 64-bit seed component (scenario/model names, sweep cell coordinates,
/// substream labels). Keep this the single copy: seed derivations in
/// different modules must agree on the hash.
pub fn fnv64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// SplitMix64: a 64-bit state PRNG used for seeding and stream splitting.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the main generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box–Muller.
    gauss_cache: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_cache: None,
        }
    }

    /// Derive an independent stream for a named sub-component.
    /// Mixing the label keeps streams decorrelated even for nearby seeds.
    pub fn substream(&self, label: &str) -> Rng {
        let h = fnv64(label);
        // Combine with the current state deterministically (do not advance self).
        Rng::seeded(h ^ self.s[0].rotate_left(17) ^ self.s[2])
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → uniform double in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(hi >= lo);
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift (unbiased enough
    /// for simulation purposes; we accept the tiny modulo bias for n near 2^64).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_cache.take() {
            return v;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_cache = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal(mu, sigma).
    #[inline]
    pub fn normal(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.gauss()
    }

    /// Lognormal such that the *median* is `median` and the multiplicative
    /// spread (sigma of the underlying normal) is `sigma_log`.
    /// Used for delay jitter: profiles give a median time, heterogeneity
    /// multiplies it by exp(N(0, sigma_log)).
    #[inline]
    pub fn lognormal_median(&mut self, median: f64, sigma_log: f64) -> f64 {
        median * (sigma_log * self.gauss()).exp()
    }

    /// Exponential with rate lambda.
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Uniform choice from a slice.
    #[inline]
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Weighted choice: weights need not be normalized.
    pub fn weighted_choice(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seeded(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::seeded(9);
        for n in [1usize, 2, 3, 10, 1000] {
            for _ in 0..1000 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn below_covers_support() {
        let mut r = Rng::seeded(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::seeded(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_median_is_median() {
        let mut r = Rng::seeded(17);
        let n = 20_001;
        let mut xs: Vec<f64> = (0..n).map(|_| r.lognormal_median(50.0, 0.3)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[n / 2];
        assert!((med - 50.0).abs() / 50.0 < 0.05, "median {med}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(19);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut r = Rng::seeded(23);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted_choice(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac2 = counts[2] as f64 / 30_000.0;
        assert!((frac2 - 0.7).abs() < 0.03, "frac {frac2}");
    }

    #[test]
    fn fnv64_distinguishes_labels_and_is_stable() {
        assert_ne!(fnv64("scenario1"), fnv64("scenario2"));
        assert_ne!(fnv64("admm"), fnv64("greedy"));
        // FNV-1a offset basis for the empty string — pins the constants so
        // seed derivations across modules can't silently drift.
        assert_eq!(fnv64(""), 0xcbf29ce484222325);
        assert_eq!(fnv64("churn"), fnv64("churn"));
    }

    #[test]
    fn substream_independent_of_parent_advancement() {
        let r = Rng::seeded(31);
        let mut s1 = r.substream("alpha");
        let mut s2 = r.substream("alpha");
        assert_eq!(s1.next_u64(), s2.next_u64());
        let mut s3 = r.substream("beta");
        assert_ne!(s1.next_u64(), s3.next_u64());
    }
}
