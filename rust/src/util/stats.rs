//! Small statistics toolkit used by the benchmark harness and the
//! experiment reports (no `criterion` in this image, so we roll our own
//! summary statistics: mean, stddev, percentiles, confidence intervals).

/// Summary statistics over a sample of f64 observations.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns a zeroed summary for an empty sample.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0, p50: 0.0, p90: 0.0, p99: 0.0 };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }

    /// Half-width of a ~95% normal-approximation confidence interval.
    pub fn ci95(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        1.96 * self.std / (self.n as f64).sqrt()
    }
}

/// Linear-interpolated percentile of an already-sorted slice; q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// Geometric mean of strictly-positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Relative gap (a - b) / b in percent — how much worse `a` is than `b`.
pub fn rel_gap_pct(a: f64, b: f64) -> f64 {
    if b == 0.0 { 0.0 } else { (a - b) / b * 100.0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 1.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn rel_gap() {
        assert!((rel_gap_pct(110.0, 100.0) - 10.0).abs() < 1e-12);
        assert_eq!(rel_gap_pct(1.0, 0.0), 0.0);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a = Summary::of(&vec![1.0, 2.0, 1.5, 2.5]);
        let big: Vec<f64> = (0..400).map(|i| 1.0 + (i % 4) as f64 * 0.5).collect();
        let b = Summary::of(&big);
        assert!(b.ci95() < a.ci95());
    }
}
