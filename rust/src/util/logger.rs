//! Leveled stderr logger with timestamps on the shared relative clock.
//!
//! No `log`/`env_logger` wiring is needed for a binary this size; the
//! coordinator and the SL runtime log through these macros. Level is
//! controlled by `PSL_LOG` (`off|error|warn|info|debug|trace`, default
//! `info`); an unknown value warns once on stderr (naming the bad value)
//! and falls back to `info`. Timestamps are seconds since
//! [`crate::obs::epoch`] — the same relative clock trace spans use, so a
//! log line and a span covering the same work show the same time.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Once;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

/// Encoded as a *threshold*: the count of enabled levels (0 = off,
/// 1 = error only, …, 5 = trace). `u8::MAX` = not yet initialized.
static THRESHOLD: AtomicU8 = AtomicU8::new(u8::MAX);

const DEFAULT_THRESHOLD: u8 = Level::Info as u8 + 1;

/// Parse a `PSL_LOG` value into a threshold (enabled-level count).
/// `None` for unrecognized values — the caller decides the fallback.
pub fn parse_threshold(s: &str) -> Option<u8> {
    Some(match s.trim().to_ascii_lowercase().as_str() {
        "off" | "none" => 0,
        "error" => Level::Error as u8 + 1,
        "warn" | "warning" => Level::Warn as u8 + 1,
        "info" => Level::Info as u8 + 1,
        "debug" => Level::Debug as u8 + 1,
        "trace" => Level::Trace as u8 + 1,
        _ => return None,
    })
}

fn threshold() -> u8 {
    let cur = THRESHOLD.load(Ordering::Relaxed);
    if cur != u8::MAX {
        return cur;
    }
    let parsed = match std::env::var("PSL_LOG") {
        Err(_) => DEFAULT_THRESHOLD,
        Ok(v) if v.is_empty() => DEFAULT_THRESHOLD,
        Ok(v) => match parse_threshold(&v) {
            Some(t) => t,
            None => {
                // Warn exactly once, naming the value — a typo'd PSL_LOG
                // must not silently read as `info`.
                static WARN_ONCE: Once = Once::new();
                WARN_ONCE.call_once(|| {
                    eprintln!(
                        "psl: unknown PSL_LOG value {v:?} (expected off|error|warn|info|debug|trace); using info"
                    );
                });
                DEFAULT_THRESHOLD
            }
        },
    };
    THRESHOLD.store(parsed, Ordering::Relaxed);
    parsed
}

/// Force the level programmatically (CLI `-v` flags).
pub fn set_level(l: Level) {
    THRESHOLD.store(l as u8 + 1, Ordering::Relaxed);
}

/// Silence the logger entirely (the programmatic `off`).
pub fn set_off() {
    THRESHOLD.store(0, Ordering::Relaxed);
}

/// True if `l` is enabled.
pub fn enabled(l: Level) -> bool {
    (l as u8) < threshold()
}

/// Log a preformatted line (used by the macros).
pub fn log_line(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    // Shared timebase with the span recorder: one epoch for both.
    let t = crate::obs::epoch().elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:10.4}s {tag} {target}] {msg}");
}

#[macro_export]
macro_rules! log_error { ($($a:tt)*) => { $crate::util::logger::log_line($crate::util::logger::Level::Error, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_warn { ($($a:tt)*) => { $crate::util::logger::log_line($crate::util::logger::Level::Warn, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_info { ($($a:tt)*) => { $crate::util::logger::log_line($crate::util::logger::Level::Info, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_debug { ($($a:tt)*) => { $crate::util::logger::log_line($crate::util::logger::Level::Debug, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_trace { ($($a:tt)*) => { $crate::util::logger::log_line($crate::util::logger::Level::Trace, module_path!(), format_args!($($a)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes the tests that mutate the global threshold.
    static LEVEL_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn level_ordering() {
        let _g = LEVEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        // Restore the default so parallel tests see stock behavior.
        set_level(Level::Info);
    }

    #[test]
    fn off_disables_everything() {
        let _g = LEVEL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        assert_eq!(parse_threshold("off"), Some(0));
        assert_eq!(parse_threshold("OFF"), Some(0));
        // Threshold 0 enables nothing, not even Error.
        set_off();
        assert!(!enabled(Level::Error));
        set_level(Level::Info);
    }

    #[test]
    fn parse_threshold_accepts_known_and_rejects_unknown() {
        assert_eq!(parse_threshold("error"), Some(1));
        assert_eq!(parse_threshold("warn"), Some(2));
        assert_eq!(parse_threshold(" Info "), Some(3));
        assert_eq!(parse_threshold("debug"), Some(4));
        assert_eq!(parse_threshold("trace"), Some(5));
        assert_eq!(parse_threshold("verbose"), None);
        assert_eq!(parse_threshold("inf0"), None);
    }
}
