//! Leveled stderr logger with wall-clock-relative timestamps.
//!
//! No `log`/`env_logger` wiring is needed for a binary this size; the
//! coordinator and the SL runtime log through these macros. Level is
//! controlled by `PSL_LOG` (error|warn|info|debug|trace), default `info`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static START: OnceLock<Instant> = OnceLock::new();

fn level() -> u8 {
    let cur = LEVEL.load(Ordering::Relaxed);
    if cur != u8::MAX {
        return cur;
    }
    let parsed = match std::env::var("PSL_LOG").unwrap_or_default().to_lowercase().as_str() {
        "error" => 0,
        "warn" => 1,
        "debug" => 3,
        "trace" => 4,
        _ => 2,
    };
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Force the level programmatically (CLI `-v` flags).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// True if `l` is enabled.
pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

/// Log a preformatted line (used by the macros).
pub fn log_line(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:10.4}s {tag} {target}] {msg}");
}

#[macro_export]
macro_rules! log_error { ($($a:tt)*) => { $crate::util::logger::log_line($crate::util::logger::Level::Error, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_warn { ($($a:tt)*) => { $crate::util::logger::log_line($crate::util::logger::Level::Warn, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_info { ($($a:tt)*) => { $crate::util::logger::log_line($crate::util::logger::Level::Info, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_debug { ($($a:tt)*) => { $crate::util::logger::log_line($crate::util::logger::Level::Debug, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_trace { ($($a:tt)*) => { $crate::util::logger::log_line($crate::util::logger::Level::Trace, module_path!(), format_args!($($a)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
    }
}
