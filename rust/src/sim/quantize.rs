//! The Fig-6 experiment machinery: the same continuous system quantized at
//! different slot lengths |S_t|, solved, and replayed.
//!
//! Observation 2 of the paper: longer slots → coarser preemption and
//! ceil-inflated processing times → larger (nominal) makespan, but a
//! smaller time horizon T → faster solve. This module produces those rows.

use super::engine;
use crate::instance::InstanceMs;
use crate::solver::admm::{self, AdmmCfg};
use std::time::Instant;

/// One row of the slot-length sweep.
#[derive(Clone, Debug)]
pub struct SlotRow {
    pub slot_ms: f64,
    /// Horizon T (number of slots) at this quantization.
    pub horizon: u32,
    /// Nominal makespan: slots × slot_ms.
    pub nominal_ms: f64,
    /// Realized makespan from the continuous replay.
    pub realized_ms: f64,
    /// Solver wall time (seconds).
    pub solve_s: f64,
    /// Preemption count in the solution.
    pub preemptions: u32,
}

/// Solve the instance with the ADMM-based method at each slot length.
pub fn sweep_slot_lengths(ms: &InstanceMs, slot_lengths: &[f64], cfg: &AdmmCfg) -> Vec<SlotRow> {
    slot_lengths
        .iter()
        .map(|&slot_ms| {
            let inst = ms.quantize(slot_ms);
            let start = Instant::now();
            let res = admm::solve(&inst, cfg).expect("feasible instance");
            let solve_s = start.elapsed().as_secs_f64();
            let rep = engine::replay(ms, &res.schedule, None);
            SlotRow {
                slot_ms,
                horizon: inst.horizon(),
                nominal_ms: res.schedule.makespan(&inst) as f64 * slot_ms,
                realized_ms: rep.makespan_ms,
                solve_s,
                preemptions: res.schedule.preemptions(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};

    #[test]
    fn horizon_shrinks_with_slot_length() {
        let ms = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 8, 2, 33).generate();
        let rows = sweep_slot_lengths(&ms, &[50.0, 150.0, 200.0], &AdmmCfg::default());
        assert!(rows[0].horizon > rows[1].horizon);
        assert!(rows[1].horizon >= rows[2].horizon);
    }

    #[test]
    fn nominal_dominates_realized() {
        let ms = ScenarioCfg::new(Scenario::S1, Model::Vgg19, 8, 2, 21).generate();
        for row in sweep_slot_lengths(&ms, &[550.0, 150.0], &AdmmCfg::default()) {
            assert!(row.realized_ms <= row.nominal_ms + 1e-6, "{row:?}");
        }
    }
}
