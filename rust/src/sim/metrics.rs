//! Schedule/replay inspection: Gantt export (JSON) and summary metrics
//! used by the CLI (`psl solve --gantt`) and the experiment reports.

use crate::instance::Instance;
use crate::solver::schedule::Schedule;
use crate::util::json::Json;

/// Summary of a slotted schedule.
#[derive(Clone, Debug)]
pub struct ScheduleMetrics {
    pub makespan_slots: u32,
    pub makespan_ms: f64,
    pub fwd_makespan_slots: u32,
    pub preemptions: u32,
    pub mean_queuing_slots: f64,
    pub max_queuing_slots: i64,
    /// Per-helper busy-slot counts.
    pub helper_load: Vec<u32>,
    /// Per-helper utilization relative to the makespan.
    pub helper_util: Vec<f64>,
}

pub fn summarize(inst: &Instance, s: &Schedule) -> ScheduleMetrics {
    let makespan = s.makespan(inst);
    let mut load = vec![0u32; inst.n_helpers];
    for j in 0..inst.n_clients {
        let i = s.assignment.helper_of[j];
        load[i] += s.fwd[j].len() + s.bwd[j].len();
    }
    let queuing: Vec<i64> = (0..inst.n_clients).map(|j| s.queuing_delay(inst, j)).collect();
    ScheduleMetrics {
        makespan_slots: makespan,
        makespan_ms: makespan as f64 * inst.slot_ms,
        fwd_makespan_slots: s.fwd_makespan(inst),
        preemptions: s.preemptions(),
        mean_queuing_slots: queuing.iter().map(|&q| q.max(0) as f64).sum::<f64>() / inst.n_clients.max(1) as f64,
        max_queuing_slots: queuing.iter().copied().max().unwrap_or(0),
        helper_load: load.clone(),
        helper_util: load
            .iter()
            .map(|&b| if makespan > 0 { b as f64 / makespan as f64 } else { 0.0 })
            .collect(),
    }
}

/// Export a schedule as a Gantt JSON document: one entry per contiguous
/// segment, grouped by helper — renderable by any plotting tool. The
/// run-length representation already stores exactly these segments.
pub fn gantt_json(inst: &Instance, s: &Schedule) -> Json {
    let mut rows = Vec::new();
    for j in 0..inst.n_clients {
        let i = s.assignment.helper_of[j];
        for (runs, phase) in [(&s.fwd[j], "fwd"), (&s.bwd[j], "bwd")] {
            for &(start, len) in runs.runs() {
                let end = start + len;
                rows.push(Json::obj(vec![
                    ("helper", Json::Num(i as f64)),
                    ("client", Json::Num(j as f64)),
                    ("phase", Json::Str(phase.to_string())),
                    ("start_slot", Json::Num(start as f64)),
                    ("end_slot", Json::Num(end as f64)),
                    ("start_ms", Json::Num(start as f64 * inst.slot_ms)),
                    ("end_ms", Json::Num(end as f64 * inst.slot_ms)),
                ]));
            }
        }
    }
    Json::obj(vec![
        ("slot_ms", Json::Num(inst.slot_ms)),
        ("makespan_slots", Json::Num(s.makespan(inst) as f64)),
        ("segments", Json::Arr(rows)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::profiles::Model;
    use crate::instance::scenario::{Scenario, ScenarioCfg};
    use crate::solver::greedy;

    #[test]
    fn metrics_consistent() {
        let inst = ScenarioCfg::new(Scenario::S1, Model::ResNet101, 10, 2, 77).generate().quantize(180.0);
        let s = greedy::solve(&inst).unwrap();
        let m = summarize(&inst, &s);
        assert_eq!(m.makespan_slots, s.makespan(&inst));
        assert!((m.makespan_ms - m.makespan_slots as f64 * 180.0).abs() < 1e-9);
        assert_eq!(m.preemptions, 0, "FCFS never preempts");
        let total_load: u32 = m.helper_load.iter().sum();
        let expected: u32 = (0..10)
            .map(|j| {
                let e = inst.edge(s.assignment.helper_of[j], j);
                inst.p[e] + inst.pp[e]
            })
            .sum();
        assert_eq!(total_load, expected);
    }

    #[test]
    fn gantt_covers_all_work() {
        let inst = ScenarioCfg::new(Scenario::S2, Model::Vgg19, 6, 2, 3).generate().quantize(550.0);
        let s = greedy::solve(&inst).unwrap();
        let g = gantt_json(&inst, &s);
        let segs = g.get("segments").as_arr().unwrap();
        let covered: f64 = segs
            .iter()
            .map(|seg| seg.get("end_slot").as_f64().unwrap() - seg.get("start_slot").as_f64().unwrap())
            .sum();
        let expected: u32 = (0..6)
            .map(|j| {
                let e = inst.edge(s.assignment.helper_of[j], j);
                inst.p[e] + inst.pp[e]
            })
            .sum();
        assert_eq!(covered as u32, expected);
        // JSON parses back.
        let txt = g.pretty();
        assert!(crate::util::json::Json::parse(&txt).is_ok());
    }
}
